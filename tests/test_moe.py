"""MoE sort-based dispatch vs a dense per-token reference."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MoESpec
from repro.models import moe as moe_lib

KEY = jax.random.PRNGKey(0)


def _dense_moe_ref(params, x, spec):
    """Every token through its top-k experts, no capacity limit."""
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_i = jax.lax.top_k(probs, spec.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf, jnp.float32)
    for e in range(spec.n_experts):
        h = jax.nn.silu(xf @ params["w1"][e]) * (xf @ params["w3"][e])
        y = h @ params["w2"][e]
        w = jnp.where(top_i == e, top_p, 0.0).sum(-1)
        out = out + w[:, None] * y
    return out.reshape(b, s, d)


@pytest.mark.parametrize("b,s,d,e,k", [(2, 16, 8, 4, 2), (1, 32, 16, 8, 3)])
def test_moe_matches_dense_when_no_drops(b, s, d, e, k):
    spec = MoESpec(n_experts=e, top_k=k, d_ff_expert=16,
                   capacity_factor=float(e))   # capacity >= all tokens
    params = moe_lib.moe_init(KEY, d, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d))
    out, aux = moe_lib.moe_apply(params, x, spec)
    ref = _dense_moe_ref(params, x, spec)
    assert float(aux["drop_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_counted():
    spec = MoESpec(n_experts=4, top_k=2, d_ff_expert=8,
                   capacity_factor=0.25)       # tight capacity forces drops
    params = moe_lib.moe_init(KEY, 8, spec)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 8))
    out, aux = moe_lib.moe_apply(params, x, spec)
    assert 0.0 < float(aux["drop_fraction"]) < 1.0
    assert np.isfinite(np.asarray(out)).all()


def test_moe_aux_losses_finite_and_positive():
    spec = MoESpec(n_experts=4, top_k=2, d_ff_expert=8)
    params = moe_lib.moe_init(KEY, 8, spec)
    x = jax.random.normal(KEY, (2, 16, 8))
    _, aux = moe_lib.moe_apply(params, x, spec)
    assert float(aux["lb_loss"]) >= 1.0 - 1e-3   # >= 1 by Cauchy-Schwarz
    assert np.isfinite(float(aux["z_loss"]))


def test_moe_grads_flow_to_experts_and_router():
    spec = MoESpec(n_experts=4, top_k=2, d_ff_expert=8)
    params = moe_lib.moe_init(KEY, 8, spec)
    x = jax.random.normal(KEY, (1, 16, 8))

    def loss(p):
        out, aux = moe_lib.moe_apply(p, x, spec)
        return jnp.sum(out ** 2) + 0.01 * aux["lb_loss"]

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["w1"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0


def test_shard_map_path_matches_local():
    """The production (shard_map) MoE == the local path, bit-for-bit on a
    1-device mesh (the dispatch/combine algebra is identical)."""
    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_host_mesh
    spec = MoESpec(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=4.0)
    params = moe_lib.moe_init(KEY, 8, spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 8))
    out1, aux1 = moe_lib._moe_apply_local(params, x, spec)
    mesh = make_host_mesh()
    with mesh, sh.axis_rules(sh.rules_for_mesh(mesh)):
        out2, aux2 = jax.jit(
            lambda p, xx: moe_lib.moe_apply(p, xx, spec))(params, x)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               rtol=2e-2, atol=2e-2)
    assert float(aux2["drop_fraction"]) == float(aux1["drop_fraction"])
