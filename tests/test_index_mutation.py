"""insert_objects / delete_objects bounds + bookkeeping regressions.

Regression for the capacity bug: inserting into a full cluster used to
take the least-loaded fallback WITHOUT re-checking capacity, writing at
slot ``counts[ci] >= cap`` (an out-of-bounds row) when the whole index
was full. Now it raises instead.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import index as il


def _tiny_index(rng, *, n, c, cap, d=8):
    emb = rng.normal(size=(n, d)).astype(np.float32)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(loc))
    params = il.index_init(jax.random.PRNGKey(0), d, c, hidden=(8,))
    feats = il.build_features(jnp.asarray(emb), jnp.asarray(loc), norm)
    top = np.asarray(il.assign_clusters(params, feats, top=min(2, c)))
    if top.ndim == 1:
        top = top[:, None]
    buf = il.build_cluster_buffers(top, emb, loc, n_clusters=c, capacity=cap)
    return buf, params, norm, emb, loc


def test_insert_overflow_raises_not_out_of_bounds(rng):
    """Index filled to exact capacity: the next insert must raise."""
    c, cap, d = 2, 4, 8
    buf, params, norm, _, _ = _tiny_index(rng, n=c * cap, c=c, cap=cap, d=d)
    assert int(np.asarray(buf["counts"]).sum()) == c * cap   # packed full
    new_emb = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(1, 2)), jnp.float32)
    with pytest.raises(ValueError, match="capacity"):
        il.insert_objects(buf, params, norm, new_emb, new_loc,
                          np.array([999]))


def test_insert_spills_to_least_loaded_within_bounds(rng):
    """Routed cluster full, another has room: insert lands in-bounds."""
    c, cap, d = 4, 8, 8
    buf, params, norm, _, _ = _tiny_index(rng, n=8, c=c, cap=cap, d=d)
    # force one cluster full, rest as-built
    counts = np.asarray(buf["counts"]).copy()
    full_ci = int(counts.argmax())
    pad = cap - counts[full_ci]
    if pad:
        ids = np.asarray(buf["ids"]).copy()
        ids[full_ci, counts[full_ci]:cap] = 10_000 + np.arange(pad)
        counts[full_ci] = cap
        buf = dict(buf)
        buf["ids"] = jnp.asarray(ids)
        buf["counts"] = jnp.asarray(counts)
    n_new = 6
    new_emb = jnp.asarray(rng.normal(size=(n_new, d)), jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(n_new, 2)), jnp.float32)
    out = il.insert_objects(buf, params, norm, new_emb, new_loc,
                            np.arange(500, 500 + n_new))
    new_counts = np.asarray(out["counts"])
    assert (new_counts <= cap).all()                     # never over cap
    assert new_counts.sum() == counts.sum() + n_new      # all placed
    ids = np.asarray(out["ids"])
    for j in range(n_new):                               # each id stored once
        assert int((ids == 500 + j).sum()) == 1


def test_insert_after_delete_fills_hole_without_clobbering(rng):
    """Regression: inserting after a lazy delete used to write at slot
    ``counts[ci]``, overwriting a LIVE object past the interior hole."""
    c, cap, d = 2, 4, 8
    buf, params, norm, _, _ = _tiny_index(rng, n=c * cap, c=c, cap=cap, d=d)
    live_before = set(np.asarray(buf["ids"]).reshape(-1).tolist())
    victim = int(np.asarray(buf["ids"])[0, 0])       # hole at slot 0
    buf2 = il.delete_objects(buf, [victim])
    new_emb = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(1, 2)), jnp.float32)
    buf3 = il.insert_objects(buf2, params, norm, new_emb, new_loc,
                             np.array([999]))
    live_after = set(np.asarray(buf3["ids"]).reshape(-1).tolist())
    # every pre-existing object except the deleted one survives
    assert live_before - {victim} <= live_after
    assert 999 in live_after
    assert int(np.asarray(buf3["counts"]).sum()) == c * cap


def test_retriever_engine_rebinds_after_mutation(rng, small_corpus,
                                                 tiny_de_cfg):
    """ListRetriever.query must not serve a stale engine snapshot after
    buffers/params are swapped (insert_objects returns a NEW dict)."""
    from repro.core import pipeline as pl
    from repro.core import relevance

    r = pl.ListRetriever(tiny_de_cfg, small_corpus)
    r.rel_params = relevance.relevance_init(jax.random.PRNGKey(0),
                                            tiny_de_cfg)
    d = tiny_de_cfg.d_model
    r.obj_emb = rng.normal(
        size=(small_corpus.cfg.n_objects, d)).astype(np.float32)
    r.index_params = il.index_init(jax.random.PRNGKey(1), d,
                                   tiny_de_cfg.n_clusters, hidden=(16,))
    r.norm = il.loc_normalizer(
        jnp.asarray(small_corpus.obj_loc.astype(np.float32)))
    r.build(capacity=256)
    e1 = r.engine()
    assert r.engine() is e1                       # cached while unchanged
    new_emb = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(1, 2)), jnp.float32)
    r.buffers = il.insert_objects(r.buffers, r.index_params, r.norm,
                                  new_emb, new_loc, np.array([99_999]))
    e2 = r.engine()
    assert e2 is not e1 and e2.buffers is r.buffers
    # the freshly inserted object is actually visible to queries
    # (k = every buffer slot across all clusters ⇒ all valid ids returned)
    k_all = r.buffers["capacity"] * tiny_de_cfg.n_clusters
    ids, _ = r.query(np.arange(8), k=k_all, cr=tiny_de_cfg.n_clusters)
    assert (ids == 99_999).any()


def test_delete_marks_padding_and_recounts(rng):
    c, cap, d = 2, 8, 8
    buf, params, norm, _, _ = _tiny_index(rng, n=10, c=c, cap=cap, d=d)
    ids = np.asarray(buf["ids"])
    victims = ids[ids >= 0][:3]
    out = il.delete_objects(buf, victims)
    out_ids = np.asarray(out["ids"])
    assert not np.isin(out_ids, victims).any()
    assert int(np.asarray(out["counts"]).sum()) == \
        int(np.asarray(buf["counts"]).sum()) - 3
    # deleted slots are masked for the scorer: emb zeroed, id -1
    mask = np.isin(np.asarray(buf["ids"]), victims)
    assert (np.asarray(out["emb"])[mask] == 0).all()
    assert (out_ids[mask] == -1).all()


def test_delete_restores_full_padding_convention(rng):
    """Regression: ``delete_objects`` used to leave the deleted object's
    LIVE location (and scale) behind. Every padding slot — built or
    deleted — must carry the exact (emb 0, loc PAD_LOC, scale 1, id -1)
    convention, or a mutated index diverges bit-wise from a rebuilt one
    (snapshot digests, compaction parity)."""
    c, cap, d = 2, 8, 8
    buf, params, norm, _, _ = _tiny_index(rng, n=10, c=c, cap=cap, d=d)
    ids = np.asarray(buf["ids"])
    victims = ids[ids >= 0][:3]
    out = il.delete_objects(buf, victims)
    pad = np.asarray(out["ids"]) == -1               # built AND deleted pads
    assert (np.asarray(out["emb"])[pad] == 0).all()
    assert (np.asarray(out["loc"])[pad] == il.PAD_LOC).all()
    assert (np.asarray(out["scale"])[pad] == 1.0).all()


def test_deleted_index_is_bit_identical_to_rebuilt(rng):
    """Mutated-vs-rebuilt parity: deleting the last-placed objects must
    leave buffers ARRAY-IDENTICAL to building from the survivors (the
    builder places greedily in input order, so dropping a trailing
    suffix changes no earlier placement). This is what keeps compaction
    and artifact digests honest — it fails if any deleted field keeps a
    stale value."""
    c, cap, d = 4, 8, 8
    n, n_del = 12, 3
    emb = rng.normal(size=(n, d)).astype(np.float32)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(loc))
    params = il.index_init(jax.random.PRNGKey(0), d, c, hidden=(8,))
    feats = il.build_features(jnp.asarray(emb), jnp.asarray(loc), norm)
    top = np.asarray(il.assign_clusters(params, feats, top=2))
    buf = il.build_cluster_buffers(top, emb, loc, n_clusters=c, capacity=cap)
    mutated = il.delete_objects(buf, np.arange(n - n_del, n))
    rebuilt = il.build_cluster_buffers(top[:n - n_del], emb[:n - n_del],
                                       loc[:n - n_del], n_clusters=c,
                                       capacity=cap)
    for f in ("emb", "loc", "ids", "scale", "counts"):
        assert np.array_equal(np.asarray(mutated[f]),
                              np.asarray(rebuilt[f])), f


def test_insert_prefers_spill_hop_over_least_loaded(rng):
    """§4.3 spill policy: with the preferred cluster full, an insert
    lands in the object's NEXT-BEST cluster (2nd spill hop) — NOT in the
    globally least-loaded one. The least-loaded fallback only engages
    when every spill hop is full (or spill=1 disables hopping)."""
    c, cap, d = 4, 8, 8
    buf, params, norm, _, _ = _tiny_index(rng, n=4, c=c, cap=cap, d=d)
    new_emb = jnp.asarray(rng.normal(size=(1, d)), jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(1, 2)), jnp.float32)
    feats = il.build_features(new_emb, new_loc, norm)
    pref = np.asarray(il.assign_clusters(params, feats, top=c))[0]

    # preferred cluster full; 2nd-best has room but is NOT least-loaded
    ids = np.asarray(buf["ids"]).copy()
    counts = np.asarray(buf["counts"]).copy()
    ids[pref[0]] = 10_000 + np.arange(cap)
    counts[pref[0]] = cap
    # top up 2nd-best to 3 residents so some other cluster is emptier
    fill = 3 - int((ids[pref[1]] >= 0).sum())
    if fill > 0:
        free = np.flatnonzero(ids[pref[1]] < 0)[:fill]
        ids[pref[1], free] = 20_000 + np.arange(fill)
    counts[pref[1]] = int((ids[pref[1]] >= 0).sum())
    least = min(range(c), key=lambda j: counts[j])
    assert least not in (int(pref[0]), int(pref[1]))  # fallback ≠ 2nd hop
    buf = dict(buf)
    buf["ids"] = jnp.asarray(ids)
    buf["counts"] = jnp.asarray(counts)

    out = il.insert_objects(buf, params, norm, new_emb, new_loc,
                            np.array([777]), spill=3)
    where = int(np.argwhere(np.asarray(out["ids"]) == 777)[0][0])
    assert where == int(pref[1])                     # landed in the 2nd hop

    # spill=1: no hopping — the same insert falls back to least-loaded
    out1 = il.insert_objects(buf, params, norm, new_emb, new_loc,
                             np.array([778]), spill=1)
    where1 = int(np.argwhere(np.asarray(out1["ids"]) == 778)[0][0])
    assert where1 == least
