"""Shard-level fault tolerance tier (DESIGN.md §15).

The mesh-sharded index must degrade, not die: with ``shard.scan_error``
injected on one of 8 shards, queries return with coverage exactly 7/8,
zero requests fail, and the ids are bit-identical to an oracle whose
view of the lost shard's clusters is empty; transient failures retry
against the host-side replica and keep full coverage; a straggling
device is hedged onto the replica with unchanged answers; and
``recover_shard`` re-materializes the device part under live traffic,
after which results are bit-identical to a never-failed run.

All failure branches are taken through the real fault-injection points
(core/faults.py — ``shard.scan_error`` / ``shard.scan_slow`` /
``shard.device_lost``), not test doubles.

Runs multi-device on CPU (conftest force-sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8``); the CI
``mesh-chaos`` job exports the same flag. ``make test-mesh-chaos``.
"""
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config
from repro.core import faults
from repro.core import index as il
from repro.core import relevance
from repro.core import server as server_lib
from repro.core.snapshot import IndexSnapshot
from repro.distributed import resilience as resilience_lib

DIST_MAX = 1.4142
N_SHARDS = 8
N_DEV = jax.device_count()


@pytest.fixture(autouse=True)
def _disarm_faults():
    faults.clear()
    yield
    faults.clear()


def _need(n_shards):
    if n_shards > N_DEV:
        pytest.skip(f"needs {n_shards} devices, have {N_DEV} "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count)")


# ---------------------------------------------------------------------------
# ShardHealth state machine (pure host logic, no devices)
# ---------------------------------------------------------------------------


class TestShardHealth:
    def test_up_suspect_down_transitions(self):
        h = resilience_lib.ShardHealth(4, down_after=3)
        assert h.state(0) == "up" and not h.is_down(0)
        assert h.record_failure(0) == "suspect"
        assert h.record_failure(0) == "suspect"
        assert h.record_failure(0) == "down"
        assert h.is_down(0) and h.down_shards() == (0,)
        # other shards untouched
        assert h.state(1) == "up"

    def test_success_clears_suspect_but_not_down(self):
        h = resilience_lib.ShardHealth(2, down_after=2)
        h.record_failure(0)
        assert h.state(0) == "suspect"
        h.record_success(0, 0.01)
        assert h.state(0) == "up"
        # DOWN is sticky: only mark_up (the recovery path) clears it
        h.record_failure(1)
        h.record_failure(1)
        assert h.is_down(1)
        h.record_success(1, 0.01)
        assert h.is_down(1)
        h.mark_up(1)
        assert h.state(1) == "up" and h.ewma(1) is None

    def test_failure_streak_resets_on_success(self):
        h = resilience_lib.ShardHealth(1, down_after=3)
        h.record_failure(0)
        h.record_failure(0)
        h.record_success(0, 0.01)
        h.record_failure(0)
        h.record_failure(0)
        assert h.state(0) == "suspect"      # streak restarted at 0

    def test_mark_down_is_immediate(self):
        h = resilience_lib.ShardHealth(3)
        h.mark_down(2)
        assert h.down_shards() == (2,)

    def test_ewma(self):
        h = resilience_lib.ShardHealth(1, alpha=0.5)
        h.record_success(0, 0.1)
        assert h.ewma(0) == pytest.approx(0.1)
        h.record_success(0, 0.2)
        assert h.ewma(0) == pytest.approx(0.15)

    def test_snapshot_shape(self):
        h = resilience_lib.ShardHealth(2)
        h.mark_down(1)
        view = h.snapshot()
        assert view["states"] == ["up", "down"]
        assert view["down"] == [1]
        assert len(view["ewma_s"]) == len(view["failures"]) == 2

    def test_validation(self):
        with pytest.raises(ValueError):
            resilience_lib.ShardHealth(0)
        with pytest.raises(ValueError):
            resilience_lib.ShardHealth(2, down_after=0)


# ---------------------------------------------------------------------------
# Fixture: a tiny mesh-sharded snapshot (c = 8, one cluster per shard)
# ---------------------------------------------------------------------------


def _build_snap(n_clusters=8, seed=0, n=96, cap=32):
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=n_clusters,
        index_mlp_hidden=(16,))
    rng = np.random.default_rng(seed)
    rel = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(1), cfg.d_model, n_clusters,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc,
                                   n_clusters=n_clusters, capacity=cap)
    return IndexSnapshot.from_parts(cfg, rel, iparams, norm, buf,
                                    dist_max=DIST_MAX)


def _make_queries(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    tok = rng.integers(2, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones_like(tok, bool)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    return tok, msk, loc


@pytest.fixture(scope="module")
def snap8():
    return _build_snap(8)


def _sharded_searcher(snap8):
    """A fresh dense Searcher over an 8-shard placement of snap8."""
    return api.Searcher(snap8.with_mesh(N_SHARDS), backend="dense")


def _full_fanout(searcher, tok, msk, loc, *, k=5):
    """cr = c: every query routes every cluster, so coverage under one
    DOWN shard is exactly (clusters on UP shards) / c, with no padding
    slack (batch == n divides evenly)."""
    c = int(np.asarray(searcher.snapshot.buffers["emb"]).shape[0])
    return searcher.query(tok, msk, loc, k=k, cr=c, batch=len(tok))


def _masked_oracle(snap8, down_shard, shard_of):
    """Single-device oracle whose view of ``down_shard``'s clusters is
    EMPTY — the exact corpus a degraded query serves."""
    g = np.flatnonzero(np.asarray(shard_of) == down_shard)
    buf = {key: np.array(v) for key, v in snap8.buffers.items()
           if key != "capacity"}
    buf["ids"][g] = -1
    buf["emb"][g] = 0
    buf["loc"][g] = il.PAD_LOC
    buf["scale"][g] = 1
    if "counts" in buf:
        buf["counts"][g] = 0
    buf["capacity"] = snap8.buffers["capacity"]
    return api.Searcher(dataclasses.replace(snap8, buffers=buf),
                        backend="dense")


def _fail_shard(target):
    """Persistent scan_error on one shard (device AND replica attempts
    fail — the shard's data is unscannable, so health drives it DOWN)."""
    def boom(shard):
        if shard == target:
            raise RuntimeError(f"injected: shard {shard} unscannable")
    faults.inject("shard.scan_error", callback=boom, times=None)


# ---------------------------------------------------------------------------
# Degraded partial-result serving
# ---------------------------------------------------------------------------


def test_scan_error_degrades_coverage_to_seven_eighths(snap8):
    _need(N_SHARDS)
    searcher = _sharded_searcher(snap8)
    tok, msk, loc = _make_queries(snap8.cfg, n=16)
    healthy = _full_fanout(searcher, tok, msk, loc)
    assert searcher.last_coverage == 1.0

    _fail_shard(3)
    ids, scores = _full_fanout(searcher, tok, msk, loc)   # must NOT raise
    health = searcher.engine._shard_health
    assert searcher.last_coverage == pytest.approx((N_SHARDS - 1) / N_SHARDS)
    assert searcher.engine.last_down_shards == (3,)
    assert searcher.engine.down_signature() == (3,)
    assert health.is_down(3)
    # every surviving shard stayed clean
    assert all(health.state(s) == "up" for s in range(N_SHARDS) if s != 3)

    # ids/scores bit-identical to the oracle that never had shard 3's
    # clusters — surviving shards contribute the exact same entries
    oracle = _masked_oracle(snap8, 3, searcher.snapshot.shards.shard_of)
    o_ids, o_scores = _full_fanout(oracle, tok, msk, loc)
    np.testing.assert_array_equal(ids, o_ids)
    np.testing.assert_array_equal(scores, o_scores)
    # and the lost entries really differ from the healthy run somewhere
    assert not np.array_equal(ids, healthy[0])

    # a second query skips the DOWN shard instantly — no fresh retries
    retries_before = searcher.engine.shard_stats["scan_retries"]
    skips_before = searcher.engine.shard_stats["down_skips"]
    _full_fanout(searcher, tok, msk, loc)
    assert searcher.last_coverage == pytest.approx((N_SHARDS - 1) / N_SHARDS)
    assert searcher.engine.shard_stats["scan_retries"] == retries_before
    assert searcher.engine.shard_stats["down_skips"] > skips_before


def test_transient_error_recovers_via_host_retry(snap8):
    _need(N_SHARDS)
    searcher = _sharded_searcher(snap8)
    tok, msk, loc = _make_queries(snap8.cfg, n=8, seed=1)
    healthy = _full_fanout(searcher, tok, msk, loc)

    def boom_once(shard):
        if shard == 0:
            raise RuntimeError("transient blip")
    faults.inject("shard.scan_error", callback=boom_once, times=1)
    ids, scores = _full_fanout(searcher, tok, msk, loc)
    eng = searcher.engine
    # one retry against the host replica, full coverage, exact answers
    assert eng.shard_stats["scan_retries"] == 1
    assert eng.shard_stats["host_scans"] == 1
    assert searcher.last_coverage == 1.0
    assert eng._shard_health.state(0) == "up"       # success cleared it
    np.testing.assert_array_equal(ids, healthy[0])
    np.testing.assert_array_equal(scores, healthy[1])


def test_device_lost_marks_down_immediately(snap8):
    _need(N_SHARDS)
    searcher = _sharded_searcher(snap8)
    tok, msk, loc = _make_queries(snap8.cfg, n=8, seed=2)

    def lost(shard):
        if shard == 1:
            raise RuntimeError("device pulled")
    faults.inject("shard.device_lost", callback=lost, times=None)
    _full_fanout(searcher, tok, msk, loc)
    eng = searcher.engine
    assert eng._shard_health.is_down(1)
    assert searcher.last_coverage == pytest.approx((N_SHARDS - 1) / N_SHARDS)
    # no retries: device loss is terminal for the chunk, not retryable
    assert eng.shard_stats["scan_retries"] == 0


def test_all_shards_down_raises_shard_unavailable(snap8):
    _need(N_SHARDS)
    searcher = _sharded_searcher(snap8)
    tok, msk, loc = _make_queries(snap8.cfg, n=8, seed=3)
    faults.inject("shard.scan_error",
                  error=RuntimeError("everything is on fire"), times=None)
    with pytest.raises(api.ShardUnavailable):
        _full_fanout(searcher, tok, msk, loc)


# ---------------------------------------------------------------------------
# Hedged scans (straggler → host replica, probes → back to the device)
# ---------------------------------------------------------------------------


def test_straggler_shard_is_hedged_with_identical_results(snap8):
    _need(N_SHARDS)
    searcher = _sharded_searcher(snap8)
    tok, msk, loc = _make_queries(snap8.cfg, n=8, seed=4)
    healthy = _full_fanout(searcher, tok, msk, loc)
    # warm the straggler window: slow() needs >= window//2 history
    for _ in range(12):
        _full_fanout(searcher, tok, msk, loc)

    def crawl(shard):
        if shard == 2:
            time.sleep(0.25)        # far past median + 5·MAD
    faults.inject("shard.scan_slow", callback=crawl, times=None)
    _full_fanout(searcher, tok, msk, loc)     # slow sample flags shard 2
    eng = searcher.engine
    assert 2 in eng._hedged
    ids, scores = _full_fanout(searcher, tok, msk, loc)  # now hedged
    assert eng.shard_stats["hedged_scans"] >= 1
    assert eng.shard_stats["host_scans"] >= 1
    assert searcher.last_coverage == 1.0      # hedging loses nothing
    assert eng._shard_health.state(2) == "up"
    np.testing.assert_array_equal(ids, healthy[0])
    np.testing.assert_array_equal(scores, healthy[1])


def test_hedge_probe_returns_to_fast_device(snap8):
    _need(N_SHARDS)
    searcher = _sharded_searcher(snap8)
    tok, msk, loc = _make_queries(snap8.cfg, n=8, seed=5)
    eng = searcher.engine
    _full_fanout(searcher, tok, msk, loc)     # materialize health state

    class NeverSlow(resilience_lib.StragglerMonitor):
        def slow(self, host):
            return False
    eng._shard_monitor = NeverSlow()
    # next hedged scan for shard 2 is the probe (count hits probe_every)
    eng._hedged = {2: eng.hedge_probe_every - 1}
    _full_fanout(searcher, tok, msk, loc)
    assert 2 not in eng._hedged               # fast probe exits hedging


# ---------------------------------------------------------------------------
# Online shard recovery
# ---------------------------------------------------------------------------


def test_recover_shard_restores_bit_parity(snap8):
    _need(N_SHARDS)
    searcher = _sharded_searcher(snap8)
    tok, msk, loc = _make_queries(snap8.cfg, n=16, seed=6)
    healthy = _full_fanout(searcher, tok, msk, loc)
    ver = searcher.snapshot.meta.version

    _fail_shard(3)
    _full_fanout(searcher, tok, msk, loc)
    assert searcher.engine._shard_health.is_down(3)
    faults.clear()

    old_part = searcher.snapshot.shards.parts[3]
    searcher.engine.recover_shard(3)
    assert searcher.engine._shard_health.state(3) == "up"
    assert searcher.engine.down_signature() == ()
    assert searcher.engine.shard_stats["recoveries"] == 1
    # placement-only: the part was re-materialized, the version didn't move
    assert searcher.snapshot.shards.parts[3] is not old_part
    assert searcher.snapshot.meta.version == ver

    ids, scores = _full_fanout(searcher, tok, msk, loc)
    assert searcher.last_coverage == 1.0
    np.testing.assert_array_equal(ids, healthy[0])
    np.testing.assert_array_equal(scores, healthy[1])
    # ...and bit-identical to a never-failed oracle engine too
    fresh = _sharded_searcher(snap8)
    f_ids, f_scores = _full_fanout(fresh, tok, msk, loc)
    np.testing.assert_array_equal(ids, f_ids)
    np.testing.assert_array_equal(scores, f_scores)


def test_recover_shard_validation(snap8):
    _need(N_SHARDS)
    with pytest.raises(ValueError, match="not mesh-sharded"):
        api.Searcher(snap8, backend="dense").engine.recover_shard(0)
    searcher = _sharded_searcher(snap8)
    with pytest.raises(ValueError, match="out of range"):
        searcher.engine.recover_shard(N_SHARDS)


# ---------------------------------------------------------------------------
# Server integration: coverage surfacing + degraded-result cache keying
# ---------------------------------------------------------------------------


def _mk_server(snap8, **over):
    eng = _sharded_searcher(snap8).engine
    kw = dict(batch_size=1, max_delay_ms=5.0, k=5,
              cr=int(np.asarray(snap8.buffers["emb"]).shape[0]),
              backend="dense", near_cells=0)
    kw.update(over)
    return server_lib.StreamingServer(eng, server_lib.ServerConfig(**kw))


def test_degraded_results_never_served_as_full_coverage(snap8):
    _need(N_SHARDS)
    server = _mk_server(snap8)
    tok, msk, loc = _make_queries(snap8.cfg, n=2, seed=7)
    oracle = _sharded_searcher(snap8)
    o_ids, _ = _full_fanout(oracle, tok, msk, loc)

    # request 0 cached healthy
    ids_b, _ = server.serve_all(tok[:1], msk[:1], loc[:1])
    assert server.stats.degraded_flushes == 0

    # shard 3 dies → request 1 computed degraded, cached under dsig (3,)
    _fail_shard(3)
    ids_c1, _ = server.serve_all(tok[1:], msk[1:], loc[1:])
    m = server.metrics()
    assert m["coverage"]["last"] == pytest.approx((N_SHARDS - 1) / N_SHARDS)
    assert m["coverage"]["degraded_flushes"] == 1
    assert m["shard_health"]["down"] == [3]
    assert not np.array_equal(ids_c1[0], o_ids[1])   # really degraded

    # while still degraded the SAME request hits the degraded cache entry
    hits_before = server.stats.exact_hits
    batches_before = server.stats.engine_batches
    ids_c2, _ = server.serve_all(tok[1:], msk[1:], loc[1:])
    assert server.stats.exact_hits == hits_before + 1
    assert server.stats.engine_batches == batches_before
    np.testing.assert_array_equal(ids_c1, ids_c2)

    # recover: the degraded entry is unreachable (different down-shard
    # signature), the request recomputes at full coverage — no cache
    # invalidation involved
    faults.clear()
    server.recover_shard(3)
    batches_before = server.stats.engine_batches
    ids_c3, _ = server.serve_all(tok[1:], msk[1:], loc[1:])
    assert server.stats.engine_batches == batches_before + 1   # real miss
    np.testing.assert_array_equal(ids_c3[0], o_ids[1])
    m = server.metrics()
    assert m["coverage"]["last"] == 1.0
    assert m["coverage"]["min"] == pytest.approx((N_SHARDS - 1) / N_SHARDS)
    assert m["shard_recoveries"] == 1
    assert m["shard_health"]["down"] == []


def test_subscription_dispatch_exactly_once_across_recovery(snap8):
    """Recovery is placement-only: it must produce ZERO notifications,
    and insert batches around a fail/recover cycle notify exactly once."""
    _need(N_SHARDS)
    server = _mk_server(snap8, delta_threshold=10_000)
    cfg = snap8.cfg
    tok, msk, loc = _make_queries(cfg, n=1, seed=8)
    sub = server.subscribe(tok[0], msk[0], loc[0], threshold=-1e9)

    rng = np.random.default_rng(9)
    d = int(np.asarray(snap8.buffers["emb"]).shape[-1])

    def insert(base):
        emb = rng.normal(size=(4, d)).astype(np.float32)
        xy = rng.uniform(size=(4, 2)).astype(np.float32)
        ids = np.arange(base, base + 4)
        server.insert_objects(emb, xy, ids)
        return set(ids.tolist())

    ids1 = insert(30_000_000)
    notes1 = {n.object_id for n in sub.drain()}
    assert notes1                      # full-fanout sub sees its inserts

    # fail + recover with no writes: not a single notification
    _fail_shard(2)
    server.serve_all(tok, msk, loc)    # degraded read traffic
    faults.clear()
    server.recover_shard(2)
    assert sub.drain() == []

    ids2 = insert(31_000_000)
    notes2 = {n.object_id for n in sub.drain()}
    assert notes2 and notes2.isdisjoint(notes1)
    # exactly-once: batch-1 ids never re-notify, every id at most once
    assert notes1 <= ids1 and notes2 <= ids2
