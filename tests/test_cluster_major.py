"""Cluster-major batched execution (DESIGN.md §10): parity + properties.

The cluster-major kernel (stream each DISTINCT routed cluster once per
batch against its whole query roster, merge the cr partial lists per
query) must be indistinguishable from the query-major pallas kernel and
the dense oracle across duplicate routings, saturated rosters, buffer
padding, and every precision tier — and the auto heuristic / plan-cache
bound around it must behave.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import engine
from repro.core import index as il
from repro.core import relevance
from repro.core import serving
from repro.kernels import ops

DIST_MAX = 1.414


# ---------------------------------------------------------------------------
# Kernel-level parity: pallas-cm == query-major pallas == dense oracle
# ---------------------------------------------------------------------------


def _mk_instance(rng, *, b, cr, c, cap, d, t=50, precision="f32",
                 valid_per_cluster=None, top_c=None):
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(b, 2)), jnp.float32)
    emb = rng.normal(size=(c, cap, d)).astype(np.float32)
    bi = np.arange(c * cap, dtype=np.int32).reshape(c, cap)
    if valid_per_cluster is not None:
        bi[:, valid_per_cluster:] = -1
    emb[bi < 0] = 0.0
    bl = rng.uniform(size=(c, cap, 2)).astype(np.float32)
    bl[bi < 0] = 1e6
    be, bs = il.quantize_rows(emb, precision)
    if top_c is None:
        top_c = rng.integers(0, c, size=(b, cr)).astype(np.int32)
    wh = np.cumsum(rng.uniform(0, 0.05, size=t)).astype(np.float32)
    return (q, ql, w, jnp.asarray(top_c), jnp.asarray(be),
            jnp.asarray(bl), jnp.asarray(bi), jnp.asarray(wh),
            jnp.asarray(bs) if precision == "int8" else None)


def _run_cluster_major_kernel(args, *, k, block_n=512, qcap=None):
    q, ql, w, top_c, be, bl, bi, wh, bs = args
    b, cr = top_c.shape
    c = be.shape[0]
    n = b * cr
    u, roster, _, _ = serving.cluster_major_plan(top_c, n_clusters=c,
                                                 qcap=qcap)
    qidx = serving.roster_query_rows(roster, cr=cr, n_total=n)
    ps, pi = ops.fused_topk_score_cluster_major(
        q[qidx], ql[qidx], w[qidx], u, roster, be, bl, bi, wh,
        k=k, dist_max=DIST_MAX, n_total=n, block_n=block_n, buf_scale=bs,
        interpret=True)
    return engine.merge_cluster_major(ps, pi, roster, b=b, cr=cr, k=k)


def _all_three(args, *, k, block_n=512):
    q, ql, w, top_c, be, bl, bi, wh, bs = args
    s_cm, i_cm = _run_cluster_major_kernel(args, k=k, block_n=block_n)
    s_qm, i_qm = ops.fused_topk_score_routed(
        q, ql, w, top_c, be, bl, bi, wh, k=k, dist_max=DIST_MAX,
        block_n=block_n, buf_scale=bs, interpret=True)
    s_d, i_d = engine.dense_cluster_major(
        q, ql, w, top_c, be, bl, bi, wh, k=k, dist_max=DIST_MAX,
        buf_scale=bs)
    return [(np.asarray(s), np.asarray(i))
            for s, i in ((s_cm, i_cm), (s_qm, i_qm), (s_d, i_d))]


def _assert_equivalent(results):
    (s0, i0), *rest = results
    order0 = np.sort(i0, axis=1)
    for s, i in rest:
        np.testing.assert_allclose(s, s0, rtol=1e-5, atol=1e-5)
        assert (np.sort(i, axis=1) == order0).all()


@pytest.mark.parametrize("precision", ["f32", "bf16", "int8"])
@pytest.mark.parametrize("b,cr,c,cap,d,k,block_n", [
    (8, 2, 6, 64, 32, 5, 512),      # cap < block_n: single-tile clusters
    (16, 4, 4, 128, 16, 10, 32),    # multi-tile streaming per cluster
    (3, 2, 5, 96, 8, 7, 64),        # odd b
    (1, 1, 2, 32, 64, 32, 512),     # single query, k == cap
])
def test_cluster_major_matches_query_major_and_dense(b, cr, c, cap, d, k,
                                                     block_n, precision,
                                                     rng):
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d,
                        precision=precision)
    _assert_equivalent(_all_three(args, k=k, block_n=block_n))


def test_cluster_major_duplicate_routes(rng):
    """A query routed TWICE to the same cluster keeps query-major
    semantics: its duplicate roster slots both score that cluster, so
    duplicated ids survive the merge exactly as the query-major paths
    duplicate them."""
    b, c, cap, d, k = 5, 4, 32, 16, 8
    top_c = np.array([[1, 1], [0, 2], [3, 3], [2, 2], [1, 1]], np.int32)
    args = _mk_instance(rng, b=b, cr=2, c=c, cap=cap, d=d, top_c=top_c)
    results = _all_three(args, k=k)
    _assert_equivalent(results)
    # duplicates ARE present (top-2·k of a twice-scanned cluster)
    i_cm = results[0][1]
    assert any(len(set(row.tolist())) < k for row in i_cm)


def test_cluster_major_saturated_single_cluster(rng):
    """Degenerate skew: every route lands on ONE cluster (U=1, the
    roster fully saturated at qcap = B·cr) — the kernel streams that
    cluster once and still matches query-major, which streams it
    B·cr times."""
    b, cr, c, cap, d, k = 8, 2, 6, 64, 32, 5
    top_c = np.full((b, cr), 3, np.int32)
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d, top_c=top_c)
    u, roster, n_distinct, n_dropped = serving.cluster_major_plan(
        jnp.asarray(top_c), n_clusters=c)
    assert int(n_distinct) == 1 and int(n_dropped) == 0
    assert (np.asarray(roster)[0] < b * cr).all()      # row 0 saturated
    assert (np.asarray(roster)[1:] == b * cr).all()    # rest empty
    _assert_equivalent(_all_three(args, k=k))


def test_cluster_major_all_distinct(rng):
    """Degenerate anti-skew: every route hits a different cluster
    (U = B·cr, dedup factor 1) — one roster entry per row."""
    b, cr, c, cap, d, k = 4, 2, 8, 32, 16, 5
    top_c = np.arange(8, dtype=np.int32).reshape(b, cr)
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d, top_c=top_c)
    u, roster, n_distinct, n_dropped = serving.cluster_major_plan(
        jnp.asarray(top_c), n_clusters=c)
    assert int(n_distinct) == b * cr and int(n_dropped) == 0
    assert ((np.asarray(roster) < b * cr).sum(axis=1) == 1).all()
    _assert_equivalent(_all_three(args, k=k))


def test_cluster_major_partial_and_empty_clusters(rng):
    """-1 buffer padding: partially-filled clusters return only valid
    ids, and k > valid candidates pads with (-1, NEG_INF) like the
    query-major contract."""
    b, cr, c, cap, d, k = 6, 2, 4, 32, 16, 20
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d,
                        valid_per_cluster=3)
    results = _all_three(args, k=k)
    _assert_equivalent(results)
    s_cm, i_cm = results[0]
    assert ((i_cm >= 0).sum(axis=1) <= 3 * cr).all()
    assert ((s_cm < -1e29) == (i_cm < 0)).all()


def test_cluster_major_qcap_saturation_degrades_gracefully(rng):
    """qcap below the realized demand drops (query, route) pairs — the
    count is surfaced and the dropped pairs contribute empty partial
    lists (never wrong results): queries keep whatever their surviving
    routes found."""
    b, cr, c, cap, d, k = 8, 1, 4, 32, 16, 4
    top_c = np.zeros((b, 1), np.int32)          # all 8 routes → cluster 0
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d, top_c=top_c)
    _, _, _, n_dropped = serving.cluster_major_plan(
        jnp.asarray(top_c), n_clusters=c, qcap=5)
    assert int(n_dropped) == 3
    s, i = _run_cluster_major_kernel(args, k=k, qcap=5)
    s, i = np.asarray(s), np.asarray(i)
    # stable sort keeps the FIRST 5 (query, route) pairs; the rest answer
    # with empty lists
    assert (i[:5] >= 0).all()
    assert (i[5:] == -1).all() and (s[5:] < -1e29).all()


# ---------------------------------------------------------------------------
# Hypothesis property test: random routings × precision tiers
# ---------------------------------------------------------------------------


def _check_property_instance(seed, b, cr, c, cap_tiles, valid, precision):
    """For ANY routing (duplicates, saturated single-cluster rosters)
    and any buffer padding, cluster-major == query-major pallas == the
    dense oracle on every precision tier: identical score multisets and
    identical id sets per query (tie order inside equal scores is
    free)."""
    rng = np.random.default_rng(seed)
    cap = 16 * cap_tiles
    k = int(rng.integers(1, cap + 1))
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=8,
                        precision=precision, valid_per_cluster=valid)
    _assert_equivalent(_all_three(args, k=k, block_n=16))


def test_cluster_major_property_parity():
    # hypothesis imported HERE so its absence skips only this test, not
    # the whole module (the rest of the file must always run)
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.given(
        seed=st.integers(0, 2**16),
        b=st.integers(1, 9),
        cr=st.integers(1, 3),
        c=st.integers(1, 6),
        cap_tiles=st.integers(1, 4),
        valid=st.sampled_from([None, 0, 3]),
        precision=st.sampled_from(["f32", "bf16", "int8"]),
    )
    @hypothesis.settings(max_examples=25, deadline=None)
    def check(seed, b, cr, c, cap_tiles, valid, precision):
        _check_property_instance(seed, b, cr, c, cap_tiles, valid, precision)

    check()


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("precision", ["f32", "bf16", "int8"])
def test_cluster_major_property_seed_sweep(seed, precision):
    """Hypothesis-free slice of the same property (always runs, even
    where hypothesis isn't installed): random shapes, routings, and
    padding per seed."""
    rng = np.random.default_rng(100 + seed)
    _check_property_instance(
        seed=int(rng.integers(0, 2**16)), b=int(rng.integers(1, 10)),
        cr=int(rng.integers(1, 4)), c=int(rng.integers(1, 7)),
        cap_tiles=int(rng.integers(1, 5)),
        valid=[None, 0, 3][int(rng.integers(0, 3))], precision=precision)


# ---------------------------------------------------------------------------
# Engine integration: plan-cache LRU bound + the auto heuristic
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_snapshot():
    from repro.core.snapshot import IndexSnapshot
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(3)
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c = 96, cfg.n_clusters
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(1), cfg.d_model, c,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=32)
    return IndexSnapshot.from_parts(cfg, params, iparams, norm, buf,
                                    dist_max=DIST_MAX)


def _queries(rng, b, L=8, vocab=512):
    tok = rng.integers(2, vocab, (b, L)).astype(np.int32)
    tok[:, 0] = 1
    return tok, np.ones((b, L), bool), rng.uniform(size=(b, 2)).astype(
        np.float32)


def test_plan_cache_lru_bound(tiny_snapshot):
    """The compiled-plan cache is LRU-bounded: distinct (batch, k, cr,
    backend, precision, filtered) keys beyond ``max_plans`` evict the
    least recently used plan, and a re-request retraces it."""
    e = engine.QueryEngine(tiny_snapshot, backend="dense", max_plans=2)
    f1 = e.query_fn(k=3, cr=1, batch=4)
    f2 = e.query_fn(k=4, cr=1, batch=4)
    assert e.query_fn(k=3, cr=1, batch=4) is f1      # hit refreshes
    e.query_fn(k=5, cr=1, batch=4)                   # evicts k=4 (LRU)
    assert len(e._plans) == 2
    assert (4, 4, 1, "dense", "f32", False) not in e._plans
    assert (4, 3, 1, "dense", "f32", False) in e._plans
    assert e.query_fn(k=4, cr=1, batch=4) is not f2  # retraced, not stale
    assert len(e._plans) == 2


def test_cluster_major_variant_heuristic():
    th = engine.CLUSTER_MAJOR_DEDUP_THRESHOLD
    assert engine.cluster_major_variant("pallas", th) == "pallas-cm"
    assert engine.cluster_major_variant("dense", th + 1) == "dense-cm"
    assert engine.cluster_major_variant("pallas", th - 0.5) == "pallas"
    # already-cluster-major names pass through
    assert engine.cluster_major_variant("pallas-cm", th) == "pallas-cm"


def test_cluster_major_feasibility_guard(rng):
    """Auto never picks a cluster-major plan whose roster overhead
    outgrows the stream it saves: u_max = min(B·cr, c) must stay within
    the buffer capacity — the large-c small-cap regime refuses the
    upgrade."""
    from repro.core.snapshot import IndexSnapshot
    assert engine.cluster_major_feasible(256, 2, 4, 32)        # u_max=4
    assert not engine.cluster_major_feasible(256, 2, 512, 128)  # u_max=512
    # end-to-end on an adversarial shape: c=16 clusters of capacity 8 —
    # a batch with B·cr > 8 would need u_max up to 16 > cap, so the
    # guard keeps query-major even though the dedup bound is maximal
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=1, d_model=16, n_heads=2, d_ff=32, vocab_size=512,
        max_len=8, spatial_t=20, n_clusters=16, index_mlp_hidden=(8,))
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap = 64, 16, 8
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(1), cfg.d_model, c,
                            hidden=(8,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap, spill=16)
    snap = IndexSnapshot.from_parts(cfg, params, iparams, norm, buf,
                                    dist_max=DIST_MAX)
    tok = rng.integers(2, 512, (8, 8)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones((8, 8), bool)
    loc = rng.uniform(size=(8, 2)).astype(np.float32)
    auto = engine.QueryEngine(snap, backend="auto")
    picked = auto.pick_backend(tok, msk, loc, cr=2, batch=8)   # u_max=16>8
    assert picked == auto.backend                  # refused the upgrade
    assert auto.last_dedup_factor is None


def test_auto_server_warmup_pretraces_both_twins(tiny_snapshot):
    """An auto server's warm-up must not let the degenerate all-padding
    batch (identical rows → maximal measured dedup) pick the plan: it
    pre-traces BOTH twins so whichever the live traffic selects is
    already compiled, and the artificial dedup factor never leaks into
    metrics()."""
    from repro.core import server as server_lib
    eng = engine.QueryEngine(tiny_snapshot, backend="auto")
    server = server_lib.StreamingServer(
        eng, server_lib.ServerConfig(batch_size=4, k=5, cr=2, backend=None))
    compiles = server.warmup()
    base = eng.backend
    twin = engine.cluster_major_variant(base, float("inf"))
    assert {f"{base}@4", f"{twin}@4"} <= set(compiles)
    backends_traced = {key[3] for key in eng._plans}
    assert {base, twin} <= backends_traced
    assert eng.last_dedup_factor is None
    assert server.metrics()["dedup_factor"] is None


def test_engine_auto_upgrades_to_cluster_major(tiny_snapshot, rng):
    """backend="auto" with a cluster-saturating batch (B·cr ≥ 2·c)
    upgrades to the cluster-major twin per batch; results match the
    explicit query-major backend modulo tie order."""
    tok, msk, loc = _queries(rng, 8)
    auto = engine.QueryEngine(tiny_snapshot, backend="auto")
    ids_a, sc_a = auto.query(tok, msk, loc, k=5, cr=2, batch=8)
    assert auto.last_dedup_factor >= engine.CLUSTER_MAJOR_DEDUP_THRESHOLD
    used = {key[3] for key in auto._plans}
    expect = "pallas-cm" if jax.default_backend() == "tpu" else "dense-cm"
    assert used == {expect}
    explicit = engine.QueryEngine(tiny_snapshot, backend="dense")
    ids_e, sc_e = explicit.query(tok, msk, loc, k=5, cr=2, batch=8)
    np.testing.assert_allclose(sc_a, sc_e, rtol=1e-5, atol=1e-5)
    assert (np.sort(ids_a) == np.sort(ids_e)).all()
    # an EXPLICIT backend never auto-upgrades
    assert {key[3] for key in explicit._plans} == {"dense"}
    # ... but an explicit "auto" REQUEST engages the pick even on a
    # non-auto engine (the serving drivers forward their resolved CLI
    # default "auto" through ServerConfig.backend)
    explicit.query(tok, msk, loc, k=5, cr=2, batch=8, backend="auto")
    assert expect in {key[3] for key in explicit._plans}
    assert explicit.last_dedup_factor is not None


def test_engine_auto_measures_when_structurally_inconclusive(tiny_snapshot,
                                                            rng):
    """When B·cr < threshold·c the pick must MEASURE: route the first
    chunk and use the realized distinct-cluster count."""
    tok, msk, loc = _queries(rng, 2)
    auto = engine.QueryEngine(tiny_snapshot, backend="auto")
    picked = auto.pick_backend(tok, msk, loc, cr=1, batch=2)
    # 2 routes over 4 clusters: structural bound 1.0 < threshold, so the
    # pick reflects the measured routing (dedup ∈ {1.0, 2.0})
    assert auto.last_dedup_factor in (1.0, 2.0)
    base = "pallas" if jax.default_backend() == "tpu" else "dense"
    expect = engine.cluster_major_variant(base, auto.last_dedup_factor)
    assert picked == expect


def test_server_flush_parity_on_cluster_major_backend(tiny_snapshot, rng):
    """A streaming server configured with backend="pallas-cm" serves
    micro-batches bit-identical to a direct engine call on the same
    backend (the padding rules compose with the cluster-major plan)."""
    from repro.core import server as server_lib
    tok, msk, loc = _queries(rng, 6)
    e = engine.QueryEngine(tiny_snapshot, backend="dense", interpret=True)
    server = server_lib.StreamingServer(
        e, server_lib.ServerConfig(batch_size=4, max_delay_ms=1.0, k=5,
                                   cr=2, backend="pallas-cm"))
    ids_s, sc_s = server.serve_all(tok, msk, loc)
    ids_d, sc_d = e.query(tok, msk, loc, k=5, cr=2, batch=4,
                          backend="pallas-cm")
    np.testing.assert_array_equal(ids_s, ids_d)
    np.testing.assert_array_equal(sc_s, sc_d)
