"""Per-assigned-architecture smoke tests: REDUCED config, one forward/train
step on CPU, asserting output shapes and no NaNs (full configs are exercised
only via the dry-run)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import arch_ids, get_config, get_shapes, reduced
from repro.data import (
    CTRStream,
    LMStream,
    SeqRecStream,
    community_graph,
    molecule_batch,
)
from repro.models import gnn as gnn_lib
from repro.models import recsys as rs
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)

LM_ARCHS = ["gemma3-27b", "stablelm-1.6b", "qwen2-7b",
            "moonshot-v1-16b-a3b", "kimi-k2-1t-a32b"]


def test_registry_complete():
    expect = {"gemma3-27b", "stablelm-1.6b", "qwen2-7b",
              "moonshot-v1-16b-a3b", "kimi-k2-1t-a32b", "gatedgcn",
              "mind", "bert4rec", "xdeepfm", "dlrm-mlperf",
              "list-dual-encoder"}
    assert expect <= set(arch_ids())
    for a in expect:
        assert len(get_shapes(a)) == 4


@pytest.mark.parametrize("arch", LM_ARCHS)
@pytest.mark.slow
def test_lm_train_step(arch):
    cfg = reduced(get_config(arch))
    params = tf.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 33), 0, cfg.vocab_size)
    loss, metrics = tf.lm_loss(params, {"tokens": toks}, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: tf.lm_loss(p, {"tokens": toks}, cfg)[0])(params)
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_decode_shapes(arch):
    cfg = reduced(get_config(arch))
    params = tf.lm_init(KEY, cfg)
    b, s = 2, 16
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    logits, cache = tf.lm_prefill(params, toks, cfg, max_len=s + 4)
    assert logits.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    lg, cache = tf.lm_decode_step(params, cache, toks[:, :1],
                                  jnp.full((b,), s, jnp.int32), cfg)
    assert lg.shape == (b, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all()


def test_gemma_pattern_structure():
    cfg = get_config("gemma3-27b")
    pat = cfg.pattern()
    assert len(pat) == 62
    assert pat.count("G") == 10 and pat.count("L") == 52
    n, period, rem = tf.scan_structure(cfg)
    assert n * len(period) + len(rem) == 62


def test_gnn_smoke():
    cfg = reduced(get_config("gatedgcn"))
    g = community_graph(100, 400, 16, 5, seed=0)
    g = {k: (jnp.asarray(v) if v is not None else None) for k, v in g.items()}
    params = gnn_lib.gnn_init(KEY, cfg, 16, 5)
    loss, m = gnn_lib.gnn_loss(params, g, cfg)
    assert np.isfinite(float(loss))
    logits = gnn_lib.gnn_forward(params, g, cfg)
    assert logits.shape == (100, 5)


def test_gnn_batched_graphs():
    cfg = reduced(get_config("gatedgcn"))
    g = molecule_batch(8, 10, 20, 16, seed=0)
    g = {k: (jnp.asarray(v) if isinstance(v, np.ndarray) else v)
         for k, v in g.items()}
    params = gnn_lib.gnn_init(KEY, cfg, 16, 1, d_edge_in=4)
    logits = gnn_lib.gnn_forward(params, g, cfg)
    assert logits.shape == (8, 1)
    loss, _ = gnn_lib.gnn_loss(params, g, cfg)
    assert np.isfinite(float(loss))


def test_neighbor_sampler_subgraph_trains():
    from repro.data import NeighborSampler
    cfg = reduced(get_config("gatedgcn"))
    g = community_graph(500, 3000, 16, 5, seed=1)
    ns = NeighborSampler(g["edge_src"], g["edge_dst"], 500)
    sub = ns.padded_batch(np.arange(32), (5, 3), g["x"], g["labels"],
                          pad_nodes=512, pad_edges=1024, seed=0)
    sub = {k: jnp.asarray(v) for k, v in sub.items() if v is not None}
    sub["edge_attr"] = None
    params = gnn_lib.gnn_init(KEY, cfg, 16, 5)
    loss, m = gnn_lib.gnn_loss(params, sub, cfg)
    assert np.isfinite(float(loss))
    # loss counted on seed nodes only
    assert float(jnp.asarray(sub["label_mask"]).sum()) == 32


@pytest.mark.parametrize("arch", ["dlrm-mlperf", "xdeepfm", "bert4rec",
                                  "mind"])
def test_recsys_smoke(arch):
    cfg = reduced(get_config(arch))
    if arch == "dlrm-mlperf":
        stream = CTRStream(cfg.n_dense, cfg.table_sizes, seed=0)
        b = stream.batch(0, 16)
        params = rs.dlrm_init(KEY, cfg)
        loss, _ = rs.dlrm_loss(params, {k: jnp.asarray(v)
                                        for k, v in b.items()}, cfg)
        logits = rs.dlrm_forward(params, jnp.asarray(b["dense"]),
                                 jnp.asarray(b["sparse"]), cfg)
        assert logits.shape == (16,)
    elif arch == "xdeepfm":
        stream = CTRStream(1, [cfg.vocab_per_field] * cfg.n_sparse, seed=0)
        b = stream.batch(0, 16)
        params = rs.xdeepfm_init(KEY, cfg)
        loss, _ = rs.xdeepfm_loss(
            params, {"sparse": jnp.asarray(b["sparse"]),
                     "label": jnp.asarray(b["label"])}, cfg)
    elif arch == "bert4rec":
        stream = SeqRecStream(cfg.n_items, seed=0)
        b = stream.bert4rec_batch(0, 8, cfg.seq_len, cfg.mask_prob,
                                  mask_token=cfg.n_items + 1)
        params = rs.bert4rec_init(KEY, cfg)
        loss, _ = rs.bert4rec_loss(params, {k: jnp.asarray(v)
                                            for k, v in b.items()}, cfg)
        emb = rs.bert4rec_user_embedding(params, jnp.asarray(b["seq"]),
                                         jnp.asarray(b["mask"]), cfg)
        assert emb.shape == (8, cfg.embed_dim)
    else:
        stream = SeqRecStream(cfg.n_items, seed=0)
        b = stream.mind_batch(0, 8, cfg.hist_len)
        params = rs.mind_init(KEY, cfg)
        loss, _ = rs.mind_loss(params, {k: jnp.asarray(v)
                                        for k, v in b.items()}, cfg)
        s = rs.mind_score_candidates(params, jnp.asarray(b["hist"]),
                                     jnp.asarray(b["hist_mask"]),
                                     jnp.arange(50), cfg)
        assert s.shape == (8, 50)
    assert np.isfinite(float(loss))


def test_embedding_bag_modes(rng):
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    idx = jnp.asarray([0, 1, 2, 3, 4, 5], jnp.int32)
    offsets = jnp.asarray([0, 2, 5], jnp.int32)
    out = rs.embedding_bag(table, idx, offsets=offsets, n_bags=3)
    np.testing.assert_allclose(np.asarray(out[0]),
                               np.asarray(table[0] + table[1]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out[2]), np.asarray(table[5]),
                               rtol=1e-6)
    out_m = rs.embedding_bag(table, idx, offsets=offsets, n_bags=3,
                             mode="mean")
    np.testing.assert_allclose(np.asarray(out_m[0]),
                               np.asarray(table[0] + table[1]) / 2, rtol=1e-6)
