import dataclasses
import os

# Multi-device CPU: the mesh-sharding tier (tests/test_mesh_sharding.py,
# DESIGN.md §12) partitions cluster buffers across jax devices, and XLA
# only honours --xla_force_host_platform_device_count if it is in the
# environment BEFORE jax first initialises its backends — hence here, at
# the top of conftest, ahead of any repro/jax import. Append-safe: an
# externally-set XLA_FLAGS (e.g. the CI mesh job) is preserved.
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8").strip()

import numpy as np
import pytest

from repro.configs import get_config
from repro.data import GeoCorpus, GeoCorpusConfig


@pytest.fixture(scope="session")
def small_corpus():
    return GeoCorpus(GeoCorpusConfig(
        n_objects=600, n_queries=120, n_topics=8, vocab_size=2048, seed=0))


@pytest.fixture(scope="session")
def tiny_de_cfg():
    return dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=2048,
        max_len=16, spatial_t=50, n_clusters=4, neg_start=200, neg_end=300,
        index_mlp_hidden=(32,))


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
