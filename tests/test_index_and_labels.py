"""LIST-I: cluster classifier, buffers, pseudo-labels (paper §4.3)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import index as il
from repro.core import pseudo_labels as pslab
from repro.core import cluster_metrics as cm
from repro.optim import clip_by_global_norm, make_optimizer


def test_features_l2_normalized(rng):
    emb = jnp.asarray(rng.normal(0, 10, size=(50, 16)), jnp.float32)
    loc = jnp.asarray(rng.uniform(5, 9, size=(50, 2)), jnp.float32)
    norm = il.loc_normalizer(loc)
    x = np.asarray(il.build_features(emb, loc, norm))
    np.testing.assert_allclose(np.linalg.norm(x[:, :16], axis=1), 1.0,
                               rtol=1e-5)
    assert (x[:, 16:] >= -1e-6).all() and (x[:, 16:] <= 1 + 1e-6).all()


def test_cluster_probs_simplex(rng):
    p = il.index_init(jax.random.PRNGKey(0), 8, 5, hidden=(16,))
    x = jnp.asarray(rng.normal(size=(20, 10)), jnp.float32)
    probs = np.asarray(il.cluster_probs(p, x))
    np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)
    assert (probs >= 0).all()


def test_mcl_learns_separable_clusters(rng):
    """MCL (Eq. 14) groups relevant pairs and balances clusters."""
    G, N, d = 3, 600, 8
    centers = rng.normal(0, 1, (G, d)) * 4
    go = rng.integers(0, G, N)
    emb = (centers[go] + rng.normal(0, 0.3, (N, d))).astype(np.float32)
    loc = rng.uniform(0, 1, (N, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(loc))
    feats = np.asarray(il.build_features(jnp.asarray(emb), jnp.asarray(loc),
                                         norm))
    ip = il.index_init(jax.random.PRNGKey(1), d, G, hidden=(32,))
    oi, ou = make_optimizer("adamw")
    stt = oi(ip)

    @jax.jit
    def step(ip, stt, fb):
        (l, m), g = jax.value_and_grad(il.mcl_loss, has_aux=True)(ip, fb)
        g, _ = clip_by_global_norm(g, 1.0)
        return *ou(g, stt, ip, 3e-3), m

    for s in range(250):
        rows = rng.integers(0, N, 32)
        pos = np.array([rng.choice(np.nonzero(go == go[r])[0])
                        for r in rows])
        neg = np.array([rng.choice(np.nonzero(go != go[r])[0], size=4)
                        for r in rows])
        fb = {"q_feat": jnp.asarray(feats[rows]),
              "pos_feat": jnp.asarray(feats[pos]),
              "neg_feat": jnp.asarray(feats[neg.reshape(-1)]).reshape(
                  32, 4, -1)}
        ip, stt, m = step(ip, stt, fb)
    assert float(m["s_pos"]) > 0.8
    assert float(m["s_neg"]) < 0.2
    a = np.asarray(il.assign_clusters(ip, jnp.asarray(feats)))
    assert cm.imbalance_factor(a, G) < 1.3
    # purity: each group maps to a single cluster
    for g_ in range(G):
        counts = np.bincount(a[go == g_], minlength=G)
        assert counts.max() / counts.sum() > 0.95


@hypothesis.given(n=st.integers(20, 200), c=st.integers(2, 8),
                  seed=st.integers(0, 3))
@hypothesis.settings(max_examples=15, deadline=None)
def test_buffer_invariants(n, c, seed):
    """Every object lands in exactly one buffer slot; pads are -1."""
    r = np.random.default_rng(seed)
    emb = r.normal(size=(n, 4)).astype(np.float32)
    loc = r.uniform(size=(n, 2)).astype(np.float32)
    assign = r.integers(0, c, size=(n, 3))
    buf = il.build_cluster_buffers(assign, emb, loc, n_clusters=c)
    ids = np.asarray(buf["ids"])
    placed = ids[ids >= 0]
    assert sorted(placed.tolist()) == list(range(n))     # exactly once
    assert int(np.asarray(buf["counts"]).sum()) == n
    # stored embeddings match originals
    for ci in range(c):
        for slot in range(int(np.asarray(buf["counts"])[ci])):
            oid = ids[ci, slot]
            np.testing.assert_allclose(
                np.asarray(buf["emb"])[ci, slot], emb[oid], rtol=1e-6)


def test_insert_delete_roundtrip(rng):
    n, c, d = 40, 4, 8
    emb = rng.normal(size=(n, d)).astype(np.float32)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(loc))
    ip = il.index_init(jax.random.PRNGKey(0), d, c, hidden=(8,))
    feats = il.build_features(jnp.asarray(emb), jnp.asarray(loc), norm)
    top = np.asarray(il.assign_clusters(ip, feats, top=2))
    buf = il.build_cluster_buffers(top, emb, loc, n_clusters=c)

    new_emb = rng.normal(size=(3, d)).astype(np.float32)
    new_loc = rng.uniform(size=(3, 2)).astype(np.float32)
    buf2 = il.insert_objects(buf, ip, norm, jnp.asarray(new_emb),
                             jnp.asarray(new_loc), np.array([100, 101, 102]))
    ids2 = np.asarray(buf2["ids"])
    assert {100, 101, 102} <= set(ids2[ids2 >= 0].tolist())
    assert int(np.asarray(buf2["counts"]).sum()) == n + 3

    buf3 = il.delete_objects(buf2, [100, 0, 5])
    ids3 = np.asarray(buf3["ids"])
    assert not ({100, 0, 5} & set(ids3[ids3 >= 0].tolist()))
    assert int(np.asarray(buf3["counts"]).sum()) == n


def _rand_scores_setup(rng, n=300, b=6, d=8):
    import dataclasses
    from repro.configs import get_config
    cfg = dataclasses.replace(get_config("list-dual-encoder"), d_model=8,
                              spatial_t=20)
    q_emb = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    q_loc = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    o_emb = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    o_loc = jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32)
    import jax as _jax
    from repro.core import relevance
    params = {"weight_mlp": None, "fixed_w": jnp.array([1.0, 1.0]),
              "spatial": {"w_s": jnp.zeros(20)}}
    return cfg, params, q_emb, q_loc, o_emb, o_loc


def test_mine_negatives_matches_argsort(rng):
    """Eq. 13: window slice of mined negatives == argsort window."""
    import dataclasses
    from repro.configs import get_config
    from repro.core import relevance
    cfg = dataclasses.replace(get_config("list-dual-encoder"), d_model=8,
                              spatial_t=20)
    b, n, d = 4, 200, 8
    key = jax.random.PRNGKey(0)
    params = relevance.relevance_init(key, cfg)
    # bypass encoders: call mine with raw embeddings
    q_emb = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    q_loc = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    o_emb = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    o_loc = jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32)
    ns_, ne_ = 50, 80
    idx = np.asarray(pslab.mine_negatives(
        params, cfg, q_emb, q_loc, o_emb, o_loc,
        neg_start=ns_, neg_end=ne_, dist_max=1.414))
    st_full = np.asarray(relevance.score_corpus(
        params, q_emb, q_loc, o_emb, o_loc, cfg, dist_max=1.414,
        train=False))
    expect = np.argsort(-st_full, axis=1)[:, ns_:ne_]
    assert idx.shape == (b, ne_ - ns_)
    # same WINDOW membership (order within window may differ on ties)
    for i in range(b):
        assert set(idx[i].tolist()) == set(expect[i].tolist())


def test_mine_negatives_excludes_positives(rng):
    import dataclasses
    from repro.configs import get_config
    from repro.core import relevance
    cfg = dataclasses.replace(get_config("list-dual-encoder"), d_model=8,
                              spatial_t=20)
    b, n, d = 3, 100, 8
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    q_emb = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    q_loc = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    o_emb = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    o_loc = jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32)
    pos_mask = np.zeros((b, n), bool)
    pos_mask[:, :10] = True
    idx = np.asarray(pslab.mine_negatives(
        params, cfg, q_emb, q_loc, o_emb, o_loc,
        pos_mask=jnp.asarray(pos_mask), neg_start=0, neg_end=50,
        dist_max=1.414))
    assert (idx >= 10).all()


def test_mine_dense_approximates_exact(rng):
    """Sharded mining (top-k merge) reproduces the exact window."""
    import dataclasses
    from repro.configs import get_config
    from repro.core import relevance
    cfg = dataclasses.replace(get_config("list-dual-encoder"), d_model=8,
                              spatial_t=20)
    b, n, d = 3, 512, 8
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    q_emb = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    q_loc = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    o_emb = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    o_loc = jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32)
    exact = np.asarray(pslab.mine_negatives(
        params, cfg, q_emb, q_loc, o_emb, o_loc, neg_start=20, neg_end=60,
        dist_max=1.414))
    dense = np.asarray(pslab.mine_negatives_dense(
        params, cfg, q_emb, q_loc, o_emb, o_loc, neg_start=20, neg_end=60,
        dist_max=1.414, shards=8, per_shard_k=64))
    for i in range(b):
        inter = len(set(exact[i].tolist()) & set(dense[i].tolist()))
        assert inter >= 0.95 * exact.shape[1]


def test_cluster_metrics():
    obj_assign = np.array([0, 0, 0, 1, 1, 1])
    assert cm.imbalance_factor(obj_assign, 2) == pytest.approx(1.0)
    skew = cm.imbalance_factor(np.zeros(6, int), 2)
    assert skew == pytest.approx(2.0)
    pc, _ = cm.cluster_precision(
        np.array([0, 1]), [np.array([0, 1]), np.array([3])], obj_assign, 2)
    assert pc == pytest.approx(1.0)
    assert cm.recall_at_k([[0, 1], [5, 3]],
                          [np.array([0, 1]), np.array([3])], 2) == 1.0
    assert cm.ndcg_at_k([[0, 9], [3, 9]],
                        [np.array([0]), np.array([3])], 2) == 1.0
