"""IndexSnapshot lifecycle tier (core/snapshot.py, DESIGN.md §8).

Covers the acceptance criteria of the artifact model:

* ``save(dir)`` → ``load(dir)`` → query is BIT-IDENTICAL to the
  in-memory snapshot on both backends (dense | pallas);
* a schema-version mismatch raises a clear error instead of silently
  reinterpreting the artifact;
* publishes are atomic: the engine refuses a cfg-digest mismatch, a
  server hot-swap under in-flight micro-batches pins every flush to
  exactly one snapshot (engine call-spy, tests/test_server.py style),
  and an open-loop run with a mid-run swap completes with zero
  failed/torn requests.
"""
import asyncio
import dataclasses
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config
from repro.core import engine as engine_lib
from repro.core import index as il
from repro.core import relevance
from repro.core import server as server_lib
from repro.core import snapshot as snapshot_lib
from repro.core.snapshot import IndexSnapshot

DIST_MAX = 1.414


# ---------------------------------------------------------------------------
# Fixture: a tiny built index (random params — the artifact layer is
# quality-agnostic)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def snap():
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(7)
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap = 96, cfg.n_clusters, 64        # headroom for inserts
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(5), cfg.d_model, c,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap)
    return IndexSnapshot.from_parts(cfg, params, iparams, norm, buf,
                                    dist_max=DIST_MAX)


def make_requests(rng, n, cfg):
    tok = rng.integers(2, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones((n, cfg.max_len), bool)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    return tok, msk, loc


def grown(snapshot, rng, n_new=5, base=5000):
    """The successor snapshot: n_new freshly routed objects, version + 1."""
    d = snapshot.cfg.d_model
    new_emb = jnp.asarray(rng.normal(size=(n_new, d)), jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(n_new, 2)), jnp.float32)
    buf = il.insert_objects(snapshot.buffers, snapshot.index_params,
                            snapshot.norm, new_emb, new_loc,
                            np.arange(base, base + n_new))
    return snapshot.with_buffers(buf)


# ---------------------------------------------------------------------------
# save → load → query bit-identity (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_roundtrip_bit_identical(snap, tmp_path, rng, backend):
    tok, msk, loc = make_requests(rng, 10, snap.cfg)
    path = api.save(snap, str(tmp_path))
    assert os.path.exists(os.path.join(path, "manifest.json"))
    loaded = api.load(str(tmp_path))

    assert loaded.meta == snap.meta
    assert loaded.cfg == snap.cfg
    assert loaded.buffers["capacity"] == snap.buffers["capacity"]
    assert loaded.buffers["n_spilled"] == snap.buffers["n_spilled"]

    ids_m, sc_m = api.Searcher(snap, backend=backend).query(
        tok, msk, loc, k=5, cr=2, batch=4)
    ids_l, sc_l = api.Searcher(loaded, backend=backend).query(
        tok, msk, loc, k=5, cr=2, batch=4)
    assert np.array_equal(ids_m, ids_l)
    assert np.array_equal(sc_m, sc_l)               # every score bit


def test_save_load_preserves_version_and_params(snap, tmp_path, rng):
    snap2 = grown(snap, rng)
    assert snap2.meta.version == snap.meta.version + 1
    assert snap2.meta.n_objects == snap.meta.n_objects + 5
    assert snap2.meta.cfg_digest == snap.meta.cfg_digest
    # the predecessor is untouched (immutability)
    assert not (np.asarray(snap.buffers["ids"]) >= 5000).any()

    api.save(snap2, str(tmp_path))
    loaded = api.load(str(tmp_path))
    assert loaded.meta.version == snap2.meta.version
    for a, b in zip(jax.tree.leaves(loaded.rel_params),
                    jax.tree.leaves(snap2.rel_params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_schema_version_mismatch_raises(snap, tmp_path):
    path = api.save(snap, str(tmp_path))
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["meta"]["schema_version"] = snapshot_lib.SCHEMA_VERSION + 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    with pytest.raises(ValueError, match="schema"):
        api.load(str(tmp_path))


def test_load_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        api.load(str(tmp_path))


def test_save_refuses_older_version_into_newer_dir(snap, tmp_path, rng):
    """A directory holds one lineage: saving version 0 into a directory
    already committed at version 1 would leave load() serving the old
    artifact while the save looked successful — refused."""
    snap2 = grown(snap, rng)
    api.save(snap2, str(tmp_path))
    with pytest.raises(ValueError, match="already holds"):
        api.save(snap, str(tmp_path))
    assert api.load(str(tmp_path)).meta.version == snap2.meta.version


def test_publish_refuses_cfg_digest_mismatch(snap, rng):
    eng = engine_lib.QueryEngine.from_snapshot(snap, backend="dense")
    other_cfg = dataclasses.replace(snap.cfg, spatial_t=51)
    impostor = IndexSnapshot.from_parts(
        other_cfg, snap.rel_params, snap.index_params, snap.norm,
        snap.buffers, dist_max=DIST_MAX)
    with pytest.raises(ValueError, match="cfg_digest"):
        eng.publish(impostor)
    assert eng.snapshot is snap                     # swap did NOT happen


def test_plans_survive_publish(snap, rng):
    """Same buffer shapes ⇒ the traced (batch, k, cr, backend, precision,
    filtered) plans are reused across a publish — no rebind, no plan-cache reset."""
    eng = engine_lib.QueryEngine.from_snapshot(snap, backend="dense")
    tok, msk, loc = make_requests(rng, 4, snap.cfg)
    eng.query(tok, msk, loc, k=5, cr=2, batch=4)
    plans = dict(eng._plans)
    assert set(plans) == {(4, 5, 2, "dense", "f32", False)}
    eng.publish(grown(snap, rng))
    ids, _ = eng.query(tok, msk, loc, k=5, cr=2, batch=4)
    assert eng._plans == plans                      # same plan objects
    assert ids.shape == (4, 5)


# ---------------------------------------------------------------------------
# Precision tiers (DESIGN.md §9): quantized round-trip + identity gates
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("precision", ["bf16", "int8"])
@pytest.mark.parametrize("backend", ["dense", "pallas"])
def test_quantized_roundtrip_bit_identical(snap, tmp_path, rng, precision,
                                           backend):
    """save → load of a quantized snapshot reproduces every byte: the
    storage dtype, the scales, and the query results on both backends."""
    snap_q = snap.with_precision(precision)
    assert snap_q.meta.precision == precision
    assert snap_q.meta.version == snap.meta.version + 1
    want_dtype = "bfloat16" if precision == "bf16" else "int8"
    assert str(np.asarray(snap_q.buffers["emb"]).dtype) == want_dtype

    tok, msk, loc = make_requests(rng, 10, snap.cfg)
    api.save(snap_q, str(tmp_path))
    loaded = api.load(str(tmp_path))
    assert loaded.meta == snap_q.meta
    assert str(np.asarray(loaded.buffers["emb"]).dtype) == want_dtype
    assert np.array_equal(np.asarray(loaded.buffers["emb"]),
                          np.asarray(snap_q.buffers["emb"]))
    assert np.array_equal(np.asarray(loaded.buffers["scale"]),
                          np.asarray(snap_q.buffers["scale"]))

    ids_m, sc_m = api.Searcher(snap_q, backend=backend).query(
        tok, msk, loc, k=5, cr=2, batch=4)
    ids_l, sc_l = api.Searcher(loaded, backend=backend).query(
        tok, msk, loc, k=5, cr=2, batch=4)
    assert np.array_equal(ids_m, ids_l)
    assert np.array_equal(sc_m, sc_l)               # every score bit


def test_unknown_precision_refused_before_arrays(snap, tmp_path,
                                                 monkeypatch):
    """An artifact declaring a precision this build doesn't understand
    must raise BEFORE any leaf array is read (the payload bytes would
    be misinterpreted)."""
    path = api.save(snap.with_precision("int8"), str(tmp_path))
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    manifest["meta"]["precision"] = "fp4"
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    calls = []
    from repro.checkpoint import ckpt as ckpt_lib
    orig_restore = ckpt_lib.restore
    monkeypatch.setattr(ckpt_lib, "restore",
                        lambda *a, **kw: (calls.append(1),
                                          orig_restore(*a, **kw))[1])
    with pytest.raises(ValueError, match="precision"):
        api.load(str(tmp_path))
    assert calls == []                     # gate fired before restore


def test_with_buffers_refuses_precision_change(snap):
    """with_buffers preserves the precision tier; switching tiers only
    goes through with_precision (which requantizes from f32)."""
    snap_q = snap.with_precision("int8")
    with pytest.raises(ValueError, match="precision"):
        snap_q.with_buffers(snap.buffers)          # f32 buffers into int8
    # and requantizing an already-quantized tier is refused too
    with pytest.raises(ValueError, match="f32"):
        snap_q.with_precision("bf16")


def test_quantized_insert_preserves_dtype_and_serves(snap, rng):
    """Corpus mutation on a quantized snapshot: the insert quantizes the
    new rows in, dtype/scales stay consistent, and the object is
    retrievable."""
    snap_q = snap.with_precision("int8")
    snap2 = grown(snap_q, rng, n_new=3, base=8000)
    assert snap2.meta.precision == "int8"
    assert str(np.asarray(snap2.buffers["emb"]).dtype) == "int8"
    assert (np.asarray(snap2.buffers["ids"]) >= 8000).sum() == 3
    eng = engine_lib.QueryEngine.from_snapshot(snap2, backend="dense")
    tok, msk, loc = make_requests(rng, 4, snap.cfg)
    k_all = snap2.buffers["capacity"] * snap2.cfg.n_clusters
    ids, _ = eng.query(tok, msk, loc, k=k_all, cr=snap2.cfg.n_clusters,
                       batch=4)
    assert (ids >= 8000).any()


# ---------------------------------------------------------------------------
# Atomic hot-swap under live traffic
# ---------------------------------------------------------------------------


def spy_versions(server):
    """Record the snapshot version each engine call was pinned to."""
    seen = []
    orig = server.engine.query

    def spying(*a, **kw):
        pinned = kw.get("snapshot") or server.engine.snapshot
        seen.append(pinned.meta.version)
        return orig(*a, **kw)

    server.engine.query = spying
    return seen


def test_hot_swap_pins_inflight_flushes(snap, rng):
    """Requests queued before a publish flush AFTER it: the whole batch
    pins the new snapshot (one version per engine call — never a mix),
    and every result is bit-identical to that snapshot's oracle."""
    server = server_lib.StreamingServer(
        engine_lib.QueryEngine.from_snapshot(snap, backend="dense"),
        server_lib.ServerConfig(batch_size=4, max_delay_ms=60_000.0,
                                k=5, cr=2, backend="dense"))
    versions = spy_versions(server)
    tok, msk, loc = make_requests(rng, 8, snap.cfg)
    snap2 = grown(snap, rng)

    async def go():
        first = [asyncio.ensure_future(server.submit(tok[i], msk[i], loc[i]))
                 for i in range(3)]                  # queued, not flushed
        await asyncio.sleep(0)
        assert server.n_pending == 3
        server.publish(snap2)                        # swap mid-queue
        rest = [asyncio.ensure_future(server.submit(tok[i], msk[i], loc[i]))
                for i in range(3, 8)]                # 4th submit → size flush
        await asyncio.sleep(0)
        server.flush_now()
        return await asyncio.gather(*first, *rest)

    out = asyncio.run(go())
    # every flush pinned exactly one snapshot — the published one
    assert versions == [snap2.meta.version] * 2
    oracle = engine_lib.QueryEngine.from_snapshot(snap2, backend="dense")
    ids_d, sc_d = oracle.query(tok, msk, loc, k=5, cr=2, batch=4)
    for i, (ids, sc) in enumerate(out):
        assert np.array_equal(ids, ids_d[i])
        assert np.array_equal(sc, sc_d[i])


def test_open_loop_swap_zero_failed_or_torn(snap, rng):
    """The acceptance criterion: a snapshot swap during an active
    open-loop run completes with zero failed requests, and every answer
    matches one snapshot's oracle bit-exactly (none torn across two)."""
    server = server_lib.StreamingServer(
        engine_lib.QueryEngine.from_snapshot(snap, backend="dense"),
        server_lib.ServerConfig(batch_size=4, max_delay_ms=1.0,
                                k=5, cr=2, backend="dense"))
    n = 32
    tok, msk, loc = make_requests(rng, n, snap.cfg)
    requests = [(tok[i], msk[i], loc[i]) for i in range(n)]
    snap2 = grown(snap, rng)

    # deterministic mid-run swap: the spy publishes the successor right
    # after the 2nd engine batch returns, while 24 requests are still
    # queued or unsent — later flushes must pin the new snapshot
    versions = []
    orig = server.engine.query

    def spy_then_swap(*a, **kw):
        versions.append(kw["snapshot"].meta.version)
        res = orig(*a, **kw)
        if len(versions) == 2:
            server.publish(snap2)
        return res

    server.engine.query = spy_then_swap
    results = asyncio.run(server_lib.open_loop(server, requests, qps=4000.0))
    assert len(results) == n                         # zero failed requests
    assert server.engine.snapshot is snap2
    assert set(versions) <= {snap.meta.version, snap2.meta.version}
    o1 = engine_lib.QueryEngine.from_snapshot(snap, backend="dense")
    o2 = engine_lib.QueryEngine.from_snapshot(snap2, backend="dense")
    ids1, sc1 = o1.query(tok, msk, loc, k=5, cr=2, batch=4)
    ids2, sc2 = o2.query(tok, msk, loc, k=5, cr=2, batch=4)
    n_new = 0
    for i, (ids, sc) in enumerate(results):
        old = np.array_equal(ids, ids1[i]) and np.array_equal(sc, sc1[i])
        new = np.array_equal(ids, ids2[i]) and np.array_equal(sc, sc2[i])
        assert old or new, f"request {i} matches NEITHER snapshot (torn)"
        n_new += int(new and not old)
    # the swap actually landed mid-run: BOTH generations served batches
    assert snap.meta.version in versions
    assert snap2.meta.version in versions


def test_server_insert_publishes_successor(snap, rng):
    """StreamingServer.insert_objects returns the published successor and
    the inserted ids are immediately retrievable; the old snapshot object
    is untouched. Pre-compaction the rows live in the delta segment —
    ``compact_now`` folds them into the buffers."""
    server = server_lib.StreamingServer(
        engine_lib.QueryEngine.from_snapshot(snap, backend="dense"),
        server_lib.ServerConfig(batch_size=2, k=5, cr=4, backend="dense"))
    d = snap.cfg.d_model
    new_emb = jnp.asarray(rng.normal(size=(3, d)), jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(3, 2)), jnp.float32)
    snap2 = server.insert_objects(new_emb, new_loc, np.arange(7000, 7003))
    assert isinstance(snap2, IndexSnapshot)
    assert server.engine.snapshot is snap2
    assert snap2.meta.version == snap.meta.version + 1
    assert server.stats.invalidations == 1
    assert not (np.asarray(snap.buffers["ids"]) >= 7000).any()
    assert snap.delta is None                       # predecessor untouched
    # O(batch): rows pend in the delta, the base buffers are untouched
    assert snap2.meta.delta_rows == 3
    assert {7000, 7001, 7002} <= set(snap2.delta.ids_live)
    assert not (np.asarray(snap2.buffers["ids"]) >= 7000).any()
    snap3 = server.compact_now()
    assert snap3.delta is None
    assert (np.asarray(snap3.buffers["ids"]) >= 7000).sum() == 3


# ---------------------------------------------------------------------------
# Schema v3: the delta subtree round-trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("precision", ["f32", "int8"])
def test_delta_roundtrip_bit_identical(snap, tmp_path, rng, precision):
    """save → load of a snapshot carrying a delta segment (schema v3)
    reproduces every byte — the delta rows, tombstones, meta — and
    queries on the loaded artifact are bit-identical."""
    from repro.core.delta import DeltaSegment

    d = snap.cfg.d_model
    snap_p = snap if precision == "f32" else snap.with_precision(precision)
    seg = (DeltaSegment.empty(d, precision)
           .insert(rng.normal(size=(4, d)).astype(np.float32),
                   rng.uniform(size=(4, 2)).astype(np.float32),
                   np.arange(7100, 7104))
           .delete([7100, int(np.asarray(snap.buffers["ids"])[0, 0])]))
    snap_d = snap_p.with_delta(seg)
    assert snap_d.meta.delta_rows == 3 and snap_d.meta.n_tombstones == 2

    api.save(snap_d, str(tmp_path))
    loaded = api.load(str(tmp_path))
    assert loaded.meta == snap_d.meta
    assert loaded.delta is not None
    assert loaded.delta.tombstones == seg.tombstones
    assert loaded.delta.ids_live == seg.ids_live
    for f in ("emb", "scale", "loc", "ids", "raw"):
        assert np.array_equal(np.asarray(loaded.delta.arrays()[f]),
                              np.asarray(seg.arrays()[f])), f

    tok, msk, loc = make_requests(rng, 8, snap.cfg)
    ids_m, sc_m = api.Searcher(snap_d, backend="dense").query(
        tok, msk, loc, k=5, cr=2, batch=4)
    ids_l, sc_l = api.Searcher(loaded, backend="dense").query(
        tok, msk, loc, k=5, cr=2, batch=4)
    assert np.array_equal(ids_m, ids_l)
    assert np.array_equal(sc_m, sc_l)               # every score bit


def test_with_precision_refuses_nonempty_delta(snap, rng):
    """Requantization is only defined on a compacted snapshot: the delta
    keeps raw f32 rows quantized at ITS tier, so switching tiers under a
    live delta would desynchronize the two."""
    from repro.core.delta import DeltaSegment

    d = snap.cfg.d_model
    seg = DeltaSegment.empty(d).insert(
        rng.normal(size=(2, d)).astype(np.float32),
        rng.uniform(size=(2, 2)).astype(np.float32), [7200, 7201])
    snap_d = snap.with_delta(seg)
    with pytest.raises(ValueError, match="delta"):
        snap_d.with_precision("int8")
    assert snap_d.compact().with_precision("int8").meta.precision == "int8"


def test_with_delta_refuses_precision_mismatch(snap):
    from repro.core.delta import DeltaSegment

    seg = DeltaSegment.empty(snap.cfg.d_model, "int8")
    with pytest.raises(ValueError, match="tiers must match"):
        snap.with_delta(seg)                        # f32 snap, int8 delta
