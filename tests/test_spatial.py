"""Spatial step-function module (paper Eq. 4–5): invariants + equivalences."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core import spatial as sp


def _params(t=50, seed=0):
    return sp.spatial_init(jax.random.PRNGKey(seed), t)


def test_train_serve_equivalence():
    """Eq. 4 (indicator sum) == Eq. 5 (prefix-table lookup) at thresholds."""
    t = 50
    p = _params(t)
    s_in = jnp.linspace(0.0, 0.999, 200)
    train = sp.spatial_relevance_train(p, s_in, t=t)
    w_hat = sp.extract_lookup(p)
    serve = sp.spatial_relevance_serve(w_hat, s_in)
    # serve table index floor(s*t) counts thresholds T[i]=i/t with T[i]<=s,
    # minus the always-on T[0]=0 ... both count indicators; equal everywhere
    np.testing.assert_allclose(np.asarray(train), np.asarray(serve),
                               rtol=1e-5, atol=1e-5)


@hypothesis.given(
    s1=st.floats(0.0, 1.0), s2=st.floats(0.0, 1.0),
    seed=st.integers(0, 5))
@hypothesis.settings(max_examples=50, deadline=None)
def test_monotone_nondecreasing(s1, s2, seed):
    """SRel is monotonically non-decreasing in S_in BY CONSTRUCTION."""
    p = _params(seed=seed)
    lo, hi = min(s1, s2), max(s1, s2)
    w_hat = sp.extract_lookup(p)
    r_lo = float(sp.spatial_relevance_serve(w_hat, jnp.float32(lo)))
    r_hi = float(sp.spatial_relevance_serve(w_hat, jnp.float32(hi)))
    assert r_hi >= r_lo - 1e-6


@hypothesis.given(st.integers(2, 200))
@hypothesis.settings(max_examples=20, deadline=None)
def test_lookup_is_prefix_sum(t):
    p = _params(t=t)
    w_hat = np.asarray(sp.extract_lookup(p))
    w = np.asarray(jax.nn.softplus(p["w_s"]))
    np.testing.assert_allclose(w_hat, np.cumsum(w), rtol=1e-5)
    assert (np.diff(w_hat) >= 0).all()


def test_gradient_flows_to_weights():
    p = _params()
    s_in = jnp.asarray([0.2, 0.5, 0.9])

    def loss(pp):
        return sp.spatial_relevance_train(pp, s_in, t=50).sum()

    g = jax.grad(loss)(p)["w_s"]
    assert float(jnp.abs(g).sum()) > 0


def test_straight_through_gradient_to_input():
    p = _params()

    def loss(s):
        return sp.spatial_relevance_train(p, s, t=50).sum()

    g = jax.grad(loss)(jnp.asarray([0.5]))
    assert np.isfinite(np.asarray(g)).all()
    assert float(g[0]) > 0  # closer (higher s_in) => higher relevance


def test_serve_clipping():
    p = _params()
    w_hat = sp.extract_lookup(p)
    out = sp.spatial_relevance_serve(w_hat, jnp.asarray([-0.5, 0.0, 1.0, 2.0]))
    assert np.isfinite(np.asarray(out)).all()
    assert float(out[3]) == float(np.asarray(w_hat)[-1])


def test_exp_ablation_nonnegative_monotone():
    p = sp.exp_init(jax.random.PRNGKey(0))
    s = jnp.linspace(0.01, 1.0, 50)
    out = np.asarray(sp.exp_srel(p, s))
    assert (out >= 0).all()
    assert (np.diff(out) >= -1e-6).all()


def test_sdist_range(rng):
    q = jnp.asarray(rng.uniform(size=(10, 2)), jnp.float32)
    o = jnp.asarray(rng.uniform(size=(10, 2)), jnp.float32)
    d = np.asarray(sp.sdist(q, o, np.sqrt(2.0)))
    assert (d >= 0).all() and (d <= 1).all()
