"""Mesh-sharded serving parity tier (DESIGN.md §12).

The headline contract: partitioning the resident cluster buffers across
a device mesh is PLACEMENT, not content — for every shard count, backend
and precision tier the sharded engine returns

* bit-identical top-k ids vs the single-device engine,
* scores equal to the single-device engine up to fusion ulps (the
  decomposed prefix+scan programs are distinct XLA programs from the
  fused single-device plan, so the last bit of a float reduction may
  differ — ids never do),
* bit-identical ids AND scores across shard counts (the sharded path is
  one program family: S=1 vs S=8 agree on every bit).

Runs multi-device on CPU: conftest force-sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` ahead of any jax
import, and the CI ``mesh`` job exports the same flag.

Also covers the satellites: a hypothesis property over random
cluster→shard assignments, non-divisible ``c % n_shards`` remainder
handling, elastic persistence (save sharded → load under 8→4→1 devices,
bit-identical to the never-sharded build, including a delta-nonempty
LSM case), and server hot-swap of a re-sharded snapshot under open-loop
load with zero failed/torn requests.
"""
import asyncio
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config
from repro.core import delta as delta_lib
from repro.core import engine as engine_lib
from repro.core import index as il
from repro.core import relevance
from repro.core import server as server_lib
from repro.core.snapshot import IndexSnapshot

DIST_MAX = 1.4142
BACKENDS = ("dense", "pallas", "dense-cm", "pallas-cm")
SHARD_COUNTS = (1, 2, 4, 8)
N_DEV = jax.device_count()


def _need(n_shards):
    if n_shards > N_DEV:
        pytest.skip(f"needs {n_shards} devices, have {N_DEV} "
                    f"(XLA_FLAGS=--xla_force_host_platform_device_count)")


def _build_snap(n_clusters, seed=0, n=96, cap=32):
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=n_clusters,
        index_mlp_hidden=(16,))
    rng = np.random.default_rng(seed)
    rel = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(1), cfg.d_model, n_clusters,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc,
                                   n_clusters=n_clusters, capacity=cap)
    return IndexSnapshot.from_parts(cfg, rel, iparams, norm, buf,
                                    dist_max=DIST_MAX)


def _make_queries(cfg, n=12, seed=0):
    rng = np.random.default_rng(seed)
    tok = rng.integers(2, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones_like(tok, bool)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    return tok, msk, loc


@pytest.fixture(scope="module")
def snap8():
    return _build_snap(8)            # c divisible by every shard count


@pytest.fixture(scope="module")
def queries(snap8):
    return _make_queries(snap8.cfg)


# one query run per (precision, backend, S) for the whole module — the
# matrix below compares cached results, not 48 fresh compiles
_cache = {}


def _run(snap, backend, queries, *, tag):
    if tag not in _cache:
        tok, msk, loc = queries
        _cache[tag] = api.Searcher(snap, backend=backend).query(
            tok, msk, loc, k=5, cr=2, batch=4)
    return _cache[tag]


def _ref(snap8, precision, backend, queries):
    return _run(snap8.with_precision(precision), backend, queries,
                tag=("ref", precision, backend))


def _sharded(snap8, precision, backend, n_shards, queries):
    key = ("mesh", precision, n_shards)
    if key not in _cache:
        _cache[key] = snap8.with_precision(precision).with_mesh(n_shards)
    return _run(_cache[key], backend, queries,
                tag=("out", precision, backend, n_shards))


# ---------------------------------------------------------------------------
# The parity matrix: {1,2,4,8} shards × backends × precision tiers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("precision", il.PRECISIONS)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_parity_matrix(snap8, queries, precision, backend, n_shards):
    _need(n_shards)
    ref_ids, ref_sc = _ref(snap8, precision, backend, queries)
    ids, sc = _sharded(snap8, precision, backend, n_shards, queries)
    assert np.array_equal(ref_ids, ids)             # ids: every bit
    assert np.allclose(ref_sc, sc, rtol=2e-5, atol=1e-6)
    # placement invariance: EVERY bit agrees across shard counts
    a_ids, a_sc = _sharded(snap8, precision, backend, 1, queries)
    assert np.array_equal(a_ids, ids)
    assert np.array_equal(a_sc, sc)


def test_with_mesh_is_placement_not_content(snap8):
    _need(2)
    s = snap8.with_mesh(2)
    assert s.meta.version == snap8.meta.version     # no version bump
    assert s.meta.n_shards == 2
    assert s.shards is not None and s.shards.n_shards == 2
    # buffers stay global host arrays, bit-identical to the base
    for k in ("emb", "loc", "ids", "scale", "counts"):
        assert np.array_equal(np.asarray(s.buffers[k]),
                              np.asarray(snap8.buffers[k]))
    u = s.unshard()
    assert u.shards is None and u.meta.n_shards == 1
    assert np.array_equal(np.asarray(u.buffers["ids"]),
                          np.asarray(snap8.buffers["ids"]))


def test_content_derivations_reshard(snap8, rng):
    """with_buffers / with_precision / compact on a sharded snapshot
    hand back a snapshot sharded the same way (stale placements would
    silently serve the OLD buffers)."""
    _need(2)
    s = snap8.with_mesh(2)
    p = s.with_precision("int8")
    assert p.shards is not None and p.shards.n_shards == 2
    assert p.meta.n_shards == 2
    new_emb = jnp.asarray(rng.normal(size=(3, snap8.cfg.d_model)),
                          jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(3, 2)), jnp.float32)
    buf = il.insert_objects(s.buffers, s.index_params, s.norm,
                            new_emb, new_loc, np.arange(8000, 8003))
    g = s.with_buffers(buf)
    assert g.shards is not None and g.shards.n_shards == 2
    assert (np.asarray(g.buffers["ids"]) >= 8000).any()
    # and the new rows are actually resident on the shards
    got = np.concatenate([np.asarray(part["ids"]).ravel()
                          for part in g.shards.parts])
    assert np.isin(np.arange(8000, 8003), got).all()


# ---------------------------------------------------------------------------
# Remainder policy: c % n_shards != 0 pads short shards, never mis-shards
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", (4, 8))
def test_nondivisible_remainder_parity(n_shards):
    _need(n_shards)
    snap6 = _build_snap(6, seed=2)          # 6 % 4 == 2, 6 < 8
    tok, msk, loc = _make_queries(snap6.cfg, seed=2)
    ref = api.Searcher(snap6, backend="dense").query(tok, msk, loc,
                                                     k=5, cr=2, batch=4)
    s = snap6.with_mesh(n_shards)
    # with 8 shards and 6 clusters some shards hold ONLY padding
    out = api.Searcher(s, backend="dense").query(tok, msk, loc,
                                                 k=5, cr=2, batch=4)
    assert np.array_equal(ref[0], out[0])
    assert np.allclose(ref[1], out[1], rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Property: parity holds for EVERY cluster→shard assignment
# ---------------------------------------------------------------------------


try:                       # optional: richer shrinking when available
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:        # seeded-random fallback, same property
    HAVE_HYPOTHESIS = False

_PROP = {}


def _assignment_parity(n_shards, assignment):
    """The property: ANY cluster→shard map — balanced, skewed, or
    starving some shard entirely — yields bit-identical top-k ids."""
    if not _PROP:
        _PROP["snap"] = _build_snap(8, seed=4)
        _PROP["q"] = _make_queries(_PROP["snap"].cfg, seed=4)
        tok, msk, loc = _PROP["q"]
        _PROP["ref"] = api.Searcher(_PROP["snap"], backend="dense").query(
            tok, msk, loc, k=5, cr=2, batch=12)
    snap, (tok, msk, loc), ref = _PROP["snap"], _PROP["q"], _PROP["ref"]
    s = snap.with_mesh(n_shards, assignment=np.asarray(assignment,
                                                       np.int32))
    out = api.Searcher(s, backend="dense").query(tok, msk, loc,
                                                 k=5, cr=2, batch=12)
    assert np.array_equal(ref[0], out[0]), (n_shards, list(assignment))
    assert np.allclose(ref[1], out[1], rtol=2e-5, atol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(data=st.data())
    def test_random_assignment_parity(data):
        if N_DEV < 2:
            pytest.skip("needs 2+ devices")
        n_shards = data.draw(st.integers(2, min(8, N_DEV)))
        assignment = data.draw(st.lists(st.integers(0, n_shards - 1),
                                        min_size=8, max_size=8))
        _assignment_parity(n_shards, assignment)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_random_assignment_parity(seed):
        if N_DEV < 2:
            pytest.skip("needs 2+ devices")
        rng = np.random.default_rng(100 + seed)
        n_shards = int(rng.integers(2, min(8, N_DEV) + 1))
        # seed 0 pins the adversarial corner: everything on one shard
        if seed == 0:
            assignment = np.zeros(8, np.int32)
        else:
            assignment = rng.integers(0, n_shards, size=8)
        _assignment_parity(n_shards, assignment)


# ---------------------------------------------------------------------------
# Elastic persistence: save sharded, load under 8→4→1 devices
# ---------------------------------------------------------------------------


def test_sharded_persistence_elastic(snap8, queries, tmp_path):
    """Arrays persist GLOBAL (gather-on-save): a snapshot sharded 8 ways
    re-shards at load time to whatever this host can hold — 4, 1, or
    unsharded — with bit-identical ids vs the never-sharded build."""
    _need(2)
    tok, msk, loc = queries
    ref = _ref(snap8, "f32", "dense", queries)
    s = snap8.with_mesh(min(8, N_DEV))
    assert s.meta.n_shards == min(8, N_DEV)
    api.save(s, str(tmp_path))
    for n_shards in (4, 2, 1):
        if n_shards > N_DEV:
            continue
        loaded = api.load(str(tmp_path), mesh=n_shards)
        assert loaded.meta.n_shards == n_shards
        out = api.Searcher(loaded, backend="dense").query(
            tok, msk, loc, k=5, cr=2, batch=4)
        assert np.array_equal(ref[0], out[0])
        assert np.allclose(ref[1], out[1], rtol=2e-5, atol=1e-6)
        # and bitwise vs the in-memory sharded run at the same count
        mem = _sharded(snap8, "f32", "dense", n_shards, queries)
        assert np.array_equal(mem[0], out[0])
        assert np.array_equal(mem[1], out[1])
    # a plain load is UNSHARDED and fully bit-identical to the base
    plain = api.load(str(tmp_path))
    assert plain.shards is None and plain.meta.n_shards == 1
    out = api.Searcher(plain, backend="dense").query(tok, msk, loc,
                                                     k=5, cr=2, batch=4)
    assert np.array_equal(ref[0], out[0])
    assert np.array_equal(ref[1], out[1])


def test_sharded_persistence_with_delta(snap8, queries, tmp_path, rng):
    """The LSM path under sharding: a snapshot with a NON-EMPTY delta
    segment (pending inserts + tombstones, DESIGN.md §11) round-trips
    sharded and serves identically — the delta merge is
    placement-agnostic and composes after the sharded base scan."""
    _need(2)
    tok, msk, loc = queries
    d = snap8.cfg.d_model
    seg = delta_lib.DeltaSegment.empty(d, "f32")
    seg = seg.insert(rng.normal(size=(4, d)).astype(np.float32),
                     rng.uniform(size=(4, 2)).astype(np.float32),
                     np.arange(9000, 9004))
    live_id = int(np.asarray(snap8.buffers["ids"]).ravel()[0])
    seg = seg.delete([live_id])
    snap_d = snap8.with_delta(seg)
    assert snap_d.meta.delta_rows == 4 and snap_d.meta.n_tombstones == 1

    ref = api.Searcher(snap_d, backend="dense").query(
        tok, msk, loc, k=5, cr=8, batch=4)
    assert (ref[0] >= 9000).any()               # delta rows retrievable
    assert not (ref[0] == live_id).any()        # tombstone filtered

    s = snap_d.with_mesh(min(4, N_DEV))
    api.save(s, str(tmp_path))
    loaded = api.load(str(tmp_path), mesh=2)
    assert loaded.meta.delta_rows == 4
    out = api.Searcher(loaded, backend="dense").query(
        tok, msk, loc, k=5, cr=8, batch=4)
    assert np.array_equal(ref[0], out[0])
    assert np.allclose(ref[1], out[1], rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Engine internals: the shard-topk tree merge
# ---------------------------------------------------------------------------


def test_merge_shard_topk_equals_global_topk(rng):
    k, n, parts = 6, 9, 5
    ids = rng.integers(0, 100_000, size=(parts, n, k)).astype(np.int32)
    sc = rng.normal(size=(parts, n, k)).astype(np.float32)
    sc = -np.sort(-sc, axis=-1)                 # each part sorted desc
    got_ids, got_sc = engine_lib.merge_shard_topk(
        [(ids[p], sc[p]) for p in range(parts)], k=k)
    all_sc = sc.transpose(1, 0, 2).reshape(n, parts * k)
    all_ids = ids.transpose(1, 0, 2).reshape(n, parts * k)
    order = np.argsort(-all_sc, axis=-1, kind="stable")[:, :k]
    assert np.array_equal(got_sc, np.take_along_axis(all_sc, order, -1))
    assert np.array_equal(got_ids, np.take_along_axis(all_ids, order, -1))
    assert got_ids.dtype == np.int32 and got_sc.dtype == np.float32


# ---------------------------------------------------------------------------
# Server hot-swap of a re-sharded snapshot under open-loop load
# ---------------------------------------------------------------------------


def test_open_loop_swap_resharded_zero_failed_or_torn(snap8, rng):
    """Mid-run publish of a GROWN, re-sharded successor: zero failed
    requests, every answer matches exactly one generation's sharded
    oracle bit-for-bit (none torn across two)."""
    _need(2)
    s1 = snap8.with_mesh(2)
    server = server_lib.StreamingServer(
        engine_lib.QueryEngine.from_snapshot(s1, backend="dense"),
        server_lib.ServerConfig(batch_size=4, max_delay_ms=1.0,
                                k=5, cr=2, backend="dense"))
    n = 32
    tok, msk, loc = _make_queries(snap8.cfg, n=n, seed=9)
    requests = [(tok[i], msk[i], loc[i]) for i in range(n)]
    # the successor: new objects inserted, re-sharded 4 ways — a shard
    # TOPOLOGY change riding the same publish
    new_emb = jnp.asarray(rng.normal(size=(5, snap8.cfg.d_model)),
                          jnp.float32)
    new_loc = jnp.asarray(rng.uniform(size=(5, 2)), jnp.float32)
    buf = il.insert_objects(s1.buffers, s1.index_params, s1.norm,
                            new_emb, new_loc, np.arange(5000, 5005))
    s2 = s1.with_buffers(buf).with_mesh(min(4, N_DEV))
    assert s2.meta.version == s1.meta.version + 1

    versions = []
    orig = server.engine.query

    def spy_then_swap(*a, **kw):
        versions.append(kw["snapshot"].meta.version)
        res = orig(*a, **kw)
        if len(versions) == 2:
            server.publish(s2)
        return res

    server.engine.query = spy_then_swap
    results = asyncio.run(server_lib.open_loop(server, requests,
                                               qps=4000.0))
    assert len(results) == n                    # zero failed requests
    assert server.engine.snapshot is s2
    assert set(versions) <= {s1.meta.version, s2.meta.version}
    o1 = engine_lib.QueryEngine.from_snapshot(s1, backend="dense")
    o2 = engine_lib.QueryEngine.from_snapshot(s2, backend="dense")
    ids1, sc1 = o1.query(tok, msk, loc, k=5, cr=2, batch=4)
    ids2, sc2 = o2.query(tok, msk, loc, k=5, cr=2, batch=4)
    for i, (ids, sc) in enumerate(results):
        old = np.array_equal(ids, ids1[i]) and np.array_equal(sc, sc1[i])
        new = np.array_equal(ids, ids2[i]) and np.array_equal(sc, sc2[i])
        assert old or new, f"request {i} matches NEITHER snapshot (torn)"
    assert s1.meta.version in versions          # both generations served
    assert s2.meta.version in versions
    m = server.metrics()
    assert m["n_shards"] == s2.meta.n_shards
    assert len(m["shard_bytes_per_device"]) == s2.meta.n_shards


# ---------------------------------------------------------------------------
# Route localization: shards that hold ONLY padding clusters
# ---------------------------------------------------------------------------


def test_localize_routes_all_off_shard():
    """A shard owning none of the routed clusters localizes EVERY route
    to its sentinel row — never clamps into a real local cluster."""
    from repro.core import serving
    # 6 global clusters on 3 shards: shard_of [0,0,1,1,2,2]
    shard_of = np.array([0, 0, 1, 1, 2, 2], np.int32)
    local_of = np.array([0, 1, 0, 1, 0, 1], np.int32)
    top_c = np.array([[0, 1], [0, 5], [4, 5]], np.int32)
    sentinel = 2
    # shard 1 owns clusters {2, 3}; no query routes there
    out = serving.localize_routes(top_c, shard_of, local_of, 1,
                                  sentinel=sentinel)
    assert out.shape == top_c.shape and out.dtype == np.int32
    assert (out == sentinel).all()
    # shards 0 and 2 see their own rows, sentinel elsewhere
    out0 = serving.localize_routes(top_c, shard_of, local_of, 0,
                                   sentinel=sentinel)
    assert out0.tolist() == [[0, 1], [0, sentinel],
                             [sentinel, sentinel]]
    out2 = serving.localize_routes(top_c, shard_of, local_of, 2,
                                   sentinel=sentinel)
    assert out2.tolist() == [[sentinel, sentinel], [sentinel, 1], [0, 1]]


def test_shard_holding_only_padding_clusters(rng):
    """A shard whose assigned clusters are ALL empty (every id -1)
    contributes only sentinel rows: the sharded answer still equals the
    unsharded oracle, and localization on that shard is all-sentinel."""
    _need(2)
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng_o = np.random.default_rng(41)
    rel = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n = 64
    obj_emb = rng_o.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng_o.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(1), cfg.d_model, 4,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    top = np.clip(top, 0, 1)           # clusters 2 and 3 stay EMPTY
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=4,
                                   capacity=48)
    snap = IndexSnapshot.from_parts(cfg, rel, iparams, norm, buf,
                                    dist_max=DIST_MAX)
    bi = np.asarray(snap.buffers["ids"])
    assert (bi[2:] == -1).all()        # the premise: all-padding clusters
    # assignment pins the two empty clusters alone on shard 1
    s = snap.with_mesh(2, assignment=np.array([0, 0, 1, 1], np.int32))
    from repro.core import serving
    sh = s.shards
    tok, msk, loc = _make_queries(cfg, n=8, seed=3)
    eng = engine_lib.QueryEngine.from_snapshot(snap, backend="dense")
    want = eng.query(tok, msk, loc, k=5, cr=2, batch=4, snapshot=snap)
    got = eng.query(tok, msk, loc, k=5, cr=2, batch=4, snapshot=s)
    assert np.array_equal(got[0], want[0])
    np.testing.assert_allclose(got[1], want[1], rtol=2e-5, atol=1e-6)
    # any routing (even straight into the empty clusters) localizes on
    # shard 1 to the sentinel, and its answers are pure padding
    top_c = np.array([[2, 3], [0, 1]], np.int32)
    local = serving.localize_routes(top_c, sh.shard_of, sh.local_of, 1,
                                    sentinel=sh.sentinel)
    # shard 1's REAL rows are its two empty clusters; routing into them
    # is indistinguishable from the sentinel: ids are -1 either way
    part_ids = np.asarray(sh.parts[1]["ids"])
    assert (part_ids[local] == -1).all()
    local0 = serving.localize_routes(top_c, sh.shard_of, sh.local_of, 0,
                                     sentinel=sh.sentinel)
    assert local0.tolist() == [[sh.sentinel, sh.sentinel], [0, 1]]
