"""Prefill+decode == full-forward logits (the KV-cache correctness test),
for dense, hybrid-window, and MoE architectures."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "gemma3-27b",
                                  "moonshot-v1-16b-a3b"])
@pytest.mark.slow
def test_decode_matches_full_forward(arch):
    import dataclasses
    from repro.configs.base import MoESpec
    cfg = reduced(get_config(arch))
    if cfg.is_moe:
        # exact parity needs drop-free routing: train/prefill group tokens
        # by sequence, decode groups by batch — capacity-limited drops
        # legitimately differ between the two groupings.
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    params = tf.lm_init(KEY, cfg)
    b, s_prompt, n_new = 2, 24, 4
    total = s_prompt + n_new
    toks = jax.random.randint(KEY, (b, total), 0, cfg.vocab_size)

    # reference: full forward over the whole sequence
    x, _, _ = tf.lm_forward(params, toks, cfg)
    ref_logits = x @ tf.unembed_matrix(params, cfg).astype(x.dtype)

    # prefill on the prompt, then decode token by token
    logits, cache = tf.lm_prefill(params, toks[:, :s_prompt], cfg,
                                  max_len=total)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref_logits[:, s_prompt - 1]),
        rtol=2e-2, atol=2e-2)
    for i in range(n_new):
        pos = jnp.full((b,), s_prompt + i, jnp.int32)
        logits, cache = tf.lm_decode_step(
            params, cache, toks[:, s_prompt + i:s_prompt + i + 1], pos, cfg)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits[:, s_prompt + i]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"decode step {i} diverged from full forward")


@pytest.mark.slow
def test_ring_buffer_window_decode():
    """Decode far beyond the window: ring buffer must keep only the last
    `window` positions — logits must match a full forward."""
    cfg = reduced(get_config("gemma3-27b"))   # pattern ("L","G"), window 16
    params = tf.lm_init(KEY, cfg)
    b = 1
    total = 40                                 # > 2× window
    toks = jax.random.randint(KEY, (b, total), 0, cfg.vocab_size)
    x, _, _ = tf.lm_forward(params, toks, cfg)
    ref_logits = x @ tf.unembed_matrix(params, cfg).astype(x.dtype)

    s_prompt = 8
    logits, cache = tf.lm_prefill(params, toks[:, :s_prompt], cfg,
                                  max_len=total)
    for i in range(total - s_prompt):
        pos = jnp.full((b,), s_prompt + i, jnp.int32)
        logits, cache = tf.lm_decode_step(
            params, cache, toks[:, s_prompt + i:s_prompt + i + 1], pos, cfg)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)


def test_banded_equals_full_window_attention(rng):
    """attention_local_banded == window-limited attention_full."""
    from repro.models import layers
    b, s, h, kv, d, w = 2, 64, 4, 2, 16, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    o1 = layers.attention_local_banded(q, k, v, window=w)
    o2 = layers.attention_full(q, k, v, causal=True, window=w, chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_chunked_xent_matches_dense(rng):
    from repro.models import layers
    b, s, d, v = 2, 16, 8, 50
    x = jnp.asarray(rng.normal(size=(b, s, d)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(d, v)), jnp.float32)
    t = jnp.asarray(rng.integers(0, v, size=(b, s)), jnp.int32)
    chunked = layers.chunked_softmax_xent(x, u, t, chunk=4)
    logits = x @ u
    logp = jax.nn.log_softmax(logits, axis=-1)
    dense = -jnp.take_along_axis(logp, t[..., None], -1).mean()
    np.testing.assert_allclose(float(chunked), float(dense), rtol=1e-5)
