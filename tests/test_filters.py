"""Filtered-search tier (core/filters.py, DESIGN.md §13).

Covers the tentpole acceptance criteria of attribute-filtered queries:

* the in-VMEM predicate (tenant equality ∧ category-bitmask intersection
  ∧ inclusive time window) returns filtered top-k ids identical to a
  PURE-NUMPY brute-force oracle over the routed clusters, across all 4
  backends × 3 precision tiers, unsharded and mesh-sharded, and over
  delta-resident rows;
* tenant isolation is absolute: a tenant-filtered query NEVER returns a
  foreign tenant's id, even when fewer than k candidates pass (failing
  rows take full padding semantics — id -1, score NEG_INF — so nothing
  can leak out of a NEG_INF slot); a hypothesis property test explores
  random attribute tables and filter mixes;
* all-no-op filters collapse to the unfiltered plan (same plan-cache
  entry, bit-identical results), and the server's cache keys carry the
  filter signature so two tenants never share a cached result.
"""
import asyncio
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.configs import get_config
from repro.core import engine as engine_lib
from repro.core import filters as filters_lib
from repro.core import index as il
from repro.core import relevance
from repro.core import server as server_lib
from repro.core.delta import DeltaSegment
from repro.core.filters import FilterSpec
from repro.core.snapshot import IndexSnapshot

DIST_MAX = 1.414
D = 32
BACKENDS = ["dense", "dense-cm", "pallas", "pallas-cm"]

N_DEV = jax.device_count()


# ---------------------------------------------------------------------------
# FilterSpec / compile unit contracts
# ---------------------------------------------------------------------------


def test_filterspec_noop_and_signature():
    assert filters_lib.NOOP_FILTER.is_noop
    assert FilterSpec().is_noop
    assert not FilterSpec(tenant=0).is_noop            # tenant 0 is real
    assert not FilterSpec(category_mask=1).is_noop
    assert not FilterSpec(t_min=5).is_noop
    # signature: all-no-op collapses to None; real specs are per-row tuples
    assert filters_lib.filter_signature(None) is None
    assert filters_lib.filter_signature(filters_lib.NOOP_FILTER) is None
    assert filters_lib.filter_signature([None, FilterSpec()]) is None
    sig = filters_lib.filter_signature(FilterSpec(tenant=2))
    assert sig is not None
    assert sig == filters_lib.filter_signature(FilterSpec(tenant=2))
    assert sig != filters_lib.filter_signature(FilterSpec(tenant=3))
    # per-row mixes keep row order in the signature
    a = filters_lib.filter_signature([FilterSpec(tenant=1), None])
    b = filters_lib.filter_signature([None, FilterSpec(tenant=1)])
    assert a != b


def test_compile_filters_shapes_and_sentinels():
    fv, filtered = filters_lib.compile_filters(None, 3)
    assert not filtered                     # static flag: unfiltered plan
    assert fv.shape == (3, filters_lib.N_FVALS)
    attrs_any = filters_lib.make_attrs([0, 5], [0, 7], [-9, 9])
    assert filters_lib.predicate_mask_np(attrs_any, fv[0][None]).all()
    fv, filtered = filters_lib.compile_filters(FilterSpec(tenant=1), 3)
    assert filtered and fv.shape == (3, filters_lib.N_FVALS)
    assert (fv == fv[0]).all()                         # broadcast spec
    # mixed rows: None rows become sentinel no-ops that pass everything
    fv, filtered = filters_lib.compile_filters(
        [FilterSpec(tenant=1), None], 2)
    assert filtered
    attrs = filters_lib.make_attrs([0, 1, 2], [0, 0, 0], [0, 0, 0])
    m = filters_lib.predicate_mask_np(attrs, fv[1][None])
    assert m.all()                                     # no-op row passes all
    m = filters_lib.predicate_mask_np(attrs, fv[0][None])
    assert m.tolist() == [False, True, False]
    with pytest.raises(ValueError):
        filters_lib.compile_filters([None], 2)         # row-count mismatch


def test_predicate_semantics():
    attrs = filters_lib.make_attrs(
        tenant=[0, 1, 1, 2],
        category_mask=[0b001, 0b010, 0b110, 0b000],
        timestamp=[10, 20, 30, 40])

    def passes(spec):
        return filters_lib.predicate_mask_np(
            attrs, spec.to_fvals()[None]).tolist()

    assert passes(FilterSpec()) == [True] * 4
    assert passes(FilterSpec(tenant=1)) == [False, True, True, False]
    # category: bitwise intersection; an object with mask 0 matches no
    # category-constrained query; a query mask of 0 means "any"
    assert passes(FilterSpec(category_mask=0b010)) == [
        False, True, True, False]
    assert passes(FilterSpec(category_mask=0b101)) == [
        True, False, True, False]
    # time window: inclusive on both bounds
    assert passes(FilterSpec(t_min=20, t_max=30)) == [
        False, True, True, False]
    assert passes(FilterSpec(t_min=41)) == [False] * 4
    # conjunction of all three legs
    assert passes(FilterSpec(tenant=1, category_mask=0b100,
                             t_min=25)) == [False, False, True, False]


def test_validate_attrs():
    z = filters_lib.validate_attrs(None, 5)
    assert z.shape == (5, 3) and z.dtype == np.int32 and not z.any()
    a = filters_lib.make_attrs([1, 2], [4, 8], [100, 200])
    assert np.array_equal(filters_lib.validate_attrs(a, 2), a)
    with pytest.raises(ValueError):
        filters_lib.validate_attrs(a, 3)               # row-count mismatch


# ---------------------------------------------------------------------------
# Fixture: a tiny snapshot carrying an attribute table
# ---------------------------------------------------------------------------

N_OBJ = 160


def _mk_attrs(n, seed=3):
    rng = np.random.default_rng(seed)
    return filters_lib.make_attrs(
        tenant=rng.integers(0, 3, n),
        category_mask=rng.integers(0, 16, n),          # 4 category bits
        timestamp=rng.integers(0, 1000, n))


@pytest.fixture(scope="module")
def fsnap():
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=D, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(17)
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap = N_OBJ, cfg.n_clusters, 64
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(5), cfg.d_model, c,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    attrs = _mk_attrs(n)
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap, attrs=attrs)
    return IndexSnapshot.from_parts(cfg, params, iparams, norm, buf,
                                    dist_max=DIST_MAX)


_TIERS, _ENGINES = {}, {}


def snap_at(snap, precision):
    if precision not in _TIERS:
        _TIERS[precision] = (snap if precision == "f32"
                             else snap.with_precision(precision))
    return _TIERS[precision]


def engine_at(snap, precision, backend):
    key = (precision, backend)
    if key not in _ENGINES:
        _ENGINES[key] = engine_lib.QueryEngine.from_snapshot(
            snap_at(snap, precision), backend=backend,
            interpret=backend.startswith("pallas"))
    return _ENGINES[key]


def make_requests(rng, n, cfg):
    tok = rng.integers(2, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones((n, cfg.max_len), bool)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    return tok, msk, loc


# a representative mixed filter roster: no-op rows ride beside real specs
def _mixed_specs(b):
    roster = [None,
              FilterSpec(tenant=1),
              FilterSpec(category_mask=0b0101),
              FilterSpec(t_min=200, t_max=700),
              FilterSpec(tenant=0, category_mask=0b0011, t_min=100)]
    return [roster[i % len(roster)] for i in range(b)]


# ---------------------------------------------------------------------------
# The pure-numpy brute-force filtered oracle
# ---------------------------------------------------------------------------


def filtered_oracle(eng, snap, tok, msk, loc, specs, *, k, cr):
    """Route with the engine's own (deterministic) prefix, then score the
    routed clusters' candidates entirely in numpy: dequant, Eq. 5 serve
    form, predicate, top-k. Independent of every jit'd scan path."""
    prefix = eng.prefix_fn(cr=cr)
    q_emb, w, top_c = (np.asarray(x) for x in prefix(
        snap.rel_params, snap.index_params, snap.norm,
        jnp.asarray(tok), jnp.asarray(msk), jnp.asarray(loc)))
    buf = snap.buffers
    be = np.asarray(buf["emb"]).astype(np.float32)
    if snap.meta.precision == "int8":
        be = be * np.asarray(buf["scale"])[..., None]
    bl, bi = np.asarray(buf["loc"]), np.asarray(buf["ids"])
    ba = np.asarray(buf["attrs"])
    w_hat = np.asarray(snap.w_hat)
    t = w_hat.shape[0]
    out_i, out_s = [], []
    for q in range(tok.shape[0]):
        ce = be[top_c[q]].reshape(-1, D)
        cl = bl[top_c[q]].reshape(-1, 2)
        ci = bi[top_c[q]].reshape(-1).copy()
        ca = ba[top_c[q]].reshape(-1, 3)
        spec = specs[q] if specs is not None else None
        fv = (spec or filters_lib.NOOP_FILTER).to_fvals()
        ci[~filters_lib.predicate_mask_np(ca, fv[None])] = -1
        trel = ce @ q_emb[q]
        d = np.linalg.norm(loc[q] - cl, axis=-1)
        s_in = 1.0 - np.clip(d / snap.meta.dist_max, 0.0, 1.0)
        srel = w_hat[np.clip(np.floor(s_in * t).astype(np.int32), 0, t - 1)]
        st = w[q, 0] * trel + w[q, 1] * srel
        st = np.where(ci >= 0, st, engine_lib.NEG_INF)
        order = np.argsort(-st, kind="stable")[:k]
        ids_q = np.where(st[order] > engine_lib.NEG_INF / 2, ci[order], -1)
        out_i.append(ids_q)
        out_s.append(st[order])
    return np.stack(out_i), np.stack(out_s)


def _assert_matches_oracle(ids, scores, want_i, want_s, specs, attrs_by_id):
    np.testing.assert_allclose(scores, want_s, rtol=2e-4, atol=2e-4)
    assert (np.sort(ids, axis=1) == np.sort(want_i, axis=1)).all()
    # every live id satisfies its row's predicate — checked against the
    # GROUND-TRUTH attribute table, not anything the engine returned
    for q in range(ids.shape[0]):
        spec = specs[q] if specs is not None else None
        if spec is None:
            continue
        fv = spec.to_fvals()
        for i in ids[q][ids[q] >= 0]:
            assert filters_lib.predicate_mask_np(
                attrs_by_id[int(i)][None], fv[None])[0]


# ---------------------------------------------------------------------------
# Backend × precision filtered parity (unsharded)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("precision", il.PRECISIONS)
def test_filtered_parity_vs_oracle(fsnap, precision, backend, rng):
    snap = snap_at(fsnap, precision)
    eng = engine_at(fsnap, precision, backend)
    b, k, cr = 10, 7, 2
    tok, msk, loc = make_requests(rng, b, fsnap.cfg)
    specs = _mixed_specs(b)
    ids, sc = eng.query(tok, msk, loc, k=k, cr=cr, batch=4,
                        snapshot=snap, filters=specs)
    want_i, want_s = filtered_oracle(eng, snap, tok, msk, loc, specs,
                                     k=k, cr=cr)
    attrs = np.asarray(fsnap.buffers["attrs"])
    base_ids = np.asarray(fsnap.buffers["ids"])
    attrs_by_id = {int(i): attrs[base_ids == i][0]
                   for i in base_ids[base_ids >= 0]}
    _assert_matches_oracle(ids, sc, want_i, want_s, specs, attrs_by_id)


def test_single_spec_broadcasts(fsnap, rng):
    """One FilterSpec (not a list) applies to every row of the request."""
    eng = engine_at(fsnap, "f32", "dense")
    tok, msk, loc = make_requests(rng, 6, fsnap.cfg)
    spec = FilterSpec(tenant=2)
    ids_b, sc_b = eng.query(tok, msk, loc, k=5, cr=2, batch=4, filters=spec)
    ids_l, sc_l = eng.query(tok, msk, loc, k=5, cr=2, batch=4,
                            filters=[spec] * 6)
    assert np.array_equal(ids_b, ids_l) and np.array_equal(sc_b, sc_l)


def test_noop_filters_use_unfiltered_plan(fsnap, rng):
    """All-no-op filter lists collapse: same results AND the same
    plan-cache entry as a plain unfiltered query (the pre-filter fast
    path stays byte-identical)."""
    eng = engine_lib.QueryEngine.from_snapshot(snap_at(fsnap, "f32"),
                                               backend="dense")
    tok, msk, loc = make_requests(rng, 4, fsnap.cfg)
    i0, s0 = eng.query(tok, msk, loc, k=5, cr=2, batch=4)
    n_plans = len(eng._plans)
    i1, s1 = eng.query(tok, msk, loc, k=5, cr=2, batch=4,
                       filters=[None] * 4)
    i2, s2 = eng.query(tok, msk, loc, k=5, cr=2, batch=4,
                       filters=filters_lib.NOOP_FILTER)
    assert len(eng._plans) == n_plans          # no new compile
    assert np.array_equal(i0, i1) and np.array_equal(i0, i2)
    assert np.array_equal(s0, s1) and np.array_equal(s0, s2)


def test_filtered_underfull_returns_padding(fsnap, rng):
    """A filter passing almost nothing yields (-1, NEG_INF) padding, not
    foreign rows — the isolation guarantee under candidate starvation."""
    eng = engine_at(fsnap, "f32", "dense")
    tok, msk, loc = make_requests(rng, 4, fsnap.cfg)
    # timestamps are < 1000 in the fixture, so this passes nothing
    ids, sc = eng.query(tok, msk, loc, k=6, cr=2, batch=4,
                        filters=FilterSpec(t_min=10_000))
    assert (ids == -1).all() and (sc < engine_lib.NEG_INF / 2).all()


# ---------------------------------------------------------------------------
# Mesh-sharded filtered parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [2, 4])
@pytest.mark.parametrize("precision", il.PRECISIONS)
def test_filtered_sharded_parity(fsnap, precision, n_shards, rng):
    """A mesh-sharded snapshot serves the same filtered answers as the
    unsharded engine — the predicate rides the per-shard scans and the
    attrs buffers shard with their clusters."""
    if n_shards > N_DEV:
        pytest.skip(f"needs {n_shards} devices, have {N_DEV}")
    snap = snap_at(fsnap, precision)
    eng = engine_at(fsnap, precision, "dense")
    b, k, cr = 8, 5, 2
    tok, msk, loc = make_requests(rng, b, fsnap.cfg)
    specs = _mixed_specs(b)
    want_i, want_s = eng.query(tok, msk, loc, k=k, cr=cr, batch=4,
                               snapshot=snap, filters=specs)
    snap_m = snap.with_mesh(n_shards)
    ids, sc = eng.query(tok, msk, loc, k=k, cr=cr, batch=4,
                        snapshot=snap_m, filters=specs)
    assert np.array_equal(ids, want_i)
    np.testing.assert_allclose(sc, want_s, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Delta-path filtered parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("precision", il.PRECISIONS)
def test_filtered_delta_rows(fsnap, precision, rng):
    """Delta-resident rows obey the same predicate: inserted rows that
    match surface, inserted rows that fail never do, and the whole
    filtered answer equals the compacted snapshot's (ids bit-equal)."""
    snap = snap_at(fsnap, precision)
    d = DeltaSegment.empty(D, precision)
    m = 12
    emb = rng.normal(size=(m, D)).astype(np.float32)
    loc_o = rng.uniform(size=(m, 2)).astype(np.float32)
    ids_new = np.arange(9000, 9000 + m)
    # half tenant 7 (a tenant no base row has), half tenant 8
    attrs = filters_lib.make_attrs(np.where(np.arange(m) < 6, 7, 8),
                                   np.full(m, 0b1), np.arange(m))
    d = d.insert(emb, loc_o, ids_new, attrs)
    snap_d = snap.with_delta(d)
    eng = engine_at(fsnap, precision, "dense")
    b, k = 6, 8
    tok, msk, loc = make_requests(rng, b, fsnap.cfg)
    spec = FilterSpec(tenant=7)
    ids, sc = eng.query(tok, msk, loc, k=k, cr=fsnap.cfg.n_clusters,
                        batch=4, snapshot=snap_d, filters=spec)
    live = ids[ids >= 0]
    assert live.size                                # tenant-7 rows surface
    assert set(live.tolist()) <= set(ids_new[:6].tolist())
    # parity with the compacted snapshot (delta folded into the base)
    snap_c = snap_d.compact()
    want_i, want_s = eng.query(tok, msk, loc, k=k, cr=fsnap.cfg.n_clusters,
                               batch=4, snapshot=snap_c, filters=spec)
    assert np.array_equal(ids, want_i)
    np.testing.assert_allclose(sc, want_s, atol=1e-5, rtol=1e-6)


def test_filtered_delta_mixed_base_and_delta(fsnap, rng):
    """A time-window filter straddling base and delta rows returns the
    union — the predicate is one contract across both scans."""
    snap = snap_at(fsnap, "f32")
    eng = engine_at(fsnap, "f32", "dense")
    m = 8
    emb = rng.normal(size=(m, D)).astype(np.float32)
    loc_o = rng.uniform(size=(m, 2)).astype(np.float32)
    ids_new = np.arange(9500, 9500 + m)
    attrs = filters_lib.make_attrs(np.zeros(m), np.full(m, 0b1),
                                   np.full(m, 500))          # in-window
    snap_d = snap.with_delta(
        DeltaSegment.empty(D, "f32").insert(emb, loc_o, ids_new, attrs))
    tok, msk, loc = make_requests(rng, 4, fsnap.cfg)
    spec = FilterSpec(t_min=400, t_max=600)
    ids, _ = eng.query(tok, msk, loc, k=20, cr=fsnap.cfg.n_clusters,
                       batch=4, snapshot=snap_d, filters=spec)
    base_attrs = np.asarray(fsnap.buffers["attrs"])
    base_ids = np.asarray(fsnap.buffers["ids"])
    in_window = set(base_ids[(base_ids >= 0) & (base_attrs[..., 2] >= 400)
                             & (base_attrs[..., 2] <= 600)].tolist())
    live = set(int(i) for i in ids[ids >= 0])
    assert live & set(ids_new.tolist())             # delta rows present
    assert live <= in_window | set(ids_new.tolist())


# ---------------------------------------------------------------------------
# Tenant isolation: the property test
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("tenant", [0, 1, 2, 3])        # 3 = nobody
def test_tenant_isolation_fixed(fsnap, backend, tenant):
    """Deterministic isolation sweep — always runs, so the guarantee has
    coverage even where hypothesis is unavailable."""
    attrs = np.asarray(fsnap.buffers["attrs"])
    base_ids = np.asarray(fsnap.buffers["ids"])
    tenant_of = {int(i): int(attrs[base_ids == i][0][0])
                 for i in base_ids[base_ids >= 0]}
    qrng = np.random.default_rng(29)
    tok, msk, loc = make_requests(qrng, 4, fsnap.cfg)
    eng = engine_at(fsnap, "f32", backend)
    ids, sc = eng.query(tok, msk, loc, k=9, cr=2, batch=4,
                        filters=FilterSpec(tenant=tenant))
    for i in ids[ids >= 0]:
        assert tenant_of[int(i)] == tenant
    assert ((ids >= 0) == (sc > engine_lib.NEG_INF / 2)).all()
    if tenant == 3:
        assert (ids == -1).all()            # no such tenant anywhere


def test_tenant_isolation_property(fsnap):
    """ANY tenant filter over ANY backend returns only that tenant's
    rows — hypothesis explores tenants, k, cr, and backends."""
    hypothesis = pytest.importorskip("hypothesis")
    st_ = hypothesis.strategies
    attrs = np.asarray(fsnap.buffers["attrs"])
    base_ids = np.asarray(fsnap.buffers["ids"])
    tenant_of = {int(i): int(attrs[base_ids == i][0][0])
                 for i in base_ids[base_ids >= 0]}
    qrng = np.random.default_rng(23)
    tok, msk, loc = make_requests(qrng, 4, fsnap.cfg)

    @hypothesis.settings(max_examples=12, deadline=None)
    @hypothesis.given(tenant=st_.integers(0, 3),       # 3 = nobody
                      k=st_.integers(1, 12),
                      cr=st_.sampled_from([1, 2, 4]),
                      backend=st_.sampled_from(BACKENDS))
    def run(tenant, k, cr, backend):
        eng = engine_at(fsnap, "f32", backend)
        ids, sc = eng.query(tok, msk, loc, k=k, cr=cr, batch=4,
                            filters=FilterSpec(tenant=tenant))
        for i in ids[ids >= 0]:
            assert tenant_of[int(i)] == tenant
        assert ((ids >= 0) == (sc > engine_lib.NEG_INF / 2)).all()

    run()


# ---------------------------------------------------------------------------
# Server integration: filter-aware cache keys
# ---------------------------------------------------------------------------


def _mk_server(fsnap, **over):
    eng = engine_lib.QueryEngine.from_snapshot(snap_at(fsnap, "f32"),
                                               backend="dense")
    kw = dict(batch_size=2, max_delay_ms=30.0, k=5, cr=2, backend="dense")
    kw.update(over)
    return server_lib.StreamingServer(eng, server_lib.ServerConfig(**kw))


def test_server_cache_isolated_by_filter(fsnap, rng):
    """The same query text under two tenant filters — and under no
    filter — must produce three distinct cached entries; repeats hit."""
    server = _mk_server(fsnap)
    tok, msk, loc = make_requests(rng, 1, fsnap.cfg)
    f0, f1 = FilterSpec(tenant=0), FilterSpec(tenant=1)

    async def go():
        outs = {}
        for tag, f in [("t0", f0), ("t1", f1), ("nf", None)]:
            a, b = await asyncio.gather(
                server.submit(tok[0], msk[0], loc[0], filters=f),
                server.submit(tok[0], msk[0], loc[0], filters=f))
            outs[tag] = (a, b)
        return outs

    outs = asyncio.run(go())
    for tag, (a, b) in outs.items():                 # coalesced pairs agree
        assert np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1])
    attrs = np.asarray(fsnap.buffers["attrs"])
    base_ids = np.asarray(fsnap.buffers["ids"])
    tenant_of = {int(i): int(attrs[base_ids == i][0][0])
                 for i in base_ids[base_ids >= 0]}
    for tag, tenant in [("t0", 0), ("t1", 1)]:
        ids = outs[tag][0][0]
        for i in ids[ids >= 0]:
            assert tenant_of[int(i)] == tenant, (
                f"{tag} leaked a foreign-tenant row — cache keys must "
                f"include the filter signature")
    # the three filter signatures never collide in the result sets
    assert not np.array_equal(outs["t0"][0][0], outs["t1"][0][0])

    async def again():
        return await server.submit(tok[0], msk[0], loc[0], filters=f0)

    n_queries = server.stats.engine_queries
    rep = asyncio.run(again())
    assert server.stats.engine_queries == n_queries   # exact-cache hit
    assert np.array_equal(rep[0], outs["t0"][0][0])


def test_server_filtered_matches_direct_engine(fsnap, rng):
    """A filtered flush returns exactly what a direct engine.query with
    the same per-row filter roster returns."""
    server = _mk_server(fsnap, batch_size=3)
    tok, msk, loc = make_requests(rng, 3, fsnap.cfg)
    specs = [FilterSpec(tenant=1), None, FilterSpec(category_mask=0b10)]

    async def go():
        return await asyncio.gather(*[
            server.submit(tok[i], msk[i], loc[i], filters=specs[i])
            for i in range(3)])

    out = asyncio.run(go())
    eng = engine_lib.QueryEngine.from_snapshot(snap_at(fsnap, "f32"),
                                               backend="dense")
    want_i, want_s = eng.query(tok, msk, loc, k=5, cr=2, batch=3,
                               filters=specs)
    for i, (ids, sc) in enumerate(out):
        assert np.array_equal(ids, want_i[i])
        assert np.array_equal(sc, want_s[i])


# ---------------------------------------------------------------------------
# api surface: Searcher.query(filters=) and attrs through api.build
# ---------------------------------------------------------------------------


def test_searcher_query_filters(fsnap, rng):
    s = api.Searcher(snap_at(fsnap, "f32"), backend="dense")
    tok, msk, loc = make_requests(rng, 4, fsnap.cfg)
    ids, sc = s.query(tok, msk, loc, k=5, cr=2, batch=4,
                      filters=FilterSpec(tenant=1))
    attrs = np.asarray(fsnap.buffers["attrs"])
    base_ids = np.asarray(fsnap.buffers["ids"])
    for i in ids[ids >= 0]:
        assert int(attrs[base_ids == int(i)][0][0]) == 1
