"""End-to-end LIST behaviour tests (paper Algorithm 1, scaled down)."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.core import index as il
from repro.core import pipeline as pl
from repro.core.baselines import BM25, IVFIndex, LSHIndex, kmeans, tkq_topk
from repro.data import GeoCorpus, GeoCorpusConfig


@pytest.fixture(scope="module")
def trained():
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=48, n_heads=2, d_ff=96, vocab_size=2048,
        max_len=16, spatial_t=50, n_clusters=8, neg_start=600, neg_end=750,
        index_mlp_hidden=(64,))
    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=1200, n_queries=240, n_topics=8, vocab_size=2048, seed=1))
    r = pl.ListRetriever(cfg, corpus)
    r.train_relevance(steps=150, batch=48, lr=1.5e-3, log_every=1000)
    r.train_index(steps=600, batch=48, lr=3e-3, log_every=1000)
    r.build()
    return r


@pytest.mark.slow
def test_list_recall_close_to_brute_force(trained):
    r = trained
    tr, va, te = r.corpus.split()
    positives = [r.corpus.positives[q] for q in te]
    bf_ids, _ = r.brute_force(te, k=10, batch=64)
    ids, _ = r.query(te, k=10, cr=2, batch=64)
    rb = cm.recall_at_k(bf_ids, positives, 10)
    rl = cm.recall_at_k(ids, positives, 10)
    assert rb > 0.15, f"relevance model too weak (brute recall {rb})"
    assert rl >= 0.7 * rb, (
        f"LIST recall {rl} lost too much vs brute {rb}")


@pytest.mark.slow
def test_list_beats_tkq(trained):
    """The paper's headline: embedding relevance > BM25 TkQ (Table 3)."""
    r = trained
    tr, va, te = r.corpus.split()
    positives = [r.corpus.positives[q] for q in te]
    bm = BM25(r.corpus.obj_doc, vocab_size=r.corpus.cfg.vocab_size)
    tkq_ids = tkq_topk(bm, r.corpus.q_doc[te], r.corpus.q_loc[te],
                       r.corpus.obj_loc, 10, dist_max=r.corpus.dist_max)
    bf_ids, _ = r.brute_force(te, k=10, batch=64)
    assert (cm.recall_at_k(bf_ids, positives, 10)
            > cm.recall_at_k(tkq_ids, positives, 10))


@pytest.mark.slow
def test_clusters_balanced_and_precise(trained):
    r = trained
    if_c = cm.imbalance_factor(r.obj_assign, r.cfg.n_clusters)
    assert if_c < 2.5, f"clusters too skewed: IF(C)={if_c}"
    tr, va, te = r.corpus.split()
    q_emb = pl.embed_queries(r.rel_params, r.corpus, r.cfg, te)
    qf = il.build_features(
        jnp.asarray(q_emb),
        jnp.asarray(r.corpus.q_loc[te].astype(np.float32)), r.norm)
    qa = np.asarray(il.assign_clusters(r.index_params, qf))
    positives = [r.corpus.positives[q] for q in te]
    pc, _ = cm.cluster_precision(qa, positives, r.obj_assign,
                                 r.cfg.n_clusters)
    assert pc > 0.4, f"cluster precision too low: P(C)={pc}"


@pytest.mark.slow
def test_pallas_query_path_matches_jnp(trained):
    r = trained
    tr, va, te = r.corpus.split()
    te = te[:32]
    ids1, sc1 = r.query(te, k=8, cr=1, backend="dense", batch=32)
    ids2, sc2 = r.query(te, k=8, cr=1, backend="pallas", batch=32)
    np.testing.assert_allclose(sc1, sc2, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_query_efficiency_candidates(trained):
    """LIST scans ≈ cr·cap objects — a fraction of the corpus (Fig. 4)."""
    cap = trained.buffers["capacity"]
    n = trained.corpus.cfg.n_objects
    assert cap * 1 < 0.8 * n


@pytest.mark.slow
def test_insertion_routes_new_objects(trained):
    r = trained
    rng = np.random.default_rng(0)
    new_emb = rng.normal(size=(5, r.obj_emb.shape[1])).astype(np.float32)
    new_loc = rng.uniform(size=(5, 2)).astype(np.float32)
    before = int(np.asarray(r.buffers["counts"]).sum())
    buf2 = il.insert_objects(r.buffers, r.index_params, r.norm,
                             jnp.asarray(new_emb), jnp.asarray(new_loc),
                             np.arange(10_000, 10_005))
    assert int(np.asarray(buf2["counts"]).sum()) == before + 5


# --- classical baselines ----------------------------------------------------


def test_kmeans_partitions(rng):
    x = np.concatenate([rng.normal(-5, 0.3, (50, 4)),
                        rng.normal(5, 0.3, (50, 4))]).astype(np.float32)
    cent, assign = kmeans(jnp.asarray(x), 2, iters=10)
    a = np.asarray(assign)
    assert len(set(a[:50].tolist())) == 1
    assert len(set(a[50:].tolist())) == 1
    assert a[0] != a[-1]


def test_ivf_candidates_contain_near_neighbors(rng):
    emb = rng.normal(size=(400, 16)).astype(np.float32)
    ivf = IVFIndex(emb, n_clusters=4)
    cands = ivf.candidates(emb[:10], cr=1)
    for i, c in enumerate(cands):
        assert i in c                     # own cluster contains self


def test_ivf_s_uses_spatial(rng):
    emb = rng.normal(size=(300, 8)).astype(np.float32)
    loc = np.concatenate([rng.uniform(0, 0.1, (150, 2)),
                          rng.uniform(0.9, 1.0, (150, 2))]).astype(np.float32)
    # alpha -> 0: clustering dominated by location
    ivf = IVFIndex(emb, loc, n_clusters=2, alpha=0.01)
    a = ivf.assign
    assert (a[:150] == a[0]).mean() > 0.9
    assert (a[150:] == a[150]).mean() > 0.9
    assert a[0] != a[150]


def test_lsh_self_retrieval(rng):
    emb = rng.normal(size=(200, 16)).astype(np.float32)
    lsh = LSHIndex(emb, nbits=8, n_tables=3)
    cands = lsh.candidates(emb[:20])
    assert all(i in c for i, c in enumerate(cands))


def test_bm25_exact_match_ranks_first():
    docs = np.array([[5, 6, 7, 0], [8, 9, 10, 0], [11, 12, 13, 0]])
    bm = BM25(docs, vocab_size=20)
    s = bm.scores(np.array([[8, 9, 0]]))
    assert s[0].argmax() == 1
    assert s[0][0] == 0.0 and s[0][2] == 0.0   # no overlap -> zero score
