"""serving.dispatch_queries round-trip invariants (DESIGN.md §5).

The sort-based scatter must (a) place every non-dropped (query, route)
pair in its routed cluster's row, (b) be invertible through ``origin``,
and (c) count capacity overflow in ``n_dropped`` instead of silently
truncating.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import serving


def _dispatch(top_c, feat, c, cap):
    q_buf, origin, n_dropped = serving.dispatch_queries(
        jnp.asarray(top_c), jnp.asarray(feat), n_clusters=c, capacity=cap)
    return np.asarray(q_buf), np.asarray(origin), int(n_dropped)


def _unique_payload(b, cr):
    """Payload row j encodes the query id so origin inversion is checkable."""
    return np.arange(b, dtype=np.float32)[:, None] + 1000.0


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("b,cr,c,cap", [
    (16, 2, 4, 16),      # ample capacity
    (32, 1, 8, 8),       # tight
    (8, 4, 2, 32),       # few clusters, heavy multi-route
])
def test_roundtrip_invariants(b, cr, c, cap, seed):
    rng = np.random.default_rng(seed)
    top_c = rng.integers(0, c, size=(b, cr)).astype(np.int32)
    feat = _unique_payload(b, cr)
    q_buf, origin, n_dropped = _dispatch(top_c, feat, c, cap)

    n = b * cr
    placed = origin[origin < n]
    # (a) + drop accounting: every pair is either placed once or counted
    assert len(set(placed.tolist())) == len(placed)
    assert len(placed) + n_dropped == n
    # per-cluster demand vs what landed
    flat = top_c.reshape(-1)
    for ci in range(c):
        demand = int((flat == ci).sum())
        landed = int((origin[ci] < n).sum())
        assert landed == min(demand, cap)
    # (a) every placed pair sits in the cluster it was routed to,
    # (b) origin inverts the scatter: the payload row matches the query
    for ci in range(c):
        for s in range(cap):
            o = origin[ci, s]
            if o < n:
                assert flat[o] == ci
                assert q_buf[ci, s, 0] == feat[o // cr, 0]
    # pad slots carry the zero payload
    pad_rows = q_buf[origin >= n]
    assert (pad_rows == 0).all()


def test_overflow_is_counted_not_silent():
    """All queries route to one cluster; capacity only fits half."""
    b, c, cap = 16, 4, 8
    top_c = np.zeros((b, 1), np.int32)
    q_buf, origin, n_dropped = _dispatch(top_c, _unique_payload(b, 1), c, cap)
    assert n_dropped == b - cap
    assert int((origin < b).sum()) == cap
    # the kept pairs are the first `cap` in stable sort order
    assert sorted(origin[0][origin[0] < b].tolist()) == list(range(cap))


def test_no_drops_when_capacity_suffices():
    b, cr, c, cap = 12, 2, 3, 24      # cap == b*cr: can never overflow
    rng = np.random.default_rng(3)
    top_c = rng.integers(0, c, size=(b, cr)).astype(np.int32)
    _, origin, n_dropped = _dispatch(top_c, _unique_payload(b, cr), c, cap)
    assert n_dropped == 0
    assert int((origin < b * cr).sum()) == b * cr


def test_dispatch_degenerate_all_distinct():
    """U = B·cr: every route its own cluster — one slot per row, no
    drops even at capacity 1."""
    b, cr, c = 4, 2, 8
    top_c = np.arange(8, dtype=np.int32).reshape(b, cr)
    _, origin, n_dropped = _dispatch(top_c, _unique_payload(b, cr), c, 1)
    assert n_dropped == 0
    assert ((origin < b * cr).sum(axis=1) == 1).all()


# ---------------------------------------------------------------------------
# cluster_major_plan: the DISTINCT-cluster roster (DESIGN.md §10)
# ---------------------------------------------------------------------------


def _plan(top_c, c, **kw):
    u, roster, n_distinct, n_dropped = serving.cluster_major_plan(
        jnp.asarray(top_c), n_clusters=c, **kw)
    return (np.asarray(u), np.asarray(roster), int(n_distinct),
            int(n_dropped))


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("b,cr,c", [(16, 2, 4), (8, 4, 2), (6, 1, 8)])
def test_cluster_major_plan_roundtrip_invariants(b, cr, c, seed):
    """(a) every (query, route) pair is placed exactly once or counted
    dropped, (b) each roster row holds exactly the pairs routed to its
    ``u`` cluster, (c) ``n_distinct`` is the realized U and u's live
    slots are the distinct clusters in ascending order."""
    rng = np.random.default_rng(seed)
    top_c = rng.integers(0, c, size=(b, cr)).astype(np.int32)
    u, roster, n_distinct, n_dropped = _plan(top_c, c)
    n = b * cr
    flat = top_c.reshape(-1)
    distinct = np.unique(flat)
    assert n_distinct == len(distinct)
    assert (u[:n_distinct] == distinct).all()      # ascending, deduped
    placed = roster[roster < n]
    assert len(set(placed.tolist())) == len(placed)
    assert len(placed) + n_dropped == n
    assert n_dropped == 0                          # default qcap = B·cr
    for slot in range(len(u)):
        entries = roster[slot][roster[slot] < n]
        if slot < n_distinct:
            # exactly the pairs routed to this distinct cluster
            assert sorted(entries.tolist()) == sorted(
                np.flatnonzero(flat == u[slot]).tolist())
        else:
            assert entries.size == 0               # padding slots empty


def test_cluster_major_plan_single_cluster_saturation():
    """All B·cr routes land on ONE cluster: U=1, roster row 0 saturated.
    At qcap exactly B·cr nothing drops; one below, exactly one pair
    drops (the LAST in stable sort order) and is counted."""
    b, cr, c = 8, 2, 4
    n = b * cr
    top_c = np.full((b, cr), 2, np.int32)
    u, roster, n_distinct, n_dropped = _plan(top_c, c)
    assert n_distinct == 1 and n_dropped == 0 and u[0] == 2
    assert sorted(roster[0].tolist()) == list(range(n))    # saturated
    assert (roster[1:] == n).all()
    # exact saturation boundary: qcap = n-1 drops exactly one pair
    u, roster, n_distinct, n_dropped = _plan(top_c, c, qcap=n - 1)
    assert n_distinct == 1 and n_dropped == 1
    assert sorted(roster[0].tolist()) == list(range(n - 1))


def test_cluster_major_plan_all_distinct():
    """U = B·cr (every route a different cluster): one entry per roster
    row, u enumerates them all, qcap=1 suffices with zero drops."""
    b, cr, c = 4, 2, 8
    top_c = np.arange(8, dtype=np.int32).reshape(b, cr)
    u, roster, n_distinct, n_dropped = _plan(top_c, c, qcap=1)
    assert n_distinct == b * cr and n_dropped == 0
    assert (u == np.arange(8)).all()
    assert ((roster < b * cr).sum(axis=1) == 1).all()


def test_cluster_major_plan_u_max_truncation_counted():
    """A caller-forced u_max below the realized U drops whole clusters —
    counted, never silent."""
    b, cr, c = 4, 1, 8
    top_c = np.array([[0], [2], [5], [7]], np.int32)
    u, roster, n_distinct, n_dropped = _plan(top_c, c, u_max=2)
    assert n_distinct == 4            # realized U is still reported
    assert n_dropped == 2             # clusters 5 and 7 fell off the plan
    assert (u == np.array([0, 2])).all()


def test_cluster_dispatch_query_surfaces_drops(rng):
    """End-to-end: return_dropped=True reports the overflow count and the
    dropped queries degrade to empty lists rather than wrong results."""
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.core import index as il
    from repro.core import relevance

    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=1, d_model=16, n_heads=2, d_ff=32, vocab_size=256,
        max_len=8, spatial_t=20, n_clusters=2, index_mlp_hidden=(8,))
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap, b, k = 64, 2, 32, 8, 4
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(1), cfg.d_model, c,
                            hidden=(8,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=1))[:, None]
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap)
    tok = jnp.asarray(rng.integers(2, 256, (b, 8)), jnp.int32)
    msk = jnp.ones((b, 8), bool)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)

    # qcap=1: at most one query per cluster survives dispatch — through
    # the snapshot-based entry point (the raw-kernel form is what
    # launch/steps.py shards; both share this body)
    from repro.core.snapshot import IndexSnapshot
    snap = IndexSnapshot.from_parts(cfg, params, iparams, norm, buf,
                                    dist_max=1.414)
    ids, sc, nd = serving.cluster_dispatch_query(
        snap, tok, msk, ql, k=k, cr=1, capacity=1,
        return_dropped=True)
    assert int(nd) == b - len(np.unique(
        np.asarray(il.route_queries(
            iparams, il.build_features(
                relevance.encode_queries(params, tok, msk, cfg), ql, norm),
            cr=1)[0])))
    dropped_rows = np.asarray(ids[(np.asarray(sc) == -np.inf).all(1)])
    assert (dropped_rows == -1).all()


def test_dispatch_quantized_snapshot_and_int8_guard(rng):
    """The dispatch path serves quantized snapshots through the shared
    score_candidates dequant, and the raw-kernel form refuses int8
    buffers passed WITHOUT their precision/scales (which would rank rows
    on raw code magnitude)."""
    import dataclasses
    import jax
    from repro.configs import get_config
    from repro.core import index as il
    from repro.core import relevance
    from repro.core.snapshot import IndexSnapshot

    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=16, n_heads=2, d_ff=32, vocab_size=256,
        max_len=8, spatial_t=20, n_clusters=2, index_mlp_hidden=(8,))
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap, b, k = 64, 2, 32, 8, 4
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(1), cfg.d_model, c,
                            hidden=(8,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=1))[:, None]
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap)
    tok = jnp.asarray(rng.integers(2, 256, (b, 8)), jnp.int32)
    msk = jnp.ones((b, 8), bool)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    snap = IndexSnapshot.from_parts(cfg, params, iparams, norm, buf,
                                    dist_max=1.414)

    ids_f, sc_f = serving.cluster_dispatch_query(snap, tok, msk, ql, k=k)
    ids_q, sc_q = serving.cluster_dispatch_query(
        snap.with_precision("int8"), tok, msk, ql, k=k)
    # same candidate sets; scores within scalar-quantization error
    np.testing.assert_allclose(np.asarray(sc_q), np.asarray(sc_f),
                               rtol=0.05, atol=0.05)

    qbuf = snap.with_precision("int8").buffers
    with pytest.raises(ValueError, match="int8"):
        serving.dispatch_query_kernel(
            params, iparams, snap.w_hat, norm, qbuf["emb"], qbuf["loc"],
            qbuf["ids"], tok, msk, ql, cfg, k=k, dist_max=1.414)
