"""Chaos tier for the serving stack (DESIGN.md §14).

Core invariant, exercised through real fault injection (core/faults.py —
no monkeypatched doubles): **zero lost acknowledged writes and zero torn
reads**. A write that returned before the crash must be present after
``api.recover``; the recovered index must answer full-fanout queries
bit-identically to a server that never crashed; a write that crashed
mid-flight may be present (at-least-once) but must never be torn.

Also covered here: the WAL's torn-tail handling, checkpoint atomicity
and corruption detection (``SnapshotCorrupt``), fallback to an older
snapshot step, the circuit breaker, deadline/admission shedding, and
slow-flush anomaly detection.

Run via ``make test-resilience``.
"""
import asyncio
import dataclasses
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import api
from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.core import engine as engine_lib
from repro.core import faults
from repro.core import index as il
from repro.core import relevance
from repro.core import server as server_lib
from repro.core import snapshot as snapshot_lib
from repro.core import wal as wal_lib
from repro.distributed import resilience as resilience_lib

DIST_MAX = 1.414


@pytest.fixture(autouse=True)
def _disarm_faults():
    """The registry is process-global: every test starts and ends clean,
    even when an injected Crash propagated out of the body."""
    faults.clear()
    yield
    faults.clear()


# ---------------------------------------------------------------------------
# Fixture: the same tiny bound engine as tests/test_server.py
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_parts():
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(11)
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap = 96, cfg.n_clusters, 64       # headroom for inserts
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(5), cfg.d_model, c,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap)
    return cfg, params, iparams, norm, buf


def make_engine(engine_parts):
    cfg, params, iparams, norm, buf = engine_parts
    return engine_lib.QueryEngine.from_parts(
        cfg, params, iparams, norm, buf, dist_max=DIST_MAX, backend="dense")


def make_server(engine_parts, **over):
    eng = make_engine(engine_parts)
    kw = dict(batch_size=4, max_delay_ms=30.0, k=5, cr=2, backend="dense")
    kw.update(over)
    return server_lib.StreamingServer(eng, server_lib.ServerConfig(**kw))


def make_requests(rng, n, cfg):
    tok = rng.integers(2, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones((n, cfg.max_len), bool)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    return tok, msk, loc


def insert_batch(server, rng, *, rows=6, base_id=10_000_000):
    """One acked insert batch; returns (emb, loc, ids) for the oracle."""
    d = int(np.asarray(server.engine.snapshot.buffers["emb"]).shape[-1])
    emb = rng.normal(size=(rows, d)).astype(np.float32)
    loc = rng.uniform(size=(rows, 2)).astype(np.float32)
    ids = np.arange(base_id, base_id + rows)
    server.insert_objects(emb, loc, ids)
    return emb, loc, ids


def full_fanout(server, tok, msk, loc, *, k=5):
    """Full-fanout dense query through the server's engine — the parity
    probe for torn-read / lost-write checks (every cluster scanned, so a
    missing or extra row can never hide behind routing)."""
    c = int(np.asarray(server.engine.snapshot.buffers["emb"]).shape[0])
    return server.engine.query(tok, msk, loc, k=k, cr=c,
                               batch=len(tok), backend="dense")


# ---------------------------------------------------------------------------
# Fault registry
# ---------------------------------------------------------------------------


def test_unknown_point_rejected():
    with pytest.raises(ValueError, match="unknown fault point"):
        faults.inject("flush.typo", error=RuntimeError("x"))


def test_error_and_callback_exclusive():
    with pytest.raises(ValueError, match="not both"):
        faults.inject("flush.engine", error=RuntimeError("x"),
                      callback=lambda: None)


def test_times_semantics():
    faults.inject("flush.engine", error=RuntimeError("boom"), times=2)
    for _ in range(2):
        with pytest.raises(RuntimeError, match="boom"):
            faults.fire("flush.engine")
    assert faults.fire("flush.engine") is None      # disarmed after 2
    assert faults.fired("flush.engine") == 2
    assert not faults.active("flush.engine")


def test_injected_clears_even_on_crash():
    with pytest.raises(faults.Crash):
        with faults.injected("write.pre_publish",
                             error=faults.Crash("died")):
            faults.fire("write.pre_publish")
    assert not faults.active("write.pre_publish")


def test_crash_tears_through_except_exception():
    """The serving stack catches Exception to keep serving; a simulated
    process death must never be swallowed by that."""
    with pytest.raises(faults.Crash):
        try:
            raise faults.Crash("simulated SIGKILL")
        except Exception:                            # noqa: BLE001
            pytest.fail("Crash was caught by an `except Exception`")


# ---------------------------------------------------------------------------
# Write-ahead log
# ---------------------------------------------------------------------------


def test_wal_roundtrip(tmp_path):
    path = str(tmp_path / "serving.wal")
    with wal_lib.WriteAheadLog(path) as wal:
        wal.append("insert", version=1,
                   emb=np.arange(6, dtype=np.float32).reshape(2, 3),
                   ids=np.array([7, 8]))
        wal.append("delete", version=2, ids=np.array([7]))
        assert wal.n_records == 2 and wal.last_version == 2
        recs = wal.records()
    assert [r["kind"] for r in recs] == ["insert", "delete"]
    assert [r["version"] for r in recs] == [1, 2]
    np.testing.assert_array_equal(
        recs[0]["emb"], np.arange(6, dtype=np.float32).reshape(2, 3))
    # reopen: counters rebuilt from disk, nothing dropped
    with wal_lib.WriteAheadLog(path) as wal:
        assert wal.n_records == 2 and not wal.dropped_tail
    # read-only replay sees the same records
    assert [r["version"] for r in wal_lib.replay(path)] == [1, 2]


def test_wal_torn_tail_dropped_on_reopen(tmp_path):
    path = str(tmp_path / "serving.wal")
    wal = wal_lib.WriteAheadLog(path)
    wal.append("insert", version=1, ids=np.array([1]))
    good_end = wal.nbytes()
    # crash mid-append: only half the second record reaches the disk
    faults.inject("wal.torn_tail", callback=lambda nbytes, path: nbytes // 2)
    with pytest.raises(faults.Crash):
        wal.append("insert", version=2, ids=np.array([2]))
    wal.close()
    assert os.path.getsize(path) > good_end          # torn bytes exist
    wal2 = wal_lib.WriteAheadLog(path)               # reopen post-crash
    assert wal2.dropped_tail
    assert wal2.n_records == 1                       # good prefix only
    assert wal2.nbytes() == good_end                 # tail truncated
    wal2.append("insert", version=3, ids=np.array([3]))
    assert [r["version"] for r in wal2.records()] == [1, 3]
    wal2.close()


def test_wal_truncate(tmp_path):
    path = str(tmp_path / "serving.wal")
    with wal_lib.WriteAheadLog(path) as wal:
        wal.append("insert", version=1, ids=np.array([1]))
        wal.truncate()
        assert wal.n_records == 0 and wal.last_version == 0
        assert wal.records() == []
        wal.append("delete", version=5, ids=np.array([9]))
        assert [r["version"] for r in wal.records()] == [5]


def test_wal_bad_magic(tmp_path):
    path = str(tmp_path / "serving.wal")
    with open(path, "wb") as f:
        f.write(b"NOTALIST" + b"\x00" * 32)
    with pytest.raises(wal_lib.WalCorrupt):
        wal_lib.WriteAheadLog(path)


# ---------------------------------------------------------------------------
# Checkpoint atomicity + corruption detection
# ---------------------------------------------------------------------------


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, 3)).astype(np.float32),
            "b": rng.normal(size=(3,)).astype(np.float32)}


def test_ckpt_crash_mid_save_keeps_prior_step(tmp_path):
    d = str(tmp_path)
    t0 = _tree(0)
    ckpt.save(d, 0, t0)
    faults.inject("ckpt.mid_save", error=faults.Crash("died mid-save"))
    with pytest.raises(faults.Crash):
        ckpt.save(d, 1, _tree(1))
    # the half-written step never became visible; step 0 still restores
    assert ckpt.all_steps(d) == [0]
    got, step, _ = ckpt.restore(d, t0)
    assert step == 0
    np.testing.assert_array_equal(got["w"], t0["w"])
    # the next successful save commits and GCs the crashed .tmp
    ckpt.save(d, 1, _tree(1))
    assert ckpt.all_steps(d) == [0, 1]
    assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_ckpt_leaf_corruption_raises_snapshot_corrupt(tmp_path):
    d = str(tmp_path)
    t0 = _tree(0)
    path = ckpt.save(d, 0, t0)
    leaf = next(p for p in sorted(os.listdir(path)) if p.endswith(".npy"))
    with open(os.path.join(path, leaf), "r+b") as f:
        f.seek(0)
        f.write(b"\xff" * 16)                        # bit-rot the header
    with pytest.raises(ckpt.SnapshotCorrupt):
        ckpt.restore(d, t0)


def test_ckpt_missing_leaf_raises_snapshot_corrupt(tmp_path):
    d = str(tmp_path)
    t0 = _tree(0)
    path = ckpt.save(d, 0, t0)
    leaf = next(p for p in sorted(os.listdir(path)) if p.endswith(".npy"))
    os.remove(os.path.join(path, leaf))
    with pytest.raises(ckpt.SnapshotCorrupt, match="committed checkpoint"):
        ckpt.restore(d, t0)


def test_ckpt_garbage_manifest_raises_snapshot_corrupt(tmp_path):
    d = str(tmp_path)
    path = ckpt.save(d, 0, _tree(0))
    with open(os.path.join(path, "manifest.json"), "w") as f:
        f.write('{"meta": {"truncated mid-wri')
    with pytest.raises(ckpt.SnapshotCorrupt):
        ckpt.read_meta(d)


def test_load_latest_good_skips_corrupt_newest(engine_parts, tmp_path):
    d = str(tmp_path)
    snap0 = make_engine(engine_parts).snapshot
    snap0.save(d)
    snap1 = snap0.with_buffers(dict(snap0.buffers))  # version + 1
    path1 = snap1.save(d)
    # bit-rot the newest step's manifest → recovery must fall back
    with open(os.path.join(path1, "manifest.json"), "w") as f:
        f.write("not json at all")
    loaded = snapshot_lib.load_latest_good(d)
    assert loaded.meta.version == snap0.meta.version
    # with every step corrupt, recovery reports which steps it tried
    path0 = os.path.join(
        d, f"step_{snap0.meta.version:09d}")
    with open(os.path.join(path0, "manifest.json"), "w") as f:
        f.write("also garbage")
    with pytest.raises(FileNotFoundError, match="corrupt"):
        snapshot_lib.load_latest_good(d)


# ---------------------------------------------------------------------------
# The core invariant: zero lost acked writes, zero torn reads
# ---------------------------------------------------------------------------


def _serve_cfg(**over):
    kw = dict(batch_size=4, max_delay_ms=30.0, k=5, cr=2, backend="dense",
              delta_threshold=1024)
    kw.update(over)
    return server_lib.ServerConfig(**kw)


@pytest.mark.parametrize("crash_point", [
    "write.pre_publish",        # WAL has the record, publish never ran
    "write.post_publish",       # published + logged, ack lost in flight
    "wal.torn_tail",            # died mid-append: record torn, dropped
])
def test_recover_loses_no_acked_write(engine_parts, tmp_path, rng,
                                      crash_point):
    snap_dir = str(tmp_path / "snap")
    wal_dir = str(tmp_path / "wal")
    cfg = _serve_cfg(wal_dir=wal_dir)
    snap0 = make_engine(engine_parts).snapshot
    api.save(snap0, snap_dir)

    victim = api.Searcher(snap0, backend="dense").serve(cfg)
    acked = [insert_batch(victim, rng, base_id=10_000_000 + 100 * i)
             for i in range(2)]                      # both batches acked

    # the third batch crashes at the injected point
    if crash_point == "wal.torn_tail":
        faults.inject(crash_point,
                      callback=lambda nbytes, path: nbytes // 3)
    else:
        faults.inject(crash_point, error=faults.Crash("process died"))
    with pytest.raises(faults.Crash):
        insert_batch(victim, rng, base_id=10_000_500)
    victim.close()                                   # what a crash leaves

    recovered = api.recover(snap_dir, wal_dir, config=cfg, backend="dense")

    # at-least-once: an acked write is always recovered; an un-acked one
    # is recovered iff its WAL record survived intact (pre/post_publish
    # crashed AFTER the durable append; torn_tail crashed during it)
    expect = len(acked) + (0 if crash_point == "wal.torn_tail" else 1)
    assert recovered.stats.recovered_writes == expect
    assert recovered.wal.dropped_tail == (crash_point == "wal.torn_tail")

    # zero torn reads: the recovered index answers bit-identically to a
    # never-crashed server that applied exactly the surviving batches
    oracle = api.Searcher(snap0, backend="dense").serve(
        _serve_cfg())                                # same knobs, no WAL
    for rec in recovered.wal.records():
        oracle.insert_objects(rec["emb"], rec["loc"], rec["ids"])
    tok, msk, loc = make_requests(rng, 8, make_engine(engine_parts).cfg)
    ids_r, sc_r = full_fanout(recovered, tok, msk, loc)
    ids_o, sc_o = full_fanout(oracle, tok, msk, loc)
    np.testing.assert_array_equal(ids_r, ids_o)
    np.testing.assert_array_equal(sc_r, sc_o)
    # each acked batch is durably witnessed, not merely counted
    logged = [set(np.asarray(r["ids"]).tolist())
              for r in recovered.wal.records()]
    for _, _, batch_ids in acked:
        assert any(int(batch_ids[0]) in s for s in logged)
    recovered.close()


def test_checkpoint_truncates_wal_and_recovers_clean(engine_parts,
                                                     tmp_path, rng):
    snap_dir = str(tmp_path / "snap")
    wal_dir = str(tmp_path / "wal")
    cfg = _serve_cfg(wal_dir=wal_dir)
    snap0 = make_engine(engine_parts).snapshot
    server = api.Searcher(snap0, backend="dense").serve(cfg)
    for i in range(2):
        insert_batch(server, rng, base_id=11_000_000 + 100 * i)
    assert server.wal.n_records == 2
    server.checkpoint(snap_dir)
    assert server.wal.n_records == 0                 # log now redundant

    recovered = api.recover(snap_dir, wal_dir, config=cfg, backend="dense")
    assert recovered.stats.recovered_writes == 0     # all in the snapshot
    tok, msk, loc = make_requests(rng, 8, make_engine(engine_parts).cfg)
    ids_a, sc_a = full_fanout(server, tok, msk, loc)
    ids_b, sc_b = full_fanout(recovered, tok, msk, loc)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sc_a, sc_b)
    server.close()
    recovered.close()


def test_replay_skips_records_already_in_snapshot(engine_parts, tmp_path,
                                                  rng):
    """Crash between snapshot.save and wal.truncate: the WAL still holds
    every record, but their versions are at-or-below the saved snapshot's
    — replay must double-apply nothing."""
    snap_dir = str(tmp_path / "snap")
    wal_dir = str(tmp_path / "wal")
    cfg = _serve_cfg(wal_dir=wal_dir)
    snap0 = make_engine(engine_parts).snapshot
    server = api.Searcher(snap0, backend="dense").serve(cfg)
    insert_batch(server, rng, base_id=12_000_000)
    # the checkpoint sequence, dying right after the save
    snap = server.compact_now()
    api.save(snap, snap_dir)
    server.close()                                   # truncate never ran
    assert wal_lib.WriteAheadLog(wal_lib.wal_path(wal_dir)).n_records == 1

    recovered = api.recover(snap_dir, wal_dir, config=cfg, backend="dense")
    assert recovered.stats.recovered_writes == 0     # skipped by version
    tok, msk, loc = make_requests(rng, 8, make_engine(engine_parts).cfg)
    ids_a, _ = full_fanout(server, tok, msk, loc)
    ids_b, _ = full_fanout(recovered, tok, msk, loc)
    np.testing.assert_array_equal(ids_a, ids_b)
    recovered.close()


# ---------------------------------------------------------------------------
# Graceful degradation: breaker, shedding, slow-flush detection
# ---------------------------------------------------------------------------


def test_breaker_trips_to_fallback_then_probes(engine_parts, rng):
    # "auto" resolves to dense on this engine, so both the primary and
    # the fallback are cheap — but their names differ, which is what
    # arms the breaker (a "dense" server has nothing to degrade to)
    server = make_server(engine_parts, backend="auto", batch_size=1,
                         breaker_threshold=2, breaker_probe_every=2,
                         retry_backoff_ms=0.0)
    tok, msk, loc = make_requests(rng, 6, server.engine.cfg)
    faults.inject("flush.engine", error=RuntimeError("XLA OOM"), times=2)

    async def go():
        outs = []
        for i in range(6):
            try:
                outs.append(await server.submit(tok[i], msk[i], loc[i]))
            except RuntimeError:
                outs.append(None)
        return outs

    outs = asyncio.run(go())
    assert outs[0] is None and outs[1] is None       # the two failures
    assert server.stats.breaker_trips == 1           # tripped on the 2nd
    # requests 3-4 ran on the fallback; after probe_every=2 successes
    # the breaker half-opened and 5-6 ran (and stayed) on the primary
    assert server.stats.breaker_fallback_flushes == 2
    assert not server.metrics()["breaker"]["open"]
    eng = make_engine(engine_parts)
    ids_d, sc_d = eng.query(tok[2:], msk[2:], loc[2:], k=5, cr=2, batch=1,
                            backend="dense")
    for i, out in enumerate(outs[2:]):
        assert out is not None
        np.testing.assert_array_equal(out[0], ids_d[i])


def test_breaker_disabled_without_fallback(engine_parts, rng):
    server = make_server(engine_parts, batch_size=1, breaker_threshold=1,
                         retry_backoff_ms=0.0)       # backend="dense"
    assert server._fallback_backend() is None
    tok, msk, loc = make_requests(rng, 2, server.engine.cfg)
    faults.inject("flush.engine", error=RuntimeError("boom"), times=1)

    async def go():
        with pytest.raises(RuntimeError, match="boom"):
            await server.submit(tok[0], msk[0], loc[0])
        return await server.submit(tok[1], msk[1], loc[1])

    out = asyncio.run(go())
    assert out is not None
    assert server.stats.breaker_trips == 0           # nothing to trip to


def test_deadline_shed_at_flush(engine_parts, rng):
    server = make_server(engine_parts, batch_size=8, max_delay_ms=30.0,
                         request_timeout_ms=1.0)
    tok, msk, loc = make_requests(rng, 3, server.engine.cfg)

    async def go():
        tasks = [asyncio.ensure_future(server.submit(tok[i], msk[i],
                                                     loc[i]))
                 for i in range(3)]
        return await asyncio.gather(*tasks, return_exceptions=True)

    out = asyncio.run(go())
    # the deadline flush fires at 30ms — every 1ms deadline has passed
    assert all(isinstance(o, server_lib.DeadlineExceeded) for o in out)
    assert server.stats.shed["expired"] == 3
    assert server.stats.engine_batches == 0          # nothing was scored


def test_deadline_shed_before_enqueue(engine_parts, rng):
    server = make_server(engine_parts, request_timeout_ms=5.0)
    tok, msk, loc = make_requests(rng, 1, server.engine.cfg)

    async def go():
        # open-loop backlog: the intended arrival is long past due
        with pytest.raises(server_lib.DeadlineExceeded):
            await server.submit(tok[0], msk[0], loc[0],
                                t_arrival=time.perf_counter() - 1.0)

    asyncio.run(go())
    assert server.stats.shed["expired"] == 1


def test_admission_shed_on_full_queue(engine_parts, rng):
    server = make_server(engine_parts, batch_size=8, max_delay_ms=60_000.0,
                         max_queue=2)
    tok, msk, loc = make_requests(rng, 3, server.engine.cfg)

    async def go():
        tasks = [asyncio.ensure_future(server.submit(tok[i], msk[i],
                                                     loc[i]))
                 for i in range(2)]
        await asyncio.sleep(0)                       # both now pending
        with pytest.raises(server_lib.Overloaded):
            await server.submit(tok[2], msk[2], loc[2])
        server.flush_now()                           # admitted ones finish
        return await asyncio.gather(*tasks)

    out = asyncio.run(go())
    assert len(out) == 2 and all(o is not None for o in out)
    assert server.stats.shed["queue_full"] == 1


def test_open_loop_shed_ok_accounts_for_every_arrival(engine_parts, rng):
    server = make_server(engine_parts, batch_size=2, max_queue=2,
                         request_timeout_ms=20.0, cache_size=0)
    n = 24
    tok, msk, loc = make_requests(rng, n, server.engine.cfg)
    reqs = [(tok[i], msk[i], loc[i]) for i in range(n)]
    results = asyncio.run(server_lib.open_loop(server, reqs, qps=5_000.0,
                                               shed_ok=True))
    served = sum(1 for r in results if r is not None)
    shed = sum(server.stats.shed.values())
    assert served + shed == n                        # conservation
    assert served > 0                                # it kept serving


def test_straggler_monitor_slow_unit():
    m = resilience_lib.StragglerMonitor(window=8)
    for _ in range(3):
        m.record("flush", 1.0)
    assert not m.slow("flush")                       # not enough history
    for _ in range(5):
        m.record("flush", 1.0)
    assert not m.slow("flush")                       # steady stream
    m.record("flush", 10.0)
    assert m.slow("flush")                           # 10× the window
    m.record("flush", 1.0)
    assert not m.slow("flush")                       # back to normal


def test_slow_flush_counted_in_metrics(engine_parts, rng):
    server = make_server(engine_parts, batch_size=1)
    for _ in range(20):                              # a steady history
        server._flush_monitor.record("flush", 1e-3)
    faults.inject("flush.slow", callback=lambda: time.sleep(0.2))
    tok, msk, loc = make_requests(rng, 1, server.engine.cfg)

    async def go():
        return await server.submit(tok[0], msk[0], loc[0])

    out = asyncio.run(go())
    assert out is not None                           # slow, not failed
    assert server.stats.slow_flushes == 1
    assert server.metrics()["last_slow_flush_at"] is not None


# ---------------------------------------------------------------------------
# WAL growth bound: auto-checkpoint off the write path
# ---------------------------------------------------------------------------


def test_wal_max_bytes_requires_both_dirs(engine_parts, tmp_path):
    with pytest.raises(ValueError, match="wal_max_bytes"):
        make_server(engine_parts, wal_max_bytes=1024,
                    wal_dir=str(tmp_path / "wal"))
    with pytest.raises(ValueError, match="wal_max_bytes"):
        make_server(engine_parts, wal_max_bytes=1024,
                    snapshot_dir=str(tmp_path / "snap"))
    # both present → fine
    make_server(engine_parts, wal_max_bytes=1024,
                wal_dir=str(tmp_path / "wal"),
                snapshot_dir=str(tmp_path / "snap")).close()


def test_wal_max_bytes_auto_checkpoints_and_truncates(engine_parts,
                                                      tmp_path, rng):
    """Regression for unbounded WAL growth: crossing ``wal_max_bytes``
    checkpoints into ``snapshot_dir`` and truncates the log, so replay
    work stays bounded no matter how long the server runs."""
    snap_dir = str(tmp_path / "snap")
    wal_dir = str(tmp_path / "wal")
    cfg = _serve_cfg(wal_dir=wal_dir, snapshot_dir=snap_dir,
                     wal_max_bytes=1)         # any append crosses it
    snap0 = make_engine(engine_parts).snapshot
    server = api.Searcher(snap0, backend="dense").serve(cfg)

    insert_batch(server, rng, base_id=14_000_000)
    assert server.stats.wal_checkpoints == 1
    assert server.wal.n_records == 0          # log truncated by the ckpt
    m = server.metrics()
    assert m["wal"]["max_bytes"] == 1
    assert m["wal"]["auto_checkpoints"] == 1

    insert_batch(server, rng, base_id=14_000_100)
    assert server.stats.wal_checkpoints == 2

    # the auto-committed snapshot alone recovers both batches
    recovered = api.recover(snap_dir, wal_dir, config=cfg, backend="dense")
    assert recovered.stats.recovered_writes == 0     # all in the snapshot
    tok, msk, loc = make_requests(rng, 8, server.engine.cfg)
    ids_a, sc_a = full_fanout(server, tok, msk, loc)
    ids_b, sc_b = full_fanout(recovered, tok, msk, loc)
    np.testing.assert_array_equal(ids_a, ids_b)
    np.testing.assert_array_equal(sc_a, sc_b)
    server.close()
    recovered.close()


def test_wal_below_threshold_never_checkpoints(engine_parts, tmp_path, rng):
    cfg = _serve_cfg(wal_dir=str(tmp_path / "wal"),
                     snapshot_dir=str(tmp_path / "snap"),
                     wal_max_bytes=1 << 30)
    server = api.Searcher(make_engine(engine_parts).snapshot,
                          backend="dense").serve(cfg)
    insert_batch(server, rng, base_id=15_000_000)
    assert server.stats.wal_checkpoints == 0
    assert server.wal.n_records == 1
    server.close()


# ---------------------------------------------------------------------------
# Seeded retry-backoff jitter
# ---------------------------------------------------------------------------


def test_backoff_jitter_sequence_is_seeded(engine_parts):
    server = make_server(engine_parts, retry_backoff_ms=2.0,
                         retry_backoff_max_ms=20.0, retry_jitter=0.25,
                         retry_seed=123)
    got = [server._backoff_ms(d) for d in range(6)]
    # pin the exact sequence by replaying the same seeded stream
    ref_rng = np.random.default_rng(123)
    want = []
    for d in range(6):
        base = min(2.0 * 2 ** d, 20.0)
        want.append(base * (1.0 - 0.25 * float(ref_rng.random())))
    assert got == pytest.approx(want)
    # jittered but never below the full-jitter floor, always capped
    for d, ms in enumerate(got):
        base = min(2.0 * 2 ** d, 20.0)
        assert 0.75 * base <= ms <= base
    # a same-seeded server reproduces the identical sequence
    twin = make_server(engine_parts, retry_backoff_ms=2.0,
                       retry_backoff_max_ms=20.0, retry_jitter=0.25,
                       retry_seed=123)
    assert [twin._backoff_ms(d) for d in range(6)] == pytest.approx(got)


def test_backoff_without_jitter_doubles_to_cap(engine_parts):
    server = make_server(engine_parts, retry_backoff_ms=2.0,
                         retry_backoff_max_ms=20.0, retry_jitter=0.0)
    assert [server._backoff_ms(d) for d in range(5)] == [
        2.0, 4.0, 8.0, 16.0, 20.0]


# ---------------------------------------------------------------------------
# api facade: operational exceptions are import-stable
# ---------------------------------------------------------------------------


def test_api_exports_operational_exceptions():
    """Callers catch these by identity — the facade must re-export the
    defining classes, not copies."""
    assert api.Overloaded is server_lib.Overloaded
    assert api.DeadlineExceeded is server_lib.DeadlineExceeded
    assert api.SnapshotCorrupt is ckpt.SnapshotCorrupt
    assert api.ShardUnavailable is resilience_lib.ShardUnavailable
    for name in ("Overloaded", "DeadlineExceeded", "SnapshotCorrupt",
                 "ShardUnavailable"):
        assert name in api.__all__


# ---------------------------------------------------------------------------
# load_latest_good / recover edge cases
# ---------------------------------------------------------------------------


def test_load_latest_good_empty_dir(tmp_path):
    with pytest.raises(FileNotFoundError, match="no committed"):
        snapshot_lib.load_latest_good(str(tmp_path))


def test_load_latest_good_only_corrupt(engine_parts, tmp_path):
    d = str(tmp_path)
    snap0 = make_engine(engine_parts).snapshot
    path0 = snap0.save(d)
    with open(os.path.join(path0, "manifest.json"), "w") as f:
        f.write("{{{ definitely not a manifest")
    with pytest.raises(FileNotFoundError, match="corrupt"):
        snapshot_lib.load_latest_good(d)


def test_recover_with_missing_wal_dir(engine_parts, tmp_path, rng):
    """First boot after enabling durability: the snapshot exists but the
    WAL directory was never created. Recovery must come up clean (zero
    replayed writes) and create the log for subsequent appends."""
    snap_dir = str(tmp_path / "snap")
    wal_dir = str(tmp_path / "never_made" / "wal")
    snap0 = make_engine(engine_parts).snapshot
    api.save(snap0, snap_dir)
    assert not os.path.isdir(wal_dir)

    cfg = _serve_cfg(wal_dir=wal_dir)
    recovered = api.recover(snap_dir, wal_dir, config=cfg, backend="dense")
    assert recovered.stats.recovered_writes == 0
    insert_batch(recovered, rng, base_id=16_000_000)   # log now appendable
    assert recovered.wal.n_records == 1
    recovered.close()
