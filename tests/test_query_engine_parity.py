"""Parity/property tier for the unified query engine (DESIGN.md §4–§5).

The gather-free Pallas kernel (scalar-prefetched routing into resident
(c, cap, d) buffers, in-kernel cr-merge) must be indistinguishable from
the dense reference (gather + one top-k) across shapes, buffer padding,
tie scores, and cr ∈ {1, 2, 4} — and its jaxpr must contain NO
(B, cr·cap, d) candidate-sized intermediate (the point of the kernel).
"""
import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import engine
from repro.core import index as il
from repro.core import relevance
from repro.core import spatial as sp
from repro.kernels import ops

DIST_MAX = 1.414


# ---------------------------------------------------------------------------
# Synthetic routed-query instances (no encoder: kernel-level parity)
# ---------------------------------------------------------------------------


def _mk_instance(rng, *, b, cr, c, cap, d, t=50, empty_clusters=(),
                 valid_per_cluster=None, tie_embeddings=False):
    """Random buffers + routed queries. -1 ids mark buffer padding."""
    q = rng.normal(size=(b, d)).astype(np.float32)
    ql = rng.uniform(size=(b, 2)).astype(np.float32)
    w = rng.uniform(0.5, 1.5, size=(b, 2)).astype(np.float32)
    be = rng.normal(size=(c, cap, d)).astype(np.float32)
    bl = rng.uniform(size=(c, cap, 2)).astype(np.float32)
    bi = np.arange(c * cap, dtype=np.int32).reshape(c, cap)
    if valid_per_cluster is not None:        # partially-filled clusters
        bi[:, valid_per_cluster:] = -1
    for ci in empty_clusters:                # fully-empty clusters
        bi[ci] = -1
    be[bi < 0] = 0.0
    bl[bi < 0] = 1e6
    if tie_embeddings:                       # every candidate scores equal
        be[:] = be[0, 0]
        bl[:] = 0.25
        ql[:] = 0.25
    top_c = rng.integers(0, c, size=(b, cr)).astype(np.int32)
    w_hat = np.cumsum(rng.uniform(0, 0.05, size=t)).astype(np.float32)
    return tuple(jnp.asarray(x) for x in (q, ql, w, top_c, be, bl, bi, w_hat))


def _both_backends(args, *, k, block_n=512):
    s_p, i_p = ops.fused_topk_score_routed(*args, k=k, dist_max=DIST_MAX,
                                           block_n=block_n, interpret=True)
    s_d, i_d = engine.dense_routed_topk(*args, k=k, dist_max=DIST_MAX)
    return (np.asarray(s_p), np.asarray(i_p),
            np.asarray(s_d), np.asarray(i_d))


# ---------------------------------------------------------------------------
# Shape sweep: n < block_n, cap not a multiple of block_n, cr ∈ {1,2,4}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cr", [1, 2, 4])
@pytest.mark.parametrize("b,c,cap,d,k,block_n", [
    (8, 6, 64, 32, 5, 512),      # cap < block_n: single-tile clusters
    (16, 4, 128, 16, 10, 32),    # multi-tile streaming per cluster
    (3, 5, 96, 8, 7, 64),        # odd b; block_n forced down to gcd=32
    (1, 2, 32, 64, 32, 512),     # single query, k == cap
])
def test_routed_kernel_matches_dense_reference(b, c, cap, d, k, cr, block_n,
                                               rng):
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d)
    s_p, i_p, s_d, i_d = _both_backends(args, k=k, block_n=block_n)
    np.testing.assert_allclose(s_p, s_d, rtol=1e-4, atol=1e-4)
    # identical id SETS per query (tie order inside equal scores is free)
    assert (np.sort(i_p, axis=1) == np.sort(i_d, axis=1)).all()


@pytest.mark.parametrize("cr", [1, 2, 4])
def test_k_exceeds_valid_candidates(cr, rng):
    """k > valid candidates: both backends pad with (-1, NEG_INF)."""
    b, c, cap, d, k = 6, 4, 32, 16, 20
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d,
                        valid_per_cluster=3)        # ≤ 3·cr valid per query
    s_p, i_p, s_d, i_d = _both_backends(args, k=k)
    np.testing.assert_allclose(s_p, s_d, rtol=1e-4, atol=1e-4)
    assert (np.sort(i_p, axis=1) == np.sort(i_d, axis=1)).all()
    n_valid = (i_p >= 0).sum(1)
    assert (n_valid <= 3 * cr).all()
    assert ((s_p < -1e29) == (i_p < 0)).all()       # pads are NEG_INF/-1


def test_fully_empty_routed_clusters(rng):
    """Queries routed into all-padding clusters return only pads."""
    b, c, cap, d, k, cr = 4, 4, 32, 16, 5, 2
    args = list(_mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d,
                             empty_clusters=(1, 3)))
    args[3] = jnp.asarray(np.array([[1, 3]] * b, np.int32))  # route to empties
    s_p, i_p, s_d, i_d = _both_backends(tuple(args), k=k)
    assert (i_p == -1).all() and (i_d == -1).all()
    np.testing.assert_allclose(s_p, s_d)
    # mixed routing: one empty + one live cluster still merges correctly
    args[3] = jnp.asarray(np.array([[1, 0]] * b, np.int32))
    s_p, i_p, s_d, i_d = _both_backends(tuple(args), k=k)
    np.testing.assert_allclose(s_p, s_d, rtol=1e-4, atol=1e-4)
    assert (np.sort(i_p, axis=1) == np.sort(i_d, axis=1)).all()
    assert (i_p < cap).all()                        # only cluster-0 objects


@pytest.mark.parametrize("cr", [1, 2, 4])
def test_tie_scores(cr, rng):
    """All candidates score identically: backends may order ties freely,
    but scores must match exactly and every returned id must be a real,
    distinct candidate from the routed clusters."""
    b, c, cap, d, k = 5, 4, 32, 16, 8
    args = _mk_instance(rng, b=b, cr=cr, c=c, cap=cap, d=d,
                        tie_embeddings=True)
    s_p, i_p, s_d, i_d = _both_backends(args, k=k)
    np.testing.assert_allclose(s_p, s_d, rtol=1e-4, atol=1e-4)
    top_c, bi = np.asarray(args[3]), np.asarray(args[6])
    for row in range(b):
        routed = set(bi[top_c[row]].reshape(-1).tolist()) - {-1}
        picked = i_p[row].tolist()
        assert len(set(picked)) == k                # no duplicates
        assert set(picked) <= routed                # all from routed clusters


# ---------------------------------------------------------------------------
# Engine-level parity (encoder + router + kernel) and batch padding
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_setup():
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(7)
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap = 160, cfg.n_clusters, 64
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(5), cfg.d_model, c,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap)
    w_hat = sp.extract_lookup(params["spatial"])
    return cfg, params, iparams, norm, buf, w_hat


@pytest.mark.parametrize("backend", ["pallas", "pallas-cm", "dense-cm"])
@pytest.mark.parametrize("cr", [1, 2, 4])
def test_engine_backend_parity_end_to_end(engine_setup, cr, backend, rng):
    """Every non-reference backend — query-major pallas AND the two
    cluster-major flavors (DESIGN.md §10) — matches the dense oracle
    through the full encode→route→scan pipeline."""
    cfg, params, iparams, norm, buf, w_hat = engine_setup
    b, k = 8, 5
    tok = jnp.asarray(rng.integers(2, 512, (b, 8)), jnp.int32)
    msk = jnp.ones((b, 8), bool)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    a = (params, iparams, w_hat, norm, buf["emb"], buf["loc"], buf["ids"],
         buf["scale"], tok, msk, ql)
    fd = engine.make_query_fn(cfg, cr=cr, k=k, backend="dense",
                              dist_max=DIST_MAX)
    fp = engine.make_query_fn(cfg, cr=cr, k=k, backend=backend,
                              interpret=True, dist_max=DIST_MAX)
    i_d, s_d = fd(*a)
    i_p, s_p = fp(*a)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_d),
                               rtol=1e-4, atol=1e-4)
    assert (np.sort(np.asarray(i_p)) == np.sort(np.asarray(i_d))).all()


# ---------------------------------------------------------------------------
# Precision tiers (DESIGN.md §9): dense↔pallas parity WITHIN each tier,
# and quantization fidelity against the exact-f32 ranking
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "pallas-cm", "dense-cm"])
@pytest.mark.parametrize("precision", ["bf16", "int8"])
@pytest.mark.parametrize("cr", [1, 2])
def test_engine_precision_tier_backend_parity(engine_setup, precision, cr,
                                              backend, rng):
    """Within a precision tier every backend must agree with the dense
    reference: the kernels (query- AND cluster-major) dequantize in VMEM
    with the same per-row scales the dense paths apply after their
    gathers."""
    from repro.core import index as il2
    cfg, params, iparams, norm, buf, w_hat = engine_setup
    qbuf = il2.quantize_buffers(buf, precision)
    assert str(np.asarray(qbuf["emb"]).dtype) == (
        "bfloat16" if precision == "bf16" else "int8")
    b, k = 8, 5
    tok = jnp.asarray(rng.integers(2, 512, (b, 8)), jnp.int32)
    msk = jnp.ones((b, 8), bool)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    a = (params, iparams, w_hat, norm, qbuf["emb"], qbuf["loc"],
         qbuf["ids"], qbuf["scale"], tok, msk, ql)
    fd = engine.make_query_fn(cfg, cr=cr, k=k, backend="dense",
                              dist_max=DIST_MAX, precision=precision)
    fp = engine.make_query_fn(cfg, cr=cr, k=k, backend=backend,
                              interpret=True, dist_max=DIST_MAX,
                              precision=precision)
    i_d, s_d = fd(*a)
    i_p, s_p = fp(*a)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_d),
                               rtol=1e-4, atol=1e-4)
    assert (np.sort(np.asarray(i_p)) == np.sort(np.asarray(i_d))).all()


@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_quantized_scores_track_f32(engine_setup, precision, rng):
    """Quantization changes TRel by at most the scalar-quantization error
    — SRel, routing, and padding are bit-identical, so the tier's scores
    must stay close to f32 and the top-k sets mostly overlap."""
    from repro.core import index as il2
    cfg, params, iparams, norm, buf, w_hat = engine_setup
    qbuf = il2.quantize_buffers(buf, precision)
    b, k, cr = 16, 10, 2
    tok = jnp.asarray(rng.integers(2, 512, (b, 8)), jnp.int32)
    msk = jnp.ones((b, 8), bool)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    f_exact = engine.make_query_fn(cfg, cr=cr, k=k, backend="dense",
                                   dist_max=DIST_MAX)
    f_quant = engine.make_query_fn(cfg, cr=cr, k=k, backend="dense",
                                   dist_max=DIST_MAX, precision=precision)
    i_e, s_e = f_exact(params, iparams, w_hat, norm, buf["emb"], buf["loc"],
                       buf["ids"], buf["scale"], tok, msk, ql)
    i_q, s_q = f_quant(params, iparams, w_hat, norm, qbuf["emb"],
                       qbuf["loc"], qbuf["ids"], qbuf["scale"], tok, msk, ql)
    # int8 per-row scalar quantization bounds the per-element embedding
    # error by scale/2; bf16 by ~2^-8 relative — both stay well under 2%
    # of the score magnitude at this scale
    np.testing.assert_allclose(np.asarray(s_q), np.asarray(s_e),
                               rtol=0.05, atol=0.05)
    overlap = np.mean([
        len(set(np.asarray(i_q)[r].tolist())
            & set(np.asarray(i_e)[r].tolist())) / k
        for r in range(b)])
    assert overlap >= 0.9, f"{precision} top-{k} overlap {overlap}"


def test_run_batched_pads_partial_batches(rng):
    """b % batch != 0: the static-shape padding trims outputs exactly."""
    calls = []

    def fn(x, y):
        calls.append(x.shape[0])
        return x * 2, y + 1

    x = rng.normal(size=(23, 4)).astype(np.float32)
    y = rng.normal(size=(23, 2)).astype(np.float32)
    ox, oy = engine.run_batched(fn, [x, y], batch=8)
    assert ox.shape == (23, 4) and oy.shape == (23, 2)
    assert calls == [8, 8, 8]                  # every chunk static-shaped
    np.testing.assert_allclose(ox, x * 2, rtol=1e-6)
    np.testing.assert_allclose(oy, y + 1, rtol=1e-6)


def test_run_batched_overlaps_transfer_with_dispatch(rng):
    """Chunk i's outputs are materialized (host sync) only AFTER chunk
    i+1 has been dispatched — the transfer/compute overlap of the
    serving path. Observed via __array__ hooks on the returned values."""
    events = []

    class Lazy:
        def __init__(self, arr, tag):
            self.arr, self.tag = arr, tag

        def __array__(self, dtype=None, copy=None):
            events.append(("sync", self.tag))
            return self.arr

    def fn(x):
        tag = sum(1 for e in events if e[0] == "dispatch")
        events.append(("dispatch", tag))
        return Lazy(np.asarray(x) * 2, tag)

    x = rng.normal(size=(24, 3)).astype(np.float32)
    out = engine.run_batched(fn, [x], batch=8)
    np.testing.assert_allclose(out, x * 2, rtol=1e-6)
    assert events == [("dispatch", 0), ("dispatch", 1), ("sync", 0),
                      ("dispatch", 2), ("sync", 1), ("sync", 2)]


def test_resolve_backend_rules():
    assert engine.resolve_backend("dense") == ("dense",
                                               engine.default_interpret())
    assert engine.resolve_backend("pallas", interpret=True) == ("pallas",
                                                                True)
    # auto keys on hardware, NOT the interpret flag: pallas iff on TPU
    # (so REPRO_PALLAS_COMPILE=1 on CPU can't route auto into Mosaic)
    expect = "pallas" if jax.default_backend() == "tpu" else "dense"
    assert engine.resolve_backend("auto", interpret=True)[0] == expect
    assert engine.resolve_backend("auto", interpret=False)[0] == expect
    with pytest.raises(ValueError):
        engine.resolve_backend("tpu")
    # the legacy entry points are collapsed: use_pallas survives ONLY as
    # the CLI alias in resolve_cli_backend (tested in test_server), and
    # pipeline no longer wraps the engine's query-fn builder
    from repro.core import pipeline as pl
    assert not hasattr(engine, "legacy_backend")
    assert not hasattr(pl, "make_query_fn")


# ---------------------------------------------------------------------------
# The acceptance criterion: the pallas path's jaxpr has NO candidate copy
# ---------------------------------------------------------------------------


def _subjaxprs_of(params):
    for val in params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            if isinstance(v, jax.core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax.core.Jaxpr):
                yield v


def _all_eqn_out_avals(jaxpr):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                yield aval
        for sub in _subjaxprs_of(eqn.params):
            yield from _all_eqn_out_avals(sub)


def test_pallas_jaxpr_has_no_candidate_gather(engine_setup, rng):
    """The gather path materializes a (B, cr·cap, d) copy; the routed
    kernel must not — assert no candidate-sized intermediate exists."""
    cfg, params, iparams, norm, buf, w_hat = engine_setup
    b, k, cr = 8, 5, 2
    cap, d = buf["emb"].shape[1], buf["emb"].shape[2]
    cand_size = b * cr * cap * d
    tok = jnp.asarray(rng.integers(2, 512, (b, 8)), jnp.int32)
    msk = jnp.ones((b, 8), bool)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    a = (params, iparams, w_hat, norm, buf["emb"], buf["loc"], buf["ids"],
         buf["scale"], tok, msk, ql)

    def sizes(backend):
        fn = engine.make_query_fn(cfg, cr=cr, k=k, backend=backend,
                                  interpret=True, dist_max=DIST_MAX)
        jaxpr = jax.make_jaxpr(fn)(*a)
        return [int(np.prod(av.shape))
                for av in _all_eqn_out_avals(jaxpr.jaxpr)]

    dense_sizes = sizes("dense")
    assert cand_size in dense_sizes, (
        "detector broken: dense path should materialize the candidate copy")
    pallas_sizes = sizes("pallas")
    assert cand_size not in pallas_sizes, (
        "gather-free path materialized a (B, cr·cap, d)-sized intermediate")
    assert max(pallas_sizes) < cand_size, (
        f"pallas path has an intermediate ≥ candidate copy: "
        f"{max(pallas_sizes)} vs {cand_size}")
    # cluster-major goes FURTHER: its largest intermediate is bounded by
    # the distinct-cluster working set min(B·cr, c)·cap·d — smaller than
    # the query-major candidate copy whenever the batch saturates the
    # cluster set (here 4 < 16 routed scans). This bound assumes the
    # roster payload fits it, i.e. B·cr ≤ cap (here 16 ≤ 64) — exactly
    # the regime engine.cluster_major_feasible admits for auto
    cm_sizes = sizes("pallas-cm")
    c = buf["emb"].shape[0]
    cm_bound = min(b * cr, c) * cap * d
    assert cand_size not in cm_sizes
    assert max(cm_sizes) <= cm_bound < cand_size, (
        f"cluster-major intermediate {max(cm_sizes)} exceeds the "
        f"distinct-cluster working set {cm_bound}")
