"""Substrate layers: optimizers, checkpoint, data determinism, distributed
helpers (compression, straggler, elastic planner, sharding rules)."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.distributed import compression as comp
from repro.distributed import sharding as sh
from repro.distributed.resilience import ElasticPlanner, StragglerMonitor
from repro.optim import (
    clip_by_global_norm,
    global_norm,
    linear_warmup_cosine,
    make_optimizer,
)


# --- optimizers -------------------------------------------------------------


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_descends_quadratic(name):
    init, update = make_optimizer(name, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0]), "m": jnp.ones((4, 6)) * 2}
    state = init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    l0 = float(loss(params))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params, 5e-2)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    init, _ = make_optimizer("adafactor")
    params = {"mat": jnp.ones((8, 16)), "vec": jnp.ones((5,)),
              "t3": jnp.ones((3, 4, 6))}
    state = init(params)
    assert state["v"]["mat"]["vr"].shape == (8,)
    assert state["v"]["mat"]["vc"].shape == (16,)
    assert state["v"]["t3"]["vr"].shape == (3, 4)
    assert state["v"]["t3"]["vc"].shape == (3, 6)
    assert state["v"]["vec"]["v"].shape == (5,)
    # factored state is ~ (r+c) not r·c
    n_state = sum(np.prod(x.shape) for x in jax.tree.leaves(state["v"]))
    n_param = sum(np.prod(x.shape) for x in jax.tree.leaves(params))
    assert n_state < 0.5 * n_param


def test_clip_by_global_norm():
    g = {"a": jnp.ones(100) * 10}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    g2 = {"a": jnp.ones(4) * 0.01}
    same, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01)


def test_schedule_warmup_and_decay():
    fn = linear_warmup_cosine(1e-3, 100, 1000)
    lrs = [float(fn(jnp.int32(s))) for s in (0, 50, 100, 500, 1000)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2] and lrs[4] < lrs[3]


# --- checkpoint -------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.arange(12.0).reshape(3, 4),
                "nested": [jnp.ones(2), {"x": jnp.zeros((2, 2))}]}
        for step in (10, 20, 30, 40):
            save(d, step, tree, keep=2)
        assert latest_step(d) == 40
        # keep=2 GC'd the old ones
        steps = [int(n.split("_")[1]) for n in os.listdir(d)
                 if n.startswith("step_") and not n.endswith(".tmp")]
        assert sorted(steps) == [30, 40]
        out, step, meta = restore(d, tree)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(tree["w"]))


def test_checkpoint_ignores_partial_writes():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.ones(3)}
        save(d, 10, tree)
        # simulate a crashed writer: orphan tmp dir without manifest
        os.makedirs(os.path.join(d, "step_000000020.tmp"))
        assert latest_step(d) == 10
        out, step, _ = restore(d, tree)
        assert step == 10


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, {"w": jnp.ones((2, 2))})
        with pytest.raises(ValueError, match="shape"):
            restore(d, {"w": jnp.ones((3, 3))})


def test_elastic_reload_shard_fn_called():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.ones((4, 4))}
        save(d, 5, tree)
        calls = []

        def shard_fn(t):
            calls.append(True)
            return jax.tree.map(jnp.asarray, t)

        mgr = CheckpointManager(d)
        out, step, _ = mgr.restore_or_init(lambda: tree, shard_fn=shard_fn)
        assert step == 5 and calls


# --- data determinism -------------------------------------------------------


def test_streams_deterministic():
    from repro.data import CTRStream, GeoCorpus, GeoCorpusConfig, LMStream
    s1 = LMStream(512, seed=7).batch(3, 4, 32)
    s2 = LMStream(512, seed=7).batch(3, 4, 32)
    np.testing.assert_array_equal(s1["tokens"], s2["tokens"])
    c1 = CTRStream(13, [100] * 4, seed=7).batch(5, 16)
    c2 = CTRStream(13, [100] * 4, seed=7).batch(5, 16)
    np.testing.assert_array_equal(c1["sparse"], c2["sparse"])
    g1 = GeoCorpus(GeoCorpusConfig(n_objects=200, n_queries=40, seed=3))
    g2 = GeoCorpus(GeoCorpusConfig(n_objects=200, n_queries=40, seed=3))
    np.testing.assert_array_equal(g1.obj_doc, g2.obj_doc)
    b1 = g1.train_batch(9, 8, np.arange(40))
    b2 = g2.train_batch(9, 8, np.arange(40))
    np.testing.assert_array_equal(b1["q_tokens"], b2["q_tokens"])


def test_corpus_ground_truth_sane(small_corpus):
    c = small_corpus
    for i in range(0, c.cfg.n_queries, 10):
        pos = c.positives[i]
        assert len(pos) >= 1
        # positives share the query's topic
        assert (c.obj_topic[pos] == c.q_topic[i]).all()
    # near-distance concentration (the paper Fig. 1b pattern)
    d_pos = [np.linalg.norm(c.obj_loc[p] - c.q_loc[i], axis=1).mean()
             for i, p in enumerate(c.positives)]
    assert np.mean(d_pos) < 0.15


# --- gradient compression ---------------------------------------------------


@hypothesis.given(st.integers(0, 5))
@hypothesis.settings(max_examples=5, deadline=None)
def test_quantize_roundtrip_error_bounded(seed):
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.normal(0, 1, size=(320,)), jnp.float32)
    q, s, n = comp.quantize_int8(g, block=64)
    deq = comp.dequantize_int8(q, s, n, g.shape)
    # error per element <= scale/2 = max|block|/254
    err = np.abs(np.asarray(deq - g))
    max_per_block = np.abs(np.asarray(g)).reshape(-1, 64).max(1)
    bound = np.repeat(max_per_block / 254 + 1e-6, 64)
    assert (err <= bound + 1e-6).all()


def test_error_feedback_reduces_bias():
    r = np.random.default_rng(0)
    g = jnp.asarray(r.normal(0, 1, size=(256,)), jnp.float32)
    res = jnp.zeros_like(g)
    acc_plain = jnp.zeros_like(g)
    acc_ef = jnp.zeros_like(g)
    for _ in range(50):
        q, s, n = comp.quantize_int8(g, block=64)
        acc_plain += comp.dequantize_int8(q, s, n, g.shape)
        qs, res_new = comp.compress_tree_for_allreduce(
            {"g": g}, {"g": res}, block=64)
        q2, s2 = qs["g"]
        acc_ef += comp.dequantize_int8(q2, s2, 256, g.shape)
        res = res_new["g"]
    target = np.asarray(g) * 50
    # error feedback keeps the accumulated estimate unbiased
    assert (np.abs(np.asarray(acc_ef) - target).mean()
            <= np.abs(np.asarray(acc_plain) - target).mean() + 1e-3)


def test_compressed_psum_matches_mean(rng):
    """shard_map int8 psum ≈ plain mean of per-device grads."""
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    if jax.device_count() < 1:
        pytest.skip("no devices")
    g = jnp.asarray(rng.normal(size=(jax.device_count(), 128)), jnp.float32)
    mesh = Mesh(np.array(jax.devices()), ("d",))
    out = shard_map(
        lambda x: comp.compressed_psum(x[0], "d")[None],
        mesh=mesh, in_specs=P("d", None), out_specs=P("d", None))(g)
    ref = g.mean(axis=0)
    err = np.abs(np.asarray(out)[0] - np.asarray(ref))
    assert err.max() < np.abs(np.asarray(g)).max() / 100


# --- resilience -------------------------------------------------------------


def test_straggler_monitor_flags_slow_host():
    m = StragglerMonitor(patience=2)
    for step in range(5):
        for h in range(8):
            m.record(f"h{h}", 1.0 + 0.01 * h)
        m.record("h8", 9.0)          # 9× slower
        flagged = m.flagged()
    assert flagged == ["h8"]


def test_straggler_monitor_tolerates_jitter():
    m = StragglerMonitor(patience=3)
    r = np.random.default_rng(0)
    for step in range(10):
        for h in range(8):
            m.record(f"h{h}", 1.0 + 0.05 * r.random())
        assert m.flagged() == []


def test_elastic_planner():
    p = ElasticPlanner(chips_per_pod=256, tp_divisor=16, global_batch=256)
    plan2 = p.plan(2)
    assert plan2.shape == (2, 16, 16) and plan2.n_chips == 512
    plan1 = p.plan(1)
    assert plan1.shape == (16, 16) and plan1.n_chips == 256
    assert p.plan(0) is None
    # 3 pods with batch 256: 256 % 3 != 0 -> falls back to 2 pods
    assert p.plan(3).shape == (2, 16, 16)


# --- sharding rules ---------------------------------------------------------


def test_param_specs_divisibility_guard():
    from jax.sharding import PartitionSpec as P
    rules = {"dp": ("data",), "tp": ("model",),
             "_sizes": {"data": 16, "model": 16}}
    shapes = {"item_embed": jax.ShapeDtypeStruct((1000001, 64), jnp.float32),
              "tables": [jax.ShapeDtypeStruct((512, 64), jnp.float32)]}
    with sh.axis_rules(rules):
        specs = sh.param_specs(shapes, sh.REC_PARAM_RULES)
    assert specs["item_embed"] == P(None, None)     # 1000001 % 16 != 0
    assert specs["tables"][0] == P("model", None)   # 512 % 16 == 0


def test_param_specs_lm_rules():
    from jax.sharding import PartitionSpec as P
    rules = {"dp": ("pod", "data"), "tp": ("model",),
             "_sizes": {"pod": 2, "data": 16, "model": 16}}
    shapes = {
        "periods": {"attn": {"wq": {"w": jax.ShapeDtypeStruct(
            (4, 1, 2048, 4096), jnp.float32)}}},
        "embed": jax.ShapeDtypeStruct((32768, 2048), jnp.float32),
    }
    with sh.axis_rules(rules):
        specs = sh.param_specs(shapes, sh.LM_PARAM_RULES)
    assert specs["periods"]["attn"]["wq"]["w"] == P(
        None, None, ("pod", "data"), "model")
    assert specs["embed"] == P("model", ("pod", "data"))
