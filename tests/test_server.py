"""Streaming-server tier (core/server.py, DESIGN.md §7).

Covers the acceptance criteria of the serving stack:

* micro-batched results are BIT-IDENTICAL to direct engine.run_batched
  calls — including across a flush boundary and after an insert_objects
  cache invalidation;
* a cached repeat query returns without invoking the engine (call-count
  spy on QueryEngine.query);
* flush triggers: size vs deadline; partial batches pad by the engine's
  run_batched rule;
* cache tiers: exact LRU, near-duplicate (cell + keyword signature),
  in-flight coalescing, and invalidation on insert/delete.
"""
import asyncio
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import engine as engine_lib
from repro.core import index as il
from repro.core import relevance
from repro.core import server as server_lib

DIST_MAX = 1.414


# ---------------------------------------------------------------------------
# Fixture: a tiny bound engine (random params — serving is quality-agnostic)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def engine_parts():
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(11)
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap = 96, cfg.n_clusters, 64       # headroom for inserts
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(5), cfg.d_model, c,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap)
    return cfg, params, iparams, norm, buf


def make_engine(engine_parts):
    cfg, params, iparams, norm, buf = engine_parts
    return engine_lib.QueryEngine.from_parts(
        cfg, params, iparams, norm, buf, dist_max=DIST_MAX, backend="dense")


def make_server(engine_parts, **over):
    eng = make_engine(engine_parts)
    kw = dict(batch_size=4, max_delay_ms=30.0, k=5, cr=2, backend="dense")
    kw.update(over)
    return server_lib.StreamingServer(eng, server_lib.ServerConfig(**kw))


def make_requests(rng, n, cfg):
    tok = rng.integers(2, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones((n, cfg.max_len), bool)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    return tok, msk, loc


def spy_on(eng):
    """Wrap eng.query with a call counter (the acceptance-criterion spy)."""
    calls = []
    orig = eng.query

    def counted(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    eng.query = counted
    return calls


def direct(eng, tok, msk, loc, *, k=5, cr=2, batch=4):
    """The oracle: the same queries straight through engine.run_batched."""
    return eng.query(tok, msk, loc, k=k, cr=cr, batch=batch, backend="dense")


# ---------------------------------------------------------------------------
# Flush triggers
# ---------------------------------------------------------------------------


def test_flush_on_size(engine_parts, rng):
    server = make_server(engine_parts, max_delay_ms=60_000.0)  # never fires
    tok, msk, loc = make_requests(rng, 4, server.engine.cfg)

    async def go():
        tasks = [asyncio.ensure_future(server.submit(tok[i], msk[i], loc[i]))
                 for i in range(4)]
        return await asyncio.gather(*tasks)

    out = asyncio.run(go())
    assert server.stats.flushes == {"size": 1, "deadline": 0, "drain": 0}
    ids_d, sc_d = direct(make_engine(engine_parts), tok, msk, loc)
    for i, (ids, sc) in enumerate(out):
        assert np.array_equal(ids, ids_d[i]) and np.array_equal(sc, sc_d[i])


def test_flush_on_deadline(engine_parts, rng):
    server = make_server(engine_parts, batch_size=8, max_delay_ms=25.0)
    tok, msk, loc = make_requests(rng, 3, server.engine.cfg)

    async def go():
        tasks = [asyncio.ensure_future(server.submit(tok[i], msk[i], loc[i]))
                 for i in range(3)]
        return await asyncio.gather(*tasks)

    t0 = time.perf_counter()
    out = asyncio.run(go())
    assert time.perf_counter() - t0 >= 0.025    # waited for the deadline
    assert server.stats.flushes == {"size": 0, "deadline": 1, "drain": 0}
    assert server.stats.engine_queries == 3     # partial batch, one flush
    ids_d, sc_d = direct(make_engine(engine_parts), tok, msk, loc, batch=8)
    for i, (ids, sc) in enumerate(out):
        assert np.array_equal(ids, ids_d[i]) and np.array_equal(sc, sc_d[i])


# ---------------------------------------------------------------------------
# Bit-identical parity with direct engine.run_batched calls
# ---------------------------------------------------------------------------


def test_bit_identical_across_flush_boundary(engine_parts, rng):
    """10 requests through a batch-4 server → flushes [4, 4, 2]; the
    direct run_batched call chunks identically. Every id AND score bit
    must match, including the padded trailing chunk."""
    server = make_server(engine_parts)
    tok, msk, loc = make_requests(rng, 10, server.engine.cfg)
    ids_s, sc_s = server.serve_all(tok, msk, loc)
    assert server.stats.flushes["size"] == 2          # two full batches
    assert server.stats.engine_queries == 10
    ids_d, sc_d = direct(make_engine(engine_parts), tok, msk, loc)
    assert np.array_equal(ids_s, ids_d)
    assert np.array_equal(sc_s, sc_d)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def test_cached_repeat_skips_engine(engine_parts, rng):
    """Acceptance criterion: a repeat query is answered WITHOUT invoking
    the engine (call-count spy), and bit-identically."""
    server = make_server(engine_parts, batch_size=2)
    calls = spy_on(server.engine)
    tok, msk, loc = make_requests(rng, 2, server.engine.cfg)
    ids1, sc1 = server.serve_all(tok, msk, loc)
    assert len(calls) == 1
    ids2, sc2 = server.serve_all(tok, msk, loc)       # exact repeats
    assert len(calls) == 1                            # engine NOT invoked
    assert server.stats.exact_hits == 2
    assert np.array_equal(ids1, ids2) and np.array_equal(sc1, sc2)


def test_metrics_expose_raw_hit_counts(engine_parts, rng):
    """metrics() reports the RAW exact-LRU and near-duplicate hit
    counters beside the rates, consistent with each other — the numbers
    drivers print without multiplying rates back up."""
    server = make_server(engine_parts, batch_size=1, near_cells=16)
    tok, msk, loc = make_requests(rng, 1, server.engine.cfg)
    loc[0] = [0.403, 0.519]
    server.serve_all(tok, msk, loc)                   # miss
    server.serve_all(tok, msk, loc)                   # exact hit
    near = loc.copy()
    near[0] += 0.002                                  # same 1/16 cell
    server.serve_all(tok, msk, near)                  # near hit
    m = server.metrics()
    assert m["exact_hits"] == 1 and m["near_hits"] == 1
    assert m["requests"] == 3
    assert m["exact_hit_rate"] == pytest.approx(m["exact_hits"] / 3)
    assert m["near_hit_rate"] == pytest.approx(m["near_hits"] / 3)
    assert m["hit_rate"] == pytest.approx(
        (m["exact_hits"] + m["near_hits"]) / 3)


def test_inflight_duplicates_coalesce(engine_parts, rng):
    """An identical request submitted before the first copy flushed shares
    its future instead of occupying a second batch slot."""
    server = make_server(engine_parts, batch_size=3, max_delay_ms=60_000.0)
    tok, msk, loc = make_requests(rng, 3, server.engine.cfg)

    async def go():
        dup = asyncio.ensure_future(server.submit(tok[0], msk[0], loc[0]))
        dup2 = asyncio.ensure_future(server.submit(tok[0], msk[0], loc[0]))
        rest = [asyncio.ensure_future(server.submit(tok[i], msk[i], loc[i]))
                for i in (1, 2)]
        return await asyncio.gather(dup, dup2, *rest)

    out = asyncio.run(go())
    assert server.stats.coalesced == 1
    assert server.stats.engine_queries == 3           # 3 unique rows only
    assert server.stats.flushes["size"] == 1          # coalesce didn't block
    assert np.array_equal(out[0][0], out[1][0])
    assert np.array_equal(out[0][1], out[1][1])


def test_near_duplicate_tier(engine_parts, rng):
    """Same keyword signature + same spatial cell → near-tier hit; a
    different cell misses. The tier is opt-in (near_cells > 0)."""
    server = make_server(engine_parts, batch_size=1, near_cells=16)
    calls = spy_on(server.engine)
    tok, msk, loc = make_requests(rng, 1, server.engine.cfg)
    loc[0] = [0.403, 0.519]
    server.serve_all(tok, msk, loc)
    assert len(calls) == 1
    near = loc.copy()
    near[0] += 0.002                                  # same 1/16 cell
    server.serve_all(tok, msk, near)
    assert len(calls) == 1 and server.stats.near_hits == 1
    far = loc.copy()
    far[0] = [0.91, 0.08]                             # different cell
    server.serve_all(tok, msk, far)
    assert len(calls) == 2 and server.stats.near_hits == 1


def test_exact_lru_evicts(engine_parts, rng):
    server = make_server(engine_parts, batch_size=1, cache_size=2)
    tok, msk, loc = make_requests(rng, 3, server.engine.cfg)
    for i in range(3):                                # fills + evicts row 0
        server.serve_all(tok[i:i + 1], msk[i:i + 1], loc[i:i + 1])
    calls = spy_on(server.engine)
    server.serve_all(tok[0:1], msk[0:1], loc[0:1])    # evicted → recompute
    assert len(calls) == 1
    server.serve_all(tok[2:3], msk[2:3], loc[2:3])    # still resident
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# Invalidation on index mutation
# ---------------------------------------------------------------------------


def test_insert_invalidates_and_stays_bit_identical(engine_parts, rng):
    """Acceptance criterion: after insert_objects the cached answer is
    dropped, the engine is re-invoked, and the fresh answer is
    bit-identical to a direct engine call on the mutated buffers."""
    cfg = engine_parts[0]
    server = make_server(engine_parts, batch_size=2)
    calls = spy_on(server.engine)
    tok, msk, loc = make_requests(rng, 2, server.engine.cfg)
    server.serve_all(tok, msk, loc)
    assert len(calls) == 1

    new_emb = rng.normal(size=(5, cfg.d_model)).astype(np.float32)
    new_loc = rng.uniform(size=(5, 2)).astype(np.float32)
    new_ids = np.arange(1000, 1005)
    server.insert_objects(jnp.asarray(new_emb), jnp.asarray(new_loc),
                          new_ids)
    assert server.stats.invalidations == 1

    ids_s, sc_s = server.serve_all(tok, msk, loc)
    assert len(calls) == 2                            # cache was dropped
    # a fresh engine over the PUBLISHED snapshot is the oracle
    eng2 = engine_lib.QueryEngine.from_snapshot(server.engine.snapshot,
                                                backend="dense")
    ids_d, sc_d = direct(eng2, tok, msk, loc, batch=2)
    assert np.array_equal(ids_s, ids_d)
    assert np.array_equal(sc_s, sc_d)
    # and every served id is live: resident in the buffers or (pre-
    # compaction) in the published snapshot's delta segment
    snap_pub = server.engine.snapshot
    live = set(np.asarray(snap_pub.buffers["ids"]).ravel().tolist())
    if snap_pub.delta is not None:
        live |= snap_pub.delta.ids_live
    assert set(np.unique(ids_s)) <= live


def test_delete_invalidates(engine_parts, rng):
    server = make_server(engine_parts, batch_size=1)
    calls = spy_on(server.engine)
    tok, msk, loc = make_requests(rng, 1, server.engine.cfg)
    ids1, _ = server.serve_all(tok, msk, loc)
    victims = [int(i) for i in ids1[0] if i >= 0][:2]
    server.delete_objects(victims)
    ids2, _ = server.serve_all(tok, msk, loc)
    assert len(calls) == 2                            # recomputed
    assert not set(victims) & set(ids2[0].tolist())   # victims gone


def test_inflight_key_is_versioned_across_publish(engine_parts, rng):
    """Regression (the bug this PR fixes): the in-flight coalescing key
    used to ignore the snapshot version, so a request arriving just
    after a publish could coalesce onto a PRE-publish future and be
    served an answer from the old index generation. Plant a resolved
    future under the old version's key, publish, submit the identical
    request: it must NOT coalesce — a fresh engine answer comes back."""
    cfg = engine_parts[0]
    server = make_server(engine_parts, batch_size=1)
    tok, msk, loc = make_requests(rng, 1, server.engine.cfg)

    async def go():
        server._adopt_loop(asyncio.get_running_loop())
        ver0 = server.engine.snapshot.meta.version
        ekey = server_lib.exact_key(
            np.ascontiguousarray(tok[0]), np.ascontiguousarray(msk[0]),
            np.ascontiguousarray(loc[0]), server.cfg.k, server.cfg.cr)
        stale = asyncio.get_running_loop().create_future()
        stale.set_result(("stale-ids", "stale-scores"))
        server._inflight[(ver0, ekey)] = stale    # pre-publish in-flight
        server.insert_objects(                    # publish: version + 1
            jnp.asarray(rng.normal(size=(2, cfg.d_model)), jnp.float32),
            jnp.asarray(rng.uniform(size=(2, 2)), jnp.float32),
            np.arange(4000, 4002))
        return await server.submit(tok[0], msk[0], loc[0])

    ids, scores = asyncio.run(go())
    assert server.stats.coalesced == 0            # did NOT share the future
    assert isinstance(ids, np.ndarray)            # fresh answer, not planted
    eng2 = engine_lib.QueryEngine.from_snapshot(server.engine.snapshot,
                                                backend="dense")
    ids_d, sc_d = direct(eng2, tok, msk, loc, batch=1)
    assert np.array_equal(ids, ids_d[0]) and np.array_equal(scores, sc_d[0])


# ---------------------------------------------------------------------------
# The LSM write path: delta accumulation, compaction triggers
# ---------------------------------------------------------------------------


def test_delta_write_path_accumulates_then_compacts(engine_parts, rng):
    """Writes below ``delta_threshold`` accumulate in the delta (buffers
    untouched — O(batch)); the write that crosses it compacts inline
    (no running loop) and folds everything into the §4.3 clusters."""
    cfg = engine_parts[0]
    server = make_server(engine_parts, delta_threshold=8)
    snap0 = server.engine.snapshot

    def rows(n):
        return (jnp.asarray(rng.normal(size=(n, cfg.d_model)), jnp.float32),
                jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32))

    emb, loc = rows(5)
    snap1 = server.insert_objects(emb, loc, np.arange(3000, 3005))
    assert snap1.meta.delta_rows == 5
    assert np.array_equal(np.asarray(snap1.buffers["ids"]),
                          np.asarray(snap0.buffers["ids"]))  # base untouched
    victims = np.asarray(snap0.buffers["ids"])[0, :2].tolist()
    snap2 = server.delete_objects(victims)
    assert snap2.meta.n_tombstones == 2 and server.stats.compactions == 0

    emb, loc = rows(1)                 # 5 rows + 2 tombstones + 1 = 8
    snap3 = server.insert_objects(emb, loc, np.array([3005]))
    assert server.stats.compactions == 1
    assert server.stats.compaction_triggers["size"] == 1
    assert snap3.delta is None and snap3.meta.delta_rows == 0
    ids = np.asarray(snap3.buffers["ids"])
    assert ((ids >= 3000) & (ids <= 3005)).sum() == 6   # folded into base
    assert not np.isin(ids, victims).any()
    assert server.stats.writes == 3


def test_compaction_defers_to_loop_tick(engine_parts, rng):
    """With an event loop running, the threshold-crossing write returns
    with the delta still attached; the fold lands on the next loop tick
    (between flushes), never inside the write call."""
    cfg = engine_parts[0]
    server = make_server(engine_parts, delta_threshold=4)

    async def go():
        server._adopt_loop(asyncio.get_running_loop())
        emb = jnp.asarray(rng.normal(size=(4, cfg.d_model)), jnp.float32)
        loc = jnp.asarray(rng.uniform(size=(4, 2)), jnp.float32)
        snap = server.insert_objects(emb, loc, np.arange(3100, 3104))
        assert snap.meta.delta_rows == 4          # not folded in-call
        assert server.stats.compactions == 0
        await asyncio.sleep(0)                    # one tick
        assert server.engine.snapshot.delta is None

    asyncio.run(go())
    assert server.stats.compactions == 1
    assert (np.asarray(server.engine.snapshot.buffers["ids"]) >= 3100
            ).sum() == 4


def test_imbalance_trigger_compacts(engine_parts, rng):
    """``max_imbalance``: tombstoning most of every cluster but one
    skews the LIVE sizes past the bound and triggers the fold even
    though the delta is nowhere near ``delta_threshold``."""
    server = make_server(engine_parts, delta_threshold=10 ** 6,
                        max_imbalance=1.5)
    ids = np.asarray(server.engine.snapshot.buffers["ids"])
    counts = np.asarray(server.engine.snapshot.buffers["counts"])
    keep = int(counts.argmax())
    victims = [int(i) for c in range(ids.shape[0]) if c != keep
               for i in ids[c][ids[c] >= 0][2:]]   # leave 2 per other cluster
    server.delete_objects(victims)
    assert server.stats.compactions == 1
    assert server.stats.compaction_triggers["imbalance"] == 1
    snap = server.engine.snapshot
    assert snap.delta is None
    assert not np.isin(np.asarray(snap.buffers["ids"]), victims).any()


def test_eager_path_when_delta_disabled(engine_parts, rng):
    """``delta_threshold=0``: the legacy eager fold — every write goes
    straight through index.insert/delete_objects into the buffers."""
    cfg = engine_parts[0]
    server = make_server(engine_parts, delta_threshold=0)
    emb = jnp.asarray(rng.normal(size=(3, cfg.d_model)), jnp.float32)
    loc = jnp.asarray(rng.uniform(size=(3, 2)), jnp.float32)
    snap = server.insert_objects(emb, loc, np.arange(3200, 3203))
    assert snap.delta is None and snap.meta.delta_rows == 0
    assert (np.asarray(snap.buffers["ids"]) >= 3200).sum() == 3
    snap2 = server.delete_objects([3200])
    assert not (np.asarray(snap2.buffers["ids"]) == 3200).any()
    assert server.stats.compactions == 0          # nothing to fold
    assert server.stats.writes == 2


def test_stale_loop_state_is_dropped(engine_parts, rng):
    """An aborted asyncio.run (flush raised mid-batch) must not poison
    the next run on a fresh loop: stale pending/timer/inflight state is
    dropped on loop change and serving proceeds normally."""
    server = make_server(engine_parts, batch_size=2, max_delay_ms=25.0)
    tok, msk, loc = make_requests(rng, 3, server.engine.cfg)
    orig = server.engine.query
    server.engine.query = lambda *a, **kw: (_ for _ in ()).throw(
        RuntimeError("engine down"))

    async def aborted():
        # one queued request, then the flush blows up
        t = asyncio.ensure_future(server.submit(tok[0], msk[0], loc[0]))
        await asyncio.sleep(0)
        server.flush_now()
        await t

    with pytest.raises(RuntimeError):
        asyncio.run(aborted())
    server._pending.append("stale-sentinel")      # simulate an abort that
    server.engine.query = orig                    # left a queued request
    ids_s, sc_s = server.serve_all(tok, msk, loc)     # fresh loop: works
    assert server.n_pending == 0
    ids_d, sc_d = direct(make_engine(engine_parts), tok, msk, loc, batch=2)
    assert np.array_equal(ids_s, ids_d) and np.array_equal(sc_s, sc_d)


def test_results_are_frozen(engine_parts, rng):
    """Cached result arrays are read-only: a caller mutating its answer
    cannot corrupt what later cache hits are served."""
    server = make_server(engine_parts, batch_size=1)
    tok, msk, loc = make_requests(rng, 1, server.engine.cfg)

    async def go():
        return await server.submit(tok[0], msk[0], loc[0])

    ids1, sc1 = asyncio.run(go())
    with pytest.raises(ValueError):
        ids1[0] = -7
    ids2, _ = asyncio.run(go())                   # exact hit, unpolluted
    assert np.array_equal(ids1, ids2)


def test_cli_backend_alias():
    from repro.core.engine import resolve_cli_backend
    with pytest.warns(DeprecationWarning, match="deprecated"):
        assert resolve_cli_backend(None, True) == "pallas"
    with pytest.warns(DeprecationWarning, match="ignored"):
        assert resolve_cli_backend("dense", True) == "dense"
    assert resolve_cli_backend(None, False) == "auto"
    assert resolve_cli_backend("pallas", False) == "pallas"


# ---------------------------------------------------------------------------
# Failure isolation + drain (DESIGN.md §14; chaos tier in
# tests/test_resilience_serving.py)
# ---------------------------------------------------------------------------


def test_poisoned_request_fails_alone(engine_parts, rng):
    """Regression for the batch-poisoning bug: one request whose scoring
    raises must NOT fail its co-batched neighbors. The flush bisects —
    healthy requests get their (bit-identical) answers, the poisoned one
    alone sees the exception, and the server keeps serving afterwards."""
    server = make_server(engine_parts, retry_backoff_ms=0.0)
    tok, msk, loc = make_requests(rng, 4, server.engine.cfg)
    poison = tok[1]
    orig = server.engine.query

    def flaky(t, m, l, **kw):
        if (np.asarray(t) == poison).all(axis=1).any():
            raise RuntimeError("poisoned row")
        return orig(t, m, l, **kw)

    server.engine.query = flaky

    async def go():
        tasks = [asyncio.ensure_future(server.submit(tok[i], msk[i],
                                                     loc[i]))
                 for i in range(4)]
        return await asyncio.gather(*tasks, return_exceptions=True)

    out = asyncio.run(go())
    assert isinstance(out[1], RuntimeError)           # the poison, alone
    server.engine.query = orig
    ids_d, sc_d = direct(make_engine(engine_parts), tok, msk, loc)
    for i in (0, 2, 3):                               # healthy neighbors
        assert np.array_equal(out[i][0], ids_d[i])
        assert np.array_equal(out[i][1], sc_d[i])
    assert server.stats.poisoned_requests == 1
    assert server.stats.flush_retries >= 1            # bisection ran
    # the server is healthy afterwards: a fresh batch serves normally
    ids_s, sc_s = server.serve_all(tok, msk, loc)
    assert np.array_equal(ids_s, ids_d) and np.array_equal(sc_s, sc_d)


def test_drain_under_load_with_pending_compaction(engine_parts, rng):
    """Shutdown/drain while a deadline timer is armed AND a compaction
    callback is queued: no deadlock, no dropped request — every queued
    submit resolves and the compaction still runs on its loop tick."""
    cfg = engine_parts[0]
    server = make_server(engine_parts, max_delay_ms=60_000.0,
                         delta_threshold=4, request_timeout_ms=10_000.0)
    tok, msk, loc = make_requests(rng, 6, cfg)

    async def go():
        tasks = [asyncio.ensure_future(server.submit(tok[i], msk[i],
                                                     loc[i]))
                 for i in range(6)]
        await asyncio.sleep(0)       # size flush of 4; 2 queued on timer
        emb = rng.normal(size=(4, cfg.d_model)).astype(np.float32)
        pts = rng.uniform(size=(4, 2)).astype(np.float32)
        server.insert_objects(emb, pts, np.arange(4000, 4004))
        assert server._compaction_handle is not None  # queued, not run
        return await server._drain(tasks)

    out = asyncio.run(go())
    assert len(out) == 6 and all(o is not None for o in out)
    assert server.n_pending == 0
    assert server.stats.shed == {"expired": 0, "queue_full": 0,
                                 "cancelled": 0}
    assert server.stats.compactions == 1
    assert server.engine.snapshot.delta is None


def test_cancelled_request_frees_its_slot(engine_parts, rng):
    """A submit whose awaiter was cancelled must not hold a batch seat:
    the flush drops it (counted as shed) and scores the live requests."""
    server = make_server(engine_parts, batch_size=8, max_delay_ms=60_000.0)
    tok, msk, loc = make_requests(rng, 3, server.engine.cfg)

    async def go():
        tasks = [asyncio.ensure_future(server.submit(tok[i], msk[i],
                                                     loc[i]))
                 for i in range(3)]
        await asyncio.sleep(0)
        tasks[1].cancel()
        await asyncio.sleep(0)
        server.flush_now()
        return await asyncio.gather(*tasks, return_exceptions=True)

    out = asyncio.run(go())
    assert isinstance(out[1], asyncio.CancelledError)
    assert server.stats.shed["cancelled"] == 1
    assert server.stats.engine_queries == 2           # live rows only
    ids_d, sc_d = direct(make_engine(engine_parts), tok, msk, loc, batch=8)
    for i in (0, 2):
        assert np.array_equal(out[i][0], ids_d[i])
        assert np.array_equal(out[i][1], sc_d[i])


# ---------------------------------------------------------------------------
# Warm-up manager
# ---------------------------------------------------------------------------


def test_warmup_pretraces_the_flush_plan(engine_parts, rng):
    server = make_server(engine_parts)
    compiles = server.warmup()
    assert compiles == {"dense@4": pytest.approx(compiles["dense@4"])}
    assert compiles["dense@4"] > 0
    plans_after_warmup = set(server.engine._plans)
    # key = (batch, k, cr, backend, precision, filtered)
    assert (4, 5, 2, "dense", "f32", False) in plans_after_warmup
    tok, msk, loc = make_requests(rng, 4, server.engine.cfg)
    server.serve_all(tok, msk, loc)
    # serving created no new plan: the warm-up traced the real flush path
    assert set(server.engine._plans) == plans_after_warmup
