"""Delta-segment mutation tier (core/delta.py + engine merge, DESIGN.md §11).

Covers the acceptance criteria of the LSM-style write path:

* ``DeltaSegment`` is an immutable value type: insert is O(batch) with
  structural sharing, delete tombstones the base and physically drops
  delta rows, duplicate/invalid ids are refused;
* queries over a snapshot carrying a delta see exactly the live set —
  inserted rows surface, deleted ids never do (tombstones filter the
  base with k over-fetch so no live row is lost);
* compaction parity: a snapshot queried through delta + tombstones
  returns the SAME results as the compacted snapshot — ids bit-equal on
  every tier; scores agree to float-reassociation tolerance (the delta
  scan reduces over a different candidate-axis length than the gathered
  buffers, so XLA's reduction blocking may differ by ~1 ulp);
* a hypothesis property test interleaves insert/delete/query against a
  brute-force oracle over the live stored rows, across all 3 precision
  tiers.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import delta as delta_lib
from repro.core import engine as engine_lib
from repro.core import index as il
from repro.core import relevance
from repro.core.delta import DeltaSegment
from repro.core.snapshot import IndexSnapshot

DIST_MAX = 1.414
D = 32                          # d_model of the fixture snapshot


# ---------------------------------------------------------------------------
# Fixture: a tiny built snapshot (random params — the mutation layer is
# quality-agnostic), plus per-precision derivatives
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def snap():
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=D, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(13)
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap = 96, cfg.n_clusters, 64        # headroom for compaction
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(5), cfg.d_model, c,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap)
    return IndexSnapshot.from_parts(cfg, params, iparams, norm, buf,
                                    dist_max=DIST_MAX)


_TIERS = {}


def snap_at(snap, precision):
    """The fixture snapshot at a precision tier (memoized per module)."""
    if precision not in _TIERS:
        _TIERS[precision] = (snap if precision == "f32"
                             else snap.with_precision(precision))
    return _TIERS[precision]


_ENGINES = {}


def engine_at(snap, precision):
    """One dense engine per tier — plans persist across tests/examples;
    the pinned snapshot is always passed explicitly to query()."""
    if precision not in _ENGINES:
        _ENGINES[precision] = engine_lib.QueryEngine.from_snapshot(
            snap_at(snap, precision), backend="dense")
    return _ENGINES[precision]


def make_requests(rng, n, cfg):
    tok = rng.integers(2, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones((n, cfg.max_len), bool)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    return tok, msk, loc


def rows_for(ids, d=D):
    """Deterministic f32 rows per id — reproducible across processes."""
    ids = np.asarray(ids).reshape(-1)
    emb = np.stack([np.random.default_rng(10_000 + int(i))
                    .normal(size=d).astype(np.float32) for i in ids])
    loc = np.stack([np.random.default_rng(20_000 + int(i))
                    .uniform(size=2).astype(np.float32) for i in ids])
    return emb, loc


# ---------------------------------------------------------------------------
# DeltaSegment value-type contract
# ---------------------------------------------------------------------------


def test_empty_segment():
    seg = DeltaSegment.empty(D)
    assert seg.is_empty and seg.n_rows == 0 and seg.n_tombstones == 0
    arrs = seg.arrays()
    assert arrs["emb"].shape == (0, D) and arrs["ids"].shape == (0,)
    assert seg.tombstone_array().dtype == np.int64
    with pytest.raises(ValueError, match="precision"):
        DeltaSegment.empty(D, "fp4")


def test_insert_shares_prior_chunks():
    """O(batch) contract: appending must not copy or touch prior chunks."""
    emb, loc = rows_for([100, 101])
    seg1 = DeltaSegment.empty(D).insert(emb, loc, [100, 101])
    emb2, loc2 = rows_for([102])
    seg2 = seg1.insert(emb2, loc2, [102])
    assert seg2.chunks[0] is seg1.chunks[0]          # shared, not copied
    assert seg1.n_rows == 2 and seg2.n_rows == 3     # predecessor untouched
    assert seg2.ids_live == frozenset({100, 101, 102})


def test_insert_refuses_bad_batches():
    emb, loc = rows_for([100, 101])
    seg = DeltaSegment.empty(D).insert(emb, loc, [100, 101])
    with pytest.raises(ValueError, match="duplicate"):
        seg.insert(*rows_for([101]), [101])          # delta-resident dup
    with pytest.raises(ValueError, match="duplicate"):
        seg.insert(*rows_for([5, 5]), [5, 5])        # within-batch dup
    with pytest.raises(ValueError, match="non-negative"):
        seg.insert(*rows_for([7]), [-1])
    with pytest.raises(ValueError, match="disagree"):
        seg.insert(emb, loc[:1], [200, 201])


def test_delete_drops_delta_rows_and_tombstones_base():
    emb, loc = rows_for([100, 101, 102])
    seg = DeltaSegment.empty(D).insert(emb, loc, [100, 101, 102])
    seg2 = seg.delete([101, 777])                    # one resident, one base
    assert seg2.n_rows == 2                          # row physically gone
    assert 101 not in seg2.ids_live
    assert set(seg2.arrays()["ids"].tolist()) == {100, 102}
    assert seg2.tombstones == frozenset({101, 777})
    assert seg.n_rows == 3                           # predecessor untouched


def test_reinsert_after_delete():
    """delete frees the id: re-inserting it must succeed, and the fresh
    row is live even though the tombstone (for the base) remains."""
    emb, loc = rows_for([100])
    seg = DeltaSegment.empty(D).insert(emb, loc, [100]).delete([100])
    assert seg.n_rows == 0 and 100 in seg.tombstones
    emb2, loc2 = rows_for([100])
    seg2 = seg.insert(emb2, loc2, [100])
    assert seg2.n_rows == 1 and 100 in seg2.ids_live
    assert 100 in seg2.tombstones                    # still kills base rows


@pytest.mark.parametrize("precision", il.PRECISIONS)
def test_leaves_roundtrip(precision):
    emb, loc = rows_for([100, 101, 102])
    seg = (DeltaSegment.empty(D, precision)
           .insert(emb, loc, [100, 101, 102]).delete([101, 55]))
    back = DeltaSegment.from_leaves(D, precision, seg.to_leaves())
    assert back.tombstones == seg.tombstones
    assert back.ids_live == seg.ids_live
    for f in delta_lib.FIELDS:
        assert np.array_equal(np.asarray(back.arrays()[f]),
                              np.asarray(seg.arrays()[f])), f


def test_quantized_rows_match_buffer_quantization():
    """A delta row must carry the SAME stored bytes the compacted buffer
    will: quantize_rows on the way in, raw f32 kept for requantization."""
    emb, loc = rows_for([100, 101])
    seg = DeltaSegment.empty(D, "int8").insert(emb, loc, [100, 101])
    q, scale = il.quantize_rows(emb, "int8")
    arrs = seg.arrays()
    assert arrs["emb"].dtype == np.int8
    assert np.array_equal(arrs["emb"], q)
    assert np.array_equal(arrs["scale"], scale)
    assert np.array_equal(arrs["raw"], emb)          # exact source retained


def test_live_counts_subtracts_resident_tombstones(snap):
    buf = snap.buffers
    base = delta_lib.live_counts(buf, None)
    assert np.array_equal(base, np.asarray(buf["counts"]))
    victims = np.asarray(buf["ids"])[0, :3].tolist()
    seg = DeltaSegment.empty(D).delete(victims + [999_999])  # one unknown
    after = delta_lib.live_counts(buf, seg)
    want = base.copy()
    want[0] -= 3                                     # unknown id: no effect
    assert np.array_equal(after, want)


# ---------------------------------------------------------------------------
# merge_delta semantics
# ---------------------------------------------------------------------------


def test_merge_delta_tombstones_filter_base_only():
    base_i = np.array([[5, 3, 9]])
    base_v = np.array([[3.0, 2.0, 1.0]], np.float32)
    delta_i = np.array([[3, -1]])                    # id 3 re-inserted
    delta_v = np.array([[2.5, engine_lib.NEG_INF]], np.float32)
    ids, sc = engine_lib.merge_delta(base_i, base_v, delta_i, delta_v,
                                     tombstones=np.array([3]), k=3)
    # base's 3 is tombstoned out; delta's 3 (fresh row) survives
    assert ids.tolist() == [[5, 3, 9]]
    assert sc.tolist() == [[3.0, 2.5, 1.0]]


def test_merge_delta_base_wins_ties_and_trims_to_k():
    base_i = np.array([[1, 2, 3, 4]])
    base_v = np.array([[4.0, 3.0, 2.0, 1.0]], np.float32)
    delta_i = np.array([[7]])
    delta_v = np.array([[3.0]], np.float32)          # exact tie with id 2
    ids, sc = engine_lib.merge_delta(base_i, base_v, delta_i, delta_v, k=3)
    assert ids.shape == (1, 3)                       # over-fetch trimmed
    assert ids.tolist() == [[1, 2, 7]]               # base entry first on tie
    assert sc.tolist() == [[4.0, 3.0, 3.0]]


# ---------------------------------------------------------------------------
# Engine queries through a delta-carrying snapshot
# ---------------------------------------------------------------------------


def test_delta_rows_visible_without_routing(snap, rng):
    """A freshly inserted row can NEVER be hidden by a routing miss: the
    delta is scanned unrouted, so it surfaces even at cr=1."""
    emb, loc = rows_for([9000, 9001])
    seg = DeltaSegment.empty(D).insert(emb, loc, [9000, 9001])
    snap_d = snap.with_delta(seg)
    eng = engine_at(snap, "f32")
    tok, msk, loc_q = make_requests(rng, 6, snap.cfg)
    k_all = snap.buffers["capacity"]          # the whole cr=1 pool
    ids, sc = eng.query(tok, msk, loc_q, k=k_all, cr=1, batch=4,
                        snapshot=snap_d)
    assert (ids == 9000).any() and (ids == 9001).any()
    # scores stay descending through the host merge
    assert (np.diff(sc, axis=-1) <= 0).all()


def test_delta_free_path_unchanged(snap, rng):
    """A compacted / delta-free snapshot takes the exact fast path: the
    results are byte-identical to an engine that never heard of deltas."""
    eng = engine_at(snap, "f32")
    tok, msk, loc_q = make_requests(rng, 6, snap.cfg)
    ids_a, sc_a = eng.query(tok, msk, loc_q, k=5, cr=2, batch=4,
                            snapshot=snap)
    snap_e = snap.with_delta(DeltaSegment.empty(D))   # empty delta attached
    ids_b, sc_b = eng.query(tok, msk, loc_q, k=5, cr=2, batch=4,
                            snapshot=snap_e)
    assert np.array_equal(ids_a, ids_b) and np.array_equal(sc_a, sc_b)


@pytest.mark.parametrize("precision", il.PRECISIONS)
def test_compaction_parity(snap, rng, precision):
    """THE acceptance criterion: delta + tombstones merged at query time
    vs the compacted snapshot — ids bit-equal on every tier; scores
    equal to reassociation tolerance (the stored row bytes are identical
    pre/post compaction — test_quantized_rows_match_buffer_quantization
    — but the scan and the gathered buffers reduce over different
    candidate-axis lengths). Victims are taken from the live top-k so
    the tombstone over-fetch (not luck) is what preserves parity."""
    snap_p = snap_at(snap, precision)
    eng = engine_at(snap, precision)
    tok, msk, loc_q = make_requests(rng, 10, snap.cfg)
    c = snap.cfg.n_clusters
    k = 10

    ids0, _ = eng.query(tok, msk, loc_q, k=k, cr=c, batch=4,
                        snapshot=snap_p)
    victims = np.unique(ids0[ids0 >= 0])[:40].tolist()  # top-ranked rows

    new_ids = list(range(9100, 9130))
    emb, loc = rows_for(new_ids)
    seg = (DeltaSegment.empty(D, precision)
           .insert(emb, loc, new_ids)
           .delete(victims + new_ids[:5]))          # base AND delta victims
    snap_d = snap_p.with_delta(seg)
    snap_c = snap_d.compact()
    assert snap_c.delta is None
    assert snap_c.meta.version == snap_d.meta.version + 1
    assert snap_c.meta.precision == precision

    # cr=c: routing covers every cluster, so parity is about the merge,
    # not about where compaction happened to place the rows
    ids_d, sc_d = eng.query(tok, msk, loc_q, k=k, cr=c, batch=4,
                            snapshot=snap_d)
    ids_c, sc_c = eng.query(tok, msk, loc_q, k=k, cr=c, batch=4,
                            snapshot=snap_c)
    assert np.array_equal(ids_d, ids_c)
    assert np.allclose(sc_d, sc_c, atol=1e-5, rtol=1e-6)
    assert not np.isin(ids_d, victims).any()        # victims truly gone
    assert (ids_d >= 9100).any()                    # survivors retrievable


# ---------------------------------------------------------------------------
# Property test: interleaved mutations vs a brute-force oracle
# ---------------------------------------------------------------------------


def _check_interleaved(snap, precision, ops, *, k=8):
    """Run a mutation log op-by-op, querying after EVERY op: returned
    ids must be live, deleted ids must never resurface, and the answer
    must match the ORACLE — the same queries against the fully-rebuilt
    (compacted) index through the same engine plans. ids bit-equal;
    scores to reassociation tolerance (the delta scan and the buffers
    reduce over different candidate-axis lengths).

    ``ops`` entries: ("insert", n) appends n fresh ids; ("delete", x)
    deletes the (x mod live)-th smallest live id.
    """
    snap_p = snap_at(snap, precision)
    eng = engine_at(snap, precision)
    cfg = snap.cfg
    qrng = np.random.default_rng(31)
    tok, msk, loc_q = make_requests(qrng, 4, cfg)
    base_ids = np.asarray(snap_p.buffers["ids"])
    seg = DeltaSegment.empty(D, precision)
    live = set(int(i) for i in base_ids[base_ids >= 0])
    deleted = set()
    next_id = 50_000
    for op, arg in ops:
        if op == "insert":
            ids = list(range(next_id, next_id + arg))
            next_id += arg
            emb, loc = rows_for(ids)
            seg = seg.insert(emb, loc, ids)
            live |= set(ids)
            deleted -= set(ids)
        elif live:
            victim = sorted(live)[arg % len(live)]
            seg = seg.delete([victim])
            live.discard(victim)
            deleted.add(victim)
        snap_d = snap_p.with_delta(seg)
        ids_s, sc_s = eng.query(tok, msk, loc_q, k=k, cr=cfg.n_clusters,
                                batch=4, snapshot=snap_d)
        returned = set(int(i) for i in ids_s[ids_s >= 0])
        assert returned <= live                      # only live ids
        assert not returned & deleted                # no resurrections
        snap_c = snap_d.compact()
        ids_c = np.asarray(snap_c.buffers["ids"])
        assert set(int(i) for i in ids_c[ids_c >= 0]) == live
        want_i, want_s = eng.query(tok, msk, loc_q, k=k,
                                   cr=cfg.n_clusters, batch=4,
                                   snapshot=snap_c)
        assert np.array_equal(ids_s, want_i)
        assert np.allclose(sc_s, want_s, atol=1e-5, rtol=1e-6)


# hand-picked interleavings exercising every transition: delete of base
# rows, delete straight after insert, insert after delete (id reuse is
# separate — ids here are fresh), long insert runs, delete-only prefixes
_FIXED_LOGS = [
    [("insert", 3), ("delete", 5), ("insert", 2), ("delete", 0),
     ("insert", 1), ("delete", 97)],
    [("delete", 7), ("delete", 7), ("insert", 4), ("delete", 2)],
    [("insert", 4), ("insert", 4), ("delete", 123456), ("delete", 3),
     ("delete", 11), ("insert", 2)],
]


@pytest.mark.parametrize("precision", il.PRECISIONS)
@pytest.mark.parametrize("log", range(len(_FIXED_LOGS)))
def test_interleaved_mutations_fixed_logs(snap, precision, log):
    """The oracle check on fixed mutation logs — always runs, so the
    write path has deterministic coverage even where hypothesis is
    unavailable."""
    _check_interleaved(snap, precision, _FIXED_LOGS[log])


@pytest.mark.parametrize("precision", il.PRECISIONS)
def test_interleaved_mutations_match_oracle(snap, precision):
    """Satellite acceptance: ANY interleaving of inserts and deletes,
    queried mid-stream, serves exactly the live set (hypothesis explores
    the op space; _FIXED_LOGS above keeps deterministic coverage when
    hypothesis is absent)."""
    hypothesis = pytest.importorskip("hypothesis")
    st_ = hypothesis.strategies

    ops_strategy = st_.lists(
        st_.one_of(
            st_.tuples(st_.just("insert"), st_.integers(1, 4)),
            st_.tuples(st_.just("delete"), st_.integers(0, 10 ** 6))),
        min_size=1, max_size=5)

    @hypothesis.settings(max_examples=10, deadline=None)
    @hypothesis.given(ops=ops_strategy)
    def run(ops):
        _check_interleaved(snap, precision, ops)

    run()
