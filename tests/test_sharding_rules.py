"""Direct unit tier for distributed/sharding.py.

The logical→physical machinery was previously only exercised indirectly
(through the training launcher and, now, mesh-sharded serving). This
tier pins its contracts on their own:

* ``rules_for_mesh`` binds dp/tp/cluster logical axes per mesh shape;
* ``logical_spec`` maps logical names under the bound rules (multi-axis
  dp collapses to a tuple entry, singletons to a bare name, and outside
  any binding it is None so model code stays mesh-agnostic);
* the ``param_specs`` divisibility guard WARNS and replicates a dim the
  mesh axes don't divide — never mis-shards, never silently;
* ``named_shardings`` maps a spec pytree (including None leaves) to
  NamedShardings on the mesh;
* ``shard_cluster_buffers`` places whole clusters per shard with
  bit-identical rows, per-shard sentinel empty clusters, device-committed
  parts, and validates explicit assignments (DESIGN.md §12).

Runs multi-device on CPU via the conftest-set
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
import numpy as np
import jax
import pytest
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import index as il
from repro.distributed import sharding as sh


def make_mesh(shape, names):
    devs = jax.devices()
    n = int(np.prod(shape))
    if len(devs) < n:
        pytest.skip(f"needs {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(shape), names)


# ---------------------------------------------------------------------------
# rules_for_mesh / logical_spec
# ---------------------------------------------------------------------------


def test_rules_for_mesh_single_pod():
    mesh = make_mesh((2, 4), ("data", "model"))
    rules = sh.rules_for_mesh(mesh)
    assert rules["dp"] == ("data",)
    assert rules["tp"] == ("model",)
    assert rules["cluster"] == ()
    assert rules["all"] == ("data", "model")
    assert rules["_sizes"] == {"data": 2, "model": 4}


def test_rules_for_mesh_multi_pod_dp_spans_axes():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rules = sh.rules_for_mesh(mesh)
    assert rules["dp"] == ("pod", "data")
    assert rules["tp"] == ("model",)


def test_rules_for_mesh_cluster_axis():
    mesh = sh.cluster_mesh(min(4, len(jax.devices())))
    rules = sh.rules_for_mesh(mesh)
    assert rules["cluster"] == (sh.CLUSTER_AXIS,)
    assert rules["dp"] == () and rules["tp"] == ()


def test_logical_spec_under_rules():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    with sh.axis_rules(sh.rules_for_mesh(mesh)):
        # multi-axis dp stays a tuple entry; singleton tp collapses
        assert sh.logical_spec("dp", None, "tp") == P(("pod", "data"),
                                                      None, "model")
        assert sh.logical_spec(None, "tp") == P(None, "model")
        # unknown logical name → replicated (empty tuple entry)
        assert sh.logical_spec("nope") == P(())


def test_logical_spec_is_none_outside_binding():
    assert sh.current_rules() is None
    assert sh.logical_spec("dp", "tp") is None
    # and constrain is a no-op, not an error
    x = np.ones((4, 4), np.float32)
    assert sh.constrain(x, "dp", "tp") is x


# ---------------------------------------------------------------------------
# param_specs divisibility guard
# ---------------------------------------------------------------------------


def test_param_specs_divisible_dim_shards():
    mesh = make_mesh((2,), ("model",))
    with sh.axis_rules(sh.rules_for_mesh(mesh)):
        specs = sh.param_specs({"tables": np.zeros((8, 4), np.float32)},
                               sh.REC_PARAM_RULES)
    assert specs["tables"] == P("model", None)


def test_param_specs_nondivisible_dim_warns_and_replicates():
    """The guard must SAY it dropped a sharding: a silently replicated
    dim looks identical to a sharded one until a device OOMs."""
    mesh = make_mesh((2,), ("model",))
    with sh.axis_rules(sh.rules_for_mesh(mesh)):
        with pytest.warns(UserWarning, match="not divisible"):
            specs = sh.param_specs({"tables": np.zeros((7, 4), np.float32)},
                                   sh.REC_PARAM_RULES)
    assert specs["tables"] == P(None, None)     # replicated, not mis-sharded


def test_param_specs_leading_scan_dims_padded():
    """Rules give specs for the TRAILING dims; stacked scan dims pad
    with None on the left."""
    mesh = make_mesh((2,), ("model",))
    with sh.axis_rules(sh.rules_for_mesh(mesh)):
        specs = sh.param_specs({"item_embed": np.zeros((3, 8, 4))},
                               sh.REC_PARAM_RULES)
    assert specs["item_embed"] == P(None, "model", None)


# ---------------------------------------------------------------------------
# named_shardings pytree mapping
# ---------------------------------------------------------------------------


def test_named_shardings_maps_pytree_with_none_leaves():
    mesh = make_mesh((2,), ("model",))
    tree = {"a": P("model", None), "b": None, "nested": {"c": P(None)}}
    out = sh.named_shardings(mesh, tree)
    assert all(isinstance(v, NamedSharding)
               for v in jax.tree.leaves(out))
    assert out["a"].spec == P("model", None)
    assert out["b"].spec == P()                 # None → fully replicated
    assert out["nested"]["c"].spec == P(None)
    assert out["a"].mesh.shape == {"model": 2}


# ---------------------------------------------------------------------------
# cluster meshes + shard_cluster_buffers
# ---------------------------------------------------------------------------


def _tiny_buffers(rng, c=6, cap=8, d=16):
    ids = np.full((c, cap), -1, np.int64)
    counts = rng.integers(1, cap + 1, size=c).astype(np.int64)
    for i, n in enumerate(counts):
        ids[i, :n] = rng.integers(0, 10_000, size=n)
    return {
        "emb": rng.normal(size=(c, cap, d)).astype(np.float32),
        "loc": rng.uniform(size=(c, cap, 2)).astype(np.float32),
        "ids": ids,
        "scale": np.ones((c, cap), np.float32),
        "counts": counts,
        "capacity": cap,
        "n_spilled": 0,
    }


def test_cluster_mesh_rejects_bad_counts():
    n_dev = len(jax.devices())
    with pytest.raises(ValueError, match="devices"):
        sh.cluster_mesh(0)
    with pytest.raises(ValueError, match="devices"):
        sh.cluster_mesh(n_dev + 1)


def test_cluster_mesh_requires_cluster_axis():
    mesh = make_mesh((2,), ("model",))
    with pytest.raises(ValueError, match=sh.CLUSTER_AXIS):
        sh._as_cluster_mesh(mesh)


def test_cluster_buffer_specs_shard_leading_axis():
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    mesh = sh.cluster_mesh(2)
    stacked = {"emb": np.zeros((4, 8, 16), np.float32),
               "loc": np.zeros((4, 8, 2), np.float32),
               "ids": np.zeros((4, 8), np.int32),
               "scale": np.zeros((4, 8), np.float32),
               "counts": np.zeros((4,), np.int32)}
    with sh.axis_rules(sh.rules_for_mesh(mesh)):
        specs = sh.cluster_buffer_specs(stacked)
    assert specs["emb"] == P(sh.CLUSTER_AXIS, None, None)
    assert specs["loc"] == P(sh.CLUSTER_AXIS, None, None)
    assert specs["ids"] == P(sh.CLUSTER_AXIS, None)
    assert specs["scale"] == P(sh.CLUSTER_AXIS, None)
    assert specs["counts"] == P(sh.CLUSTER_AXIS)


def test_shard_cluster_buffers_validates_assignment():
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    buf = _tiny_buffers(np.random.default_rng(0))
    with pytest.raises(ValueError, match="assignment shape"):
        sh.shard_cluster_buffers(buf, 2, assignment=np.zeros(3, np.int32))
    with pytest.raises(ValueError, match="must lie in"):
        sh.shard_cluster_buffers(buf, 2,
                                 assignment=np.full(6, 5, np.int32))


def test_shard_cluster_buffers_layout_and_commitment():
    """c=6 over 4 shards: blocks of 2, every real row bit-identical on
    its owning shard, sentinel + remainder rows empty (ids −1), each
    part committed to exactly its shard's device, and per-device bytes
    ≈ 1/n_shards of the whole."""
    n_shards = min(4, len(jax.devices()))
    if n_shards < 2:
        pytest.skip("needs 2+ devices")
    buf = _tiny_buffers(np.random.default_rng(1), c=6)
    shards = sh.shard_cluster_buffers(buf, n_shards)

    assert shards.n_shards == n_shards
    assert shards.c_global == 6
    per = -(-6 // n_shards)
    assert shards.c_local == per
    assert shards.sentinel == shards.c_local
    # every global cluster's rows, bit-for-bit, on its owning shard
    for g in range(6):
        s, r = int(shards.shard_of[g]), int(shards.local_of[g])
        part = shards.parts[s]
        for key in ("emb", "loc", "ids", "scale"):
            assert np.array_equal(np.asarray(part[key])[r], buf[key][g]), \
                (key, g)
        assert int(np.asarray(part["counts"])[r]) == int(buf["counts"][g])
    # sentinel (and any remainder padding) rows are EMPTY clusters
    for s, part in enumerate(shards.parts):
        ids = np.asarray(part["ids"])
        assert ids.shape[0] == shards.c_local + 1
        n_real = int(np.sum(shards.shard_of == s))
        assert (ids[n_real:] == -1).all()
        assert (np.asarray(part["loc"])[shards.sentinel] == il.PAD_LOC).all()
        # device commitment: the part lives on exactly its shard's device
        assert part["emb"].devices() == {shards.devices[s]}
    # the scalability headline in miniature
    per_dev = shards.nbytes_per_device()
    total = sum(int(np.asarray(buf[k]).nbytes)
                for k in ("emb", "loc", "ids", "scale"))
    assert max(per_dev) < total


def test_shard_cluster_buffers_random_assignment_covers_all():
    n_shards = min(4, len(jax.devices()))
    if n_shards < 2:
        pytest.skip("needs 2+ devices")
    rng = np.random.default_rng(3)
    buf = _tiny_buffers(rng, c=9)
    assignment = rng.integers(0, n_shards, size=9).astype(np.int32)
    shards = sh.shard_cluster_buffers(buf, n_shards, assignment=assignment)
    assert np.array_equal(shards.shard_of, assignment)
    seen = set()
    for g in range(9):
        s, r = int(shards.shard_of[g]), int(shards.local_of[g])
        assert np.array_equal(
            np.asarray(shards.parts[s]["ids"])[r], buf["ids"][g])
        seen.add((s, r))
    assert len(seen) == 9                       # no two clusters collide
