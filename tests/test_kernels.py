"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("b,n,d,t,k", [
    (8, 1024, 32, 50, 5),
    (16, 2048, 64, 100, 10),
    (4, 512, 128, 1000, 20),
])
def test_fused_topk_score(b, n, d, t, k, rng):
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(b, 2)), jnp.float32)
    ce = jnp.asarray(rng.normal(size=(b, n, d)), jnp.float32)
    cl = jnp.asarray(rng.uniform(size=(b, n, 2)), jnp.float32)
    ci = jnp.asarray(rng.integers(-1, 10_000, size=(b, n)), jnp.int32)
    wh = jnp.asarray(np.cumsum(rng.uniform(0, 0.01, size=t)), jnp.float32)
    s1, i1 = ops.fused_topk_score(q, ql, w, ce, cl, ci, wh, k=k,
                                  dist_max=1.414, interpret=True)
    s2, i2 = ref.fused_topk_score_ref(q, ql, w, ce, cl, ci, wh, k=k,
                                      dist_max=1.414)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,c,cap,d,t,k,cr", [
    (8, 8, 256, 32, 50, 5, 1),
    (4, 6, 128, 64, 100, 10, 2),
    (5, 4, 64, 16, 20, 8, 4),
])
def test_fused_topk_score_routed(b, c, cap, d, t, k, cr, rng):
    """Gather-free kernel == the dense oracle (engine.dense_routed_topk —
    the single routed reference, shared with the engine parity tier)."""
    from repro.core.engine import dense_routed_topk
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(b, 2)), jnp.float32)
    be = jnp.asarray(rng.normal(size=(c, cap, d)), jnp.float32)
    bl = jnp.asarray(rng.uniform(size=(c, cap, 2)), jnp.float32)
    bi = jnp.asarray(np.arange(c * cap).reshape(c, cap), jnp.int32)
    tc = jnp.asarray(rng.integers(0, c, size=(b, cr)), jnp.int32)
    wh = jnp.asarray(np.cumsum(rng.uniform(0, 0.01, size=t)), jnp.float32)
    s1, i1 = ops.fused_topk_score_routed(q, ql, w, tc, be, bl, bi, wh,
                                         k=k, dist_max=1.414, block_n=64,
                                         interpret=True)
    s2, i2 = dense_routed_topk(q, ql, w, tc, be, bl, bi, wh,
                               k=k, dist_max=1.414)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    assert (np.sort(np.asarray(i1)) == np.sort(np.asarray(i2))).all()


def test_fused_topk_score_odd_batch_clamps_block_m(rng):
    """Regression: b % block_m != 0 used to trip the kernel's grid
    assert; block_m now clamps to the largest divisor of b, matching the
    routed variant's block_n/cap rule."""
    b, n, d, t, k = 7, 512, 16, 20, 5            # odd batch, block_m=8 > 7
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(b, 2)), jnp.float32)
    ce = jnp.asarray(rng.normal(size=(b, n, d)), jnp.float32)
    cl = jnp.asarray(rng.uniform(size=(b, n, 2)), jnp.float32)
    ci = jnp.asarray(rng.integers(-1, 10_000, size=(b, n)), jnp.int32)
    wh = jnp.asarray(np.cumsum(rng.uniform(0, 0.01, size=t)), jnp.float32)
    s1, i1 = ops.fused_topk_score(q, ql, w, ce, cl, ci, wh, k=k,
                                  dist_max=1.414, block_m=8, interpret=True)
    s2, _ = ref.fused_topk_score_ref(q, ql, w, ce, cl, ci, wh, k=k,
                                     dist_max=1.414)
    assert s1.shape == (b, k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_fused_topk_score_routed_tile_collapse_warns_but_correct(rng):
    """The cap-has-no-large-divisor fallback (prime cap ⇒ tiles collapse
    to 1): the warning must fire AND results must still match the dense
    oracle — a pathological grid is slow, never wrong."""
    import warnings
    from repro.core.engine import dense_routed_topk
    b, c, cap, d, t, k, cr = 3, 4, 127, 8, 20, 5, 2     # 127 is prime
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(b, 2)), jnp.float32)
    be = jnp.asarray(rng.normal(size=(c, cap, d)), jnp.float32)
    bl = jnp.asarray(rng.uniform(size=(c, cap, 2)), jnp.float32)
    bi = jnp.asarray(np.arange(c * cap).reshape(c, cap), jnp.int32)
    tc = jnp.asarray(rng.integers(0, c, size=(b, cr)), jnp.int32)
    wh = jnp.asarray(np.cumsum(rng.uniform(0, 0.01, size=t)), jnp.float32)
    from repro.kernels import fused_topk_score as fts
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        s1, i1 = fts.fused_topk_score_routed(q, ql, w, tc, be, bl, bi, wh,
                                             k=k, dist_max=1.414,
                                             block_n=64, interpret=True)
    assert any("tiles collapsed" in str(w_.message) for w_ in caught)
    s2, i2 = dense_routed_topk(q, ql, w, tc, be, bl, bi, wh,
                               k=k, dist_max=1.414)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    assert (np.sort(np.asarray(i1)) == np.sort(np.asarray(i2))).all()


@pytest.mark.parametrize("b,c,cap,d,t,k,cr", [
    (8, 8, 256, 32, 50, 5, 1),
    (4, 6, 128, 64, 100, 10, 2),
])
def test_fused_topk_score_routed_int8_dequant(b, c, cap, d, t, k, cr, rng):
    """Dequant-in-kernel path (DESIGN.md §9): int8 resident buffers +
    per-row scales must match the dense oracle applying the SAME scales
    after its gather."""
    from repro.core import index as il
    from repro.core.engine import dense_routed_topk
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(b, 2)), jnp.float32)
    emb = rng.normal(size=(c, cap, d)).astype(np.float32)
    q_emb8, scale = il.quantize_rows(emb, "int8")
    be = jnp.asarray(q_emb8)
    bs = jnp.asarray(scale)
    assert be.dtype == jnp.int8 and bs.shape == (c, cap)
    bl = jnp.asarray(rng.uniform(size=(c, cap, 2)), jnp.float32)
    bi = jnp.asarray(np.arange(c * cap).reshape(c, cap), jnp.int32)
    tc = jnp.asarray(rng.integers(0, c, size=(b, cr)), jnp.int32)
    wh = jnp.asarray(np.cumsum(rng.uniform(0, 0.01, size=t)), jnp.float32)
    s1, i1 = ops.fused_topk_score_routed(q, ql, w, tc, be, bl, bi, wh,
                                         k=k, dist_max=1.414, block_n=64,
                                         buf_scale=bs, interpret=True)
    s2, i2 = dense_routed_topk(q, ql, w, tc, be, bl, bi, wh,
                               k=k, dist_max=1.414, buf_scale=bs)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
    assert (np.sort(np.asarray(i1)) == np.sort(np.asarray(i2))).all()


def test_fused_topk_score_int8_dequant_gather_variant(rng):
    """The gather-path kernel's dequant variant agrees with scoring the
    host-dequantized candidates through the f32 reference."""
    from repro.core import index as il
    b, n, d, t, k = 4, 512, 16, 20, 8
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(b, 2)), jnp.float32)
    emb = rng.normal(size=(b, n, d)).astype(np.float32)
    q_emb8, scale = il.quantize_rows(emb, "int8")
    cl = jnp.asarray(rng.uniform(size=(b, n, 2)), jnp.float32)
    ci = jnp.asarray(rng.integers(-1, 10_000, size=(b, n)), jnp.int32)
    wh = jnp.asarray(np.cumsum(rng.uniform(0, 0.01, size=t)), jnp.float32)
    s1, _ = ops.fused_topk_score(q, ql, w, jnp.asarray(q_emb8), cl, ci, wh,
                                 k=k, dist_max=1.414,
                                 cand_scale=jnp.asarray(scale),
                                 interpret=True)
    deq = jnp.asarray(il.dequantize_rows(q_emb8, scale, "int8"))
    s2, _ = ref.fused_topk_score_ref(q, ql, w, deq, cl, ci, wh, k=k,
                                     dist_max=1.414)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_quantize_rows_int8_bounds_error(rng):
    """Symmetric per-row scalar quantization: |emb − deq(q)| ≤ scale/2
    elementwise, padding (all-zero) rows get unit scales and stay exact."""
    from repro.core import index as il
    emb = rng.normal(size=(6, 32)).astype(np.float32)
    emb[2] = 0.0                                 # a padding row
    q, scale = il.quantize_rows(emb, "int8")
    assert q.dtype == np.int8 and scale.dtype == np.float32
    assert scale[2] == 1.0 and (q[2] == 0).all()
    deq = il.dequantize_rows(q, scale, "int8")
    assert (np.abs(deq - emb) <= scale[:, None] / 2 + 1e-7).all()


def test_fused_topk_masks_padding(rng):
    b, n, d, t, k = 4, 512, 16, 20, 8
    q = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    ql = jnp.zeros((b, 2), jnp.float32)
    w = jnp.ones((b, 2), jnp.float32)
    ce = jnp.asarray(rng.normal(size=(b, n, d)), jnp.float32)
    cl = jnp.zeros((b, n, 2), jnp.float32)
    ci = jnp.full((b, n), -1, jnp.int32)          # everything is padding
    ci = ci.at[:, :k].set(jnp.arange(k))
    wh = jnp.asarray(np.linspace(0, 1, t), jnp.float32)
    s, i = ops.fused_topk_score(q, ql, w, ce, cl, ci, wh, k=k,
                                dist_max=1.414, interpret=True)
    # only the k valid slots can be selected
    assert (np.asarray(i) < k).all() and (np.asarray(i) >= 0).all()


@pytest.mark.parametrize("b,s,h,kv,d,causal,window", [
    (2, 256, 4, 2, 32, True, 0),
    (1, 128, 4, 4, 64, True, 64),
    (2, 200, 2, 1, 16, True, 0),          # non-multiple seq (padding path)
    (1, 256, 8, 2, 32, True, 100),        # window not multiple of block
    (1, 64, 2, 2, 32, False, 0),
])
def test_flash_attention(b, s, h, kv, d, causal, window, rng):
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=causal, window=window,
                             block_q=64, block_k=64, interpret=True)
    o2 = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16(rng):
    q = jnp.asarray(rng.normal(size=(2, 128, 4, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.bfloat16)
    o1 = ops.flash_attention(q, k, v, interpret=True)
    o2 = ref.flash_attention_ref(q, k, v)
    err = np.abs(np.asarray(o1, np.float32) - np.asarray(o2, np.float32))
    assert err.max() < 2e-2


def test_flash_matches_layers_oracle(rng):
    """The kernel also matches the model's chunked-attention path."""
    from repro.models import layers
    q = jnp.asarray(rng.normal(size=(2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    o1 = ops.flash_attention(q, k, v, causal=True, interpret=True)
    o2 = layers.attention_full(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,f,d", [(128, 27, 16), (256, 27, 128), (64, 8, 8)])
def test_dot_interaction(b, f, d, rng):
    x = jnp.asarray(rng.normal(size=(b, f, d)), jnp.float32)
    o1 = ops.dot_interaction(x, block_m=64, interpret=True)
    o2 = ref.dot_interaction_ref(x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)
    # matches the model's implementation too
    from repro.models.recsys import dlrm_dot_interaction
    o3 = dlrm_dot_interaction(x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("v,d,b,p,block_v", [
    (1000, 32, 128, 8, 256),
    (500, 16, 64, 4, 512),     # block_v > v (single tile)
    (4096, 64, 256, 16, 512),
])
def test_embedding_bag(v, d, b, p, block_v, rng):
    tab = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, v, size=(b, p)), jnp.int32)
    o1 = ops.embedding_bag(tab, idx, block_v=block_v, interpret=True)
    o2 = ref.embedding_bag_ref(tab, idx)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)


def test_embedding_bag_duplicate_indices(rng):
    tab = jnp.asarray(rng.normal(size=(100, 8)), jnp.float32)
    idx = jnp.asarray([[3, 3, 3, -1]], jnp.int32)
    idx = jnp.tile(idx, (8, 1))
    o = ops.embedding_bag(tab, idx, interpret=True)
    np.testing.assert_allclose(np.asarray(o)[0], 3 * np.asarray(tab)[3],
                               rtol=1e-5)
