"""Continuous-query tier (core/continuous.py, DESIGN.md §13).

Covers the acceptance criteria of the standing-query subscription
engine:

* every insert-batch dispatch notifies EXACTLY the pairs the match
  semantics admit — assign(o) ∈ route(q, cr) ∧ predicate ∧
  ST(q, o) ≥ threshold — checked against an independent numpy oracle;
* replaying a stream of insert batches with a snapshot hot-swap
  (compaction) in the middle drops NOTHING and duplicates NOTHING, and
  matches the per-insert one-shot re-query oracle: with cr spanning all
  clusters, the notified set per batch equals the new rows a fresh
  filtered engine.query of the standing query returns above threshold,
  scores bit-matching the delta scan;
* registry membership survives hot-swaps; routes/encodings re-derive
  only when a publish actually changes routing params (n_reroutes
  stays 0 across compactions, increments on a param swap);
* subscriptions are async iterators; close/unsubscribe ends iteration;
* dispatch work scales with DISTINCT routed clusters per batch, not
  with the roster size (the reversed cluster-major economics).
"""
import asyncio
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import continuous as cont_lib
from repro.core import engine as engine_lib
from repro.core import filters as filters_lib
from repro.core import index as il
from repro.core import relevance
from repro.core import server as server_lib
from repro.core.filters import FilterSpec

DIST_MAX = 1.414
D = 32


@pytest.fixture(scope="module")
def parts():
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=D, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(19)
    params = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c, cap = 96, cfg.n_clusters, 96           # headroom for inserts
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(5), cfg.d_model, c,
                            hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    attrs = filters_lib.make_attrs(np.arange(n) % 3, 1 << (np.arange(n) % 4),
                                   np.arange(n))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap, attrs=attrs)
    return cfg, params, iparams, norm, buf


def mk_server(parts, **over):
    cfg, params, iparams, norm, buf = parts
    eng = engine_lib.QueryEngine.from_parts(
        cfg, params, iparams, norm, buf, dist_max=DIST_MAX, backend="dense")
    kw = dict(batch_size=4, max_delay_ms=30.0, k=8, cr=2, backend="dense")
    kw.update(over)
    return server_lib.StreamingServer(eng, server_lib.ServerConfig(**kw))


def mk_queries(rng, n, cfg):
    tok = rng.integers(2, cfg.vocab_size, (n, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones((n, cfg.max_len), bool)
    loc = rng.uniform(size=(n, 2)).astype(np.float32)
    return tok, msk, loc


def mk_batch(rng, cfg, m, first_id, *, tenant=None, ts=None):
    emb = rng.normal(size=(m, cfg.d_model)).astype(np.float32)
    loc = rng.uniform(size=(m, 2)).astype(np.float32)
    ids = np.arange(first_id, first_id + m, dtype=np.int32)
    attrs = filters_lib.make_attrs(
        np.arange(m) % 3 if tenant is None else np.full(m, tenant),
        np.full(m, 0b1),
        np.arange(m) if ts is None else np.full(m, ts))
    return emb, loc, ids, attrs


def oracle_matches(server, sub, emb, loc, ids, attrs):
    """The match semantics computed independently: argmax assignment,
    numpy predicate, serve-form score of the QUANTIZED rows."""
    snap = server.engine.snapshot
    m = len(ids)
    feats = il.build_features(np.asarray(emb, np.float32),
                              np.asarray(loc, np.float32), snap.norm)
    assign = np.asarray(il.assign_clusters(snap.index_params, feats,
                                           top=1)).reshape(m)
    stored, scale = il.quantize_rows(np.asarray(emb, np.float32),
                                     snap.meta.precision)
    fv = (sub.filters or filters_lib.NOOP_FILTER).to_fvals()
    pred = filters_lib.predicate_mask_np(attrs, fv[None])
    sc = np.asarray(engine_lib.score_candidates(
        sub.q_emb[None], sub.loc[None], sub.w_st[None],
        stored[None], np.asarray(loc, np.float32)[None],
        np.asarray(ids, np.int32)[None], np.asarray(snap.w_hat),
        dist_max=snap.meta.dist_max,
        cand_scale=None if snap.meta.precision != "int8"
        else scale[None]))[0]
    routed = set(int(c) for c in sub.routes)
    return {int(ids[j]): float(sc[j]) for j in range(m)
            if int(assign[j]) in routed and pred[j]
            and sc[j] >= sub.threshold}


# ---------------------------------------------------------------------------
# One dispatch vs the match-semantics oracle
# ---------------------------------------------------------------------------


def test_dispatch_matches_semantics_oracle(parts, rng):
    server = mk_server(parts)
    cfg = server.engine.cfg
    tok, msk, qloc = mk_queries(rng, 3, cfg)
    subs = [
        server.subscribe(tok[0], msk[0], qloc[0], threshold=-1e9),
        server.subscribe(tok[1], msk[1], qloc[1],
                         filters=FilterSpec(tenant=1), threshold=-1e9),
        server.subscribe(tok[2], msk[2], qloc[2], threshold=0.5),
    ]
    emb, loc, ids, attrs = mk_batch(rng, cfg, 12, 1000)
    server.insert_objects(emb, loc, ids, attrs)
    version = int(server.engine.snapshot.meta.version)
    for sub in subs:
        want = oracle_matches(server, sub, emb, loc, ids, attrs)
        got = sub.drain()
        assert {n.object_id for n in got} == set(want)
        for n in got:
            assert n.sub_id == sub.sub_id
            assert n.version == version
            assert np.isclose(n.score, want[n.object_id],
                              rtol=1e-6, atol=1e-6)
    # the unfiltered bottom-threshold sub saw every routed-cluster row
    assert subs[0].n_notified > 0


def test_attrs_default_to_zero(parts, rng):
    """insert_objects without attrs: rows carry all-zero attributes, so
    a tenant-0 subscription sees them and a tenant-1 one never does."""
    server = mk_server(parts)
    cfg = server.engine.cfg
    tok, msk, qloc = mk_queries(rng, 2, cfg)
    s0 = server.subscribe(tok[0], msk[0], qloc[0],
                          filters=FilterSpec(tenant=0), threshold=-1e9)
    s1 = server.subscribe(tok[1], msk[1], qloc[1],
                          filters=FilterSpec(tenant=1), threshold=-1e9)
    emb, loc, ids, _ = mk_batch(rng, cfg, 8, 2000)
    server.insert_objects(emb, loc, ids)          # no attrs
    assert {n.object_id for n in s0.drain()} == set(
        oracle_matches(server, s0, emb, loc, ids,
                       np.zeros((8, 3), np.int32)))
    assert s1.drain() == []


# ---------------------------------------------------------------------------
# Replay parity vs the one-shot re-query oracle, across a hot-swap
# ---------------------------------------------------------------------------


def test_replay_parity_one_shot_oracle_across_hot_swap(parts, rng):
    """The acceptance replay: stream insert batches; after each, the
    notified set for every subscription equals what a one-shot filtered
    re-query of the standing query (cr spanning ALL clusters, so routing
    admits every row) returns among the new ids above threshold — scores
    bit-matching the delta scan. A compaction hot-swap mid-replay drops
    and duplicates nothing."""
    cfg0 = parts[0]
    server = mk_server(parts, cr=cfg0.n_clusters, k=256,
                       delta_threshold=1024)      # compaction manual only
    cfg = server.engine.cfg
    tok, msk, qloc = mk_queries(rng, 2, cfg)
    thr = 0.4
    subs = [
        server.subscribe(tok[0], msk[0], qloc[0], threshold=thr),
        server.subscribe(tok[1], msk[1], qloc[1],
                         filters=FilterSpec(tenant=2), threshold=thr),
    ]
    seen = {s.sub_id: [] for s in subs}           # full replay transcript
    next_id = 5000
    for step in range(6):
        m = 6 + step
        emb, loc, ids, attrs = mk_batch(rng, cfg, m, next_id)
        next_id += m
        server.insert_objects(emb, loc, ids, attrs)
        # one-shot oracle: re-query each standing query over the post-
        # insert snapshot, keep the NEW ids above threshold
        for sub in subs:
            got = sub.drain()
            ids_q, sc_q = server.engine.query(
                sub.tokens[None], sub.mask[None], sub.loc[None],
                k=256, cr=cfg.n_clusters, batch=1, filters=sub.filters)
            new_scores = {int(i): float(s)
                          for i, s in zip(ids_q[0], sc_q[0])
                          if int(i) in set(ids.tolist())}
            want = {i: s for i, s in new_scores.items() if s >= thr}
            assert {n.object_id for n in got} == set(want), (
                f"step {step} sub {sub.sub_id}")
            for n in got:
                assert np.isclose(n.score, want[n.object_id],
                                  rtol=1e-6, atol=1e-6)
            seen[sub.sub_id].extend(got)
        if step == 2:                             # the mid-replay hot-swap
            v_before = int(server.engine.snapshot.meta.version)
            server.compact_now()
            assert int(server.engine.snapshot.meta.version) > v_before
            assert len(server.subscriptions) == 2  # membership survives
            # a swap with unchanged routing params re-encodes nothing
            assert server.subscriptions.n_reroutes == 0
    # zero duplicates across the whole replay (exactly-once)
    for s in subs:
        pairs = [(n.sub_id, n.object_id) for n in seen[s.sub_id]]
        assert len(pairs) == len(set(pairs))
        # versions strictly follow the publish order
        versions = [n.version for n in seen[s.sub_id]]
        assert versions == sorted(versions)


# ---------------------------------------------------------------------------
# Routing residency: reroutes happen exactly when params change
# ---------------------------------------------------------------------------


def test_reroute_only_on_param_change(parts, rng):
    cfg, params, iparams, norm, buf = parts
    server = mk_server(parts)
    tok, msk, qloc = mk_queries(rng, 1, cfg)
    sub = server.subscribe(tok[0], msk[0], qloc[0], threshold=-1e9)
    routes0 = sub.routes.copy()
    # delta publish + compaction: same param objects, no re-encode
    emb, loc, ids, attrs = mk_batch(rng, cfg, 4, 3000)
    server.insert_objects(emb, loc, ids, attrs)
    server.compact_now()
    assert server.subscriptions.n_reroutes == 0
    assert np.array_equal(sub.routes, routes0)
    # a publish with NEW routing params re-encodes and re-routes
    iparams2 = il.index_init(jax.random.PRNGKey(99), cfg.d_model,
                             cfg.n_clusters, hidden=(16,))
    snap = server.engine.snapshot
    snap2 = dataclasses.replace(snap, index_params=iparams2)
    server.publish(snap2)
    assert server.subscriptions.n_reroutes == 1
    # the fresh routes equal an independent encoding on the new params
    reg2 = cont_lib.SubscriptionRegistry(server.engine, cr=server.cfg.cr)
    fresh = reg2.register(tok[0], msk[0], qloc[0], threshold=-1e9)
    assert np.array_equal(sub.routes, fresh.routes)
    np.testing.assert_allclose(sub.q_emb, fresh.q_emb)


# ---------------------------------------------------------------------------
# Async iteration, close, unregister
# ---------------------------------------------------------------------------


def test_async_iteration_and_close(parts, rng):
    server = mk_server(parts)
    cfg = server.engine.cfg
    tok, msk, qloc = mk_queries(rng, 1, cfg)

    async def go():
        sub = server.subscribe(tok[0], msk[0], qloc[0], threshold=-1e9)
        emb, loc, ids, attrs = mk_batch(rng, cfg, 6, 4000)
        server.insert_objects(emb, loc, ids, attrs)
        server.unsubscribe(sub.sub_id)            # closes the stream
        return sub, [n async for n in sub]

    sub, notes = asyncio.run(go())
    assert len(notes) == sub.n_notified > 0
    assert all(isinstance(n, cont_lib.Notification) for n in notes)
    # closed stream stays ended (the sentinel re-posts)
    assert sub.drain() == []


def test_unregister_stops_delivery(parts, rng):
    server = mk_server(parts)
    cfg = server.engine.cfg
    tok, msk, qloc = mk_queries(rng, 2, cfg)
    keep = server.subscribe(tok[0], msk[0], qloc[0], threshold=-1e9)
    gone = server.subscribe(tok[1], msk[1], qloc[1], threshold=-1e9)
    server.unsubscribe(gone.sub_id)
    assert len(server.subscriptions) == 1
    emb, loc, ids, attrs = mk_batch(rng, cfg, 8, 4500)
    server.insert_objects(emb, loc, ids, attrs)
    assert gone.n_notified == 0
    assert keep.n_notified > 0


def test_register_validates_filters(parts, rng):
    server = mk_server(parts)
    tok, msk, qloc = mk_queries(rng, 1, server.engine.cfg)
    with pytest.raises(TypeError):
        server.subscribe(tok[0], msk[0], qloc[0], filters={"tenant": 1})


# ---------------------------------------------------------------------------
# Dispatch economics and metrics
# ---------------------------------------------------------------------------


def test_dispatch_cost_scales_with_distinct_clusters(parts, rng):
    """Roster size does not multiply dispatch work: a batch landing in
    d distinct clusters costs d scoring calls no matter how many
    subscriptions are registered (the metric the bench gates on)."""
    server = mk_server(parts)
    cfg = server.engine.cfg
    tok, msk, qloc = mk_queries(rng, 12, cfg)
    for i in range(12):                           # a 12-strong roster
        server.subscribe(tok[i], msk[i], qloc[i], threshold=-1e9)
    calls = []
    orig = engine_lib.score_candidates

    def counted(*a, **kw):
        calls.append(1)
        return orig(*a, **kw)

    cont_lib.engine_lib.score_candidates = counted
    try:
        emb, loc, ids, attrs = mk_batch(rng, cfg, 16, 6000)
        server.insert_objects(emb, loc, ids, attrs)
    finally:
        cont_lib.engine_lib.score_candidates = orig
    reg = server.subscriptions
    assert reg.n_dispatches == 1
    assert len(calls) == reg.n_distinct_clusters <= cfg.n_clusters
    m = server.metrics()["subscriptions"]
    assert m["subscriptions"] == 12
    assert m["objects_seen"] == 16
    assert m["distinct_clusters_per_dispatch"] == reg.n_distinct_clusters
    assert m["notifications"] == reg.n_notifications > 0


def test_metrics_without_registry(parts):
    """A server that never subscribed reports no subscription block and
    exposes the satellite raw cache counters."""
    server = mk_server(parts)
    m = server.metrics()
    assert "subscriptions" not in m
    assert m["exact_hits"] == 0 and m["near_hits"] == 0
