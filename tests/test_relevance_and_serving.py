"""LIST-R scoring consistency + the two query-phase implementations agree."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import index as il
from repro.core import relevance, serving
from repro.core import spatial as sp
from repro.core.snapshot import IndexSnapshot

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup(tiny_de_cfg=None):
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    params = relevance.relevance_init(KEY, cfg)
    return cfg, params


def test_score_pairs_vs_corpus_consistency(setup, rng):
    """score_corpus(B,N) diagonal == score_pairs on aligned pairs."""
    cfg, params = setup
    n = 6
    emb = jnp.asarray(rng.normal(size=(n, cfg.d_model)), jnp.float32)
    loc = jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32)
    pair = relevance.score_pairs(params, emb, loc, emb, loc, cfg,
                                 dist_max=1.414, train=False)
    corp = relevance.score_corpus(params, emb, loc, emb, loc, cfg,
                                  dist_max=1.414, train=False)
    np.testing.assert_allclose(np.asarray(pair), np.diag(np.asarray(corp)),
                               rtol=1e-5, atol=1e-5)


def test_train_serve_scoring_equivalence(setup, rng):
    """Eq. 4 (train path) and Eq. 5 (serve path) give identical ST."""
    cfg, params = setup
    b, n = 4, 50
    qe = jnp.asarray(rng.normal(size=(b, cfg.d_model)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    oe = jnp.asarray(rng.normal(size=(n, cfg.d_model)), jnp.float32)
    ol = jnp.asarray(rng.uniform(size=(n, 2)), jnp.float32)
    st_train = relevance.score_corpus(params, qe, ql, oe, ol, cfg,
                                      dist_max=1.414, train=True)
    st_serve = relevance.score_corpus(params, qe, ql, oe, ol, cfg,
                                      dist_max=1.414, train=False)
    np.testing.assert_allclose(np.asarray(st_train), np.asarray(st_serve),
                               rtol=1e-4, atol=1e-4)


def test_contrastive_loss_decreases_with_easy_positive(setup, rng):
    cfg, params = setup
    b, L = 4, 8
    batch = {
        "q_tokens": jnp.asarray(rng.integers(2, 512, (b, L)), jnp.int32),
        "q_mask": jnp.ones((b, L), bool),
        "q_loc": jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32),
        "pos_tokens": jnp.asarray(rng.integers(2, 512, (b, L)), jnp.int32),
        "pos_mask": jnp.ones((b, L), bool),
        "pos_loc": jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32),
        "neg_tokens": jnp.asarray(rng.integers(2, 512, (b, 2, L)), jnp.int32),
        "neg_mask": jnp.ones((b, 2, L), bool),
        "neg_loc": jnp.asarray(rng.uniform(size=(b, 2, 2)), jnp.float32),
    }
    loss, m = relevance.contrastive_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: relevance.contrastive_loss(p, batch, cfg)[0])(
        params)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(g))


def test_weight_modes(setup, rng):
    cfg, params = setup
    qe = jnp.asarray(rng.normal(size=(3, cfg.d_model)), jnp.float32)
    w_mlp = relevance.st_weights(params, qe, weight_mode="mlp")
    w_fix = relevance.st_weights(params, qe, weight_mode="fixed")
    assert w_mlp.shape == (3, 2) and w_fix.shape == (3, 2)
    assert (np.asarray(w_mlp) > 0).all()         # softplus positivity
    np.testing.assert_allclose(
        np.asarray(w_fix),
        np.broadcast_to(np.asarray(w_fix[0]), w_fix.shape),
        rtol=1e-6)        # fixed = same per query


def test_dispatch_roundtrip(rng):
    """dispatch_queries places each (query, route) exactly once."""
    b, cr, c, cap = 16, 2, 4, 16
    top_c = jnp.asarray(rng.integers(0, c, size=(b, cr)), jnp.int32)
    feat = jnp.asarray(np.arange(b, dtype=np.float32)[:, None], jnp.float32)
    q_buf, origin, n_dropped = serving.dispatch_queries(
        top_c, feat, n_clusters=c, capacity=cap)
    assert int(n_dropped) == 0          # capacity b*cr/c*... is ample here
    org = np.asarray(origin)
    placed = org[org < b * cr]
    assert len(placed) == b * cr and len(set(placed.tolist())) == b * cr
    # payload carried correctly: origin slot row == query id feature
    qb = np.asarray(q_buf)
    for ci in range(c):
        for s in range(cap):
            o = org[ci, s]
            if o < b * cr:
                assert qb[ci, s, 0] == o // cr


def test_cluster_dispatch_equals_gather_path(setup, rng):
    """The distributed (expert-dispatch) query phase returns the same
    top-k as the simple gather path for every query."""
    cfg, params = setup
    n, c, d = 160, 4, cfg.d_model
    cap = 64
    b, k = 8, 5

    obj_emb = rng.normal(size=(n, d)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = il.loc_normalizer(jnp.asarray(obj_loc))
    iparams = il.index_init(jax.random.PRNGKey(5), d, c, hidden=(16,))
    feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                              norm)
    top = np.asarray(il.assign_clusters(iparams, feats, top=2))
    buf = il.build_cluster_buffers(top, obj_emb, obj_loc, n_clusters=c,
                                   capacity=cap)
    w_hat = sp.extract_lookup(params["spatial"])

    q_tokens = jnp.asarray(rng.integers(2, 512, (b, 8)), jnp.int32)
    q_mask = jnp.ones((b, 8), bool)
    q_loc = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)

    snap = IndexSnapshot.from_parts(cfg, params, iparams, norm, buf,
                                    dist_max=1.414)
    ids_d, sc_d = serving.cluster_dispatch_query(
        snap, q_tokens, q_mask, q_loc, k=k, cr=1,
        capacity=b)   # capacity >= b: no dispatch drops

    # simple gather path (core/engine.make_query_fn logic, inlined)
    q_emb = relevance.encode_queries(params, q_tokens, q_mask, cfg)
    qf = il.build_features(q_emb, q_loc, norm)
    top_c, _ = il.route_queries(iparams, qf, cr=1)
    cand_emb = buf["emb"][top_c].reshape(b, -1, d)
    cand_loc = buf["loc"][top_c].reshape(b, -1, 2)
    cand_ids = buf["ids"][top_c].reshape(b, -1)
    w = relevance.st_weights(params, q_emb)
    trel = jnp.einsum("bd,bnd->bn", q_emb, cand_emb)
    dist = jnp.linalg.norm(q_loc[:, None] - cand_loc, axis=-1)
    srel = sp.spatial_relevance_serve(
        w_hat, 1.0 - jnp.clip(dist / 1.414, 0, 1))
    st = w[:, :1] * trel + w[:, 1:] * srel
    st = jnp.where(cand_ids >= 0, st, -jnp.inf)
    sc_g, pos = jax.lax.top_k(st, k)
    ids_g = jnp.take_along_axis(cand_ids, pos, axis=1)

    finite = np.isfinite(np.asarray(sc_g))
    np.testing.assert_allclose(np.asarray(sc_d)[finite],
                               np.asarray(sc_g)[finite], rtol=1e-4,
                               atol=1e-4)
    assert (np.asarray(ids_d)[finite] == np.asarray(ids_g)[finite]).all()
