# Tiers:
#   make test          - tier-1: fast unit/parity tests (minutes)
#   make test-slow     - everything, including e2e training + interpret-mode
#                        decode sweeps (tens of minutes on CPU)
#   make bench-smoke   - CI-scale benchmark smoke (--fast settings)
#   make bench-serving - streaming-serving benchmark -> BENCH_serving.json

PY      := python
PYPATH  := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}

.PHONY: test test-slow bench-smoke bench-serving

test:
	$(PYPATH) $(PY) -m pytest -x -q -m "not slow"

test-slow:
	$(PYPATH) $(PY) -m pytest -x -q

bench-smoke:
	$(PYPATH) $(PY) -m benchmarks.run --fast --only Kernel_fusion,Table4_memory,Serving_stream

bench-serving:
	$(PYPATH) $(PY) -m benchmarks.bench_serving
