# Tiers:
#   make test               - tier-1: fast unit/parity tests (minutes)
#   make test-slow          - everything, including e2e training +
#                             interpret-mode decode sweeps (tens of
#                             minutes on CPU)
#   make snapshot-roundtrip - IndexSnapshot save->load->query bit-identity
#                             self-test on both backends x all precision
#                             tiers (seconds)
#   make bench-smoke        - CI-scale benchmark smoke (--fast settings)
#   make bench-serving      - streaming-serving benchmark -> BENCH_serving.json
#   make bench-kernels      - kernel roofline (backend x precision)
#                             -> BENCH_kernels.json

PY      := python
PYPATH  := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}

.PHONY: test test-slow snapshot-roundtrip bench-smoke bench-serving \
        bench-kernels

test:
	$(PYPATH) $(PY) -m pytest -x -q -m "not slow"

test-slow:
	$(PYPATH) $(PY) -m pytest -x -q

snapshot-roundtrip:
	$(PYPATH) $(PY) -m repro.api

bench-smoke:
	$(PYPATH) $(PY) -m benchmarks.run --fast --only Kernel_roofline,Table4_memory,Serving_stream

bench-serving:
	$(PYPATH) $(PY) -m benchmarks.bench_serving

bench-kernels:
	$(PYPATH) $(PY) -m benchmarks.bench_kernels
