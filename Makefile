# Tiers:
#   make test               - tier-1: fast unit/parity tests (minutes)
#   make test-slow          - everything, including e2e training +
#                             interpret-mode decode sweeps (tens of
#                             minutes on CPU)
#   make test-mesh          - the mesh-sharding parity tier on 8 forced
#                             CPU devices (tests/test_mesh_sharding.py +
#                             tests/test_sharding_rules.py, DESIGN.md §12)
#   make snapshot-roundtrip - IndexSnapshot save->load->query bit-identity
#                             self-test on both backends x all precision
#                             tiers (seconds)
#   make bench-smoke        - CI-scale benchmark smoke (--fast settings,
#                             EVERY registered benchmark)
#   make bench-serving      - streaming-serving benchmark -> BENCH_serving.json
#   make bench-filters      - filtered-search + subscription-dispatch
#                             acceptance -> `filters` section of
#                             BENCH_serving.json
#   make test-filters       - the filtered/continuous parity tier
#                             (4 backends x 3 precision tiers + the
#                             standing-query replay oracle)
#   make test-resilience    - the chaos tier (DESIGN.md §14): fault
#                             injection across WAL/checkpoint/flush,
#                             crash-recovery parity, shedding + breaker
#   make bench-resilience   - overload-shedding + crash-recovery
#                             acceptance -> `resilience` section of
#                             BENCH_serving.json (+ the `mesh_chaos`
#                             section when >= 2 devices are visible)
#   make test-mesh-chaos    - shard fault-tolerance tier (DESIGN.md §15)
#                             on 8 forced CPU devices: health tracking,
#                             hedged scans, degraded coverage, recovery
#   make bench-kernels      - kernel roofline (backend x precision)
#                             -> BENCH_kernels.json
#   make bench-scalability  - Fig7 corpus scaling + mesh-sharded scale-out
#                             sweep -> BENCH_scalability.json

PY      := python
PYPATH  := PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH}
# multi-device CPU for the mesh tiers: must be exported before jax
# first initialises its backends (conftest also force-sets it for pytest)
MESHENV := XLA_FLAGS="--xla_force_host_platform_device_count=8"

.PHONY: test test-slow test-mesh test-filters test-resilience \
        test-mesh-chaos snapshot-roundtrip bench-smoke bench-serving \
        bench-filters bench-kernels bench-resilience bench-scalability

test:
	$(PYPATH) $(PY) -m pytest -x -q -m "not slow"

test-slow:
	$(PYPATH) $(PY) -m pytest -x -q

test-mesh:
	$(MESHENV) $(PYPATH) $(PY) -m pytest -x -q \
		tests/test_mesh_sharding.py tests/test_sharding_rules.py

snapshot-roundtrip:
	$(PYPATH) $(PY) -m repro.api

test-filters:
	$(MESHENV) $(PYPATH) $(PY) -m pytest -x -q \
		tests/test_filters.py tests/test_continuous.py

test-resilience:
	$(PYPATH) $(PY) -m pytest -x -q \
		tests/test_resilience_serving.py tests/test_server.py

test-mesh-chaos:
	$(MESHENV) $(PYPATH) $(PY) -m pytest -x -q \
		tests/test_shard_faults.py

# no --only: the smoke covers EVERY registered benchmark suite
bench-smoke:
	$(MESHENV) $(PYPATH) $(PY) -m benchmarks.run --fast

bench-serving:
	$(PYPATH) $(PY) -m benchmarks.bench_serving

bench-filters:
	$(PYPATH) $(PY) -m benchmarks.bench_filters

bench-kernels:
	$(PYPATH) $(PY) -m benchmarks.bench_kernels

bench-resilience:
	$(PYPATH) $(PY) -m benchmarks.bench_resilience

bench-scalability:
	$(MESHENV) $(PYPATH) $(PY) -m benchmarks.bench_scalability
