"""Paper Table 6: spatial-relevance + weight-learning ablations.

LIST-R (step SRel, MLP weights) vs +S_in (linear), +a·S_in^b (learnable
exp), and fixed weights (the ADrW-replacement row).
"""
from __future__ import annotations

from benchmarks import common

ABLATION_STEPS = 200


def run():
    corpus = common.get_corpus()
    te, positives = common.test_split_positives(corpus)
    rows = []
    variants = [
        ("LIST-R(step,mlp)", dict(spatial_mode="step", weight_mode="mlp")),
        ("LIST-R+S_in", dict(spatial_mode="linear", weight_mode="mlp")),
        ("LIST-R+a*S_in^b", dict(spatial_mode="exp", weight_mode="mlp")),
        ("LIST-R+fixed_w", dict(spatial_mode="step", weight_mode="fixed")),
    ]
    for name, kw in variants:
        r = common.get_retriever(rel_steps=ABLATION_STEPS, tag=name,
                                 with_index=False, **kw)
        ids, _ = r.brute_force(te, k=20)
        rows.append(common.fmt_row(name, common.eval_ranking(ids, positives)))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
