"""Paper Table 3: relevance-model effectiveness via brute-force search.

LIST-R vs TkQ (BM25 + linear spatial). (DrW/PALM/MGeo are proprietary-
artifact baselines; TkQ is the reproducible classical anchor — the paper's
own finding is LIST-R > DrW > TkQ > PALM.)
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core.baselines import BM25, tkq_topk


def run():
    corpus = common.get_corpus()
    te, positives = common.test_split_positives(corpus)
    rows = []

    bm = BM25(corpus.obj_doc, vocab_size=corpus.cfg.vocab_size)
    tkq_ids = tkq_topk(bm, corpus.q_doc[te], corpus.q_loc[te],
                       corpus.obj_loc, 20, dist_max=corpus.dist_max)
    rows.append(common.fmt_row("TkQ(BM25)",
                               common.eval_ranking(tkq_ids, positives)))

    r = common.get_retriever()
    ids, _ = r.brute_force(te, k=20)
    m = common.eval_ranking(ids, positives)
    rows.append(common.fmt_row("LIST-R(brute)", m))

    # word-mismatch slice (paper Fig. 1a motivation): queries with zero
    # token overlap with their seed object
    mism = corpus.q_mismatch[te]
    pos_m = [p for p, f in zip(positives, mism) if f]
    rows.append(common.fmt_row(
        "TkQ(BM25)[mismatch-only]",
        common.eval_ranking(tkq_ids[mism], pos_m)))
    rows.append(common.fmt_row(
        "LIST-R[mismatch-only]",
        common.eval_ranking(ids[mism], pos_m)))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
