"""Paper Fig. 4 + Fig. 5: effectiveness–efficiency trade-off.

All index baselines retrieve candidates; LIST-R reranks them (identical
rerank model for fairness, as in the paper). Efficiency proxy = candidates
scored per query (hardware-independent) + measured wall seconds.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core.baselines import (
    BM25,
    IVFIndex,
    LSHIndex,
    rerank_candidates,
    tkq_topk,
)


def run(k: int = 10):
    corpus = common.get_corpus()
    te, positives = common.test_split_positives(corpus)
    r = common.get_retriever()
    r.ensure_embeddings()
    q_emb = np.asarray(
        __import__("repro.core.pipeline", fromlist=["x"]).embed_queries(
            r.rel_params, corpus, r.cfg, te))
    q_loc = corpus.q_loc[te].astype(np.float32)
    score = r.score_fn()
    rows = []

    # brute force = upper anchor
    t0 = time.time()
    ids, _ = r.brute_force(te, k=k)
    rows.append(common.fmt_row(
        "BruteForce(LIST-R)", common.eval_ranking(ids, positives),
        f"cand={corpus.cfg.n_objects},sec={time.time()-t0:.2f}"))

    # LIST at cr = 1, 2, 3 (Fig. 5 knob)
    for cr in (1, 2, 3):
        t0 = time.time()
        ids, _ = r.query(te, k=k, cr=cr)
        cand = cr * r.buffers["capacity"]
        rows.append(common.fmt_row(
            f"LIST(cr={cr})", common.eval_ranking(ids, positives),
            f"cand={cand},sec={time.time()-t0:.2f}"))

    # IVF / IVF_S on the same embeddings, LIST-R rerank
    for name, idx in (
            ("IVF", IVFIndex(r.obj_emb, n_clusters=common.N_CLUSTERS,
                             seed=0)),
            ("IVF_S(a=0.9)", IVFIndex(r.obj_emb, corpus.obj_loc,
                                      n_clusters=common.N_CLUSTERS,
                                      alpha=0.9, seed=0))):
        for cr in (1, 2):
            t0 = time.time()
            cands = (idx.candidates(q_emb, cr=cr) if name == "IVF"
                     else idx.candidates(q_emb, q_loc, cr=cr))
            out, mean_c = rerank_candidates(
                lambda i, c: score(q_emb[i], q_loc[i], c), cands, k)
            rows.append(common.fmt_row(
                f"{name}+LIST-R(cr={cr})",
                common.eval_ranking(out, positives),
                f"cand={mean_c:.0f},sec={time.time()-t0:.2f}"))

    # LSH
    lsh = LSHIndex(r.obj_emb, nbits=12, n_tables=4, seed=0)
    t0 = time.time()
    cands = lsh.candidates(q_emb)
    out, mean_c = rerank_candidates(
        lambda i, c: score(q_emb[i], q_loc[i], c), cands, k)
    rows.append(common.fmt_row(
        "LSH+LIST-R", common.eval_ranking(out, positives),
        f"cand={mean_c:.0f},sec={time.time()-t0:.2f}"))

    # TkQ as retriever (Fig. 5's slow-riser), k sweep
    bm = BM25(corpus.obj_doc, vocab_size=corpus.cfg.vocab_size)
    for kk in (100, 500):
        t0 = time.time()
        top = tkq_topk(bm, corpus.q_doc[te], q_loc, corpus.obj_loc, kk,
                       dist_max=corpus.dist_max)
        out, mean_c = rerank_candidates(
            lambda i, c: score(q_emb[i], q_loc[i], c), list(top), k)
        rows.append(common.fmt_row(
            f"TkQ+LIST-R(k={kk})", common.eval_ranking(out, positives),
            f"cand={mean_c:.0f},sec={time.time()-t0:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
