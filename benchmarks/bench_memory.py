"""Paper Table 4: index memory footprint.

Bytes of each index structure ON TOP of the shared parts (relevance model
params + precomputed object embeddings + geo-locations), mirroring the
paper's accounting where LIST ≈ IVF ≈ IVFPQ < LSH < HNSW < TkQ/IR-tree.
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks import common
from repro.core.baselines import BM25, IVFIndex, LSHIndex


def _nbytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


def run():
    corpus = common.get_corpus()
    r = common.get_retriever()
    r.ensure_embeddings()
    rows = []
    shared = (np.asarray(r.obj_emb).nbytes
              + corpus.obj_loc.astype(np.float32).nbytes
              + _nbytes(r.rel_params))
    rows.append(common.fmt_row("shared(model+emb+loc)",
                               {"MB": shared / 1e6}))

    # LIST: the MLP router (+ the padded buffers replace the raw emb array)
    list_extra = _nbytes(r.index_params)
    rows.append(common.fmt_row("LIST(index MLP)",
                               {"MB": list_extra / 1e6,
                                "total_MB": (shared + list_extra) / 1e6}))

    ivf = IVFIndex(r.obj_emb, n_clusters=common.N_CLUSTERS, seed=0)
    ivf_extra = ivf.centroids.nbytes + sum(l.nbytes for l in ivf.lists)
    rows.append(common.fmt_row("IVF(centroids+lists)",
                               {"MB": ivf_extra / 1e6,
                                "total_MB": (shared + ivf_extra) / 1e6}))

    lsh = LSHIndex(r.obj_emb, nbits=12, n_tables=4, seed=0)
    lsh_extra = (lsh.planes.nbytes + lsh.codes.nbytes
                 + sum(v.nbytes for t in lsh.tables for v in t.values()))
    rows.append(common.fmt_row("LSH(planes+tables)",
                               {"MB": lsh_extra / 1e6,
                                "total_MB": (shared + lsh_extra) / 1e6}))

    bm = BM25(corpus.obj_doc, vocab_size=corpus.cfg.vocab_size)
    bm_extra = bm.idf.nbytes + bm.docs.nbytes + bm.doc_len.nbytes
    rows.append(common.fmt_row("TkQ(BM25 stats)",
                               {"MB": bm_extra / 1e6,
                                "total_MB": (shared + bm_extra) / 1e6}))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
