"""Kernel roofline benchmark: the query-phase scan per backend × precision,
plus the route-skew sweep that measures the cluster-major dedup win.

LIST's query phase is a memory-bound corpus scan (DESIGN.md §4): the
roofline is set by how many bytes of resident cluster buffer stream
through HBM per query. Two orthogonal levers attack that stream:

* the **precision policy** (DESIGN.md §9) shrinks each streamed row —
  bf16 halves it, int8 cuts it ~4× (symmetric per-row scalar
  quantization, dequantized in VMEM inside the kernel);
* **cluster-major batched execution** (DESIGN.md §10) shrinks how many
  rows stream — the query-major kernel re-streams a popular cluster
  once per routed query (``B·cr`` cluster-scans per batch), while the
  cluster-major kernel streams each DISTINCT routed cluster once
  (``min(B·cr, c)`` scans, further reduced to the measured ``U`` by a
  dynamic grid). The two compose multiplicatively.

This bench trains one retriever, requantizes its snapshot at every tier
(``IndexSnapshot.with_precision``), and for each (backend × precision)
measures wall time per batch, **estimated HBM bytes streamed per
query** (kernel-true: what the grid actually DMAs), and recall@10 vs
the f32 dense oracle. A second, route-skew sweep replays the test
queries uniformly and Zipf-skewed (the serving stack's workload model,
core/server.zipf_sample), measures the per-batch **dedup factor**
``B·cr/U`` from the real router, and checks the cluster-major backend
returns the query-major results bit-identically modulo tie order
(recall ≥ 0.999 — 1.0 unless an equal-score tie straddles the k
boundary) while streaming ≥2× fewer bytes — the acceptance bar CI
gates.

Emits ``BENCH_kernels.json`` (schema in README.md §Benchmarks).

    PYTHONPATH=src python -m benchmarks.bench_kernels [--fast]
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.core import engine as engine_lib
from repro.core import index as index_lib

OUT_PATH = "BENCH_kernels.json"

K = 10
CR = 2
BATCH = 64
REPEATS = 3
D_MODEL = 128          # bench-scale d; large enough that the exact
                       # loc/ids sidecar doesn't mask the emb-stream cut

N_REPLAY = 256         # route-skew replay length (multiple of BATCH)
SKEWS = (("uniform", 0.0), ("zipf", 1.05))

_EMB_BYTES = {"f32": 4, "bf16": 2, "int8": 1}


def row_bytes(d: int, precision: str) -> int:
    """Bytes one candidate row streams: emb in the storage dtype + f32
    scale (int8 only) + exact f32 loc (2×4) + int32 id."""
    return d * _EMB_BYTES[precision] + (4 if precision == "int8" else 0) \
        + 2 * 4 + 4


def bytes_per_query(cap: int, d: int, precision: str, *, cr: int = CR) -> int:
    """Query-major scan: cr·cap candidate rows stream per query."""
    return cr * cap * row_bytes(d, precision)


def bytes_per_query_cluster_major(cap: int, d: int, precision: str, *,
                                  n_clusters: int, batch: int = BATCH,
                                  cr: int = CR) -> float:
    """Cluster-major scan (kernel-true): the grid streams
    ``u_max = min(B·cr, c)`` distinct-cluster scans per BATCH, amortized
    over its ``batch`` queries. The measured dedup factor (skew sweep)
    tells how much further a dynamic grid could cut (``U ≤ u_max``)."""
    u_max = min(batch * cr, n_clusters)
    return u_max * cap * row_bytes(d, precision) / batch


def _recall_vs_oracle(ids, oracle_ids) -> float:
    inter = [len(set(a.tolist()) & set(b.tolist())) / oracle_ids.shape[1]
             for a, b in zip(ids, oracle_ids)]
    return float(np.mean(inter))


def _time_queries(searcher, corpus, te, backend):
    ids, _ = searcher.query_corpus(corpus, te, k=K, cr=CR, batch=BATCH,
                                   backend=backend)        # warm + result
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        searcher.query_corpus(corpus, te, k=K, cr=CR, batch=BATCH,
                              backend=backend)
    wall = (time.perf_counter() - t0) / REPEATS
    return ids, wall


def _est_bytes(backend: str, precision: str, cap: int, d: int,
               n_clusters: int) -> float:
    if backend.endswith("-cm"):
        return bytes_per_query_cluster_major(cap, d, precision,
                                             n_clusters=n_clusters)
    return bytes_per_query(cap, d, precision)


def _skew_sweep(snap, corpus, te, rows):
    """Route-skew axis: replay uniform vs zipf traffic, measure the
    batch dedup factor from the real router, and compare query-major vs
    cluster-major per precision tier on the same replay."""
    from repro.core import server as server_lib

    cap = snap.buffers["capacity"]
    c = int(snap.buffers["emb"].shape[0])
    d = snap.cfg.d_model
    rng = np.random.default_rng(7)
    route_engine = api.Searcher(snap, backend="dense").engine
    sweep = {}
    for name, a in SKEWS:
        picks = te[server_lib.zipf_sample(rng, len(te), N_REPLAY, a=a)]
        tok, msk = corpus.query_tokens(picks)
        loc = corpus.q_loc[picks].astype(np.float32)

        distinct = []
        for s in range(0, N_REPLAY, BATCH):
            tc = np.asarray(route_engine.route(
                tok[s:s + BATCH], msk[s:s + BATCH], loc[s:s + BATCH], cr=CR))
            distinct.append(len(np.unique(tc)))
        mean_u = float(np.mean(distinct))
        dedup = BATCH * CR / mean_u

        tiers = ("f32", "int8") if a > 0 else ("f32",)
        per_backend = {}
        for precision in tiers:
            snap_p = snap.with_precision(precision)
            results = {}
            for backend in ("pallas", "pallas-cm"):
                s_ = api.Searcher(snap_p, backend=backend)
                s_.query(tok, msk, loc, k=K, cr=CR, batch=BATCH)    # warm
                t0 = time.perf_counter()
                ids, _ = s_.query(tok, msk, loc, k=K, cr=CR, batch=BATCH)
                results[backend] = (ids, time.perf_counter() - t0)
            for backend, (ids, wall) in results.items():
                entry = {
                    "wall_ms_per_batch": wall / (N_REPLAY // BATCH) * 1e3,
                    # kernel-true: what the static grid actually streams
                    "est_hbm_bytes_per_query":
                        _est_bytes(backend, precision, cap, d, c),
                    "recall_at_10_vs_query_major": _recall_vs_oracle(
                        ids, results["pallas"][0]),
                }
                if backend.endswith("-cm"):
                    # what a dynamic grid streaming only the MEASURED U
                    # distinct clusters would cost — the skew-dependent
                    # headroom beyond the structural u_max bound
                    entry["est_hbm_bytes_per_query_dynamic_grid"] = (
                        mean_u * cap * row_bytes(d, precision) / BATCH)
                per_backend[f"{backend}@{precision}"] = entry
        sweep[name] = {
            "zipf_a": a,
            "mean_distinct_clusters": mean_u,
            "dedup_factor": dedup,
            "per_backend": per_backend,
        }
        rows.append(common.fmt_row(f"route_skew({name})", {
            "zipf_a": a, "U": mean_u, "dedup": dedup,
            **{f"MBq({k_})": v["est_hbm_bytes_per_query"] / 1e6
               for k_, v in per_backend.items()},
        }))
    return sweep


def run(out_path: str = OUT_PATH):
    r = common.get_retriever(tag=f"kernels-d{D_MODEL}",
                             cfg_over={"d_model": D_MODEL})
    corpus = common.get_corpus()
    te, _ = common.test_split_positives(corpus)
    snap = r.snapshot()
    cap = snap.buffers["capacity"]
    c = int(snap.buffers["emb"].shape[0])
    d = snap.cfg.d_model

    oracle_searcher = api.Searcher(snap, backend="dense")
    oracle_ids, oracle_wall = _time_queries(oracle_searcher, corpus, te,
                                            "dense")

    f32_bytes = bytes_per_query(cap, d, "f32")
    sweep = {}
    rows = []
    for precision in index_lib.PRECISIONS:
        snap_p = snap.with_precision(precision)
        for backend in ("dense", "pallas", "pallas-cm"):
            est = _est_bytes(backend, precision, cap, d, c)
            if (backend, precision) == ("dense", "f32"):
                ids, wall = oracle_ids, oracle_wall    # it IS the oracle
            else:
                ids, wall = _time_queries(
                    api.Searcher(snap_p, backend=backend), corpus, te,
                    backend)
            entry = {
                "wall_ms_per_batch": wall / max(1, -(-len(te) // BATCH))
                * 1e3,
                "est_hbm_bytes_per_query": est,
                "bytes_reduction_vs_f32": f32_bytes / est,
                "recall_at_10_vs_f32_dense": _recall_vs_oracle(ids,
                                                               oracle_ids),
            }
            sweep[f"{backend}@{precision}"] = entry
            rows.append(common.fmt_row(
                f"kernel_scan({backend}@{precision})", {
                    "ms/batch": entry["wall_ms_per_batch"],
                    "MBq": est / 1e6,
                    "bytes_cut": entry["bytes_reduction_vs_f32"],
                    "recall@10_vs_f32": entry["recall_at_10_vs_f32_dense"],
                }))

    skew_sweep = _skew_sweep(snap, corpus, te, rows)

    # hardware-independent traffic models (paper-scale d=768, Geo-Glue):
    # fusing score+spatial+topk keeps everything but the emb stream in
    # VMEM; the routed kernel reads the scanned slice once vs 3× for the
    # gather path; int8 then shrinks that one stream itself; cluster-
    # major divides it by the batch dedup factor on top
    n_paper, d_paper = 2_849_754, 768
    unfused = n_paper * (d_paper + 7) * 4
    fused = n_paper * (d_paper + 2) * 4
    traffic = {
        "fused_vs_unfused_saved_pct": 100 * (1 - fused / unfused),
        "routed_vs_gather_saved_pct": 100 * (1 - 1 / 3),
        "int8_vs_f32_paper_scale_reduction":
            bytes_per_query(1, d_paper, "f32", cr=1)
            / bytes_per_query(1, d_paper, "int8", cr=1),
        "cluster_major_vs_query_major_reduction":
            bytes_per_query(cap, d, "f32")
            / bytes_per_query_cluster_major(cap, d, "f32", n_clusters=c),
    }
    rows.append(common.fmt_row("traffic-model(paper-scale)", traffic))

    zipf = skew_sweep["zipf"]["per_backend"]
    # the kernel-true bytes ratio is STRUCTURAL: the cm grid streams
    # min(B·cr, c) cluster-scans per batch vs B·cr query-major, and
    # row_bytes cancels — one number, identical across precision tiers
    # (the measured, skew-dependent headroom beyond it is dedup_factor /
    # the dynamic-grid bytes recorded per entry above)
    cm_cut = (bytes_per_query(cap, d, "f32")
              / bytes_per_query_cluster_major(cap, d, "f32", n_clusters=c))
    cm_recall = min(
        zipf[f"pallas-cm@{p}"]["recall_at_10_vs_query_major"]
        for p in ("f32", "int8"))
    report = {
        "bench": "kernels",
        "config": {
            "n_objects": corpus.cfg.n_objects,
            "n_queries": int(len(te)),
            "d_model": d, "capacity": int(cap), "n_clusters": c,
            "k": K, "cr": CR, "batch": BATCH, "n_replay": N_REPLAY,
            "interpret_mode": bool(engine_lib.default_interpret()),
        },
        "sweep": sweep,
        "skew_sweep": skew_sweep,
        "traffic_model": traffic,
        "acceptance": {
            "int8_bytes_reduction_vs_f32":
                sweep["pallas@int8"]["bytes_reduction_vs_f32"],
            "int8_recall_at_10_vs_f32_dense": min(
                sweep["pallas@int8"]["recall_at_10_vs_f32_dense"],
                sweep["dense@int8"]["recall_at_10_vs_f32_dense"]),
            "cluster_major_bytes_reduction_vs_pallas": cm_cut,
            "cluster_major_recall_vs_query_major": cm_recall,
            "zipf_dedup_factor": skew_sweep["zipf"]["dedup_factor"],
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(common.fmt_row("kernels(json)", {"path": out_path}))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-scale training (same knobs as benchmarks.run)")
    args = ap.parse_args()
    if args.fast:
        common.N_OBJECTS = 1500
        common.N_QUERIES = 300
        common.REL_STEPS = 120
        common.IDX_STEPS = 250
    print("\n".join(run()))
