"""Kernel-fusion microbenchmark (CPU interpret-mode = correctness-scale
numbers; real speedups are measured via the dry-run roofline — see
EXPERIMENTS.md §Perf). Reports the BYTES saved by fusing score+spatial+topk
into one pass, which is hardware-independent."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run():
    rows = []
    # traffic model for LIST's query inner loop, per (query-block, corpus):
    # unfused: read emb (N·d·4) + write trel (N·4) + read trel + write srel
    #          + read both + write st + topk read  ≈ N(d+7)·4 bytes
    # fused:   read emb once, everything else stays in VMEM ≈ N(d+2)·4
    n, d = 2_849_754, 768     # Geo-Glue scale
    unfused = n * (d + 7) * 4
    fused = n * (d + 2) * 4
    rows.append(common.fmt_row("fused_topk_score(traffic-model)", {
        "unfused_GB": unfused / 1e9,
        "fused_GB": fused / 1e9,
        "saved_pct": 100 * (1 - fused / unfused)}))

    # flash attention: O(S²) score materialization avoided
    b, s, h, dh = 32, 32_768, 32, 128
    naive = b * h * s * s * 4                # score matrix bytes (one layer)
    flash = b * s * h * dh * 2 * 3           # just q,k,v streamed
    rows.append(common.fmt_row("flash_attention(traffic-model)", {
        "naive_score_GB": naive / 1e9,
        "flash_GB": flash / 1e9}))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
