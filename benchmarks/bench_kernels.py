"""Kernel-fusion microbenchmark (CPU interpret-mode = correctness-scale
numbers; real speedups are measured via the dry-run roofline — see
EXPERIMENTS.md §Perf). Reports the BYTES saved by fusing score+spatial+topk
into one pass, which is hardware-independent."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def run():
    rows = []
    # traffic model for LIST's query inner loop, per (query-block, corpus):
    # unfused: read emb (N·d·4) + write trel (N·4) + read trel + write srel
    #          + read both + write st + topk read  ≈ N(d+7)·4 bytes
    # fused:   read emb once, everything else stays in VMEM ≈ N(d+2)·4
    n, d = 2_849_754, 768     # Geo-Glue scale
    unfused = n * (d + 7) * 4
    fused = n * (d + 2) * 4
    rows.append(common.fmt_row("fused_topk_score(traffic-model)", {
        "unfused_GB": unfused / 1e9,
        "fused_GB": fused / 1e9,
        "saved_pct": 100 * (1 - fused / unfused)}))

    # gather path vs gather-free routed kernel (engine backend="pallas"):
    # per query batch B with cr routed clusters of capacity cap,
    # N_cand = B·cr·cap candidate rows of d floats.
    # gather:  read buffers (N·d·4) + write the (B, cr·cap, d) copy (N·d·4)
    #          + kernel re-reads the copy (N·d·4)  = 3·N·d·4
    # routed:  scalar-prefetched block-indexing streams each resident tile
    #          exactly once                         = 1·N·d·4
    bq, cr, cap = 1024, 2, 4096   # serving-shape example at Geo-Glue scale
    n_cand = bq * cr * cap
    gather = 3 * n_cand * d * 4
    routed = 1 * n_cand * d * 4
    rows.append(common.fmt_row("fused_topk_score_routed(traffic-model)", {
        "gather_GB": gather / 1e9,
        "routed_GB": routed / 1e9,
        "saved_pct": 100 * (1 - routed / gather)}))

    # correctness-scale sanity: both kernel paths agree (interpret mode)
    import jax.numpy as jnp
    from repro.core import engine
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    b, c, cap_s, d_s, k, cr_s = 8, 8, 256, 64, 10, 2
    q = jnp.asarray(rng.normal(size=(b, d_s)), jnp.float32)
    ql = jnp.asarray(rng.uniform(size=(b, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 1.5, size=(b, 2)), jnp.float32)
    be = jnp.asarray(rng.normal(size=(c, cap_s, d_s)), jnp.float32)
    bl = jnp.asarray(rng.uniform(size=(c, cap_s, 2)), jnp.float32)
    bi = jnp.asarray(np.arange(c * cap_s).reshape(c, cap_s), jnp.int32)
    tc = jnp.asarray(rng.integers(0, c, size=(b, cr_s)), jnp.int32)
    wh = jnp.asarray(np.cumsum(rng.uniform(0, 0.01, size=100)), jnp.float32)
    s_r, i_r = ops.fused_topk_score_routed(q, ql, w, tc, be, bl, bi, wh,
                                           k=k, dist_max=1.414,
                                           interpret=True)
    s_d, i_d = engine.dense_routed_topk(q, ql, w, tc, be, bl, bi, wh,
                                        k=k, dist_max=1.414)
    ok = (np.allclose(np.asarray(s_r), np.asarray(s_d), atol=1e-4)
          and (np.sort(np.asarray(i_r)) == np.sort(np.asarray(i_d))).all())
    rows.append(common.fmt_row("fused_topk_score_routed(parity-smoke)", {
        "b": b, "cr": cr_s, "cap": cap_s, "agrees_with_dense": float(ok)}))

    # flash attention: O(S²) score materialization avoided
    b, s, h, dh = 32, 32_768, 32, 128
    naive = b * h * s * s * 4                # score matrix bytes (one layer)
    flash = b * s * h * dh * 2 * 3           # just q,k,v streamed
    rows.append(common.fmt_row("flash_attention(traffic-model)", {
        "naive_score_GB": naive / 1e9,
        "flash_GB": flash / 1e9}))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
