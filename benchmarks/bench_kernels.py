"""Kernel roofline benchmark: the query-phase scan per backend × precision.

LIST's query phase is a memory-bound corpus scan (DESIGN.md §4): the
roofline is set by how many bytes of resident cluster buffer stream
through HBM per query. The precision policy (DESIGN.md §9) attacks
exactly that stream — bf16 halves it, int8 cuts it ~4× (symmetric
per-row scalar quantization, dequantized in VMEM inside the kernel).

This bench trains one retriever, requantizes its snapshot at every tier
(``IndexSnapshot.with_precision`` — same routing, same loc/ids), and for
each (backend × precision) measures

* wall time per query batch (CPU interpret-mode = correctness-scale
  numbers off-TPU; the bytes model below is the hardware-independent
  part),
* **estimated HBM bytes streamed per query** — the scanned slice is
  ``cr·cap`` candidate rows, each costing the embedding row in the
  tier's storage dtype, its f32 dequant scale (int8 only), the exact
  f32 location pair, and the int32 id,
* **recall@10 vs the f32 dense oracle** — routing is precision-
  independent (it reads query features only), so this isolates pure
  quantization-induced rank churn inside the scanned candidates.

Emits ``BENCH_kernels.json`` (schema in README.md §Benchmarks) to start
the kernel-level perf trajectory next to ``BENCH_serving.json``. The
acceptance bar tracked by CI: int8 streams ≥3.5× fewer estimated bytes
than f32 at recall@10 ≥ 0.99.

    PYTHONPATH=src python -m benchmarks.bench_kernels [--fast]
"""
from __future__ import annotations

import json
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.core import engine as engine_lib
from repro.core import index as index_lib

OUT_PATH = "BENCH_kernels.json"

K = 10
CR = 2
BATCH = 64
REPEATS = 3
D_MODEL = 128          # bench-scale d; large enough that the exact
                       # loc/ids sidecar doesn't mask the emb-stream cut

_EMB_BYTES = {"f32": 4, "bf16": 2, "int8": 1}


def bytes_per_query(cap: int, d: int, precision: str, *, cr: int = CR) -> int:
    """Estimated HBM bytes the scan streams per query: cr·cap candidate
    rows of (emb in storage dtype + f32 scale (int8 only) + exact f32
    loc (2×4) + int32 id)."""
    row = d * _EMB_BYTES[precision] + (4 if precision == "int8" else 0) \
        + 2 * 4 + 4
    return cr * cap * row


def _recall_vs_oracle(ids, oracle_ids) -> float:
    inter = [len(set(a.tolist()) & set(b.tolist())) / oracle_ids.shape[1]
             for a, b in zip(ids, oracle_ids)]
    return float(np.mean(inter))


def _time_queries(searcher, corpus, te, backend):
    ids, _ = searcher.query_corpus(corpus, te, k=K, cr=CR, batch=BATCH,
                                   backend=backend)        # warm + result
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        searcher.query_corpus(corpus, te, k=K, cr=CR, batch=BATCH,
                              backend=backend)
    wall = (time.perf_counter() - t0) / REPEATS
    return ids, wall


def run(out_path: str = OUT_PATH):
    r = common.get_retriever(tag=f"kernels-d{D_MODEL}",
                             cfg_over={"d_model": D_MODEL})
    corpus = common.get_corpus()
    te, _ = common.test_split_positives(corpus)
    snap = r.snapshot()
    cap = snap.buffers["capacity"]
    d = snap.cfg.d_model

    oracle_searcher = api.Searcher(snap, backend="dense")
    oracle_ids, oracle_wall = _time_queries(oracle_searcher, corpus, te,
                                            "dense")

    f32_bytes = bytes_per_query(cap, d, "f32")
    sweep = {}
    rows = []
    for precision in index_lib.PRECISIONS:
        snap_p = snap.with_precision(precision)
        est = bytes_per_query(cap, d, precision)
        for backend in ("dense", "pallas"):
            if (backend, precision) == ("dense", "f32"):
                ids, wall = oracle_ids, oracle_wall    # it IS the oracle
            else:
                ids, wall = _time_queries(
                    api.Searcher(snap_p, backend=backend), corpus, te,
                    backend)
            entry = {
                "wall_ms_per_batch": wall / max(1, -(-len(te) // BATCH))
                * 1e3,
                "est_hbm_bytes_per_query": est,
                "bytes_reduction_vs_f32": f32_bytes / est,
                "recall_at_10_vs_f32_dense": _recall_vs_oracle(ids,
                                                               oracle_ids),
            }
            sweep[f"{backend}@{precision}"] = entry
            rows.append(common.fmt_row(
                f"kernel_scan({backend}@{precision})", {
                    "ms/batch": entry["wall_ms_per_batch"],
                    "MBq": est / 1e6,
                    "bytes_cut": entry["bytes_reduction_vs_f32"],
                    "recall@10_vs_f32": entry["recall_at_10_vs_f32_dense"],
                }))

    # hardware-independent traffic models (paper-scale d=768, Geo-Glue):
    # fusing score+spatial+topk keeps everything but the emb stream in
    # VMEM; the routed kernel reads the scanned slice once vs 3× for the
    # gather path; int8 then shrinks that one stream itself
    n_paper, d_paper = 2_849_754, 768
    unfused = n_paper * (d_paper + 7) * 4
    fused = n_paper * (d_paper + 2) * 4
    traffic = {
        "fused_vs_unfused_saved_pct": 100 * (1 - fused / unfused),
        "routed_vs_gather_saved_pct": 100 * (1 - 1 / 3),
        "int8_vs_f32_paper_scale_reduction":
            bytes_per_query(1, d_paper, "f32", cr=1)
            / bytes_per_query(1, d_paper, "int8", cr=1),
    }
    rows.append(common.fmt_row("traffic-model(paper-scale)", traffic))

    report = {
        "bench": "kernels",
        "config": {
            "n_objects": corpus.cfg.n_objects,
            "n_queries": int(len(te)),
            "d_model": d, "capacity": int(cap), "k": K, "cr": CR,
            "batch": BATCH,
            "interpret_mode": bool(engine_lib.default_interpret()),
        },
        "sweep": sweep,
        "traffic_model": traffic,
        "acceptance": {
            "int8_bytes_reduction_vs_f32":
                sweep["pallas@int8"]["bytes_reduction_vs_f32"],
            "int8_recall_at_10_vs_f32_dense": min(
                sweep["pallas@int8"]["recall_at_10_vs_f32_dense"],
                sweep["dense@int8"]["recall_at_10_vs_f32_dense"]),
        },
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    rows.append(common.fmt_row("kernels(json)", {"path": out_path}))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-scale training (same knobs as benchmarks.run)")
    args = ap.parse_args()
    if args.fast:
        common.N_OBJECTS = 1500
        common.N_QUERIES = 300
        common.REL_STEPS = 120
        common.IDX_STEPS = 250
    print("\n".join(run()))
