"""Shared benchmark harness: train ONE retriever, reuse across tables.

The trained state is cached in-process (module singleton) so that
``python -m benchmarks.run`` trains once and every bench reads it. Scale is
chosen so the full suite finishes on one CPU in ~10 min; the same harness
runs the paper-scale datasets on a real fleet.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.core import index as il
from repro.core import pipeline as pl
from repro.data import GeoCorpus, GeoCorpusConfig

# benchmark-scale knobs (CPU-feasible analogue of the paper's datasets)
N_OBJECTS = 4000
N_QUERIES = 600
N_TOPICS = 16
N_CLUSTERS = 8
REL_STEPS = 300
IDX_STEPS = 600
SEED = 0

_STATE = {}


def bench_cfg(**over):
    base = dict(
        n_layers=4, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=N_CLUSTERS,
        neg_start=N_OBJECTS // 2, neg_end=N_OBJECTS // 2 + 200,
        index_mlp_hidden=(128,))
    base.update(over)
    return dataclasses.replace(get_config("list-dual-encoder"), **base)


def get_corpus():
    if "corpus" not in _STATE:
        _STATE["corpus"] = GeoCorpus(GeoCorpusConfig(
            n_objects=N_OBJECTS, n_queries=N_QUERIES, n_topics=N_TOPICS,
            vocab_size=4096, seed=SEED))
    return _STATE["corpus"]


def get_retriever(*, spatial_mode="step", weight_mode="mlp",
                  rel_steps=REL_STEPS, idx_steps=IDX_STEPS, tag=None,
                  with_index=True, cfg_over=None):
    """One trained retriever per ``tag`` (cached in-process). ``cfg_over``
    overrides bench_cfg fields (pass a distinct ``tag`` with it, or the
    cache would alias differently-configured retrievers)."""
    key = tag or f"{spatial_mode}-{weight_mode}"
    if key not in _STATE:
        corpus = get_corpus()
        r = pl.ListRetriever(bench_cfg(**(cfg_over or {})), corpus,
                             spatial_mode=spatial_mode,
                             weight_mode=weight_mode)
        t0 = time.time()
        r.train_relevance(steps=rel_steps, batch=64, lr=1e-3, log_every=10**9)
        if with_index:
            r.train_index(steps=idx_steps, batch=64, lr=3e-3,
                          log_every=10**9)
            r.build()
        else:
            r.ensure_embeddings()
        r.train_seconds = time.time() - t0
        _STATE[key] = r
    return _STATE[key]


def eval_ranking(ids, positives):
    return {
        "recall@20": cm.recall_at_k(ids, positives, 20),
        "recall@10": cm.recall_at_k(ids, positives, 10),
        "ndcg@5": cm.ndcg_at_k(ids, positives, 5),
        "ndcg@1": cm.ndcg_at_k(ids, positives, 1),
    }


def test_split_positives(corpus):
    tr, va, te = corpus.split()
    return te, [corpus.positives[q] for q in te]


def query_cluster_assign(r, query_ids):
    q_emb = pl.embed_queries(r.rel_params, r.corpus, r.cfg, query_ids)
    qf = il.build_features(
        jnp.asarray(q_emb),
        jnp.asarray(r.corpus.q_loc[query_ids].astype(np.float32)), r.norm)
    return np.asarray(il.assign_clusters(r.index_params, qf))


def fmt_row(name: str, metrics: dict, extra: str = "") -> str:
    body = ",".join(f"{k}={v:.4f}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in metrics.items())
    return f"{name},{body}" + (f",{extra}" if extra else "")
