"""Filtered-search + continuous-query benchmark (core/filters.py,
core/continuous.py, DESIGN.md §13).

Two acceptance measurements, appended as the ``filters`` section of
``BENCH_serving.json`` (the serving perf trajectory file) and gated in
CI (.github/workflows/ci.yml, ``filtered-parity`` job):

* **Filtered throughput within ~2× of unfiltered at equal recall.**
  The same query batch runs through the warmed engine twice: once
  unfiltered, once under a PASS-ALL (but non-no-op) FilterSpec — the
  predicate mask streams and evaluates for every candidate, yet admits
  every row, so the two answers are id-identical (recall is EQUAL by
  construction, not approximately). The slowdown ratio isolates the
  pure predicate-mask overhead. A selective per-tenant filter is also
  timed for color (its recall target differs, so it carries no gate).

* **Subscription dispatch cost O(distinct routed clusters), measured.**
  A roster of S standing queries receives an insert batch; a spy on
  ``engine.score_candidates`` counts the actual scoring calls the
  reversed cluster-major dispatch makes. The gate is exact equality
  with the number of distinct assigned clusters (≤ n_clusters) — NOT
  with S — demonstrated at two roster sizes (8 and 8·8): same call
  count, roster size 8× larger.

    PYTHONPATH=src python -m benchmarks.bench_filters [--fast]
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common
from repro.core import continuous as cont_lib
from repro.core import engine as engine_lib
from repro.core import filters as filters_lib
from repro.core import server as server_lib

OUT_PATH = "BENCH_serving.json"

K = 10
CR = 2
BATCH = 64
N_TENANTS = 4
REPEATS = 5                  # timing repeats; the median is reported
ROSTERS = (8, 64)            # dispatch-economics roster sizes
INSERT_BATCH = 64
SLOWDOWN_MAX = 2.0

# pass-all but NON-no-op: the time window spans every int32 timestamp
# except the degenerate empty range, so the filtered plan runs its full
# predicate per candidate while admitting every row
PASS_ALL = filters_lib.FilterSpec(t_min=filters_lib.INT32_MIN,
                                  t_max=filters_lib.INT32_MAX - 1)
assert not PASS_ALL.is_noop


def _attrs_snapshot(r):
    """The trained snapshot with a synthetic multi-tenant attribute
    table: tenant round-robin by id, one category bit, timestamp = id."""
    snap = r.snapshot()
    bi = np.asarray(snap.buffers["ids"])
    flat = bi.reshape(-1)
    tenants = np.where(flat >= 0, flat % N_TENANTS, 0)
    cats = np.where(flat >= 0, 1 << (flat % 4), 0)
    ts = np.maximum(flat, 0)
    attrs = np.stack([tenants, cats, ts], axis=-1).astype(np.int32)
    buf = dict(snap.buffers)
    buf["attrs"] = attrs.reshape(bi.shape + (3,))
    return snap.with_buffers(buf)


def _timed_query(eng, snap, tok, msk, loc, *, filters, repeats=REPEATS):
    outs = None
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        outs = eng.query(tok, msk, loc, k=K, cr=CR, batch=BATCH,
                         snapshot=snap, filters=filters)
        walls.append(time.perf_counter() - t0)
    return outs, float(np.median(walls))


def _dispatch_economics(r, snap, corpus, te):
    """Measured dispatch cost per insert batch at two roster sizes."""
    rng = np.random.default_rng(common.SEED + 83)
    d = snap.cfg.d_model
    rows = {}
    for s_count in ROSTERS:
        eng = engine_lib.QueryEngine.from_snapshot(snap, backend="dense")
        server = server_lib.StreamingServer(eng, server_lib.ServerConfig(
            batch_size=8, k=K, cr=CR, backend="dense"))
        picks = te[rng.integers(0, len(te), s_count)]
        tok, msk = corpus.query_tokens(picks)
        qloc = corpus.q_loc[picks].astype(np.float32)
        for i in range(s_count):
            server.subscribe(tok[i], msk[i], qloc[i], threshold=-1e9)
        emb = rng.normal(size=(INSERT_BATCH, d)).astype(np.float32)
        oloc = rng.uniform(size=(INSERT_BATCH, 2)).astype(np.float32)
        ids = np.arange(10 ** 6 + s_count * 10 ** 4,
                        10 ** 6 + s_count * 10 ** 4 + INSERT_BATCH)
        attrs = filters_lib.make_attrs(np.arange(INSERT_BATCH) % N_TENANTS,
                                       1, np.arange(INSERT_BATCH))
        calls = []
        orig = engine_lib.score_candidates

        def counted(*a, **kw):
            calls.append(1)
            return orig(*a, **kw)

        cont_lib.engine_lib.score_candidates = counted
        try:
            t0 = time.perf_counter()
            server.insert_objects(emb, oloc, ids, attrs)
            wall = time.perf_counter() - t0
        finally:
            cont_lib.engine_lib.score_candidates = orig
        m = server.subscriptions.metrics()
        rows[s_count] = {
            "roster_size": s_count,
            "scoring_calls": len(calls),
            "distinct_clusters": m["distinct_clusters"],
            "notifications": m["notifications"],
            "dispatch_ms": wall * 1e3,
        }
    return rows


def run(out_path: str = OUT_PATH):
    r = common.get_retriever()
    corpus = common.get_corpus()
    te, _ = common.test_split_positives(corpus)
    snap = _attrs_snapshot(r)
    eng = engine_lib.QueryEngine.from_snapshot(snap, backend="dense")

    tok, msk = corpus.query_tokens(te)
    loc = corpus.q_loc[te].astype(np.float32)

    # warm both plans (unfiltered + filtered) before timing
    eng.query(tok[:BATCH], msk[:BATCH], loc[:BATCH], k=K, cr=CR,
              batch=BATCH, snapshot=snap)
    eng.query(tok[:BATCH], msk[:BATCH], loc[:BATCH], k=K, cr=CR,
              batch=BATCH, snapshot=snap, filters=PASS_ALL)

    (ids_u, _), t_unf = _timed_query(eng, snap, tok, msk, loc,
                                     filters=None)
    (ids_f, _), t_pass = _timed_query(eng, snap, tok, msk, loc,
                                      filters=PASS_ALL)
    ids_equal = bool(np.array_equal(ids_u, ids_f))   # ⇒ recall EQUAL
    slowdown = t_pass / t_unf
    # selective tenant slice, reported for color (no recall gate: the
    # target set itself shrinks to one tenant's rows)
    tenant_spec = filters_lib.FilterSpec(tenant=1)
    (ids_t, _), t_tenant = _timed_query(eng, snap, tok, msk, loc,
                                        filters=tenant_spec)
    live = ids_t[ids_t >= 0]
    isolation_ok = bool((live % N_TENANTS == 1).all()) if live.size else True

    econ = _dispatch_economics(r, snap, corpus, te)
    o_distinct = all(econ[s]["scoring_calls"] == econ[s]["distinct_clusters"]
                     for s in ROSTERS)
    roster_free = (econ[ROSTERS[1]]["scoring_calls"]
                   <= snap.cfg.n_clusters)

    n_queries = len(te)
    acceptance = {
        "filtered_slowdown": slowdown,
        "filtered_slowdown_max": SLOWDOWN_MAX,
        "ids_identical_at_equal_recall": ids_equal,
        "tenant_isolation": isolation_ok,
        "dispatch_calls_equal_distinct_clusters": bool(o_distinct),
        "dispatch_calls_bounded_by_n_clusters": bool(roster_free),
    }
    acceptance["pass"] = bool(
        slowdown <= SLOWDOWN_MAX and ids_equal and isolation_ok
        and o_distinct and roster_free)

    section = {
        "config": {"k": K, "cr": CR, "batch": BATCH,
                   "n_queries": int(n_queries), "n_tenants": N_TENANTS,
                   "rosters": list(ROSTERS), "insert_batch": INSERT_BATCH},
        "unfiltered_qps": n_queries / t_unf,
        "passall_filtered_qps": n_queries / t_pass,
        "tenant_filtered_qps": n_queries / t_tenant,
        "dispatch": econ,
        "acceptance": acceptance,
    }

    # append as the `filters` section of the serving perf file
    report = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError):
            report = {}
    report.setdefault("bench", "serving")
    report["filters"] = section
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    return [
        common.fmt_row("serving(filters)", {
            "unfiltered_qps": section["unfiltered_qps"],
            "passall_qps": section["passall_filtered_qps"],
            "tenant_qps": section["tenant_filtered_qps"],
            "slowdown": slowdown,
            "ids_identical": int(ids_equal),
            "pass": int(acceptance["pass"])}),
        common.fmt_row("serving(subscriptions)", {
            f"calls@{ROSTERS[0]}": econ[ROSTERS[0]]["scoring_calls"],
            f"calls@{ROSTERS[1]}": econ[ROSTERS[1]]["scoring_calls"],
            "distinct_clusters": econ[ROSTERS[1]]["distinct_clusters"],
            "dispatch_ms": econ[ROSTERS[1]]["dispatch_ms"],
            "o_distinct": int(o_distinct)}),
        common.fmt_row("serving(filters,json)", {"path": out_path}),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-scale training (same knobs as benchmarks.run)")
    args = ap.parse_args()
    if args.fast:
        common.N_OBJECTS = 1500
        common.N_QUERIES = 300
        common.REL_STEPS = 120
        common.IDX_STEPS = 250
    print("\n".join(run()))
