"""Serving-resilience benchmark (DESIGN.md §14): overload shedding and
crash recovery, merged as the ``resilience`` section of
``BENCH_serving.json`` (same merge pattern as bench_filters.py).

Two legs:

* **Overload** — with result caches OFF (every request is real engine
  work), take the unloaded baseline from a fixed-concurrency closed
  loop (full batches, at most one batch queued — the server's best
  sustainable shape), bound capacity by the engine's own blocking
  service time (the event loop stalls for the whole batch, so the
  server can never exceed ``BATCH / service_time``), then drive an
  open-loop workload at **2× that bound** with a per-request deadline
  and a bounded admission queue. The designed behavior under overload
  is to shed the excess and keep the admitted requests fast; the
  acceptance block gates ``p99(admitted) <= 2 × p99(unloaded)``, a
  non-trivial shed fraction (counted in server metrics), and request
  conservation (served + shed == offered — nothing hangs, every
  arrival is accounted for).

* **Recovery** — run acknowledged write batches through a WAL-enabled
  server, "crash" it (drop it without checkpointing, exactly what a
  process death leaves on disk), then ``api.recover`` from the saved
  snapshot + WAL and gate ``recovered_writes == acked_writes`` plus
  bit-identical full-fanout query results vs the never-crashed server.

    PYTHONPATH=src python -m benchmarks.bench_resilience [--fast]
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.core import server as server_lib

OUT_PATH = "BENCH_serving.json"

BATCH = 32
MAX_DELAY_MS = 2.0
K = 10
CR = 1
CAPACITY_REQUESTS = 384     # closed-loop probe sizing the engine's rate
LOAD_REQUESTS = 512         # per open-loop leg
OVERLOAD_FACTOR = 2.0
WRITE_BATCHES = 6           # acked write batches the recovery leg replays
WRITE_ROWS = 8


def _requests(corpus, te, n, *, seed):
    """n all-distinct requests (cache/coalesce can never collapse two):
    test-split queries with a per-request location nudge."""
    rng = np.random.default_rng(seed)
    picks = te[rng.integers(0, len(te), size=n)]
    tok, msk = corpus.query_tokens(picks)
    loc = corpus.q_loc[picks].astype(np.float32)
    loc = np.clip(loc + rng.uniform(1e-6, 1e-4, size=loc.shape)
                  * np.arange(1, n + 1, dtype=np.float32)[:, None], 0, 1)
    return [(tok[i], msk[i], loc[i]) for i in range(n)]


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _mk_server(engine, **over):
    cfg = server_lib.ServerConfig(
        batch_size=BATCH, max_delay_ms=MAX_DELAY_MS, k=K, cr=CR,
        cache_size=0, near_cells=0, **over)
    return server_lib.StreamingServer(engine, cfg)


def _overload(engine, corpus, te):
    server = _mk_server(engine)
    server.warmup()

    # unloaded baseline: fixed-concurrency closed loop = full batches
    # with at most one batch queued — the best shape the micro-batcher
    # can sustain (an open loop BELOW capacity would flush ragged
    # deadline batches and pay the static-shape padding for a handful
    # of rows, which is slower than the loaded server — not a baseline)
    reqs = _requests(corpus, te, CAPACITY_REQUESTS, seed=common.SEED + 3)
    asyncio.run(server_lib.closed_loop(server, reqs, concurrency=BATCH))
    p99_unloaded = server.metrics()["latency_ms"]["p99"]

    # capacity bound: the engine call blocks the event loop for a whole
    # batch, so the server can never exceed BATCH / service_time; the
    # best-of-N direct timing is the TIGHTEST such bound, making the 2×
    # leg overload by construction
    probe = _requests(corpus, te, BATCH, seed=common.SEED + 5)
    tok = np.stack([p[0] for p in probe])
    msk = np.stack([p[1] for p in probe])
    loc = np.stack([p[2] for p in probe])
    service_s = min(
        _timed(lambda: engine.query(tok, msk, loc, k=K, cr=CR,
                                    batch=BATCH))
        for _ in range(3))
    capacity_qps = BATCH / service_s

    # overload: 2× capacity against a deadline + bounded queue. An
    # admitted request pays at most its queue wait (<= deadline at the
    # flush-time check), the in-flight flush blocking the event loop,
    # and its own batch service — so budgeting
    # ``deadline = 2*p99_unloaded - 2*service`` (with slack for timer
    # jitter) keeps admitted p99 inside the 2× gate by construction,
    # PROVIDED shedding actually enforces the deadline.
    service_ms = service_s * 1e3
    timeout_ms = max(2.0 * p99_unloaded - 2.2 * service_ms, 1.0)
    over = _mk_server(engine, request_timeout_ms=timeout_ms,
                      max_queue=4 * BATCH)
    reqs = _requests(corpus, te, LOAD_REQUESTS, seed=common.SEED + 7)
    results = asyncio.run(server_lib.open_loop(
        over, reqs, qps=OVERLOAD_FACTOR * capacity_qps, shed_ok=True))
    m = over.metrics()
    served = sum(1 for r in results if r is not None)
    shed = sum(m["shed"].values())
    p99_admitted = m["latency_ms"]["p99"]

    return {
        "capacity_qps": capacity_qps,
        "overload_qps": OVERLOAD_FACTOR * capacity_qps,
        "request_timeout_ms": timeout_ms,
        "max_queue": 4 * BATCH,
        "offered": len(reqs),
        "served": served,
        "shed": dict(m["shed"]),
        "p99_unloaded_ms": p99_unloaded,
        "p99_admitted_ms": p99_admitted,
    }


def _recovery(snap0, corpus, te):
    """Acked writes → crash (no checkpoint) → api.recover → parity."""
    root = tempfile.mkdtemp(prefix="bench_resilience_")
    snap_dir = os.path.join(root, "snap")
    wal_dir = os.path.join(root, "wal")
    cfg = server_lib.ServerConfig(
        batch_size=BATCH, max_delay_ms=MAX_DELAY_MS, k=K, cr=CR,
        cache_size=0, near_cells=0, wal_dir=wal_dir,
        delta_threshold=WRITE_BATCHES * WRITE_ROWS * 4)
    try:
        api.save(snap0, snap_dir)
        victim = api.Searcher(snap0).serve(cfg)
        rng = np.random.default_rng(common.SEED + 11)
        d = int(np.asarray(snap0.buffers["emb"]).shape[-1])
        next_id = 20_000_000
        acked = 0
        t_wal = []
        for _ in range(WRITE_BATCHES):
            emb = rng.normal(size=(WRITE_ROWS, d)).astype(np.float32)
            loc = rng.uniform(size=(WRITE_ROWS, 2)).astype(np.float32)
            ids = np.arange(next_id, next_id + WRITE_ROWS)
            next_id += WRITE_ROWS
            t0 = time.perf_counter()
            victim.insert_objects(emb, loc, ids)
            t_wal.append((time.perf_counter() - t0) * 1e3)
            acked += 1
        # "crash": the process dies here — no checkpoint, no compaction;
        # everything acked above lives only in the delta segment + WAL
        victim.close()

        t0 = time.perf_counter()
        recovered = api.recover(snap_dir, wal_dir, config=cfg)
        recover_ms = (time.perf_counter() - t0) * 1e3

        # parity probe at full fanout: the recovered index must answer
        # exactly like the never-crashed one
        probe = te[:min(len(te), 64)]
        tok, msk = corpus.query_tokens(probe)
        loc = corpus.q_loc[probe].astype(np.float32)
        c = int(np.asarray(snap0.buffers["emb"]).shape[0])
        a = victim.engine.query(tok, msk, loc, k=K, cr=c, batch=BATCH)
        b = recovered.engine.query(tok, msk, loc, k=K, cr=c, batch=BATCH)
        identical = bool(np.array_equal(a[0], b[0])
                         and np.array_equal(a[1], b[1]))
        out = {
            "acked_writes": acked,
            "recovered_writes": recovered.stats.recovered_writes,
            "wal_append_ms_median": float(np.median(t_wal)),
            "recover_ms": recover_ms,
            "query_parity": identical,
        }
        recovered.close()
        return out
    finally:
        shutil.rmtree(root, ignore_errors=True)


def run(out_path: str = OUT_PATH):
    r = common.get_retriever()
    corpus = common.get_corpus()
    te, _ = common.test_split_positives(corpus)
    engine = r.engine()

    overload = _overload(engine, corpus, te)
    recovery = _recovery(engine.snapshot, corpus, te)

    shed_total = sum(overload["shed"].values())
    acceptance = {
        "p99_ratio": overload["p99_admitted_ms"]
        / max(overload["p99_unloaded_ms"], 1e-9),
        "p99_ratio_max": 2.0,
        "shed_fraction": shed_total / overload["offered"],
        "shed_fraction_min": 0.05,
        "conservation_ok": overload["served"] + shed_total
        == overload["offered"],
        "recovered_writes": recovery["recovered_writes"],
        "acked_writes": recovery["acked_writes"],
        "recovery_ok": recovery["recovered_writes"]
        == recovery["acked_writes"] and recovery["query_parity"],
    }
    acceptance["pass"] = bool(
        acceptance["p99_ratio"] <= acceptance["p99_ratio_max"]
        and acceptance["shed_fraction"] >= acceptance["shed_fraction_min"]
        and acceptance["conservation_ok"]
        and acceptance["recovery_ok"])

    section = {
        "overload": overload,
        "recovery": recovery,
        "acceptance": acceptance,
    }
    report = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError):
            report = {}
    report.setdefault("bench", "serving")
    report["resilience"] = section
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    return [
        common.fmt_row("serving(overload)", {
            "capacity_qps": overload["capacity_qps"],
            "p99_unloaded_ms": overload["p99_unloaded_ms"],
            "p99_admitted_ms": overload["p99_admitted_ms"],
            "p99_ratio": acceptance["p99_ratio"],
            "shed_fraction": acceptance["shed_fraction"],
            "served": overload["served"]}),
        common.fmt_row("serving(recovery)", {
            "acked": recovery["acked_writes"],
            "recovered": recovery["recovered_writes"],
            "parity": int(recovery["query_parity"]),
            "recover_ms": recovery["recover_ms"],
            "wal_append_ms": recovery["wal_append_ms_median"]}),
        common.fmt_row("serving(resilience)", {
            "pass": int(acceptance["pass"]), "path": out_path}),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-scale training (same knobs as benchmarks.run)")
    args = ap.parse_args()
    if args.fast:
        common.N_OBJECTS = 1500
        common.N_QUERIES = 300
        common.REL_STEPS = 120
        common.IDX_STEPS = 250
    print("\n".join(run()))
