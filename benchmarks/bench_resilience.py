"""Serving-resilience benchmark (DESIGN.md §14): overload shedding and
crash recovery, merged as the ``resilience`` section of
``BENCH_serving.json`` (same merge pattern as bench_filters.py).

Two legs:

* **Overload** — with result caches OFF (every request is real engine
  work), take the unloaded baseline from a fixed-concurrency closed
  loop (full batches, at most one batch queued — the server's best
  sustainable shape), bound capacity by the engine's own blocking
  service time (the event loop stalls for the whole batch, so the
  server can never exceed ``BATCH / service_time``), then drive an
  open-loop workload at **2× that bound** with a per-request deadline
  and a bounded admission queue. The designed behavior under overload
  is to shed the excess and keep the admitted requests fast; the
  acceptance block gates ``p99(admitted) <= 2 × p99(unloaded)``, a
  non-trivial shed fraction (counted in server metrics), and request
  conservation (served + shed == offered — nothing hangs, every
  arrival is accounted for).

* **Recovery** — run acknowledged write batches through a WAL-enabled
  server, "crash" it (drop it without checkpointing, exactly what a
  process death leaves on disk), then ``api.recover`` from the saved
  snapshot + WAL and gate ``recovered_writes == acked_writes`` plus
  bit-identical full-fanout query results vs the never-crashed server.

A third leg, **mesh-chaos** (DESIGN.md §15), runs only when ≥ 2 local
devices are visible (the CI ``mesh-chaos`` job exports
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; elsewhere it
records a skip without touching the ``resilience`` gate). It shards the
index across S devices, kills one shard with a persistent
``shard.scan_error``, and gates: zero failed requests while degraded,
minimum coverage exactly ``(S-1)/S`` (full-fanout routing makes the
fraction exact), degraded recall@10 within ``2.5/S`` of healthy,
surviving-shard ids bit-identical to a single-device oracle whose view
of the dead shard's clusters is empty, and — after an online
``recover_shard`` — results bit-identical to the healthy pass. Written
as its own ``mesh_chaos`` section of ``BENCH_serving.json``.

    PYTHONPATH=src python -m benchmarks.bench_resilience [--fast]
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.core import server as server_lib

OUT_PATH = "BENCH_serving.json"

BATCH = 32
MAX_DELAY_MS = 2.0
K = 10
CR = 1
CAPACITY_REQUESTS = 384     # closed-loop probe sizing the engine's rate
LOAD_REQUESTS = 512         # per open-loop leg
OVERLOAD_FACTOR = 2.0
WRITE_BATCHES = 6           # acked write batches the recovery leg replays
WRITE_ROWS = 8
CHAOS_REQUESTS = 64         # per mesh-chaos pass (healthy/degraded/recovered)
CHAOS_VICTIM = 3            # shard killed by the injected fault
RECALL_DROP_BOUND = 2.5     # max recall@10 drop while degraded, × 1/S


def _requests(corpus, te, n, *, seed):
    """n all-distinct requests (cache/coalesce can never collapse two):
    test-split queries with a per-request location nudge."""
    rng = np.random.default_rng(seed)
    picks = te[rng.integers(0, len(te), size=n)]
    tok, msk = corpus.query_tokens(picks)
    loc = corpus.q_loc[picks].astype(np.float32)
    loc = np.clip(loc + rng.uniform(1e-6, 1e-4, size=loc.shape)
                  * np.arange(1, n + 1, dtype=np.float32)[:, None], 0, 1)
    return [(tok[i], msk[i], loc[i]) for i in range(n)]


def _timed(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _mk_server(engine, **over):
    kw = dict(batch_size=BATCH, max_delay_ms=MAX_DELAY_MS, k=K, cr=CR,
              cache_size=0, near_cells=0)
    kw.update(over)
    return server_lib.StreamingServer(engine, server_lib.ServerConfig(**kw))


def _overload(engine, corpus, te):
    server = _mk_server(engine)
    server.warmup()

    # unloaded baseline: fixed-concurrency closed loop = full batches
    # with at most one batch queued — the best shape the micro-batcher
    # can sustain (an open loop BELOW capacity would flush ragged
    # deadline batches and pay the static-shape padding for a handful
    # of rows, which is slower than the loaded server — not a baseline)
    reqs = _requests(corpus, te, CAPACITY_REQUESTS, seed=common.SEED + 3)
    asyncio.run(server_lib.closed_loop(server, reqs, concurrency=BATCH))
    p99_unloaded = server.metrics()["latency_ms"]["p99"]

    # capacity bound: the engine call blocks the event loop for a whole
    # batch, so the server can never exceed BATCH / service_time; the
    # best-of-N direct timing is the TIGHTEST such bound, making the 2×
    # leg overload by construction
    probe = _requests(corpus, te, BATCH, seed=common.SEED + 5)
    tok = np.stack([p[0] for p in probe])
    msk = np.stack([p[1] for p in probe])
    loc = np.stack([p[2] for p in probe])
    service_s = min(
        _timed(lambda: engine.query(tok, msk, loc, k=K, cr=CR,
                                    batch=BATCH))
        for _ in range(3))
    capacity_qps = BATCH / service_s

    # overload: 2× capacity against a deadline + bounded queue. An
    # admitted request pays at most its queue wait (<= deadline at the
    # flush-time check), the in-flight flush blocking the event loop,
    # and its own batch service — so budgeting
    # ``deadline = 2*p99_unloaded - 2*service`` (with slack for timer
    # jitter) keeps admitted p99 inside the 2× gate by construction,
    # PROVIDED shedding actually enforces the deadline.
    service_ms = service_s * 1e3
    timeout_ms = max(2.0 * p99_unloaded - 2.2 * service_ms, 1.0)
    over = _mk_server(engine, request_timeout_ms=timeout_ms,
                      max_queue=4 * BATCH)
    reqs = _requests(corpus, te, LOAD_REQUESTS, seed=common.SEED + 7)
    results = asyncio.run(server_lib.open_loop(
        over, reqs, qps=OVERLOAD_FACTOR * capacity_qps, shed_ok=True))
    m = over.metrics()
    served = sum(1 for r in results if r is not None)
    shed = sum(m["shed"].values())
    p99_admitted = m["latency_ms"]["p99"]

    return {
        "capacity_qps": capacity_qps,
        "overload_qps": OVERLOAD_FACTOR * capacity_qps,
        "request_timeout_ms": timeout_ms,
        "max_queue": 4 * BATCH,
        "offered": len(reqs),
        "served": served,
        "shed": dict(m["shed"]),
        "p99_unloaded_ms": p99_unloaded,
        "p99_admitted_ms": p99_admitted,
    }


def _recovery(snap0, corpus, te):
    """Acked writes → crash (no checkpoint) → api.recover → parity."""
    root = tempfile.mkdtemp(prefix="bench_resilience_")
    snap_dir = os.path.join(root, "snap")
    wal_dir = os.path.join(root, "wal")
    cfg = server_lib.ServerConfig(
        batch_size=BATCH, max_delay_ms=MAX_DELAY_MS, k=K, cr=CR,
        cache_size=0, near_cells=0, wal_dir=wal_dir,
        delta_threshold=WRITE_BATCHES * WRITE_ROWS * 4)
    try:
        api.save(snap0, snap_dir)
        victim = api.Searcher(snap0).serve(cfg)
        rng = np.random.default_rng(common.SEED + 11)
        d = int(np.asarray(snap0.buffers["emb"]).shape[-1])
        next_id = 20_000_000
        acked = 0
        t_wal = []
        for _ in range(WRITE_BATCHES):
            emb = rng.normal(size=(WRITE_ROWS, d)).astype(np.float32)
            loc = rng.uniform(size=(WRITE_ROWS, 2)).astype(np.float32)
            ids = np.arange(next_id, next_id + WRITE_ROWS)
            next_id += WRITE_ROWS
            t0 = time.perf_counter()
            victim.insert_objects(emb, loc, ids)
            t_wal.append((time.perf_counter() - t0) * 1e3)
            acked += 1
        # "crash": the process dies here — no checkpoint, no compaction;
        # everything acked above lives only in the delta segment + WAL
        victim.close()

        t0 = time.perf_counter()
        recovered = api.recover(snap_dir, wal_dir, config=cfg)
        recover_ms = (time.perf_counter() - t0) * 1e3

        # parity probe at full fanout: the recovered index must answer
        # exactly like the never-crashed one
        probe = te[:min(len(te), 64)]
        tok, msk = corpus.query_tokens(probe)
        loc = corpus.q_loc[probe].astype(np.float32)
        c = int(np.asarray(snap0.buffers["emb"]).shape[0])
        a = victim.engine.query(tok, msk, loc, k=K, cr=c, batch=BATCH)
        b = recovered.engine.query(tok, msk, loc, k=K, cr=c, batch=BATCH)
        identical = bool(np.array_equal(a[0], b[0])
                         and np.array_equal(a[1], b[1]))
        out = {
            "acked_writes": acked,
            "recovered_writes": recovered.stats.recovered_writes,
            "wal_append_ms_median": float(np.median(t_wal)),
            "recover_ms": recover_ms,
            "query_parity": identical,
        }
        recovered.close()
        return out
    finally:
        shutil.rmtree(root, ignore_errors=True)


def _mesh_chaos(snap0, corpus, te, positives):
    """Shard-kill leg (DESIGN.md §15): degrade, don't die — then recover
    online and prove bit-parity with the healthy pass."""
    import dataclasses as _dc

    import jax

    from repro.core import faults
    from repro.core import index as il

    n_dev = jax.device_count()
    c = int(np.asarray(snap0.buffers["emb"]).shape[0])
    S = min(8, n_dev)
    while S > 1 and c % S != 0:
        S -= 1
    if S < 2:
        return {"skipped": f"needs >= 2 local devices whose count divides "
                           f"c={c} clusters (have {n_dev}); run with "
                           "XLA_FLAGS=--xla_force_host_platform_device_"
                           "count=8"}
    victim = min(CHAOS_VICTIM, S - 1)

    n = min(CHAOS_REQUESTS, len(te))
    probe = te[:n]
    tok, msk = corpus.query_tokens(probe)
    loc = corpus.q_loc[probe].astype(np.float32)
    pos = positives[:n]

    def serve_pass(server):
        """Per-request submits (gathered): a shard fault must surface as
        degraded coverage on EVERY request, never as a failed one."""
        async def go():
            return await asyncio.gather(
                *(server.submit(tok[i], msk[i], loc[i]) for i in range(n)),
                return_exceptions=True)
        t0 = time.perf_counter()
        outs = asyncio.run(go())
        dt = time.perf_counter() - t0
        failures = sum(1 for o in outs if isinstance(o, BaseException))
        ids = (np.stack([o[0] for o in outs]) if failures == 0 else None)
        return ids, failures, dt

    searcher = api.Searcher(snap0.with_mesh(S), backend="dense")
    server = _mk_server(searcher.engine, cr=c)     # full fanout: exact
    server.warmup()                                # coverage fractions
    try:
        ids_h, fail_h, t_h = serve_pass(server)
        recall_h = common.eval_ranking(ids_h, pos)["recall@10"]

        # kill one shard: persistent scan_error fails the device scan AND
        # every host-replica retry, so health drives it UP→SUSPECT→DOWN
        def _boom(shard):
            if shard == victim:
                raise RuntimeError(f"injected: shard {shard} unscannable")
        faults.inject("shard.scan_error", callback=_boom, times=None)
        ids_d, fail_d, t_d = serve_pass(server)
        m = server.metrics()
        cov_min = m["coverage"]["min"]
        recall_d = (common.eval_ranking(ids_d, pos)["recall@10"]
                    if ids_d is not None else 0.0)

        # surviving shards stayed bit-exact: compare against a
        # single-device oracle whose view of the victim's clusters is
        # empty (same fills as shard_cluster_buffers padding)
        g = np.flatnonzero(
            np.asarray(searcher.snapshot.shards.shard_of) == victim)
        buf = {key: np.array(v) for key, v in snap0.buffers.items()
               if key != "capacity"}
        buf["ids"][g] = -1
        buf["emb"][g] = 0
        buf["loc"][g] = il.PAD_LOC
        buf["scale"][g] = 1
        if "counts" in buf:
            buf["counts"][g] = 0
        buf["capacity"] = snap0.buffers["capacity"]
        oracle = api.Searcher(_dc.replace(snap0, buffers=buf),
                              backend="dense")
        o_ids, _ = oracle.query(tok, msk, loc, k=K, cr=c, batch=BATCH)
        survivor_parity = bool(ids_d is not None
                               and np.array_equal(ids_d, o_ids))

        # online recovery under the same server, then replay parity
        faults.clear()
        server.recover_shard(victim)
        ids_r, fail_r, t_r = serve_pass(server)
        m = server.metrics()
        recovery_parity = bool(ids_r is not None
                               and np.array_equal(ids_r, ids_h))

        acceptance = {
            "failed_requests": fail_h + fail_d + fail_r,
            "coverage_min": cov_min,
            "coverage_floor": (S - 1) / S,
            "recall10_healthy": recall_h,
            "recall10_degraded": recall_d,
            "recall_drop_max": RECALL_DROP_BOUND / S,
            "survivor_parity": survivor_parity,
            "recovery_parity": recovery_parity,
        }
        acceptance["pass"] = bool(
            acceptance["failed_requests"] == 0
            and cov_min >= acceptance["coverage_floor"] - 1e-9
            and recall_h - recall_d <= acceptance["recall_drop_max"]
            and survivor_parity and recovery_parity)
        return {
            "n_shards": S,
            "n_clusters": c,
            "victim_shard": victim,
            "requests_per_pass": n,
            "serve_s": {"healthy": t_h, "degraded": t_d, "recovered": t_r},
            "coverage": dict(m["coverage"]),
            "shard_health": m["shard_health"],
            "shard_stats": dict(m["shard_stats"]),
            "acceptance": acceptance,
        }
    finally:
        faults.clear()
        server.close()


def run(out_path: str = OUT_PATH):
    r = common.get_retriever()
    corpus = common.get_corpus()
    te, positives = common.test_split_positives(corpus)
    engine = r.engine()

    overload = _overload(engine, corpus, te)
    recovery = _recovery(engine.snapshot, corpus, te)
    mesh_chaos = _mesh_chaos(engine.snapshot, corpus, te, positives)

    shed_total = sum(overload["shed"].values())
    acceptance = {
        "p99_ratio": overload["p99_admitted_ms"]
        / max(overload["p99_unloaded_ms"], 1e-9),
        "p99_ratio_max": 2.0,
        "shed_fraction": shed_total / overload["offered"],
        "shed_fraction_min": 0.05,
        "conservation_ok": overload["served"] + shed_total
        == overload["offered"],
        "recovered_writes": recovery["recovered_writes"],
        "acked_writes": recovery["acked_writes"],
        "recovery_ok": recovery["recovered_writes"]
        == recovery["acked_writes"] and recovery["query_parity"],
    }
    acceptance["pass"] = bool(
        acceptance["p99_ratio"] <= acceptance["p99_ratio_max"]
        and acceptance["shed_fraction"] >= acceptance["shed_fraction_min"]
        and acceptance["conservation_ok"]
        and acceptance["recovery_ok"])

    section = {
        "overload": overload,
        "recovery": recovery,
        "acceptance": acceptance,
    }
    report = {}
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError):
            report = {}
    report.setdefault("bench", "serving")
    report["resilience"] = section
    report["mesh_chaos"] = mesh_chaos
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    if "skipped" in mesh_chaos:
        chaos_row = common.fmt_row(
            "serving(mesh-chaos)", {"skipped": 1},
            extra=mesh_chaos["skipped"])
    else:
        acc = mesh_chaos["acceptance"]
        chaos_row = common.fmt_row("serving(mesh-chaos)", {
            "shards": mesh_chaos["n_shards"],
            "failed_requests": acc["failed_requests"],
            "coverage_min": acc["coverage_min"],
            "recall10_healthy": acc["recall10_healthy"],
            "recall10_degraded": acc["recall10_degraded"],
            "survivor_parity": int(acc["survivor_parity"]),
            "recovery_parity": int(acc["recovery_parity"]),
            "pass": int(acc["pass"])})

    return [
        common.fmt_row("serving(overload)", {
            "capacity_qps": overload["capacity_qps"],
            "p99_unloaded_ms": overload["p99_unloaded_ms"],
            "p99_admitted_ms": overload["p99_admitted_ms"],
            "p99_ratio": acceptance["p99_ratio"],
            "shed_fraction": acceptance["shed_fraction"],
            "served": overload["served"]}),
        common.fmt_row("serving(recovery)", {
            "acked": recovery["acked_writes"],
            "recovered": recovery["recovered_writes"],
            "parity": int(recovery["query_parity"]),
            "recover_ms": recovery["recover_ms"],
            "wal_append_ms": recovery["wal_append_ms_median"]}),
        chaos_row,
        common.fmt_row("serving(resilience)", {
            "pass": int(acceptance["pass"]), "path": out_path}),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-scale training (same knobs as benchmarks.run)")
    args = ap.parse_args()
    if args.fast:
        common.N_OBJECTS = 1500
        common.N_QUERIES = 300
        common.REL_STEPS = 120
        common.IDX_STEPS = 250
    print("\n".join(run()))
