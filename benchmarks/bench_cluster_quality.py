"""Paper Table 5: cluster quality — LIST-I vs IVF k-means, P(C) and IF(C)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import cluster_metrics as cm
from repro.core.baselines import IVFIndex


def run():
    corpus = common.get_corpus()
    te, positives = common.test_split_positives(corpus)
    r = common.get_retriever()
    r.ensure_embeddings()
    rows = []

    # LIST-I
    qa = common.query_cluster_assign(r, te)
    pc, _ = cm.cluster_precision(qa, positives, r.obj_assign,
                                 common.N_CLUSTERS)
    rows.append(common.fmt_row("LIST-I", {
        "P(C)": pc,
        "IF(C)": cm.imbalance_factor(r.obj_assign, common.N_CLUSTERS)}))

    # IVF on the same embeddings
    ivf = IVFIndex(r.obj_emb, n_clusters=common.N_CLUSTERS, seed=0)
    import repro.core.pipeline as pl
    q_emb = np.asarray(pl.embed_queries(r.rel_params, corpus, r.cfg, te))
    probes = ivf.probe(q_emb, cr=1)[:, 0]
    pc_ivf, _ = cm.cluster_precision(probes, positives, ivf.assign,
                                     common.N_CLUSTERS)
    rows.append(common.fmt_row("IVF", {
        "P(C)": pc_ivf,
        "IF(C)": cm.imbalance_factor(ivf.assign, common.N_CLUSTERS)}))

    # IVF_S (manually weighted spatial factor)
    ivfs = IVFIndex(r.obj_emb, corpus.obj_loc, n_clusters=common.N_CLUSTERS,
                    alpha=0.9, seed=0)
    probes = ivfs.probe(q_emb, corpus.q_loc[te], cr=1)[:, 0]
    pc_s, _ = cm.cluster_precision(probes, positives, ivfs.assign,
                                   common.N_CLUSTERS)
    rows.append(common.fmt_row("IVF_S(a=0.9)", {
        "P(C)": pc_s,
        "IF(C)": cm.imbalance_factor(ivfs.assign, common.N_CLUSTERS)}))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
