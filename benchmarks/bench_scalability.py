"""Paper Fig. 7: LIST / LIST-R query runtime vs corpus size (linear scaling).

The trained encoder + router are reused; only the corpus (and its buffers)
grows — matching the paper's augmented-Geo-Glue methodology where no
ground truth exists for the added POIs (efficiency only).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from benchmarks import common
from repro import api
from repro.core import index as il
from repro.core import pipeline as pl
from repro.core.snapshot import IndexSnapshot
from repro.data import GeoCorpus, GeoCorpusConfig


def run():
    r = common.get_retriever()
    cfg = r.cfg
    rows = []
    te_small, _ = common.test_split_positives(common.get_corpus())
    for n in (2000, 4000, 8000, 16000):
        big = GeoCorpus(GeoCorpusConfig(
            n_objects=n, n_queries=64, n_topics=common.N_TOPICS,
            vocab_size=4096, seed=1))
        obj_emb = pl.embed_objects(r.rel_params, big, cfg)
        obj_loc = big.obj_loc.astype(np.float32)
        feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                                  r.norm)
        top = np.asarray(il.assign_clusters(r.index_params, feats, top=3))
        buf = il.build_cluster_buffers(top, obj_emb, obj_loc,
                                       n_clusters=cfg.n_clusters)
        # brute force timing (encode at query time, same as LIST below)
        q_loc = big.q_loc[:64].astype(np.float32)
        tok_b, msk_b = big.query_tokens(np.arange(64))
        from repro.core import relevance
        import jax

        @jax.jit
        def score(tok, msk, ql):
            qe = relevance.encode_queries(r.rel_params, tok, msk, cfg)
            return jax.lax.top_k(relevance.score_corpus(
                r.rel_params, qe, ql, jnp.asarray(obj_emb),
                jnp.asarray(obj_loc), cfg, dist_max=big.dist_max,
                train=False), 10)

        bargs = (jnp.asarray(tok_b), jnp.asarray(msk_b), jnp.asarray(q_loc))
        score(*bargs)  # warm
        t0 = time.perf_counter()
        for _ in range(3):
            out = score(*bargs)
        jax.tree.leaves(out)[0].block_until_ready()
        t_brute = (time.perf_counter() - t0) / 3

        # LIST timing (route + gather + fused score): the same traced
        # plan api.Searcher serves, taken from a from_parts snapshot of
        # the grown corpus
        snap = IndexSnapshot.from_parts(
            cfg, r.rel_params, r.index_params, r.norm, buf,
            dist_max=float(big.dist_max))
        eng = api.Searcher(snap).engine
        qfn = eng.query_fn(k=10, cr=1, batch=64)
        args = (snap.rel_params, snap.index_params, snap.w_hat, snap.norm,
                buf["emb"], buf["loc"], buf["ids"], buf["scale"])
        tok, msk = big.query_tokens(np.arange(64))
        qa = (jnp.asarray(tok), jnp.asarray(msk), jnp.asarray(q_loc))
        qfn(*args, *qa)  # warm
        t0 = time.perf_counter()
        for _ in range(3):
            out = qfn(*args, *qa)
        jax.tree.leaves(out)[0].block_until_ready()
        t_list = (time.perf_counter() - t0) / 3
        rows.append(common.fmt_row(f"n={n}", {
            "brute_ms/64q": t_brute * 1e3,
            "LIST_ms/64q": t_list * 1e3,
            "cap": buf["capacity"]}))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
