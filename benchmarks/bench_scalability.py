"""Paper Fig. 7: LIST / LIST-R query runtime vs corpus size (linear
scaling) — plus the mesh-sharded serving scale-out sweep (DESIGN.md §12).

Part 1 (corpus rows) reuses the trained encoder + router and only grows
the corpus (and its buffers) — matching the paper's augmented-Geo-Glue
methodology where no ground truth exists for the added POIs
(efficiency only).

Part 2 (mesh rows) takes the trained retriever's OWN snapshot (whose
corpus has ground truth) and shards its cluster buffers across
{1, 2, 4, 8} devices: per-device resident bytes must shrink ~linearly
with the shard count while recall@10 stays EXACTLY unchanged (the
parity contract — top-k ids are bit-identical across placements,
tests/test_mesh_sharding.py). On CPU the devices come from
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (set before jax
imports — the Makefile bench-smoke target and the CI job export it);
shard counts above the available device count are skipped.

Emits ``BENCH_scalability.json`` (schema in README.md §Benchmarks).
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks import common
from repro import api
from repro.core import cluster_metrics as cm
from repro.core import index as il
from repro.core import pipeline as pl
from repro.core.snapshot import IndexSnapshot
from repro.data import GeoCorpus, GeoCorpusConfig

OUT_PATH = "BENCH_scalability.json"
SHARD_COUNTS = (1, 2, 4, 8)
K = 10


def _time_query(searcher, tok, msk, loc, *, k, cr, batch, reps=3):
    searcher.query(tok, msk, loc, k=k, cr=cr, batch=batch)       # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = searcher.query(tok, msk, loc, k=k, cr=cr, batch=batch)
    return (time.perf_counter() - t0) / reps, out


def _corpus_rows(r):
    """Fig. 7 proper: runtime vs corpus size on ONE device."""
    cfg = r.cfg
    rows, report = [], []
    for n in (2000, 4000, 8000, 16000):
        big = GeoCorpus(GeoCorpusConfig(
            n_objects=n, n_queries=64, n_topics=common.N_TOPICS,
            vocab_size=4096, seed=1))
        obj_emb = pl.embed_objects(r.rel_params, big, cfg)
        obj_loc = big.obj_loc.astype(np.float32)
        feats = il.build_features(jnp.asarray(obj_emb), jnp.asarray(obj_loc),
                                  r.norm)
        top = np.asarray(il.assign_clusters(r.index_params, feats, top=3))
        buf = il.build_cluster_buffers(top, obj_emb, obj_loc,
                                       n_clusters=cfg.n_clusters)
        # brute force timing (encode at query time, same as LIST below)
        q_loc = big.q_loc[:64].astype(np.float32)
        tok_b, msk_b = big.query_tokens(np.arange(64))
        from repro.core import relevance

        @jax.jit
        def score(tok, msk, ql):
            qe = relevance.encode_queries(r.rel_params, tok, msk, cfg)
            return jax.lax.top_k(relevance.score_corpus(
                r.rel_params, qe, ql, jnp.asarray(obj_emb),
                jnp.asarray(obj_loc), cfg, dist_max=big.dist_max,
                train=False), 10)

        bargs = (jnp.asarray(tok_b), jnp.asarray(msk_b), jnp.asarray(q_loc))
        score(*bargs)  # warm
        t0 = time.perf_counter()
        for _ in range(3):
            out = score(*bargs)
        jax.tree.leaves(out)[0].block_until_ready()
        t_brute = (time.perf_counter() - t0) / 3

        # LIST timing (route + gather + fused score): the same traced
        # plan api.Searcher serves, taken from a from_parts snapshot of
        # the grown corpus
        snap = IndexSnapshot.from_parts(
            cfg, r.rel_params, r.index_params, r.norm, buf,
            dist_max=float(big.dist_max))
        eng = api.Searcher(snap).engine
        qfn = eng.query_fn(k=10, cr=1, batch=64)
        args = (snap.rel_params, snap.index_params, snap.w_hat, snap.norm,
                buf["emb"], buf["loc"], buf["ids"], buf["scale"])
        tok, msk = big.query_tokens(np.arange(64))
        qa = (jnp.asarray(tok), jnp.asarray(msk), jnp.asarray(q_loc))
        qfn(*args, *qa)  # warm
        t0 = time.perf_counter()
        for _ in range(3):
            out = qfn(*args, *qa)
        jax.tree.leaves(out)[0].block_until_ready()
        t_list = (time.perf_counter() - t0) / 3
        rows.append(common.fmt_row(f"n={n}", {
            "brute_ms/64q": t_brute * 1e3,
            "LIST_ms/64q": t_list * 1e3,
            "cap": buf["capacity"]}))
        report.append({"n_objects": n, "brute_ms": t_brute * 1e3,
                       "list_ms": t_list * 1e3,
                       "capacity": int(buf["capacity"])})
    return rows, report


def _mesh_rows(r):
    """Scale-out sweep: per-device resident bytes vs shard count, recall
    and ids pinned against the unsharded engine."""
    corpus = common.get_corpus()
    te, positives = common.test_split_positives(corpus)
    snap = r.snapshot()
    c = int(np.asarray(snap.buffers["ids"]).shape[0])
    tok, msk = corpus.query_tokens(te)
    loc = corpus.q_loc[te].astype(np.float32)

    base_bytes = int(sum(np.asarray(snap.buffers[k]).nbytes
                         for k in ("emb", "loc", "ids", "scale", "counts")))
    n_dev = jax.device_count()
    counts = [s for s in SHARD_COUNTS if s <= n_dev]
    skipped = [s for s in SHARD_COUNTS if s > n_dev]
    if skipped:
        print(f"# scalability: {n_dev} devices — skipping shard counts "
              f"{skipped} (export XLA_FLAGS="
              f"--xla_force_host_platform_device_count=8)")

    rows, report = [], []
    ref_ids = None
    for s in counts:
        if s == 1:
            sd, bytes_dev = snap, base_bytes
        else:
            sd = snap.with_mesh(s)
            bytes_dev = max(sd.shards.nbytes_per_device())
        t, (ids, _) = _time_query(api.Searcher(sd, backend="dense"),
                                  tok, msk, loc, k=K, cr=1, batch=64)
        if ref_ids is None:
            ref_ids = ids
        recall = cm.recall_at_k(ids, positives, K)
        ids_match = float(np.mean(ids == ref_ids))
        rows.append(common.fmt_row(f"mesh_shards={s}", {
            "bytes/device_MB": bytes_dev / 1e6,
            f"recall@{K}": recall,
            "ids_match": ids_match,
            "LIST_ms": t * 1e3}))
        report.append({"n_shards": s, "bytes_per_device": bytes_dev,
                       "recall_at_10": float(recall),
                       "ids_match_vs_unsharded": ids_match,
                       "list_ms": t * 1e3})

    acceptance = {"device_count": n_dev, "shard_counts": counts,
                  "pass": True}
    if len(report) > 1:
        s_max = report[-1]["n_shards"]
        got = report[0]["bytes_per_device"] / report[-1]["bytes_per_device"]
        # the per-shard sentinel empty cluster caps the achievable cut:
        # c rows shrink to ceil(c/S)+1 rows per device, not c/S
        ideal = c / (-(-c // s_max) + 1)
        recall_delta = report[-1]["recall_at_10"] - report[0]["recall_at_10"]
        ids_match = min(row["ids_match_vs_unsharded"] for row in report)
        acceptance.update({
            "bytes_reduction": got,
            "ideal_reduction": ideal,
            "recall_delta": recall_delta,
            "ids_match": ids_match,
            "pass": bool(got >= 0.8 * ideal and abs(recall_delta) < 1e-12
                         and ids_match == 1.0),
        })
    return rows, report, acceptance


def run(out_path: str = OUT_PATH):
    r = common.get_retriever()
    corpus_rows, corpus_report = _corpus_rows(r)
    mesh_rows, mesh_report, acceptance = _mesh_rows(r)
    report = {
        "bench": "scalability",
        "config": {"n_clusters": r.cfg.n_clusters,
                   "n_objects": common.N_OBJECTS, "k": K},
        "corpus_rows": corpus_report,
        "mesh_rows": mesh_report,
        "acceptance": acceptance,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    return (corpus_rows + mesh_rows
            + [common.fmt_row("scalability(json)", {"path": out_path})])


if __name__ == "__main__":
    print("\n".join(run()))
