"""Streaming-serving benchmark: the micro-batcher + result caches
(core/server.py, DESIGN.md §7) replaying a Zipf-skewed query workload
against a trained retriever.

Emits ``BENCH_serving.json`` (schema documented in README.md
§Benchmarks) to start the serving perf trajectory: latency percentiles
p50/p95/p99, achieved QPS, cache hit rate per tier, micro-batch fill —
plus a pure cache-replay pass that bounds the hot-set ceiling, and the
artifact-lifecycle costs (snapshot save / load / atomic hot-swap
seconds) a deploy pipeline budgets around.

    PYTHONPATH=src python -m benchmarks.bench_serving [--fast]
"""
from __future__ import annotations

import asyncio
import json
import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.core import cluster_metrics as cm
from repro.core import server as server_lib

OUT_PATH = "BENCH_serving.json"

BATCH = 64
MAX_DELAY_MS = 2.0
K = 10
CR = 1
SKEW = 1.05
NEAR_CELLS = 64
REQUESTS_PER_UNIQUE = 5
JITTER_FRAC = 0.3          # requests re-issued a few meters away: these
JITTER = 0.002             # miss the exact tier but hit the near tier


def _replay(server, corpus, picks, *, jitter_rng=None):
    tok, msk = corpus.query_tokens(picks)
    loc = corpus.q_loc[picks].astype(np.float32)
    if jitter_rng is not None:
        rows = jitter_rng.random(len(picks)) < JITTER_FRAC
        loc[rows] = np.clip(
            loc[rows] + jitter_rng.uniform(-JITTER, JITTER,
                                           size=(rows.sum(), 2)), 0.0, 1.0)
    requests = [(tok[i], msk[i], loc[i]) for i in range(len(picks))]
    t0 = time.perf_counter()
    results = asyncio.run(server_lib.closed_loop(server, requests,
                                                 concurrency=BATCH))
    return results, time.perf_counter() - t0


def run(out_path: str = OUT_PATH):
    r = common.get_retriever()
    corpus = common.get_corpus()
    te, _ = common.test_split_positives(corpus)

    server = server_lib.StreamingServer(r.engine(), server_lib.ServerConfig(
        batch_size=BATCH, max_delay_ms=MAX_DELAY_MS, k=K, cr=CR,
        near_cells=NEAR_CELLS))
    compiles = server.warmup()

    # --- skewed live pass: misses + exact/near hits mixed -----------------
    rng = np.random.default_rng(common.SEED + 29)
    n_requests = REQUESTS_PER_UNIQUE * len(te)
    picks = te[server_lib.zipf_sample(rng, len(te), n_requests, a=SKEW)]
    results, wall = _replay(server, corpus, picks, jitter_rng=rng)
    m = server.metrics(wall_seconds=wall)
    served_ids = np.stack([res[0] for res in results])
    served_pos = [corpus.positives[q] for q in picks]
    recall = cm.recall_at_k(served_ids, served_pos, K)

    # --- pure replay pass: the whole hot set is cached --------------------
    server.stats = server_lib.ServerStats()
    _, wall_hot = _replay(server, corpus, picks)
    m_hot = server.metrics(wall_seconds=wall_hot)

    # --- artifact lifecycle: save → load → atomic hot-swap ----------------
    snap = server.engine.snapshot
    art_dir = tempfile.mkdtemp(prefix="bench_snapshot_")
    try:
        t0 = time.perf_counter()
        api.save(snap, art_dir)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        loaded = api.load(art_dir)
        t_load = time.perf_counter() - t0
        # publish a version-bumped successor into the LIVE server: the
        # swap is one digest-checked reference assignment + cache clear
        t0 = time.perf_counter()
        server.publish(loaded.with_buffers(loaded.buffers))
        t_swap = time.perf_counter() - t0
    finally:
        shutil.rmtree(art_dir, ignore_errors=True)
    snapshot_ms = {"save_ms": t_save * 1e3, "load_ms": t_load * 1e3,
                   "swap_ms": t_swap * 1e3,
                   "version_after_swap": server.engine.snapshot.meta.version}

    report = {
        "bench": "serving",
        "config": {
            "n_objects": corpus.cfg.n_objects,
            "n_unique_queries": int(len(te)),
            "n_requests": int(n_requests),
            "batch_size": BATCH, "max_delay_ms": MAX_DELAY_MS,
            "k": K, "cr": CR, "backend": server.engine.backend,
            "zipf_a": SKEW, "near_cells": NEAR_CELLS,
        },
        "latency_ms": m["latency_ms"],
        "qps": m["qps"],
        "cache": {
            "exact_hit_rate": m["exact_hit_rate"],
            "near_hit_rate": m["near_hit_rate"],
            "hit_rate": m["hit_rate"],
            "coalesced": m["coalesced"],
        },
        "batch_fill": m["batch_fill"],
        "flushes": m["flushes"],
        "engine_batches": m["engine_batches"],
        "recall_at_k": recall,
        "compile_seconds": compiles,
        "hot_replay": {
            "latency_ms": m_hot["latency_ms"],
            "qps": m_hot["qps"],
            "hit_rate": m_hot["hit_rate"],
        },
        "snapshot": snapshot_ms,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        common.fmt_row("serving(live,zipf)", {
            "qps": m["qps"], "p50_ms": m["latency_ms"]["p50"],
            "p95_ms": m["latency_ms"]["p95"],
            "p99_ms": m["latency_ms"]["p99"],
            "hit_rate": m["hit_rate"], "batch_fill": m["batch_fill"],
            f"recall@{K}": recall}),
        common.fmt_row("serving(hot-replay)", {
            "qps": m_hot["qps"], "p50_ms": m_hot["latency_ms"]["p50"],
            "p99_ms": m_hot["latency_ms"]["p99"],
            "hit_rate": m_hot["hit_rate"]}),
        common.fmt_row("serving(snapshot)", {
            "save_ms": snapshot_ms["save_ms"],
            "load_ms": snapshot_ms["load_ms"],
            "swap_ms": snapshot_ms["swap_ms"]}),
        common.fmt_row("serving(json)", {"path": out_path}),
    ]
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-scale training (same knobs as benchmarks.run)")
    args = ap.parse_args()
    if args.fast:
        common.N_OBJECTS = 1500
        common.N_QUERIES = 300
        common.REL_STEPS = 120
        common.IDX_STEPS = 250
    print("\n".join(run()))
