"""Streaming-serving benchmark: the micro-batcher + result caches
(core/server.py, DESIGN.md §7) replaying a Zipf-skewed query workload
against a trained retriever.

Emits ``BENCH_serving.json`` (schema documented in README.md
§Benchmarks) to start the serving perf trajectory: latency percentiles
p50/p95/p99, achieved QPS, cache hit rate per tier, micro-batch fill —
plus a pure cache-replay pass that bounds the hot-set ceiling, the
artifact-lifecycle costs (snapshot save / load / atomic hot-swap
seconds) a deploy pipeline budgets around, and a **sustained-churn**
scenario for the LSM write path (DESIGN.md §11): rounds of
insert/delete/query applied identically to a delta server and to an
eager (``delta_threshold=0``, O(index)-per-write) twin. The churn
section carries an ``acceptance`` block — write cost O(batch) not
O(index) (speedup bound), p99 flat across rounds, recall within 0.005
of the always-folded oracle, post-compaction top-k overlap — gated in
CI (.github/workflows/ci.yml).

    PYTHONPATH=src python -m benchmarks.bench_serving [--fast]
"""
from __future__ import annotations

import asyncio
import json
import shutil
import tempfile
import time

import numpy as np

from benchmarks import common
from repro import api
from repro.core import cluster_metrics as cm
from repro.core import server as server_lib

OUT_PATH = "BENCH_serving.json"

BATCH = 64
MAX_DELAY_MS = 2.0
K = 10
CR = 1
SKEW = 1.05
NEAR_CELLS = 64
REQUESTS_PER_UNIQUE = 5
JITTER_FRAC = 0.3          # requests re-issued a few meters away: these
JITTER = 0.002             # miss the exact tier but hit the near tier

# --- sustained-churn scenario (writes + queries, DESIGN.md §11) -----------
CHURN_ROUNDS = 10
CHURN_INSERTS = 32         # new objects per round
CHURN_DELETES = 16         # tombstoned base objects per round
CHURN_QUERIES = 128        # served queries per round
CHURN_DELTA_THRESHOLD = 192  # 48 writes/round ⇒ size-compaction ~every 4


def _replay(server, corpus, picks, *, jitter_rng=None):
    tok, msk = corpus.query_tokens(picks)
    loc = corpus.q_loc[picks].astype(np.float32)
    if jitter_rng is not None:
        rows = jitter_rng.random(len(picks)) < JITTER_FRAC
        loc[rows] = np.clip(
            loc[rows] + jitter_rng.uniform(-JITTER, JITTER,
                                           size=(rows.sum(), 2)), 0.0, 1.0)
    requests = [(tok[i], msk[i], loc[i]) for i in range(len(picks))]
    t0 = time.perf_counter()
    results = asyncio.run(server_lib.closed_loop(server, requests,
                                                 concurrency=BATCH))
    return results, time.perf_counter() - t0


def _churn(corpus, te, snap0):
    """A/B the LSM write path under sustained churn.

    The SAME mutation + query log runs against two fresh servers over
    ``snap0``: one with delta segments (size-triggered background
    compaction) and an eager twin (``delta_threshold=0``) that folds
    every write into the base buffers — the always-compacted oracle.
    Per round: insert CHURN_INSERTS synthetic objects, tombstone
    CHURN_DELETES never-relevant base objects, then serve CHURN_QUERIES
    Zipf-skewed queries. Medians make the numbers robust to the one-off
    compile spikes (round-1 traces) and the rounds whose write absorbs
    an inline compaction."""
    rng = np.random.default_rng(common.SEED + 71)
    base_ids = np.asarray(snap0.buffers["ids"])
    base_emb = np.asarray(snap0.buffers["emb"], np.float32)
    live = base_emb[base_ids >= 0]
    mu, sd = float(live.mean()), float(live.std())
    d = base_emb.shape[-1]

    # deletions only ever hit objects that are not a positive of any
    # served query, so recall is comparable across rounds
    protected = set()
    for q in te:
        protected.update(int(i) for i in corpus.positives[q])
    pool = [i for i in range(corpus.cfg.n_objects) if i not in protected]
    rng.shuffle(pool)
    assert len(pool) >= CHURN_ROUNDS * CHURN_DELETES

    def mk(threshold):
        srv = api.Searcher(snap0).serve(server_lib.ServerConfig(
            batch_size=BATCH, max_delay_ms=MAX_DELAY_MS, k=K, cr=CR,
            near_cells=NEAR_CELLS, delta_threshold=threshold))
        srv.warmup()
        return srv

    servers = {"delta": mk(CHURN_DELTA_THRESHOLD), "eager": mk(0)}
    w_ms = {name: [] for name in servers}
    p99_ms = {name: [] for name in servers}
    rec = {name: [] for name in servers}
    next_id = 10_000_000
    for _ in range(CHURN_ROUNDS):
        emb = (mu + sd * rng.standard_normal((CHURN_INSERTS, d))
               ).astype(np.float32)
        loc = rng.uniform(size=(CHURN_INSERTS, 2)).astype(np.float32)
        new_ids = np.arange(next_id, next_id + CHURN_INSERTS)
        next_id += CHURN_INSERTS
        victims = [pool.pop() for _ in range(CHURN_DELETES)]
        picks = te[server_lib.zipf_sample(rng, len(te), CHURN_QUERIES,
                                          a=SKEW)]
        tok, msk = corpus.query_tokens(picks)
        qloc = corpus.q_loc[picks].astype(np.float32)
        pos = [corpus.positives[q] for q in picks]
        for name, srv in servers.items():
            t0 = time.perf_counter()
            srv.insert_objects(emb, loc, new_ids)
            srv.delete_objects(victims)
            w_ms[name].append((time.perf_counter() - t0) * 1e3)
            n0 = len(srv.stats.latencies_s)
            out_ids, _ = srv.serve_all(tok, msk, qloc)
            lat = np.asarray(list(srv.stats.latencies_s)[n0:], np.float64)
            p99_ms[name].append(float(np.percentile(lat, 99) * 1e3))
            rec[name].append(cm.recall_at_k(out_ids, pos, K))

    # post-compaction parity probe: the pending delta folds into base
    # and the SAME queries must surface (essentially) the same ids.
    # Full fan-out (cr = n_clusters) so the probe measures compaction
    # parity, not routing: pre-compaction delta rows are scanned
    # exhaustively while folded rows live in exactly one cluster, so at
    # cr=1 a boundary row can legitimately drop out of a cell the query
    # does not route to — that effect is recall (measured above), not a
    # compaction bug.
    srv = servers["delta"]
    pending = int(srv.engine.snapshot.meta.delta_rows)
    n_clusters = base_emb.shape[0]
    probe = te[:min(len(te), CHURN_QUERIES)]
    tokp, mskp = corpus.query_tokens(probe)
    locp = corpus.q_loc[probe].astype(np.float32)
    ids_pre, _ = srv.engine.query(tokp, mskp, locp, k=K, cr=n_clusters,
                                  batch=BATCH)
    t0 = time.perf_counter()
    srv.compact_now()
    compact_ms = (time.perf_counter() - t0) * 1e3
    ids_post, _ = srv.engine.query(tokp, mskp, locp, k=K, cr=n_clusters,
                                   batch=BATCH)
    overlap = float(np.mean([
        len(set(a[a >= 0]) & set(b[b >= 0])) / max(1, len(set(a[a >= 0])))
        for a, b in zip(ids_pre, ids_post)]))

    # p99 flatness over rounds, skipping round 1 (plan-tracing spike)
    head = float(np.mean(p99_ms["delta"][1:4]))
    tail = float(np.mean(p99_ms["delta"][-3:]))
    w_med = {name: float(np.median(v)) for name, v in w_ms.items()}
    acceptance = {
        "write_speedup": w_med["eager"] / max(w_med["delta"], 1e-9),
        "write_speedup_min": 2.0,
        "recall_delta": float(np.mean(rec["delta"]) - np.mean(rec["eager"])),
        "recall_delta_min": -0.005,
        "p99_ratio": tail / max(head, 1e-9),
        "p99_ratio_max": 5.0,
        "post_compaction_overlap": overlap,
        "overlap_min": 0.999,
    }
    acceptance["pass"] = bool(
        acceptance["write_speedup"] >= acceptance["write_speedup_min"]
        and acceptance["recall_delta"] >= acceptance["recall_delta_min"]
        and acceptance["p99_ratio"] <= acceptance["p99_ratio_max"]
        and acceptance["post_compaction_overlap"] >= acceptance["overlap_min"])

    m = srv.metrics()
    churn = {
        "rounds": CHURN_ROUNDS,
        "inserts_per_round": CHURN_INSERTS,
        "deletes_per_round": CHURN_DELETES,
        "queries_per_round": CHURN_QUERIES,
        "delta_threshold": CHURN_DELTA_THRESHOLD,
        "write_ms_median": w_med,
        "write_ms_per_round": w_ms,
        "p99_ms_per_round": p99_ms,
        "recall_at_k_per_round": rec,
        "writes": m["writes"],
        "compactions": m["compactions"],
        "compaction_triggers": m["compaction_triggers"],
        "pending_delta_rows_at_probe": pending,
        "compact_now_ms": compact_ms,
        "acceptance": acceptance,
    }
    row = common.fmt_row("serving(churn)", {
        "write_ms(delta)": w_med["delta"],
        "write_ms(eager)": w_med["eager"],
        "write_speedup": acceptance["write_speedup"],
        "p99_ratio": acceptance["p99_ratio"],
        f"recall@{K}": float(np.mean(rec["delta"])),
        "recall_delta": acceptance["recall_delta"],
        "overlap": acceptance["post_compaction_overlap"],
        "pass": int(acceptance["pass"])})
    return churn, row


def run(out_path: str = OUT_PATH):
    r = common.get_retriever()
    corpus = common.get_corpus()
    te, _ = common.test_split_positives(corpus)

    server = server_lib.StreamingServer(r.engine(), server_lib.ServerConfig(
        batch_size=BATCH, max_delay_ms=MAX_DELAY_MS, k=K, cr=CR,
        near_cells=NEAR_CELLS))
    compiles = server.warmup()

    # --- skewed live pass: misses + exact/near hits mixed -----------------
    rng = np.random.default_rng(common.SEED + 29)
    n_requests = REQUESTS_PER_UNIQUE * len(te)
    picks = te[server_lib.zipf_sample(rng, len(te), n_requests, a=SKEW)]
    results, wall = _replay(server, corpus, picks, jitter_rng=rng)
    m = server.metrics(wall_seconds=wall)
    served_ids = np.stack([res[0] for res in results])
    served_pos = [corpus.positives[q] for q in picks]
    recall = cm.recall_at_k(served_ids, served_pos, K)

    # --- pure replay pass: the whole hot set is cached --------------------
    server.stats = server_lib.ServerStats()
    _, wall_hot = _replay(server, corpus, picks)
    m_hot = server.metrics(wall_seconds=wall_hot)

    # --- artifact lifecycle: save → load → atomic hot-swap ----------------
    snap = server.engine.snapshot
    art_dir = tempfile.mkdtemp(prefix="bench_snapshot_")
    try:
        t0 = time.perf_counter()
        api.save(snap, art_dir)
        t_save = time.perf_counter() - t0
        t0 = time.perf_counter()
        loaded = api.load(art_dir)
        t_load = time.perf_counter() - t0
        # publish a version-bumped successor into the LIVE server: the
        # swap is one digest-checked reference assignment + cache clear
        t0 = time.perf_counter()
        server.publish(loaded.with_buffers(loaded.buffers))
        t_swap = time.perf_counter() - t0
    finally:
        shutil.rmtree(art_dir, ignore_errors=True)
    snapshot_ms = {"save_ms": t_save * 1e3, "load_ms": t_load * 1e3,
                   "swap_ms": t_swap * 1e3,
                   "version_after_swap": server.engine.snapshot.meta.version}

    # --- sustained churn: delta write path vs eager twin ------------------
    churn, churn_row = _churn(corpus, te, snap)

    report = {
        "bench": "serving",
        "config": {
            "n_objects": corpus.cfg.n_objects,
            "n_unique_queries": int(len(te)),
            "n_requests": int(n_requests),
            "batch_size": BATCH, "max_delay_ms": MAX_DELAY_MS,
            "k": K, "cr": CR, "backend": server.engine.backend,
            "zipf_a": SKEW, "near_cells": NEAR_CELLS,
        },
        "latency_ms": m["latency_ms"],
        "qps": m["qps"],
        "cache": {
            "exact_hit_rate": m["exact_hit_rate"],
            "near_hit_rate": m["near_hit_rate"],
            "hit_rate": m["hit_rate"],
            "coalesced": m["coalesced"],
        },
        "batch_fill": m["batch_fill"],
        "flushes": m["flushes"],
        "engine_batches": m["engine_batches"],
        "recall_at_k": recall,
        "compile_seconds": compiles,
        "hot_replay": {
            "latency_ms": m_hot["latency_ms"],
            "qps": m_hot["qps"],
            "hit_rate": m_hot["hit_rate"],
        },
        "snapshot": snapshot_ms,
        "churn": churn,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)

    rows = [
        common.fmt_row("serving(live,zipf)", {
            "qps": m["qps"], "p50_ms": m["latency_ms"]["p50"],
            "p95_ms": m["latency_ms"]["p95"],
            "p99_ms": m["latency_ms"]["p99"],
            "hit_rate": m["hit_rate"], "batch_fill": m["batch_fill"],
            f"recall@{K}": recall}),
        common.fmt_row("serving(hot-replay)", {
            "qps": m_hot["qps"], "p50_ms": m_hot["latency_ms"]["p50"],
            "p99_ms": m_hot["latency_ms"]["p99"],
            "hit_rate": m_hot["hit_rate"]}),
        common.fmt_row("serving(snapshot)", {
            "save_ms": snapshot_ms["save_ms"],
            "load_ms": snapshot_ms["load_ms"],
            "swap_ms": snapshot_ms["swap_ms"]}),
        churn_row,
        common.fmt_row("serving(json)", {"path": out_path}),
    ]
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-scale training (same knobs as benchmarks.run)")
    args = ap.parse_args()
    if args.fast:
        common.N_OBJECTS = 1500
        common.N_QUERIES = 300
        common.REL_STEPS = 120
        common.IDX_STEPS = 250
    print("\n".join(run()))
