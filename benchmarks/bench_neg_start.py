"""Paper Fig. 8: the neg_start knob — pseudo-negative hardness trades
cluster precision P(C) against balance IF(C)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import cluster_metrics as cm
from repro.core import pipeline as pl


def run():
    corpus = common.get_corpus()
    te, positives = common.test_split_positives(corpus)
    base = common.get_retriever()          # reuses the relevance model
    rows = []
    n = common.N_OBJECTS
    for frac in (0.05, 0.2, 0.5, 0.8):
        ns = int(n * frac)
        iparams, norm, obj_emb, _ = pl.train_cluster_index(
            base.rel_params, corpus, base.cfg, obj_emb=base.obj_emb,
            steps=common.IDX_STEPS, batch=64, lr=3e-3,
            neg_start=ns, neg_end=ns + 200, log_every=10**9)
        import jax.numpy as jnp
        from repro.core import index as il
        feats = il.build_features(
            jnp.asarray(obj_emb),
            jnp.asarray(corpus.obj_loc.astype(np.float32)), norm)
        assign = np.asarray(il.assign_clusters(iparams, feats))
        q_emb = pl.embed_queries(base.rel_params, corpus, base.cfg, te)
        qf = il.build_features(
            jnp.asarray(q_emb),
            jnp.asarray(corpus.q_loc[te].astype(np.float32)), norm)
        qa = np.asarray(il.assign_clusters(iparams, qf))
        pc, _ = cm.cluster_precision(qa, positives, assign,
                                     common.N_CLUSTERS)
        rows.append(common.fmt_row(f"neg_start={ns}", {
            "P(C)": pc,
            "IF(C)": cm.imbalance_factor(assign, common.N_CLUSTERS)}))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
