"""Run every paper-table benchmark; print CSV blocks per table.

    PYTHONPATH=src python -m benchmarks.run [--fast]

--fast cuts training steps (CI smoke); default reproduces the full report
 in ~10 min on one CPU.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma-separated bench names to run")
    args = ap.parse_args(argv)

    from benchmarks import common
    if args.fast:
        common.N_OBJECTS = 1500
        common.N_QUERIES = 300
        common.REL_STEPS = 120
        common.IDX_STEPS = 250

    from benchmarks import (
        bench_ablation_spatial,
        bench_cluster_quality,
        bench_filters,
        bench_kernels,
        bench_memory,
        bench_neg_start,
        bench_relevance,
        bench_resilience,
        bench_scalability,
        bench_serving,
        bench_tradeoff,
    )
    suite = [
        ("Table3_relevance", bench_relevance.run),
        ("Fig4_5_tradeoff", bench_tradeoff.run),
        ("Table4_memory", bench_memory.run),
        ("Table5_cluster_quality", bench_cluster_quality.run),
        ("Fig8_neg_start", bench_neg_start.run),
        ("Table6_spatial_ablation", bench_ablation_spatial.run),
        ("Fig7_scalability", bench_scalability.run),
        ("Kernel_roofline", bench_kernels.run),
        ("Serving_stream", bench_serving.run),
        ("Filters_continuous", bench_filters.run),
        ("Serving_resilience", bench_resilience.run),
    ]
    only = {s for s in args.only.split(",") if s}
    failures = 0
    for name, fn in suite:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"\n### {name}")
        try:
            for row in fn():
                print(row)
            print(f"# ({time.time() - t0:.1f}s)")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# FAILED: {type(e).__name__}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
