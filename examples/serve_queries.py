"""Serve batched spatial-keyword requests through a trained LIST index —
both query-phase implementations:

  * gather path (single host): route → gather cluster buffer → fused
    score (optionally the Pallas kernel) → top-k
  * dispatch path (the multi-chip layout): clusters-as-experts dispatch
    (core/serving.py), verified here against the gather path

    PYTHONPATH=src python examples/serve_queries.py [--use-pallas]
"""
import argparse
import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.core import serving
from repro.core import spatial as sp
from repro.core.pipeline import ListRetriever
from repro.data import GeoCorpus, GeoCorpusConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--use-pallas", action="store_true",
                    help="legacy alias for --backend pallas")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "dense", "auto"],
                    help="engine backend: pallas = gather-free fused "
                         "kernel, dense = jnp reference, auto = per "
                         "platform (core/engine.py)")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()
    from repro.core.engine import legacy_backend
    backend = legacy_backend(args.backend, args.use_pallas)

    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=2000, n_queries=400, n_topics=12, vocab_size=4096, seed=0))
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=8, neg_start=1000,
        neg_end=1200, index_mlp_hidden=(64,))
    r = ListRetriever(cfg, corpus)
    print("training retriever ...")
    r.train_relevance(steps=200, batch=64, lr=1.5e-3, log_every=10**9)
    r.train_index(steps=400, batch=64, lr=3e-3, log_every=10**9)
    r.build()

    tr, va, te = corpus.split()
    req = te[: args.requests]
    positives = [corpus.positives[q] for q in req]

    # engine path (backend-selected: gather-free pallas kernel or dense)
    t0 = time.time()
    ids_g, sc_g = r.query(req, k=args.k, cr=1, backend=backend, batch=64)
    t_g = time.time() - t0
    print(f"engine path ({backend}): "
          f"recall@{args.k}={cm.recall_at_k(ids_g, positives, args.k):.3f} "
          f"{t_g:.2f}s for {len(req)} requests")

    # dispatch path (the multi-pod serving layout, run on one host)
    tok, msk = corpus.query_tokens(req)
    w_hat = sp.extract_lookup(r.rel_params["spatial"])
    t0 = time.time()
    ids_d, sc_d, n_dropped = serving.cluster_dispatch_query(
        r.rel_params, r.index_params, w_hat, r.norm,
        r.buffers["emb"], r.buffers["loc"], r.buffers["ids"],
        jnp.asarray(tok), jnp.asarray(msk),
        jnp.asarray(corpus.q_loc[req].astype(np.float32)), cfg,
        k=args.k, cr=1, dist_max=corpus.dist_max, return_dropped=True)
    t_d = time.time() - t0
    print(f"dispatch path (clusters-as-experts): "
          f"recall@{args.k}={cm.recall_at_k(np.asarray(ids_d), positives, args.k):.3f} "
          f"{t_d:.2f}s  dropped={int(n_dropped)} (query, route) pairs")

    agree = (np.asarray(ids_d) == ids_g).mean()
    print(f"paths agree on {agree:.1%} of returned ids "
          f"({int(n_dropped)} capacity drops account for the rest)")


if __name__ == "__main__":
    main()
