"""Serve spatial-keyword requests through a trained LIST index — all three
serving layers, all fed by ONE immutable `IndexSnapshot` (repro.api):

  * streaming server (core/server.py): async micro-batcher + result
    caches + warm-up over the unified engine — the long-lived path
  * engine path (single host, one-shot): route → score → top-k
  * dispatch path (the multi-chip layout): clusters-as-experts dispatch
    (core/serving.py), verified here against the engine path

    PYTHONPATH=src python examples/serve_queries.py [--backend dense]
"""
import argparse
import dataclasses
import time

import numpy as np

from repro import api
from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.core import server as server_lib
from repro.core import serving
from repro.core.engine import resolve_cli_backend
from repro.data import GeoCorpus, GeoCorpusConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--use-pallas", action="store_true",
                    help="DEPRECATED alias for --backend pallas "
                         "(warns and forwards)")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "pallas-cm", "dense", "dense-cm",
                             "auto"],
                    help="engine backend: pallas = gather-free fused "
                         "kernel, *-cm = cluster-major batched execution "
                         "(DESIGN.md §10), dense = jnp reference, auto = "
                         "per platform + per-batch dedup (core/engine.py)")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()
    backend = resolve_cli_backend(args.backend, args.use_pallas)

    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=2000, n_queries=400, n_topics=12, vocab_size=4096, seed=0))
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=8, neg_start=1000,
        neg_end=1200, index_mlp_hidden=(64,))
    print("training retriever ...")
    snap = api.build(cfg, corpus, rel_steps=200, idx_steps=400,
                     rel_lr=1.5e-3, idx_lr=3e-3, log_every=10**9)
    searcher = api.Searcher(snap)

    tr, va, te = corpus.split()
    req = te[: args.requests]
    positives = [corpus.positives[q] for q in req]
    tok, msk = corpus.query_tokens(req)
    loc = corpus.q_loc[req].astype(np.float32)

    # streaming server: micro-batched requests over the engine, pre-warmed.
    # batch_size matches the direct engine call below — the bit-identity
    # guarantee holds per batch SHAPE (same shape ⇒ same jitted program)
    server = searcher.serve(server_lib.ServerConfig(
        batch_size=64, max_delay_ms=2.0, k=args.k, cr=1, backend=backend))
    server.warmup()
    t0 = time.time()
    ids_s, sc_s = server.serve_all(tok, msk, loc)
    ids_s, sc_s = server.serve_all(tok, msk, loc)   # replay: cache hits
    t_s = time.time() - t0
    m = server.metrics(wall_seconds=t_s)
    print(f"streaming server ({backend}): "
          f"recall@{args.k}={cm.recall_at_k(ids_s, positives, args.k):.3f} "
          f"{t_s:.2f}s for {m['requests']} requests "
          f"(hit_rate={m['hit_rate']:.1%}, "
          f"p95={m['latency_ms']['p95']:.1f}ms, "
          f"{m['engine_batches']} engine batches)")

    # engine path, one-shot (backend-selected: gather-free pallas or dense)
    t0 = time.time()
    ids_g, sc_g = searcher.query(tok, msk, loc, k=args.k, cr=1,
                                 backend=backend, batch=64)
    t_g = time.time() - t0
    print(f"engine path ({backend}): "
          f"recall@{args.k}={cm.recall_at_k(ids_g, positives, args.k):.3f} "
          f"{t_g:.2f}s for {len(req)} requests")
    assert (np.sort(ids_s, 1) == np.sort(ids_g, 1)).all(), \
        "streaming server and direct engine path disagree"

    # dispatch path (the multi-pod serving layout, run on one host) —
    # same snapshot, same score_candidates scoring surface
    t0 = time.time()
    ids_d, sc_d, n_dropped = serving.cluster_dispatch_query(
        snap, tok, msk, loc, k=args.k, cr=1, return_dropped=True)
    t_d = time.time() - t0
    print(f"dispatch path (clusters-as-experts): "
          f"recall@{args.k}={cm.recall_at_k(np.asarray(ids_d), positives, args.k):.3f} "
          f"{t_d:.2f}s  dropped={int(n_dropped)} (query, route) pairs")

    agree = (np.asarray(ids_d) == ids_g).mean()
    print(f"paths agree on {agree:.1%} of returned ids "
          f"({int(n_dropped)} capacity drops account for the rest)")


if __name__ == "__main__":
    main()
