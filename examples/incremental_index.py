"""Insertion/deletion via the LSM-style delta write path (paper §4.3
"Insertion and Deletion Policy" + DESIGN.md §8/§11): new POIs stream in
and become visible to queries the instant the successor `IndexSnapshot`
is published to the live server — in O(batch), because writes append to
the snapshot's small delta segment instead of rebuilding the (c, cap)
cluster buffers. Deletes tombstone. Compaction later folds the delta
into its §4.3 clusters (here forced via ``compact_now`` to show the
fold; a live server triggers it in the background past
``delta_threshold``). The resident index is never mutated in place —
each write derives version N+1 and swaps it atomically, so concurrent
traffic is never served a torn index.

    PYTHONPATH=src python examples/incremental_index.py
"""
import dataclasses

import numpy as np
import jax.numpy as jnp

from repro import api
from repro.configs import get_config
from repro.core import pipeline as pl
from repro.core import server as server_lib
from repro.data import GeoCorpus, GeoCorpusConfig

NEW_ID_BASE = 10_000


def main():
    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=2000, n_queries=400, n_topics=12, vocab_size=4096, seed=0))
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=8, neg_start=1000,
        neg_end=1200, index_mlp_hidden=(64,))
    snap = api.build(cfg, corpus, rel_steps=200, idx_steps=400,
                     rel_lr=1.5e-3, idx_lr=3e-3, log_every=10**9)
    print(f"snapshot v{snap.meta.version}: cluster sizes "
          f"{np.asarray(snap.buffers['counts']).tolist()}")

    # a live server over the snapshot (micro-batcher + result caches);
    # the high delta_threshold keeps compaction manual for this demo
    server = api.Searcher(snap).serve(server_lib.ServerConfig(
        batch_size=32, max_delay_ms=2.0, k=20, cr=cfg.n_clusters,
        delta_threshold=4096))

    # probe workload: the held-out queries of a NEW downtown district
    new_city = GeoCorpus(GeoCorpusConfig(
        n_objects=200, n_queries=40, n_topics=12, vocab_size=4096, seed=9))
    probe_ids = np.arange(new_city.cfg.n_queries)
    tok, msk = new_city.query_tokens(probe_ids)
    loc = new_city.q_loc[probe_ids].astype(np.float32)

    ids_before, _ = server.serve_all(tok, msk, loc)
    assert not (ids_before >= NEW_ID_BASE).any()     # nothing to see yet

    # --- the new district's POIs open: embed, append, PUBLISH -------------
    new_emb = pl.embed_objects(snap.rel_params, new_city, cfg)
    new_loc = new_city.obj_loc.astype(np.float32)
    new_ids = np.arange(NEW_ID_BASE, NEW_ID_BASE + new_city.cfg.n_objects)
    snap2 = server.insert_objects(jnp.asarray(new_emb), jnp.asarray(new_loc),
                                  new_ids)
    assert snap2.meta.version == snap.meta.version + 1
    assert server.engine.snapshot is snap2           # atomically published
    assert snap2.meta.delta_rows == new_city.cfg.n_objects
    print(f"published v{snap2.meta.version}: {snap2.meta.delta_rows} rows "
          f"pending in the delta segment (base untouched: "
          f"{np.asarray(snap2.buffers['counts']).tolist()}; O(batch) "
          f"write, no routing, no retraining)")

    # --- post-insert queries MUST see the new objects ----------------------
    ids_after, _ = server.serve_all(tok, msk, loc)
    n_new_hits = int((ids_after >= NEW_ID_BASE).sum())
    assert n_new_hits > 0, "published objects not visible to queries"
    print(f"post-publish: {n_new_hits} of the new district's POIs surface "
          f"in the probe queries' top-20 (cache invalidated: "
          f"{server.stats.invalidations} publishes)")
    # the original snapshot object is untouched — immutable artifacts
    assert not (np.asarray(snap.buffers["ids"]) >= NEW_ID_BASE).any()
    assert snap.delta is None

    # --- some POIs close: delete, same publish protocol --------------------
    victims = [int(i) for i in np.unique(ids_after[ids_after >= NEW_ID_BASE])
               ][:50]
    snap3 = server.delete_objects(victims)
    ids_del, _ = server.serve_all(tok, msk, loc)
    assert not np.isin(ids_del, victims).any()       # victims gone
    print(f"published v{snap3.meta.version}: {len(victims)} deletions "
          f"(delta-resident rows dropped; {snap3.meta.n_tombstones} "
          f"tombstones)")

    # --- compaction: fold the delta into its §4.3 clusters -----------------
    snap4 = server.compact_now()
    assert snap4.delta is None and snap4.meta.delta_rows == 0
    base_ids = np.asarray(snap4.buffers["ids"])
    assert (base_ids >= NEW_ID_BASE).sum() == len(new_ids) - len(victims)
    ids_comp, _ = server.serve_all(tok, msk, loc)
    assert np.array_equal(ids_comp, ids_del)         # queries unchanged
    print(f"compacted -> v{snap4.meta.version}: cluster sizes "
          f"{np.asarray(snap4.buffers['counts']).tolist()} "
          f"(results bit-identical across the fold)")


if __name__ == "__main__":
    main()
