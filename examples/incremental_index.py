"""Insertion/deletion + index-only retraining (paper §4.3 "Insertion and
Deletion Policy"): new POIs stream in, get routed by the trained index with
NO relevance-model retraining; deletions are lazy. When drift accumulates,
only the (tiny) index MLP is retrained.

    PYTHONPATH=src python examples/incremental_index.py
"""
import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.core import index as il
from repro.core import pipeline as pl
from repro.data import GeoCorpus, GeoCorpusConfig


def main():
    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=2000, n_queries=400, n_topics=12, vocab_size=4096, seed=0))
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=8, neg_start=1000,
        neg_end=1200, index_mlp_hidden=(64,))
    r = pl.ListRetriever(cfg, corpus)
    r.train_relevance(steps=200, batch=64, lr=1.5e-3, log_every=10**9)
    r.train_index(steps=400, batch=64, lr=3e-3, log_every=10**9)
    r.build()
    print("initial cluster sizes:",
          np.asarray(r.buffers["counts"]).tolist())

    # --- a new batch of POIs opens downtown --------------------------------
    new_city = GeoCorpus(GeoCorpusConfig(
        n_objects=200, n_queries=10, n_topics=12, vocab_size=4096, seed=9))
    new_emb = pl.embed_objects(r.rel_params, new_city, cfg)
    new_loc = new_city.obj_loc.astype(np.float32)
    buf2 = il.insert_objects(
        r.buffers, r.index_params, r.norm, jnp.asarray(new_emb),
        jnp.asarray(new_loc), np.arange(10_000, 10_200))
    print("after 200 insertions:", np.asarray(buf2["counts"]).tolist(),
          "(insertion = index MLP inference, no retraining)")

    # --- some POIs close ----------------------------------------------------
    buf3 = il.delete_objects(buf2, list(range(0, 100)))
    print("after 100 deletions:", np.asarray(buf3["counts"]).tolist(),
          "(lazy: ids masked, compaction deferred to next rebuild)")

    # --- drift: retrain ONLY the index (paper: relevance model untouched) --
    r.train_index(steps=200, batch=64, lr=3e-3, log_every=10**9)
    r.build()
    if_c = cm.imbalance_factor(r.obj_assign, cfg.n_clusters)
    import jax
    n_mlp = sum(int(np.prod(x.shape))
                for x in jax.tree.leaves(r.index_params))
    print(f"after index-only retrain: IF(C)={if_c:.3f} "
          f"(index MLP = {n_mlp:,} params; the dual encoder was not touched)")


if __name__ == "__main__":
    main()
