"""Quickstart: train LIST end-to-end on a small synthetic city, freeze
the built index into a durable `IndexSnapshot` artifact, reload it, and
answer spatial keyword queries — the whole paper (plus the artifact
lifecycle) in ~3 minutes on a laptop CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import tempfile

import numpy as np

from repro import api
from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.data import GeoCorpus, GeoCorpusConfig


def main():
    # 1. a city: 2000 POIs with latent topics + spatial hotspots, and a
    #    click log of 400 queries (the paper's Beijing/Shanghai analogue)
    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=2000, n_queries=400, n_topics=12, vocab_size=4096, seed=0))

    # 2. LIST = dual-encoder relevance model + learned cluster index;
    #    api.build runs Eq. 8 contrastive training, Eq. 13/14 index
    #    training, and packs the cluster buffers
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=8,
        neg_start=1000, neg_end=1200, index_mlp_hidden=(64,))
    snap = api.build(cfg, corpus, rel_steps=200, idx_steps=400,
                     rel_lr=1.5e-3, idx_lr=3e-3, verbose=True, log_every=100)
    print("cluster sizes:", np.asarray(snap.buffers["counts"]).tolist())

    # 3. the built index is an immutable artifact: save → load round-trips
    #    to bit-identical results (this is what a serving fleet deploys)
    art_dir = tempfile.mkdtemp(prefix="list_snapshot_")
    path = api.save(snap, art_dir)
    snap = api.load(art_dir)
    print(f"snapshot v{snap.meta.version} ({snap.meta.n_objects} objects, "
          f"cfg digest {snap.meta.cfg_digest}) round-tripped via {path}")

    # 4. answer the held-out queries from the LOADED artifact
    searcher = api.Searcher(snap)
    tr, va, te = corpus.split()
    positives = [corpus.positives[q] for q in te]
    ids, scores = searcher.query_corpus(corpus, te, k=10, cr=1)
    bf_ids, _ = api.brute_force(snap, corpus, te, k=10)
    cap = snap.buffers["capacity"]
    print(f"\nLIST        recall@10 = {cm.recall_at_k(ids, positives, 10):.3f}"
          f"  (scans ≤{cap} of {corpus.cfg.n_objects} objects)")
    print(f"brute force recall@10 = "
          f"{cm.recall_at_k(bf_ids, positives, 10):.3f}"
          f"  (scans all {corpus.cfg.n_objects})")

    # 5. one concrete query, end to end
    q = te[0]
    print(f"\nquery {q}: keywords={corpus.q_doc[q].tolist()} "
          f"loc={np.round(corpus.q_loc[q], 3).tolist()}")
    print(f"  top-5 objects: {ids[0][:5].tolist()}")
    print(f"  ground truth : {corpus.positives[q][:5].tolist()}")


if __name__ == "__main__":
    main()
