"""Quickstart: train LIST end-to-end on a small synthetic city and answer
spatial keyword queries — the whole paper in ~3 minutes on a laptop CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.core.pipeline import ListRetriever
from repro.data import GeoCorpus, GeoCorpusConfig


def main():
    # 1. a city: 2000 POIs with latent topics + spatial hotspots, and a
    #    click log of 400 queries (the paper's Beijing/Shanghai analogue)
    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=2000, n_queries=400, n_topics=12, vocab_size=4096, seed=0))

    # 2. LIST = dual-encoder relevance model + learned cluster index
    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=8,
        neg_start=1000, neg_end=1200, index_mlp_hidden=(64,))
    r = ListRetriever(cfg, corpus)

    print("training relevance model (contrastive, Eq. 8) ...")
    r.train_relevance(steps=200, batch=64, lr=1.5e-3, verbose=True,
                      log_every=100)
    print("training index (pseudo-labels Eq. 13 + MCL Eq. 14) ...")
    r.train_index(steps=400, batch=64, lr=3e-3, verbose=True, log_every=200)
    buf = r.build()
    print("cluster sizes:", np.asarray(buf["counts"]).tolist())

    # 3. answer the held-out queries
    tr, va, te = corpus.split()
    positives = [corpus.positives[q] for q in te]
    ids, scores = r.query(te, k=10, cr=1)
    bf_ids, _ = r.brute_force(te, k=10)
    print(f"\nLIST        recall@10 = {cm.recall_at_k(ids, positives, 10):.3f}"
          f"  (scans ≤{buf['capacity']} of {corpus.cfg.n_objects} objects)")
    print(f"brute force recall@10 = "
          f"{cm.recall_at_k(bf_ids, positives, 10):.3f}"
          f"  (scans all {corpus.cfg.n_objects})")

    # 4. one concrete query, end to end
    q = te[0]
    print(f"\nquery {q}: keywords={corpus.q_doc[q].tolist()} "
          f"loc={np.round(corpus.q_loc[q], 3).tolist()}")
    print(f"  top-5 objects: {ids[0][:5].tolist()}")
    print(f"  ground truth : {corpus.positives[q][:5].tolist()}")


if __name__ == "__main__":
    main()
