"""End-to-end driver: train a ~100M-parameter dual encoder for a few
hundred steps with checkpoint/restart — the paper's relevance model at its
real geometry (BERT-base towers), on the synthetic geo corpus.

On this CPU container the default is a scaled-down tower but the --full
flag selects the paper's exact 12L/768/12H geometry (each tower ≈ 53M,
dual ≈ 106M params) — that is what runs on the fleet.

    PYTHONPATH=src python examples/train_dual_encoder.py --steps 300
    PYTHONPATH=src python examples/train_dual_encoder.py --full --steps 200
"""
import argparse
import dataclasses
import time

import jax
import numpy as np
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import relevance
from repro.data import GeoCorpus, GeoCorpusConfig
from repro.optim import clip_by_global_norm, linear_warmup_cosine, make_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--full", action="store_true",
                    help="paper geometry (12L/768): ~106M params")
    ap.add_argument("--ckpt-dir", default="/tmp/list_dual_encoder")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_config("list-dual-encoder")
    if not args.full:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, n_heads=4,
                                  d_ff=512, vocab_size=8192, max_len=16)
    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=4000, n_queries=800, n_topics=24,
        vocab_size=cfg.vocab_size, max_len=min(cfg.max_len, 16), seed=0))

    opt_init, opt_update = make_optimizer(cfg.optimizer)

    def fresh():
        p = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
        return {"params": p, "opt": opt_init(p)}

    mgr = CheckpointManager(args.ckpt_dir, every=100, keep=2)
    state, start, _ = mgr.restore_or_init(fresh)
    n_params = sum(int(np.prod(x.shape))
                   for x in jax.tree.leaves(state["params"]))
    print(f"dual encoder: {n_params/1e6:.1f}M params "
          f"({'paper' if args.full else 'reduced'} geometry), "
          f"resume from step {start}")

    sched = linear_warmup_cosine(args.lr, 20, args.steps)

    @jax.jit
    def step_fn(state, batch, lr):
        (loss, m), g = jax.value_and_grad(
            lambda p: relevance.contrastive_loss(p, batch, cfg),
            has_aux=True)(state["params"])
        g, gn = clip_by_global_norm(g, 1.0)
        p, o = opt_update(g, state["opt"], state["params"], lr)
        return {"params": p, "opt": o}, {**m, "grad_norm": gn}

    tr, va, te = corpus.split()
    for step in range(start, args.steps):
        b = corpus.train_batch(step, args.batch, tr, b_neg=cfg.hard_neg_b)
        b.pop("query_ids")
        b = {k: jnp.asarray(v) for k, v in b.items()}
        t0 = time.time()
        state, m = step_fn(state, b, sched(jnp.int32(step)))
        if step % 25 == 0 or step == args.steps - 1:
            print(f"step {step}: loss={float(m['loss']):.4f} "
                  f"acc={float(m['acc']):.3f} ({(time.time()-t0)*1e3:.0f}ms)")
        mgr.maybe_save(step + 1, state, meta={"loss": float(m["loss"])})
    mgr.maybe_save(args.steps, state, force=True)
    print("done; checkpoints in", args.ckpt_dir)


if __name__ == "__main__":
    main()
