"""Fault-tolerant sharded checkpointing (see ckpt.py)."""
from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointManager,
    latest_step,
    read_meta,
    restore,
    save,
)
