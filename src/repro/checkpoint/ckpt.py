"""Mesh-agnostic, fault-tolerant checkpointing.

Layout (one directory per step)::

    <dir>/step_000100.tmp/        # written first
        manifest.json             # tree structure, dtypes, shapes, meta
        arr_00000.npy ...         # one file per leaf (host-gathered)
    <dir>/step_000100/            # atomic rename == commit

Properties needed at 1000-node scale:

- **Atomic commit**: readers never observe a half-written checkpoint — the
  ``.tmp`` directory is renamed only after every array and the manifest are
  flushed. A crash mid-write leaves a ``.tmp`` that restore ignores and the
  next save garbage-collects.
- **Elastic reload**: arrays are saved *logically* (fully replicated numpy
  via multihost gather); restore re-shards onto whatever mesh/sharding the
  new job provides — the checkpoint does not bake in topology. This is what
  lets a 512-chip job resume on 256 chips after losing a pod.
- **Keep-k GC**: old steps are pruned after a successful commit.
- Leaf files are plain ``.npy`` so any tool can inspect them.
- **Dtype-faithful leaves**: the manifest records each leaf's TRUE dtype.
  Extension dtypes the ``.npy`` format can't express (bfloat16 — numpy
  round-trips it as an opaque void) are stored as a same-width unsigned
  view and bit-exactly viewed back on restore, so quantized/bf16 index
  buffers (core/snapshot.py precision tiers) survive save→load.

On real multi-host fleets the per-leaf gather would be
``multihost_utils.process_allgather`` + per-host shard files; on this
single-process container ``jax.device_get`` is the same code path with
world size 1 (the manifest format already records per-leaf sharding specs
for the sharded-file extension).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import zlib
from typing import Any, Callable, Optional

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 with np.dtype)
import numpy as np

from repro.core import faults as faults_lib

_STEP_RE = re.compile(r"^step_(\d{9})$")

# extension dtypes .npy cannot round-trip → same-width storage view
_VIEW_DTYPES = {"bfloat16": np.uint16}


class SnapshotCorrupt(ValueError):
    """A committed checkpoint that cannot be trusted: truncated or
    garbage manifest, a leaf file whose checksum doesn't match the
    manifest, or a leaf file missing outright. Distinct from "no
    checkpoint here" (``FileNotFoundError``) so recovery code can walk
    back to an older step instead of treating corruption as absence."""


def _step_dir(directory: str, step: int, tmp=False) -> str:
    name = f"step_{step:09d}"
    return os.path.join(directory, name + (".tmp" if tmp else ""))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _crc_file(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                return crc
            crc = zlib.crc32(chunk, crc)


def _fsync_dir(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(directory: str, step: int, tree: Any, *, meta: Optional[dict] = None,
         keep: int = 3) -> str:
    """Save a pytree of arrays. Returns the committed path.

    Commit sequence (crash anywhere leaves a loadable state):
    leaves + manifest land in ``<step>.tmp`` and are fsync'd, then the
    tmp dir renames into place. When a committed dir for the same step
    already exists it is first renamed aside to ``<step>.old`` (an
    atomic rename, unlike rmtree-then-rename which has a window with NO
    committed artifact) and deleted only after the new commit.
    Per-leaf crc32s in the manifest let ``restore`` detect bit-rot or a
    post-commit partial overwrite as :class:`SnapshotCorrupt`.
    """
    os.makedirs(directory, exist_ok=True)
    tmp = _step_dir(directory, step, tmp=True)
    final = _step_dir(directory, step)
    old = final + ".old"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "meta": meta or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"arr_{i:05d}.npy"
        stored = arr
        if str(arr.dtype) in _VIEW_DTYPES:
            stored = arr.view(_VIEW_DTYPES[str(arr.dtype)])
        leaf_path = os.path.join(tmp, fn)
        with open(leaf_path, "wb") as f:
            np.save(f, stored)
            f.flush()
            os.fsync(f.fileno())
        # manifest records the TRUE dtype; restore views back when the
        # stored file's dtype differs
        manifest["leaves"].append(
            {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
             "crc32": _crc_file(leaf_path)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    faults_lib.fire("ckpt.mid_save", tmp=tmp, final=final)
    if os.path.exists(final):
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(final, old)
    os.rename(tmp, final)          # atomic commit
    _fsync_dir(directory)
    shutil.rmtree(old, ignore_errors=True)
    _gc(directory, keep)
    faults_lib.fire("ckpt.post_commit", path=final)
    return final


def _gc(directory: str, keep: int):
    steps = all_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)
    # orphaned tmp/old dirs from crashed writers
    for name in os.listdir(directory):
        if name.endswith(".tmp") or name.endswith(".old"):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def _read_manifest(path: str) -> dict:
    """Parse ``<step dir>/manifest.json``, folding every way a truncated
    or garbage file can fail (empty file, cut-off JSON, binary noise,
    JSON of the wrong shape) into one :class:`SnapshotCorrupt`."""
    mf = os.path.join(path, "manifest.json")
    try:
        with open(mf, "rb") as f:
            manifest = json.loads(f.read().decode("utf-8"))
    except FileNotFoundError:
        raise
    except (json.JSONDecodeError, UnicodeDecodeError, ValueError) as e:
        raise SnapshotCorrupt(
            f"{mf}: manifest is truncated or garbage ({e}); the commit "
            f"was damaged after the fact — fall back to an older step "
            f"or re-build the artifact") from e
    if not isinstance(manifest, dict) or "meta" not in manifest \
            or "leaves" not in manifest:
        raise SnapshotCorrupt(
            f"{mf}: manifest parses as JSON but is not a checkpoint "
            f"manifest (missing meta/leaves blocks)")
    return manifest


def read_meta(directory: str, *, step: Optional[int] = None):
    """Read a committed checkpoint's ``meta`` block without touching the
    array files. Returns ``(meta, step)``. Lets artifact readers (e.g.
    core/snapshot.py) validate schema/config identity and rebuild the
    tree structure BEFORE deciding to load gigabytes of leaves.
    Raises :class:`SnapshotCorrupt` on a truncated/garbage manifest."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    return _read_manifest(_step_dir(directory, step))["meta"], step


def restore(directory: str, tree_like: Any, *, step: Optional[int] = None,
            shard_fn: Optional[Callable[[Any], Any]] = None):
    """Restore into the structure of ``tree_like`` (shapes validated).

    shard_fn: optional fn(host_tree) -> device_tree applying the *new* mesh's
    shardings (elastic reload); default leaves arrays on host for the caller
    (e.g. jax.device_put with NamedShardings) to place.
    Returns (tree, step, meta).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = _step_dir(directory, step)
    manifest = _read_manifest(path)
    leaves_ref, treedef = _flatten(tree_like)
    if len(leaves_ref) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, expected "
            f"{len(leaves_ref)} — structure mismatch")
    leaves = []
    for i, (info, ref) in enumerate(zip(manifest["leaves"], leaves_ref)):
        leaf_path = os.path.join(path, info["file"])
        want_crc = info.get("crc32")
        try:
            if want_crc is not None and _crc_file(leaf_path) != want_crc:
                raise SnapshotCorrupt(
                    f"leaf {i} ({leaf_path}): checksum mismatch vs "
                    f"manifest — the committed file was damaged")
            arr = np.load(leaf_path)
        except SnapshotCorrupt:
            raise
        except FileNotFoundError as e:
            raise SnapshotCorrupt(
                f"leaf {i} ({leaf_path}): missing from a committed "
                f"checkpoint") from e
        except ValueError as e:
            raise SnapshotCorrupt(
                f"leaf {i} ({leaf_path}): not a readable .npy "
                f"({e})") from e
        want = info.get("dtype")
        if want and str(arr.dtype) != want:
            # leaf was stored under a view dtype (e.g. bf16 → uint16):
            # bit-exact view back to the manifest's true dtype
            arr = arr.view(np.dtype(want))
        if hasattr(ref, "shape") and tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"leaf {i}: checkpoint shape {arr.shape} != expected "
                f"{tuple(ref.shape)}")
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shard_fn is not None:
        tree = shard_fn(tree)
    return tree, step, manifest["meta"]


class CheckpointManager:
    """Step-driven convenience wrapper with save-every-N policy and
    auto-resume: the training loop calls ``maybe_save`` each step and
    ``restore_or_init`` once at startup."""

    def __init__(self, directory: str, *, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, tree, *, meta=None, force=False):
        if force or (self.every > 0 and step % self.every == 0 and step > 0):
            return save(self.directory, step, tree, meta=meta, keep=self.keep)
        return None

    def restore_or_init(self, init_fn: Callable[[], Any], *,
                        shard_fn=None):
        """Returns (tree, start_step, meta). start_step is 0 on fresh init."""
        step = latest_step(self.directory)
        if step is None:
            return init_fn(), 0, {}
        tree_like = jax.eval_shape(init_fn)
        tree, step, meta = restore(self.directory, tree_like, step=step,
                                   shard_fn=shard_fn)
        return tree, step, meta
