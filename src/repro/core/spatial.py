"""Learnable monotonic step-function spatial relevance (paper §4.2, Eq. 4–5).

Training form (Eq. 4): SRel = Σ_i act(w_s[i]) · 1[S_in ≥ T[i]] with uniform
thresholds T[i] = i/t. ``act`` = softplus keeps every increment non-negative,
so the learned function is monotonically non-decreasing in S_in (i.e.
non-increasing in distance) BY CONSTRUCTION — the paper's feature (1) — and
piecewise-constant between thresholds — feature (2).

Serving form (Eq. 5): the prefix sums ŵ_s[i] = Σ_{j≤i} act(w_s[j]) are
extracted once; SRel = ŵ_s[⌊S_in · t⌋] is a single O(1) gather, fused into
the score kernel (kernels/fused_topk_score).

The indicator in Eq. 4 has zero gradient; we train with the straight-through
surrogate used in practice for step functions: a temperature-controlled
sigmoid relaxation of the indicator (exact step in the forward pass, sigmoid
gradient in the backward pass).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spatial_init(key, t: int):
    # small positive initial increments: roughly linear ramp as a prior
    return {"w_s": jnp.full((t,), -2.0) + 0.01 * jax.random.normal(key, (t,))}


def thresholds(t: int):
    return jnp.arange(t, dtype=jnp.float32) / t       # T[i] = i/t


@jax.custom_vjp
def _step_indicator(s_in, thr, tau):
    """1[s_in >= thr] with sigmoid surrogate gradient (temperature tau)."""
    return (s_in[..., None] >= thr).astype(jnp.float32)


def _step_fwd(s_in, thr, tau):
    out = _step_indicator(s_in, thr, tau)
    return out, (s_in, thr, tau)


def _step_bwd(res, g):
    s_in, thr, tau = res
    z = (s_in[..., None] - thr) / tau
    sig = jax.nn.sigmoid(z)
    ds = (g * sig * (1 - sig) / tau).sum(-1)
    return ds, None, None


_step_indicator.defvjp(_step_fwd, _step_bwd)


def spatial_relevance_train(params, s_in, *, t: int, tau: float = 0.05):
    """Eq. 4. s_in: (...,) in [0, 1] → SRel (...,). Differentiable in both
    w_s (exact) and s_in (straight-through)."""
    w = jax.nn.softplus(params["w_s"])                 # (t,) non-negative
    ind = _step_indicator(s_in, thresholds(t), tau)    # (..., t)
    return ind @ w


def extract_lookup(params):
    """Eq. 5 preparation: ŵ_s[i] = Σ_{j<=i} act(w_s[j]). Returns (t,) table."""
    return jnp.cumsum(jax.nn.softplus(params["w_s"]))


def spatial_relevance_serve(w_hat, s_in):
    """Eq. 5: O(1) lookup. w_hat: (t,); s_in: (...,) → (...,)."""
    t = w_hat.shape[0]
    idx = jnp.clip(jnp.floor(s_in * t).astype(jnp.int32), 0, t - 1)
    return jnp.take(w_hat, idx)


# --- distances -------------------------------------------------------------


def sdist(q_loc, o_loc, dist_max):
    """Normalized Euclidean distance (paper §3.1). q_loc: (..., 2)."""
    d = jnp.linalg.norm(q_loc - o_loc, axis=-1)
    return jnp.clip(d / dist_max, 0.0, 1.0)


def s_in_from_locs(q_loc, o_loc, dist_max):
    return 1.0 - sdist(q_loc, o_loc, dist_max)


# --- ablation variants (paper Table 6) -------------------------------------


def linear_srel(s_in):
    """LIST-R + S_in ablation: spatial relevance = S_in itself."""
    return s_in


def exp_init(key):
    return {"alpha": jnp.zeros(()), "beta": jnp.zeros(())}


def exp_srel(params, s_in):
    """LIST-R + α·S_in^β ablation (learnable, both kept non-negative)."""
    a = jax.nn.softplus(params["alpha"])
    b = jax.nn.softplus(params["beta"])
    return a * jnp.power(jnp.maximum(s_in, 1e-6), b)
