"""Unified LIST query engine (DESIGN.md §3–§5).

Every query-phase consumer — :class:`~repro.core.pipeline.ListRetriever`,
the distributed dispatch path (core/serving.py), the baselines' reranker,
and the benchmarks — goes through this module. It owns the three things
that used to be duplicated (and therefore drifted) across them:

1. **Backend selection.** ``backend="pallas" | "dense" | "auto"``:

   * ``"pallas"`` — the gather-free fused kernel
     (kernels/fused_topk_score_routed): routed cluster ids are
     scalar-prefetched and the resident ``(c, cap, d)`` buffers are
     block-indexed directly, so no ``(B, cr·cap, d)`` candidate copy is
     ever materialized and the ``cr`` routed lists merge in-kernel.
   * ``"dense"`` — the pure-jnp reference path (gather + one
     ``jax.lax.top_k``). Always available, and the parity oracle.
   * ``"auto"`` — ``"pallas"`` when a compiled TPU backend is present,
     else ``"dense"`` (interpret-mode Pallas is a correctness tool, not a
     fast path).

   ``interpret`` for the Pallas kernels is auto-detected from the
   platform (off-TPU ⇒ interpreter) and can be forced with the
   ``REPRO_PALLAS_COMPILE=1`` env var, matching kernels/ops.py.

2. **The ``score_candidates`` primitive.** One dense ST(q, o) scorer
   (Eq. 5 serve form) with leading-dim broadcasting, used by the engine's
   dense backend, serving's per-cluster batched score, and the baselines'
   candidate reranking — so "the score" has exactly one definition.

3. **Static-shape batch padding.** :func:`run_batched` pads the trailing
   partial batch to the jitted batch shape (one compile per shape) and
   trims the outputs; previously re-implemented in ``query``,
   ``brute_force``, and ``_embed``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import index as index_lib
from repro.core import relevance
from repro.core import spatial as sp

NEG_INF = -1e30

BACKENDS = ("pallas", "dense", "auto")


# ---------------------------------------------------------------------------
# Backend selection
# ---------------------------------------------------------------------------


def default_interpret() -> bool:
    """Interpret-mode default for the Pallas kernels: compiled on TPU (or
    when forced via REPRO_PALLAS_COMPILE=1), interpreted everywhere else.
    Shared with kernels/ops.py so every entry point agrees."""
    from repro.kernels import ops as kops
    return kops._interpret_default()


def resolve_backend(backend: str = "auto",
                    interpret: Optional[bool] = None) -> Tuple[str, bool]:
    """→ (backend ∈ {"pallas", "dense"}, interpret flag for pallas).

    "auto" keys on the HARDWARE (pallas iff a TPU backend is present),
    not on the interpret flag — REPRO_PALLAS_COMPILE=1 on a CPU host
    must not route auto callers into a Mosaic lowering that cannot
    compile there."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    interpret = default_interpret() if interpret is None else interpret
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "dense"
    return backend, interpret


def legacy_backend(backend: Optional[str], use_pallas: bool) -> str:
    """Resolve the legacy ``use_pallas`` flag: an explicit ``backend``
    always wins; otherwise the bool maps to pallas/dense. The single
    definition of this alias rule for every entry point."""
    if backend is not None:
        return backend
    return "pallas" if use_pallas else "dense"


# ---------------------------------------------------------------------------
# The one scoring primitive (Eq. 5 serve form)
# ---------------------------------------------------------------------------


def score_candidates(q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids,
                     w_hat, *, dist_max: float):
    """ST(q, o) = w_t·(q·o) + w_s·ŵ_s[⌊S_in·t⌋] over explicit candidates.

    Shapes broadcast over leading dims: q_emb (..., d), q_loc (..., 2),
    w_st (..., 2) against cand_emb (..., N, d), cand_loc (..., N, 2),
    cand_ids (..., N). Returns (..., N) f32 with padding (ids < 0) masked
    to NEG_INF (-1e30, finite — NOT -inf: the Pallas kernels use the same
    sentinel, keeping backends bit-identical; filter results by
    ``ids >= 0``, not ``isfinite(score)``). Callers:

    * engine dense backend:  q (B, d)    × cand (B, N, d)
    * serving per-cluster:   q (c, Q, d) × cand (c, 1, cap, d)
    * baselines rerank:      q (d,)      × cand (N, d)
    """
    trel = jnp.einsum("...d,...nd->...n", q_emb.astype(jnp.float32),
                      cand_emb.astype(jnp.float32))
    d = jnp.linalg.norm(q_loc[..., None, :].astype(jnp.float32)
                        - cand_loc.astype(jnp.float32), axis=-1)
    s_in = 1.0 - jnp.clip(d / dist_max, 0.0, 1.0)
    srel = sp.spatial_relevance_serve(w_hat, s_in)
    st = w_st[..., :1] * trel + w_st[..., 1:2] * srel
    return jnp.where(cand_ids >= 0, st, NEG_INF)


def dense_routed_topk(q_emb, q_loc, w_st, top_c, buf_emb, buf_loc, buf_ids,
                      w_hat, *, k: int, dist_max: float):
    """Dense reference for the routed query phase: gather + one top-k.

    Returns (scores (B, k), ids (B, k) global object ids, -1 past-the-end)
    — the exact contract of kernels/fused_topk_score_routed.
    """
    b = q_emb.shape[0]
    cand_emb = buf_emb[top_c].reshape(b, -1, buf_emb.shape[-1])
    cand_loc = buf_loc[top_c].reshape(b, -1, 2)
    cand_ids = buf_ids[top_c].reshape(b, -1)
    st = score_candidates(q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids,
                          w_hat, dist_max=dist_max)
    scores, pos = jax.lax.top_k(st, k)
    ids = jnp.take_along_axis(cand_ids, pos, axis=1)
    return scores, ids


# ---------------------------------------------------------------------------
# The routed query phase: encode → route → score → top-k
# ---------------------------------------------------------------------------


def make_query_fn(cfg, *, cr: int = 1, k: int = 20, backend: str = "auto",
                  interpret: Optional[bool] = None,
                  dist_max: float = 1.4142, weight_mode: str = "mlp",
                  block_n: int = 512):
    """Build the jitted query-phase function (paper Algorithm 1).

    signature: fn(rel_params, index_params, w_hat, norm,
                  buf_emb, buf_loc, buf_ids, q_tokens, q_mask, q_loc)
               -> (ids (B, k) global object ids, scores (B, k))

    ``backend="pallas"`` runs gather-free (scalar-prefetched routing into
    the resident buffers, in-kernel cr-merge); ``"dense"`` is the jnp
    reference (gather + top-k); ``"auto"`` picks per platform.
    """
    backend, interpret = resolve_backend(backend, interpret)

    def query_fn(rel_params, index_params, w_hat, norm, buf_emb, buf_loc,
                 buf_ids, q_tokens, q_mask, q_loc):
        q_emb = relevance.encode_queries(rel_params, q_tokens, q_mask, cfg)
        feats = index_lib.build_features(q_emb, q_loc, norm)
        top_c, _ = index_lib.route_queries(index_params, feats, cr=cr)
        w = relevance.st_weights(rel_params, q_emb,
                                 weight_mode=weight_mode)          # (B, 2)
        if backend == "pallas":
            from repro.kernels import fused_topk_score as fts
            score, ids = fts.fused_topk_score_routed(
                q_emb, q_loc, w, top_c, buf_emb, buf_loc, buf_ids, w_hat,
                k=k, dist_max=dist_max, block_n=block_n,
                interpret=interpret)
        else:
            score, ids = dense_routed_topk(
                q_emb, q_loc, w, top_c, buf_emb, buf_loc, buf_ids, w_hat,
                k=k, dist_max=dist_max)
        return ids, score

    return jax.jit(query_fn)


# ---------------------------------------------------------------------------
# Static-shape batch padding (one compile per batch shape)
# ---------------------------------------------------------------------------


def pad_leading(arr, batch: int):
    """Zero-pad axis 0 of ``arr`` up to ``batch`` rows (numpy, no-op jit)."""
    n = arr.shape[0]
    if n == batch:
        return arr
    assert n < batch, (n, batch)
    return np.pad(arr, ((0, batch - n),) + ((0, 0),) * (arr.ndim - 1))


def run_batched(fn: Callable, arrays: Sequence[np.ndarray], *, batch: int):
    """Map a jitted ``fn`` over ``arrays`` in static-shape chunks.

    Every chunk fed to ``fn`` has exactly ``batch`` rows (the trailing
    partial chunk is zero-padded, outputs trimmed) so the jit compiles
    once. ``fn(*chunks) -> array | tuple``; returns np.ndarray(s)
    concatenated back to the full leading dim.
    """
    n = arrays[0].shape[0]
    assert all(a.shape[0] == n for a in arrays), [a.shape for a in arrays]
    outs = None
    for s in range(0, n, batch):
        e = min(s + batch, n)
        chunk = [pad_leading(np.asarray(a[s:e]), batch) for a in arrays]
        res = fn(*[jnp.asarray(c) for c in chunk])
        res = res if isinstance(res, (tuple, list)) else (res,)
        if outs is None:
            outs = [[] for _ in res]
        for o, r in zip(outs, res):
            o.append(np.asarray(r)[: e - s])
    cat = tuple(np.concatenate(o, axis=0) for o in outs)
    return cat if len(cat) > 1 else cat[0]


# ---------------------------------------------------------------------------
# Stateful façade
# ---------------------------------------------------------------------------


class QueryEngine:
    """Bound (params + buffers) query engine with cached jitted plans.

    Both the single-host path (``ListRetriever.query``) and callers that
    hold raw artifacts use this; the distributed dispatch path shares
    :func:`score_candidates` instead (its data movement is the point).
    """

    def __init__(self, cfg, rel_params, index_params, norm, buffers, *,
                 dist_max: float, spatial_mode: str = "step",
                 weight_mode: str = "mlp", backend: str = "auto",
                 interpret: Optional[bool] = None):
        self.cfg = cfg
        self.rel_params = rel_params
        self.index_params = index_params
        self.norm = norm
        self.buffers = buffers
        self.dist_max = float(dist_max)
        self.spatial_mode = spatial_mode
        self.weight_mode = weight_mode
        self.backend, self.interpret = resolve_backend(backend, interpret)
        self._plans = {}

    @property
    def w_hat(self):
        """Serve-form step table (Eq. 5), recomputed from the CURRENT
        rel_params on every access — in-place updates of the spatial
        sub-params are picked up without rebuilding the engine (it's a
        jit argument, so no recompile either)."""
        if self.spatial_mode == "step":
            return sp.extract_lookup(self.rel_params["spatial"])
        return jnp.linspace(0, 1, self.cfg.spatial_t)

    def query_fn(self, *, k: int, cr: int, backend: Optional[str] = None):
        backend = self.backend if backend is None else backend
        key = (k, cr, backend)
        if key not in self._plans:
            self._plans[key] = make_query_fn(
                self.cfg, cr=cr, k=k, backend=backend,
                interpret=self.interpret, dist_max=self.dist_max,
                weight_mode=self.weight_mode)
        return self._plans[key]

    def query(self, q_tokens, q_mask, q_loc, *, k: int = 20, cr: int = 1,
              batch: int = 256, backend: Optional[str] = None):
        """Batched routed query: (ids (n, k), scores (n, k)) numpy."""
        fn = self.query_fn(k=k, cr=cr, backend=backend)
        buf = self.buffers
        w_hat = self.w_hat          # once per call, not per chunk
        return run_batched(
            lambda t, m, l: fn(self.rel_params, self.index_params,
                               w_hat, self.norm, buf["emb"], buf["loc"],
                               buf["ids"], t, m, l),
            [q_tokens, q_mask, q_loc], batch=batch)
