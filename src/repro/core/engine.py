"""Unified LIST query engine (DESIGN.md §2–§6).

This module is the single entry point to the paper's **query phase**
(Algorithm 1: encode the query → build index features → route to the
top-``cr`` learned clusters → score those clusters' resident objects →
top-k). Every consumer — :class:`~repro.core.pipeline.ListRetriever`,
the streaming server (core/server.py), the distributed dispatch path
(core/serving.py), the baselines' reranker, and the benchmarks — goes
through it, so routing, scoring, and batching each have exactly one
definition.

Public surface
--------------

:func:`make_query_fn`
    Build the jitted end-to-end query function for a model config.
    Returns ``fn(rel_params, index_params, w_hat, norm, buf_emb,
    buf_loc, buf_ids, buf_scale, q_tokens, q_mask, q_loc) ->
    (ids, scores)``. This is the function a serving process compiles
    once and calls on every batch.

:func:`score_candidates`
    The one dense scoring primitive: ST(q, o) over an explicit
    candidate set, used by the dense backend, the dispatch path's
    per-cluster score, and baseline reranking.

:func:`run_batched`
    Static-shape batch execution: map a jitted function over arrays in
    fixed-size chunks, zero-padding the trailing partial chunk so the
    function compiles for exactly one batch shape.

:class:`QueryEngine`
    A stateless executor over an immutable ``IndexSnapshot``
    (core/snapshot.py, DESIGN.md §8) with a cache of traced plans keyed
    ``(batch, k, cr, backend, precision)`` — what the streaming server and the
    retriever hold onto. Snapshot swaps go through
    :meth:`QueryEngine.publish` (atomic, digest-checked); plans survive
    them.

:func:`resolve_backend` / :func:`resolve_cli_backend` /
:data:`BACKENDS`
    Backend-selection rules. ``resolve_cli_backend`` is the ONLY home
    of the deprecated ``--use-pallas`` alias (warns and forwards — see
    below and DESIGN.md §6); library code takes ``backend=`` only.

Inputs, throughout: ``q_tokens (B, L) int32`` hashed token ids with
token 0 = padding, ``q_mask (B, L) bool`` True on real tokens,
``q_loc (B, 2) float32`` locations in the unit box, and the cluster
buffers of ``index.build_cluster_buffers`` — ``buf_emb (c, cap, d)``
(f32, bf16, or int8 per the precision policy, DESIGN.md §9),
``buf_loc (c, cap, 2)``, ``buf_ids (c, cap)`` with ``-1`` marking
padding slots, ``buf_scale (c, cap)`` f32 dequant scales. Outputs:
``ids (B, k)`` **global object ids** with ``-1`` past-the-end, and
``scores (B, k)`` f32 descending.

Backend selection
-----------------

``backend="pallas" | "pallas-cm" | "dense" | "dense-cm" | "auto"``:

* ``"pallas"`` — the gather-free fused kernel
  (kernels/fused_topk_score_routed): routed cluster ids are
  scalar-prefetched and the resident ``(c, cap, d)`` buffers are
  block-indexed directly, so no ``(B, cr·cap, d)`` candidate copy is
  ever materialized and the ``cr`` routed lists merge in-kernel.
  Query-major: a cluster routed by many queries streams once per route.
* ``"pallas-cm"`` — the CLUSTER-MAJOR kernel (DESIGN.md §10): the batch
  plan dedupes the routed clusters (``serving.cluster_major_plan``) and
  each distinct cluster's tiles stream from HBM once per batch, scored
  against that cluster's whole query roster in one MXU matmul; a thin
  scatter + top-k merge (:func:`merge_cluster_major`) folds the ``cr``
  partial lists per query. Wins by the batch dedup factor ``B·cr/U``
  under skewed (or simply cluster-saturating, ``B·cr > c``) routing.
* ``"dense"`` — the pure-jnp reference path (gather + one
  ``jax.lax.top_k``). Always available, and the parity oracle.
* ``"dense-cm"`` — the pure-jnp mirror of the cluster-major plan
  (:func:`dense_cluster_major`): same dedupe/roster/merge, gathering
  each distinct cluster once. The cluster-major parity oracle.
* ``"auto"`` — ``"pallas"`` when a compiled TPU backend is present,
  else ``"dense"`` (interpret-mode Pallas is a correctness tool, not a
  fast path). On top of that, :meth:`QueryEngine.query` upgrades an
  auto-resolved backend to its cluster-major twin per batch when the
  batch dedup factor crosses :data:`CLUSTER_MAJOR_DEDUP_THRESHOLD`
  (structurally, or measured by routing the first chunk — see
  :func:`cluster_major_variant`).

``interpret`` for the Pallas kernels is auto-detected from the
platform (off-TPU ⇒ interpreter) and can be forced with the
``REPRO_PALLAS_COMPILE=1`` env var, matching kernels/ops.py. Backends
are bit-compatible: parity across shapes, padding, ties, and ``cr`` is
enforced by tests/test_query_engine_parity.py.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import filters as filters_lib
from repro.core import index as index_lib
from repro.core import relevance
from repro.core import spatial as sp

NEG_INF = -1e30

BACKENDS = ("pallas", "pallas-cm", "dense", "dense-cm", "auto")

# query-major backends and their cluster-major twins (DESIGN.md §10)
_CM_TWIN = {"pallas": "pallas-cm", "dense": "dense-cm"}

# auto upgrades to cluster-major when the batch streams each distinct
# cluster at least this many times under query-major execution
CLUSTER_MAJOR_DEDUP_THRESHOLD = 2.0

# traced plans an engine keeps before evicting least-recently-used ones
DEFAULT_PLAN_CACHE_SIZE = 32

# shard fault tolerance (DESIGN.md §15): per-shard scan retry/backoff
# and the health state machine driving degraded partial-result serving
SHARD_SCAN_RETRIES = 2             # extra attempts per shard per chunk
SHARD_RETRY_BACKOFF_MS = 1.0       # first retry delay; doubles, capped
SHARD_RETRY_BACKOFF_MAX_MS = 20.0
SHARD_DOWN_AFTER = 3               # consecutive scan failures → DOWN
SHARD_HEDGE_PROBE_EVERY = 8        # hedged scans between device probes

# delta-segment scans pad the row count up to a multiple of this, so a
# growing delta retraces the scan once per bucket, not once per insert
DELTA_PAD_BUCKET = 128

# when a snapshot carries tombstones, the base top-k is over-fetched by
# the tombstone count (rounded up to this bucket — bounded recompiles):
# every tombstone can knock one entry out of the base list, so fetching
# k + n_tombstones guarantees the post-filter top-k is exact
TOMBSTONE_K_BUCKET = 32


# ---------------------------------------------------------------------------
# Backend selection
# ---------------------------------------------------------------------------


def default_interpret() -> bool:
    """Interpret-mode default for the Pallas kernels: compiled on TPU (or
    when forced via REPRO_PALLAS_COMPILE=1), interpreted everywhere else.
    Shared with kernels/ops.py so every entry point agrees."""
    from repro.kernels import ops as kops
    return kops._interpret_default()


def resolve_backend(backend: str = "auto",
                    interpret: Optional[bool] = None) -> Tuple[str, bool]:
    """→ (backend ∈ {"pallas", "dense"}, interpret flag for pallas).

    "auto" keys on the HARDWARE (pallas iff a TPU backend is present),
    not on the interpret flag — REPRO_PALLAS_COMPILE=1 on a CPU host
    must not route auto callers into a Mosaic lowering that cannot
    compile there."""
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    interpret = default_interpret() if interpret is None else interpret
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "dense"
    return backend, interpret


def resolve_cli_backend(backend: Optional[str], use_pallas: bool,
                        *, default: str = "auto") -> str:
    """The CLI flavor of the alias rule, shared by every driver:
    ``--use-pallas`` is deprecated — warn and forward it to
    ``--backend pallas``; an explicit ``--backend`` always wins (with a
    warning that the alias was ignored — the flags never silently
    coexist). Neither flag given → ``default`` ("auto": hardware picks).
    """
    if use_pallas:
        import warnings
        if backend is None:
            warnings.warn("--use-pallas is deprecated; forwarding to "
                          "--backend pallas", DeprecationWarning,
                          stacklevel=2)
            return "pallas"
        if backend != "pallas":
            warnings.warn(f"--use-pallas ignored: explicit --backend "
                          f"{backend} wins", DeprecationWarning,
                          stacklevel=2)
    return backend or default


def cluster_major_variant(backend: str, dedup_factor: float, *,
                          threshold: float = CLUSTER_MAJOR_DEDUP_THRESHOLD
                          ) -> str:
    """The cluster-major auto heuristic (DESIGN.md §10).

    Upgrade a query-major ``backend`` ("pallas" | "dense") to its
    cluster-major twin when the batch dedup factor ``B·cr/U`` (how many
    times query-major execution would re-stream each distinct routed
    cluster) reaches ``threshold``; at lower dedup the roster padding
    overhead isn't paid for. Cluster-major backends and non-upgradable
    names pass through unchanged, so this is safe to apply to any
    resolved backend.
    """
    if dedup_factor >= threshold:
        return _CM_TWIN.get(backend, backend)
    return backend


def cluster_major_feasible(batch: int, cr: int, n_clusters: int,
                           capacity: int) -> bool:
    """Shape guard for the AUTO upgrade: cluster-major pays a static
    roster — a ``(u_max, B·cr, d)`` query-payload gather and a
    ``u_max``-fold matmul over mostly-empty roster rows, with
    ``u_max = min(B·cr, c)``. Requiring ``u_max ≤ cap`` bounds that
    payload by the query-major candidate copy ``(B, cr·cap, d)`` it
    replaces, so auto can never pick a plan whose overhead outgrows the
    stream it saves (large-``c`` small-``cap`` regimes). An explicit
    ``*-cm`` backend bypasses this — callers who know their skew (or
    pass a tight ``qcap`` at the plan level) stay in control.
    """
    return min(batch * cr, n_clusters) <= capacity


# ---------------------------------------------------------------------------
# The one scoring primitive (Eq. 5 serve form)
# ---------------------------------------------------------------------------


def score_candidates(q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids,
                     w_hat, *, dist_max: float, cand_scale=None,
                     cand_attrs=None, fvals=None):
    """Score an explicit candidate set with the paper's serve-form ST.

    ST(q, o) = w_t·(q·o) + w_s·ŵ_s[⌊S_in·t⌋] (Eq. 5): textual relevance
    is the embedding dot product; spatial relevance looks the normalized
    proximity ``S_in = 1 − clip(dist/dist_max, 0, 1)`` up in the learned
    monotone step table ``w_hat (t,)``; ``w_st (..., 2)`` holds the
    per-query (textual, spatial) mixing weights from
    ``relevance.st_weights``.

    Shapes broadcast over leading dims: q_emb (..., d), q_loc (..., 2),
    w_st (..., 2) against cand_emb (..., N, d), cand_loc (..., N, 2),
    cand_ids (..., N). Returns (..., N) f32 with padding (ids < 0) masked
    to NEG_INF (-1e30, finite — NOT -inf: the Pallas kernels use the same
    sentinel, keeping backends bit-identical; filter results by
    ``ids >= 0``, not ``isfinite(score)``). Callers:

    * engine dense backend:  q (B, d)    × cand (B, N, d)
    * serving per-cluster:   q (c, Q, d) × cand (c, 1, cap, d)
    * baselines rerank:      q (d,)      × cand (N, d)

    ``cand_scale (..., N)`` dequantizes int8 candidate embeddings
    (DESIGN.md §9): ``emb = cand_emb.astype(f32) * scale[..., None]`` —
    the same per-row symmetric scales the Pallas kernels apply in VMEM,
    so dense-vs-pallas parity holds within every precision tier. bf16
    candidates need no scale (the astype below is the whole dequant).

    ``cand_attrs (..., N, 3)`` + ``fvals (..., 4)`` apply the filtered-
    search predicate (core/filters.py, DESIGN.md §13): rows that fail
    score NEG_INF, exactly like padding — the same mask the Pallas
    kernels apply in VMEM. Pass both or neither.

    This is the ONE definition of "the score" — if you are scoring
    (query, object) pairs anywhere, call this, don't re-derive it.
    """
    ce = cand_emb.astype(jnp.float32)
    if cand_scale is not None:
        ce = ce * cand_scale[..., None]
    trel = jnp.einsum("...d,...nd->...n", q_emb.astype(jnp.float32), ce)
    d = jnp.linalg.norm(q_loc[..., None, :].astype(jnp.float32)
                        - cand_loc.astype(jnp.float32), axis=-1)
    s_in = 1.0 - jnp.clip(d / dist_max, 0.0, 1.0)
    srel = sp.spatial_relevance_serve(w_hat, s_in)
    st = w_st[..., :1] * trel + w_st[..., 1:2] * srel
    ok = cand_ids >= 0
    if cand_attrs is not None:
        ok = ok & filters_lib.predicate_mask(cand_attrs,
                                             fvals[..., None, :])
    return jnp.where(ok, st, NEG_INF)


def dense_routed_topk(q_emb, q_loc, w_st, top_c, buf_emb, buf_loc, buf_ids,
                      w_hat, *, k: int, dist_max: float, buf_scale=None,
                      buf_attrs=None, q_filt=None):
    """Dense reference for the routed query phase: gather + one top-k.

    Returns (scores (B, k), ids (B, k) global object ids, -1 past-the-end)
    — the exact contract of kernels/fused_topk_score_routed.
    ``buf_scale (c, cap)`` dequantizes int8 buffers with the same per-row
    scales the kernel applies in VMEM (parity within a precision tier).
    ``buf_attrs (c, cap, 3)`` + ``q_filt (B, 4)`` apply the filtered-
    search predicate (DESIGN.md §13) by nulling failing candidates to
    full padding semantics (id -1, score NEG_INF) — the kernel's rule,
    so filtered parity holds per backend.
    """
    b = q_emb.shape[0]
    cand_emb = buf_emb[top_c].reshape(b, -1, buf_emb.shape[-1])
    cand_loc = buf_loc[top_c].reshape(b, -1, 2)
    cand_ids = buf_ids[top_c].reshape(b, -1)
    cand_scale = (None if buf_scale is None
                  else buf_scale[top_c].reshape(b, -1))
    if buf_attrs is not None:
        cand_attrs = buf_attrs[top_c].reshape(b, -1, buf_attrs.shape[-1])
        pred = filters_lib.predicate_mask(cand_attrs, q_filt[:, None, :])
        cand_ids = jnp.where(pred, cand_ids, -1)
    st = score_candidates(q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids,
                          w_hat, dist_max=dist_max, cand_scale=cand_scale)
    scores, pos = jax.lax.top_k(st, k)
    ids = jnp.take_along_axis(cand_ids, pos, axis=1)
    return scores, ids


# ---------------------------------------------------------------------------
# Cluster-major execution (DESIGN.md §10): plan → score once → merge
# ---------------------------------------------------------------------------


def merge_cluster_major(part_scores, part_ids, roster, *, b: int, cr: int,
                        k: int):
    """Fold per-roster-slot partial top-k lists back into per-query ones.

    ``part_scores`` / ``part_ids`` (u_max, Qcap, k) are the cluster-major
    partials (kernel or dense); ``roster`` (u_max, Qcap) maps each slot
    to its flattened (query, route) index in ``[0, B·cr)`` with ``B·cr``
    on empty slots. The inverse scatter drops empty slots into an
    overflow row, reshapes to ``(B, cr·k)``, and one top-k per query
    folds the ``cr`` routes — the same undispatch the distributed path
    uses (core/serving.py step 4). (query, route) pairs dropped at
    ``Qcap`` saturation simply contribute ``(-1, NEG_INF)`` entries:
    graceful degradation, identical to the dispatch path's.

    Returns (scores (B, k) f32 descending, ids (B, k) i32 global object
    ids, -1 past-the-end) — the exact contract of the query-major paths.
    """
    n = b * cr
    flat = roster.reshape(-1)
    back_v = jnp.full((n + 1, k), NEG_INF, jnp.float32)
    back_i = jnp.full((n + 1, k), -1, jnp.int32)
    back_v = back_v.at[flat].set(part_scores.reshape(-1, k))
    back_i = back_i.at[flat].set(part_ids.reshape(-1, k).astype(jnp.int32))
    per_q_v = back_v[:n].reshape(b, cr * k)
    per_q_i = back_i[:n].reshape(b, cr * k)
    scores, pos = jax.lax.top_k(per_q_v, k)
    ids = jnp.take_along_axis(per_q_i, pos, axis=1)
    return scores, ids


def dense_cluster_major(q_emb, q_loc, w_st, top_c, buf_emb, buf_loc, buf_ids,
                        w_hat, *, k: int, dist_max: float, buf_scale=None,
                        buf_attrs=None, q_filt=None,
                        qcap: Optional[int] = None):
    """Dense mirror of the cluster-major plan — the parity oracle.

    Same contract as :func:`dense_routed_topk`, same execution model as
    the ``pallas-cm`` kernel: dedupe the batch's routed clusters
    (``serving.cluster_major_plan``), gather each DISTINCT cluster's
    buffer once (``u_max ≤ min(B·cr, c)`` rows instead of ``B·cr``),
    score it against its whole query roster via the shared
    :func:`score_candidates`, and fold the per-slot partial top-k lists
    with :func:`merge_cluster_major`. Results are bit-compatible with
    the query-major backends modulo tie order within equal scores.
    """
    from repro.core import serving as serving_lib   # lazy: serving imports us

    b = q_emb.shape[0]
    c, cap, _ = buf_emb.shape
    cr = top_c.shape[1]
    n = b * cr
    u, roster, _, _ = serving_lib.cluster_major_plan(top_c, n_clusters=c,
                                                     qcap=qcap)
    qidx = serving_lib.roster_query_rows(roster, cr=cr, n_total=n)
    cand_scale = buf_scale[u][:, None] if buf_scale is not None else None
    cand_ids = buf_ids[u][:, None]                        # (u_max, 1, cap)
    if buf_attrs is not None:
        # filtered rows take full padding semantics (id -1 → NEG_INF),
        # exactly the kernel's rule — see dense_routed_topk
        pred = filters_lib.predicate_mask(
            buf_attrs[u][:, None], q_filt[qidx][:, :, None, :])
        cand_ids = jnp.where(pred, cand_ids, -1)   # (u_max, Qcap, cap)
    st = score_candidates(
        q_emb[qidx], q_loc[qidx], w_st[qidx],
        buf_emb[u][:, None], buf_loc[u][:, None], cand_ids,
        w_hat, dist_max=dist_max, cand_scale=cand_scale)  # (u_max, Qcap, cap)
    st = jnp.where((roster < n)[..., None], st, NEG_INF)  # empty roster slots
    kk = min(k, cap)
    vals, pos = jax.lax.top_k(st, kk)
    ids = jnp.take_along_axis(
        jnp.broadcast_to(cand_ids, st.shape), pos, axis=-1)
    ids = jnp.where((roster < n)[..., None], ids, -1)
    if kk < k:                       # k > cap: pad partials like the kernel
        pad = ((0, 0), (0, 0), (0, k - kk))
        vals = jnp.pad(vals, pad, constant_values=NEG_INF)
        ids = jnp.pad(ids, pad, constant_values=-1)
    return merge_cluster_major(vals, ids, roster, b=b, cr=cr, k=k)


# ---------------------------------------------------------------------------
# The routed query phase: encode → route → score → top-k
# ---------------------------------------------------------------------------


def _routed_topk(q_emb, q_loc, w, top_c, buf_emb, buf_loc, buf_ids,
                 buf_scale, w_hat, *, k: int, backend: str, interpret: bool,
                 dist_max: float, block_n: int, precision: str,
                 buf_attrs=None, q_filt=None):
    """Backend dispatch for the routed scan: score the ``top_c``-routed
    clusters of an explicit buffer set and keep the top ``k`` — the body
    shared by :func:`make_query_fn` (inline, after encode+route) and
    :func:`make_shard_topk_fn` (per shard, routes pre-localized).
    ``backend`` must be resolved (never "auto"). ``buf_attrs``/``q_filt``
    (pass both or neither) engage the filtered variants (DESIGN.md §13).
    Returns (ids, scores).
    """
    # f32/bf16 stream no scales: the astype upcast is the whole dequant
    scale = buf_scale if precision == "int8" else None
    if backend == "pallas":
        from repro.kernels import fused_topk_score as fts
        score, ids = fts.fused_topk_score_routed(
            q_emb, q_loc, w, top_c, buf_emb, buf_loc, buf_ids, w_hat,
            k=k, dist_max=dist_max, block_n=block_n, buf_scale=scale,
            buf_attrs=buf_attrs, q_filt=q_filt, interpret=interpret)
    elif backend == "pallas-cm":
        # cluster-major (DESIGN.md §10): dedupe the routed clusters,
        # stream each distinct one ONCE against its query roster
        from repro.core import serving as serving_lib
        from repro.kernels import fused_topk_score as fts
        b = q_emb.shape[0]
        cr = top_c.shape[1]
        n = b * cr
        u, roster, _, _ = serving_lib.cluster_major_plan(
            top_c, n_clusters=buf_emb.shape[0])
        qidx = serving_lib.roster_query_rows(roster, cr=cr, n_total=n)
        q_filt_r = q_filt[qidx] if q_filt is not None else None
        ps, pi = fts.fused_topk_score_cluster_major(
            q_emb[qidx], q_loc[qidx], w[qidx], u, roster,
            buf_emb, buf_loc, buf_ids, w_hat, k=k, dist_max=dist_max,
            n_total=n, block_n=block_n, buf_scale=scale,
            buf_attrs=buf_attrs, q_filt_r=q_filt_r, interpret=interpret)
        score, ids = merge_cluster_major(ps, pi, roster, b=b, cr=cr, k=k)
    elif backend == "dense-cm":
        score, ids = dense_cluster_major(
            q_emb, q_loc, w, top_c, buf_emb, buf_loc, buf_ids, w_hat,
            k=k, dist_max=dist_max, buf_scale=scale,
            buf_attrs=buf_attrs, q_filt=q_filt)
    else:
        score, ids = dense_routed_topk(
            q_emb, q_loc, w, top_c, buf_emb, buf_loc, buf_ids, w_hat,
            k=k, dist_max=dist_max, buf_scale=scale,
            buf_attrs=buf_attrs, q_filt=q_filt)
    return ids, score


def make_query_fn(cfg, *, cr: int = 1, k: int = 20, backend: str = "auto",
                  interpret: Optional[bool] = None,
                  dist_max: float = 1.4142, weight_mode: str = "mlp",
                  block_n: int = 512, precision: str = "f32",
                  filtered: bool = False):
    """Build the jitted query-phase function (paper Algorithm 1).

    The returned function runs the whole serve path in one XLA program:
    encode queries (dual-encoder), build index features (Eq. 9–10),
    route to the top-``cr`` clusters (Eq. 11), score those clusters'
    resident objects, and keep the top ``k``.

    signature: fn(rel_params, index_params, w_hat, norm, buf_emb,
                  buf_loc, buf_ids, buf_scale, q_tokens, q_mask, q_loc)
               -> (ids (B, k) global object ids, scores (B, k))

    where ``rel_params`` / ``index_params`` are the trained relevance
    and cluster-classifier params, ``w_hat (t,)`` is the serve-form
    spatial step table (``spatial.extract_lookup``), ``norm`` the
    location normalizer bounds (``index.loc_normalizer``), and
    ``buf_*`` the padded cluster buffers (module docstring) —
    ``buf_scale (c, cap)`` the per-row dequant scales of quantized
    buffers (``index.quantize_rows``; all-ones, and unused, below
    int8). Rows past the valid candidates come back as
    ``(-1, NEG_INF)`` pairs.

    Keyword args: ``cr`` routed clusters per query; ``k`` results per
    query; ``backend``/``interpret`` per the module docstring
    (``"pallas"`` runs gather-free — scalar-prefetched routing into the
    resident buffers, in-kernel cr-merge; ``"pallas-cm"`` /
    ``"dense-cm"`` run the cluster-major plan — each distinct routed
    cluster streamed once per batch, DESIGN.md §10; ``"dense"`` is the
    jnp reference; ``"auto"`` picks query-major per platform — the
    per-batch cluster-major upgrade lives in
    :meth:`QueryEngine.query`); ``dist_max`` the
    distance normalizer of Eq. 5 (√2 for the unit box);
    ``weight_mode`` how the (textual, spatial) mixing weights are
    produced; ``block_n`` the Pallas streaming tile size; ``precision``
    the buffers' storage tier (DESIGN.md §9) — routing, SRel, and the
    padding mask are identical across tiers, only TRel dequantizes
    (in-kernel on pallas, via the same per-row scales on dense, so
    backend parity holds *within* every tier).

    ``filtered=True`` is the STATIC filtered-search plan dimension
    (DESIGN.md §13): the signature grows ``buf_attrs (c, cap, 3)`` after
    ``buf_scale`` and ``q_filt (B, 4)`` after ``q_loc``, and the
    predicate mask is applied in-scan. ``filtered=False`` builds the
    exact pre-filter program — zero extra bytes streamed.

    The result is a ``jax.jit`` function: every distinct batch shape
    triggers one compile, so serve fixed shapes via :func:`run_batched`
    (or hold a :class:`QueryEngine`, which does both for you).
    """
    backend, interpret = resolve_backend(backend, interpret)
    if precision not in index_lib.PRECISIONS:
        raise ValueError(f"precision must be one of {index_lib.PRECISIONS}, "
                         f"got {precision!r}")

    def _run(rel_params, index_params, w_hat, norm, buf_emb, buf_loc,
             buf_ids, buf_scale, q_tokens, q_mask, q_loc, buf_attrs, q_filt):
        q_emb = relevance.encode_queries(rel_params, q_tokens, q_mask, cfg)
        feats = index_lib.build_features(q_emb, q_loc, norm)
        top_c, _ = index_lib.route_queries(index_params, feats, cr=cr)
        w = relevance.st_weights(rel_params, q_emb,
                                 weight_mode=weight_mode)          # (B, 2)
        return _routed_topk(q_emb, q_loc, w, top_c, buf_emb, buf_loc,
                            buf_ids, buf_scale, w_hat, k=k, backend=backend,
                            interpret=interpret, dist_max=dist_max,
                            block_n=block_n, precision=precision,
                            buf_attrs=buf_attrs, q_filt=q_filt)

    if filtered:
        def query_fn(rel_params, index_params, w_hat, norm, buf_emb,
                     buf_loc, buf_ids, buf_scale, buf_attrs, q_tokens,
                     q_mask, q_loc, q_filt):
            return _run(rel_params, index_params, w_hat, norm, buf_emb,
                        buf_loc, buf_ids, buf_scale, q_tokens, q_mask,
                        q_loc, buf_attrs, q_filt)
    else:
        def query_fn(rel_params, index_params, w_hat, norm, buf_emb,
                     buf_loc, buf_ids, buf_scale, q_tokens, q_mask, q_loc):
            return _run(rel_params, index_params, w_hat, norm, buf_emb,
                        buf_loc, buf_ids, buf_scale, q_tokens, q_mask,
                        q_loc, None, None)

    return jax.jit(query_fn)


def make_route_fn(cfg, *, cr: int = 1):
    """Build the jitted route-only prefix of the query phase: encode →
    features → top-``cr`` clusters. ``fn(rel_params, index_params, norm,
    q_tokens, q_mask, q_loc) -> top_c (B, cr) int32``.

    The auto heuristic (:func:`cluster_major_variant`) and the skew
    benchmarks use it to measure a batch's dedup factor ``B·cr/U``
    without running the scan."""
    def route_fn(rel_params, index_params, norm, q_tokens, q_mask, q_loc):
        q_emb = relevance.encode_queries(rel_params, q_tokens, q_mask, cfg)
        feats = index_lib.build_features(q_emb, q_loc, norm)
        top_c, _ = index_lib.route_queries(index_params, feats, cr=cr)
        return top_c

    return jax.jit(route_fn)


# ---------------------------------------------------------------------------
# Mesh-sharded execution (DESIGN.md §12): shared prefix → per-shard
# scan → host tree merge. The shard_topk idiom of
# pseudo_labels.mine_negatives_sharded, promoted to the serving path.
# ---------------------------------------------------------------------------


def make_prefix_fn(cfg, *, cr: int = 1, weight_mode: str = "mlp"):
    """Build the jitted GLOBAL prefix of the sharded query phase:
    encode → mixing weights → route, run ONCE per chunk on the default
    device (router + relevance params are replicated). ``fn(rel_params,
    index_params, norm, q_tokens, q_mask, q_loc) -> (q_emb (B, d),
    w (B, 2), top_c (B, cr))``.

    One program for EVERY shard count (its shapes don't depend on the
    mesh), so ``q_emb``/``w``/``top_c`` are bit-identical across
    placements — the first leg of the parity contract."""
    def prefix_fn(rel_params, index_params, norm, q_tokens, q_mask, q_loc):
        q_emb = relevance.encode_queries(rel_params, q_tokens, q_mask, cfg)
        feats = index_lib.build_features(q_emb, q_loc, norm)
        top_c, _ = index_lib.route_queries(index_params, feats, cr=cr)
        w = relevance.st_weights(rel_params, q_emb, weight_mode=weight_mode)
        return q_emb, w, top_c

    return jax.jit(prefix_fn)


def make_shard_topk_fn(*, k: int = 20, backend: str = "dense",
                       interpret: Optional[bool] = None,
                       dist_max: float = 1.4142, block_n: int = 512,
                       precision: str = "f32", filtered: bool = False):
    """Build the jitted PER-SHARD suffix of the sharded query phase:
    score one shard's local cluster buffers against pre-encoded queries
    and pre-localized routes, any backend (DESIGN.md §12).

    signature: fn(w_hat, buf_emb, buf_loc, buf_ids, buf_scale,
                  q_emb, q_loc, w, top_c) -> (ids (B, k), scores (B, k))

    ``buf_*`` are one shard's local buffers (``c_local + 1`` clusters,
    the last the sentinel empty cluster) and ``top_c`` holds LOCAL rows
    (``serving.localize_routes`` — off-shard routes point at the
    sentinel, scoring ``(−1, NEG_INF)`` like padding). Execution is
    pinned by data placement: the buffers are device-committed
    (``sharding.ClusterShards.parts``), so jax runs each shard's call
    on its shard's device — pass the query-side arrays as host numpy
    (uncommitted) or the mixed-commitment check will refuse the call.

    Per-candidate scores are bitwise identical to the single-device
    scan: the same ``cr·cap`` candidate rows (off-shard ones masked),
    the same per-row reductions, so per-shard top-k + the host tree
    merge (:func:`merge_shard_topk`) reproduce the single-device top-k
    exactly whenever scores at the k boundary are distinct.

    ``filtered=True`` grows the signature with ``buf_attrs`` after
    ``buf_scale`` and ``q_filt (B, 4)`` last, mirroring
    :func:`make_query_fn` — the predicate is shard-local like every
    other per-candidate term, so the tree merge composes unchanged."""
    backend, interpret = resolve_backend(backend, interpret)
    if precision not in index_lib.PRECISIONS:
        raise ValueError(f"precision must be one of {index_lib.PRECISIONS}, "
                         f"got {precision!r}")

    if filtered:
        def shard_fn(w_hat, buf_emb, buf_loc, buf_ids, buf_scale, buf_attrs,
                     q_emb, q_loc, w, top_c, q_filt):
            return _routed_topk(q_emb, q_loc, w, top_c, buf_emb, buf_loc,
                                buf_ids, buf_scale, w_hat, k=k,
                                backend=backend, interpret=interpret,
                                dist_max=dist_max, block_n=block_n,
                                precision=precision, buf_attrs=buf_attrs,
                                q_filt=q_filt)
    else:
        def shard_fn(w_hat, buf_emb, buf_loc, buf_ids, buf_scale,
                     q_emb, q_loc, w, top_c):
            return _routed_topk(q_emb, q_loc, w, top_c, buf_emb, buf_loc,
                                buf_ids, buf_scale, w_hat, k=k,
                                backend=backend, interpret=interpret,
                                dist_max=dist_max, block_n=block_n,
                                precision=precision)

    return jax.jit(shard_fn)


def merge_shard_topk(parts, *, k: Optional[int] = None):
    """Pairwise tree-reduce per-shard partial top-k lists (host, numpy)
    — ``pseudo_labels.shard_topk``'s merge, promoted to serving.

    ``parts`` is a sequence of per-shard ``(ids (B, m), scores (B, m))``
    pairs in shard order. Pairs are merged pairwise (top-k of top-ks —
    each level keeps the best ``k``) until one list remains; ``k``
    defaults to the partial width. The per-level sort is STABLE with
    the lower-index operand's entries first, so an exact cross-shard
    score tie resolves in shard order — the one documented divergence
    from single-device tie order (DESIGN.md §12); within a shard ties
    already match (same ``jax.lax.top_k``). Returns ``(ids (B, k) i32,
    scores (B, k) f32 descending)`` — the engine's output contract.
    """
    items = [(np.asarray(i), np.asarray(v, np.float32)) for i, v in parts]
    if not items:
        raise ValueError("merge_shard_topk: no partial lists")
    if k is None:
        k = items[0][0].shape[-1]

    def merge2(a, b):
        ci = np.concatenate([a[0], b[0]], axis=-1)
        cv = np.concatenate([a[1], b[1]], axis=-1)
        order = np.argsort(-cv, axis=-1, kind="stable")[..., :k]
        return (np.take_along_axis(ci, order, axis=-1),
                np.take_along_axis(cv, order, axis=-1))

    while len(items) > 1:
        nxt = [merge2(items[i], items[i + 1])
               for i in range(0, len(items) - 1, 2)]
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    ids, scores = items[0]
    return (ids[..., :k].astype(np.int32),
            scores[..., :k].astype(np.float32))


# ---------------------------------------------------------------------------
# Delta-segment scan + merge (the LSM mutation path, DESIGN.md §11)
# ---------------------------------------------------------------------------


def make_delta_scan_fn(cfg, *, k: int = 20, dist_max: float = 1.4142,
                       weight_mode: str = "mlp", precision: str = "f32",
                       filtered: bool = False):
    """Build the jitted brute-force scan over a delta segment's rows.

    The delta is small by construction (the server compacts it past a
    threshold), so it is scored WITHOUT routing: every query sees every
    delta row — a freshly inserted object can never be hidden by a
    routing miss before compaction folds it into its cluster.

    signature: fn(rel_params, w_hat, d_emb (m, d), d_scale (m,),
                  d_loc (m, 2), d_ids (m,), q_tokens, q_mask, q_loc)
               -> (ids (B, k), scores (B, k))

    with the usual ``(-1, NEG_INF)`` padding convention; padding rows in
    the delta arrays (``ids == -1``) mask exactly like buffer padding.
    Scoring goes through :func:`score_candidates` with the same
    precision semantics as the base backends, so a row scores
    bit-identically whether it is delta-resident or compacted (same
    stored quantized values, same dequant, same ST form).

    ``filtered=True`` grows the signature with ``d_attrs (m, 3)`` after
    ``d_ids`` and ``q_filt (B, 4)`` last: delta rows obey the same
    predicate as compacted ones (a fresh insert must never leak across
    a tenant filter while it waits for compaction).
    """
    if precision not in index_lib.PRECISIONS:
        raise ValueError(f"precision must be one of {index_lib.PRECISIONS}, "
                         f"got {precision!r}")

    def _scan(rel_params, w_hat, d_emb, d_scale, d_loc, d_ids, d_attrs,
              q_tokens, q_mask, q_loc, q_filt):
        q_emb = relevance.encode_queries(rel_params, q_tokens, q_mask, cfg)
        w = relevance.st_weights(rel_params, q_emb, weight_mode=weight_mode)
        scale = d_scale[None] if precision == "int8" else None
        ids_eff = d_ids[None]                               # (1, m)
        if d_attrs is not None:
            # failing rows take full padding semantics (id -1), the
            # shared filtered rule of every scan in this module
            pred = filters_lib.predicate_mask(d_attrs[None],
                                              q_filt[:, None, :])
            ids_eff = jnp.where(pred, ids_eff, -1)          # (B, m)
        st = score_candidates(q_emb, q_loc, w, d_emb[None], d_loc[None],
                              ids_eff, w_hat, dist_max=dist_max,
                              cand_scale=scale)             # (B, m)
        kk = min(k, d_emb.shape[0])
        vals, pos = jax.lax.top_k(st, kk)
        ids = jnp.take_along_axis(
            jnp.broadcast_to(ids_eff, st.shape), pos, axis=1
        ).astype(jnp.int32)
        if kk < k:
            pad = ((0, 0), (0, k - kk))
            vals = jnp.pad(vals, pad, constant_values=NEG_INF)
            ids = jnp.pad(ids, pad, constant_values=-1)
        return ids, vals

    if filtered:
        def scan_fn(rel_params, w_hat, d_emb, d_scale, d_loc, d_ids,
                    d_attrs, q_tokens, q_mask, q_loc, q_filt):
            return _scan(rel_params, w_hat, d_emb, d_scale, d_loc, d_ids,
                         d_attrs, q_tokens, q_mask, q_loc, q_filt)
    else:
        def scan_fn(rel_params, w_hat, d_emb, d_scale, d_loc, d_ids,
                    q_tokens, q_mask, q_loc):
            return _scan(rel_params, w_hat, d_emb, d_scale, d_loc, d_ids,
                         None, q_tokens, q_mask, q_loc, None)

    return jax.jit(scan_fn)


def merge_delta(base_ids, base_scores, delta_ids=None, delta_scores=None, *,
                tombstones=None, k=None):
    """Merge a delta scan's partial top-k into the base engine's (host).

    ``tombstones`` (sorted id array) is applied to the BASE lists only —
    tombstoned entries become ``(-1, NEG_INF)`` pairs and sink out of
    the top-k. Delta rows are live by construction (``DeltaSegment.delete``
    drops them physically), so the delta lists are merged unfiltered.

    Ids may appear in both lists only if the same id was inserted twice
    without an intervening delete — a contract violation upstream
    (``DeltaSegment.insert`` raises on delta-resident duplicates).

    The sort is stable with base entries first: on an exact score tie
    the base row wins, matching the "earlier candidate wins" tie rule of
    ``jax.lax.top_k`` inside the backends. ``k`` defaults to the base
    list width; pass it explicitly when the base lists were over-fetched
    to absorb tombstone kills (:data:`TOMBSTONE_K_BUCKET`). Returns
    ``(ids (B, k) i32, scores (B, k) f32 descending)`` — the engine's
    output contract.
    """
    base_ids = np.asarray(base_ids)
    base_scores = np.asarray(base_scores, np.float32)
    if k is None:
        k = base_ids.shape[-1]
    if tombstones is not None and len(tombstones):
        dead = np.isin(base_ids, np.asarray(tombstones))
        base_ids = np.where(dead, -1, base_ids)
        base_scores = np.where(dead, NEG_INF, base_scores)
    if delta_ids is None:
        cat_i = base_ids
        cat_v = base_scores
    else:
        cat_i = np.concatenate([base_ids, np.asarray(delta_ids)], axis=-1)
        cat_v = np.concatenate(
            [base_scores, np.asarray(delta_scores, np.float32)], axis=-1)
    order = np.argsort(-cat_v, axis=-1, kind="stable")[..., :k]
    ids = np.take_along_axis(cat_i, order, axis=-1).astype(np.int32)
    scores = np.take_along_axis(cat_v, order, axis=-1).astype(np.float32)
    return ids, scores


# ---------------------------------------------------------------------------
# Static-shape batch padding (one compile per batch shape)
# ---------------------------------------------------------------------------


def pad_leading(arr, batch: int):
    """Zero-pad axis 0 of ``arr`` up to ``batch`` rows (numpy, no-op jit)."""
    n = arr.shape[0]
    if n == batch:
        return arr
    assert n < batch, (n, batch)
    return np.pad(arr, ((0, batch - n),) + ((0, 0),) * (arr.ndim - 1))


def run_batched(fn: Callable, arrays: Sequence[np.ndarray], *, batch: int):
    """Map a jitted ``fn`` over ``arrays`` in static-shape chunks.

    ``arrays`` is a sequence of equal-leading-dim inputs (e.g. tokens,
    mask, locations, each with ``n`` rows). They are walked in lockstep
    ``batch`` rows at a time, and every chunk fed to ``fn`` has exactly
    ``batch`` rows: the trailing partial chunk is zero-padded up to
    ``batch`` (:func:`pad_leading`) and the corresponding output rows
    trimmed. ``fn`` therefore sees ONE batch shape and jit-compiles
    exactly once, no matter what ``n`` is.

    ``fn(*chunks) -> array | tuple of arrays`` (leading dim ``batch``);
    returns the per-chunk outputs concatenated back to leading dim
    ``n`` as ``np.ndarray`` — a single array if ``fn`` returned one,
    else a tuple.

    Padding rows are all-zeros; make sure ``fn`` is row-independent
    (every query-phase function here is), so pad rows can't perturb
    real rows. This is the padding rule the whole repo shares: the
    retriever, the brute-force oracle, corpus embedding, and the
    streaming server's micro-batch flushes (core/server.py) — which is
    why a micro-batched result is bit-identical to an offline one at a
    fixed backend. (An AUTO engine picks query- vs cluster-major per
    ``QueryEngine.query`` call, so differently-composed batches may
    take different — bit-compatible modulo tie order — flavors;
    DESIGN.md §10.)

    Execution is pipelined: chunk ``i``'s outputs are materialized on
    the host (``np.asarray`` — a device sync) only *after* chunk
    ``i+1``'s work has been dispatched, so on an async backend the
    device-to-host transfer of one chunk overlaps the next chunk's
    compute instead of serializing the serving path.
    """
    n = arrays[0].shape[0]
    assert all(a.shape[0] == n for a in arrays), [a.shape for a in arrays]
    outs = None
    pending = None            # chunk i's device results, not yet synced
    for s in range(0, n, batch):
        e = min(s + batch, n)
        chunk = [pad_leading(np.asarray(a[s:e]), batch) for a in arrays]
        res = fn(*[jnp.asarray(c) for c in chunk])      # dispatch, no sync
        res = res if isinstance(res, (tuple, list)) else (res,)
        if outs is None:
            outs = [[] for _ in res]
        if pending is not None:
            p_res, p_rows = pending
            for o, r in zip(outs, p_res):
                o.append(np.asarray(r)[:p_rows])        # sync chunk i-1
        pending = (res, e - s)
    if pending is not None:
        p_res, p_rows = pending
        for o, r in zip(outs, p_res):
            o.append(np.asarray(r)[:p_rows])
    cat = tuple(np.concatenate(o, axis=0) for o in outs)
    return cat if len(cat) > 1 else cat[0]


# ---------------------------------------------------------------------------
# Stateful façade
# ---------------------------------------------------------------------------


class QueryEngine:
    """Stateless query executor over an immutable :class:`IndexSnapshot`.

    The engine owns exactly two things: a *reference* to the current
    snapshot (core/snapshot.py — all params/buffers live there, frozen)
    and a cache of traced plans keyed ``(batch, k, cr, backend, precision)``. Both
    the single-host path (``ListRetriever.query``) and the streaming
    server (core/server.py, DESIGN.md §7–§8) hold one; the distributed
    dispatch path shares :func:`score_candidates` instead (its data
    movement is the point).

    Snapshot swaps are atomic: :meth:`publish` replaces the reference in
    one assignment (it validates ``meta.cfg_digest`` — params from a
    different model config never sneak in). Every :meth:`query` call
    reads the snapshot reference ONCE up front, so a concurrent publish
    can never tear a batch across two snapshots. Plans survive swaps
    that preserve buffer shapes — snapshot contents are jit *arguments*,
    so same shapes ⇒ no retrace, and a shape-changing swap just
    retraces lazily.
    """

    def __init__(self, snapshot, *, backend: str = "auto",
                 interpret: Optional[bool] = None,
                 max_plans: int = DEFAULT_PLAN_CACHE_SIZE,
                 cm_threshold: float = CLUSTER_MAJOR_DEDUP_THRESHOLD):
        self._snapshot = snapshot
        self.backend, self.interpret = resolve_backend(backend, interpret)
        # "auto" keeps its per-batch cluster-major upgrade (DESIGN.md
        # §10); an explicit backend is always served verbatim
        self._auto_cm = backend == "auto"
        self.cm_threshold = float(cm_threshold)
        self.last_dedup_factor: Optional[float] = None
        self.max_plans = int(max_plans)
        self._plans: "collections.OrderedDict" = collections.OrderedDict()
        self._route_plans = {}          # keyed cr: tiny, never evicted
        self._delta_plans = {}          # keyed (k, precision): tiny too
        self._prefix_plans = {}         # keyed cr: the sharded-path prefix
        # shard fault tolerance (DESIGN.md §15): health + hedging state
        # for the mesh-sharded scan, plus the last query's coverage
        self.last_coverage: float = 1.0
        self.last_down_shards: Tuple[int, ...] = ()
        self.shard_stats = {"hedged_scans": 0, "scan_retries": 0,
                            "down_skips": 0, "host_scans": 0,
                            "recoveries": 0}
        self.shard_retries = SHARD_SCAN_RETRIES
        self.shard_backoff_ms = SHARD_RETRY_BACKOFF_MS
        self.shard_backoff_max_ms = SHARD_RETRY_BACKOFF_MAX_MS
        self.shard_down_after = SHARD_DOWN_AFTER
        self.hedge_probe_every = SHARD_HEDGE_PROBE_EVERY
        self._shard_health = None       # lazy: sized on first sharded query
        self._shard_monitor = None      # StragglerMonitor over device scans
        self._hedged = {}               # shard → hedged-scan count
        self._host_parts = {}           # host replicas, keyed by placement

    # --- construction -----------------------------------------------------

    @classmethod
    def from_snapshot(cls, snapshot, *, backend: str = "auto",
                      interpret: Optional[bool] = None) -> "QueryEngine":
        return cls(snapshot, backend=backend, interpret=interpret)

    @classmethod
    def from_parts(cls, cfg, rel_params, index_params, norm, buffers, *,
                   dist_max: float, spatial_mode: str = "step",
                   weight_mode: str = "mlp", backend: str = "auto",
                   interpret: Optional[bool] = None) -> "QueryEngine":
        """Convenience: wrap loose artifacts into a version-0 snapshot.
        Serving code should hold real snapshots (repro.api.build/load)."""
        from repro.core import snapshot as snapshot_lib
        snap = snapshot_lib.IndexSnapshot.from_parts(
            cfg, rel_params, index_params, norm, buffers,
            dist_max=dist_max, spatial_mode=spatial_mode,
            weight_mode=weight_mode)
        return cls(snap, backend=backend, interpret=interpret)

    # --- the snapshot reference (the ONLY mutable state) ------------------

    @property
    def snapshot(self):
        return self._snapshot

    def publish(self, snapshot):
        """Atomically swap the served snapshot; returns the old one.

        Refuses a snapshot whose ``meta.cfg_digest`` differs from the
        current one — traced plans close over the model config, so a
        config change requires a NEW engine, not a swap. Single
        reference assignment ⇒ a concurrent :meth:`query` sees either
        the old snapshot or the new one, never a mix.
        """
        old = self._snapshot
        if snapshot.meta.cfg_digest != old.meta.cfg_digest:
            raise ValueError(
                f"publish: snapshot cfg_digest {snapshot.meta.cfg_digest} "
                f"!= engine's {old.meta.cfg_digest}; build a new engine "
                f"for a different model config")
        self._snapshot = snapshot
        return old

    # --- read-only views (back-compat with pre-snapshot callers) ----------

    @property
    def cfg(self):
        return self._snapshot.cfg

    @property
    def rel_params(self):
        return self._snapshot.rel_params

    @property
    def index_params(self):
        return self._snapshot.index_params

    @property
    def norm(self):
        return self._snapshot.norm

    @property
    def buffers(self):
        return self._snapshot.buffers

    @property
    def dist_max(self) -> float:
        return self._snapshot.meta.dist_max

    @property
    def spatial_mode(self) -> str:
        return self._snapshot.meta.spatial_mode

    @property
    def weight_mode(self) -> str:
        return self._snapshot.meta.weight_mode

    @property
    def w_hat(self):
        """Serve-form step table (Eq. 5) of the CURRENT snapshot."""
        return self._snapshot.w_hat

    # --- plans + execution ------------------------------------------------

    def query_fn(self, *, k: int, cr: int, backend: Optional[str] = None,
                 batch: Optional[int] = None,
                 precision: Optional[str] = None, filtered: bool = False):
        """The traced plan for ``(batch, k, cr, backend, precision,
        filtered)``. Plans are keyed on the batch shape too so a serving
        process can see its full plan inventory in ``_plans``; they never
        rebind snapshot state (everything is passed as jit arguments), so
        they survive every publish. ``precision`` defaults to the CURRENT
        snapshot's tier — a publish that changes precision simply traces
        (and caches) new plans under the new key. ``filtered`` is the
        static filtered-search dimension (DESIGN.md §13): filtered and
        unfiltered traffic never share a program."""
        backend = self.backend if backend is None else backend
        if precision is None:
            precision = self._snapshot.meta.precision
        key = (batch, k, cr, backend, precision, filtered)
        if key not in self._plans:
            # bounded LRU: hot-swaps, precision changes, and backend
            # upgrades retrace freely without growing the cache forever
            while len(self._plans) >= self.max_plans:
                self._plans.popitem(last=False)
            self._plans[key] = make_query_fn(
                self.cfg, cr=cr, k=k, backend=backend,
                interpret=self.interpret, dist_max=self.dist_max,
                weight_mode=self.weight_mode, precision=precision,
                filtered=filtered)
        self._plans.move_to_end(key)
        return self._plans[key]

    def route(self, q_tokens, q_mask, q_loc, *, cr: int = 1,
              snapshot=None):
        """Route-only prefix: → top_c (n, cr) int32 (device array).

        One cached jitted plan per ``cr`` (:func:`make_route_fn`); the
        auto heuristic and the skew benchmarks measure dedup with it."""
        snap = self._snapshot if snapshot is None else snapshot
        if cr not in self._route_plans:
            self._route_plans[cr] = make_route_fn(self.cfg, cr=cr)
        return self._route_plans[cr](
            snap.rel_params, snap.index_params, snap.norm,
            jnp.asarray(q_tokens), jnp.asarray(q_mask), jnp.asarray(q_loc))

    def pick_backend(self, q_tokens, q_mask, q_loc, *, cr: int, batch: int,
                     snapshot=None, base: Optional[str] = None) -> str:
        """Resolve the per-batch backend for an auto request (DESIGN.md
        §10): upgrade the hardware-resolved query-major ``base`` backend
        (default: this engine's own) to its cluster-major twin when the
        batch dedup factor ``B·cr/U`` crosses ``cm_threshold``.

        The structural bound ``batch·cr / min(batch·cr, c)`` is checked
        first — when the batch saturates the cluster set (``B·cr ≥
        threshold·c``, the common serving regime) no measurement is
        needed and the pick is data-independent. Otherwise the FIRST
        chunk is routed (:meth:`route` — the cheap encoder+MLP prefix)
        and the measured distinct-cluster count decides. The last
        factor used is kept in ``last_dedup_factor`` for observability.
        """
        snap = self._snapshot if snapshot is None else snapshot
        base = self.backend if base is None else base
        c, cap = snap.buffers["emb"].shape[:2]
        # shape guard first: refuse plans whose roster overhead outgrows
        # the stream they save (the plan is traced at the PADDED batch)
        if not cluster_major_feasible(batch, cr, c, cap):
            self.last_dedup_factor = None
            return base
        eff = min(batch, q_tokens.shape[0])
        dedup = (eff * cr) / min(eff * cr, c)     # structural lower bound
        if dedup < self.cm_threshold:
            # measure on the first chunk, PADDED to the static plan
            # shape: route_fn then compiles once per (batch, cr) — a
            # serving flush of any fill level reuses it instead of
            # retracing the encoder inside the latency-critical flush
            tok = pad_leading(np.asarray(q_tokens[:eff]), batch)
            msk = pad_leading(np.asarray(q_mask[:eff]), batch)
            loc = pad_leading(np.asarray(q_loc[:eff]), batch)
            top_c = np.asarray(self.route(tok, msk, loc, cr=cr,
                                          snapshot=snap))[:eff]
            dedup = (eff * cr) / max(len(np.unique(top_c)), 1)
        self.last_dedup_factor = float(dedup)
        return cluster_major_variant(base, dedup,
                                     threshold=self.cm_threshold)

    def prefix_fn(self, *, cr: int):
        """The jitted sharded-path prefix (:func:`make_prefix_fn`) for
        ``cr`` — one per engine regardless of shard count, so encode/
        route results are bit-identical across placements."""
        if cr not in self._prefix_plans:
            self._prefix_plans[cr] = make_prefix_fn(
                self.cfg, cr=cr, weight_mode=self.weight_mode)
        return self._prefix_plans[cr]

    def shard_topk_fn(self, *, k: int, backend: Optional[str] = None,
                      batch: Optional[int] = None,
                      precision: Optional[str] = None,
                      filtered: bool = False):
        """The traced per-shard plan (:func:`make_shard_topk_fn`),
        cached in the same bounded LRU as the query plans under the key
        ``("shard", batch, k, backend, precision, filtered)``. ONE
        program serves every shard — the local buffer shapes agree
        across shards by construction (sentinel + remainder padding),
        and jax compiles one executable per committed device."""
        backend = self.backend if backend is None else backend
        if precision is None:
            precision = self._snapshot.meta.precision
        key = ("shard", batch, k, backend, precision, filtered)
        if key not in self._plans:
            while len(self._plans) >= self.max_plans:
                self._plans.popitem(last=False)
            self._plans[key] = make_shard_topk_fn(
                k=k, backend=backend, interpret=self.interpret,
                dist_max=self.dist_max, precision=precision,
                filtered=filtered)
        self._plans.move_to_end(key)
        return self._plans[key]

    def _shard_state(self, n_shards: int):
        """Lazy per-mesh health state: a :class:`ShardHealth` +
        :class:`StragglerMonitor` pair sized to the current shard count
        (re-created when a publish changes the mesh width)."""
        from repro.distributed import resilience as resilience_lib

        if (self._shard_health is None
                or self._shard_health.n_shards != n_shards):
            self._shard_health = resilience_lib.ShardHealth(
                n_shards, down_after=self.shard_down_after)
            self._shard_monitor = resilience_lib.StragglerMonitor()
            self._hedged = {}
        return self._shard_health

    def _host_shard_part(self, snap, shards, s: int):
        """Host-side replica of shard ``s``'s local buffers, rebuilt
        from the snapshot's retained GLOBAL arrays (``with_mesh`` keeps
        them host-side for save — DESIGN.md §12) with the exact
        layout/fill convention of ``sharding.shard_cluster_buffers``:
        rows ``[0, len(group))`` hold the shard's clusters in ascending
        global order, everything above (including the sentinel row) is
        empty padding. The SAME jitted shard plan runs on it with
        all-host operands (default device), so a hedged or recovered
        scan is bit-identical to the device scan. Cached per placement
        object (a publish or recovery invalidates by identity)."""
        cache = self._host_parts
        if cache.get("key") != id(shards):
            self._host_parts = cache = {"key": id(shards)}
        part = cache.get(s)
        if part is None:
            g = np.flatnonzero(np.asarray(shards.shard_of) == s)
            rows = shards.c_local + 1        # + sentinel empty cluster
            fills = {"emb": 0, "loc": index_lib.PAD_LOC, "ids": -1,
                     "scale": 1, "attrs": 0, "counts": 0}
            part = {}
            for key, fill in fills.items():
                if key not in snap.buffers:
                    continue
                arr = np.asarray(snap.buffers[key])
                if key == "counts":
                    arr = arr.astype(np.int32)
                out = np.full((rows,) + arr.shape[1:], fill,
                              dtype=arr.dtype)
                out[:len(g)] = arr[g]
                part[key] = out
            cache[s] = part
        return part

    def down_signature(self) -> Tuple[int, ...]:
        """The currently-DOWN shard set — the cache-key component that
        keeps degraded results from ever serving as full-coverage ones
        (DESIGN.md §15)."""
        health = self._shard_health
        return () if health is None else health.down_shards()

    def recover_shard(self, s: int):
        """Online shard recovery (DESIGN.md §15): re-materialize shard
        ``s``'s device part from the snapshot's global host buffers
        (same placement/fill convention as ``shard_cluster_buffers``),
        atomically publish the patched placement, and flip the shard
        back UP. Placement-only — no version bump, no content change,
        and no ``SubscriptionRegistry`` dispatch (notifications flow
        only from insert publishes, so exactly-once delivery is
        untouched). Returns the snapshot now being served."""
        snap = self._snapshot
        shards = getattr(snap, "shards", None)
        if shards is None:
            raise ValueError("recover_shard: snapshot is not mesh-sharded")
        if not 0 <= s < shards.n_shards:
            raise ValueError(f"recover_shard: shard {s} out of range "
                             f"0..{shards.n_shards - 1}")
        host = self._host_shard_part(snap, shards, s)
        device = shards.devices[s]
        new_part = {key: jax.device_put(arr, device)
                    for key, arr in host.items()}
        parts = list(shards.parts)
        parts[s] = new_part
        new_shards = dataclasses.replace(shards, parts=tuple(parts))
        # single reference assignment, like publish(): a concurrent
        # query sees the old placement or the new one, never a mix
        self._snapshot = dataclasses.replace(snap, shards=new_shards)
        self._host_parts = {}           # placement identity changed
        if self._shard_health is not None:
            self._shard_health.mark_up(s)
        self._hedged.pop(s, None)
        self.shard_stats["recoveries"] += 1
        return self._snapshot

    def _query_sharded(self, snap, q_tokens, q_mask, q_loc, *, k: int,
                       cr: int, batch: int, backend: Optional[str],
                       fvals=None, filtered: bool = False):
        """The mesh-sharded scan (DESIGN.md §12): shared prefix on the
        default device, localized per-shard scans pinned to each
        shard's device by their committed buffers, host tree merge.
        The filtered variant threads each shard's local ``attrs`` part
        plus the per-query ``fvals`` rows through the same plan.

        Fault tolerance (DESIGN.md §15): every shard scan is timed into
        :class:`ShardHealth`; failures retry against a host-side replica
        of the shard's clusters with doubling-capped backoff; a shard
        flagged slow by the :class:`StragglerMonitor` is hedged — its
        scans pre-emptively run on the replica (with periodic device
        probes to detect recovery); a DOWN shard is skipped and the
        surviving partials merge into a degraded result whose coverage
        fraction (routed clusters scanned / routed) lands in
        ``last_coverage``. Raises :class:`ShardUnavailable` only when
        NO shard can serve."""
        from repro.core import faults as faults_lib
        from repro.core import serving as serving_lib
        from repro.distributed import resilience as resilience_lib

        shards = snap.shards
        backend = self.backend if backend is None else backend
        prefix = self.prefix_fn(cr=cr)
        sfn = self.shard_topk_fn(k=k, backend=backend, batch=batch,
                                 precision=snap.meta.precision,
                                 filtered=filtered)
        # host (uncommitted) copies of everything the per-shard calls
        # consume: a committed default-device operand would clash with
        # buffers committed on shard s (jax refuses mixed commitments)
        w_hat = np.asarray(snap.w_hat)
        health = self._shard_state(shards.n_shards)
        monitor = self._shard_monitor
        shard_of = np.asarray(shards.shard_of)
        coverage = [0, 0]               # routed clusters scanned / routed
        down_seen = set()

        def run_scan(s, part, q_emb, loc, w, local_c, qf, *, on_device):
            # scan_error fires on BOTH device and host-replica attempts
            # (the shard's DATA is unscannable, not just its device);
            # scan_slow only models a slow device — the replica is fine
            if on_device:
                faults_lib.fire("shard.scan_slow", shard=s)
            faults_lib.fire("shard.scan_error", shard=s)
            if filtered:
                out = sfn(w_hat, part["emb"], part["loc"], part["ids"],
                          part["scale"], part["attrs"],
                          q_emb, loc, w, local_c, qf)
            else:
                out = sfn(w_hat, part["emb"], part["loc"], part["ids"],
                          part["scale"], q_emb, loc, w, local_c)
            # sync here so the wall time fed to ShardHealth measures
            # THIS shard's scan, not whatever dispatch queued behind it
            return np.asarray(out[0]), np.asarray(out[1])

        def scan_shard(s, part, q_emb, loc, w, local_c, qf):
            """One shard's partial ``(ids, scores)``, or ``None`` when
            the shard could not be scanned this chunk."""
            try:
                faults_lib.fire("shard.device_lost", shard=s)
            except Exception:
                health.mark_down(s)
                return None
            hedge = s in self._hedged
            probe = False
            if hedge:
                # hedged shard: serve from the replica, but probe the
                # device every Nth scan so a recovered device is noticed
                self._hedged[s] += 1
                probe = self._hedged[s] % self.hedge_probe_every == 0
            delay_ms = self.shard_backoff_ms
            for attempt in range(1 + self.shard_retries):
                if attempt > 0:
                    self.shard_stats["scan_retries"] += 1
                    if delay_ms > 0:
                        time.sleep(min(delay_ms,
                                       self.shard_backoff_max_ms) / 1e3)
                    delay_ms = min(delay_ms * 2, self.shard_backoff_max_ms)
                # retries go straight to the host replica: the device
                # already failed once this chunk
                on_host = (hedge and not probe) or attempt > 0
                try:
                    t0 = time.perf_counter()
                    if on_host:
                        out = run_scan(
                            s, self._host_shard_part(snap, shards, s),
                            q_emb, loc, w, local_c, qf, on_device=False)
                        self.shard_stats["host_scans"] += 1
                        if hedge and not probe:
                            self.shard_stats["hedged_scans"] += 1
                    else:
                        out = run_scan(s, part, q_emb, loc, w, local_c,
                                       qf, on_device=True)
                    dt = time.perf_counter() - t0
                    health.record_success(s, dt)
                    if not on_host:
                        # only device timings feed the straggler stream:
                        # a hedged replica scan must not mask the slow
                        # device we are hedging against
                        monitor.record(f"shard{s}", dt)
                        if monitor.slow(f"shard{s}"):
                            self._hedged.setdefault(s, 0)
                        elif hedge:
                            self._hedged.pop(s, None)    # probe came
                            # back fast — device recovered, stop hedging
                    return out
                except Exception:
                    health.record_failure(s)
                    if health.is_down(s):
                        return None
            return None                  # retries exhausted, not DOWN yet

        def chunk_fn(t, m, l, *rest):
            q_emb, w, top_c = prefix(snap.rel_params, snap.index_params,
                                     snap.norm, t, m, l)
            q_emb = np.asarray(q_emb)
            w = np.asarray(w)
            top_c = np.asarray(top_c)
            loc = np.asarray(l)
            qf = np.asarray(rest[0]) if filtered else None
            routes_per = np.bincount(shard_of[top_c].ravel(),
                                     minlength=shards.n_shards)
            coverage[1] += int(top_c.size)
            partials = []
            for s, part in enumerate(shards.parts):
                if health.is_down(s):
                    self.shard_stats["down_skips"] += 1
                    down_seen.add(s)
                    continue
                local_c = serving_lib.localize_routes(
                    top_c, shards.shard_of, shards.local_of, s,
                    sentinel=shards.sentinel)
                out = scan_shard(s, part, q_emb, loc, w, local_c, qf)
                if out is None:
                    if health.is_down(s):
                        down_seen.add(s)
                    continue
                coverage[0] += int(routes_per[s])
                partials.append(out)
            if not partials:
                raise resilience_lib.ShardUnavailable(
                    f"all {shards.n_shards} shards down/unscannable — "
                    f"no partial top-k lists to merge")
            return merge_shard_topk(partials, k=k)

        arrays = [q_tokens, q_mask, q_loc]
        if filtered:
            arrays.append(fvals)
        out = run_batched(chunk_fn, arrays, batch=batch)
        self.last_coverage = (coverage[0] / coverage[1]
                              if coverage[1] else 1.0)
        self.last_down_shards = tuple(sorted(down_seen))
        return out

    def delta_scan_fn(self, *, k: int, precision: str,
                      filtered: bool = False):
        """The jitted delta scan plan for ``(k, precision, filtered)``.
        Retraces lazily per padded row-count bucket
        (:data:`DELTA_PAD_BUCKET`)."""
        key = (k, precision, filtered)
        if key not in self._delta_plans:
            self._delta_plans[key] = make_delta_scan_fn(
                self.cfg, k=k, dist_max=self.dist_max,
                weight_mode=self.weight_mode, precision=precision,
                filtered=filtered)
        return self._delta_plans[key]

    def _scan_delta(self, snap, q_tokens, q_mask, q_loc, *, k: int,
                    batch: int, fvals=None, filtered: bool = False):
        """Brute-force scan the pinned snapshot's delta rows: every
        query × every delta row, padded to the bucketed static shape."""
        from repro.core.filters import N_ATTRS

        arrs = snap.delta.arrays()
        m = arrs["ids"].shape[0]
        m_pad = -(-m // DELTA_PAD_BUCKET) * DELTA_PAD_BUCKET
        emb = np.zeros((m_pad,) + arrs["emb"].shape[1:], arrs["emb"].dtype)
        emb[:m] = arrs["emb"]
        scale = np.ones((m_pad,), np.float32)
        scale[:m] = arrs["scale"]
        loc = np.full((m_pad, 2), index_lib.PAD_LOC, np.float32)
        loc[:m] = arrs["loc"]
        ids = np.full((m_pad,), -1, np.int32)
        ids[:m] = arrs["ids"]
        fn = self.delta_scan_fn(k=k, precision=snap.meta.precision,
                                filtered=filtered)
        w_hat = snap.w_hat
        de, ds, dl, di = (jnp.asarray(a) for a in (emb, scale, loc, ids))
        if filtered:
            attrs = np.zeros((m_pad, N_ATTRS), np.int32)
            attrs[:m] = arrs["attrs"]
            da = jnp.asarray(attrs)
            return run_batched(
                lambda t, mk, l, f: fn(snap.rel_params, w_hat, de, ds, dl,
                                       di, da, t, mk, l, f),
                [q_tokens, q_mask, q_loc, fvals], batch=batch)
        return run_batched(
            lambda t, mk, l: fn(snap.rel_params, w_hat, de, ds, dl, di,
                                t, mk, l),
            [q_tokens, q_mask, q_loc], batch=batch)

    def query(self, q_tokens, q_mask, q_loc, *, k: int = 20, cr: int = 1,
              batch: int = 256, backend: Optional[str] = None,
              snapshot=None, filters=None):
        """Batched routed query: (ids (n, k), scores (n, k)) numpy.

        Reads the snapshot reference exactly once (or serves an explicit
        ``snapshot`` — the server's flush path pins the one it started
        with), so every chunk of the batch scores one consistent index.
        The plan is selected for the pinned snapshot's precision tier;
        an auto engine additionally picks query- vs cluster-major per
        batch (:meth:`pick_backend`) unless ``backend`` overrides it.

        ``filters`` (core/filters.py, DESIGN.md §13) is ``None``, one
        :class:`~repro.core.filters.FilterSpec` broadcast over the whole
        request, or one spec (or None) per query row. Filters compile to
        per-query ``fvals`` rows riding the batch arrays; all-no-op
        filters collapse to the unfiltered plan, so pre-filter callers
        trace and run the byte-identical program. The predicate applies
        uniformly to base, sharded, and delta scans — a row never leaks
        across a filter anywhere in its lifecycle.

        When the pinned snapshot carries a delta segment (DESIGN.md
        §11), the base results are post-processed on the host: the delta
        rows are scanned (:meth:`_scan_delta`, same ``batch``), the base
        lists tombstone-filtered, and both merged by
        :func:`merge_delta`. A compacted (or delta-free) snapshot skips
        all of it — the fast path is byte-identical to before.

        When the pinned snapshot is mesh-sharded (``snap.shards``,
        DESIGN.md §12), the base scan runs per shard and tree-merges
        (:meth:`_query_sharded`) BEFORE the delta merge — the delta
        path is placement-agnostic and composes unchanged.
        """
        snap = self._snapshot if snapshot is None else snapshot
        # coverage annotation (DESIGN.md §15): 1.0 unless the sharded
        # path below loses a shard; read by Searcher/server after the call
        self.last_coverage = 1.0
        self.last_down_shards = ()
        fvals, filtered = filters_lib.compile_filters(
            filters, np.asarray(q_tokens).shape[0])
        # the per-batch cluster-major pick engages whenever the request
        # is "auto": explicitly (e.g. the serving drivers' resolved CLI
        # default, forwarded through ServerConfig.backend) or implicitly
        # (no override on an auto-constructed engine)
        if backend == "auto" or (backend is None and self._auto_cm):
            base = (resolve_backend("auto")[0] if backend == "auto"
                    else self.backend)
            backend = self.pick_backend(q_tokens, q_mask, q_loc, cr=cr,
                                        batch=batch, snapshot=snap,
                                        base=base)
        buf = snap.buffers
        delta = getattr(snap, "delta", None)
        use_delta = delta is not None and not delta.is_empty
        # every tombstone can kill one base entry, so over-fetch the
        # base list by the tombstone count (bucketed — bounded
        # recompiles; capped by the routed candidate pool) and trim back
        # to k after the merge: the post-filter top-k is then exactly
        # what a compacted snapshot would return
        k_fetch = k
        if use_delta and delta.n_tombstones:
            extra = (-(-delta.n_tombstones // TOMBSTONE_K_BUCKET)
                     * TOMBSTONE_K_BUCKET)
            pool = cr * int(buf["capacity"])
            k_fetch = max(k, min(k + extra, pool))
        if getattr(snap, "shards", None) is not None:
            # mesh-sharded snapshot (DESIGN.md §12): per-shard plans +
            # host tree merge, then the same delta merge below
            ids, scores = self._query_sharded(
                snap, q_tokens, q_mask, q_loc, k=k_fetch, cr=cr,
                batch=batch, backend=backend, fvals=fvals,
                filtered=filtered)
        else:
            fn = self.query_fn(k=k_fetch, cr=cr, backend=backend,
                               batch=batch, precision=snap.meta.precision,
                               filtered=filtered)
            w_hat = snap.w_hat          # once per call, not per chunk
            if filtered:
                ids, scores = run_batched(
                    lambda t, m, l, f: fn(
                        snap.rel_params, snap.index_params, w_hat,
                        snap.norm, buf["emb"], buf["loc"], buf["ids"],
                        buf["scale"], buf["attrs"], t, m, l, f),
                    [q_tokens, q_mask, q_loc, fvals], batch=batch)
            else:
                ids, scores = run_batched(
                    lambda t, m, l: fn(snap.rel_params, snap.index_params,
                                       w_hat, snap.norm, buf["emb"],
                                       buf["loc"], buf["ids"],
                                       buf["scale"], t, m, l),
                    [q_tokens, q_mask, q_loc], batch=batch)
        if not use_delta:
            return ids, scores
        d_ids = d_scores = None
        if delta.n_rows:
            d_ids, d_scores = self._scan_delta(snap, q_tokens, q_mask,
                                               q_loc, k=k, batch=batch,
                                               fvals=fvals,
                                               filtered=filtered)
        return merge_delta(ids, scores, d_ids, d_scores,
                           tombstones=delta.tombstone_array(), k=k)
