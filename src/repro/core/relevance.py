"""LIST-R: the embedding-based spatio-textual relevance model (paper §4.2).

ST(q, o) = w_st · [TRel, SRel]          (Eq. 7)
  TRel   = q.emb · o.emb                (Eq. 3, dual encoder)
  SRel   = learned step function        (Eq. 4/5, core/spatial.py)
  w_st   = MLP(q.emb) ∈ R²              (Eq. 6, adaptive weighting)

Training: contrastive NLL over the positive + b hard negatives + in-batch
negatives (Eq. 8).

Spatial-module ablations (paper Table 6) select via cfg-style kwargs:
``spatial_mode`` in {"step", "linear", "exp"}; ``weight_mode`` in
{"mlp", "fixed"}.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import spatial as sp
from repro.models import layers, transformer


def relevance_init(key, cfg, *, spatial_mode="step", weight_mode="mlp"):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "q_enc": transformer.encoder_init(k1, cfg),
        "o_enc": transformer.encoder_init(k2, cfg),
        "weight_mlp": layers.mlp_init(k3, (cfg.d_model, 64, 2)),
        "fixed_w": jnp.array([1.0, 1.0]),
    }
    if spatial_mode == "step":
        p["spatial"] = sp.spatial_init(k4, cfg.spatial_t)
    elif spatial_mode == "exp":
        p["spatial"] = sp.exp_init(k4)
    else:
        p["spatial"] = {}
    return p


def encode_queries(params, tokens, mask, cfg):
    return transformer.encoder_forward(params["q_enc"], tokens, mask, cfg)


def encode_objects(params, tokens, mask, cfg):
    return transformer.encoder_forward(params["o_enc"], tokens, mask, cfg)


def st_weights(params, q_emb, *, weight_mode="mlp"):
    """Per-query [w_text, w_spatial] (Eq. 6); softplus keeps them positive."""
    if weight_mode == "fixed":
        w = jnp.broadcast_to(params["fixed_w"], q_emb.shape[:-1] + (2,))
        return jax.nn.softplus(w)
    return jax.nn.softplus(layers.mlp_apply(params["weight_mlp"], q_emb))


def srel(params, s_in, cfg, *, spatial_mode="step", train=True):
    if spatial_mode == "step":
        if train:
            return sp.spatial_relevance_train(params["spatial"], s_in,
                                              t=cfg.spatial_t)
        w_hat = sp.extract_lookup(params["spatial"])
        return sp.spatial_relevance_serve(w_hat, s_in)
    if spatial_mode == "exp":
        return sp.exp_srel(params["spatial"], s_in)
    return sp.linear_srel(s_in)


def score_pairs(params, q_emb, q_loc, o_emb, o_loc, cfg, *, dist_max=1.0,
                spatial_mode="step", weight_mode="mlp", train=True):
    """ST(q, o) for aligned pairs. q_emb: (..., d); o_emb: (..., d)."""
    trel = jnp.sum(q_emb * o_emb, axis=-1)
    s_in = sp.s_in_from_locs(q_loc, o_loc, dist_max)
    s = srel(params, s_in, cfg, spatial_mode=spatial_mode, train=train)
    w = st_weights(params, q_emb, weight_mode=weight_mode)
    return w[..., 0] * trel + w[..., 1] * s


def score_corpus(params, q_emb, q_loc, obj_emb, obj_loc, cfg, *,
                 dist_max=1.0, spatial_mode="step", weight_mode="mlp",
                 train=False):
    """ST(q, o) for every (query, object) pair: (B, d)×(N, d) → (B, N).

    Pure-jnp oracle of the fused Pallas kernel (kernels/fused_topk_score).
    """
    trel = q_emb @ obj_emb.T                              # (B, N)
    d = jnp.linalg.norm(q_loc[:, None, :] - obj_loc[None, :, :], axis=-1)
    s_in = 1.0 - jnp.clip(d / dist_max, 0.0, 1.0)
    s = srel(params, s_in, cfg, spatial_mode=spatial_mode, train=train)
    w = st_weights(params, q_emb, weight_mode=weight_mode)  # (B, 2)
    return w[:, :1] * trel + w[:, 1:] * s


def contrastive_loss(params, batch, cfg, *, spatial_mode="step",
                     weight_mode="mlp", in_batch_negatives=True):
    """Eq. 8 with in-batch negatives.

    batch:
      q_tokens (B, L), q_mask, q_loc (B, 2)
      pos_tokens (B, L), pos_mask, pos_loc (B, 2)
      neg_tokens (B, b, L), neg_mask, neg_loc (B, b, 2)
      dist_max  scalar
    """
    b = batch["q_tokens"].shape[0]
    nneg = batch["neg_tokens"].shape[1]
    q = encode_queries(params, batch["q_tokens"], batch["q_mask"], cfg)
    pos = encode_objects(params, batch["pos_tokens"], batch["pos_mask"], cfg)
    flat_nt = batch["neg_tokens"].reshape(b * nneg, -1)
    flat_nm = batch["neg_mask"].reshape(b * nneg, -1)
    neg = encode_objects(params, flat_nt, flat_nm, cfg).reshape(b, nneg, -1)

    dist_max = batch.get("dist_max", 1.0)
    kw = dict(spatial_mode=spatial_mode, weight_mode=weight_mode, train=True,
              dist_max=dist_max)
    s_pos = score_pairs(params, q, batch["q_loc"], pos, batch["pos_loc"],
                        cfg, **kw)                               # (B,)
    s_neg = score_pairs(params, q[:, None, :], batch["q_loc"][:, None, :],
                        neg, batch["neg_loc"], cfg, **kw)        # (B, b)
    logits = [s_pos[:, None], s_neg]
    if in_batch_negatives:
        # other queries' positives as extra negatives (excluding self)
        s_ib = score_corpus(params, q, batch["q_loc"], pos, batch["pos_loc"],
                            cfg, spatial_mode=spatial_mode,
                            weight_mode=weight_mode, train=True,
                            dist_max=dist_max)                   # (B, B)
        mask = ~jnp.eye(b, dtype=bool)
        s_ib = jnp.where(mask, s_ib, -1e30)
        logits.append(s_ib)
    logits = jnp.concatenate(logits, axis=1).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -logp[:, 0].mean()
    acc = (logits.argmax(-1) == 0).mean()
    return loss, {"loss": loss, "acc": acc}
