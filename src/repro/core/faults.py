"""Fault-injection registry for the serving stack (DESIGN.md §14).

Chaos testing without monkeypatching: production code is instrumented
with named **injection points** — one :func:`fire` call at each place a
real deployment fails (engine errors mid-flush, crashes around the WAL
append / snapshot publish, torn WAL tails, corrupted snapshot files,
slow flushes). In production every ``fire`` is a dict lookup that misses
and returns ``None``; a chaos test arms a point with :func:`inject` and
the *real* code path — not a test double — takes the failure branch.

    faults.inject("flush.engine", error=RuntimeError("XLA OOM"), times=2)
    ... the next two engine flushes raise, then behavior is clean again

    with faults.injected("write.pre_publish", error=faults.Crash("died")):
        server.insert_objects(...)        # acked never happens: WAL has
                                          # the record, publish does not

Two injection flavors per point:

* ``error=`` — ``fire`` raises that exception (fresh copy semantics are
  the caller's concern; the same instance is raised each time);
* ``callback=`` — ``fire(point, **ctx)`` returns ``callback(**ctx)``;
  the callback may sleep (slow-flush), return a value the instrumented
  site interprets (e.g. ``wal.torn_tail`` returns how many bytes of the
  record actually reach the disk), or raise.

:class:`Crash` simulates a process dying at the injection point. It
derives from ``BaseException`` so the serving stack's own error
handling (which catches ``Exception`` to keep serving) can never
swallow a simulated crash — exactly like a real SIGKILL, nothing
downstream of the crash point runs.

The registry is process-global (module state) and explicitly NOT
thread-safe — the serving stack is single-event-loop by design. Tests
must :func:`clear` in teardown (or use the :func:`injected` context
manager, which does).
"""
from __future__ import annotations

import contextlib
from typing import Callable, Dict, List, Optional

# Every instrumented site, so a typo'd inject() fails loudly instead of
# arming a point nothing ever fires.
POINTS = frozenset({
    # core/server.py
    "flush.engine",          # raised in place of the engine call
    "flush.slow",            # fired before the engine call (callback sleeps)
    "write.pre_publish",     # after the WAL append, before the publish
    "write.post_publish",    # after the publish, before the write returns
    # core/wal.py
    "wal.torn_tail",         # callback → n bytes of the record written,
                             # then Crash (simulates dying mid-append)
    # checkpoint/ckpt.py
    "ckpt.mid_save",         # between leaf writes and the atomic commit
    "ckpt.post_commit",      # after commit (callback gets path=, e.g. to
                             # corrupt a committed file on purpose)
    # core/engine.py (_query_sharded — callback gets shard=)
    "shard.scan_error",      # raised in place of a shard scan, device AND
                             # host-replica attempts (the shard's data is
                             # unscannable, not just its device)
    "shard.scan_slow",       # fired before a DEVICE scan (callback sleeps
                             # — a slow device; the host replica is fine)
    "shard.device_lost",     # fired once per shard per chunk before any
                             # attempt; raising = device gone → instant DOWN
})


class Crash(BaseException):
    """A simulated process death at an injection point.

    BaseException on purpose: the serving stack's keep-serving handlers
    catch ``Exception``; a crash must tear through them like a SIGKILL.
    """


class FaultError(RuntimeError):
    """Default injected failure when ``inject`` gets no error/callback."""


class _Injection:
    __slots__ = ("error", "callback", "remaining")

    def __init__(self, error, callback, times):
        self.error = error
        self.callback = callback
        self.remaining = times          # None → fire forever


_armed: Dict[str, List[_Injection]] = {}
_fired: Dict[str, int] = {}


def inject(point: str, *, error: Optional[BaseException] = None,
           callback: Optional[Callable] = None,
           times: Optional[int] = 1) -> None:
    """Arm ``point``: the next ``times`` fires (None = every fire) raise
    ``error`` or run ``callback`` (exactly one of the two; with neither,
    a generic :class:`FaultError` is raised). Multiple injections on one
    point queue FIFO."""
    if point not in POINTS:
        raise ValueError(f"unknown fault point {point!r}; known: "
                         f"{sorted(POINTS)}")
    if error is not None and callback is not None:
        raise ValueError("inject: pass error= or callback=, not both")
    if error is None and callback is None:
        error = FaultError(f"injected fault at {point}")
    _armed.setdefault(point, []).append(_Injection(error, callback, times))


def clear(point: Optional[str] = None) -> None:
    """Disarm one point (or everything), and reset the fired counters."""
    if point is None:
        _armed.clear()
        _fired.clear()
    else:
        _armed.pop(point, None)
        _fired.pop(point, None)


def active(point: str) -> bool:
    return bool(_armed.get(point))


def fired(point: str) -> int:
    """How many times ``point`` actually took an injected branch."""
    return _fired.get(point, 0)


def fire(point: str, **ctx):
    """The instrumented-site hook. No-op (returns ``None``) unless the
    point is armed; otherwise consumes one firing of the front injection
    and raises its error or returns its callback's result."""
    queue = _armed.get(point)
    if not queue:
        return None
    inj = queue[0]
    if inj.remaining is not None:
        inj.remaining -= 1
        if inj.remaining <= 0:
            queue.pop(0)
            if not queue:
                _armed.pop(point, None)
    _fired[point] = _fired.get(point, 0) + 1
    if inj.callback is not None:
        return inj.callback(**ctx)
    raise inj.error


@contextlib.contextmanager
def injected(point: str, *, error: Optional[BaseException] = None,
             callback: Optional[Callable] = None,
             times: Optional[int] = 1):
    """Context-manager form of :func:`inject`; disarms the point on exit
    even when the armed fault (e.g. a :class:`Crash`) propagates out."""
    inject(point, error=error, callback=callback, times=times)
    try:
        yield
    finally:
        clear(point)


__all__ = ["POINTS", "Crash", "FaultError", "inject", "clear", "active",
           "fired", "fire", "injected"]
