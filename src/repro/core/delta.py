"""Delta segment: the mutable half of the LSM-style mutation path.

The base index is an immutable ``IndexSnapshot`` — cheap to share, cheap
to serve, expensive to mutate (`insert_objects` rewrites (c, cap, d)
buffers, O(index) per write batch). A :class:`DeltaSegment` is the small
mutable overlay in front of it:

* ``insert`` appends a chunk of rows in O(batch) — prior chunks are
  shared structurally, nothing is copied or re-routed;
* ``delete`` records ids in a **tombstone** set (applied to BASE results
  at query time) and physically drops any delta-resident rows with those
  ids, so delta rows are always live and never need tombstone filtering;
* queries brute-force scan the delta (it is small by construction — the
  server compacts it past a threshold) and merge into the base top-k
  (``engine.merge_delta``);
* compaction (:meth:`IndexSnapshot.compact`) folds tombstones + delta
  rows into a fresh base via the §4.3 delete/insert policy and clears
  the delta — one version bump, query results unchanged.

Rows are quantized to the snapshot's precision tier on the way IN (the
same ``quantize_rows`` the buffers use) so a delta-resident object
scores identically before and after compaction; the raw f32 rows are
kept alongside so compaction re-quantizes from the exact source instead
of compounding error.

Everything here is host-side numpy; the jitted scan lives in
``core/engine.make_delta_scan_fn``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import ml_dtypes
import numpy as np

from repro.core.index import PAD_LOC, PRECISIONS, quantize_rows

_STORE_DTYPE = {"f32": np.float32, "bf16": ml_dtypes.bfloat16,
                "int8": np.int8}

# chunk / concatenated-array field names, in canonical order
FIELDS = ("emb", "scale", "loc", "ids", "raw", "attrs")


def _empty_arrays(d: int, precision: str) -> Dict[str, np.ndarray]:
    from repro.core.filters import N_ATTRS
    return {
        "emb": np.zeros((0, d), _STORE_DTYPE[precision]),
        "scale": np.zeros((0,), np.float32),
        "loc": np.zeros((0, 2), np.float32),
        "ids": np.zeros((0,), np.int32),
        "raw": np.zeros((0, d), np.float32),
        "attrs": np.zeros((0, N_ATTRS), np.int32),
    }


@dataclasses.dataclass(frozen=True)
class DeltaSegment:
    """Immutable value type: every mutation returns a NEW segment.

    ``chunks`` is a tuple of per-insert row blocks (dicts over
    :data:`FIELDS`); appending shares all prior chunks, so an insert is
    O(batch) regardless of how much the delta already holds. ``ids_live``
    is the set of delta-resident ids (O(1) duplicate checks);
    ``tombstones`` the ids deleted from the BASE since the last
    compaction.
    """

    d: int
    precision: str = "f32"
    chunks: Tuple[Dict[str, np.ndarray], ...] = ()
    ids_live: frozenset = frozenset()
    tombstones: frozenset = frozenset()

    # -- constructors -------------------------------------------------------

    @classmethod
    def empty(cls, d: int, precision: str = "f32") -> "DeltaSegment":
        if precision not in PRECISIONS:
            raise ValueError(f"precision must be one of {PRECISIONS}, "
                             f"got {precision!r}")
        return cls(d=int(d), precision=precision)

    # -- inspection ---------------------------------------------------------

    @property
    def n_rows(self) -> int:
        return sum(c["ids"].shape[0] for c in self.chunks)

    @property
    def n_tombstones(self) -> int:
        return len(self.tombstones)

    @property
    def is_empty(self) -> bool:
        return not self.chunks and not self.tombstones

    def arrays(self) -> Dict[str, np.ndarray]:
        """Concatenated row arrays (memoized; cheap when chunks are few)."""
        memo = self.__dict__.get("_arrays")
        if memo is None:
            if not self.chunks:
                memo = _empty_arrays(self.d, self.precision)
            else:
                memo = {f: np.concatenate([c[f] for c in self.chunks])
                        for f in FIELDS}
            object.__setattr__(self, "_arrays", memo)
        return memo

    def tombstone_array(self) -> np.ndarray:
        """Sorted int64 id array (np.isin-friendly)."""
        return np.sort(np.fromiter(self.tombstones, np.int64,
                                   len(self.tombstones)))

    # -- mutations ----------------------------------------------------------

    def insert(self, new_emb, new_loc, new_ids,
               new_attrs=None) -> "DeltaSegment":
        """Append a batch of rows. O(batch): prior chunks are shared."""
        from repro.core.filters import validate_attrs
        raw = np.asarray(new_emb, np.float32).reshape(-1, self.d)
        loc = np.asarray(new_loc, np.float32).reshape(-1, 2)
        ids = np.asarray(new_ids, np.int32).reshape(-1)
        attrs = validate_attrs(new_attrs, ids.shape[0])
        if not (raw.shape[0] == loc.shape[0] == ids.shape[0]):
            raise ValueError("insert: emb/loc/ids batch sizes disagree")
        if (ids < 0).any():
            raise ValueError("insert: ids must be non-negative "
                             "(-1 is the padding sentinel)")
        dup = self.ids_live.intersection(ids.tolist())
        if dup or len(set(ids.tolist())) != ids.shape[0]:
            raise ValueError(f"insert: duplicate ids in delta: "
                             f"{sorted(dup) or 'within batch'}")
        stored, scale = quantize_rows(raw, self.precision)
        chunk = {"emb": stored, "scale": scale.astype(np.float32),
                 "loc": loc, "ids": ids, "raw": raw, "attrs": attrs}
        return dataclasses.replace(
            self, chunks=self.chunks + (chunk,),
            ids_live=self.ids_live.union(ids.tolist()))

    def delete(self, del_ids) -> "DeltaSegment":
        """Tombstone ids for the base; drop matching delta rows physically.

        Ids need not be live — deleting an unknown id is a no-op beyond
        the (harmless) tombstone entry.
        """
        dels = set(int(i) for i in np.asarray(del_ids).reshape(-1))
        in_delta = self.ids_live.intersection(dels)
        chunks = self.chunks
        if in_delta:
            kill = np.fromiter(in_delta, np.int64, len(in_delta))
            new_chunks = []
            for c in chunks:
                keep = ~np.isin(c["ids"], kill)
                if keep.all():
                    new_chunks.append(c)
                elif keep.any():
                    new_chunks.append({f: c[f][keep] for f in FIELDS})
            chunks = tuple(new_chunks)
        return dataclasses.replace(
            self, chunks=chunks,
            ids_live=self.ids_live.difference(dels),
            tombstones=self.tombstones.union(dels))

    # -- serialization (snapshot schema v3) ---------------------------------

    def to_leaves(self) -> Dict[str, np.ndarray]:
        """Canonical single-chunk array dict + tombstones, for checkpointing."""
        leaves = dict(self.arrays())
        leaves["tombstones"] = self.tombstone_array()
        return leaves

    @classmethod
    def from_leaves(cls, d: int, precision: str, leaves) -> "DeltaSegment":
        arrs = {f: np.asarray(leaves[f]) for f in FIELDS}
        arrs["emb"] = arrs["emb"].astype(_STORE_DTYPE[precision])
        arrs["attrs"] = arrs["attrs"].astype(np.int32)
        tomb = frozenset(int(i) for i in np.asarray(leaves["tombstones"]))
        chunks = (arrs,) if arrs["ids"].shape[0] else ()
        return cls(d=int(d), precision=precision, chunks=chunks,
                   ids_live=frozenset(int(i) for i in arrs["ids"]),
                   tombstones=tomb)


def live_counts(buffers, delta: "DeltaSegment | None") -> np.ndarray:
    """Effective per-cluster live sizes of the BASE: counts minus
    tombstoned rows still physically resident. O(index) — only call on
    slow paths (compaction-trigger checks with ``max_imbalance`` set)."""
    counts = np.asarray(buffers["counts"]).astype(np.int64).copy()
    if delta is not None and delta.tombstones:
        ids = np.asarray(buffers["ids"])
        dead = np.isin(ids, delta.tombstone_array()) & (ids >= 0)
        counts -= dead.sum(axis=-1)
    return counts


__all__ = ["DeltaSegment", "live_counts", "FIELDS", "PAD_LOC"]
