"""LIST-I: the learned cluster-classifier index (paper §4.3).

A single MLP shared between queries and objects maps
x = [L2norm(emb), lat̂, lon̂] (Eq. 9–10) to a softmax over c clusters
(Eq. 11). Training uses the MCL pairwise loss (Eq. 14) on ground-truth
positives + pseudo-negatives mined by the relevance model (Eq. 13,
core/pseudo_labels.py).

TPU-native indexing phase (DESIGN.md §3): instead of pointer-based inverted
lists, objects are packed into fixed-capacity padded **cluster buffers**
(emb (c, cap, d), loc (c, cap, 2), ids (c, cap)) so the query phase is a
static-shape gather + fused score. Overflowing objects spill to their
next-best cluster (at most `spill` hops) — balance is learned (that is the
point of the pseudo-label design), spill is the safety net.

Precision policy (DESIGN.md §9): the query phase is memory-bound on
streaming ``emb (c, cap, d)``, so the resident embeddings can be stored
quantized — ``precision ∈ PRECISIONS``:

* ``"f32"``  — exact float32 (the default and the parity oracle);
* ``"bf16"`` — bfloat16 cast, 2× less HBM traffic, no scale needed;
* ``"int8"`` — symmetric per-row scalar quantization, 4× less traffic:
  ``q = clip(round(emb / scale), -127, 127)`` with
  ``scale = max|emb_row| / 127`` kept in ``buffers["scale"] (c, cap)``
  float32. Dequantization happens in VMEM inside the fused kernels
  (kernels/fused_topk_score.py) so only compressed bytes cross HBM.

``loc``/``ids`` always stay exact: spatial relevance and the padding
mask are bit-identical across precision tiers — only TRel quantizes.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import ml_dtypes
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import layers

PRECISIONS = ("f32", "bf16", "int8")

# Padding sentinel for ``loc`` rows: far enough outside any normalized
# corpus extent that a padded slot can never look spatially relevant.
# Both the build path and the mutation path MUST use the same value, or
# a mutated index diverges bit-wise from a rebuilt one.
PAD_LOC = 1e6


# ---------------------------------------------------------------------------
# Feature construction (Eq. 9–10)
# ---------------------------------------------------------------------------


def loc_normalizer(locs):
    """Fit min/max normalization bounds from the object corpus. locs: (N,2)."""
    lo = locs.min(axis=0)
    hi = locs.max(axis=0)
    return {"lo": lo, "span": jnp.maximum(hi - lo, 1e-9)}


def build_features(emb, loc, norm):
    """x = [L2norm(emb), lat̂, lon̂]: (..., d+2)."""
    e = emb / jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True), 1e-9)
    l_hat = (loc - norm["lo"]) / norm["span"]
    return jnp.concatenate([e, l_hat], axis=-1)


# ---------------------------------------------------------------------------
# Cluster classifier (Eq. 11)
# ---------------------------------------------------------------------------


def index_init(key, d_emb: int, n_clusters: int, hidden=(512, 512)):
    dims = (d_emb + 2,) + tuple(hidden) + (n_clusters,)
    return {"mlp": layers.mlp_init(key, dims)}


def cluster_logits(params, x):
    return layers.mlp_apply(params["mlp"], x, act=jax.nn.relu)


def cluster_probs(params, x):
    return jax.nn.softmax(cluster_logits(params, x).astype(jnp.float32), -1)


# ---------------------------------------------------------------------------
# MCL training loss (Eq. 14)
# ---------------------------------------------------------------------------


def mcl_loss(params, batch, *, balance_weight: float = 0.5):
    """Meta-classification likelihood over pairwise pseudo-labels.

    batch:
      q_feat   (B, d+2)
      pos_feat (B, d+2)     one positive per query
      neg_feat (B, m, d+2)  m pseudo-negatives per query
    ŝ(q,o) = Prob_q · Prob_o; maximize log ŝ(pos) + Σ log(1 − ŝ(neg)).

    ``balance_weight`` adds KL(mean-assignment ‖ uniform) — a beyond-paper
    stabilizer (DESIGN.md §6): the paper relies on pseudo-negative hardness
    alone for balance, which we found collapse-prone at small scale (all
    probability mass drifting to a few clusters early in training kills the
    pairwise gradient). The regularizer only bites while the MEAN assignment
    is skewed; at the paper's balanced optimum it vanishes.
    """
    pq = cluster_probs(params, batch["q_feat"])          # (B, c)
    pp = cluster_probs(params, batch["pos_feat"])        # (B, c)
    pn = cluster_probs(params, batch["neg_feat"])        # (B, m, c)
    s_pos = jnp.sum(pq * pp, axis=-1)
    s_neg = jnp.einsum("bc,bmc->bm", pq, pn)
    eps = 1e-6
    loss = -(jnp.log(s_pos + eps).mean()
             + jnp.log(1.0 - s_neg + eps).sum(-1).mean())
    if balance_weight:
        c = pq.shape[-1]
        mean_p = jnp.concatenate(
            [pq, pp, pn.reshape(-1, c)], axis=0).mean(0)
        kl_unif = jnp.log(c) + jnp.sum(mean_p * jnp.log(mean_p + eps))
        loss = loss + balance_weight * kl_unif
    return loss, {"loss": loss, "s_pos": s_pos.mean(), "s_neg": s_neg.mean()}


# ---------------------------------------------------------------------------
# Precision policy: scalar quantization of resident embeddings
# ---------------------------------------------------------------------------


def quantize_rows(emb, precision: str):
    """Quantize embedding rows ``(..., d)`` f32 → (stored, scale (...,) f32).

    Symmetric per-row scalar quantization: each row's scale is
    ``max|row| / 127`` (1.0 for all-zero rows, e.g. padding slots, so
    dequant is a no-op there). ``"f32"``/``"bf16"`` need no scale and
    return all-ones; the uniform return shape keeps the buffer schema
    identical across tiers.
    """
    if precision not in PRECISIONS:
        raise ValueError(f"precision must be one of {PRECISIONS}, "
                         f"got {precision!r}")
    emb = np.asarray(emb, np.float32)
    scale = np.ones(emb.shape[:-1], np.float32)
    if precision == "f32":
        return emb, scale
    if precision == "bf16":
        return emb.astype(ml_dtypes.bfloat16), scale
    amax = np.abs(emb).max(axis=-1)
    scale = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(emb / scale[..., None]), -127, 127).astype(np.int8)
    return q, scale


def dequantize_rows(emb, scale, precision: str) -> np.ndarray:
    """Host-side inverse of :func:`quantize_rows` (lossy for int8)."""
    emb = np.asarray(emb).astype(np.float32)
    if precision == "int8":
        emb = emb * np.asarray(scale, np.float32)[..., None]
    return emb


def quantize_buffers(buffers: dict, precision: str) -> dict:
    """Derive a quantized copy of f32 cluster buffers (loc/ids untouched).

    Requantization is only defined FROM the exact tier: quantizing an
    already-quantized buffer would silently compound error, so any other
    source precision raises. Returns a new dict; the input is unchanged.
    """
    src = buffers.get("precision", "f32")
    if src == precision:
        return dict(buffers)
    if src != "f32":
        raise ValueError(
            f"quantize_buffers: can only requantize from 'f32' buffers, "
            f"these are {src!r}; rebuild the index at f32 first")
    q, scale = quantize_rows(np.asarray(buffers["emb"], np.float32),
                             precision)
    out = dict(buffers)
    out["emb"] = jnp.asarray(q)
    out["scale"] = jnp.asarray(scale)
    out["precision"] = precision
    return out


# ---------------------------------------------------------------------------
# Indexing phase: partition objects into padded cluster buffers
# ---------------------------------------------------------------------------


def assign_clusters(params, feats, *, top=1):
    """argmax (or top-`top`) cluster per object. feats: (N, d+2)."""
    logits = cluster_logits(params, feats)
    if top == 1:
        return jnp.argmax(logits, axis=-1)
    return jax.lax.top_k(logits, top)[1]


def build_cluster_buffers(assign_top, emb, loc, *, n_clusters: int,
                          capacity: Optional[int] = None, spill: int = 3,
                          precision: str = "f32", attrs=None):
    """Pack objects into (c, cap) padded buffers (host-side, numpy).

    assign_top: (N, spill) preferred clusters per object, best first.
    Returns dict with emb (c,cap,d) in ``precision``'s storage dtype,
    loc (c,cap,2), ids (c,cap) int32 (-1 = padding), counts (c,),
    scale (c,cap) f32 per-row dequant scales (all ones unless int8),
    attrs (c,cap,3) int32 per-object filter attributes (core/filters.py;
    zeros when ``attrs`` is None), plus the host-side scalars
    capacity / n_spilled / precision.
    """
    from repro.core import filters as filters_lib
    assign_top = np.asarray(assign_top)
    emb = np.asarray(emb)
    loc = np.asarray(loc)
    attrs = filters_lib.validate_attrs(attrs, emb.shape[0])
    n, d = emb.shape
    c = n_clusters
    if capacity is None:
        capacity = int(math.ceil(n / c * 2.0))
        capacity = -(-capacity // 128) * 128
    counts = np.zeros(c, np.int64)
    ids = np.full((c, capacity), -1, np.int32)
    n_spilled = 0
    for i in range(n):
        placed = False
        for h in range(min(spill, assign_top.shape[1])):
            ci = int(assign_top[i, h])
            if counts[ci] < capacity:
                ids[ci, counts[ci]] = i
                counts[ci] += 1
                placed = True
                if h > 0:
                    n_spilled += 1
                break
        if not placed:  # everything full: force into least-loaded cluster
            ci = int(np.argmin(counts))
            if counts[ci] >= capacity:
                raise ValueError("cluster capacity exhausted; raise capacity")
            ids[ci, counts[ci]] = i
            counts[ci] += 1
            n_spilled += 1
    gather = np.where(ids >= 0, ids, 0)
    buf_emb = emb[gather]
    buf_loc = loc[gather]
    buf_attrs = attrs[gather]
    valid = ids >= 0
    # zero out padding so fused scores on pads are harmless (masked anyway)
    buf_emb[~valid] = 0.0
    buf_loc[~valid] = PAD_LOC
    buf_attrs[~valid] = 0
    buf_emb, buf_scale = quantize_rows(buf_emb, precision)
    return {
        "emb": jnp.asarray(buf_emb), "loc": jnp.asarray(buf_loc),
        "ids": jnp.asarray(ids), "counts": jnp.asarray(counts),
        "scale": jnp.asarray(buf_scale), "attrs": jnp.asarray(buf_attrs),
        "n_spilled": n_spilled, "capacity": capacity, "precision": precision,
    }


def route_queries(params, q_feats, *, cr: int = 1):
    """Top-cr clusters per query: (B, cr) ids + probs."""
    logits = cluster_logits(params, q_feats)
    p = jax.nn.softmax(logits.astype(jnp.float32), -1)
    top_p, top_i = jax.lax.top_k(p, cr)
    return top_i, top_p


# ---------------------------------------------------------------------------
# Insertion / deletion (paper §4.3 "Insertion and Deletion Policy")
# ---------------------------------------------------------------------------


def insert_objects(buffers, params, norm, new_emb, new_loc, new_ids, *,
                   spill: int = 3, new_attrs=None):
    """Route new objects through the trained index into their buffers.

    Placement mirrors :func:`build_cluster_buffers` (paper §4.3): each
    object walks its top-``spill`` preferred clusters best-first and
    lands in the first with a free slot; only when ALL spill hops are
    full does it fall back to the least-loaded cluster. If even that
    cluster has no free slot (the whole index is at capacity) a
    ValueError is raised. Writes go to the first FREE slot
    (``id == -1``) rather than ``counts[ci]`` — after delete_objects a
    cluster has interior holes, and slot ``counts[ci]`` may hold a live
    object (regression: tests/test_index_mutation.py).

    ``new_emb`` is always float32; quantized buffers (DESIGN.md §9)
    quantize the new rows with their own per-row scales on the way in,
    so an insert never changes the buffer's storage dtype.
    """
    from repro.core import filters as filters_lib
    feats = build_features(new_emb, new_loc, norm)
    n_clusters = int(np.asarray(buffers["counts"]).shape[0])
    hops = max(1, min(int(spill), n_clusters))
    cl = np.asarray(assign_clusters(params, feats, top=hops))
    if cl.ndim == 1:
        cl = cl[:, None]
    new_attrs = filters_lib.validate_attrs(new_attrs,
                                           np.asarray(new_ids).shape[0])
    emb_np = {k: np.asarray(v).copy() for k, v in buffers.items()
              if k in ("emb", "loc", "ids", "scale", "attrs")}
    counts = np.asarray(buffers["counts"]).copy()
    cap = buffers["capacity"]
    q_emb, q_scale = quantize_rows(np.asarray(new_emb, np.float32),
                                   buffers.get("precision", "f32"))
    for j in range(cl.shape[0]):
        ci = -1
        for h in range(cl.shape[1]):          # spill hops, best first
            if counts[int(cl[j, h])] < cap:
                ci = int(cl[j, h])
                break
        if ci < 0:
            ci = int(np.argmin(counts))       # least-loaded fallback
        if counts[ci] >= cap:                 # fallback full too: all full
            raise ValueError(
                f"insert_objects: all clusters at capacity {cap} "
                f"(inserted {j}/{cl.shape[0]}); rebuild with higher capacity")
        free = np.flatnonzero(emb_np["ids"][ci] < 0)
        if free.size == 0:                    # counts out of sync with ids
            raise ValueError(
                f"insert_objects: cluster {ci} reports {counts[ci]} < "
                f"cap={cap} but has no free slot; counts/ids inconsistent")
        slot = int(free[0])
        emb_np["emb"][ci, slot] = q_emb[j]
        emb_np["scale"][ci, slot] = q_scale[j]
        emb_np["loc"][ci, slot] = np.asarray(new_loc[j])
        emb_np["ids"][ci, slot] = int(new_ids[j])
        emb_np["attrs"][ci, slot] = new_attrs[j]
        counts[ci] += 1
    out = dict(buffers)
    out.update({k: jnp.asarray(v) for k, v in emb_np.items()})
    out["counts"] = jnp.asarray(counts)
    return out


def delete_objects(buffers, del_ids):
    """Mark deleted ids as padding (lazy deletion, compaction on rebuild).

    A deleted slot is restored to EXACTLY the padding convention of
    :func:`build_cluster_buffers` — emb 0, scale 1, loc ``PAD_LOC``,
    id -1 — so a mutated index stays bit-identical to a rebuilt one.
    (Regression: ``loc`` used to keep the deleted object's live value.)
    """
    ids = np.asarray(buffers["ids"]).copy()
    emb = np.asarray(buffers["emb"]).copy()
    loc = np.asarray(buffers["loc"]).copy()
    scale = np.asarray(buffers["scale"]).copy()
    attrs = np.asarray(buffers["attrs"]).copy()
    mask = np.isin(ids, np.asarray(del_ids))
    ids[mask] = -1
    emb[mask] = 0.0
    loc[mask] = PAD_LOC
    scale[mask] = 1.0          # padding rows dequantize as exact zeros
    attrs[mask] = 0
    out = dict(buffers)
    out["ids"] = jnp.asarray(ids)
    out["emb"] = jnp.asarray(emb)
    out["loc"] = jnp.asarray(loc)
    out["scale"] = jnp.asarray(scale)
    out["attrs"] = jnp.asarray(attrs)
    out["counts"] = jnp.asarray((ids >= 0).sum(-1))
    return out
