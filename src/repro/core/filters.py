"""Filtered search: per-object attribute tables + predicate compilation.

One shared index serves many isolated slices (the workload-partitioning
concern WISK solves at index-build time, done here at query time): every
object carries a packed int32 attribute row

    ``attrs = [tenant id, category bitmask, timestamp]``   (:data:`N_ATTRS`)

stored as an extra ``(c, cap, 3)`` buffer family beside ``ids`` — same
padding convention (all-zero rows on padding slots), same gather layout,
threaded through build, mutation, delta segments, snapshot schema v5 and
mesh sharding.

A :class:`FilterSpec` (tenant equality + category bitmask + inclusive
time range) compiles to a per-query int32 vector

    ``fvals = [tenant, category_mask, t_min, t_max]``      (:data:`N_FVALS`)

whose components use **sentinel no-op values** (tenant ``-1`` = any,
mask ``0`` = any, time bounds int32 min/max = any) so ONE kernel variant
serves every filter combination with no static branching per filter
kind, and a mixed-tenant micro-batch compiles to a single plan. The
predicate is applied beside the dequant step inside the fused kernels
(kernels/fused_topk_score.py) and the dense oracles: filtered rows score
``NEG_INF`` in VMEM, candidates never round-trip to host.

Cache-isolation invariant: :func:`filter_signature` is the hashable
component the engine plan cache and every server cache / coalescing key
must include — two tenants can never share a cached result because their
signatures differ.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import numpy as np
import jax.numpy as jnp

# packed attribute columns: attrs[..., k]
N_ATTRS = 3
ATTR_TENANT, ATTR_CATEGORY, ATTR_TIME = 0, 1, 2

# compiled per-query filter values: fvals[..., k]
N_FVALS = 4

INT32_MIN = -(2 ** 31)
INT32_MAX = 2 ** 31 - 1

# sentinel no-op components (see FilterSpec): a query carrying all three
# sentinels matches every live row and is equivalent to no filter at all
ANY_TENANT = -1
ANY_CATEGORY = 0


@dataclasses.dataclass(frozen=True)
class FilterSpec:
    """One standing predicate over object attributes.

    * ``tenant``        — exact match on ``attrs[0]``; ``ANY_TENANT`` (-1)
                          accepts every tenant;
    * ``category_mask`` — bitwise-AND test against ``attrs[1]``
                          (match ⟺ ``attrs[1] & mask != 0``);
                          ``ANY_CATEGORY`` (0) accepts every category;
    * ``t_min``/``t_max`` — inclusive bounds on ``attrs[2]``; the int32
                          extremes accept every timestamp.
    """

    tenant: int = ANY_TENANT
    category_mask: int = ANY_CATEGORY
    t_min: int = INT32_MIN
    t_max: int = INT32_MAX

    def __post_init__(self):
        for name in ("tenant", "category_mask", "t_min", "t_max"):
            v = getattr(self, name)
            if not (INT32_MIN <= int(v) <= INT32_MAX):
                raise ValueError(f"FilterSpec.{name}={v} outside int32")

    @property
    def is_noop(self) -> bool:
        return (self.tenant == ANY_TENANT
                and self.category_mask == ANY_CATEGORY
                and self.t_min == INT32_MIN and self.t_max == INT32_MAX)

    def signature(self) -> Tuple[int, int, int, int]:
        """Hashable identity for cache keys (exact component values)."""
        return (int(self.tenant), int(self.category_mask),
                int(self.t_min), int(self.t_max))

    def to_fvals(self) -> np.ndarray:
        return np.array(self.signature(), np.int32)


NOOP_FILTER = FilterSpec()

Filters = Union[None, FilterSpec, Sequence[Optional[FilterSpec]]]


# ---------------------------------------------------------------------------
# Attribute-table validation / construction (host side)
# ---------------------------------------------------------------------------


def validate_attrs(attrs, n: int) -> np.ndarray:
    """Coerce a per-object attribute table to the packed (n, N_ATTRS)
    int32 layout; ``None`` yields all zeros (tenant 0, no categories,
    t=0) so unfiltered corpora cost nothing to carry."""
    if attrs is None:
        return np.zeros((n, N_ATTRS), np.int32)
    out = np.asarray(attrs)
    if out.shape != (n, N_ATTRS):
        raise ValueError(f"attrs must be ({n}, {N_ATTRS}), got {out.shape}")
    if not np.issubdtype(out.dtype, np.integer):
        raise ValueError(f"attrs must be integer, got dtype {out.dtype}")
    return out.astype(np.int32)


def make_attrs(tenant, category_mask=0, timestamp=0) -> np.ndarray:
    """Pack broadcastable per-object columns into an (n, N_ATTRS) table."""
    t, c, ts = np.broadcast_arrays(
        np.asarray(tenant), np.asarray(category_mask), np.asarray(timestamp))
    return np.stack([t, c, ts], axis=-1).astype(np.int32).reshape(
        -1, N_ATTRS)


# ---------------------------------------------------------------------------
# Filter compilation: FilterSpec(s) -> per-query fvals rows
# ---------------------------------------------------------------------------


def compile_filters(filters: Filters, batch: int) -> Tuple[np.ndarray, bool]:
    """Compile to ``(fvals (batch, N_FVALS) int32, filtered: bool)``.

    A single spec broadcasts over the batch; a sequence supplies one spec
    per query (``None`` entries become the no-op sentinel row). The bool
    is the STATIC plan dimension: when False (all no-op) callers take the
    unfiltered fast path and stream zero extra bytes.
    """
    if filters is None:
        specs = [NOOP_FILTER] * batch
    elif isinstance(filters, FilterSpec):
        specs = [filters] * batch
    else:
        specs = [f if f is not None else NOOP_FILTER for f in filters]
        if len(specs) != batch:
            raise ValueError(f"got {len(specs)} filters for batch {batch}")
        for f in specs:
            if not isinstance(f, FilterSpec):
                raise TypeError(f"filters must be FilterSpec, got {type(f)}")
    fvals = np.stack([f.to_fvals() for f in specs])
    return fvals, not all(f.is_noop for f in specs)


def filter_signature(filters: Filters):
    """Hashable cache-key component. ``None`` / no-op collapse to ``None``
    so pre-filter cache entries stay valid for unfiltered queries."""
    if filters is None:
        return None
    if isinstance(filters, FilterSpec):
        return None if filters.is_noop else filters.signature()
    sigs = tuple((f.signature() if f is not None else NOOP_FILTER.signature())
                 for f in filters)
    if all(s == NOOP_FILTER.signature() for s in sigs):
        return None
    return sigs


# ---------------------------------------------------------------------------
# The predicate (jnp; identical math inside kernels and dense oracles)
# ---------------------------------------------------------------------------


def predicate_mask(attrs, fvals):
    """Vectorized predicate: ``attrs`` int32 ``(..., N_ATTRS)``, ``fvals``
    int32 ``(..., N_FVALS)`` broadcastable against ``attrs[..., 0]``.
    Returns bool ``(...)`` — True = row passes. All three clauses are
    sentinel-aware, so no-op components accept everything.
    """
    tenant = attrs[..., ATTR_TENANT]
    cat = attrs[..., ATTR_CATEGORY]
    ts = attrs[..., ATTR_TIME]
    f_tenant = fvals[..., 0]
    f_mask = fvals[..., 1]
    t_lo = fvals[..., 2]
    t_hi = fvals[..., 3]
    ok_tenant = (f_tenant < 0) | (tenant == f_tenant)
    ok_cat = (f_mask == 0) | ((cat & f_mask) != 0)
    ok_time = (ts >= t_lo) & (ts <= t_hi)
    return ok_tenant & ok_cat & ok_time


def predicate_mask_np(attrs, fvals) -> np.ndarray:
    """Numpy twin of :func:`predicate_mask` for host-side oracles."""
    return np.asarray(predicate_mask(jnp.asarray(attrs), jnp.asarray(fvals)))


__all__ = [
    "N_ATTRS", "N_FVALS", "ATTR_TENANT", "ATTR_CATEGORY", "ATTR_TIME",
    "INT32_MIN", "INT32_MAX", "ANY_TENANT", "ANY_CATEGORY",
    "FilterSpec", "NOOP_FILTER", "Filters",
    "validate_attrs", "make_attrs",
    "compile_filters", "filter_signature",
    "predicate_mask", "predicate_mask_np",
]
