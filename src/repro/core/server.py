"""Streaming serving stack over the unified query engine (DESIGN.md §7).

The engine (core/engine.py) answers *batches*; real traffic arrives as
*individual* requests. This module is the production-shaped layer in
between — everything a long-lived serving process needs so that no user
request pays compile latency, repeated work, or a ragged-batch recompile:

* :class:`StreamingServer` — an **async micro-batcher**. ``await
  server.submit(tokens, mask, loc)`` enqueues one request; the queue is
  flushed into a single engine call when it reaches the configured
  static batch size (*size* flush) or when the oldest request has waited
  ``max_delay_ms`` (*deadline* flush). Flushes go through
  ``QueryEngine.query`` → ``engine.run_batched``, so a partial flush is
  zero-padded to the jitted batch shape by exactly the same rule as any
  direct engine call — micro-batched results are bit-identical to
  offline ones at a fixed backend (tests/test_server.py; an AUTO
  engine picks query- vs cluster-major per batch, DESIGN.md §10, so
  differently-composed batches are bit-compatible modulo tie order
  within equal scores).

* a **two-tier result cache** that exploits workload skew (WISK's
  observation: real query logs are heavily repeated):

  - *exact tier* — LRU keyed on the full request bytes
    ``(k, cr, tokens, mask, loc)``; a repeat of a previously answered
    request returns without touching the engine.
  - *near-duplicate tier* (opt-in via ``near_cells > 0``) — keyed on the
    **keyword signature** (sorted unique token ids) plus the **spatial
    cell** (location quantized to a ``near_cells × near_cells`` grid).
    Two queries with the same keywords issued a few meters apart share
    one answer. This tier is an *approximation* — word order and
    in-cell displacement are dropped — so it is off by default and
    meant for skew-heavy traffic where the recall cost is measured
    (benchmarks/bench_serving.py reports both tiers separately).

  Identical requests that are *in flight* (submitted before the first
  copy's flush completed) are coalesced onto one future instead of
  occupying two batch slots.

* an **LSM-style write path** (DESIGN.md §11) — :meth:`insert_objects`
  / :meth:`delete_objects` append to the snapshot's small mutable
  **delta segment** (core/delta.py) in O(batch) and publish the
  successor (``snapshot.with_delta`` — ``meta.version`` + 1); queries
  brute-force scan the delta and merge it into the base top-k
  (``engine.merge_delta``), with deletes as tombstones. When the delta
  crosses ``delta_threshold`` rows+tombstones — or, with
  ``max_imbalance`` set, when the live cluster sizes degrade past that
  imbalance-factor bound — a background **compaction**
  (``snapshot.compact``: the §4.3 delete/insert fold, one version
  bump) runs on the next event-loop tick, between flushes, and
  publishes the folded base. ``delta_threshold=0`` disables the delta
  entirely: every write folds eagerly through ``with_buffers``
  (O(index) per batch — the legacy path, kept as the bench baseline).

* **atomic snapshot publication** — the server never mutates the
  engine's resident state. Writes derive the successor snapshot and
  :meth:`publish` it: one engine reference swap plus a cache clear in
  the same event-loop step. Every cache key additionally embeds
  ``snapshot.meta.version``, so even a stale entry could never be
  served against the wrong index generation. A flush pins the snapshot
  it started with (passed explicitly into ``engine.query``), so
  requests already being scored finish on the OLD snapshot — no torn
  reads — while everything still queued flushes on the new one.

* a **warm-up manager** — :meth:`warmup` pre-traces the configured
  (batch, backend) shapes through the *same* bound plan the flush path
  uses, so the first live request hits an already-compiled program.
  Per-shape compile seconds are recorded in the stats block.

The event loop is single-threaded and the engine call blocks it for the
duration of one batch — the right model for a single-host accelerator
where query batches are executed serially anyway. A multi-host front
tier would run one server per accelerator behind a router; the dispatch
path (core/serving.py, DESIGN.md §5) is the intra-pod analogue.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import delta as delta_lib
from repro.core import engine as engine_lib
from repro.core import faults as faults_lib
from repro.core import filters as filters_lib
from repro.core import index as index_lib
from repro.core import cluster_metrics as cm
from repro.core import wal as wal_lib
from repro.distributed import resilience as resilience_lib


class Overloaded(RuntimeError):
    """Admission refused: the pending queue is at ``max_queue``. The
    caller sees this at submit time — load shedding, not a hang."""


class DeadlineExceeded(TimeoutError):
    """The request's deadline (``request_timeout_ms``) passed before its
    batch launched; it was shed instead of scored (DESIGN.md §14)."""


# ---------------------------------------------------------------------------
# Config + stats
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Knobs of the streaming server (DESIGN.md §7).

    batch_size      static jitted batch shape; a full queue flushes
                    immediately ("size" flush)
    max_delay_ms    deadline flush: the oldest queued request never waits
                    longer than this before its batch is launched
    k, cr           top-k size and routed-clusters fanout of every answer
    backend         engine backend for flushes (any of engine.BACKENDS,
                    e.g. "pallas-cm" to force cluster-major batched
                    execution; None → the engine's own pick — an auto
                    engine then chooses query- vs cluster-major per
                    micro-batch from its dedup factor, DESIGN.md §10)
    cache_size      exact-tier LRU entries
    near_cells      near-duplicate tier grid resolution per axis
                    (0 disables the tier — the default: it approximates)
    near_cache_size near-tier LRU entries
    delta_threshold compaction trigger: fold the delta into the base
                    once ``delta_rows + tombstones`` reaches this.
                    0 disables the delta path entirely — every write
                    eagerly rebuilds buffers (O(index), the legacy
                    behavior and the churn-bench baseline)
    max_imbalance   optional second trigger: compact when the LIVE
                    per-cluster sizes' imbalance factor
                    (cluster_metrics.imbalance_factor; uniform = 1.0)
                    exceeds this bound. 0 disables (the default —
                    the check is O(index) per write batch)
    spill           §4.3 spill hops for insert routing (both the delta
                    compaction fold and the eager path)

    Resilience knobs (DESIGN.md §14):

    wal_dir         directory for the write-ahead log (core/wal.py).
                    None (default) disables durability: acknowledged
                    writes in the delta segment die with the process.
                    Set → every insert/delete batch is logged BEFORE
                    its publish; ``checkpoint()`` truncates the log
    wal_fsync       fsync each WAL append (durable ack; default) vs
                    OS-buffered (lower write latency, bounded loss)
    max_queue       admission bound: a submit arriving with this many
                    requests already pending raises :class:`Overloaded`
                    instead of growing the queue. 0 = unbounded
    request_timeout_ms  per-request deadline: a request still queued
                    when its deadline passes is shed with
                    :class:`DeadlineExceeded` at the next flush instead
                    of riding an already-late batch. 0 = no deadlines
    breaker_threshold   consecutive engine-call failures that trip the
                    circuit breaker onto the bit-identical dense
                    fallback backend (pallas→dense, pallas-cm→dense-cm;
                    no-op when the configured backend is already its
                    own fallback). 0 disables the breaker
    breaker_probe_every successful fallback flushes before the breaker
                    half-opens and the primary backend is probed again
    retry_backoff_ms    base backoff before retrying the halves of a
                    failed multi-request flush (doubles per bisection
                    level, capped at retry_backoff_max_ms)
    retry_backoff_max_ms  backoff cap for the bisection retry path
    retry_jitter    full-jitter fraction on the bisection backoff: each
                    sleep is scaled by a factor drawn uniformly from
                    ``[1 - retry_jitter, 1]`` so co-failing flushes
                    don't retry in lockstep. 0 disables (pure doubling)
    retry_seed      seed of the jitter stream — the backoff sequence is
                    deterministic per server instance (pinnable in tests)
    wal_max_bytes   WAL growth bound (DESIGN.md §15): once the log file
                    exceeds this many bytes after a write, the server
                    schedules :meth:`checkpoint` (compact + save +
                    truncate) into ``snapshot_dir`` off the write path.
                    0 (default) disables; > 0 requires both ``wal_dir``
                    and ``snapshot_dir``
    snapshot_dir    where the auto-checkpoint commits snapshots
    """
    batch_size: int = 64
    max_delay_ms: float = 2.0
    k: int = 10
    cr: int = 1
    backend: Optional[str] = None
    cache_size: int = 8192
    near_cells: int = 0
    near_cache_size: int = 8192
    delta_threshold: int = 1024
    max_imbalance: float = 0.0
    spill: int = 3
    wal_dir: Optional[str] = None
    wal_fsync: bool = True
    max_queue: int = 0
    request_timeout_ms: float = 0.0
    breaker_threshold: int = 3
    breaker_probe_every: int = 8
    retry_backoff_ms: float = 1.0
    retry_backoff_max_ms: float = 50.0
    retry_jitter: float = 0.25
    retry_seed: int = 0
    wal_max_bytes: int = 0
    snapshot_dir: Optional[str] = None


LATENCY_WINDOW = 65536       # sliding window of most-recent request latencies


@dataclasses.dataclass
class ServerStats:
    """Counters + per-request latencies; read via StreamingServer.metrics().

    ``latencies_s`` is a bounded deque (most recent :data:`LATENCY_WINDOW`
    requests) so a long-lived server neither grows without bound nor pays
    an ever-increasing percentile cost in ``metrics()``.
    """
    n_requests: int = 0
    exact_hits: int = 0
    near_hits: int = 0
    coalesced: int = 0
    engine_batches: int = 0
    engine_queries: int = 0            # real (unpadded) rows sent on-device
    flushes: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"size": 0, "deadline": 0, "drain": 0})
    invalidations: int = 0
    writes: int = 0                    # insert/delete batches accepted
    compactions: int = 0
    compaction_triggers: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"size": 0, "imbalance": 0, "manual": 0})
    compile_seconds: Dict[str, float] = dataclasses.field(default_factory=dict)
    latencies_s: "collections.deque" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))
    # resilience counters (DESIGN.md §14)
    shed: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {"expired": 0, "queue_full": 0,
                                 "cancelled": 0})
    flush_retries: int = 0             # bisection levels entered after failure
    poisoned_requests: int = 0         # singletons that failed alone
    breaker_trips: int = 0
    breaker_fallback_flushes: int = 0  # engine calls served by the fallback
    slow_flushes: int = 0              # StragglerMonitor anomalies
    last_slow_flush_at: Optional[float] = None   # unix seconds
    wal_appends: int = 0
    recovered_writes: int = 0          # WAL records applied by replay_wal
    wal_checkpoints: int = 0           # auto-checkpoints (wal_max_bytes)
    # shard fault tolerance (DESIGN.md §15)
    degraded_flushes: int = 0          # flushes served at coverage < 1.0
    last_coverage: float = 1.0         # of the most recent flush
    min_coverage: Optional[float] = None
    shard_recoveries: int = 0


def latency_percentiles(latencies_s: Sequence[float]) -> Dict[str, float]:
    """→ {"p50", "p95", "p99", "mean"} in milliseconds (0.0 when empty)."""
    if not len(latencies_s):
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
    ms = np.asarray(latencies_s, np.float64) * 1e3
    return {"p50": float(np.percentile(ms, 50)),
            "p95": float(np.percentile(ms, 95)),
            "p99": float(np.percentile(ms, 99)),
            "mean": float(ms.mean())}


def zipf_sample(rng, n_unique: int, size: int, *, a: float = 1.05):
    """Rank-frequency Zipf draw over ``[0, n_unique)`` — the standard model
    of query-log skew (WISK): p(rank r) ∝ 1/r^a. ``a <= 0`` → uniform."""
    if a <= 0:
        return rng.integers(0, n_unique, size=size)
    p = 1.0 / np.arange(1, n_unique + 1, dtype=np.float64) ** a
    return rng.choice(n_unique, size=size, p=p / p.sum())


# ---------------------------------------------------------------------------
# LRU cache (both tiers)
# ---------------------------------------------------------------------------


class LRUCache:
    """Plain ordered-dict LRU; get() refreshes recency, put() evicts oldest."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._d: "collections.OrderedDict" = collections.OrderedDict()

    def get(self, key):
        if key not in self._d:
            return None
        self._d.move_to_end(key)
        return self._d[key]

    def put(self, key, value):
        if self.capacity <= 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)

    def clear(self):
        self._d.clear()

    def __len__(self):
        return len(self._d)


def exact_key(tokens: np.ndarray, mask: np.ndarray, loc: np.ndarray,
              k: int, cr: int, fsig=None) -> tuple:
    """Full-request cache key: every byte of the request participates.
    ``fsig`` (``filters.filter_signature``) is the tenant-isolation
    component (DESIGN.md §13): two requests differing only in their
    filter can never share a cached answer."""
    return (k, cr, fsig, tokens.tobytes(), mask.tobytes(), loc.tobytes())


def near_key(tokens: np.ndarray, mask: np.ndarray, loc: np.ndarray,
             k: int, cr: int, cells: int, fsig=None) -> tuple:
    """Near-duplicate key: keyword signature (sorted unique token ids) +
    spatial cell (loc quantized to a cells×cells grid over the unit box)
    + the filter signature (near-duplicates must agree on the predicate
    exactly — proximity never crosses a tenant boundary)."""
    sig = tuple(sorted(set(tokens[mask].tolist())))
    cell = tuple(np.clip((loc * cells).astype(np.int64), 0, cells - 1).tolist())
    return (k, cr, fsig, sig, cell)


# ---------------------------------------------------------------------------
# The streaming server
# ---------------------------------------------------------------------------


class _Pending:
    __slots__ = ("tokens", "mask", "loc", "filt", "ekey", "ikey", "nkey",
                 "future", "t_deadline")

    def __init__(self, tokens, mask, loc, filt, ekey, ikey, nkey, future,
                 t_deadline=None):
        self.tokens, self.mask, self.loc = tokens, mask, loc
        self.filt = filt
        self.ekey, self.ikey = ekey, ikey
        self.nkey, self.future = nkey, future
        self.t_deadline = t_deadline     # perf_counter stamp; None = none


class StreamingServer:
    """Micro-batching, caching, pre-warmed front end for one QueryEngine.

    Single-event-loop usage::

        server = StreamingServer(retriever.engine(),
                                 ServerConfig(batch_size=64, max_delay_ms=2))
        server.warmup()
        ids, scores = await server.submit(tokens_row, mask_row, loc_row)

    ``submit`` answers one request: ``ids (k,)`` global object ids
    (``-1`` past-the-end) and ``scores (k,)`` — the same contract as one
    row of ``QueryEngine.query``. Batch replay without writing the async
    plumbing: :meth:`serve_all`.
    """

    def __init__(self, engine: engine_lib.QueryEngine,
                 config: Optional[ServerConfig] = None):
        self.engine = engine
        self.cfg = config or ServerConfig()
        self.stats = ServerStats()
        self._exact = LRUCache(self.cfg.cache_size)
        self._near = LRUCache(self.cfg.near_cache_size)
        self._inflight: Dict[tuple, asyncio.Future] = {}
        self._pending: List[_Pending] = []
        self._timer: Optional[asyncio.TimerHandle] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._compaction_handle: Optional[asyncio.Handle] = None
        self._checkpoint_handle: Optional[asyncio.Handle] = None
        self._subs = None            # SubscriptionRegistry, created lazily
        if self.cfg.wal_max_bytes > 0 and not (self.cfg.wal_dir
                                               and self.cfg.snapshot_dir):
            raise ValueError(
                "ServerConfig.wal_max_bytes requires wal_dir AND "
                "snapshot_dir (the auto-checkpoint must know where to "
                "commit the snapshot before truncating the log)")
        # seeded jitter stream for the bisection-retry backoff: a fixed
        # retry_seed makes the sleep sequence reproducible under test
        self._backoff_rng = np.random.default_rng(self.cfg.retry_seed)
        # durability (DESIGN.md §14): WAL opened eagerly so a torn tail
        # from a previous crash is truncated before the first append
        self.wal: Optional[wal_lib.WriteAheadLog] = None
        if self.cfg.wal_dir:
            self.wal = wal_lib.WriteAheadLog(
                wal_lib.wal_path(self.cfg.wal_dir),
                fsync=self.cfg.wal_fsync)
        self._replaying = False      # replay_wal must not re-append
        # circuit breaker over the engine backend
        self._breaker_open = False
        self._breaker_failstreak = 0
        self._breaker_successes = 0
        # per-flush wall-time anomaly detection (single-stream reuse of
        # the fleet StragglerMonitor, distributed/resilience.py)
        self._flush_monitor = resilience_lib.StragglerMonitor()

    # --- warm-up manager --------------------------------------------------

    def warmup(self, batch_sizes: Optional[Sequence[int]] = None,
               backends: Optional[Sequence[str]] = None) -> Dict[str, float]:
        """Pre-trace every configured (batch, backend) shape.

        Runs an all-padding batch through the *same* bound plan the flush
        path uses (same ``(k, cr, backend)`` plan key, same batch shape),
        so the jit cache is hot before the first live request. An "auto"
        configuration picks query- vs cluster-major per LIVE batch
        (DESIGN.md §10) — warmup's identical all-padding rows would
        mistrain that pick (they all route to one cluster, so the
        measured dedup is always maximal) — so auto warm-up pre-traces
        BOTH twins explicitly and leaves the choice to real traffic.
        Returns {"backend@batch": seconds} and records it in ``stats``.
        """
        eng = self.engine
        L = eng.cfg.max_len
        for backend in backends or (self.cfg.backend,):
            for b in batch_sizes or (self.cfg.batch_size,):
                targets = [backend]
                if backend == "auto" or (backend is None and eng._auto_cm):
                    base = (engine_lib.resolve_backend("auto")[0]
                            if backend == "auto" else eng.backend)
                    targets = [base]
                    c, cap = eng.snapshot.buffers["emb"].shape[:2]
                    if engine_lib.cluster_major_feasible(b, self.cfg.cr,
                                                         c, cap):
                        targets.append(engine_lib.cluster_major_variant(
                            base, float("inf")))
                tok = np.zeros((b, L), np.int32)
                tok[:, 0] = 1                        # CLS: keep masks non-empty
                msk = tok != 0
                loc = np.zeros((b, 2), np.float32)
                for target in targets:
                    t0 = time.perf_counter()
                    eng.query(tok, msk, loc, k=self.cfg.k, cr=self.cfg.cr,
                              batch=b, backend=target)
                    name = f"{target or eng.backend}@{b}"
                    self.stats.compile_seconds[name] = \
                        time.perf_counter() - t0
        # warmup's degenerate routing is not traffic: don't let its
        # artificial dedup factor leak into metrics()
        eng.last_dedup_factor = None
        return dict(self.stats.compile_seconds)

    # --- the write path (DESIGN.md §8 + §11) ------------------------------

    def _delta_of(self, snap) -> delta_lib.DeltaSegment:
        if snap.delta is not None:
            return snap.delta
        return delta_lib.DeltaSegment.empty(
            int(snap.buffers["emb"].shape[-1]), snap.meta.precision)

    def insert_objects(self, new_emb, new_loc, new_ids, new_attrs=None):
        """Accept a batch of new objects and publish the successor
        snapshot. Returns the snapshot being served after the call.

        O(batch): the rows append to the snapshot's delta segment
        (quantized at its precision tier); queries see them immediately
        via the engine's delta scan. Compaction folds them into their
        §4.3 clusters later (:meth:`_maybe_compact`). With
        ``delta_threshold=0`` the fold happens eagerly instead
        (``index.insert_objects`` — O(index), the legacy path).
        ``new_attrs (n, 3)`` are the rows' filter attributes
        (core/filters.py; None → all-zero).

        After the publish the batch is dispatched ONCE against the
        standing-query roster (:meth:`subscribe`, core/continuous.py):
        matched subscriptions are notified synchronously, tagged with
        the published version — exactly-once across any later hot-swap.

        After a publish the SERVER'S SNAPSHOT is the source of truth for
        the corpus: a ``ListRetriever`` that originally supplied the
        engine still holds the pre-mutation state, so its offline
        oracles (``brute_force``, cluster metrics) describe the old
        corpus until it is rebuilt.

        With ``wal_dir`` set, the batch is durably logged BEFORE the
        publish (WAL-then-publish, DESIGN.md §14): a crash at any point
        after the append is recoverable by :func:`repro.api.recover`,
        so a returned (acknowledged) write is never lost."""
        snap = self.engine.snapshot
        new_emb = np.asarray(new_emb)
        new_loc = np.asarray(new_loc)
        new_ids = np.asarray(new_ids)
        if new_attrs is not None:
            new_attrs = np.asarray(new_attrs)
        self.stats.writes += 1
        self._wal_append("insert", snap, emb=new_emb, loc=new_loc,
                         ids=new_ids,
                         **({"attrs": new_attrs}
                            if new_attrs is not None else {}))
        faults_lib.fire("write.pre_publish", kind="insert")
        if self.cfg.delta_threshold <= 0:
            buf = index_lib.insert_objects(
                snap.buffers, snap.index_params, snap.norm,
                new_emb, new_loc, new_ids, spill=self.cfg.spill,
                new_attrs=new_attrs)
            out = self.publish(snap.with_buffers(buf))
        else:
            delta = self._delta_of(snap).insert(new_emb, new_loc, new_ids,
                                                new_attrs)
            out = self.publish(snap.with_delta(delta))
        faults_lib.fire("write.post_publish", kind="insert")
        if self._subs is not None and len(self._subs):
            self._subs.dispatch(new_emb, new_loc, new_ids, new_attrs,
                                snapshot=out)
        if self.cfg.delta_threshold > 0:
            self._maybe_compact()
        self._maybe_checkpoint()
        return self.engine.snapshot

    def delete_objects(self, del_ids):
        """Delete objects and publish the successor snapshot. Returns
        the snapshot being served after the call.

        O(batch): the ids join the delta's tombstone set (filtering base
        results at query time; delta-resident rows are dropped
        physically). With ``delta_threshold=0``: the legacy eager mask
        (``index.delete_objects`` — O(index)). WAL-then-publish like
        :meth:`insert_objects`."""
        snap = self.engine.snapshot
        del_ids = np.asarray(del_ids)
        self.stats.writes += 1
        self._wal_append("delete", snap, ids=del_ids)
        faults_lib.fire("write.pre_publish", kind="delete")
        if self.cfg.delta_threshold <= 0:
            buf = index_lib.delete_objects(snap.buffers, del_ids)
            out = self.publish(snap.with_buffers(buf))
            faults_lib.fire("write.post_publish", kind="delete")
            self._maybe_checkpoint()
            return out
        delta = self._delta_of(snap).delete(del_ids)
        self.publish(snap.with_delta(delta))
        faults_lib.fire("write.post_publish", kind="delete")
        self._maybe_compact()
        self._maybe_checkpoint()
        return self.engine.snapshot

    def _wal_append(self, kind: str, snap, **arrays):
        """Log one write batch before its publish. The record carries
        the version the publish WILL produce, so recovery can skip
        records whose effects are already inside the snapshot it loaded
        (a crash between snapshot save and WAL truncate double-applies
        nothing). Replay sets ``_replaying`` — replayed writes must not
        re-log themselves."""
        if self.wal is None or self._replaying:
            return
        self.wal.append(kind, version=snap.meta.version + 1, **arrays)
        self.stats.wal_appends += 1

    # --- durability: checkpoint + recovery (DESIGN.md §14) ----------------

    def checkpoint(self, directory: str, *, keep: int = 3) -> str:
        """Make every acknowledged write durable in a committed snapshot,
        then truncate the WAL (its records are now redundant). Sequence:
        compact (fold the delta), ``snapshot.save`` (atomic commit),
        ``wal.truncate``. A crash between save and truncate is safe —
        replay skips records at-or-below the saved version. Returns the
        committed snapshot path."""
        snap = self.compact_now()
        path = snap.save(directory, keep=keep)
        if self.wal is not None:
            self.wal.truncate()
        return path

    def _maybe_checkpoint(self):
        """WAL growth bound (``ServerConfig.wal_max_bytes``): once the
        log exceeds the threshold after a write, run :meth:`checkpoint`
        into ``snapshot_dir`` — scheduled on the next loop tick (like
        compaction) so the save never sits inside a write call's
        latency; inline when no loop is running. Never during
        :meth:`replay_wal`: truncating mid-replay with re-append
        suppressed would drop the records not yet applied."""
        if (self.wal is None or self.cfg.wal_max_bytes <= 0
                or self._replaying
                or self.wal.nbytes() <= self.cfg.wal_max_bytes):
            return
        try:
            loop = asyncio.get_running_loop()
        except RuntimeError:
            loop = None
        if loop is None:
            self._auto_checkpoint()
        elif self._checkpoint_handle is None:
            self._checkpoint_handle = loop.call_soon(self._checkpoint_cb)

    def _checkpoint_cb(self):
        self._checkpoint_handle = None
        self._auto_checkpoint()

    def _auto_checkpoint(self):
        if (self.wal is None
                or self.wal.nbytes() <= self.cfg.wal_max_bytes):
            return               # a queued trigger may already be stale
        self.checkpoint(self.cfg.snapshot_dir)
        self.stats.wal_checkpoints += 1

    def replay_wal(self) -> int:
        """Re-apply logged writes missing from the current snapshot:
        every WAL record with ``version > snapshot.meta.version`` runs
        back through the normal write path (same delta append, same
        compaction triggers — so the recovered index is bit-identical
        to one that never crashed), without re-logging. Returns the
        number of records applied."""
        if self.wal is None:
            return 0
        base = self.engine.snapshot.meta.version
        applied = 0
        self._replaying = True
        try:
            for rec in self.wal.records():
                if rec["version"] <= base:
                    continue
                if rec["kind"] == "insert":
                    self.insert_objects(rec["emb"], rec["loc"], rec["ids"],
                                        rec.get("attrs"))
                else:
                    self.delete_objects(rec["ids"])
                applied += 1
        finally:
            self._replaying = False
        self.stats.recovered_writes += applied
        return applied

    def close(self):
        """Release the WAL file handle (tests / clean shutdown)."""
        if self.wal is not None:
            self.wal.close()

    def _maybe_compact(self):
        """Check the compaction triggers; fold now (no running event
        loop) or on the next loop tick (between flushes, so a compaction
        never sits inside a write call's latency or splits a batch)."""
        snap = self.engine.snapshot
        delta = snap.delta
        if delta is None or delta.is_empty:
            return
        trigger = None
        if delta.n_rows + delta.n_tombstones >= self.cfg.delta_threshold:
            trigger = "size"
        elif self.cfg.max_imbalance > 0:
            counts = delta_lib.live_counts(snap.buffers, delta)
            if cm.imbalance_factor_from_counts(counts) > self.cfg.max_imbalance:
                trigger = "imbalance"
        if trigger is None:
            return
        try:
            loop = asyncio.get_running_loop()
        except RuntimeError:
            loop = None
        if loop is None:
            self._compact(trigger)
        elif self._compaction_handle is None:
            self._compaction_handle = loop.call_soon(self._compact_cb,
                                                     trigger)

    def _compact_cb(self, trigger: str):
        self._compaction_handle = None
        self._compact(trigger)

    def _compact(self, trigger: str):
        """Fold the current delta into the base and publish — atomic
        like any publish; the pre-compaction snapshot keeps serving any
        flush that already pinned it."""
        snap = self.engine.snapshot
        if snap.delta is None or snap.delta.is_empty:
            return
        self.publish(snap.compact(spill=self.cfg.spill))
        self.stats.compactions += 1
        self.stats.compaction_triggers[trigger] = \
            self.stats.compaction_triggers.get(trigger, 0) + 1

    def compact_now(self):
        """Force a synchronous compaction (drain loops, shutdown,
        pre-save). Returns the snapshot being served after the call."""
        self._compact("manual")
        return self.engine.snapshot

    def publish(self, snapshot):
        """Atomically publish ``snapshot``: swap the engine's reference
        (digest-checked) and drop every cached result, in ONE event-loop
        step — a pre-publish answer is never served post-publish. The
        queue is untouched: pending requests flush *after* the publish
        and score the new snapshot; a flush that already started pinned
        the old snapshot and finishes on it (no torn reads). Returns the
        published snapshot."""
        self.engine.publish(snapshot)
        self.invalidate_cache()
        if self._subs is not None:
            self._subs.on_publish(snapshot)
        return snapshot

    # --- continuous queries (DESIGN.md §13, core/continuous.py) -----------

    @property
    def subscriptions(self):
        """The lazily created standing-query registry."""
        if self._subs is None:
            from repro.core import continuous as continuous_lib
            self._subs = continuous_lib.SubscriptionRegistry(
                self.engine, cr=self.cfg.cr)
        return self._subs

    def subscribe(self, tokens, mask, loc, *, filters=None,
                  threshold: float = 0.0):
        """Register a standing query → :class:`~repro.core.continuous.
        Subscription` (an async iterator of notifications). Every
        subsequent :meth:`insert_objects` batch is matched against it:
        assigned cluster ∈ its routes, filter predicate, ST ≥
        ``threshold``. Survives snapshot hot-swaps; :meth:`unsubscribe`
        (or ``sub.close()``) ends the stream."""
        return self.subscriptions.register(tokens, mask, loc,
                                           filters=filters,
                                           threshold=threshold)

    def unsubscribe(self, sub_id: int):
        if self._subs is not None:
            self._subs.unregister(sub_id)

    def invalidate_cache(self):
        self._exact.clear()
        self._near.clear()
        self.stats.invalidations += 1

    # --- the micro-batcher ------------------------------------------------

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def _adopt_loop(self, loop):
        """Bind the batcher state to ``loop``. Timer handles, pending
        entries, and in-flight futures are per-event-loop objects: if a
        previous ``asyncio.run`` was aborted mid-batch (engine error,
        cancellation), its leftovers would poison a fresh loop — a timer
        that never re-arms, flushes resolving futures of a closed loop,
        duplicates coalescing onto dead futures. On loop change, drop
        them (their awaiters are gone with the old loop)."""
        if self._loop is not loop:
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None
            if self._compaction_handle is not None:
                self._compaction_handle.cancel()
                self._compaction_handle = None
            if self._checkpoint_handle is not None:
                self._checkpoint_handle.cancel()
                self._checkpoint_handle = None
            self._pending.clear()
            self._inflight.clear()
            self._loop = loop

    async def submit(self, tokens, mask, loc, *, filters=None,
                     t_arrival=None):
        """Answer one spatial-keyword request: → (ids (k,), scores (k,)).

        Cache hits return immediately; misses wait for the size- or
        deadline-triggered flush of the current micro-batch. The
        returned arrays are read-only (shared with the result cache);
        ``.copy()`` before mutating.

        ``filters`` is an optional per-request
        :class:`~repro.core.filters.FilterSpec` (DESIGN.md §13). Its
        signature joins every cache and coalescing key, so requests
        with different predicates — different tenants above all — never
        share an answer; a no-op spec keys identically to no filter.

        ``t_arrival`` (a ``time.perf_counter()`` stamp) backdates the
        latency measurement to the request's intended arrival time —
        open-loop load generators pass it so queueing backlog under
        overload is counted instead of omitted.
        """
        tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
        mask = np.ascontiguousarray(np.asarray(mask, bool))
        loc = np.ascontiguousarray(np.asarray(loc, np.float32))
        if filters is not None and not isinstance(filters,
                                                  filters_lib.FilterSpec):
            raise TypeError(f"filters must be a FilterSpec or None, "
                            f"got {type(filters)}")
        fsig = filters_lib.filter_signature(filters)
        t0 = time.perf_counter() if t_arrival is None else t_arrival
        self._adopt_loop(asyncio.get_running_loop())
        self.stats.n_requests += 1
        k, cr = self.cfg.k, self.cfg.cr

        # cache lookups are keyed on the CURRENT snapshot version: a hit
        # can only come from an answer computed against this exact index
        # generation (publish also clears, so this is belt and braces).
        # The down-shard signature (DESIGN.md §15) joins every key: a
        # degraded answer is cached under the shard set it was computed
        # WITHOUT, so it can never serve a full-coverage request (or a
        # differently-degraded one) — and recovery needs no invalidation
        ver = self.engine.snapshot.meta.version
        dsig = self.engine.down_signature()
        ekey = exact_key(tokens, mask, loc, k, cr, fsig)
        hit = self._exact.get((ver, dsig, ekey))
        if hit is not None:
            self.stats.exact_hits += 1
            self.stats.latencies_s.append(time.perf_counter() - t0)
            return hit
        nkey = None
        if self.cfg.near_cells > 0:
            nkey = near_key(tokens, mask, loc, k, cr, self.cfg.near_cells,
                            fsig)
            hit = self._near.get((ver, dsig, nkey))
            if hit is not None:
                self.stats.near_hits += 1
                self.stats.latencies_s.append(time.perf_counter() - t0)
                return hit

        # the in-flight key embeds the snapshot version + down-shard
        # signature, like the result caches: a request arriving just
        # after a publish (or a shard state change) must NOT coalesce
        # onto a stale flush's future
        ikey = (ver, dsig, ekey)
        inflight = self._inflight.get(ikey)
        if inflight is not None:                 # identical request queued:
            self.stats.coalesced += 1            # share its future, don't
            res = await inflight                 # spend a second batch slot
            self.stats.latencies_s.append(time.perf_counter() - t0)
            return res

        # graceful degradation (DESIGN.md §14): shed at the door rather
        # than queue without bound. Cache/coalesce hits above stay free
        # — shedding only applies to work that would claim a batch slot.
        if 0 < self.cfg.max_queue <= len(self._pending):
            self.stats.shed["queue_full"] += 1
            raise Overloaded(
                f"admission queue full ({len(self._pending)} pending >= "
                f"max_queue={self.cfg.max_queue}); retry with backoff")
        t_deadline = None
        if self.cfg.request_timeout_ms > 0:
            t_deadline = t0 + self.cfg.request_timeout_ms / 1e3
            if time.perf_counter() > t_deadline:
                # open-loop backlog: the intended arrival is already
                # past its deadline — shed now, don't occupy a slot
                self.stats.shed["expired"] += 1
                raise DeadlineExceeded(
                    f"request expired before enqueue (deadline "
                    f"{self.cfg.request_timeout_ms}ms)")

        loop = asyncio.get_running_loop()
        fut: asyncio.Future = loop.create_future()
        self._inflight[ikey] = fut
        self._pending.append(_Pending(tokens, mask, loc, filters, ekey,
                                      ikey, nkey, fut, t_deadline))
        if len(self._pending) >= self.cfg.batch_size:
            self._flush("size")
        elif self._timer is None:
            self._timer = loop.call_later(self.cfg.max_delay_ms / 1e3,
                                          self._flush, "deadline")
        res = await fut
        self.stats.latencies_s.append(time.perf_counter() - t0)
        return res

    def flush_now(self):
        """Force-flush the queue (used by drain loops and shutdown)."""
        self._flush("drain")

    def _flush(self, reason: str):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        # shed BEFORE the engine call (DESIGN.md §14): a request whose
        # deadline passed while queued gets a fast DeadlineExceeded, not
        # a seat on an already-late batch; cancelled waiters (their
        # submit was cancelled/abandoned) free their slots the same way
        now = time.perf_counter()
        live = []
        for p in pending:
            if p.future.done():
                self._inflight.pop(p.ikey, None)
                self.stats.shed["cancelled"] += 1
            elif p.t_deadline is not None and now > p.t_deadline:
                self._inflight.pop(p.ikey, None)
                self.stats.shed["expired"] += 1
                p.future.set_exception(DeadlineExceeded(
                    f"request shed at flush: waited past its "
                    f"{self.cfg.request_timeout_ms}ms deadline"))
            else:
                live.append(p)
        if not live:
            return
        self._flush_group(live, reason, 0)

    def _flush_group(self, pending: List[_Pending], reason: str,
                     depth: int):
        """Score one group of requests; on failure, isolate the poison.

        A healthy group resolves every future. A failed singleton fails
        ALONE — its exception reaches only its own future (the §14 fix
        for the batch-poisoning bug where one request's error was set on
        every co-batched future). A failed multi-request group backs off
        (bounded, doubling per bisection level) and retries as two
        halves, so co-batched healthy requests still resolve and a
        transient engine error costs retries, not a dropped batch."""
        tok = np.stack([p.tokens for p in pending])
        msk = np.stack([p.mask for p in pending])
        loc = np.stack([p.loc for p in pending])
        # per-row filters: a mixed-tenant micro-batch compiles to ONE
        # filtered plan (sentinel no-op rows, core/filters.py); an
        # all-unfiltered batch collapses to the unfiltered program
        filts = ([p.filt for p in pending]
                 if any(p.filt is not None for p in pending) else None)
        # pin the snapshot for the WHOLE flush: every row of this batch
        # scores one consistent index generation even if a publish lands
        # while the engine call is executing, and the results are cached
        # under the version actually served
        snap = self.engine.snapshot
        try:
            ids, scores = self._engine_call(tok, msk, loc, filts, snap)
        except Exception as e:                   # noqa: BLE001
            if len(pending) == 1:
                p = pending[0]
                self._inflight.pop(p.ikey, None)
                self.stats.poisoned_requests += 1
                if not p.future.done():
                    p.future.set_exception(e)
                return
            # bounded backoff, then bisect: a transient failure clears
            # on the retry; a poisoned request is cornered in O(log b)
            # levels while every healthy sibling still gets its answer.
            # time.sleep is deliberate — the engine call itself blocks
            # the loop far longer, and backoff must also apply to the
            # sync serve_all path.
            self.stats.flush_retries += 1
            backoff = self._backoff_ms(depth)
            if backoff > 0:
                time.sleep(backoff / 1e3)
            mid = len(pending) // 2
            self._flush_group(pending[:mid], reason, depth + 1)
            self._flush_group(pending[mid:], reason, depth + 1)
            return
        if depth == 0:
            self.stats.flushes[reason] += 1
        self.stats.engine_batches += 1
        self.stats.engine_queries += len(pending)
        ver = snap.meta.version
        # coverage annotation (DESIGN.md §15): results computed while a
        # shard was DOWN are cached under the shard set actually MISSING
        # from the answer — not the one seen at submit time — so a
        # degraded result can only ever be re-served to requests
        # degraded the same way
        coverage = self.engine.last_coverage
        dsig_served = self.engine.last_down_shards
        self.stats.last_coverage = coverage
        if (self.stats.min_coverage is None
                or coverage < self.stats.min_coverage):
            self.stats.min_coverage = coverage
        if coverage < 1.0:
            self.stats.degraded_flushes += 1
        for i, p in enumerate(pending):
            res = (ids[i].copy(), scores[i].copy())
            for arr in res:              # shared with the cache + every
                arr.setflags(write=False)  # waiter: freeze, don't trust
            self._exact.put((ver, dsig_served, p.ekey), res)
            if p.nkey is not None:
                self._near.put((ver, dsig_served, p.nkey), res)
            self._inflight.pop(p.ikey, None)
            if not p.future.done():
                p.future.set_result(res)

    def _backoff_ms(self, depth: int) -> float:
        """One bisection-retry sleep: doubling in ``depth``, capped at
        ``retry_backoff_max_ms``, scaled by a seeded full-jitter factor
        in ``[1 - retry_jitter, 1]`` so co-failing flush groups spread
        out instead of retrying in lockstep (deterministic for a fixed
        ``retry_seed`` — tests pin the exact sequence)."""
        base = min(self.cfg.retry_backoff_ms * (2 ** depth),
                   self.cfg.retry_backoff_max_ms)
        jitter = self.cfg.retry_jitter
        if base <= 0 or jitter <= 0:
            return base
        return base * (1.0 - jitter * float(self._backoff_rng.random()))

    # --- shard fault tolerance (DESIGN.md §15) ----------------------------

    def recover_shard(self, s: int):
        """Online shard recovery: re-materialize a DOWN shard's device
        part from the snapshot's global host buffers and flip it back UP
        (:meth:`QueryEngine.recover_shard`) — under live traffic, no
        version bump, no drained queue. Cached results need no
        invalidation: degraded answers are keyed by their down-shard
        signature, so post-recovery full-coverage requests can never hit
        them. The ``SubscriptionRegistry`` dispatch path is untouched
        (recovery publishes no content change → no notifications), so
        exactly-once delivery holds across a fail/recover cycle. Returns
        the snapshot being served after the call."""
        snap = self.engine.recover_shard(s)
        self.stats.shard_recoveries += 1
        return snap

    # --- degraded execution: breaker + anomaly detection ------------------

    def _fallback_backend(self) -> Optional[str]:
        """The bit-identical oracle the breaker degrades onto: pallas →
        dense (query-major or cluster-major preserved). None when the
        configured backend IS its own fallback (nothing to degrade to)."""
        primary = self.cfg.backend or self.engine.backend
        fallback = {"pallas": "dense", "pallas-cm": "dense-cm",
                    "auto": "dense"}.get(primary)
        return fallback

    def _engine_call(self, tok, msk, loc, filts, snap):
        """One engine call wearing the resilience instrumentation:
        fault points (chaos tier), the circuit breaker (repeated
        primary-backend failures route to the dense fallback until a
        probe succeeds — parity-certified, so results stay
        bit-identical), and per-flush wall-time anomaly detection."""
        backend = self.cfg.backend
        fallback = self._fallback_backend()
        if self._breaker_open and fallback is not None:
            backend = fallback
        t0 = time.perf_counter()
        try:
            faults_lib.fire("flush.slow")        # callback sleeps
            faults_lib.fire("flush.engine")      # armed → raises in-place
            out = self.engine.query(
                tok, msk, loc, k=self.cfg.k, cr=self.cfg.cr,
                batch=self.cfg.batch_size, backend=backend,
                snapshot=snap, filters=filts)
        except Exception:
            self._breaker_failstreak += 1
            if (not self._breaker_open and fallback is not None
                    and self.cfg.breaker_threshold > 0
                    and self._breaker_failstreak
                    >= self.cfg.breaker_threshold):
                self._breaker_open = True
                self._breaker_successes = 0
                self.stats.breaker_trips += 1
            raise
        dt = time.perf_counter() - t0
        self._flush_monitor.record("flush", dt)
        if self._flush_monitor.slow("flush"):
            self.stats.slow_flushes += 1
            self.stats.last_slow_flush_at = time.time()
        self._breaker_failstreak = 0
        if self._breaker_open:
            self.stats.breaker_fallback_flushes += 1
            self._breaker_successes += 1
            if self._breaker_successes >= self.cfg.breaker_probe_every:
                # half-open probe: route the next flush back through the
                # primary; if it still fails, the streak re-trips
                self._breaker_open = False
        return out

    # --- batch replay convenience ----------------------------------------

    async def _drain(self, tasks):
        """Resolve every submitted task: one loop tick lets each queued
        submit run to its enqueue point (ready callbacks are FIFO, so
        all of them go before we resume), one forced flush drains the
        trailing partial batch, and the deadline timer backstops any
        straggler — no busy-spinning over the task list."""
        await asyncio.sleep(0)
        self.flush_now()
        return await asyncio.gather(*tasks)

    async def submit_all(self, tokens, mask, locs):
        """Submit every row of (n, L)/(n, L)/(n, 2), drain, and return
        stacked (ids (n, k), scores (n, k)). Requests enqueue in row
        order, so flush boundaries land exactly where a direct
        ``engine.run_batched`` call would put its chunk boundaries."""
        tasks = [asyncio.ensure_future(self.submit(tokens[i], mask[i],
                                                   locs[i]))
                 for i in range(len(tokens))]
        out = await self._drain(tasks)
        return (np.stack([o[0] for o in out]),
                np.stack([o[1] for o in out]))

    def serve_all(self, tokens, mask, locs):
        """Synchronous wrapper around :meth:`submit_all` (owns the loop)."""
        return asyncio.run(self.submit_all(tokens, mask, locs))

    # --- reporting --------------------------------------------------------

    def metrics(self, wall_seconds: Optional[float] = None) -> dict:
        """One flat dict for drivers/benchmarks: hit rates, batch fill,
        latency percentiles (ms), flush/invalidation counters, compile
        seconds, the engine's last measured route-dedup factor (the
        cluster-major auto signal, DESIGN.md §10), and QPS when
        ``wall_seconds`` is given."""
        s = self.stats
        n = max(s.n_requests, 1)
        filled = s.engine_batches * self.cfg.batch_size
        out = {
            "requests": s.n_requests,
            # split cache economics (DESIGN.md §7): raw counts beside the
            # rates, so drivers can report exact-LRU vs near-duplicate
            # traffic without multiplying rates back up
            "exact_hits": s.exact_hits,
            "near_hits": s.near_hits,
            "exact_hit_rate": s.exact_hits / n,
            "near_hit_rate": s.near_hits / n,
            "hit_rate": (s.exact_hits + s.near_hits) / n,
            "coalesced": s.coalesced,
            "engine_batches": s.engine_batches,
            "engine_queries": s.engine_queries,
            "batch_fill": s.engine_queries / filled if filled else 0.0,
            "latency_ms": latency_percentiles(s.latencies_s),
            "flushes": dict(s.flushes),
            "invalidations": s.invalidations,
            "compile_seconds": dict(s.compile_seconds),
            "dedup_factor": self.engine.last_dedup_factor,
            "writes": s.writes,
            "delta_rows": self.engine.snapshot.meta.delta_rows,
            "tombstones": self.engine.snapshot.meta.n_tombstones,
            "compactions": s.compactions,
            "compaction_triggers": dict(s.compaction_triggers),
            # resilience block (DESIGN.md §14)
            "shed": dict(s.shed),
            "flush_retries": s.flush_retries,
            "poisoned_requests": s.poisoned_requests,
            "breaker": {"open": self._breaker_open,
                        "trips": s.breaker_trips,
                        "fallback_flushes": s.breaker_fallback_flushes},
            "slow_flushes": s.slow_flushes,
            "last_slow_flush_at": s.last_slow_flush_at,
            "wal": {"enabled": self.wal is not None,
                    "appends": s.wal_appends,
                    "records": self.wal.n_records if self.wal else 0,
                    "bytes": self.wal.nbytes() if self.wal else 0,
                    "max_bytes": self.cfg.wal_max_bytes,
                    "auto_checkpoints": s.wal_checkpoints},
            "recovered_writes": s.recovered_writes,
            # degraded partial-result serving (DESIGN.md §15)
            "coverage": {"last": s.last_coverage,
                         "min": s.min_coverage,
                         "degraded_flushes": s.degraded_flushes},
        }
        if self._subs is not None:
            # standing-query dispatch economics (core/continuous.py):
            # distinct_clusters_per_dispatch is the O(·) the reversed
            # cluster-major plan promises per insert batch
            out["subscriptions"] = self._subs.metrics()
        snap = self.engine.snapshot
        out["n_shards"] = snap.meta.n_shards
        if snap.shards is not None:
            # mesh-sharded serving (DESIGN.md §12): resident bytes per
            # device — the number that should shrink ~linearly with the
            # shard count at unchanged recall (bench_scalability.py)
            out["shard_bytes_per_device"] = snap.shards.nbytes_per_device()
            # shard fault tolerance (DESIGN.md §15): the health state
            # machine + hedge/retry/recovery counters
            health = self.engine._shard_health
            out["shard_health"] = (health.snapshot()
                                   if health is not None else None)
            out["shard_stats"] = dict(self.engine.shard_stats)
            out["shard_recoveries"] = s.shard_recoveries
        if wall_seconds is not None and wall_seconds > 0:
            out["qps"] = s.n_requests / wall_seconds
        return out


# ---------------------------------------------------------------------------
# Load generation (drivers + benchmarks)
# ---------------------------------------------------------------------------


async def open_loop(server: StreamingServer, requests, *, qps: float,
                    shed_ok: bool = False):
    """Fixed-rate arrivals: one submit every 1/qps seconds regardless of
    completions. Each submit is stamped with its INTENDED arrival time,
    so when the engine can't keep up the backlog shows up as queueing
    latency instead of being coordinated-omitted from the percentiles.
    ``requests`` is a sequence of (tokens, mask, loc) rows.

    ``shed_ok=True`` is the overload-bench mode: a request the server
    sheds (:class:`Overloaded` / :class:`DeadlineExceeded`) yields
    ``None`` in the result list instead of aborting the run — shedding
    under 2× load is the designed behavior being measured, and the
    server's ``shed`` counters account for every one."""

    async def one(tok, msk, loc, arrival):
        try:
            return await server.submit(tok, msk, loc, t_arrival=arrival)
        except (Overloaded, DeadlineExceeded):
            if not shed_ok:
                raise
            return None

    interval = 1.0 / qps
    t_start = time.perf_counter()
    tasks = []
    for i, (tok, msk, loc) in enumerate(requests):
        arrival = t_start + i * interval
        delay = arrival - time.perf_counter()
        if delay > 0:
            await asyncio.sleep(delay)
        tasks.append(asyncio.ensure_future(one(tok, msk, loc, arrival)))
    return await server._drain(tasks)


async def closed_loop(server: StreamingServer, requests, *,
                      concurrency: int):
    """Fixed-concurrency workers: each keeps exactly one request
    outstanding, pulling the next from a shared iterator on completion."""
    results = [None] * len(requests)
    it = iter(range(len(requests)))

    async def worker():
        for i in it:
            tok, msk, loc = requests[i]
            results[i] = await server.submit(tok, msk, loc)

    await asyncio.gather(*[worker()
                           for _ in range(min(concurrency, len(requests)))])
    return results
