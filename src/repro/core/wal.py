"""Write-ahead log for the serving stack's mutation path (DESIGN.md §14).

The LSM write path (DESIGN.md §11) makes writes O(batch) by keeping them
in an in-memory delta segment until compaction — which means every
acknowledged insert/delete since the last ``snapshot.save`` lives only
in process memory. A :class:`WriteAheadLog` closes that durability hole:
``StreamingServer.insert_objects`` / ``delete_objects`` append one
checksummed record *before* publishing the successor snapshot, so after
a crash ``recover()`` = load the last good snapshot + replay the intact
WAL suffix, and no acknowledged write is ever lost.

On-disk format — one append-only file::

    [8-byte magic "LISTWAL1"]
    record*:  [u32 payload length][u32 crc32(payload)][payload]

The payload is a self-contained ``.npz`` blob (numpy's own container —
any tool can inspect it) holding the op kind (``insert`` | ``delete``),
the post-write snapshot ``version`` the record produced, and the op's
arrays. Properties:

* **torn tails are detected, never propagated**: a crash mid-append
  leaves a record whose length/crc don't match; :meth:`records` stops at
  the first bad record and reports the good prefix. Re-opening for
  append truncates the torn tail so new records extend the good prefix.
* **append is atomic-enough**: length+crc are written with the payload
  in one buffered write and (optionally, default on) fsync'd, so an
  acknowledged write is on disk before the publish makes it visible.
* **replay is idempotent w.r.t. snapshots**: each record carries the
  snapshot version its publish produced; recovery replays only records
  with ``version > loaded_snapshot.meta.version``, so a crash between
  ``snapshot.save`` and :meth:`truncate` double-applies nothing.
* :meth:`truncate` (called by ``StreamingServer.checkpoint`` after a
  successful compact+save) atomically replaces the log with an empty
  one via temp-file + ``os.replace``.

The ``wal.torn_tail`` fault point (core/faults.py) lets the chaos tier
inject a mid-append crash: the injection returns how many bytes of the
record reach the disk, the append writes exactly that prefix, and a
:class:`~repro.core.faults.Crash` tears out — precisely the state a real
power cut leaves behind.
"""
from __future__ import annotations

import io
import os
import struct
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import faults as faults_lib

MAGIC = b"LISTWAL1"
_HEADER = struct.Struct("<II")           # payload length, crc32(payload)

KINDS = ("insert", "delete")


class WalCorrupt(ValueError):
    """The log's magic header is wrong — this is not (or no longer) a
    LIST WAL. Torn/garbage *records* are NOT an error: they are the
    expected crash artifact and are silently dropped at the tail."""


def encode_record(kind: str, version: int, arrays: Dict[str, np.ndarray]
                  ) -> bytes:
    """One op → a self-contained npz payload."""
    if kind not in KINDS:
        raise ValueError(f"kind must be one of {KINDS}, got {kind!r}")
    bio = io.BytesIO()
    np.savez(bio, kind=np.array(kind), version=np.array(int(version)),
             **{k: np.asarray(v) for k, v in arrays.items()})
    return bio.getvalue()


def decode_record(payload: bytes) -> dict:
    with np.load(io.BytesIO(payload)) as z:
        out = {k: z[k] for k in z.files}
    out["kind"] = str(out["kind"])
    out["version"] = int(out["version"])
    return out


def _scan(path: str) -> Tuple[List[dict], int, bool]:
    """Parse the log → (good records, byte offset of the good prefix's
    end, torn-tail flag). Stops at the first short/corrupt record."""
    with open(path, "rb") as f:
        magic = f.read(len(MAGIC))
        if magic != MAGIC:
            raise WalCorrupt(f"{path}: bad magic {magic!r} — not a LIST "
                             f"write-ahead log")
        records: List[dict] = []
        good_end = f.tell()
        torn = False
        while True:
            header = f.read(_HEADER.size)
            if len(header) == 0:
                break
            if len(header) < _HEADER.size:
                torn = True
                break
            length, crc = _HEADER.unpack(header)
            payload = f.read(length)
            if len(payload) < length or zlib.crc32(payload) != crc:
                torn = True
                break
            try:
                records.append(decode_record(payload))
            except Exception:                      # noqa: BLE001
                torn = True                        # crc collision / garbage
                break
            good_end = f.tell()
        return records, good_end, torn


class WriteAheadLog:
    """Append-only, checksummed durability log for serving writes.

    ``fsync=True`` (default) makes every acknowledged write durable at
    the cost of one fsync per write batch — the LIST write path batches,
    so this amortizes exactly like the engine call does. ``fsync=False``
    trades the tail of writes since the last OS flush for latency
    (still crash-consistent: the checksums bound what replay trusts).
    """

    def __init__(self, path: str, *, fsync: bool = True):
        self.path = path
        self.fsync = bool(fsync)
        self.dropped_tail = False      # a previous crash left a torn record
        self._n_records = 0
        self._last_version = 0
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        if os.path.exists(path):
            records, good_end, torn = _scan(path)
            self.dropped_tail = torn
            self._n_records = len(records)
            if records:
                self._last_version = max(r["version"] for r in records)
            self._f = open(path, "r+b")
            # new appends must extend the GOOD prefix, not a torn record
            self._f.truncate(good_end)
            self._f.seek(good_end)
        else:
            self._f = open(path, "w+b")
            self._f.write(MAGIC)
            self._flush()

    # -- inspection ---------------------------------------------------------

    @property
    def n_records(self) -> int:
        return self._n_records

    @property
    def last_version(self) -> int:
        """Highest snapshot version any record in the log produced."""
        return self._last_version

    def nbytes(self) -> int:
        return self._f.tell()

    def records(self) -> List[dict]:
        """Re-read the good prefix from disk (what replay would see)."""
        self._f.flush()
        records, _, _ = _scan(self.path)
        return records

    # -- the write path -----------------------------------------------------

    def append(self, kind: str, *, version: int,
               **arrays) -> int:
        """Durably append one op record; returns the record count after.

        MUST be called before the corresponding snapshot publish: the
        contract is WAL-then-publish, so an acknowledged write is always
        either on disk or not yet visible."""
        payload = encode_record(kind, version, arrays)
        blob = _HEADER.pack(len(payload), zlib.crc32(payload)) + payload
        torn_at = faults_lib.fire("wal.torn_tail", nbytes=len(blob),
                                  path=self.path)
        if torn_at is not None:
            # simulated crash mid-append: exactly torn_at bytes reach
            # the disk, then the process "dies"
            self._f.write(blob[:int(torn_at)])
            self._flush()
            raise faults_lib.Crash(
                f"simulated crash mid-WAL-append ({int(torn_at)}/"
                f"{len(blob)} bytes reached {self.path})")
        self._f.write(blob)
        self._flush()
        self._n_records += 1
        self._last_version = max(self._last_version, int(version))
        return self._n_records

    def _flush(self):
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    # -- lifecycle ----------------------------------------------------------

    def truncate(self) -> None:
        """Drop every record — the log's writes are now durable in a
        committed snapshot (compact + save happened). Atomic: a fresh
        empty log is built beside and ``os.replace``d over the old one,
        so a crash mid-truncate leaves either the full old log (replay
        skips it by version) or the empty new one — never a torn file."""
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(MAGIC)
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        self._f = open(self.path, "r+b")
        self._f.seek(0, os.SEEK_END)
        self._n_records = 0
        self._last_version = 0
        self.dropped_tail = False

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def replay(path: str) -> Iterator[dict]:
    """Read-only replay of a log file's good prefix (no lock, no append
    handle): yields decoded records in append order. Missing file →
    empty iterator, matching 'nothing to recover'."""
    if not os.path.exists(path):
        return iter(())
    records, _, _ = _scan(path)
    return iter(records)


def wal_path(wal_dir: str) -> str:
    """The canonical log location under a WAL directory."""
    return os.path.join(wal_dir, "serving.wal")


__all__ = ["WriteAheadLog", "WalCorrupt", "replay", "wal_path",
           "encode_record", "decode_record", "MAGIC", "KINDS"]
