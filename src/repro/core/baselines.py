"""Classical retrieval baselines the paper compares against (§5.1).

- BM25 text relevance + TkQ ranking (Eq. 1 with BM25 TRel, linear SRel)
- brute-force embedding search (LIST-R over the whole corpus)
- IVF: k-means clusters on text embeddings, route to cr nearest centroids
- IVF_S: k-means on the weighted concat of embedding + geo features (the
  "manually balance the two factors" strawman, paper §5.2)
- LSH: random-hyperplane signatures, multi-table bucket lookup

All are JAX/numpy re-implementations (Faiss is CPU/GPU C++; these map the
same math onto dense linear algebra — DESIGN.md §3). HNSW is deliberately
not ported: beam search over a pointer graph is scalar-core-hostile on TPU
(DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# BM25 + TkQ
# ---------------------------------------------------------------------------


class BM25:
    """BM25 over token-id documents (exact word matching — the point)."""

    def __init__(self, docs: np.ndarray, *, k1=1.2, b=0.75,
                 vocab_size: Optional[int] = None):
        """docs: (N, L) int token ids, 0 = pad."""
        self.k1, self.b = k1, b
        self.docs = docs
        n, l = docs.shape
        self.doc_len = (docs != 0).sum(1)
        self.avg_len = max(float(self.doc_len.mean()), 1.0)
        V = vocab_size or int(docs.max()) + 1
        df = np.zeros(V, np.int64)
        for i in range(n):
            df[np.unique(docs[i][docs[i] != 0])] += 1
        self.idf = np.log(1.0 + (n - df + 0.5) / (df + 0.5))
        self.n = n
        self.V = V

    def scores(self, q_tokens: np.ndarray) -> np.ndarray:
        """q_tokens: (B, Lq) → (B, N) BM25 scores."""
        B = q_tokens.shape[0]
        out = np.zeros((B, self.n), np.float32)
        k1, b = self.k1, self.b
        norm = k1 * (1 - b + b * self.doc_len / self.avg_len)  # (N,)
        for i in range(B):
            terms = np.unique(q_tokens[i][q_tokens[i] > 1])
            for t in terms:
                tf = (self.docs == t).sum(1)                    # (N,)
                out[i] += self.idf[t] * tf * (k1 + 1) / (tf + norm)
        return out


def tkq_scores(bm25: BM25, q_tokens, q_loc, obj_loc, *, alpha=0.4,
               dist_max=math.sqrt(2.0)) -> np.ndarray:
    """Eq. 1: (1-α)·SRel_linear + α·TRel_BM25-normalized. → (B, N)."""
    t = bm25.scores(q_tokens)
    t_max = t.max(axis=1, keepdims=True)
    t = t / np.maximum(t_max, 1e-9)                       # normalize to [0,1]
    d = np.linalg.norm(q_loc[:, None] - obj_loc[None], axis=-1)
    srel = 1.0 - np.clip(d / dist_max, 0.0, 1.0)
    return (1 - alpha) * srel + alpha * t


def tkq_topk(bm25, q_tokens, q_loc, obj_loc, k, **kw) -> np.ndarray:
    s = tkq_scores(bm25, q_tokens, q_loc, obj_loc, **kw)
    return np.argsort(-s, axis=1)[:, :k]


# ---------------------------------------------------------------------------
# k-means (Lloyd, pure JAX) — substrate for IVF / IVF_S
# ---------------------------------------------------------------------------


def kmeans(x, n_clusters: int, *, iters: int = 25, seed: int = 0):
    """x: (N, d) → (centroids (c, d), assign (N,)). Pure-JAX Lloyd."""
    x = jnp.asarray(x)
    n = x.shape[0]
    key = jax.random.PRNGKey(seed)
    init = jax.random.choice(key, n, (n_clusters,), replace=False)
    cent = x[init]

    @jax.jit
    def step(cent):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ cent.T
             + jnp.sum(cent * cent, 1)[None])
        a = jnp.argmin(d, axis=1)
        oh = jax.nn.one_hot(a, n_clusters, dtype=x.dtype)     # (N, c)
        sums = oh.T @ x
        cnt = oh.sum(0)[:, None]
        new = jnp.where(cnt > 0, sums / jnp.maximum(cnt, 1), cent)
        return new, a

    assign = None
    for _ in range(iters):
        cent, assign = step(cent)
    return cent, assign


class IVFIndex:
    """k-means inverted file over embeddings (+ optional spatial factor)."""

    def __init__(self, emb, loc=None, *, n_clusters: int, alpha: float = 1.0,
                 iters: int = 25, seed: int = 0):
        """alpha=1.0 → plain IVF (text embedding only).
        alpha<1.0 → IVF_S: k-means on [α·L2norm(emb), (1-α)·loc_hat]."""
        emb = np.asarray(emb, np.float32)
        self.alpha = alpha
        if alpha >= 1.0 or loc is None:
            feats = emb
            self._loc_stats = None
        else:
            e = emb / np.maximum(
                np.linalg.norm(emb, axis=1, keepdims=True), 1e-9)
            lo, hi = loc.min(0), loc.max(0)
            lh = (loc - lo) / np.maximum(hi - lo, 1e-9)
            feats = np.concatenate([alpha * e, (1 - alpha) * lh], axis=1)
            self._loc_stats = (lo, hi)
        cent, assign = kmeans(jnp.asarray(feats), n_clusters, iters=iters,
                              seed=seed)
        self.centroids = np.asarray(cent)
        self.assign = np.asarray(assign)
        self.n_clusters = n_clusters
        self.lists = [np.nonzero(self.assign == c)[0]
                      for c in range(n_clusters)]

    def _query_feats(self, q_emb, q_loc):
        q_emb = np.asarray(q_emb, np.float32)
        if self._loc_stats is None:
            return q_emb
        lo, hi = self._loc_stats
        e = q_emb / np.maximum(
            np.linalg.norm(q_emb, axis=1, keepdims=True), 1e-9)
        lh = (np.asarray(q_loc) - lo) / np.maximum(hi - lo, 1e-9)
        return np.concatenate([self.alpha * e, (1 - self.alpha) * lh], axis=1)

    def probe(self, q_emb, q_loc=None, *, cr: int = 1) -> np.ndarray:
        """(B, cr) nearest centroid ids (L2)."""
        f = self._query_feats(q_emb, q_loc)
        d = (np.sum(f * f, 1)[:, None] - 2 * f @ self.centroids.T
             + np.sum(self.centroids ** 2, 1)[None])
        return np.argsort(d, axis=1)[:, :cr]

    def candidates(self, q_emb, q_loc=None, *, cr: int = 1):
        """list of per-query candidate id arrays."""
        probes = self.probe(q_emb, q_loc, cr=cr)
        return [np.concatenate([self.lists[c] for c in row]) if len(row)
                else np.empty(0, np.int64) for row in probes]


class LSHIndex:
    """Random-hyperplane LSH with L tables of nbits-bit signatures."""

    def __init__(self, emb, *, nbits: int = 16, n_tables: int = 4,
                 seed: int = 0):
        emb = np.asarray(emb, np.float32)
        rng = np.random.default_rng(seed)
        d = emb.shape[1]
        self.planes = rng.normal(size=(n_tables, nbits, d)).astype(np.float32)
        self.n_tables = n_tables
        self.nbits = nbits
        self.codes = self._hash(emb)                 # (T, N)
        self.tables = []
        for t in range(n_tables):
            buckets = {}
            for i, c in enumerate(self.codes[t]):
                buckets.setdefault(int(c), []).append(i)
            self.tables.append({k: np.array(v, np.int64)
                                for k, v in buckets.items()})

    def _hash(self, x) -> np.ndarray:
        sig = np.einsum("tbd,nd->tnb", self.planes, x) > 0
        weights = (1 << np.arange(self.nbits)).astype(np.int64)
        return sig @ weights                          # (T, N)

    def candidates(self, q_emb):
        codes = self._hash(np.asarray(q_emb, np.float32))   # (T, B)
        outs = []
        for i in range(codes.shape[1]):
            cand = [self.tables[t].get(int(codes[t, i]), np.empty(0, np.int64))
                    for t in range(self.n_tables)]
            outs.append(np.unique(np.concatenate(cand))
                        if cand else np.empty(0, np.int64))
        return outs


# ---------------------------------------------------------------------------
# Shared rerank: score candidate lists with LIST-R, return top-k
# ---------------------------------------------------------------------------


def rerank_candidates(score_fn, cand_lists, k: int):
    """score_fn(q_idx, cand_ids) -> scores; returns (B, k) padded id matrix
    (-1 pad) plus mean candidate count (the efficiency proxy)."""
    out = np.full((len(cand_lists), k), -1, np.int64)
    n_scored = 0
    for i, cand in enumerate(cand_lists):
        if len(cand) == 0:
            continue
        n_scored += len(cand)
        s = np.asarray(score_fn(i, cand))
        order = np.argsort(-s)[:k]
        out[i, :len(order)] = cand[order]
    return out, n_scored / max(len(cand_lists), 1)
