"""Distributed LIST query phase: clusters as experts (DESIGN.md §3/§5).

The paper serves queries on one CPU: route each query to a cluster, scan
that cluster's inverted list. On a TPU pod the cluster buffers are sharded
over all chips, so "scan the routed cluster" becomes a data-movement
problem. Our TPU-native mapping treats it as **expert-parallel dispatch**
(exactly the MoE pattern): clusters are experts, queries are tokens,
capacity = ceil(B·cr/c · balance) — the paper's learned balance (low IF(C))
is precisely what keeps the capacity (and thus the dispatch cost) tight.

  1. route: tiny replicated MLP → top-cr clusters per query
  2. dispatch: sort-based scatter of queries into a (c, Qcap, d) buffer,
     sharded cluster-major over all chips (all-to-all under GSPMD)
  3. score: per-cluster batched matmul (c, Qcap, d)×(c, cap, d) — each chip
     multiplies only ITS clusters against ITS resident buffer shard; the
     object corpus never moves
  4. per-cluster top-k, undispatch back to queries, merge the cr lists

Compute cost: c·Qcap·cap·d ≈ (balance·cr)·B·(n/c)·d = the paper's 1/c
search-space reduction, now bandwidth-local per chip.

The same sort-based scatter core (:func:`_sorted_runs`) also builds the
single-host CLUSTER-MAJOR batch plan (:func:`cluster_major_plan`,
DESIGN.md §10): instead of one roster row per cluster shard, one row
per *distinct routed* cluster, so the engine's ``pallas-cm`` backend
streams each distinct cluster's resident tiles once per batch.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as engine_lib
from repro.core import index as index_lib
from repro.core import relevance
from repro.distributed.sharding import constrain


def query_capacity(batch: int, n_clusters: int, cr: int,
                   balance: float = 2.0) -> int:
    c = int(batch * cr / n_clusters * balance)
    return max(8, -(-c // 8) * 8)


def _sorted_runs(flat):
    """Stable-sort a flat vector of routed cluster ids and mark its runs.

    → (sort_idx, sorted_c, is_start, pos): ``sort_idx`` the stable
    argsort, ``sorted_c`` the sorted cluster ids, ``is_start`` True at
    the first element of each equal-cluster run, ``pos`` each element's
    rank within its run. This is the sort-based scatter core shared by
    :func:`dispatch_queries` (one roster row per cluster, all ``c`` of
    them) and :func:`cluster_major_plan` (one roster row per DISTINCT
    routed cluster).
    """
    n = flat.shape[0]
    sort_idx = jnp.argsort(flat, stable=True)
    sorted_c = flat[sort_idx]
    ar = jnp.arange(n)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_c[1:] != sorted_c[:-1]])
    run_start = jax.lax.cummax(jnp.where(is_start, ar, 0))
    pos = ar - run_start
    return sort_idx, sorted_c, is_start, pos


def dispatch_queries(top_c, q_feat, *, n_clusters: int, capacity: int):
    """Sort-based dispatch (mirrors models/moe.py).

    top_c: (B, cr) routed clusters; q_feat: (B, f) payload to dispatch.
    Returns (q_buf (c, Qcap, f), origin (c, Qcap) int32 in [0, B·cr],
    pad row = B·cr, n_dropped () int32).

    ``n_dropped`` counts (query, route) pairs that exceeded a cluster's
    capacity and were NOT placed — overflow is surfaced, never silently
    truncated. Callers decide whether to raise capacity or accept the
    recall loss (the merged cr lists degrade gracefully).
    """
    b, cr = top_c.shape
    n = b * cr
    flat = top_c.reshape(n)
    sort_idx, sorted_c, _, pos = _sorted_runs(flat)
    keep = pos < capacity
    slot = jnp.where(keep, sorted_c * capacity + pos, n_clusters * capacity)
    n_dropped = jnp.sum(~keep).astype(jnp.int32)

    origin = jnp.full((n_clusters * capacity + 1,), n, jnp.int32)
    origin = origin.at[slot].set(sort_idx.astype(jnp.int32))
    origin = origin[:-1].reshape(n_clusters, capacity)

    fpad = jnp.concatenate([q_feat[jnp.repeat(jnp.arange(b), cr)],
                            jnp.zeros((1,) + q_feat.shape[1:], q_feat.dtype)])
    q_buf = fpad[jnp.where(origin < n, origin, n)]
    return q_buf, origin, n_dropped


def cluster_major_plan(top_c, *, n_clusters: int,
                       qcap: Optional[int] = None,
                       u_max: Optional[int] = None):
    """Batch execution plan for CLUSTER-MAJOR scanning (DESIGN.md §10).

    Where :func:`dispatch_queries` builds one roster row for every one
    of the ``c`` clusters (the sharded all-to-all layout), this dedupes
    the batch's routed clusters and builds one row per **distinct**
    routed cluster — the plan the cluster-major kernel
    (``kernels.fused_topk_score_cluster_major``) streams: each distinct
    cluster's resident tiles cross HBM once per batch, scored against
    that cluster's whole query roster.

    top_c: (B, cr) routed cluster ids (duplicates allowed — a query
    routed twice to one cluster occupies two roster slots, preserving
    the query-major duplicate semantics). Returns

      u          (u_max,) int32 — the distinct routed cluster ids, one
                 per roster row, in ascending cluster order. Slots past
                 the realized distinct count ``U`` hold cluster 0 with
                 an empty roster (static shapes: ``u_max`` defaults to
                 ``min(B·cr, n_clusters)``, the structural upper bound
                 on ``U``).
      roster     (u_max, qcap) int32 — the inverse map: flattened
                 (query, route) indices in ``[0, B·cr)`` assigned to
                 each distinct cluster, ``B·cr`` marking empty slots.
                 ``qcap`` defaults to ``B·cr`` (exact: nothing can
                 drop); a smaller ``qcap`` bounds the roster like the
                 dispatch capacity does.
      n_distinct () int32 — the realized U; the batch dedup factor is
                 ``B·cr / U`` (the auto heuristic's signal).
      n_dropped  () int32 — (query, route) pairs that exceeded ``qcap``
                 (or ``u_max``) and were NOT placed; surfaced, never
                 silently truncated, exactly like the dispatch path.
    """
    b, cr = top_c.shape
    n = b * cr
    u_max = min(n, n_clusters) if u_max is None else u_max
    qcap = n if qcap is None else qcap
    flat = top_c.reshape(n)
    sort_idx, sorted_c, is_start, pos = _sorted_runs(flat)
    slot_of = jnp.cumsum(is_start) - 1            # distinct-slot per pair
    n_distinct = slot_of[-1].astype(jnp.int32) + 1
    keep = (pos < qcap) & (slot_of < u_max)
    dest = jnp.where(keep, slot_of * qcap + pos, u_max * qcap)
    n_dropped = jnp.sum(~keep).astype(jnp.int32)

    roster = jnp.full((u_max * qcap + 1,), n, jnp.int32)
    roster = roster.at[dest].set(sort_idx.astype(jnp.int32))
    roster = roster[:-1].reshape(u_max, qcap)

    u_dest = jnp.where(is_start & (slot_of < u_max), slot_of, u_max)
    u = jnp.zeros((u_max + 1,), jnp.int32)
    u = u.at[u_dest].set(sorted_c.astype(jnp.int32))[:u_max]
    return u, roster, n_distinct, n_dropped


def localize_routes(top_c, shard_of, local_of, shard: int, *,
                    sentinel: int):
    """Map GLOBAL routed cluster ids to one shard's LOCAL buffer rows
    (host, numpy) — the route-localization step of mesh-sharded serving
    (DESIGN.md §12).

    ``top_c (B, cr)`` global routed cluster ids; ``shard_of`` /
    ``local_of`` the ``(c,)`` placement maps of
    ``sharding.ClusterShards``; ``sentinel`` the shard's appended empty
    cluster row (``ClusterShards.sentinel``). Routes owned by ``shard``
    map to their local row; every other route maps to the sentinel, so
    the per-shard plan keeps its static ``(B, cr)`` shape and off-shard
    candidates mask to ``(−1, NEG_INF)`` exactly like padding slots —
    never clamped into a real cluster by jit's out-of-bounds indexing.

    This is the ONE definition of off-shard route semantics, shared by
    the engine's sharded path and the mesh parity tests. Duplicate
    routes to one cluster land on one shard together, preserving the
    single-device duplicate semantics the cluster-major plan relies on.
    """
    tc = np.asarray(top_c)
    shard_of = np.asarray(shard_of)
    local_of = np.asarray(local_of)
    on = shard_of[tc] == shard
    return np.where(on, local_of[tc], sentinel).astype(np.int32)


def roster_query_rows(roster, *, cr: int, n_total: int):
    """Invert roster slots to query rows: slot value ``o ∈ [0, B·cr)``
    is the flattened (query, route) pair, so the query row is
    ``o // cr``; empty slots (``o == n_total``) clamp to row 0 — mask
    them via ``roster < n_total`` (the kernel and the merge both do).
    The ONE definition of the roster's empty-slot semantics, shared by
    the pallas-cm gather, the dense oracle, and the tests."""
    return jnp.where(roster < n_total, roster, 0) // cr


def cluster_dispatch_query(snapshot, q_tokens, q_mask, q_loc, *,
                           k: int = 20, cr: int = 1,
                           capacity: Optional[int] = None,
                           return_dropped: bool = False):
    """The distributed query phase over an :class:`IndexSnapshot`
    (core/snapshot.py) — the same artifact the gather path's
    ``QueryEngine`` serves, so dispatch and gather share one scoring
    surface (``engine.score_candidates``) *and* one state surface.

    Returns (ids (B, k), scores (B, k)), plus the dispatch overflow
    count n_dropped () when ``return_dropped``. Mesh-parallel plans that
    need explicit array arguments (launch/steps.py builds them from
    abstract shapes) call :func:`dispatch_query_kernel` directly.
    """
    buf = snapshot.buffers
    return dispatch_query_kernel(
        snapshot.rel_params, snapshot.index_params, snapshot.w_hat,
        snapshot.norm, buf["emb"], buf["loc"], buf["ids"],
        q_tokens, q_mask, q_loc, snapshot.cfg, k=k, cr=cr,
        dist_max=snapshot.meta.dist_max, capacity=capacity,
        buf_scale=buf.get("scale"), precision=snapshot.meta.precision,
        return_dropped=return_dropped)


def dispatch_query_kernel(rel_params, index_params, w_hat, norm,
                          buf_emb, buf_loc, buf_ids,
                          q_tokens, q_mask, q_loc, cfg, *,
                          k: int = 20, cr: int = 1, dist_max: float = 1.0,
                          capacity: Optional[int] = None,
                          buf_scale=None, precision: str = "f32",
                          return_dropped: bool = False):
    """Explicit-array form of :func:`cluster_dispatch_query` — the body
    that launch/steps.py stages into sharded meshes. Returns
    (ids (B, k), scores (B, k)), plus the dispatch overflow count
    n_dropped () when ``return_dropped``.

    buf_emb (c, cap, d) / buf_loc (c, cap, 2) / buf_ids (c, cap): the padded
    cluster buffers, sharded cluster-major ("all") on the production mesh.
    Quantized buffers (DESIGN.md §9) pass ``precision`` and, for int8,
    the per-row ``buf_scale (c, cap)`` — dequantization rides the shared
    ``engine.score_candidates`` primitive, so dispatch and gather agree
    per tier. The scale shard is cluster-major like the buffers.
    """
    b = q_tokens.shape[0]
    c, cap, d = buf_emb.shape
    # int8 codes scored unscaled would rank rows on raw code magnitude —
    # refuse loudly instead of silently corrupting top-k results
    if buf_emb.dtype == jnp.int8 and (precision != "int8"
                                      or buf_scale is None):
        raise ValueError(
            "dispatch_query_kernel: buf_emb is int8 but "
            f"precision={precision!r} / buf_scale="
            f"{'set' if buf_scale is not None else 'None'}; quantized "
            "buffers require precision='int8' and their per-row scales "
            "(see DESIGN.md §9)")
    qcap = capacity or query_capacity(b, c, cr)

    # 1. encode + route (replicated tiny MLP)
    q_emb = relevance.encode_queries(rel_params, q_tokens, q_mask, cfg)
    w = relevance.st_weights(rel_params, q_emb)                  # (B, 2)
    feats = index_lib.build_features(q_emb, q_loc, norm)
    top_c, _ = index_lib.route_queries(index_params, feats, cr=cr)

    # 2. dispatch query payloads [emb, loc, w] to their clusters
    payload = jnp.concatenate(
        [q_emb, q_loc.astype(q_emb.dtype), w.astype(q_emb.dtype)], axis=-1)
    q_buf, origin, n_dropped = dispatch_queries(top_c, payload,
                                                n_clusters=c, capacity=qcap)
    q_buf = constrain(q_buf, "all", None, None)     # (c, Qcap, d+4)
    qe = q_buf[..., :d]
    ql = q_buf[..., d:d + 2].astype(jnp.float32)
    qw = q_buf[..., d + 2:].astype(jnp.float32)

    # 3. fused score per cluster — each chip against its resident shard;
    # the engine's score_candidates broadcasts (c, Q, d) × (c, 1, cap, d)
    cand_scale = (buf_scale[:, None]
                  if precision == "int8" and buf_scale is not None else None)
    st = engine_lib.score_candidates(
        qe, ql, qw, buf_emb[:, None], buf_loc[:, None], buf_ids[:, None],
        w_hat, dist_max=dist_max, cand_scale=cand_scale)
    st = constrain(st, "all", None, None)

    # 4. per-cluster top-k, then undispatch + merge the cr candidate lists
    vals, pos = jax.lax.top_k(st, k)                        # (c, Qcap, k)
    ids = jnp.take_along_axis(
        jnp.broadcast_to(buf_ids[:, None, :], st.shape), pos, axis=-1)

    flat_vals = vals.reshape(c * qcap, k)
    flat_ids = ids.reshape(c * qcap, k)
    # origin slot -> row in (B·cr): scatter back
    n = b * cr
    back_v = jnp.full((n + 1, k), -jnp.inf, flat_vals.dtype)
    back_i = jnp.full((n + 1, k), -1, flat_ids.dtype)
    orig = origin.reshape(-1)
    back_v = back_v.at[orig].set(flat_vals)
    back_i = back_i.at[orig].set(flat_ids)
    per_q_v = back_v[:n].reshape(b, cr * k)
    per_q_i = back_i[:n].reshape(b, cr * k)
    fv, fpos = jax.lax.top_k(per_q_v, k)
    fi = jnp.take_along_axis(per_q_i, fpos, axis=1)
    if return_dropped:
        return fi, fv, n_dropped
    return fi, fv
