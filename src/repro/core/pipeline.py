"""LIST end-to-end pipeline (paper Algorithm 1): train → index → query.

Public API is the :class:`ListRetriever`:

    retriever = ListRetriever(cfg, corpus)
    retriever.train_relevance(steps=...)     # Eq. 8 contrastive
    retriever.train_index(steps=...)         # Eq. 13 pseudo-labels + Eq. 14 MCL
    retriever.build()                        # indexing phase (cluster buffers)
    ids, scores = retriever.query(q_ids, k)  # query phase (route+score+topk)

The query phase is a single jitted program owned by the unified engine
(core/engine.py): encode → features → route → fused score → top-k.
``backend="pallas"`` runs the GATHER-FREE kernel
(kernels/fused_topk_score_routed): routed cluster ids are
scalar-prefetched and the resident (c, cap, d) buffers block-indexed
directly, so no (B, cr·cap, d) candidate copy is materialized and cr > 1
merges in-kernel. ``backend="dense"`` is the jnp reference path.

The built state is exported as an immutable, versioned
``IndexSnapshot`` (:meth:`ListRetriever.snapshot`, core/snapshot.py) —
the artifact ``repro.api`` saves, loads, and serves.
"""
from __future__ import annotations

import functools
import time
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as engine_lib
from repro.core import index as index_lib
from repro.core import pseudo_labels, relevance
from repro.core import snapshot as snapshot_lib
from repro.core import spatial as sp
from repro.core.baselines import BM25, tkq_topk
from repro.optim import make_optimizer, clip_by_global_norm, linear_warmup_cosine


# ---------------------------------------------------------------------------
# Corpus embedding (offline, batched)
# ---------------------------------------------------------------------------


def embed_objects(params, corpus, cfg, *, batch: int = 512) -> np.ndarray:
    tokens, mask = corpus.object_tokens()
    return _embed(functools.partial(relevance.encode_objects, params, cfg=cfg),
                  tokens, mask, batch)


def embed_queries(params, corpus, cfg, query_ids=None, *,
                  batch: int = 512) -> np.ndarray:
    tokens, mask = corpus.query_tokens(query_ids)
    return _embed(functools.partial(relevance.encode_queries, params, cfg=cfg),
                  tokens, mask, batch)


def _embed(encode, tokens, mask, batch):
    jfn = jax.jit(lambda t, m: encode(t, m))
    return engine_lib.run_batched(jfn, [tokens, mask], batch=batch)


# ---------------------------------------------------------------------------
# TkQ hard negatives for relevance training (paper §4.2 Training Strategy)
# ---------------------------------------------------------------------------


def mine_tkq_negatives(corpus, query_ids, *, pool: int = 50,
                       alpha: float = 0.4) -> np.ndarray:
    """(len(query_ids), pool) top-TkQ-ranked non-positive objects/query."""
    bm = BM25(corpus.obj_doc, vocab_size=corpus.cfg.vocab_size)
    q_tok = corpus.q_doc[query_ids]
    top = tkq_topk(bm, q_tok, corpus.q_loc[query_ids], corpus.obj_loc,
                   pool * 2, alpha=alpha, dist_max=corpus.dist_max)
    out = np.zeros((len(query_ids), pool), np.int64)
    for i, qi in enumerate(query_ids):
        pos = set(corpus.positives[qi].tolist())
        neg = [o for o in top[i] if o not in pos][:pool]
        while len(neg) < pool:  # top up with randoms
            cand = np.random.default_rng(qi).integers(
                0, corpus.cfg.n_objects, size=pool)
            neg.extend([o for o in cand if o not in pos])
        out[i] = np.array(neg[:pool])
    return out


# ---------------------------------------------------------------------------
# Trainers
# ---------------------------------------------------------------------------


def train_relevance_model(corpus, cfg, *, steps: int = 200, batch: int = 64,
                          lr: float = 3e-4, seed: int = 0,
                          spatial_mode: str = "step",
                          weight_mode: str = "mlp",
                          hard_negatives: bool = True,
                          log_every: int = 50, verbose: bool = False):
    """Contrastive training (Eq. 8). Returns (params, metrics_history)."""
    key = jax.random.PRNGKey(seed)
    params = relevance.relevance_init(key, cfg, spatial_mode=spatial_mode,
                                      weight_mode=weight_mode)
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    opt_state = opt_init(params)
    sched = linear_warmup_cosine(lr, max(steps // 20, 1), steps)
    train_q, _, _ = corpus.split()
    negs = (mine_tkq_negatives(corpus, train_q, pool=16)
            if hard_negatives else None)
    neg_lookup = np.zeros((corpus.cfg.n_queries, 16), np.int64)
    if negs is not None:
        neg_lookup[train_q] = negs

    @jax.jit
    def step_fn(params, opt_state, batch_dev, lr_now):
        def loss_fn(p):
            return relevance.contrastive_loss(
                p, batch_dev, cfg, spatial_mode=spatial_mode,
                weight_mode=weight_mode)
        (loss, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt_update(grads, opt_state, params, lr_now)
        m["grad_norm"] = gnorm
        return params, opt_state, m

    hist = []
    for step in range(steps):
        b = corpus.train_batch(step, batch, train_q,
                               hard_negs=neg_lookup if hard_negatives else None,
                               b_neg=cfg.hard_neg_b)
        b.pop("query_ids")
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, m = step_fn(params, opt_state, b,
                                       sched(jnp.int32(step)))
        if step % log_every == 0 or step == steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = step
            hist.append(rec)
            if verbose:
                print(f"  [relevance] step {step}: loss={rec['loss']:.4f} "
                      f"acc={rec['acc']:.3f}")
    return params, hist


def train_cluster_index(rel_params, corpus, cfg, *, obj_emb=None,
                        steps: int = 300, batch: int = 64, lr: float = 1e-3,
                        seed: int = 0, neg_start: Optional[int] = None,
                        neg_end: Optional[int] = None, m_negs: Optional[int] = None,
                        log_every: int = 100, verbose: bool = False,
                        spatial_mode="step", weight_mode="mlp"):
    """LIST-I training: Eq. 13 pseudo-negatives + Eq. 14 MCL loss.

    Returns (index_params, loc_norm, obj_emb, history).
    """
    neg_start = cfg.neg_start if neg_start is None else neg_start
    neg_end = cfg.neg_end if neg_end is None else neg_end
    m_negs = cfg.mcl_negatives if m_negs is None else m_negs
    if obj_emb is None:
        obj_emb = embed_objects(rel_params, corpus, cfg)
    obj_loc = corpus.obj_loc.astype(np.float32)
    norm = index_lib.loc_normalizer(jnp.asarray(obj_loc))

    train_q, _, _ = corpus.split()
    q_emb = embed_queries(rel_params, corpus, cfg, train_q)
    q_loc = corpus.q_loc[train_q].astype(np.float32)

    # --- Eq. 13: mine the pseudo-negative window with the relevance model --
    pos_mask = corpus.positives_mask(train_q)
    neg_ids = np.asarray(pseudo_labels.mine_negatives(
        rel_params, cfg, jnp.asarray(q_emb), jnp.asarray(q_loc),
        jnp.asarray(obj_emb), jnp.asarray(obj_loc),
        pos_mask=jnp.asarray(pos_mask), neg_start=neg_start, neg_end=neg_end,
        dist_max=corpus.dist_max, spatial_mode=spatial_mode,
        weight_mode=weight_mode))                       # (Bq, window)

    # --- features ---------------------------------------------------------
    obj_feats = np.asarray(index_lib.build_features(
        jnp.asarray(obj_emb), jnp.asarray(obj_loc), norm))
    q_feats = np.asarray(index_lib.build_features(
        jnp.asarray(q_emb), jnp.asarray(q_loc), norm))

    key = jax.random.PRNGKey(seed + 7)
    iparams = index_lib.index_init(key, obj_emb.shape[1], cfg.n_clusters,
                                   hidden=cfg.index_mlp_hidden)
    opt_init, opt_update = make_optimizer("adamw")
    opt_state = opt_init(iparams)
    sched = linear_warmup_cosine(lr, max(steps // 20, 1), steps)

    @jax.jit
    def step_fn(iparams, opt_state, fb, lr_now):
        (loss, m), grads = jax.value_and_grad(
            index_lib.mcl_loss, has_aux=True)(iparams, fb)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        iparams, opt_state = opt_update(grads, opt_state, iparams, lr_now)
        m["grad_norm"] = gnorm
        return iparams, opt_state, m

    rng = np.random.default_rng(seed)
    nq = len(train_q)
    hist = []
    for step in range(steps):
        rows = rng.integers(0, nq, size=batch)
        pos_pick = np.array([
            corpus.positives[train_q[r]][
                rng.integers(0, len(corpus.positives[train_q[r]]))]
            for r in rows])
        neg_pick = neg_ids[rows[:, None],
                           rng.integers(0, neg_ids.shape[1],
                                        size=(batch, m_negs))]
        fb = {
            "q_feat": jnp.asarray(q_feats[rows]),
            "pos_feat": jnp.asarray(obj_feats[pos_pick]),
            "neg_feat": jnp.asarray(obj_feats[neg_pick.reshape(-1)]
                                    ).reshape(batch, m_negs, -1),
        }
        iparams, opt_state, m = step_fn(iparams, opt_state, fb,
                                        sched(jnp.int32(step)))
        if step % log_every == 0 or step == steps - 1:
            rec = {k: float(v) for k, v in m.items()}
            rec["step"] = step
            hist.append(rec)
            if verbose:
                print(f"  [index] step {step}: loss={rec['loss']:.4f} "
                      f"s_pos={rec['s_pos']:.3f} s_neg={rec['s_neg']:.3f}")
    return iparams, norm, obj_emb, hist


# ---------------------------------------------------------------------------
# The retriever façade
# ---------------------------------------------------------------------------
# (The jitted query-phase builder lives in core/engine.make_query_fn —
# the former pipeline.make_query_fn wrapper and its use_pallas alias are
# gone; --use-pallas survives only in engine.resolve_cli_backend.)


class ListRetriever:
    """LIST = LIST-R (relevance) + LIST-I (learned cluster index)."""

    def __init__(self, cfg, corpus, *, spatial_mode="step", weight_mode="mlp"):
        self.cfg = cfg
        self.corpus = corpus
        self.spatial_mode = spatial_mode
        self.weight_mode = weight_mode
        self.rel_params = None
        self.index_params = None
        self.norm = None
        self.obj_emb = None
        self.buffers = None
        self.history = {}

    # --- training phase ---------------------------------------------------

    def train_relevance(self, **kw):
        self.rel_params, h = train_relevance_model(
            self.corpus, self.cfg, spatial_mode=self.spatial_mode,
            weight_mode=self.weight_mode, **kw)
        self.history["relevance"] = h
        return h

    def train_index(self, **kw):
        assert self.rel_params is not None, "train_relevance first"
        self.index_params, self.norm, self.obj_emb, h = train_cluster_index(
            self.rel_params, self.corpus, self.cfg, obj_emb=self.obj_emb,
            spatial_mode=self.spatial_mode, weight_mode=self.weight_mode,
            **kw)
        self.history["index"] = h
        return h

    # --- indexing phase -----------------------------------------------------

    def build(self, *, capacity=None, spill: int = 3,
              precision: str = "f32", attrs=None):
        """Indexing phase: pack the corpus into padded cluster buffers,
        optionally quantized (``precision ∈ index.PRECISIONS``,
        DESIGN.md §9 — int8 cuts the query phase's dominant HBM stream
        4×; loc/ids stay exact). ``attrs (n_objects, 3)`` attaches
        per-object filter attributes (core/filters.py, DESIGN.md §13);
        None → all-zero rows."""
        assert self.index_params is not None, "train_index first"
        if self.obj_emb is None:
            self.obj_emb = embed_objects(self.rel_params, self.corpus, self.cfg)
        obj_loc = self.corpus.obj_loc.astype(np.float32)
        feats = index_lib.build_features(
            jnp.asarray(self.obj_emb), jnp.asarray(obj_loc), self.norm)
        top = index_lib.assign_clusters(self.index_params, feats,
                                        top=max(spill, 1))
        if top.ndim == 1:
            top = top[:, None]
        self.buffers = index_lib.build_cluster_buffers(
            np.asarray(top), self.obj_emb, obj_loc,
            n_clusters=self.cfg.n_clusters, capacity=capacity, spill=spill,
            precision=precision, attrs=attrs)
        self.obj_assign = np.asarray(top[:, 0])
        self._engine = None            # buffers changed: invalidate plans
        return self.buffers

    # --- query phase --------------------------------------------------------

    def snapshot(self) -> "snapshot_lib.IndexSnapshot":
        """The immutable, versioned artifact of the current built state
        (core/snapshot.py): what you ``save()``, hand to
        ``repro.api.Searcher``, or publish to a streaming server.

        Re-derived (with ``meta.version`` bumped) whenever the
        retriever's params/buffers objects are swapped — retraining,
        ``index.insert_objects`` / ``delete_objects`` returning new
        buffer dicts — so a fresh call never describes stale state."""
        assert self.buffers is not None, "build() first"
        key = (id(self.rel_params), id(self.index_params), id(self.norm),
               id(self.buffers))
        if (getattr(self, "_snapshot", None) is None
                or getattr(self, "_snapshot_key", None) != key):
            version = getattr(self, "_snapshot_gen", -1) + 1
            self._snapshot_gen = version
            self._snapshot = snapshot_lib.IndexSnapshot.from_parts(
                self.cfg, self.rel_params, self.index_params, self.norm,
                self.buffers, dist_max=float(self.corpus.dist_max),
                spatial_mode=self.spatial_mode,
                weight_mode=self.weight_mode, version=version)
            self._snapshot_key = key
        return self._snapshot

    def engine(self) -> engine_lib.QueryEngine:
        """A stateless engine over :meth:`snapshot` (built lazily after
        build(); rebuilt when the snapshot re-derives, so queries never
        serve a stale index)."""
        snap = self.snapshot()
        if (getattr(self, "_engine", None) is None
                or self._engine.snapshot is not snap):
            self._engine = engine_lib.QueryEngine.from_snapshot(snap)
        return self._engine

    def query(self, query_ids, *, k: int = 20, cr: int = 1,
              backend: Optional[str] = None, batch: int = 256):
        eng = self.engine()
        tokens, mask = self.corpus.query_tokens(query_ids)
        q_loc = self.corpus.q_loc[query_ids].astype(np.float32)
        t0 = time.perf_counter()
        ids, sc = eng.query(tokens, mask, q_loc, k=k, cr=cr, batch=batch,
                            backend=backend)
        self.last_query_seconds = time.perf_counter() - t0
        return ids, sc

    # --- brute force (LIST-R over the whole corpus) -------------------------

    def brute_force(self, query_ids, *, k: int = 20, batch: int = 256):
        q_emb = embed_queries(self.rel_params, self.corpus, self.cfg,
                              query_ids, batch=batch)
        q_loc = self.corpus.q_loc[query_ids].astype(np.float32)
        obj_loc = self.corpus.obj_loc.astype(np.float32)

        @jax.jit
        def score_top(qe, ql):
            st = relevance.score_corpus(
                self.rel_params, qe, ql, jnp.asarray(self.obj_emb),
                jnp.asarray(obj_loc), self.cfg, dist_max=self.corpus.dist_max,
                spatial_mode=self.spatial_mode, weight_mode=self.weight_mode,
                train=False)
            sc, ids = jax.lax.top_k(st, k)
            return ids, sc

        t0 = time.perf_counter()
        ids, sc = engine_lib.run_batched(score_top, [q_emb, q_loc],
                                         batch=batch)
        self.last_query_seconds = time.perf_counter() - t0
        return ids, sc

    # --- embedding accessor for baselines -----------------------------------

    def ensure_embeddings(self):
        if self.obj_emb is None:
            self.obj_emb = embed_objects(self.rel_params, self.corpus, self.cfg)
        return self.obj_emb

    def score_fn(self):
        """score_fn(query_row_embedding context) for baseline reranking:
        returns fn(q_emb_row, q_loc_row, cand_ids) -> scores.

        Scoring goes through the engine's single ``score_candidates``
        primitive so reranked baselines use the exact serve-path ST."""
        obj_loc = self.corpus.obj_loc.astype(np.float32)
        w_hat = (sp.extract_lookup(self.rel_params["spatial"])
                 if self.spatial_mode == "step"
                 else jnp.linspace(0, 1, self.cfg.spatial_t))

        def fn(q_emb_row, q_loc_row, cand):
            ce = jnp.asarray(self.obj_emb[cand])
            cl = jnp.asarray(obj_loc[cand])
            w = relevance.st_weights(self.rel_params, q_emb_row[None],
                                     weight_mode=self.weight_mode)[0]
            st = engine_lib.score_candidates(
                q_emb_row, q_loc_row, w, ce, cl,
                jnp.asarray(cand, jnp.int32), w_hat,
                dist_max=float(self.corpus.dist_max))
            return np.asarray(st)
        return fn
