"""Immutable, versioned index artifacts (DESIGN.md §8).

LIST's value is a *built* index: the trained relevance params, the
cluster-classifier params, the location normalizer, and the packed
cluster buffers. An :class:`IndexSnapshot` freezes all of that — plus a
meta block identifying exactly what it is — into one pytree artifact
that

* **round-trips durably**: ``snap.save(dir)`` / ``IndexSnapshot.load(dir)``
  (built on checkpoint/ckpt.py's atomic-commit layout) reproduce
  bit-identical query results on every backend;
* **publishes atomically**: the serving stack never mutates an engine's
  resident state in place — mutation builds a *new* snapshot
  (:meth:`with_buffers` bumps ``meta.version``) and swaps it in one
  reference assignment, so an in-flight flush keeps scoring the
  snapshot it started with and no reader ever sees half an update;
* **self-describes**: ``meta.schema_version`` gates loads across format
  changes, ``meta.cfg_digest`` pins the model config the params were
  trained under (an engine refuses to swap in a snapshot built for a
  different config), ``meta.version`` keys result-cache entries in the
  streaming server, and ``meta.precision`` names the buffers' storage
  tier (DESIGN.md §9) — an unknown tier is refused before any array is
  read.

The snapshot is a frozen dataclass; treat every array inside it as
read-only. Derivations that would mutate (insert/delete) go through
``index.insert_objects`` / ``index.delete_objects`` + :meth:`with_buffers`,
which return a *new* snapshot.

On-disk layout — one ckpt step per snapshot version::

    <dir>/step_000000000/
        manifest.json      # ckpt manifest; meta = SnapshotMeta + cfg +
                           #   tree_spec (the container structure)
        arr_00000.npy ...  # one file per leaf

``tree_spec`` records the nested dict/list/tuple structure of the param
trees so a load needs NO template: the structure is rebuilt from the
manifest and ckpt.restore validates the leaf count. Loads therefore
work even for params whose shapes can't be derived from the config
(e.g. an index MLP built with non-config hidden sizes).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import DualEncoderConfig
from repro.core import delta as delta_lib
from repro.core import index as index_lib
from repro.core import spatial as sp

# v2: precision-aware buffers — ``buffers["scale"]`` joined the leaf
# arrays and ``meta.precision`` the identity block (DESIGN.md §9). A v1
# artifact has no scale leaf and no precision field, so loads across the
# bump fail the schema gate (clear ValueError) instead of misreading.
# v3: LSM-style mutation path (DESIGN.md §11) — an optional delta
# segment (pending inserted rows + tombstoned ids) joins the tree, and
# ``meta.delta_rows`` / ``meta.n_tombstones`` the identity block. A v2
# artifact cannot declare pending mutations, so loads across the bump
# fail the schema gate rather than silently dropping them.
# v4: mesh-sharded serving (DESIGN.md §12) — ``meta.n_shards`` joins
# the identity block as placement provenance. Arrays are still saved
# GLOBAL (gather-on-save: a sharded snapshot keeps its host-side global
# buffers, so the artifact bakes in no topology); load() always hands
# back an unsharded snapshot and ``api.load(..., mesh=)`` /
# ``with_mesh`` re-shard under whatever device count the loading host
# has — the elastic 8→4→1 reload the parity tests pin.
# v5: filtered search (DESIGN.md §13) — ``buffers["attrs"]`` (c, cap, 3)
# int32 per-object filter attributes joins the leaf arrays, and the
# delta segment grows a matching ``attrs`` column. A v4 artifact has no
# attribute table, so loads across the bump fail the schema gate rather
# than inventing all-zero tenants for rows that may have had real ones.
SCHEMA_VERSION = 5

# buffer keys that are arrays (saved as leaves) vs host-side ints (meta)
_BUFFER_ARRAYS = ("emb", "loc", "ids", "counts", "scale", "attrs")
_BUFFER_SCALARS = ("capacity", "n_spilled")


# ---------------------------------------------------------------------------
# Config identity
# ---------------------------------------------------------------------------


def cfg_digest(cfg) -> str:
    """Stable digest of the model config: the identity a snapshot's params
    are only valid under. Tuples serialize as JSON lists, so the digest is
    identical before a save and after a load."""
    blob = json.dumps(dataclasses.asdict(cfg), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def _cfg_from_dict(d: dict) -> DualEncoderConfig:
    kw = {k: tuple(v) if isinstance(v, list) else v for k, v in d.items()}
    return DualEncoderConfig(**kw)


# ---------------------------------------------------------------------------
# Structure spec: JSON-able container skeleton of a pytree
# ---------------------------------------------------------------------------


def _tree_spec(tree) -> Any:
    """The container structure of ``tree`` with leaves as ``None``.

    Dict children are listed in sorted-key order — the same order
    ``jax.tree_util`` flattens dicts in — so a skeleton rebuilt from the
    spec has the exact treedef of the original.
    """
    if isinstance(tree, dict):
        return {"d": {k: _tree_spec(tree[k]) for k in sorted(tree)}}
    if isinstance(tree, (list, tuple)):
        kind = "t" if isinstance(tree, tuple) else "l"
        return {kind: [_tree_spec(v) for v in tree]}
    return None


def _spec_skeleton(spec) -> Any:
    """Rebuild the container structure with ``0`` placeholder leaves
    (no ``.shape`` attribute, so ckpt.restore skips shape validation and
    only checks the leaf count)."""
    if spec is None:
        return 0
    if "d" in spec:
        return {k: _spec_skeleton(v) for k, v in spec["d"].items()}
    if "l" in spec:
        return [_spec_skeleton(v) for v in spec["l"]]
    return tuple(_spec_skeleton(v) for v in spec["t"])


# ---------------------------------------------------------------------------
# The artifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SnapshotMeta:
    """Identity + provenance of one snapshot.

    schema_version  on-disk format gate (load refuses a mismatch)
    cfg_digest      hash of the model config (engine refuses a swap
                    across digests)
    n_objects       live objects in the buffers (counts.sum())
    built_at        unix seconds the snapshot (version) was created
    version         monotone publish counter; bumped by with_buffers,
                    keys the server's result caches
    dist_max        Eq. 5 distance normalizer the params trained under
    spatial_mode    "step" | "exp" | "linear" (how w_hat derives)
    weight_mode     "mlp" | "fixed" (how the ST mixing weights derive)
    precision       "f32" | "bf16" | "int8" — the buffers' storage tier
                    (DESIGN.md §9); load refuses an unknown tier BEFORE
                    reading any array
    delta_rows      rows pending in the delta segment (0 = compacted)
    n_tombstones    ids deleted from the base since the last compaction
    n_shards        device shards the cluster buffers are partitioned
                    across (DESIGN.md §12); 1 = single-device. Placement
                    provenance, NOT content identity: with_mesh derives
                    a re-placed snapshot withOUT a version bump (results
                    are bit-identical by the parity contract), and
                    load() always normalizes to 1 — the artifact's
                    arrays are global, re-shard after loading

    ``n_objects`` counts the BASE buffers only (counts.sum()); the live
    corpus size is ``n_objects - n_tombstones + delta_rows`` assuming
    every tombstone hits a base row.
    """
    schema_version: int
    cfg_digest: str
    n_objects: int
    built_at: float
    version: int
    dist_max: float
    spatial_mode: str = "step"
    weight_mode: str = "mlp"
    precision: str = "f32"
    delta_rows: int = 0
    n_tombstones: int = 0
    n_shards: int = 1


@dataclasses.dataclass(frozen=True)
class IndexSnapshot:
    """A frozen, versioned, servable LIST index.

    Fields: ``cfg`` (DualEncoderConfig), ``rel_params`` /
    ``index_params`` (trained pytrees), ``norm`` (location-normalizer
    bounds), ``buffers`` (packed cluster buffers of
    ``index.build_cluster_buffers``), ``meta`` (:class:`SnapshotMeta`).

    Construction: :meth:`from_parts` (fresh, version 0),
    :meth:`with_buffers` (derive: new buffers, version + 1),
    :meth:`with_delta` (derive: new delta segment, version + 1),
    :meth:`compact` (fold delta into base, version + 1),
    :meth:`load` (from disk). Never mutate a field — the whole point is
    that holders of a snapshot reference can trust it forever.

    ``delta`` is the optional LSM-style mutable overlay
    (:class:`repro.core.delta.DeltaSegment`, DESIGN.md §11): rows
    inserted since the base buffers were built plus tombstoned ids.
    ``None`` means "no pending mutations" (base-only fast path).

    ``shards`` is the optional mesh placement
    (:class:`repro.distributed.sharding.ClusterShards`, DESIGN.md §12):
    per-device committed partitions of the cluster buffers along the
    cluster axis, derived by :meth:`with_mesh`. When set, ``buffers``
    holds the HOST-side global arrays (shapes / persistence / compaction
    — device memory only carries the per-shard parts) and
    ``QueryEngine.query`` runs the per-shard plan + tree merge instead
    of the single-device scan. ``None`` = unsharded (the default).
    """
    cfg: DualEncoderConfig
    rel_params: Any
    index_params: Any
    norm: Any
    buffers: dict
    meta: SnapshotMeta
    delta: Optional[delta_lib.DeltaSegment] = None
    shards: Optional[Any] = None

    # --- construction -----------------------------------------------------

    @classmethod
    def from_parts(cls, cfg, rel_params, index_params, norm, buffers, *,
                   dist_max: float, spatial_mode: str = "step",
                   weight_mode: str = "mlp", version: int = 0,
                   built_at: Optional[float] = None) -> "IndexSnapshot":
        missing = [k for k in _BUFFER_ARRAYS + _BUFFER_SCALARS
                   if k not in buffers]
        if missing:
            raise ValueError(f"buffers missing keys {missing}; expected the "
                             f"dict of index.build_cluster_buffers")
        precision = buffers.get("precision", "f32")
        if precision not in index_lib.PRECISIONS:
            raise ValueError(f"buffers carry unknown precision "
                             f"{precision!r}; expected one of "
                             f"{index_lib.PRECISIONS}")
        meta = SnapshotMeta(
            schema_version=SCHEMA_VERSION, cfg_digest=cfg_digest(cfg),
            n_objects=int(np.asarray(buffers["counts"]).sum()),
            built_at=time.time() if built_at is None else float(built_at),
            version=int(version), dist_max=float(dist_max),
            spatial_mode=spatial_mode, weight_mode=weight_mode,
            precision=precision)
        return cls(cfg=cfg, rel_params=rel_params, index_params=index_params,
                   norm=norm, buffers=buffers, meta=meta)

    def with_buffers(self, buffers: dict) -> "IndexSnapshot":
        """Derive the successor snapshot: same params, new buffers,
        ``meta.version + 1``. This is the ONLY sanctioned way corpus
        mutations become servable — build new buffers (index.insert_objects
        / delete_objects), derive, publish. The precision tier is part of
        the snapshot's identity: a derivation must preserve it (switch
        tiers through :meth:`with_precision` instead)."""
        if buffers.get("precision", "f32") != self.meta.precision:
            raise ValueError(
                f"with_buffers: buffers are "
                f"{buffers.get('precision', 'f32')!r} but this snapshot is "
                f"{self.meta.precision!r}; use with_precision to change "
                f"tiers")
        meta = dataclasses.replace(
            self.meta, version=self.meta.version + 1, built_at=time.time(),
            n_objects=int(np.asarray(buffers["counts"]).sum()))
        # content changed: a predecessor's mesh parts are stale, re-shard
        out = dataclasses.replace(self, buffers=buffers, meta=meta,
                                  shards=None)
        return out._reshard_like(self)

    def with_mesh(self, mesh, *, assignment=None) -> "IndexSnapshot":
        """Derive the same snapshot with its cluster buffers partitioned
        across a device mesh (DESIGN.md §12): ``mesh`` is a shard count
        or a mesh carrying the ``cluster`` axis; ``assignment`` an
        optional ``(c,)`` cluster→shard map. Router/relevance params
        replicate (they stay plain snapshot fields — every per-shard
        plan reads the same reference).

        Placement, NOT content: results are bit-identical to the
        unsharded snapshot (the parity contract the mesh test tier
        pins), so the version does NOT bump and server result caches
        keyed on it stay valid across a re-shard publish. ``buffers``
        drops to host numpy — device memory holds only the per-shard
        parts. ``with_mesh(None)`` (or :meth:`unshard`) removes the
        placement. A non-empty delta segment rides along unsharded (it
        is small and host-merged, DESIGN.md §11)."""
        from repro.distributed import sharding as sharding_lib

        if mesh is None:
            return self.unshard()
        host = {k: np.asarray(self.buffers[k]) for k in _BUFFER_ARRAYS}
        for k in _BUFFER_SCALARS + ("precision",):
            host[k] = self.buffers[k]
        shards = sharding_lib.shard_cluster_buffers(host, mesh,
                                                    assignment=assignment)
        meta = dataclasses.replace(self.meta, n_shards=shards.n_shards)
        return dataclasses.replace(self, buffers=host, shards=shards,
                                   meta=meta)

    def unshard(self) -> "IndexSnapshot":
        """Drop the mesh placement: single-device serving again, with
        the global buffers re-materialized as device arrays (the
        unsharded fast path keeps them resident). No version bump —
        the placement inverse of :meth:`with_mesh`."""
        if self.shards is None and self.meta.n_shards == 1:
            return self
        buffers = dict(self.buffers)
        for k in _BUFFER_ARRAYS:
            buffers[k] = jnp.asarray(buffers[k])
        meta = dataclasses.replace(self.meta, n_shards=1)
        return dataclasses.replace(self, buffers=buffers, shards=None,
                                   meta=meta)

    def _reshard_like(self, predecessor: "IndexSnapshot") -> "IndexSnapshot":
        """Re-derive the mesh placement after a content change: buffer
        contents (or the cluster count) changed, so the predecessor's
        parts are stale — re-shard onto the same device count with the
        default block assignment (a custom assignment cannot survive a
        cluster-count change)."""
        if predecessor.shards is None:
            return self
        return self.with_mesh(predecessor.shards.n_shards)

    def with_delta(self, delta: delta_lib.DeltaSegment) -> "IndexSnapshot":
        """Derive the successor snapshot with a new delta segment:
        same params and base buffers, ``meta.version + 1``. This is the
        O(batch) write path (DESIGN.md §11) — append/tombstone on the
        delta, derive, publish; the base is untouched until
        :meth:`compact` folds the delta in."""
        if delta.precision != self.meta.precision:
            raise ValueError(
                f"with_delta: delta is {delta.precision!r} but this "
                f"snapshot is {self.meta.precision!r}; quantization tiers "
                f"must match for pre/post-compaction score parity")
        meta = dataclasses.replace(
            self.meta, version=self.meta.version + 1, built_at=time.time(),
            delta_rows=delta.n_rows, n_tombstones=delta.n_tombstones)
        return dataclasses.replace(self, delta=delta, meta=meta)

    def compact(self, *, spill: int = 3) -> "IndexSnapshot":
        """Fold the delta into the base buffers: tombstoned rows become
        padding (``index.delete_objects``), pending rows route into
        their clusters through the §4.3 policy (``index.insert_objects``
        — re-quantized from the raw f32 rows the delta kept, so stored
        values bit-match the delta-resident ones). One version bump;
        query results are unchanged by construction. Returns ``self``
        when there is nothing to fold."""
        if self.delta is None or self.delta.is_empty:
            return self
        buf = self.buffers
        if self.delta.tombstones:
            buf = index_lib.delete_objects(buf, self.delta.tombstone_array())
        arrs = self.delta.arrays()
        if arrs["ids"].shape[0]:
            buf = index_lib.insert_objects(
                buf, self.index_params, self.norm,
                arrs["raw"], arrs["loc"], arrs["ids"], spill=spill,
                new_attrs=arrs["attrs"])
        meta = dataclasses.replace(
            self.meta, version=self.meta.version + 1, built_at=time.time(),
            n_objects=int(np.asarray(buf["counts"]).sum()),
            delta_rows=0, n_tombstones=0)
        out = dataclasses.replace(self, buffers=buf, delta=None, meta=meta,
                                  shards=None)
        return out._reshard_like(self)

    def with_precision(self, precision: str) -> "IndexSnapshot":
        """Derive the same index at another precision tier (DESIGN.md §9):
        requantized buffers (``index.quantize_buffers`` — loc/ids/counts
        untouched, so routing, SRel, and padding stay bit-identical),
        ``meta.precision`` swapped, ``meta.version + 1``. Only defined
        FROM the exact f32 tier; returns ``self`` when already there."""
        if precision == self.meta.precision:
            return self
        if self.delta is not None and not self.delta.is_empty:
            raise ValueError(
                "with_precision: snapshot has a non-empty delta segment; "
                "compact() first so pending mutations requantize with the "
                "base instead of being carried at the old tier")
        buffers = index_lib.quantize_buffers(self.buffers, precision)
        meta = dataclasses.replace(
            self.meta, precision=precision, version=self.meta.version + 1,
            built_at=time.time())
        out = dataclasses.replace(self, buffers=buffers, meta=meta,
                                  shards=None)
        return out._reshard_like(self)

    # --- derived serve-form state -----------------------------------------

    @property
    def w_hat(self):
        """Serve-form spatial step table (Eq. 5), derived from rel_params."""
        if self.meta.spatial_mode == "step":
            return sp.extract_lookup(self.rel_params["spatial"])
        return jnp.linspace(0, 1, self.cfg.spatial_t)

    @property
    def dist_max(self) -> float:
        return self.meta.dist_max

    # --- persistence ------------------------------------------------------

    def _tree(self) -> dict:
        tree = {
            "rel_params": self.rel_params,
            "index_params": self.index_params,
            "norm": self.norm,
            "buffers": {k: self.buffers[k] for k in _BUFFER_ARRAYS},
        }
        if self.delta is not None and not self.delta.is_empty:
            # canonical single-chunk form + tombstone array; the
            # manifest's tree_spec records the extra subtree, so loads
            # of delta-free artifacts need no special casing
            tree["delta"] = self.delta.to_leaves()
        return tree

    def save(self, directory: str, *, keep: int = 3) -> str:
        """Persist under ``directory`` (ckpt step = meta.version; atomic
        commit, keep-k GC). Returns the committed path.

        A directory holds ONE snapshot lineage: load() serves the
        highest committed version, so writing a lower version than the
        directory already holds would leave the old artifact as the
        load target while looking like a successful save — refused.
        """
        latest = ckpt.latest_step(directory)
        if latest is not None and latest > self.meta.version:
            raise ValueError(
                f"snapshot.save: {directory} already holds version "
                f"{latest} > this snapshot's {self.meta.version}; load() "
                f"would keep serving the old artifact. Save a successor "
                f"of that lineage, or use a fresh directory")
        tree = self._tree()
        meta = dataclasses.asdict(self.meta)
        meta.update({
            "cfg": dataclasses.asdict(self.cfg),
            "tree_spec": _tree_spec(tree),
            **{k: int(self.buffers[k]) for k in _BUFFER_SCALARS},
        })
        return ckpt.save(directory, self.meta.version, tree, meta=meta,
                         keep=keep)

    @classmethod
    def load(cls, directory: str,
             step: Optional[int] = None) -> "IndexSnapshot":
        """Load a committed snapshot (latest version unless ``step``).

        Raises a clear ``ValueError`` on a schema-version mismatch — a
        snapshot written by an incompatible build must never be silently
        reinterpreted — and ``FileNotFoundError`` when the directory has
        no committed snapshot.
        """
        meta, step = ckpt.read_meta(directory, step=step)
        got = meta.get("schema_version")
        if got != SCHEMA_VERSION:
            raise ValueError(
                f"snapshot schema mismatch in {directory}: artifact has "
                f"schema_version={got!r}, this build reads "
                f"{SCHEMA_VERSION}; re-build the index (repro.api.build) "
                f"or load with the matching code version")
        precision = meta.get("precision")
        if precision not in index_lib.PRECISIONS:
            # gate BEFORE restore: an unknown tier means the arrays would
            # be misinterpreted (e.g. int8 payload scored as raw floats)
            raise ValueError(
                f"snapshot precision mismatch in {directory}: artifact "
                f"declares precision={precision!r}, this build understands "
                f"{index_lib.PRECISIONS}; upgrade the code or re-build "
                f"the index at a supported tier")
        cfg = _cfg_from_dict(meta["cfg"])
        if cfg_digest(cfg) != meta["cfg_digest"]:
            raise ckpt.SnapshotCorrupt(
                f"snapshot cfg_digest mismatch in {directory}: manifest "
                f"says {meta['cfg_digest']} but the stored config hashes "
                f"to {cfg_digest(cfg)}; artifact is corrupt")
        skeleton = _spec_skeleton(meta["tree_spec"])
        tree, _, _ = ckpt.restore(directory, skeleton, step=step)
        # ckpt.restore hands back host numpy; re-materialize as jax
        # arrays so a loaded snapshot behaves exactly like a built one
        # (numpy params captured as jit constants cannot be indexed by
        # traced token ids — the embedding gather would throw)
        tree = jax.tree_util.tree_map(jnp.asarray, tree)
        buffers = dict(tree["buffers"])
        for k in _BUFFER_SCALARS:
            buffers[k] = int(meta[k])
        buffers["precision"] = precision
        delta = None
        if "delta" in tree:
            delta = delta_lib.DeltaSegment.from_leaves(
                int(buffers["emb"].shape[-1]), precision, tree["delta"])
        # n_shards normalizes to 1: the artifact's arrays are GLOBAL
        # (gather-on-save), so placement never survives the trip — the
        # manifest's value is provenance only. Re-shard with with_mesh
        # (or api.load(mesh=)) under the loading host's device count.
        sm = SnapshotMeta(
            schema_version=meta["schema_version"],
            cfg_digest=meta["cfg_digest"], n_objects=meta["n_objects"],
            built_at=meta["built_at"], version=meta["version"],
            dist_max=meta["dist_max"], spatial_mode=meta["spatial_mode"],
            weight_mode=meta["weight_mode"], precision=precision,
            delta_rows=meta.get("delta_rows", 0),
            n_tombstones=meta.get("n_tombstones", 0), n_shards=1)
        return cls(cfg=cfg, rel_params=tree["rel_params"],
                   index_params=tree["index_params"], norm=tree["norm"],
                   buffers=buffers, meta=sm, delta=delta)


def load(directory: str, step: Optional[int] = None) -> IndexSnapshot:
    """Module-level alias of :meth:`IndexSnapshot.load`."""
    return IndexSnapshot.load(directory, step=step)


def load_latest_good(directory: str) -> IndexSnapshot:
    """Load the newest committed snapshot that actually restores.

    Recovery entry point (DESIGN.md §14): walks the directory's
    committed steps newest-first, skipping any that raise
    :class:`~repro.checkpoint.ckpt.SnapshotCorrupt` (damaged manifest,
    checksum-failed or missing leaf, digest mismatch). Schema/precision
    mismatches are NOT skipped — those are plain ``ValueError``s and
    mean the wrong build, not a damaged artifact. Raises
    ``FileNotFoundError`` when no step loads."""
    steps = ckpt.all_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed snapshots in {directory}")
    corrupt = []
    for step in reversed(steps):
        try:
            return IndexSnapshot.load(directory, step=step)
        except ckpt.SnapshotCorrupt as e:
            corrupt.append((step, str(e)))
    raise FileNotFoundError(
        f"no loadable snapshot in {directory}: every committed step is "
        f"corrupt — {corrupt}")
