"""Continuous spatial-keyword queries: a standing-query subscription
engine over the streaming write path (DESIGN.md §13).

A one-shot query asks "what matches now"; a *continuous* query asks
"tell me whenever a NEW object matches". This module keeps standing
queries resident — encoded once, routed once — and evaluates every
insert batch against the whole roster with the cluster-major plan run
in REVERSE: instead of streaming resident clusters against a query
batch, the freshly inserted objects are grouped by their assigned
cluster and each distinct cluster's group is scored against that
cluster's subscribed queries in one ``score_candidates`` matmul. Per
insert batch the dispatch cost is O(distinct assigned clusters), not
O(subscriptions) — the same dedup economics as pallas-cm, applied to
the write path.

Match semantics (deterministic, replicable by an oracle that re-runs
the one-shot pipeline per insert):

    match(q, o)  ⟺  assign(o) ∈ route(q, cr)
                 ∧  predicate(attrs(o), q.filters)        (core/filters.py)
                 ∧  ST(q, o) ≥ q.threshold                (Eq. 5 serve form)

``assign(o)`` is the ARGMAX cluster of the trained router
(``index.assign_clusters``, top=1) — deliberately NOT the §4.3 spill
placement, which depends on buffer fill state and would make matches
irreproducible. ``ST`` is scored on the QUANTIZED row exactly as the
delta scan stores it, so a notification's score equals what a one-shot
re-query of the standing query would report for that row
(tests/test_continuous.py).

Snapshot hot-swaps: registry membership is independent of the engine's
snapshot reference, so subscriptions survive every publish. Routes and
encodings are recomputed only when a publish actually changes the
routing inputs (``rel_params`` / ``index_params`` / ``norm`` object
identity) — delta appends and compactions reuse the same param objects
and trigger nothing. Delivery is exactly-once by construction: the
server dispatches each insert batch synchronously, once, after the
successor snapshot is published; later swaps never re-dispatch.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
from typing import Dict, List, Optional

import numpy as np

from repro.core import engine as engine_lib
from repro.core import filters as filters_lib
from repro.core import index as index_lib

_CLOSED = object()          # queue sentinel injected by Subscription.close


@dataclasses.dataclass(frozen=True)
class Notification:
    """One matched (standing query, inserted object) pair.

    ``version`` is the snapshot version the insert batch published —
    the generation whose delta physically holds the object."""
    sub_id: int
    object_id: int
    score: float
    version: int


class Subscription:
    """One standing query: an async iterator of :class:`Notification`.

    Consumed with ``async for note in sub``; ends when :meth:`close` is
    called and the queue drains. :meth:`drain` is the synchronous
    convenience for replay-style tests and benchmarks — it pops every
    notification delivered so far without awaiting.
    """

    def __init__(self, sub_id: int, tokens, mask, loc, *,
                 filters: Optional[filters_lib.FilterSpec],
                 threshold: float, cr: int):
        self.sub_id = int(sub_id)
        self.tokens = np.ascontiguousarray(np.asarray(tokens, np.int32))
        self.mask = np.ascontiguousarray(np.asarray(mask, bool))
        self.loc = np.ascontiguousarray(np.asarray(loc, np.float32))
        self.filters = filters
        self.threshold = float(threshold)
        self.cr = int(cr)
        self.closed = False
        self.n_notified = 0
        # resident serve-side state, owned by the registry
        self.q_emb: Optional[np.ndarray] = None      # (d,)
        self.w_st: Optional[np.ndarray] = None       # (2,)
        self.routes: Optional[np.ndarray] = None     # (cr,)
        # put_nowait needs no running loop, so the server's synchronous
        # write path can deliver; awaiting consumers wake on their loop
        self._queue: "asyncio.Queue" = asyncio.Queue()

    def _push(self, note: Notification):
        self.n_notified += 1
        self._queue.put_nowait(note)

    def close(self):
        if not self.closed:
            self.closed = True
            self._queue.put_nowait(_CLOSED)

    def drain(self) -> List[Notification]:
        out = []
        while True:
            try:
                item = self._queue.get_nowait()
            except asyncio.QueueEmpty:
                return out
            if item is _CLOSED:
                self._queue.put_nowait(_CLOSED)   # keep the iterator ending
                return out
            out.append(item)

    def __aiter__(self):
        return self

    async def __anext__(self) -> Notification:
        if self.closed and self._queue.empty():
            raise StopAsyncIteration
        item = await self._queue.get()
        if item is _CLOSED:
            raise StopAsyncIteration
        return item


class SubscriptionRegistry:
    """The resident standing-query roster + its insert-batch dispatcher.

    Owned by a :class:`~repro.core.server.StreamingServer` (or used
    standalone around a :class:`~repro.core.engine.QueryEngine`). All
    mutation and dispatch runs on the server's single event-loop thread
    — no locking. ``cr`` is the routing fanout every subscription is
    matched under (one roster per registry keeps dispatch one pass).
    """

    def __init__(self, engine: engine_lib.QueryEngine, *, cr: int = 1):
        self.engine = engine
        self.cr = int(cr)
        self._subs: Dict[int, Subscription] = {}
        self._ids = itertools.count()
        self._dirty = True                   # resident stacks need rebuild
        self._routing_key = self._routing_identity(engine.snapshot)
        # cumulative dispatch economics (server.metrics() reads these)
        self.n_dispatches = 0
        self.n_objects_seen = 0
        self.n_distinct_clusters = 0         # Σ distinct assigned clusters
        self.n_notifications = 0
        self.n_reroutes = 0
        # rebuilt-on-demand resident stacks (S = len(self._subs))
        self._stack = None

    # --- membership -------------------------------------------------------

    def __len__(self) -> int:
        return len(self._subs)

    def register(self, tokens, mask, loc, *, filters=None,
                 threshold: float = 0.0) -> Subscription:
        """Add a standing query; encodes + routes it against the CURRENT
        snapshot immediately so the first dispatch after registration
        already sees it."""
        if filters is not None and not isinstance(filters,
                                                  filters_lib.FilterSpec):
            raise TypeError(f"filters must be a FilterSpec or None, "
                            f"got {type(filters)}")
        sub = Subscription(next(self._ids), tokens, mask, loc,
                           filters=filters, threshold=threshold, cr=self.cr)
        self._encode(sub, self.engine.snapshot)
        self._subs[sub.sub_id] = sub
        self._dirty = True
        return sub

    def unregister(self, sub_id: int):
        sub = self._subs.pop(int(sub_id), None)
        if sub is not None:
            sub.close()
            self._dirty = True

    # --- routing residency ------------------------------------------------

    def _encode(self, sub: Subscription, snap):
        """Encode + route one subscription on ``snap``'s params (the
        sharded-path prefix plan: one compile per cr, batch 1)."""
        prefix = self.engine.prefix_fn(cr=self.cr)
        q_emb, w, top_c = prefix(snap.rel_params, snap.index_params,
                                 snap.norm, sub.tokens[None],
                                 sub.mask[None], sub.loc[None])
        sub.q_emb = np.asarray(q_emb)[0]
        sub.w_st = np.asarray(w)[0]
        sub.routes = np.asarray(top_c)[0]

    @staticmethod
    def _routing_identity(snap):
        return (id(snap.rel_params), id(snap.index_params), id(snap.norm))

    def on_publish(self, snap):
        """Called after every snapshot publish. Delta appends and
        compactions reuse the same param objects — free. A publish that
        swaps routing inputs (retrained params) re-encodes and re-routes
        every subscription once."""
        key = self._routing_identity(snap)
        if key == self._routing_key:
            return
        if self._subs:
            for sub in self._subs.values():
                self._encode(sub, snap)
            self.n_reroutes += 1
            self._dirty = True
        self._routing_key = key

    def _stacks(self):
        """Resident stacked arrays + cluster→subscription roster."""
        if not self._dirty and self._stack is not None:
            return self._stack
        subs = list(self._subs.values())
        fvals = np.stack([(s.filters or filters_lib.NOOP_FILTER).to_fvals()
                          for s in subs]) if subs else \
            np.zeros((0, filters_lib.N_FVALS), np.int32)
        stack = {
            "subs": subs,
            "q_emb": np.stack([s.q_emb for s in subs]) if subs else None,
            "w_st": np.stack([s.w_st for s in subs]) if subs else None,
            "loc": np.stack([s.loc for s in subs]) if subs else None,
            "thr": np.array([s.threshold for s in subs], np.float32),
            "fvals": fvals,
            "roster": {},                 # cluster id -> sub row indices
        }
        for row, s in enumerate(subs):
            for c in np.unique(s.routes):
                stack["roster"].setdefault(int(c), []).append(row)
        stack["roster"] = {c: np.asarray(rows, np.int64)
                           for c, rows in stack["roster"].items()}
        self._stack = stack
        self._dirty = False
        return stack

    # --- the reversed cluster-major dispatch ------------------------------

    def dispatch(self, new_emb, new_loc, new_ids, new_attrs=None,
                 snapshot=None) -> List[Notification]:
        """Evaluate one insert batch against the whole roster.

        Groups the batch by argmax-assigned cluster and scores each
        distinct cluster's object group against that cluster's
        subscribed queries in one matmul — the cluster-major plan with
        the roles of resident/streamed swapped. Rows are quantized to
        the snapshot's precision tier first, so scores equal what the
        delta scan will report for the same rows. Returns (and pushes)
        the notifications, in (cluster, subscription row, object) order.
        """
        snap = self.engine.snapshot if snapshot is None else snapshot
        self.n_dispatches += 1
        n = np.asarray(new_ids).reshape(-1).shape[0]
        self.n_objects_seen += n
        if not self._subs or n == 0:
            return []
        st = self._stacks()
        emb = np.asarray(new_emb, np.float32).reshape(n, -1)
        loc = np.asarray(new_loc, np.float32).reshape(n, 2)
        ids = np.asarray(new_ids, np.int32).reshape(n)
        attrs = filters_lib.validate_attrs(new_attrs, n)
        # the oracle-replicable assignment: argmax router cluster
        feats = index_lib.build_features(emb, loc, snap.norm)
        assign = np.asarray(index_lib.assign_clusters(
            snap.index_params, feats, top=1)).reshape(n)
        # score the QUANTIZED rows — bit-parity with the delta scan
        stored, scale = index_lib.quantize_rows(emb, snap.meta.precision)
        cand_scale = scale if snap.meta.precision == "int8" else None
        w_hat = np.asarray(snap.w_hat)
        notes: List[Notification] = []
        version = int(snap.meta.version)
        distinct = [int(c) for c in np.unique(assign)
                    if int(c) in st["roster"]]
        self.n_distinct_clusters += len(distinct)
        for c in distinct:
            rows = st["roster"][c]                    # (S_c,) sub rows
            sel = np.flatnonzero(assign == c)         # (m_c,) object rows
            scores = np.asarray(engine_lib.score_candidates(
                st["q_emb"][rows], st["loc"][rows], st["w_st"][rows],
                stored[sel][None], loc[sel][None], ids[sel][None],
                w_hat, dist_max=snap.meta.dist_max,
                cand_scale=None if cand_scale is None
                else cand_scale[sel][None],
                cand_attrs=attrs[sel][None],
                fvals=st["fvals"][rows]))             # (S_c, m_c)
            hit = ((scores >= st["thr"][rows][:, None])
                   & (scores > engine_lib.NEG_INF / 2))
            for i, j in zip(*np.nonzero(hit)):
                sub = st["subs"][rows[i]]
                note = Notification(sub.sub_id, int(ids[sel[j]]),
                                    float(scores[i, j]), version)
                sub._push(note)
                notes.append(note)
        self.n_notifications += len(notes)
        return notes

    # --- reporting --------------------------------------------------------

    def metrics(self) -> dict:
        d = max(self.n_dispatches, 1)
        return {
            "subscriptions": len(self._subs),
            "dispatches": self.n_dispatches,
            "objects_seen": self.n_objects_seen,
            "notifications": self.n_notifications,
            "distinct_clusters": self.n_distinct_clusters,
            "distinct_clusters_per_dispatch": self.n_distinct_clusters / d,
            "reroutes": self.n_reroutes,
        }


__all__ = ["Notification", "Subscription", "SubscriptionRegistry"]
