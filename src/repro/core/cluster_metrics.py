"""Cluster-quality metrics (paper §4.3, Eq. 15–17): P(C) and IF(C)."""
from __future__ import annotations

import numpy as np


def imbalance_factor(obj_assign, n_clusters: int) -> float:
    """IF(C) = Σ|C_i|² / (Σ|C_i|)²·c — normalized so perfectly even = 1.0.

    (The paper reports Σ|C_i|²/(Σ|C_i|)², whose floor is 1/c; we multiply
    by c so the floor is 1.0 regardless of c, matching the magnitudes the
    paper tabulates, e.g. 1.3–1.5 for c=20.)
    """
    sizes = np.bincount(np.asarray(obj_assign), minlength=n_clusters)
    return imbalance_factor_from_counts(sizes)


def imbalance_factor_from_counts(counts) -> float:
    """IF(C) from the per-cluster size vector directly (uniform = 1.0).

    The serving stack's compaction trigger uses this on the buffers'
    live ``counts`` (core/server.py) — the assignment vector of
    :func:`imbalance_factor` doesn't exist for a mutated index whose
    objects never lived in one array.
    """
    sizes = np.asarray(counts, np.float64)
    tot = sizes.sum()
    if tot == 0:
        return 0.0
    return float((sizes ** 2).sum() / tot**2 * sizes.shape[0])


def cluster_precision(q_assign, positives, obj_assign, n_clusters: int):
    """P(C) (Eq. 15–16): per-cluster mean fraction of each routed query's
    positives that landed in the same cluster, weighted by queries routed.

    q_assign: (B,) cluster per validation query.
    positives: list of B int arrays (ground-truth object ids per query).
    obj_assign: (N,) cluster per object.
    """
    q_assign = np.asarray(q_assign)
    obj_assign = np.asarray(obj_assign)
    num = np.zeros(n_clusters)
    cnt = np.zeros(n_clusters)
    for qa, pos in zip(q_assign, positives):
        pos = np.asarray(pos)
        if pos.size == 0:
            continue
        frac = (obj_assign[pos] == qa).mean()
        num[qa] += frac
        cnt[qa] += 1
    mask = cnt > 0
    pc_i = np.zeros(n_clusters)
    pc_i[mask] = num[mask] / cnt[mask]
    total_q = cnt.sum()
    if total_q == 0:
        return 0.0, pc_i
    pc = float((pc_i * cnt).sum() / total_q)
    return pc, pc_i


def recall_at_k(retrieved, positives, k: int) -> float:
    """Mean over queries of |top-k ∩ positives| / |positives|."""
    vals = []
    for r, p in zip(retrieved, positives):
        p = set(int(x) for x in np.asarray(p).tolist())
        if not p:
            continue
        r = [int(x) for x in np.asarray(r)[:k].tolist()]
        vals.append(len(p.intersection(r)) / len(p))
    return float(np.mean(vals)) if vals else 0.0


def ndcg_at_k(retrieved, positives, k: int) -> float:
    """Binary-relevance NDCG@k (paper §5.1)."""
    vals = []
    for r, p in zip(retrieved, positives):
        p = set(int(x) for x in np.asarray(p).tolist())
        if not p:
            continue
        r = [int(x) for x in np.asarray(r)[:k].tolist()]
        dcg = sum(1.0 / np.log2(i + 2) for i, x in enumerate(r) if x in p)
        ideal = sum(1.0 / np.log2(i + 2) for i in range(min(len(p), k)))
        vals.append(dcg / ideal if ideal > 0 else 0.0)
    return float(np.mean(vals)) if vals else 0.0
