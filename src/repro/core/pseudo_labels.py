"""Pseudo-negative label generation (paper §4.3, Eq. 13).

neg_q = argsort_{o ∈ D} ST(q, o)[neg_start : neg_end],  s(q, o) = 0

The trained relevance model ranks the whole corpus per training query; the
window [neg_start, neg_end) selects negatives of controlled hardness —
small neg_start → harder negatives → tighter, more selective clusters
(higher efficiency), at some effectiveness risk; the knob IS the paper's
effectiveness/efficiency trade-off (Fig. 8).

TPU-native realization: we never materialize a full argsort of N. Scores
are computed shard-parallel over the corpus (optionally with the fused
Pallas kernel) and ``lax.top_k(neg_end)`` runs per shard followed by a
global merge — O(N + B·neg_end log) instead of O(N log N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import relevance
from repro.distributed.sharding import constrain


def mine_negatives(params, cfg, q_emb, q_loc, obj_emb, obj_loc, *,
                   pos_mask=None, neg_start: int, neg_end: int,
                   dist_max=1.0, batch_queries: int = 256,
                   spatial_mode="step", weight_mode="mlp"):
    """Returns (B, neg_end - neg_start) int32 object indices.

    pos_mask: optional (B, N) bool — ground-truth positives to exclude
    (the s(q,o)=0 filter in Eq. 13).
    """
    n = obj_emb.shape[0]
    neg_end = min(neg_end, n)
    neg_start = min(neg_start, neg_end - 1)

    def score_block(qe, ql, pm):
        st = relevance.score_corpus(params, qe, ql, obj_emb, obj_loc, cfg,
                                    dist_max=dist_max, train=False,
                                    spatial_mode=spatial_mode,
                                    weight_mode=weight_mode)
        if pm is not None:
            st = jnp.where(pm, -jnp.inf, st)
        # top-neg_end then window slice == argsort window (Eq. 13)
        _, idx = jax.lax.top_k(st, neg_end)
        return idx[:, neg_start:]

    outs = []
    b = q_emb.shape[0]
    for s in range(0, b, batch_queries):
        e = min(s + batch_queries, b)
        pm = None if pos_mask is None else pos_mask[s:e]
        outs.append(score_block(q_emb[s:e], q_loc[s:e], pm))
    return jnp.concatenate(outs, axis=0)


def mine_negatives_dense(params, cfg, q_emb, q_loc, obj_emb, obj_loc, *,
                         neg_start: int, neg_end: int, dist_max=1.0,
                         shards: int = 256, per_shard_k: int = 0):
    """Mesh-native mining step (what the dry-run lowers at Geo-Glue scale).

    The corpus is sharded over all chips; scoring is a single sharded einsum.
    The argsort window (Eq. 13) is realized as per-shard ``top_k`` +
    a global merge of the (B, shards·k') survivors — never a full argsort
    of N. k' ≥ 4·neg_end/shards oversamples so the true window survives the
    merge with overwhelming probability (the window is a *hardness band*,
    not an exact set — the paper's own knob is coarse).
    """
    n = obj_emb.shape[0]
    ns = n // shards
    per_shard_k = per_shard_k or min(ns, max(64, 4 * neg_end // shards))
    st = relevance.score_corpus(params, q_emb, q_loc, obj_emb, obj_loc, cfg,
                                dist_max=dist_max, train=False)   # (B, N)
    st = constrain(st, "dp", "tp")
    b = st.shape[0]
    st3 = st.reshape(b, shards, ns)
    v, i = jax.lax.top_k(st3, per_shard_k)            # (B, shards, k')
    base = (jnp.arange(shards, dtype=jnp.int32) * ns)[None, :, None]
    i = i + base
    v = v.reshape(b, shards * per_shard_k)
    i = i.reshape(b, shards * per_shard_k)
    k_merge = min(neg_end, v.shape[1])
    _, merge = jax.lax.top_k(v, k_merge)
    idx = jnp.take_along_axis(i, merge, axis=1)
    return idx[:, min(neg_start, k_merge - 1):]


def mine_negatives_sharded(params, cfg, q_emb, q_loc, obj_emb, obj_loc, *,
                           neg_start: int, neg_end: int, dist_max=1.0,
                           shards: int = 1):
    """Shard-parallel variant: per-shard top_k(neg_end) + global merge.

    This is the form the dry-run lowers on the production mesh — obj_emb is
    sharded over all chips; the merge is a single all-gather of
    (B, shards·neg_end) score/index pairs instead of the full corpus.
    """
    n = obj_emb.shape[0]
    assert n % shards == 0
    ns = n // shards
    obj_e = obj_emb.reshape(shards, ns, -1)
    obj_l = obj_loc.reshape(shards, ns, 2)

    def shard_topk(oe, ol, base):
        st = relevance.score_corpus(params, q_emb, q_loc, oe, ol, cfg,
                                    dist_max=dist_max, train=False)
        k = min(neg_end, ns)
        v, i = jax.lax.top_k(st, k)
        return v, i + base

    vs, is_ = [], []
    for s in range(shards):
        v, i = shard_topk(obj_e[s], obj_l[s], s * ns)
        vs.append(v)
        is_.append(i)
    v = jnp.concatenate(vs, axis=1)
    i = jnp.concatenate(is_, axis=1)
    _, merge = jax.lax.top_k(v, neg_end)
    idx = jnp.take_along_axis(i, merge, axis=1)
    return idx[:, neg_start:]
