"""Criteo-like CTR stream + sequential-rec batches (stateless, seeded)."""
from __future__ import annotations

from typing import Sequence

import numpy as np


class CTRStream:
    """Synthetic click stream with a planted logistic ground truth so models
    can actually fit it: label ~ sigmoid(w·dense + embedding interactions)."""

    def __init__(self, n_dense: int, table_sizes: Sequence[int], *,
                 seed: int = 0):
        self.n_dense = n_dense
        self.table_sizes = tuple(table_sizes)
        self.seed = seed
        rng = np.random.default_rng(seed)
        self.w_dense = rng.normal(0, 0.5, size=n_dense)
        # hash-based per-field latent preference (no giant tables needed)
        self.field_salt = rng.integers(1, 1 << 31, size=len(table_sizes))

    def batch(self, step: int, batch: int) -> dict:
        rng = np.random.default_rng(self.seed * 31_337 + step)
        dense = rng.lognormal(0, 1, size=(batch, self.n_dense)).astype(
            np.float32)
        sparse = np.stack(
            [rng.integers(0, v, size=batch) for v in self.table_sizes],
            axis=1)
        # planted signal: parity-ish hash of (field, id)
        h = (sparse * self.field_salt[None, :]) % 97
        logit = (np.log1p(dense) @ self.w_dense) * 0.1 \
            + (h.mean(axis=1) - 48.0) * 0.08
        label = (rng.random(batch) < 1 / (1 + np.exp(-logit)))
        return {
            "dense": dense,
            "sparse": sparse.astype(np.int32),
            "label": label.astype(np.float32),
        }


class SeqRecStream:
    """Item-sequence batches for BERT4Rec (masked) and MIND (next-item)."""

    def __init__(self, n_items: int, *, seed: int = 0, n_patterns: int = 512,
                 pat_len: int = 8):
        self.n_items = n_items
        self.seed = seed
        rng = np.random.default_rng(seed)
        # users follow latent "tastes": repeating item patterns
        self.patterns = rng.integers(1, n_items + 1,
                                     size=(n_patterns, pat_len))

    def _sequences(self, rng, batch: int, seq_len: int):
        n_chunks = -(-seq_len // self.patterns.shape[1])
        pat = self.patterns[
            rng.integers(0, len(self.patterns), size=(batch, n_chunks))]
        seq = pat.reshape(batch, -1)[:, :seq_len]
        return seq

    def bert4rec_batch(self, step: int, batch: int, seq_len: int,
                       mask_prob: float = 0.2, *, mask_token: int = None,
                       max_preds: int = 20) -> dict:
        rng = np.random.default_rng(self.seed * 65_537 + step)
        mask_token = mask_token or (self.n_items + 1)
        seq = self._sequences(rng, batch, seq_len)
        is_masked = rng.random((batch, seq_len)) < mask_prob
        is_masked[:, 0] |= ~is_masked.any(axis=1)     # at least one mask
        tgt = np.where(is_masked, seq, 0)
        seq_in = np.where(is_masked, mask_token, seq)
        # gather up to max_preds masked positions per row
        pos = np.zeros((batch, max_preds), np.int32)
        mtgt = np.zeros((batch, max_preds), np.int32)
        mmask = np.zeros((batch, max_preds), np.float32)
        for i in range(batch):
            idx = np.nonzero(is_masked[i])[0][:max_preds]
            pos[i, :len(idx)] = idx
            mtgt[i, :len(idx)] = tgt[i, idx]
            mmask[i, :len(idx)] = 1.0
        return {
            "seq": seq_in.astype(np.int32),
            "mask": np.ones((batch, seq_len), bool),
            "mlm_pos": pos, "mlm_tgt": mtgt, "mlm_mask": mmask,
        }

    def mind_batch(self, step: int, batch: int, hist_len: int) -> dict:
        rng = np.random.default_rng(self.seed * 104_729 + step)
        seq = self._sequences(rng, batch, hist_len + 1)
        return {
            "hist": seq[:, :hist_len].astype(np.int32),
            "hist_mask": np.ones((batch, hist_len), bool),
            "target": seq[:, hist_len].astype(np.int32),
        }
