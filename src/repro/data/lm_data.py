"""Deterministic synthetic LM token streams.

A seeded Markov-ish stream: per-position tokens are drawn from a mixture of
(a) a repeated-ngram process (so the model has learnable structure and the
loss visibly decreases) and (b) uniform noise. Stateless — batch(step) is a
pure function of (seed, step), which makes the input pipeline
preemption-safe and host-replicable.
"""
from __future__ import annotations

import numpy as np


class LMStream:
    def __init__(self, vocab_size: int, *, seed: int = 0, ngram: int = 8,
                 n_patterns: int = 4096):
        self.vocab_size = vocab_size
        self.seed = seed
        self.ngram = ngram
        rng = np.random.default_rng(seed)
        self.patterns = rng.integers(
            2, vocab_size, size=(n_patterns, ngram), dtype=np.int64)

    def batch(self, step: int, batch: int, seq_len: int) -> dict:
        rng = np.random.default_rng(self.seed * 7_919 + step)
        n_chunks = -(-(seq_len + 1) // self.ngram)
        pat = self.patterns[
            rng.integers(0, len(self.patterns), size=(batch, n_chunks))]
        toks = pat.reshape(batch, n_chunks * self.ngram)[:, : seq_len + 1]
        noise = rng.random((batch, seq_len + 1)) < 0.05
        toks = np.where(
            noise, rng.integers(2, self.vocab_size, size=toks.shape), toks)
        return {"tokens": toks.astype(np.int32)}
