"""Deterministic synthetic data substrate (stateless, step-seeded)."""
from repro.data.geotextual import GeoCorpus, GeoCorpusConfig, scale_corpus  # noqa: F401
from repro.data.lm_data import LMStream  # noqa: F401
from repro.data.graph_data import (  # noqa: F401
    NeighborSampler,
    community_graph,
    molecule_batch,
)
from repro.data.recsys_data import CTRStream, SeqRecStream  # noqa: F401
