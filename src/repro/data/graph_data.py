"""Graph generators + fanout neighbor sampler (GNN shapes).

- ``community_graph``: planted-partition graph with community-correlated
  features/labels (full-batch cells: full_graph_sm, ogb_products geometry).
- ``molecule_batch``: batched small graphs with graph-level labels.
- ``NeighborSampler``: real fanout sampling (15-10 style) over a CSR adjacency
  built once; emits padded static-shape subgraphs (minibatch_lg cell).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np


def community_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                    *, seed: int = 0, homophily: float = 0.8):
    """Random graph with planted communities. Returns a graph dict (numpy)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, size=n_nodes)
    # community-informative features + noise
    centers = rng.normal(0, 1, size=(n_classes, d_feat))
    x = centers[labels] + rng.normal(0, 1.0, size=(n_nodes, d_feat))
    # edges: homophilous within class, else random
    src = rng.integers(0, n_nodes, size=n_edges)
    same = rng.random(n_edges) < homophily
    # destination from same class where homophilous (approx via resample)
    dst = rng.integers(0, n_nodes, size=n_edges)
    # cheap homophily: redirect same-class edges to a random same-class node
    order = np.argsort(labels, kind="stable")
    cls_start = np.searchsorted(labels[order], np.arange(n_classes))
    cls_end = np.append(cls_start[1:], n_nodes)
    lab_src = labels[src]
    lo = cls_start[lab_src]
    hi = np.maximum(cls_end[lab_src], lo + 1)
    redirect = order[(lo + rng.integers(0, 1 << 30, size=n_edges)
                      % np.maximum(hi - lo, 1))]
    dst = np.where(same, redirect, dst)
    return {
        "x": x.astype(np.float32),
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "edge_attr": None,
        "node_mask": np.ones(n_nodes, bool),
        "edge_mask": np.ones(n_edges, bool),
        "labels": labels.astype(np.int32),
        "label_mask": np.ones(n_nodes, np.float32),
    }


def molecule_batch(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                   *, seed: int = 0):
    """Batched small graphs, one regression target per graph."""
    rng = np.random.default_rng(seed)
    N, E = batch * n_nodes, batch * n_edges
    x = rng.normal(0, 1, size=(N, d_feat)).astype(np.float32)
    # edges within each graph
    src = (rng.integers(0, n_nodes, size=E)
           + np.repeat(np.arange(batch), n_edges) * n_nodes)
    dst = (rng.integers(0, n_nodes, size=E)
           + np.repeat(np.arange(batch), n_edges) * n_nodes)
    graph_ids = np.repeat(np.arange(batch), n_nodes)
    # target: mean feature norm per graph (learnable from x)
    tgt = x.reshape(batch, n_nodes, d_feat).mean((1, 2))
    return {
        "x": x,
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "edge_attr": rng.normal(0, 1, size=(E, 4)).astype(np.float32),
        "node_mask": np.ones(N, bool),
        "edge_mask": np.ones(E, bool),
        "graph_ids": graph_ids.astype(np.int32),
        "n_graphs": batch,
        "labels": tgt.astype(np.float32),
        "label_mask": np.ones(batch, np.float32),
    }


class NeighborSampler:
    """Fanout neighbor sampler over a CSR adjacency (GraphSAGE-style).

    Produces padded, static-shape subgraphs: seeds -> fanout[0] neighbors ->
    fanout[1] neighbors of those, etc. Loss is computed on seed nodes only
    (label_mask marks them).
    """

    def __init__(self, edge_src, edge_dst, n_nodes: int):
        order = np.argsort(edge_dst, kind="stable")
        self.nbr = edge_src[order]                     # in-neighbors per dst
        counts = np.bincount(edge_dst, minlength=n_nodes)
        self.ptr = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes

    def sample(self, seeds: np.ndarray, fanout: Sequence[int], *, seed: int = 0):
        rng = np.random.default_rng(seed)
        layers = [seeds.astype(np.int64)]
        edges_s, edges_d = [], []
        frontier = seeds.astype(np.int64)
        for f in fanout:
            lo, hi = self.ptr[frontier], self.ptr[frontier + 1]
            deg = hi - lo
            # sample f neighbors (with replacement; isolated nodes self-loop)
            off = rng.integers(0, 1 << 62, size=(len(frontier), f))
            idx = lo[:, None] + off % np.maximum(deg, 1)[:, None]
            nb = np.where(deg[:, None] > 0, self.nbr[idx], frontier[:, None])
            edges_s.append(nb.reshape(-1))
            edges_d.append(np.repeat(frontier, f))
            frontier = np.unique(nb.reshape(-1))
            layers.append(frontier)
        # relabel to compact local ids
        nodes = np.unique(np.concatenate(layers))
        remap = {g: l for l, g in enumerate(nodes.tolist())}
        src = np.array([remap[g] for g in np.concatenate(edges_s).tolist()],
                       np.int32)
        dst = np.array([remap[g] for g in np.concatenate(edges_d).tolist()],
                       np.int32)
        seed_local = np.array([remap[g] for g in seeds.tolist()], np.int32)
        return nodes, src, dst, seed_local

    def padded_batch(self, seeds, fanout, x, labels, *, pad_nodes: int,
                     pad_edges: int, seed: int = 0):
        nodes, src, dst, seed_local = self.sample(seeds, fanout, seed=seed)
        n, e = len(nodes), len(src)
        if n > pad_nodes or e > pad_edges:
            raise ValueError(f"sample ({n} nodes, {e} edges) exceeds padding "
                             f"({pad_nodes}, {pad_edges})")
        xb = np.zeros((pad_nodes, x.shape[1]), np.float32)
        xb[:n] = x[nodes]
        lb = np.zeros(pad_nodes, np.int32)
        lb[:n] = labels[nodes]
        lmask = np.zeros(pad_nodes, np.float32)
        lmask[seed_local] = 1.0
        sp = np.zeros(pad_edges, np.int32)
        dp = np.zeros(pad_edges, np.int32)
        sp[:e], dp[:e] = src, dst
        emask = np.zeros(pad_edges, bool)
        emask[:e] = True
        nmask = np.zeros(pad_nodes, bool)
        nmask[:n] = True
        return {
            "x": xb, "edge_src": sp, "edge_dst": dp, "edge_attr": None,
            "node_mask": nmask, "edge_mask": emask,
            "labels": lb, "label_mask": lmask,
        }
