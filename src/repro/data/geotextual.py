"""Synthetic geo-textual corpus + query-log generator with latent ground truth.

The paper's datasets (Beijing/Shanghai/Geo-Glue click logs) are proprietary;
we generate a corpus with a *planted* relevance structure so every paper
claim is checkable:

- ``n_topics`` latent topics (e.g. "italian restaurant"). Each topic owns two
  DISJOINT synonym vocabularies: an *object* vocabulary (used in POI
  descriptions, e.g. "pasta house trattoria") and a *query* vocabulary
  ("italian restaurant"). A tunable ``mismatch`` fraction of queries draws
  keywords ONLY from the query vocabulary — those pairs have zero word
  overlap, reproducing the word-mismatch phenomenon of paper Fig. 1a that
  breaks BM25 but not embeddings.

- Object locations are drawn from a mixture of spatial hotspots (cities have
  dense centers); queries are issued near a *seed object* with displacement
  following a truncated exponential — the sharp near-distance CDF of paper
  Fig. 1b that motivates the step-function spatial model.

- Ground-truth positives of a query = objects sharing its topic within a
  relevance radius of the seed (click-through proxy).

Everything is produced by a stateless, seed-deterministic numpy generator so
data loading is preemption-safe (re-seed from step) and identical across
hosts.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class GeoCorpusConfig:
    n_objects: int = 20_000
    n_queries: int = 2_000
    n_topics: int = 50
    words_per_topic: int = 12      # per synonym side
    doc_len: int = 8               # words in an object description
    query_len: int = 3             # words in query keywords
    max_len: int = 16              # token budget (incl. CLS)
    vocab_size: int = 32_768       # hashing-tokenizer space
    n_hotspots: int = 8
    hotspot_sigma: float = 0.05    # spatial spread of a hotspot
    query_dist_scale: float = 0.02  # exp displacement of query from seed
    relevance_radius: float = 0.08  # ground-truth radius
    mismatch: float = 0.35         # fraction of queries with zero overlap
    noise_words: int = 2           # background words mixed into docs
    seed: int = 0

    @property
    def cls_token(self) -> int:
        return 1                    # 0 = pad, 1 = CLS


class GeoCorpus:
    """Holds the full synthetic corpus (objects, queries, ground truth)."""

    def __init__(self, cfg: GeoCorpusConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, T, W = cfg.vocab_size, cfg.n_topics, cfg.words_per_topic

        # --- topic vocabularies: object-side and query-side, disjoint ---
        # reserve [0, 2) for pad/CLS; hash words into the rest
        words = rng.choice(np.arange(2, V), size=(T, 2 * W), replace=False)
        self.obj_vocab = words[:, :W]          # (T, W)
        self.qry_vocab = words[:, W:]          # (T, W)
        self.bg_vocab = rng.choice(np.arange(2, V), size=4 * W, replace=False)

        # --- spatial hotspots ---
        self.hotspots = rng.uniform(0.1, 0.9, size=(cfg.n_hotspots, 2))

        # --- objects ---
        n = cfg.n_objects
        self.obj_topic = rng.integers(0, T, size=n)
        hs = rng.integers(0, cfg.n_hotspots, size=n)
        self.obj_loc = (self.hotspots[hs]
                        + rng.normal(0, cfg.hotspot_sigma, size=(n, 2)))
        self.obj_loc = np.clip(self.obj_loc, 0.0, 1.0)
        # description: mostly object-side topic words + a few query-side +
        # background noise (so embeddings must learn the topic structure)
        docs = np.zeros((n, cfg.doc_len), np.int64)
        for j in range(cfg.doc_len):
            r = rng.random(n)
            w_obj = self.obj_vocab[self.obj_topic,
                                   rng.integers(0, W, size=n)]
            w_qry = self.qry_vocab[self.obj_topic,
                                   rng.integers(0, W, size=n)]
            w_bg = self.bg_vocab[rng.integers(0, len(self.bg_vocab), size=n)]
            docs[:, j] = np.where(r < 0.55, w_obj,
                                  np.where(r < 0.75, w_qry, w_bg))
        self.obj_doc = docs

        # --- queries ---
        m = cfg.n_queries
        seed_obj = rng.integers(0, n, size=m)
        self.query_seed = seed_obj
        self.q_topic = self.obj_topic[seed_obj]
        disp = rng.exponential(cfg.query_dist_scale, size=m)
        disp = np.minimum(disp, 0.3)
        ang = rng.uniform(0, 2 * np.pi, size=m)
        self.q_loc = self.obj_loc[seed_obj] + \
            disp[:, None] * np.stack([np.cos(ang), np.sin(ang)], -1)
        self.q_loc = np.clip(self.q_loc, 0.0, 1.0)
        mism = rng.random(m) < cfg.mismatch
        self.q_mismatch = mism
        qdocs = np.zeros((m, cfg.query_len), np.int64)
        for j in range(cfg.query_len):
            w_q = self.qry_vocab[self.q_topic, rng.integers(0, W, size=m)]
            w_o = self.obj_vocab[self.q_topic, rng.integers(0, W, size=m)]
            r = rng.random(m)
            # mismatched queries use ONLY query-side words; others mix
            qdocs[:, j] = np.where(mism | (r < 0.5), w_q, w_o)
        self.q_doc = qdocs

        # --- ground truth: same topic && within relevance radius of seed ---
        self.positives: List[np.ndarray] = []
        topic_objs = [np.nonzero(self.obj_topic == t)[0] for t in range(T)]
        for i in range(m):
            cand = topic_objs[self.q_topic[i]]
            d = np.linalg.norm(self.obj_loc[cand] - self.q_loc[i][None], axis=1)
            pos = cand[d < cfg.relevance_radius]
            if pos.size == 0:
                pos = np.array([seed_obj[i]])
            self.positives.append(pos.astype(np.int64))

        self.dist_max = float(np.sqrt(2.0))

    # --- tokenization into fixed (max_len) windows with CLS ---------------

    def _tokens(self, docs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        b, l = docs.shape
        L = self.cfg.max_len
        out = np.zeros((b, L), np.int32)
        out[:, 0] = self.cfg.cls_token
        take = min(l, L - 1)
        out[:, 1:1 + take] = docs[:, :take]
        mask = out != 0
        return out, mask

    def object_tokens(self, ids=None):
        docs = self.obj_doc if ids is None else self.obj_doc[ids]
        return self._tokens(docs)

    def query_tokens(self, ids=None):
        docs = self.q_doc if ids is None else self.q_doc[ids]
        return self._tokens(docs)

    # --- splits ------------------------------------------------------------

    def split(self, val_frac=0.1, test_frac=0.1):
        m = self.cfg.n_queries
        rng = np.random.default_rng(self.cfg.seed + 1)
        perm = rng.permutation(m)
        n_test = int(m * test_frac)
        n_val = int(m * val_frac)
        return (perm[n_test + n_val:], perm[n_test:n_test + n_val],
                perm[:n_test])

    # --- contrastive training batches (Eq. 8) ------------------------------

    def train_batch(self, step: int, batch: int, query_ids: np.ndarray,
                    hard_negs: Optional[np.ndarray] = None, b_neg: int = 4):
        """Stateless batch: seeded by step. hard_negs: (n_queries, H) pool of
        TkQ-mined negatives per query (see core/pipeline.mine_tkq_negatives);
        falls back to random negatives when absent."""
        rng = np.random.default_rng(self.cfg.seed * 1_000_003 + step)
        qi = query_ids[rng.integers(0, len(query_ids), size=batch)]
        pos = np.array([self.positives[i][rng.integers(0, len(self.positives[i]))]
                        for i in qi])
        if hard_negs is not None:
            hsel = hard_negs[qi]
            neg = hsel[np.arange(batch)[:, None],
                       rng.integers(0, hsel.shape[1], size=(batch, b_neg))]
        else:
            neg = rng.integers(0, self.cfg.n_objects, size=(batch, b_neg))
        qt, qm = self.query_tokens(qi)
        pt, pm = self.object_tokens(pos)
        nt, nm = self.object_tokens(neg.reshape(-1))
        L = self.cfg.max_len
        return {
            "q_tokens": qt, "q_mask": qm,
            "q_loc": self.q_loc[qi].astype(np.float32),
            "pos_tokens": pt, "pos_mask": pm,
            "pos_loc": self.obj_loc[pos].astype(np.float32),
            "neg_tokens": nt.reshape(batch, b_neg, L),
            "neg_mask": nm.reshape(batch, b_neg, L),
            "neg_loc": self.obj_loc[neg.reshape(-1)].reshape(
                batch, b_neg, 2).astype(np.float32),
            "dist_max": self.dist_max,
            "query_ids": qi,
        }

    def positives_mask(self, query_ids) -> np.ndarray:
        """(B, N) bool mask of ground-truth positives (Eq. 13 filter)."""
        out = np.zeros((len(query_ids), self.cfg.n_objects), bool)
        for r, qi in enumerate(query_ids):
            out[r, self.positives[qi]] = True
        return out


def scale_corpus(cfg: GeoCorpusConfig, n_objects: int) -> GeoCorpusConfig:
    """Scalability-study helper (paper Fig. 7): same generator, more POIs."""
    return dataclasses.replace(cfg, n_objects=n_objects)
