"""Step builders + input specs + shardings for every (arch × shape) cell.

``plan_cell(arch_id, shape_name, mesh)`` returns a :class:`CellPlan` whose
``fn`` is jit-able and whose ``args`` are ShapeDtypeStruct trees — the
dry-run does ``jax.jit(fn, in_shardings=...).lower(*args).compile()`` and
nothing ever allocates. The same builders power the real train/serve
drivers (which pass concrete arrays instead).

Sharding doctrine (DESIGN.md §5):
  LM      params TP over "model" (heads/ffn/vocab/experts) + FSDP over dp;
          batch over dp; KV caches (B→dp, T→model) for full-attention
          layers (flash-decoding via GSPMD), ring buffers replicated on tp.
  GNN     nodes/edges sharded over ALL axes (segment_sum → GSPMD psum).
  RecSys  embedding tables row-sharded over "model", batch over dp,
          candidate/item axes over "model".
  LIST    cluster buffers cluster-major over ALL axes; query phase is
          expert-style dispatch (core/serving.py); mining is a sharded
          einsum + per-shard top-k merge.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfg_base, get_config, get_shape
from repro.core import index as index_lib
from repro.core import pseudo_labels, relevance, serving
from repro.core import spatial as sp_lib
from repro.distributed import sharding as sh
from repro.models import gnn as gnn_lib
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.optim import clip_by_global_norm, make_optimizer

SDS = jax.ShapeDtypeStruct


def pad_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass
class CellPlan:
    arch_id: str
    shape_name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    out_shardings: Any = None          # None = let GSPMD decide
    notes: str = ""
    skip: Optional[str] = None


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------


def dp_axes(mesh):
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for n in dp_axes(mesh):
        s *= mesh.shape[n]
    return s


def tp_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def all_axes(mesh):
    return tuple(mesh.axis_names)


def all_size(mesh) -> int:
    s = 1
    for n in mesh.axis_names:
        s *= mesh.shape[n]
    return s


def _ns(mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, P(*axes))


def batch_sharding(mesh, b: int, extra: int = 0) -> NamedSharding:
    dp = dp_axes(mesh)
    lead = dp if (dp and b % dp_size(mesh) == 0) else None
    return _ns(mesh, lead, *([None] * extra))


def all_sharding(mesh, n: int, extra: int = 0) -> NamedSharding:
    axes = all_axes(mesh)
    lead = axes if n % all_size(mesh) == 0 else None
    return _ns(mesh, lead, *([None] * extra))


def _params_plan(mesh, params_shape, rules):
    with sh.axis_rules(sh.rules_for_mesh(mesh)):
        specs = sh.param_specs(params_shape, rules)
    return specs, sh.named_shardings(mesh, specs)


def _opt_plan(mesh, params_shape, pspecs, optimizer):
    with sh.axis_rules(sh.rules_for_mesh(mesh)):
        ospecs = sh.opt_state_specs(params_shape, pspecs, optimizer)
    return sh.named_shardings(mesh, ospecs)


def _train_step(loss_fn, cfg, *, lr=3e-4, clip=1.0):
    opt_init, opt_update = make_optimizer(cfg.optimizer)

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = opt_update(grads, opt_state, params, lr)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return step, opt_init


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _lm_params_shape(cfg):
    return jax.eval_shape(lambda: tf.lm_init(jax.random.PRNGKey(0), cfg))


def _cache_shardings(mesh, cache_shape, cfg, batch: int):
    """KV caches: trailing dims (B, T, KV, HD). B→dp when divisible; T→model
    for full-length buffers (flash-decoding); window ring buffers keep T
    replicated (their in-place slot writes must stay local)."""
    dp = dp_axes(mesh)
    tpn = tp_size(mesh)

    def leaf(x):
        b_ax = dp if (dp and batch % dp_size(mesh) == 0) else None
        t = x.shape[-3]
        is_ring = cfg.window_size and t == cfg.window_size
        t_ax = "model" if (not is_ring and tpn > 1 and t % tpn == 0) else None
        lead = (None,) * (x.ndim - 4)
        return _ns(mesh, *lead, b_ax, t_ax, None, None)

    return jax.tree.map(leaf, cache_shape)


def plan_lm(arch_id: str, shape, mesh) -> CellPlan:
    cfg = get_config(arch_id)
    dims = shape.dims
    params_shape = _lm_params_shape(cfg)
    pspecs, psh = _params_plan(mesh, params_shape, sh.LM_PARAM_RULES)

    if shape.kind == "lm_train":
        b, s = dims["global_batch"], dims["seq_len"]
        step, opt_init = _train_step(
            lambda p, batch: tf.lm_loss(p, batch, cfg), cfg)
        opt_shape = jax.eval_shape(opt_init, params_shape)
        osh = _opt_plan(mesh, params_shape, pspecs, cfg.optimizer)
        batch = {"tokens": SDS((b, s + 1), jnp.int32)}
        bsh = {"tokens": batch_sharding(mesh, b, extra=1)}
        return CellPlan(arch_id, shape.name, step,
                        (params_shape, opt_shape, batch), (psh, osh, bsh),
                        out_shardings=(psh, osh, None))

    if shape.kind == "lm_prefill":
        b, s = dims["global_batch"], dims["seq_len"]

        def prefill(params, tokens):
            return tf.lm_prefill(params, tokens, cfg)

        tok = SDS((b, s), jnp.int32)
        cache_shape = jax.eval_shape(prefill, params_shape, tok)[1]
        csh = _cache_shardings(mesh, cache_shape, cfg, b)
        return CellPlan(arch_id, shape.name, prefill, (params_shape, tok),
                        (psh, batch_sharding(mesh, b, extra=1)),
                        out_shardings=(batch_sharding(mesh, b, extra=1), csh))

    # lm_decode: one token against a seq_len cache
    b, s = dims["global_batch"], dims["seq_len"]
    if shape.skip:
        return CellPlan(arch_id, shape.name, None, (), (), skip=shape.skip)

    def decode(params, cache, token, pos):
        return tf.lm_decode_step(params, cache, token, pos, cfg)

    cache_shape = jax.eval_shape(
        lambda: tf.make_decode_cache(cfg, b, s))
    csh = _cache_shardings(mesh, cache_shape, cfg, b)
    token = SDS((b, 1), jnp.int32)
    pos = SDS((b,), jnp.int32)
    return CellPlan(
        arch_id, shape.name, decode,
        (params_shape, cache_shape, token, pos),
        (psh, csh, batch_sharding(mesh, b, extra=1),
         batch_sharding(mesh, b)),
        out_shardings=(batch_sharding(mesh, b, extra=1), csh))


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def plan_gnn(arch_id: str, shape, mesh) -> CellPlan:
    cfg = get_config(arch_id)
    d = shape.dims
    batched = d.get("batched", False)
    sampled = d.get("sampled", False)
    n_classes = d.get("n_classes", 2)
    d_feat = d["d_feat"]

    if batched:
        n_graphs = d["batch"]
        n_nodes = pad_up(d["n_nodes"] * n_graphs, 512)
        n_edges = pad_up(d["n_edges"] * n_graphs, 512)
    elif sampled:
        seeds, (f1, f2) = d["batch_nodes"], d["fanout"]
        n_nodes = pad_up(seeds * (1 + f1 + f1 * f2) // 1, 512)
        n_edges = pad_up(seeds * f1 + seeds * f1 * f2, 512)
    else:
        n_nodes = pad_up(d["n_nodes"], 512)
        n_edges = pad_up(d["n_edges"], 512)

    params_shape = jax.eval_shape(
        lambda: gnn_lib.gnn_init(jax.random.PRNGKey(0), cfg, d_feat,
                                 n_classes, d_edge_in=4 if batched else 0))
    pspecs, psh = _params_plan(mesh, params_shape, sh.GNN_PARAM_RULES)
    step, opt_init = _train_step(
        lambda p, g: gnn_lib.gnn_loss(p, g, cfg), cfg)
    opt_shape = jax.eval_shape(opt_init, params_shape)
    osh = _opt_plan(mesh, params_shape, pspecs, cfg.optimizer)

    graph = {
        "x": SDS((n_nodes, d_feat), jnp.float32),
        "edge_src": SDS((n_edges,), jnp.int32),
        "edge_dst": SDS((n_edges,), jnp.int32),
        "edge_attr": SDS((n_edges, 4), jnp.float32) if batched else None,
        "node_mask": SDS((n_nodes,), jnp.bool_),
        "edge_mask": SDS((n_edges,), jnp.bool_),
    }
    gsh = {
        "x": all_sharding(mesh, n_nodes, extra=1),
        "edge_src": all_sharding(mesh, n_edges),
        "edge_dst": all_sharding(mesh, n_edges),
        "edge_attr": all_sharding(mesh, n_edges, extra=1) if batched else None,
        "node_mask": all_sharding(mesh, n_nodes),
        "edge_mask": all_sharding(mesh, n_edges),
    }
    if batched:
        n_graphs_p = pad_up(n_graphs, 512)
        graph.update({
            "graph_ids": SDS((n_nodes,), jnp.int32),
            "n_graphs": n_graphs_p,
            "labels": SDS((n_graphs_p,), jnp.float32),
            "label_mask": SDS((n_graphs_p,), jnp.float32),
        })
        gsh.update({
            "graph_ids": all_sharding(mesh, n_nodes),
            "n_graphs": None,
            "labels": all_sharding(mesh, n_graphs_p),
            "label_mask": all_sharding(mesh, n_graphs_p),
        })
        # n_graphs is static — close over it instead of passing an int arg
        def step_b(params, opt_state, g):
            g = dict(g)
            g["n_graphs"] = n_graphs_p
            return step(params, opt_state, g)
        fn = step_b
        graph.pop("n_graphs")
        gsh.pop("n_graphs")
    else:
        graph.update({
            "labels": SDS((n_nodes,), jnp.int32),
            "label_mask": SDS((n_nodes,), jnp.float32),
        })
        gsh.update({
            "labels": all_sharding(mesh, n_nodes),
            "label_mask": all_sharding(mesh, n_nodes),
        })
        fn = step

    return CellPlan(arch_id, shape.name, fn,
                    (params_shape, opt_shape, graph), (psh, osh, gsh),
                    out_shardings=(psh, osh, None))


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def _chunked_item_topk(score_chunk, n_items: int, chunk: int, k: int,
                       batch: int):
    """Running top-k over item chunks (keeps the (B, V) logits virtual)."""
    n_chunks = n_items // chunk

    def body(carry, ci):
        best_v, best_i = carry
        s = score_chunk(ci)                                   # (B, chunk)
        ids = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        cat_v = jnp.concatenate([best_v, s], axis=1)
        cat_i = jnp.concatenate([best_i, ids], axis=1)
        v, pos = jax.lax.top_k(cat_v, k)
        return (v, jnp.take_along_axis(cat_i, pos, axis=1)), None

    init = (jnp.full((batch, k), -jnp.inf, jnp.float32),
            jnp.full((batch, k), -1, jnp.int32))
    (v, i), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return v, i


def plan_recsys(arch_id: str, shape, mesh) -> CellPlan:
    cfg = get_config(arch_id)
    d = shape.dims
    model = cfg.model

    if model == "dlrm":
        init_fn = lambda: rs.dlrm_init(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: rs.dlrm_loss(p, b, cfg)
        fwd = lambda p, b: rs.dlrm_forward(p, b["dense"], b["sparse"], cfg)

        def batch_specs(b):
            return ({"dense": SDS((b, cfg.n_dense), jnp.float32),
                     "sparse": SDS((b, cfg.n_sparse), jnp.int32),
                     "label": SDS((b,), jnp.float32)},
                    {"dense": batch_sharding(mesh, b, extra=1),
                     "sparse": batch_sharding(mesh, b, extra=1),
                     "label": batch_sharding(mesh, b)})
    elif model == "xdeepfm":
        init_fn = lambda: rs.xdeepfm_init(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: rs.xdeepfm_loss(p, b, cfg)
        fwd = lambda p, b: rs.xdeepfm_forward(p, b["sparse"], cfg)

        def batch_specs(b):
            return ({"sparse": SDS((b, cfg.n_sparse), jnp.int32),
                     "label": SDS((b,), jnp.float32)},
                    {"sparse": batch_sharding(mesh, b, extra=1),
                     "label": batch_sharding(mesh, b)})
    elif model == "bert4rec":
        init_fn = lambda: rs.bert4rec_init(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: rs.bert4rec_loss(p, b, cfg)
        fwd = None

        def batch_specs(b):
            L, Pm = cfg.seq_len, 20
            return ({"seq": SDS((b, L), jnp.int32),
                     "mask": SDS((b, L), jnp.bool_),
                     "mlm_pos": SDS((b, Pm), jnp.int32),
                     "mlm_tgt": SDS((b, Pm), jnp.int32),
                     "mlm_mask": SDS((b, Pm), jnp.float32)},
                    {k: batch_sharding(mesh, b, extra=1)
                     for k in ("seq", "mask", "mlm_pos", "mlm_tgt",
                               "mlm_mask")})
    elif model == "mind":
        init_fn = lambda: rs.mind_init(jax.random.PRNGKey(0), cfg)
        loss_fn = lambda p, b: rs.mind_loss(p, b, cfg)
        fwd = None

        def batch_specs(b):
            return ({"hist": SDS((b, cfg.hist_len), jnp.int32),
                     "hist_mask": SDS((b, cfg.hist_len), jnp.bool_),
                     "target": SDS((b,), jnp.int32)},
                    {"hist": batch_sharding(mesh, b, extra=1),
                     "hist_mask": batch_sharding(mesh, b, extra=1),
                     "target": batch_sharding(mesh, b)})
    else:
        raise ValueError(model)

    params_shape = jax.eval_shape(init_fn)
    pspecs, psh = _params_plan(mesh, params_shape, sh.REC_PARAM_RULES)

    if shape.kind == "rec_train":
        b = d["batch"]
        step, opt_init = _train_step(loss_fn, cfg)
        opt_shape = jax.eval_shape(opt_init, params_shape)
        osh = _opt_plan(mesh, params_shape, pspecs, cfg.optimizer)
        batch, bsh = batch_specs(b)
        return CellPlan(arch_id, shape.name, step,
                        (params_shape, opt_shape, batch), (psh, osh, bsh),
                        out_shardings=(psh, osh, None))

    if shape.kind == "rec_serve":
        b = d["batch"]
        if model in ("dlrm", "xdeepfm"):
            def serve(params, batch):
                return fwd(params, batch)
            batch, bsh = batch_specs(b)
            batch.pop("label")
            bsh.pop("label")
            return CellPlan(arch_id, shape.name, serve,
                            (params_shape, batch), (psh, bsh))
        # bert4rec / mind: user embedding + chunked top-k over all items
        chunk = 65536
        rows = params_shape["item_embed"].shape[0]
        n_items = pad_up(rows, chunk)
        k = 100

        def _padded_table(params):
            emb = params["item_embed"]
            return jnp.pad(emb, ((0, n_items - emb.shape[0]), (0, 0)))

        if model == "bert4rec":
            def serve(params, batch):
                u = rs.bert4rec_user_embedding(params, batch["seq"],
                                               batch["mask"], cfg)
                emb = _padded_table(params)

                def score_chunk(ci):
                    rows_ = jax.lax.dynamic_slice_in_dim(
                        emb, ci * chunk, chunk, axis=0)
                    return (u @ rows_.T.astype(u.dtype)).astype(jnp.float32)

                return _chunked_item_topk(score_chunk, n_items, chunk, k, b)
            batch, bsh = batch_specs(b)
            for key in ("mlm_pos", "mlm_tgt", "mlm_mask"):
                batch.pop(key)
                bsh.pop(key)
        else:
            def serve(params, batch):
                u = rs.mind_interests(params, batch["hist"],
                                      batch["hist_mask"], cfg)   # (B, K, d)
                emb = _padded_table(params)

                def score_chunk(ci):
                    rows_ = jax.lax.dynamic_slice_in_dim(
                        emb, ci * chunk, chunk, axis=0)
                    s = jnp.einsum("bkd,cd->bkc", u, rows_.astype(u.dtype))
                    return s.max(axis=1).astype(jnp.float32)

                return _chunked_item_topk(score_chunk, n_items, chunk, k, b)
            batch, bsh = batch_specs(b)
            batch.pop("target")
            bsh.pop("target")
        return CellPlan(arch_id, shape.name, serve,
                        (params_shape, batch), (psh, bsh))

    # retrieval: 1 query (or user) vs n_candidates
    nc = pad_up(d["n_candidates"], all_size(mesh))
    k = 100
    if model in ("dlrm", "xdeepfm"):
        # CTR rankers score candidate ITEMS pointwise for one user context —
        # LIST-style retrieval is inapplicable (DESIGN.md §7): they act as
        # re-rankers; this cell is the bulk pointwise scoring of 1M pairs.
        def serve(params, batch):
            logits = fwd(params, batch)
            return jax.lax.top_k(logits, k)
        if model == "dlrm":
            batch = {"dense": SDS((nc, cfg.n_dense), jnp.float32),
                     "sparse": SDS((nc, cfg.n_sparse), jnp.int32)}
            bsh = {"dense": all_sharding(mesh, nc, extra=1),
                   "sparse": all_sharding(mesh, nc, extra=1)}
        else:
            batch = {"sparse": SDS((nc, cfg.n_sparse), jnp.int32)}
            bsh = {"sparse": all_sharding(mesh, nc, extra=1)}
        return CellPlan(arch_id, shape.name, serve,
                        (params_shape, batch), (psh, bsh),
                        notes="pointwise CTR scoring (LIST inapplicable)")

    b = d["batch"]
    cand = SDS((nc,), jnp.int32)
    csh = all_sharding(mesh, nc)
    if model == "mind":
        def serve(params, hist, hist_mask, cand_ids):
            s = rs.mind_score_candidates(params, hist, hist_mask, cand_ids,
                                         cfg)
            return jax.lax.top_k(s, k)
        args = (params_shape, SDS((b, cfg.hist_len), jnp.int32),
                SDS((b, cfg.hist_len), jnp.bool_), cand)
        insh = (psh, _ns(mesh, None, None), _ns(mesh, None, None), csh)
    else:  # bert4rec
        def serve(params, seq, mask, cand_ids):
            u = rs.bert4rec_user_embedding(params, seq, mask, cfg)
            ce = rs.embedding_lookup(params["item_embed"], cand_ids)
            s = (u @ ce.T.astype(u.dtype)).astype(jnp.float32)
            return jax.lax.top_k(s, k)
        args = (params_shape, SDS((b, cfg.seq_len), jnp.int32),
                SDS((b, cfg.seq_len), jnp.bool_), cand)
        insh = (psh, _ns(mesh, None, None), _ns(mesh, None, None), csh)
    return CellPlan(arch_id, shape.name, serve, args, insh)


# ---------------------------------------------------------------------------
# Dual encoder (the paper's own architecture)
# ---------------------------------------------------------------------------


def _de_params_shape(cfg):
    return jax.eval_shape(
        lambda: relevance.relevance_init(jax.random.PRNGKey(0), cfg))


def plan_dual_encoder(arch_id: str, shape, mesh) -> CellPlan:
    cfg = get_config(arch_id)
    d = shape.dims
    params_shape = _de_params_shape(cfg)
    pspecs, psh = _params_plan(mesh, params_shape, sh.LM_PARAM_RULES)

    if shape.kind == "de_train":
        b, L, nneg = d["global_batch"], d["max_len"], d["hard_negs"]
        step, opt_init = _train_step(
            lambda p, batch: relevance.contrastive_loss(p, batch, cfg), cfg)
        opt_shape = jax.eval_shape(opt_init, params_shape)
        osh = _opt_plan(mesh, params_shape, pspecs, cfg.optimizer)
        batch = {
            "q_tokens": SDS((b, L), jnp.int32),
            "q_mask": SDS((b, L), jnp.bool_),
            "q_loc": SDS((b, 2), jnp.float32),
            "pos_tokens": SDS((b, L), jnp.int32),
            "pos_mask": SDS((b, L), jnp.bool_),
            "pos_loc": SDS((b, 2), jnp.float32),
            "neg_tokens": SDS((b, nneg, L), jnp.int32),
            "neg_mask": SDS((b, nneg, L), jnp.bool_),
            "neg_loc": SDS((b, nneg, 2), jnp.float32),
        }
        bsh = {k: batch_sharding(mesh, b, extra=v.ndim - 1)
               for k, v in batch.items()}
        return CellPlan(arch_id, shape.name, step,
                        (params_shape, opt_shape, batch), (psh, osh, bsh),
                        out_shardings=(psh, osh, None))

    if shape.kind == "de_encode":
        b, L = d["global_batch"], d["max_len"]

        def encode(params, tokens, mask):
            return relevance.encode_objects(params, tokens, mask, cfg)

        return CellPlan(
            arch_id, shape.name, encode,
            (params_shape, SDS((b, L), jnp.int32), SDS((b, L), jnp.bool_)),
            (psh, batch_sharding(mesh, b, extra=1),
             batch_sharding(mesh, b, extra=1)))

    if shape.kind == "list_serve":
        b = d["query_batch"]
        n_obj, c_real = d["n_objects"], d["n_clusters"]
        k = d["topk"]
        L = cfg.max_len
        dm = cfg.d_model
        c = pad_up(c_real, all_size(mesh))          # padded cluster count
        cap = pad_up(int(n_obj / c_real * 1.5), 128)
        qcap = serving.query_capacity(b, c_real, cfg.cluster_route)
        index_shape = jax.eval_shape(
            lambda: index_lib.index_init(jax.random.PRNGKey(0), dm, c,
                                         hidden=cfg.index_mlp_hidden))
        _, ish = _params_plan(mesh, index_shape, ((r".*", (None,)),))
        norm_shape = {"lo": SDS((2,), jnp.float32),
                      "span": SDS((2,), jnp.float32)}

        def serve(params, iparams, w_hat, norm, buf_emb, buf_loc, buf_ids,
                  q_tokens, q_mask, q_loc):
            return serving.dispatch_query_kernel(
                params, iparams, w_hat, norm, buf_emb, buf_loc, buf_ids,
                q_tokens, q_mask, q_loc, cfg, k=k, cr=cfg.cluster_route,
                dist_max=1.4142, capacity=qcap)

        # §Perf LIST iteration: the 110M dual encoder is tiny next to the
        # 256-chip mesh — TP-serving it spends 2/3 of the wire on encoder
        # activation all-reduces. Serve it PURE-DP instead: params fully
        # replicated, query batch sharded over ALL axes; only the cluster
        # dispatch (q payloads, MBs) and the top-k merge touch the network.
        rep_rules = ((r".*", (None,)),)
        _, psh_rep = _params_plan(mesh, params_shape, rep_rules)
        args = (params_shape, index_shape, SDS((cfg.spatial_t,), jnp.float32),
                norm_shape,
                SDS((c, cap, dm), jnp.float32), SDS((c, cap, 2), jnp.float32),
                SDS((c, cap), jnp.int32),
                SDS((b, L), jnp.int32), SDS((b, L), jnp.bool_),
                SDS((b, 2), jnp.float32))
        insh = (psh_rep, ish, _ns(mesh, None), {"lo": _ns(mesh, None),
                                                "span": _ns(mesh, None)},
                all_sharding(mesh, c, extra=2), all_sharding(mesh, c, extra=2),
                all_sharding(mesh, c, extra=1),
                all_sharding(mesh, b, extra=1),
                all_sharding(mesh, b, extra=1),
                all_sharding(mesh, b, extra=1))
        return CellPlan(arch_id, shape.name, serve, args, insh,
                        notes=f"c={c} cap={cap} qcap={qcap} dp-encoder")

    if shape.kind == "list_mine":
        b = d["query_batch"]
        n_obj = pad_up(d["n_objects"], all_size(mesh))
        ns_, ne_ = d["neg_start"], d["neg_end"]
        dm = cfg.d_model
        shards = all_size(mesh)

        def mine(params, q_emb, q_loc, obj_emb, obj_loc):
            return pseudo_labels.mine_negatives_dense(
                params, cfg, q_emb, q_loc, obj_emb, obj_loc,
                neg_start=ns_, neg_end=ne_, dist_max=1.4142, shards=shards)

        args = (params_shape, SDS((b, dm), jnp.float32),
                SDS((b, 2), jnp.float32), SDS((n_obj, dm), jnp.float32),
                SDS((n_obj, 2), jnp.float32))
        insh = (psh, batch_sharding(mesh, b, extra=1),
                batch_sharding(mesh, b, extra=1),
                all_sharding(mesh, n_obj, extra=1),
                all_sharding(mesh, n_obj, extra=1))
        return CellPlan(arch_id, shape.name, mine, args, insh)

    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def plan_cell(arch_id: str, shape_name: str, mesh) -> CellPlan:
    cfg = get_config(arch_id)
    shape = get_shape(arch_id, shape_name)
    if shape.skip:
        return CellPlan(arch_id, shape_name, None, (), (), skip=shape.skip)
    fam = cfg.family
    if fam == "lm":
        return plan_lm(arch_id, shape, mesh)
    if fam == "gnn":
        return plan_gnn(arch_id, shape, mesh)
    if fam == "recsys":
        return plan_recsys(arch_id, shape, mesh)
    if fam == "dual_encoder":
        return plan_dual_encoder(arch_id, shape, mesh)
    raise ValueError(fam)
