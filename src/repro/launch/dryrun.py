import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes, and record memory/cost/collective analysis for the
roofline (EXPERIMENTS.md §Dry-run / §Roofline).

MUST be run as its own process (the XLA_FLAGS line above has to execute
before jax initializes — hence its position as the first statement).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json
"""
import argparse
import json
import time
import traceback

import jax

from repro.analysis import hlo_cost
from repro.analysis import roofline as rl
from repro.configs import arch_ids, get_config, get_shapes
from repro.distributed import sharding as sh
from repro.launch import mesh as mesh_lib
from repro.launch import steps


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             verbose: bool = True) -> dict:
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh_lib.mesh_chips(mesh)
    t0 = time.time()
    try:
        plan = steps.plan_cell(arch, shape_name, mesh)
        if plan.skip:
            rec["status"] = "SKIP"
            rec["reason"] = plan.skip
            return rec
        with mesh, sh.axis_rules(sh.rules_for_mesh(mesh)):
            jfn = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                          out_shardings=plan.out_shardings)
            lowered = jfn.lower(*plan.args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        text = compiled.as_text()
        # first-principles walk with while-trip-count multipliers — XLA's
        # cost_analysis visits scan bodies once (see analysis/hlo_cost.py)
        hc = hlo_cost.analyze(text)
        flops = hc["flops"]
        nbytes = hc["bytes"]
        coll = hc["coll"]
        terms = rl.roofline_terms(flops, nbytes, coll)
        rec.update({
            "status": "OK",
            "chips": chips,
            "compile_s": round(time.time() - t0, 1),
            "flops_per_chip": flops,
            "bytes_per_chip": nbytes,
            "collectives": {k: v for k, v in coll.items() if v},
            "roofline": terms,
            "xla_flops_once": float(cost.get("flops", 0.0)),
            "xla_bytes_once": float(cost.get("bytes accessed", 0.0)),
            "notes": plan.notes,
        })
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[attr] = int(v)
        if verbose:
            print(f"[{rec['mesh']}] {arch} × {shape_name}: OK  "
                  f"flops/chip={flops:.3e}  bytes/chip={nbytes:.3e}  "
                  f"coll={coll['total']:.3e}B  "
                  f"bottleneck={terms['bottleneck']}  "
                  f"({rec['compile_s']}s)")
    except Exception as e:  # noqa: BLE001 — a failing cell is a result
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{rec['mesh']}] {arch} × {shape_name}: FAIL {rec['error']}")
    return rec


def all_cells():
    for arch in arch_ids():
        for shape in get_shapes(arch):
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    if args.all:
        cells = list(all_cells())
    else:
        assert args.arch, "--arch or --all required"
        if args.shape:
            cells = [(args.arch, args.shape)]
        else:
            cells = [(args.arch, s.name) for s in get_shapes(args.arch)]
    for arch, shape in cells:
        for mp in meshes:
            results.append(run_cell(arch, shape, multi_pod=mp))
    ok = sum(r["status"] == "OK" for r in results)
    skip = sum(r["status"] == "SKIP" for r in results)
    fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n=== dry-run: {ok} OK, {skip} SKIP, {fail} FAIL "
          f"of {len(results)} cells ===")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    return 0 if fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
