"""LIST serving driver over the ``repro.api`` facade: build (or load) an
immutable ``IndexSnapshot``, then run a long-lived streaming server
(core/server.py, DESIGN.md §7–§8) and replay a skewed query workload
against it — open-loop (fixed arrival rate) or closed-loop (fixed
concurrency) load generation.

    PYTHONPATH=src python -m repro.launch.serve --objects 4000 --queries 600 \
        --train-steps 200 --index-steps 400 --serve-batch 64 \
        --mode closed --concurrency 64 --requests 1200 --skew 1.05

``--snapshot-dir DIR`` makes the artifact durable: the first run trains,
builds, and ``api.save``s; later runs ``api.load`` the committed
snapshot and skip training entirely (bit-identical serving, per
tests/test_snapshot.py). ``--precision {f32,bf16,int8}`` picks the
resident-buffer storage tier (DESIGN.md §9): int8 quantizes the scanned
embeddings ~4× smaller with in-kernel dequant; a loaded artifact must
already be at the requested tier.

Reports two layers of metrics:

* quality (one-shot, as before): Recall@k / NDCG@k vs brute force,
  candidates scanned (the 1/c search-space reduction), P(C) / IF(C);
* serving (streamed): p50/p95/p99 latency, achieved QPS, cache hit
  rates per tier, micro-batch fill, flush-reason counts, and per-shape
  warm-up compile seconds.

``--churn N`` additionally applies N insert+delete batches through the
server's O(batch) delta write path (DESIGN.md §11) before streaming;
``--delta-threshold`` / ``--max-imbalance`` control when the background
compaction folds the delta into the base (0 threshold = legacy eager
O(index) writes).
"""
from __future__ import annotations

import argparse
import asyncio
import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from repro import api
from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.core import index as index_lib
from repro.core import pipeline as pl
from repro.core import server as server_lib
from repro.core.engine import resolve_cli_backend
from repro.data import GeoCorpus, GeoCorpusConfig


# ---------------------------------------------------------------------------
# Workload construction (load-gen loops live next to the server:
# server_lib.open_loop / server_lib.closed_loop)
# ---------------------------------------------------------------------------


def build_workload(corpus, query_ids, n_requests: int, *, skew: float,
                   seed: int):
    """Zipf-skewed replay of the test split: (request list, query ids)."""
    rng = np.random.default_rng(seed + 13)
    picks = query_ids[server_lib.zipf_sample(rng, len(query_ids), n_requests,
                                             a=skew)]
    tok, msk = corpus.query_tokens(picks)
    loc = corpus.q_loc[picks].astype(np.float32)
    return [(tok[i], msk[i], loc[i]) for i in range(n_requests)], picks


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=4000)
    ap.add_argument("--queries", type=int, default=600)
    ap.add_argument("--topics", type=int, default=16)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--index-steps", type=int, default=600)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--cr", type=int, default=1)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--use-pallas", action="store_true",
                    help="DEPRECATED alias for --backend pallas "
                         "(warns and forwards)")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "pallas-cm", "dense", "dense-cm",
                             "auto"],
                    help="engine backend: *-cm forces cluster-major "
                         "batched execution (each distinct routed "
                         "cluster streamed once per micro-batch, "
                         "DESIGN.md §10); auto picks query- vs "
                         "cluster-major per batch from the measured "
                         "route dedup factor")
    ap.add_argument("--precision", default=None,
                    choices=list(index_lib.PRECISIONS),
                    help="resident-buffer storage tier (DESIGN.md §9): "
                         "int8 streams ~4x fewer HBM bytes in the scan "
                         "kernel; default f32 on build, the artifact's "
                         "own tier on --snapshot-dir load")
    ap.add_argument("--mesh", type=int, default=None, metavar="N",
                    help="shard the resident cluster buffers across N "
                         "devices along the cluster axis (DESIGN.md §12); "
                         "router/relevance params replicated, top-k ids "
                         "bit-identical to single-device serving. On a "
                         "CPU-only host export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N first")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--snapshot-dir", default=None,
                    help="durable IndexSnapshot artifact dir: load it when "
                         "a committed snapshot exists, else train + save")
    # --- streaming-server knobs ---
    ap.add_argument("--serve-batch", type=int, default=64,
                    help="micro-batch size (the static jitted batch shape)")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="deadline flush: max queueing delay per request")
    ap.add_argument("--cache-size", type=int, default=8192)
    ap.add_argument("--near-cells", type=int, default=0,
                    help="near-duplicate cache grid (0 = exact tier only)")
    ap.add_argument("--delta-threshold", type=int, default=1024,
                    help="LSM write path (DESIGN.md §11): compact the "
                         "delta segment into the base once it holds this "
                         "many rows+tombstones; 0 = eager O(index) writes")
    ap.add_argument("--max-imbalance", type=float, default=0.0,
                    help="also compact when the live cluster sizes' "
                         "imbalance factor exceeds this (0 = off)")
    ap.add_argument("--spill", type=int, default=3,
                    help="insert routing spill hops (paper §4.3)")
    ap.add_argument("--churn", type=int, default=0,
                    help="write batches applied through the server before "
                         "streaming: each inserts 32 synthetic objects "
                         "and deletes 16 live ones through the O(batch) "
                         "delta path (recall is then measured against "
                         "the surviving positives)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip pre-tracing (the first query run — here the "
                         "quality snapshot — then pays the compile)")
    # --- resilience knobs (DESIGN.md §14) ---
    ap.add_argument("--wal-dir", default=None,
                    help="write-ahead log directory: every insert/delete "
                         "batch is durably logged before its publish; on "
                         "startup intact records newer than the loaded "
                         "snapshot are replayed (crash recovery). Pair "
                         "with --snapshot-dir")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="admission bound: shed (Overloaded) submits "
                         "arriving with this many already queued; 0 = "
                         "unbounded")
    ap.add_argument("--timeout-ms", type=float, default=0.0,
                    help="per-request deadline: requests still queued past "
                         "it are shed (DeadlineExceeded) instead of riding "
                         "a late batch; 0 = no deadlines")
    # --- load generation ---
    ap.add_argument("--mode", default="closed", choices=["open", "closed"])
    ap.add_argument("--requests", type=int, default=1200,
                    help="total requests replayed against the server")
    ap.add_argument("--qps", type=float, default=500.0,
                    help="open-loop arrival rate")
    ap.add_argument("--concurrency", type=int, default=64,
                    help="closed-loop outstanding requests")
    ap.add_argument("--skew", type=float, default=1.05,
                    help="Zipf exponent of the query workload (0 = uniform)")
    args = ap.parse_args(argv)
    backend = resolve_cli_backend(args.backend, args.use_pallas)

    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=4, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=args.clusters,
        neg_start=args.objects // 2, neg_end=args.objects // 2 + 200,
        index_mlp_hidden=(128,))
    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=args.objects, n_queries=args.queries,
        n_topics=args.topics, vocab_size=4096, seed=args.seed))

    # --- the artifact: load a committed snapshot, or build + save one ----
    from repro.checkpoint import ckpt as ckpt_lib
    r = None
    if (args.snapshot_dir
            and ckpt_lib.latest_step(args.snapshot_dir) is not None):
        t0 = time.perf_counter()
        snap = api.load(args.snapshot_dir)
        # the artifact must match what the CLI args describe, or every
        # quality number below (recall vs THIS corpus's ground truth)
        # would be silently meaningless
        from repro.core.snapshot import cfg_digest
        if snap.meta.cfg_digest != cfg_digest(cfg):
            raise SystemExit(
                f"--snapshot-dir {args.snapshot_dir}: artifact was built "
                f"for a different model config (digest "
                f"{snap.meta.cfg_digest} != {cfg_digest(cfg)}); rerun "
                f"with the original --objects/--clusters/... flags or "
                f"point at a fresh directory to retrain")
        if args.precision and snap.meta.precision != args.precision:
            raise SystemExit(
                f"--snapshot-dir {args.snapshot_dir}: artifact is "
                f"precision={snap.meta.precision!r} but --precision "
                f"{args.precision} was requested; re-build, or requantize "
                f"an f32 artifact via IndexSnapshot.with_precision")
        print(f"== loaded snapshot v{snap.meta.version} "
              f"({snap.meta.n_objects} objects, {snap.meta.precision}) "
              f"from {args.snapshot_dir} "
              f"in {time.perf_counter() - t0:.2f}s — skipping training ==")
    else:
        print("== training (Eq. 8 relevance + Eq. 13/14 index) ==")
        snap, r = api.build(
            cfg, corpus, rel_steps=args.train_steps,
            idx_steps=args.index_steps, batch=64, rel_lr=1e-3, idx_lr=3e-3,
            precision=args.precision or "f32", seed=args.seed, verbose=True,
            log_every=max(args.train_steps // 3, 1), return_retriever=True)
        if args.snapshot_dir:
            path = api.save(snap, args.snapshot_dir)
            print(f"== saved snapshot v{snap.meta.version} -> {path} ==")
    if args.mesh:
        snap = snap.with_mesh(args.mesh)
        per_dev = snap.shards.nbytes_per_device()
        print(f"== mesh: cluster buffers sharded across "
              f"{snap.meta.n_shards} devices, "
              f"{max(per_dev) / 1e6:.2f} MB/device resident ==")
    buf = snap.buffers
    counts = np.asarray(buf["counts"])
    print(f"== index: clusters={counts.tolist()} "
          f"spilled={buf['n_spilled']} precision={snap.meta.precision} ==")

    tr, va, te = corpus.split()
    positives = [corpus.positives[q] for q in te]

    # --- the streaming server (DESIGN.md §7) ------------------------------
    # built and warmed BEFORE any other query runs: the quality snapshot
    # below uses the same (k, cr, backend, batch) plan, so warming later
    # would measure a hot cache and report bogus compile seconds
    searcher = api.Searcher(snap)
    server = searcher.serve(server_lib.ServerConfig(
        batch_size=args.serve_batch, max_delay_ms=args.max_delay_ms,
        k=args.k, cr=args.cr, backend=backend,
        cache_size=args.cache_size, near_cells=args.near_cells,
        delta_threshold=args.delta_threshold,
        max_imbalance=args.max_imbalance, spill=args.spill,
        wal_dir=args.wal_dir, max_queue=args.max_queue,
        request_timeout_ms=args.timeout_ms))
    if args.wal_dir and server.wal.n_records:
        # crash recovery (DESIGN.md §14): the log outlived a previous
        # process — re-apply every intact record the loaded snapshot
        # doesn't already contain, before serving a single request
        applied = server.replay_wal()
        print(f"== recovery: replayed {applied} WAL record(s) "
              f"(torn tail dropped: {server.wal.dropped_tail}) -> "
              f"serving v{server.engine.snapshot.meta.version} ==")
    if not args.no_warmup:
        compiles = server.warmup()
        print("== warm-up: pre-traced "
              + ", ".join(f"{k} in {v:.2f}s" for k, v in compiles.items())
              + " ==")

    # --- quality snapshot (one-shot, vs brute force) ----------------------
    t0 = time.perf_counter()
    bf_ids, _ = api.brute_force(snap, corpus, te, k=args.k,
                                batch=args.serve_batch)
    t_bf = time.perf_counter() - t0
    ids, _ = searcher.query_corpus(corpus, te, k=args.k, cr=args.cr,
                                   backend=backend, batch=args.serve_batch)
    cap = buf["capacity"]
    scanned = args.cr * cap
    print(f"\n== quality over {len(te)} held-out queries ==")
    print(f"brute force : recall@{args.k}="
          f"{cm.recall_at_k(bf_ids, positives, args.k):.4f} "
          f"ndcg@5={cm.ndcg_at_k(bf_ids, positives, 5):.4f} "
          f"({t_bf:.2f}s, scans {args.objects} objects/query)")
    print(f"LIST cr={args.cr}  : recall@{args.k}="
          f"{cm.recall_at_k(ids, positives, args.k):.4f} "
          f"ndcg@5={cm.ndcg_at_k(ids, positives, 5):.4f} "
          f"(scans ≤{scanned} objects/query = "
          f"{scanned / args.objects:.1%} of corpus)")

    if r is not None:       # obj_assign is training-time state, not artifact
        q_emb = pl.embed_queries(snap.rel_params, corpus, cfg, te)
        qf = index_lib.build_features(
            jnp.asarray(q_emb),
            jnp.asarray(corpus.q_loc[te].astype(np.float32)), snap.norm)
        qa = np.asarray(index_lib.assign_clusters(snap.index_params, qf))
        pc, _ = cm.cluster_precision(qa, positives, r.obj_assign,
                                     cfg.n_clusters)
        print(f"cluster quality: P(C)={pc:.4f} "
              f"IF(C)={cm.imbalance_factor(r.obj_assign, cfg.n_clusters):.3f}")

    # --- churn: exercise the O(batch) write path before streaming ---------
    deleted: set = set()
    if args.churn:
        wrng = np.random.default_rng(args.seed + 99)
        next_id = 10_000_000
        t0 = time.perf_counter()
        for _ in range(args.churn):
            ne = wrng.normal(size=(32, cfg.d_model)).astype(np.float32)
            nl = wrng.uniform(size=(32, 2)).astype(np.float32)
            server.insert_objects(ne, nl, np.arange(next_id, next_id + 32))
            next_id += 32
            victims = [int(v) for v in wrng.choice(args.objects, size=16,
                                                   replace=False)
                       if v not in deleted]
            server.delete_objects(np.asarray(victims, np.int64))
            deleted.update(victims)
        t_w = time.perf_counter() - t0
        wm = server.metrics()
        print(f"== churn: {args.churn} write rounds in {t_w:.2f}s "
              f"(delta_rows={wm['delta_rows']} "
              f"tombstones={wm['tombstones']} "
              f"compactions={wm['compactions']}) ==")

    # --- streamed load against the pre-built server -----------------------
    requests, picks = build_workload(corpus, te, args.requests,
                                     skew=args.skew, seed=args.seed)
    print(f"== streaming {args.requests} requests "
          f"({len(set(picks.tolist()))} unique, zipf a={args.skew}) "
          f"mode={args.mode} ==")
    shedding = args.max_queue > 0 or args.timeout_ms > 0
    t0 = time.perf_counter()
    if args.mode == "open":
        results = asyncio.run(
            server_lib.open_loop(server, requests, qps=args.qps,
                                 shed_ok=shedding))
    else:
        results = asyncio.run(
            server_lib.closed_loop(server, requests,
                                   concurrency=args.concurrency))
    wall = time.perf_counter() - t0

    m = server.metrics(wall_seconds=wall)
    lat = m["latency_ms"]
    served = [(res, q) for res, q in zip(results, picks) if res is not None]
    served_ids = (np.stack([res[0] for res, _ in served])
                  if served else np.zeros((0, args.k), np.int64))
    served_pos = [np.asarray([p for p in corpus.positives[q]
                              if int(p) not in deleted])
                  for _, q in served]
    print(f"served QPS  : {m['qps']:.1f} ({wall:.2f}s wall)")
    print(f"latency ms  : p50={lat['p50']:.2f} p95={lat['p95']:.2f} "
          f"p99={lat['p99']:.2f} mean={lat['mean']:.2f}")
    print(f"cache       : hit_rate={m['hit_rate']:.1%} "
          f"(exact={m['exact_hit_rate']:.1%} near={m['near_hit_rate']:.1%} "
          f"coalesced={m['coalesced']})")
    print(f"cache hits  : exact={m['exact_hits']} near={m['near_hits']} "
          f"of {m['requests']} requests")
    print(f"micro-batch : {m['engine_batches']} engine batches, "
          f"fill={m['batch_fill']:.1%}, flushes={m['flushes']}")
    if m["writes"]:
        print(f"write path  : writes={m['writes']} "
              f"delta_rows={m['delta_rows']} "
              f"tombstones={m['tombstones']} "
              f"compactions={m['compactions']} "
              f"triggers={m['compaction_triggers']}")
    if m.get("dedup_factor"):
        print(f"route dedup : {m['dedup_factor']:.1f}x "
              f"(B*cr / distinct clusters — the cluster-major win)")
    # resilience summary (DESIGN.md §14)
    shed_total = sum(m["shed"].values())
    if shed_total or shedding:
        print(f"shed        : {shed_total} of {len(requests)} offered "
              f"({m['shed']}) — served {len(served)}")
    if m["flush_retries"] or m["poisoned_requests"]:
        print(f"degradation : flush_retries={m['flush_retries']} "
              f"poisoned_requests={m['poisoned_requests']}")
    if m["breaker"]["trips"]:
        print(f"breaker     : trips={m['breaker']['trips']} "
              f"fallback_flushes={m['breaker']['fallback_flushes']} "
              f"open={m['breaker']['open']}")
    if m["slow_flushes"]:
        print(f"slow flushes: {m['slow_flushes']} "
              f"(last at {m['last_slow_flush_at']:.0f} unix s)")
    if m["wal"]["enabled"]:
        print(f"wal         : {m['wal']['records']} record(s), "
              f"{m['wal']['bytes'] / 1e3:.1f} kB "
              f"(appends={m['wal']['appends']} "
              f"recovered={m['recovered_writes']})")
    if len(served):
        print(f"recall@{args.k} under serving: "
              f"{cm.recall_at_k(served_ids, served_pos, args.k):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
