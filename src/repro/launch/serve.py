"""LIST serving driver: train (or load) a retriever, then serve batched
spatial-keyword queries through the learned index.

    PYTHONPATH=src python -m repro.launch.serve --objects 4000 --queries 600 \
        --train-steps 200 --index-steps 400 --serve-batch 64

Reports the paper's serving metrics: Recall@k / NDCG@k vs brute force,
latency per batch, candidates scanned (the 1/c search-space reduction),
cluster quality P(C) / IF(C).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import cluster_metrics as cm
from repro.core import index as index_lib
from repro.core import pipeline as pl
from repro.data import GeoCorpus, GeoCorpusConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--objects", type=int, default=4000)
    ap.add_argument("--queries", type=int, default=600)
    ap.add_argument("--topics", type=int, default=16)
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--index-steps", type=int, default=600)
    ap.add_argument("--clusters", type=int, default=8)
    ap.add_argument("--cr", type=int, default=1)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--serve-batch", type=int, default=64)
    ap.add_argument("--use-pallas", action="store_true",
                    help="legacy alias for --backend pallas")
    ap.add_argument("--backend", default=None,
                    choices=["pallas", "dense", "auto"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=4, d_model=64, n_heads=4, d_ff=128, vocab_size=4096,
        max_len=16, spatial_t=100, n_clusters=args.clusters,
        neg_start=args.objects // 2, neg_end=args.objects // 2 + 200,
        index_mlp_hidden=(128,))
    corpus = GeoCorpus(GeoCorpusConfig(
        n_objects=args.objects, n_queries=args.queries,
        n_topics=args.topics, vocab_size=4096, seed=args.seed))

    r = pl.ListRetriever(cfg, corpus)
    print("== training relevance model (Eq. 8) ==")
    r.train_relevance(steps=args.train_steps, batch=64, lr=1e-3,
                      verbose=True, log_every=max(args.train_steps // 3, 1))
    print("== training index (Eq. 13 + 14) ==")
    r.train_index(steps=args.index_steps, batch=64, lr=3e-3, verbose=True,
                  log_every=max(args.index_steps // 3, 1))
    buf = r.build()
    counts = np.asarray(buf["counts"])
    print(f"== index built: clusters={counts.tolist()} "
          f"spilled={buf['n_spilled']} ==")

    tr, va, te = corpus.split()
    positives = [corpus.positives[q] for q in te]

    t0 = time.perf_counter()
    bf_ids, _ = r.brute_force(te, k=args.k, batch=args.serve_batch)
    t_bf = time.perf_counter() - t0
    t0 = time.perf_counter()
    from repro.core.engine import legacy_backend
    ids, _ = r.query(te, k=args.k, cr=args.cr,
                     backend=legacy_backend(args.backend, args.use_pallas),
                     batch=args.serve_batch)
    t_list = time.perf_counter() - t0

    cap = buf["capacity"]
    scanned = args.cr * cap
    print(f"\n== serving {len(te)} queries (batch={args.serve_batch}) ==")
    print(f"brute force : recall@{args.k}="
          f"{cm.recall_at_k(bf_ids, positives, args.k):.4f} "
          f"ndcg@5={cm.ndcg_at_k(bf_ids, positives, 5):.4f} "
          f"({t_bf:.2f}s, scans {args.objects} objects/query)")
    print(f"LIST cr={args.cr}  : recall@{args.k}="
          f"{cm.recall_at_k(ids, positives, args.k):.4f} "
          f"ndcg@5={cm.ndcg_at_k(ids, positives, 5):.4f} "
          f"({t_list:.2f}s, scans ≤{scanned} objects/query = "
          f"{scanned / args.objects:.1%} of corpus)")

    q_emb = pl.embed_queries(r.rel_params, corpus, cfg, te)
    qf = index_lib.build_features(
        jnp.asarray(q_emb), jnp.asarray(corpus.q_loc[te].astype(np.float32)),
        r.norm)
    qa = np.asarray(index_lib.assign_clusters(r.index_params, qf))
    pc, _ = cm.cluster_precision(qa, positives, r.obj_assign, cfg.n_clusters)
    print(f"cluster quality: P(C)={pc:.4f} "
          f"IF(C)={cm.imbalance_factor(r.obj_assign, cfg.n_clusters):.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
