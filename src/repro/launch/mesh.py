"""Production meshes (TPU v5e pods).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS *before* the first jax call.

single-pod : (16, 16)    axes ("data", "model")   — 256 chips
multi-pod  : (2, 16, 16) axes ("pod", "data", "model") — 512 chips, the
             "pod" axis is pure data parallelism across ICI-disjoint pods
             (gradient all-reduce crosses DCN).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by analysis/roofline.
PEAK_FLOPS_BF16 = 197e12         # FLOP/s
HBM_BW = 819e9                   # bytes/s
ICI_BW = 50e9                    # bytes/s per link (~per-chip usable)
DCN_BW = 6.25e9                  # bytes/s per host NIC (50 Gb/s), pod axis
HBM_PER_CHIP = 16 * 1024**3      # bytes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke tests (same axis names, size 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
