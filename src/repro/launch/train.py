"""Production training driver.

One driver for every family: picks the per-arch step builder from
launch/steps.py, feeds it the deterministic synthetic streams, and wires in
the fleet substrate — checkpoint/auto-resume, straggler monitoring,
microbatch accumulation, optional int8 gradient compression.

On this CPU container it runs REDUCED configs end-to-end (``--reduced``,
the default); on a fleet the same driver runs the full configs under the
production mesh (``--mesh single|multi``).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 50 --batch 8 --seq-len 128 --ckpt-dir /tmp/ck
    PYTHONPATH=src python -m repro.launch.train --arch gatedgcn --steps 20
    PYTHONPATH=src python -m repro.launch.train --arch dlrm-mlperf --steps 20
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.data import (
    CTRStream,
    LMStream,
    SeqRecStream,
    community_graph,
    molecule_batch,
)
from repro.distributed.resilience import StragglerMonitor, watchdog_step
from repro.models import gnn as gnn_lib
from repro.models import recsys as rs
from repro.models import transformer as tf
from repro.optim import clip_by_global_norm, make_optimizer


def _train_fns(cfg, args):
    """Returns (init_fn, loss_fn, batch_fn(step) -> pytree of np arrays)."""
    fam = cfg.family
    if fam == "lm":
        stream = LMStream(cfg.vocab_size, seed=args.seed)
        return (lambda k: tf.lm_init(k, cfg),
                lambda p, b: tf.lm_loss(p, b, cfg),
                lambda s: stream.batch(s, args.batch, args.seq_len))
    if fam == "gnn":
        if args.gnn_shape == "molecule":
            g0 = molecule_batch(args.batch, 30, 64, 16, seed=args.seed)
            d_in, n_cls, d_e = 16, 1, 4
        else:
            g0 = community_graph(2708, 10556, 64, 7, seed=args.seed)
            d_in, n_cls, d_e = 64, 7, 0
        return (lambda k: gnn_lib.gnn_init(k, cfg, d_in, n_cls, d_edge_in=d_e),
                lambda p, b: gnn_lib.gnn_loss(p, b, cfg),
                lambda s: g0)
    if fam == "recsys":
        if cfg.model == "dlrm":
            stream = CTRStream(cfg.n_dense, cfg.table_sizes, seed=args.seed)
            return (lambda k: rs.dlrm_init(k, cfg),
                    lambda p, b: rs.dlrm_loss(p, b, cfg),
                    lambda s: stream.batch(s, args.batch))
        if cfg.model == "xdeepfm":
            stream = CTRStream(1, [cfg.vocab_per_field] * cfg.n_sparse,
                               seed=args.seed)
            def xb(s):
                b = stream.batch(s, args.batch)
                return {"sparse": b["sparse"], "label": b["label"]}
            return (lambda k: rs.xdeepfm_init(k, cfg),
                    lambda p, b: rs.xdeepfm_loss(p, b, cfg), xb)
        if cfg.model == "bert4rec":
            stream = SeqRecStream(cfg.n_items, seed=args.seed)
            return (lambda k: rs.bert4rec_init(k, cfg),
                    lambda p, b: rs.bert4rec_loss(p, b, cfg),
                    lambda s: stream.bert4rec_batch(
                        s, args.batch, cfg.seq_len, cfg.mask_prob))
        if cfg.model == "mind":
            stream = SeqRecStream(cfg.n_items, seed=args.seed)
            return (lambda k: rs.mind_init(k, cfg),
                    lambda p, b: rs.mind_loss(p, b, cfg),
                    lambda s: stream.mind_batch(s, args.batch, cfg.hist_len))
    raise ValueError(f"use examples/train_list.py for {fam}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient accumulation factor")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--gnn-shape", default="full_graph_sm")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    init_fn, loss_fn, batch_fn = _train_fns(cfg, args)
    opt_init, opt_update = make_optimizer(cfg.optimizer)

    def fresh():
        params = init_fn(jax.random.PRNGKey(args.seed))
        return {"params": params, "opt": opt_init(params)}

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
        state, start_step, _ = mgr.restore_or_init(fresh)
        if start_step:
            print(f"resumed from step {start_step}")
    else:
        state = fresh()

    @jax.jit
    def step_fn(state, batch):
        def micro_loss(p, mb):
            return loss_fn(p, mb)

        if args.microbatch > 1:
            def split(x):
                return x.reshape((args.microbatch, -1) + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(
                    micro_loss, has_aux=True)(state["params"], mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), m
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state["params"])
            (grads, ltot), ms = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / args.microbatch, grads)
            loss = ltot / args.microbatch
            metrics = jax.tree.map(lambda x: x[-1], ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                micro_loss, has_aux=True)(state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt = opt_update(grads, state["opt"], state["params"],
                                 args.lr)
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm})
        return {"params": params, "opt": opt}, metrics

    monitor = StragglerMonitor()
    host = "host0"
    losses = []
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_fn(step).items()
                 if v is not None}
        (state, metrics), dt = watchdog_step(step_fn, state, batch,
                                             deadline_s=600.0)
        monitor.record(host, dt)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step}: loss={loss:.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f} "
                  f"({dt*1000:.0f} ms)"
                  + (f" stragglers={monitor.flagged()}"
                     if monitor.flagged() else ""))
        if mgr:
            mgr.maybe_save(step + 1, state,
                           meta={"arch": args.arch, "loss": loss})
    if mgr:
        mgr.maybe_save(args.steps, state, force=True,
                       meta={"arch": args.arch, "final": True})
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
