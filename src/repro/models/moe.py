"""Token-choice top-k MoE with sort-based (gather/scatter) dispatch.

Design (DESIGN.md §5): no one-hot dispatch einsums — those cost T·E·C·d MACs
of pure overhead and wreck the compute roofline. Instead, per routing group:

  1. router top-k → (T, k) expert ids + renormalized weights
  2. stable sort of the T·k assignments by expert id
  3. position-in-expert from run starts (cummax trick) → capacity mask
  4. scatter token slots into a (E, C) index table
  5. gather token activations → (E, C, d), 3 GEMMs per expert (SwiGLU)
  6. scatter-add back weighted by router prob

Expert weights are sharded E→"model" (expert parallel) and d_ff→"data"
(FSDP); the (G, E, C, d) dispatch buffer is sharded (data, model) so each
chip gathers only its experts' slots. Routing groups are sequences for
train/prefill and the whole batch for decode (S==1), keeping per-group
capacity C = ceil(T_g·k/E·cf) small and drops rare.

Aux losses: Switch load-balance loss + router z-loss, returned to the caller.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

# jax.shard_map only exists as a top-level API on newer jax; fall back to
# the experimental home so the production MoE path works on 0.4.x too.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map


def moe_init(key, d_model, spec, *, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e, f = spec.n_experts, spec.d_ff_expert
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(f)
    return {
        "router": layers._normal(k1, (d_model, e), s_in, jnp.float32),
        "w1": layers._normal(k2, (e, d_model, f), s_in, dtype),
        "w3": layers._normal(k3, (e, d_model, f), s_in, dtype),
        "w2": layers._normal(k4, (e, f, d_model), s_out, dtype),
    }


def capacity(tokens_per_group: int, spec) -> int:
    c = math.ceil(tokens_per_group * spec.top_k / spec.n_experts
                  * spec.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8, floor 8


def _positions_in_expert(sorted_ids):
    """sorted_ids: (G, N) expert id per sorted slot → position within its run."""
    n = sorted_ids.shape[-1]
    ar = jnp.arange(n)[None, :]
    is_start = jnp.concatenate(
        [jnp.ones_like(sorted_ids[:, :1], bool),
         sorted_ids[:, 1:] != sorted_ids[:, :-1]], axis=-1)
    run_start = jax.lax.cummax(jnp.where(is_start, ar, 0), axis=1)
    return ar - run_start


def route(params, x, spec):
    """x: (G, T, d) → (expert_ids (G,T,k), weights (G,T,k), aux metrics)."""
    logits = (x.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,T,E)
    top_p, top_i = jax.lax.top_k(probs, spec.top_k)            # (G,T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch load-balance loss: E * Σ_e fraction_tokens(e)·mean_prob(e)
    e = spec.n_experts
    frac = jnp.mean(
        (jax.nn.one_hot(top_i[..., 0], e)), axis=(0, 1))       # top-1 fraction
    mean_p = jnp.mean(probs, axis=(0, 1))
    lb_loss = e * jnp.sum(frac * mean_p)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_i, top_p, {"lb_loss": lb_loss, "z_loss": z_loss}


def moe_apply(params, x, spec, *, group="seq", dp_axes=("data",),
              ep_axis="model"):
    """x: (B, S, d). Returns (out (B, S, d), aux dict).

    Dispatches to the shard_map implementation when a production mesh is
    bound (launch/dryrun): GSPMD cannot infer that the batched dispatch
    gather/scatter is group-local and falls back to full replication —
    measured at ~22 TB of wire per kimi train step (EXPERIMENTS.md §Perf).
    The shard_map path keeps dispatch local and pays exactly one psum
    (combine) + one FSDP weight all-gather per layer.
    """
    from repro.distributed.sharding import current_rules
    rules = current_rules()
    mesh = rules.get("_mesh") if rules else None
    if mesh is not None and "model" in mesh.axis_names:
        return _moe_apply_shard_map(params, x, spec, mesh, group=group)
    return _moe_apply_local(params, x, spec, group=group, dp_axes=dp_axes,
                            ep_axis=ep_axis)


def _moe_apply_local(params, x, spec, *, group="seq", dp_axes=("data",),
                     ep_axis="model"):
    """Single-host / GSPMD path (smoke tests, CPU training)."""
    b, s, d = x.shape
    if group == "seq" and s > 1:
        xg = x                                   # groups = sequences
    else:
        xg = x.reshape(1, b * s, d)              # decode: one global group
    g, t, _ = xg.shape
    k = spec.top_k
    e = spec.n_experts
    c = capacity(t, spec)

    top_i, top_p, aux = route(params, xg, spec)                 # (G,T,k)
    flat_ids = top_i.reshape(g, t * k)                          # (G, N)
    sort_idx = jnp.argsort(flat_ids, axis=-1, stable=True)      # (G, N)
    sorted_ids = jnp.take_along_axis(flat_ids, sort_idx, axis=-1)
    pos = _positions_in_expert(sorted_ids)                      # (G, N)
    keep = pos < c
    # slot in flattened (E*C [+1 overflow]) table
    slot = jnp.where(keep, sorted_ids * c + pos, e * c)
    token_of_sorted = sort_idx // k                             # (G, N) in [0,T)

    # scatter token index + weight into the table (overflow slot dropped)
    table = jnp.full((g, e * c + 1), t, jnp.int32)              # t = pad row
    table = table.at[jnp.arange(g)[:, None], slot].set(token_of_sorted)
    w_sorted = jnp.take_along_axis(top_p.reshape(g, t * k), sort_idx, axis=-1)
    w_table = jnp.zeros((g, e * c + 1), jnp.float32)
    w_table = w_table.at[jnp.arange(g)[:, None], slot].set(w_sorted)
    table = table[:, : e * c].reshape(g, e, c)
    w_table = w_table[:, : e * c].reshape(g, e, c)

    # gather activations: pad row t is zeros
    xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xin = xpad[jnp.arange(g)[:, None], table.reshape(g, e * c)]
    xin = xin.reshape(g, e, c, d)
    xin = _constrain(xin, (dp_axes[0] if g > 1 else None, ep_axis, None, None))

    h = jnp.einsum("gecd,edf->gecf", xin, params["w1"].astype(xin.dtype))
    u = jnp.einsum("gecd,edf->gecf", xin, params["w3"].astype(xin.dtype))
    h = jax.nn.silu(h) * u
    out_e = jnp.einsum("gecf,efd->gecd", h, params["w2"].astype(h.dtype))
    out_e = out_e * w_table[..., None].astype(out_e.dtype)

    # scatter-add back to tokens
    flat_out = jnp.zeros((g, t + 1, d), out_e.dtype)
    flat_out = flat_out.at[
        jnp.arange(g)[:, None], table.reshape(g, e * c)
    ].add(out_e.reshape(g, e * c, d))
    out = flat_out[:, :t].reshape(b, s, d)
    aux["drop_fraction"] = 1.0 - keep.mean()
    return out.astype(x.dtype), aux


def _constrain(x, spec_tuple):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec_tuple))
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# shard_map path: dispatch stays chip-local; ONE bf16 psum combines expert
# outputs over the EP axis; FSDP weight shards are all-gathered explicitly.
# Wire per layer per chip ≈ 2·(G_loc·T·d)·bf16 (combine) + weights/dp·(n-1)
# — vs GSPMD's replicate-everything fallback (≈60 GB/layer for kimi).
# ---------------------------------------------------------------------------


def _moe_apply_shard_map(params, x, spec, mesh, *, group="seq"):
    from jax.sharding import PartitionSpec as P

    dp = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    ep = "model"
    n_ep = mesh.shape[ep]
    e = spec.n_experts
    b, s, d = x.shape
    dp_size = 1
    for n in dp:
        dp_size *= mesh.shape[n]
    if e % n_ep or b % dp_size or d % dp_size:
        return _moe_apply_local(params, x, spec, group=group)
    e_loc = e // n_ep

    def body(router, w1, w3, w2, xl):
        # xl (B_loc, S, d) replicated over ep; w* ((E_loc, d/dp, f) etc.)
        w1f = jax.lax.all_gather(w1, dp, axis=1, tiled=True)
        w3f = jax.lax.all_gather(w3, dp, axis=1, tiled=True)
        w2f = jax.lax.all_gather(w2, dp, axis=2, tiled=True)
        bl, sl, _ = xl.shape
        if group == "seq" and sl > 1:
            xg = xl
        else:
            xg = xl.reshape(1, bl * sl, d)
        g, t, _ = xg.shape
        k = spec.top_k
        c = capacity(t, spec)

        top_i, top_p, aux = route({"router": router}, xg, spec)
        flat_ids = top_i.reshape(g, t * k)
        sort_idx = jnp.argsort(flat_ids, axis=-1, stable=True)
        sorted_ids = jnp.take_along_axis(flat_ids, sort_idx, axis=-1)
        pos = _positions_in_expert(sorted_ids)
        keep = pos < c
        slot = jnp.where(keep, sorted_ids * c + pos, e * c)
        token_of_sorted = sort_idx // k
        table = jnp.full((g, e * c + 1), t, jnp.int32)
        table = table.at[jnp.arange(g)[:, None], slot].set(token_of_sorted)
        w_sorted = jnp.take_along_axis(top_p.reshape(g, t * k), sort_idx,
                                       axis=-1)
        w_table = jnp.zeros((g, e * c + 1), jnp.float32)
        w_table = w_table.at[jnp.arange(g)[:, None], slot].set(w_sorted)

        # this chip computes only ITS e_loc experts' slots
        rank = jax.lax.axis_index(ep)
        lo = rank * e_loc * c
        table_loc = jax.lax.dynamic_slice_in_dim(
            table[:, : e * c], lo, e_loc * c, axis=1)
        wt_loc = jax.lax.dynamic_slice_in_dim(
            w_table[:, : e * c], lo, e_loc * c, axis=1)

        xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
        xin = xpad[jnp.arange(g)[:, None], table_loc]        # (g, elc·c, d)
        xin = xin.reshape(g, e_loc, c, d)
        h = jnp.einsum("gecd,edf->gecf", xin, w1f.astype(xin.dtype))
        u = jnp.einsum("gecd,edf->gecf", xin, w3f.astype(xin.dtype))
        h = jax.nn.silu(h) * u
        out_e = jnp.einsum("gecf,efd->gecd", h, w2f.astype(h.dtype))
        out_e = out_e * wt_loc.reshape(g, e_loc, c, 1).astype(out_e.dtype)

        flat_out = jnp.zeros((g, t + 1, d), out_e.dtype)
        flat_out = flat_out.at[
            jnp.arange(g)[:, None], table_loc
        ].add(out_e.reshape(g, e_loc * c, d))
        out = jax.lax.psum(flat_out[:, :t], ep)              # bf16 combine
        aux["drop_fraction"] = 1.0 - keep.mean()
        # aux is model-invariant (computed from ep-replicated routing);
        # average over the data axes only
        aux = jax.tree.map(lambda a: jax.lax.pmean(a, dp), aux)
        return out.reshape(bl, sl, d).astype(xl.dtype), aux

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(ep, dp, None), P(ep, dp, None), P(ep, None, dp),
                  P(dp, None, None)),
        out_specs=(P(dp, None, None), P()))
    return fn(params["router"], params["w1"], params["w3"], params["w2"], x)
