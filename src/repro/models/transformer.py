"""Decoder LMs (dense / MoE, GQA, RoPE, sliding-window hybrid) and
bidirectional encoders (the dual-encoder towers), in functional JAX.

Layer stacks are scanned in *periods* so hybrid attention patterns
(e.g. Gemma-3's 5 local : 1 global) stay static inside the scan body:
layers = n_periods × period (+ remainder, unrolled). Uniform models use
period=1. KV caches mirror this structure; local layers keep a ring buffer
of `window` slots, global layers a full-length buffer.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers, moe as moe_lib


# ---------------------------------------------------------------------------
# Pattern → scan structure
# ---------------------------------------------------------------------------


def scan_structure(cfg) -> Tuple[int, Tuple[str, ...], Tuple[str, ...]]:
    """Return (n_periods, period_pattern, remainder_pattern)."""
    pat = cfg.pattern()
    if all(k == pat[0] for k in pat):
        return len(pat), (pat[0],), ()
    # find smallest period that tiles a prefix, leaving a remainder
    for plen in range(2, len(pat) + 1):
        period = pat[:plen]
        n = len(pat) // plen
        if n >= 1 and pat[: n * plen] == period * n:
            rem = pat[n * plen:]
            if not rem or len(rem) < plen:
                return n, period, rem
    return len(pat), (pat[0],), ()  # unreachable


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    bias = cfg.qkv_bias
    return {
        "wq": layers.dense_init(ks[0], d, h * hd, bias=bias, dtype=dtype),
        "wk": layers.dense_init(ks[1], d, kv * hd, bias=bias, dtype=dtype),
        "wv": layers.dense_init(ks[2], d, kv * hd, bias=bias, dtype=dtype),
        "wo": layers.dense_init(ks[3], h * hd, d, bias=False, dtype=dtype),
    }


def _block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": layers.norm_init(cfg.d_model, dtype=dtype),
        "ln2": layers.norm_init(cfg.d_model, dtype=dtype),
        "attn": _attn_init(k1, cfg, dtype),
    }
    if cfg.is_moe:
        p["moe"] = moe_lib.moe_init(k2, cfg.d_model, cfg.moe, dtype=dtype)
    else:
        ks = jax.random.split(k2, 3)
        d, f = cfg.d_model, cfg.d_ff
        p["mlp"] = {
            "w1": layers.dense_init(ks[0], d, f, dtype=dtype),
            "w3": layers.dense_init(ks[1], d, f, dtype=dtype),
            "w2": layers.dense_init(ks[2], f, d, dtype=dtype),
        }
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def lm_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    n_periods, period, rem = scan_structure(cfg)
    keys = jax.random.split(key, 3)
    bkeys = jax.random.split(keys[0], n_periods * len(period))
    blocks = [
        _stack([_block_init(bkeys[i * len(period) + j], cfg, dtype)
                for j in range(len(period))])
        for i in range(n_periods)
    ]
    params = {
        "embed": layers._normal(keys[1], (cfg.vocab_size, cfg.d_model),
                                1.0 / math.sqrt(cfg.d_model), dtype),
        "periods": _stack(blocks),
        "final_norm": layers.norm_init(cfg.d_model, dtype=dtype),
    }
    if rem:
        rkeys = jax.random.split(keys[2], len(rem) + 1)
        params["rem"] = _stack([_block_init(rkeys[j], cfg, dtype)
                                for j in range(len(rem))])
    if not cfg.tie_embeddings:
        params["unembed"] = layers._normal(
            jax.random.split(keys[2])[0], (cfg.d_model, cfg.vocab_size),
            1.0 / math.sqrt(cfg.d_model), dtype)
    return params


# ---------------------------------------------------------------------------
# Block apply
# ---------------------------------------------------------------------------


def _qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = layers.dense(p["wq"], x).reshape(b, s, h, hd)
    k = layers.dense(p["wk"], x).reshape(b, s, kv, hd)
    v = layers.dense(p["wv"], x).reshape(b, s, kv, hd)
    # NOTE (§Perf gemma iteration 1, REFUTED): explicit head-sharding
    # constraints here were tried and removed — GSPMD already picks the
    # column-parallel layout where legal, and for GQA configs with
    # n_kv_heads < tp the forced q-sharding (with unshardable k/v) made it
    # redistribute attention inputs (kimi collective 61.6 s → 261 s).
    q = layers.rope(q, positions, theta=cfg.rope_theta)
    k = layers.rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def _block_full(p, x, cfg, kind, *, return_cache=False, cache_len=0):
    """Train/prefill path. x: (B, S, d)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    h = layers.apply_norm(p["ln1"], x, eps=cfg.norm_eps)
    q, k, v = _qkv(p["attn"], h, cfg, positions)
    local = kind == "L" and cfg.window_size > 0
    if local and s > cfg.window_size and s % cfg.window_size == 0:
        o = layers.attention_local_banded(q, k, v, window=cfg.window_size)
    else:
        o = layers.attention_full(
            q, k, v, causal=True,
            window=cfg.window_size if local else 0,
            chunk=min(cfg.attn_chunk, s))
    o = layers.dense(p["attn"]["wo"], o.reshape(b, s, -1))
    # materialize the row-parallel output in bf16 BEFORE the f32 norm
    # consumer: otherwise XLA hoists the f32 convert above the tp
    # all-reduce and the wire doubles (§Perf gemma iteration 2)
    o = constrain(o, "dp", None, None)
    x = x + o
    x = constrain(x, "dp", None, None)
    h = layers.apply_norm(p["ln2"], x, eps=cfg.norm_eps)
    aux = {"lb_loss": jnp.float32(0), "z_loss": jnp.float32(0),
           "drop_fraction": jnp.float32(0)}
    if cfg.is_moe:
        m, aux = moe_lib.moe_apply(p["moe"], h, cfg.moe)
    else:
        g = jax.nn.silu(layers.dense(p["mlp"]["w1"], h))
        u = layers.dense(p["mlp"]["w3"], h)
        m = layers.dense(p["mlp"]["w2"], g * u)
    m = constrain(m, "dp", None, None)         # bf16 AR (see `o` above)
    x = x + m
    x = constrain(x, "dp", None, None)
    cache = None
    if return_cache:
        w = cfg.window_size if local else 0
        if local:
            last = min(s, w)
            slots = (jnp.arange(s - last, s)) % w
            kc = jnp.zeros((b, w) + k.shape[2:], k.dtype).at[:, slots].set(
                k[:, s - last:])
            vc = jnp.zeros((b, w) + v.shape[2:], v.dtype).at[:, slots].set(
                v[:, s - last:])
        else:
            pad = cache_len - s
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": kc, "v": vc}
    return x, aux, cache


def _block_decode(p, x, cache, pos, cfg, kind):
    """Decode path. x: (B, 1, d); pos: (B,) absolute position of new token."""
    b = x.shape[0]
    local = kind == "L" and cfg.window_size > 0
    h = layers.apply_norm(p["ln1"], x, eps=cfg.norm_eps)
    q, k, v = _qkv(p["attn"], h, cfg, pos[:, None])
    t = cache["k"].shape[1]
    slot = (pos % t) if local else jnp.minimum(pos, t - 1)
    kc = cache["k"].at[jnp.arange(b), slot].set(k[:, 0])
    vc = cache["v"].at[jnp.arange(b), slot].set(v[:, 0])
    o = layers.decode_attention(
        q, kc, vc, pos, window=cfg.window_size if local else 0, ring=local)
    o = layers.dense(p["attn"]["wo"], o.reshape(b, 1, -1))
    x = x + o
    h = layers.apply_norm(p["ln2"], x, eps=cfg.norm_eps)
    if cfg.is_moe:
        m, _ = moe_lib.moe_apply(p["moe"], h, cfg.moe)
    else:
        g = jax.nn.silu(layers.dense(p["mlp"]["w1"], h))
        u = layers.dense(p["mlp"]["w3"], h)
        m = layers.dense(p["mlp"]["w2"], g * u)
    x = x + m
    x = constrain(x, "dp", None, None)
    return x, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg):
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    return constrain(x, "dp", None, None)


def _maybe_remat(fn, cfg):
    if cfg.remat:
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def _group_caches(pattern, caches):
    """Group per-layer cache dicts by attention kind so shapes stack."""
    out = {}
    for kind in sorted(set(pattern)):
        out[kind] = _stack([c for c, k in zip(caches, pattern) if k == kind])
    return out


def _kind_index(pattern, j):
    """Index of layer j within its kind group."""
    return sum(1 for k in pattern[:j] if k == pattern[j])


def lm_forward(params, tokens, cfg, *, collect_cache=False, cache_len=0):
    """Returns (hidden (B,S,d), aux, cache_or_None)."""
    n_periods, period, rem = scan_structure(cfg)
    x = _embed(params, tokens, cfg)

    def period_body(x, block_p):
        auxes = []
        caches = []
        for j, kind in enumerate(period):
            pj = jax.tree.map(lambda a: a[j], block_p)
            x, aux, cache = _block_full(
                pj, x, cfg, kind, return_cache=collect_cache,
                cache_len=cache_len)
            auxes.append(aux)
            caches.append(cache)
        aux = jax.tree.map(lambda *xs: sum(xs), *auxes)
        ys = (aux, _group_caches(period, caches) if collect_cache else 0)
        return x, ys

    body = _maybe_remat(period_body, cfg)
    x, (aux_stacked, cache_main) = jax.lax.scan(
        body, x, params["periods"])
    aux = jax.tree.map(jnp.sum, aux_stacked)

    cache_rem = None
    rem_auxes = []
    if rem:
        rem_caches = []
        for j, kind in enumerate(rem):
            pj = jax.tree.map(lambda a: a[j], params["rem"])
            x, a, cch = _block_full(pj, x, cfg, kind,
                                    return_cache=collect_cache,
                                    cache_len=cache_len)
            rem_auxes.append(a)
            rem_caches.append(cch)
        if collect_cache:
            cache_rem = _group_caches(rem, rem_caches)
    if rem_auxes:
        aux = jax.tree.map(lambda a, *bs: a + sum(bs), aux, *rem_auxes)

    x = layers.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    cache = ({"main": cache_main, "rem": cache_rem} if collect_cache else None)
    return x, aux, cache


def unembed_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def lm_loss(params, batch, cfg):
    """batch: {"tokens": (B, S+1) int32}. Next-token xent + MoE aux losses."""
    tokens = batch["tokens"]
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    x, aux, _ = lm_forward(params, inp, cfg)
    loss = layers.chunked_softmax_xent(x, unembed_matrix(params, cfg), tgt)
    total = loss + 0.01 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
    metrics = {"xent": loss, "lb_loss": aux["lb_loss"],
               "z_loss": aux["z_loss"], "drop_fraction": aux["drop_fraction"]}
    return total, metrics


def lm_prefill(params, tokens, cfg, *, max_len=None):
    """Returns (last-token logits (B, V), cache). The cache is allocated at
    ``max_len`` (defaults to the prompt length) so decode can extend it."""
    b, s = tokens.shape
    x, _, cache = lm_forward(params, tokens, cfg, collect_cache=True,
                             cache_len=max_len or s)
    last = x[:, -1]
    logits = last @ unembed_matrix(params, cfg).astype(last.dtype)
    return logits.astype(jnp.float32), cache


def lm_decode_step(params, cache, token, pos, cfg):
    """token: (B, 1) int32; pos: (B,) int32. Returns (logits (B,V), cache')."""
    n_periods, period, rem = scan_structure(cfg)
    x = _embed(params, token, cfg)

    def period_body(carry, xs):
        x = carry
        block_p, cch = xs
        new_c = []
        for j, kind in enumerate(period):
            pj = jax.tree.map(lambda a: a[j], block_p)
            ki = _kind_index(period, j)
            cj = jax.tree.map(lambda a: a[ki], cch[kind])
            x, nc = _block_decode(pj, x, cj, pos, cfg, kind)
            new_c.append(nc)
        return x, _group_caches(period, new_c)

    x, cache_main = jax.lax.scan(
        period_body, x, (params["periods"], cache["main"]))

    cache_rem = cache.get("rem")
    if rem:
        new_rem = []
        for j, kind in enumerate(rem):
            pj = jax.tree.map(lambda a: a[j], params["rem"])
            ki = _kind_index(rem, j)
            cj = jax.tree.map(lambda a: a[ki], cache_rem[kind])
            x, nc = _block_decode(pj, x, cj, pos, cfg, kind)
            new_rem.append(nc)
        cache_rem = _group_caches(rem, new_rem)

    x = layers.apply_norm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = x[:, 0] @ unembed_matrix(params, cfg).astype(x.dtype)
    return logits.astype(jnp.float32), {"main": cache_main, "rem": cache_rem}


def make_decode_cache(cfg, batch, seq_len, *, dtype=None):
    """Zero KV cache pytree matching the scan structure (for specs/serving)."""
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    n_periods, period, rem = scan_structure(cfg)
    kv, hd = cfg.n_kv_heads, cfg.head_dim

    def entry(kind, lead):
        t = cfg.window_size if (kind == "L" and cfg.window_size) else seq_len
        shp = lead + (batch, t, kv, hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}

    def group(pattern, lead):
        return {kind: _stack([entry(kind, lead)
                              for k in pattern if k == kind])
                for kind in sorted(set(pattern))}

    main = group(period, (n_periods,))
    # stacking placed the kind-count dim first: (n_k, n_periods, ...) →
    # (n_periods, n_k, ...)
    main = jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1), main)
    out = {"main": main, "rem": None}
    if rem:
        out["rem"] = group(rem, ())
    return out


# ---------------------------------------------------------------------------
# Bidirectional encoder (dual-encoder towers, BERT geometry)
# ---------------------------------------------------------------------------


def encoder_init(key, cfg):
    """cfg: DualEncoderConfig-like (n_layers, d_model, n_heads, d_ff, vocab)."""
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    d = cfg.d_model
    blocks = []
    bkeys = jax.random.split(keys[0], cfg.n_layers)
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(bkeys[i])
        ks = jax.random.split(k2, 2)
        blocks.append({
            "ln1": layers.norm_init(d, kind="layer", dtype=dtype),
            "ln2": layers.norm_init(d, kind="layer", dtype=dtype),
            "attn": {
                "wq": layers.dense_init(jax.random.fold_in(k1, 0), d, d, bias=True, dtype=dtype),
                "wk": layers.dense_init(jax.random.fold_in(k1, 1), d, d, bias=True, dtype=dtype),
                "wv": layers.dense_init(jax.random.fold_in(k1, 2), d, d, bias=True, dtype=dtype),
                "wo": layers.dense_init(jax.random.fold_in(k1, 3), d, d, bias=True, dtype=dtype),
            },
            "mlp": {
                "w1": layers.dense_init(ks[0], d, cfg.d_ff, bias=True, dtype=dtype),
                "w2": layers.dense_init(ks[1], cfg.d_ff, d, bias=True, dtype=dtype),
            },
        })
    return {
        "embed": layers._normal(keys[1], (cfg.vocab_size, d),
                                1.0 / math.sqrt(d), dtype),
        "pos_embed": layers._normal(keys[2], (cfg.max_len, d), 0.02, dtype),
        "blocks": _stack(blocks),
        "final_ln": layers.norm_init(d, kind="layer", dtype=dtype),
        "cls": layers.dense_init(keys[3], d, d, bias=True, dtype=dtype),
    }


def encoder_forward(params, tokens, mask, cfg):
    """tokens: (B, L) int32; mask: (B, L) bool. Returns (B, d) CLS embedding."""
    b, l = tokens.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt) + \
        params["pos_embed"][:l].astype(cdt)[None]
    x = constrain(x, "dp", None, None)
    h_heads = cfg.n_heads
    hd = cfg.d_model // h_heads

    def body(x, p):
        h = layers.apply_norm(p["ln1"], x, eps=cfg.norm_eps)
        q = layers.dense(p["attn"]["wq"], h).reshape(b, l, h_heads, hd)
        k = layers.dense(p["attn"]["wk"], h).reshape(b, l, h_heads, hd)
        v = layers.dense(p["attn"]["wv"], h).reshape(b, l, h_heads, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) / math.sqrt(hd)
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
        o = layers.dense(p["attn"]["wo"], o.reshape(b, l, -1).astype(cdt))
        x = x + o
        h = layers.apply_norm(p["ln2"], x, eps=cfg.norm_eps)
        m = layers.dense(p["mlp"]["w2"],
                         jax.nn.gelu(layers.dense(p["mlp"]["w1"], h)))
        x = x + m
        x = constrain(x, "dp", None, None)
        return x, None

    body_fn = _maybe_remat(body, cfg) if getattr(cfg, "remat", False) else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    x = layers.apply_norm(params["final_ln"], x, eps=cfg.norm_eps)
    cls = jnp.tanh(layers.dense(params["cls"], x[:, 0]))
    return cls.astype(jnp.float32)
