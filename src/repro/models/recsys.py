"""RecSys model zoo: DLRM, xDeepFM, BERT4Rec, MIND.

The shared hot path is the sparse embedding lookup. JAX has no native
EmbeddingBag — we build it from ``jnp.take`` + ``jax.ops.segment_sum``
(``embedding_bag`` below; Pallas-tiled variant in kernels/). Big tables are
row-sharded over the "model"/"tp" axis (model-parallel embeddings); batches
ride the "dp" axes.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers


# ---------------------------------------------------------------------------
# Embedding primitives
# ---------------------------------------------------------------------------


def pad_rows(v: int, m: int = 512) -> int:
    """Round table row counts up to a multiple of ``m`` so row-sharding over
    the model axis always divides (configs keep the published sizes; the
    padding rows are dead weight, ≤0.05% for the large tables)."""
    return -(-v // m) * m


def embedding_lookup(table, idx):
    """table: (V, d); idx: int32 (...,) → (..., d)."""
    return jnp.take(table, idx, axis=0)


def embedding_bag(table, idx, offsets=None, *, segment_ids=None, n_bags=None,
                  mode="sum", weights=None):
    """EmbeddingBag built from gather + segment_sum.

    Either ``offsets`` (torch-style: bag b = idx[offsets[b]:offsets[b+1]]) or
    explicit ``segment_ids`` (one per idx entry, len n_bags) selects bags.
    """
    rows = jnp.take(table, idx, axis=0)                       # (L, d)
    if weights is not None:
        rows = rows * weights[:, None]
    if segment_ids is None:
        assert offsets is not None and n_bags is not None
        # segment id = number of offsets <= position - 1
        pos = jnp.arange(idx.shape[0])
        segment_ids = jnp.searchsorted(offsets, pos, side="right") - 1
    out = jax.ops.segment_sum(rows, segment_ids, num_segments=n_bags)
    if mode == "mean":
        cnt = jax.ops.segment_sum(jnp.ones_like(idx, rows.dtype),
                                  segment_ids, num_segments=n_bags)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _bce(logit, label):
    """Binary cross-entropy with logits, numerically stable."""
    logit = logit.astype(jnp.float32)
    label = label.astype(jnp.float32)
    return jnp.maximum(logit, 0) - logit * label + jnp.log1p(
        jnp.exp(-jnp.abs(logit)))


# ---------------------------------------------------------------------------
# DLRM (MLPerf config)
# ---------------------------------------------------------------------------


def dlrm_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 3 + len(cfg.table_sizes))
    tables = [
        layers._normal(keys[i], (pad_rows(v), cfg.embed_dim),
                       1.0 / math.sqrt(cfg.embed_dim), dtype)
        for i, v in enumerate(cfg.table_sizes)
    ]
    bot = layers.mlp_init(keys[-3], (cfg.n_dense,) + cfg.bot_mlp, dtype=dtype)
    n_feat = cfg.n_sparse + 1
    n_inter = n_feat * (n_feat - 1) // 2
    top_in = cfg.embed_dim + n_inter
    top = layers.mlp_init(keys[-2], (top_in,) + cfg.top_mlp, dtype=dtype)
    return {"tables": tables, "bot": bot, "top": top}


def dlrm_dot_interaction(feats):
    """feats: (B, F, d) → upper-triangle pairwise dots (B, F(F-1)/2).

    Pure-jnp oracle for kernels/dot_interaction.
    """
    b, f, d = feats.shape
    g = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(f, k=1)
    return g[:, iu, ju]


def dlrm_forward(params, dense, sparse, cfg):
    """dense: (B, n_dense) f32; sparse: (B, n_sparse) int32 → logits (B,)."""
    x = layers.mlp_apply(params["bot"], jnp.log1p(jnp.abs(dense)),
                         act=jax.nn.relu, final_act=jax.nn.relu)
    embs = [embedding_lookup(t, sparse[:, i])
            for i, t in enumerate(params["tables"])]
    feats = jnp.stack([x] + embs, axis=1)            # (B, 27, d)
    feats = constrain(feats, "dp", None, None)
    inter = dlrm_dot_interaction(feats)
    top_in = jnp.concatenate([x, inter], axis=-1)
    logit = layers.mlp_apply(params["top"], top_in, act=jax.nn.relu)
    return logit[..., 0]


def dlrm_loss(params, batch, cfg):
    logit = dlrm_forward(params, batch["dense"], batch["sparse"], cfg)
    loss = _bce(logit, batch["label"]).mean()
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# xDeepFM
# ---------------------------------------------------------------------------


def xdeepfm_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)
    m, d = cfg.n_sparse, cfg.embed_dim
    table = layers._normal(keys[0],
                           (pad_rows(cfg.n_sparse * cfg.vocab_per_field), d),
                           1.0 / math.sqrt(d), dtype)
    lin = layers._normal(keys[1], (pad_rows(cfg.n_sparse * cfg.vocab_per_field),),
                         0.01, dtype)
    cin_ws, h_prev = [], m
    ck = jax.random.split(keys[2], len(cfg.cin_layers))
    for i, h in enumerate(cfg.cin_layers):
        cin_ws.append(layers._normal(ck[i], (h, h_prev, m),
                                     1.0 / math.sqrt(h_prev * m), dtype))
        h_prev = h
    mlp = layers.mlp_init(keys[3], (m * d,) + cfg.mlp + (1,), dtype=dtype)
    cin_out = layers.dense_init(keys[4], sum(cfg.cin_layers), 1, bias=True,
                                dtype=dtype)
    return {"tables": table, "linear": lin, "cin": cin_ws, "mlp": mlp,
            "cin_out": cin_out}


def xdeepfm_forward(params, sparse, cfg):
    """sparse: (B, n_sparse) int32 per-field ids (field-offset applied here)."""
    b, m = sparse.shape
    offs = jnp.arange(m, dtype=sparse.dtype) * cfg.vocab_per_field
    flat = (sparse + offs[None, :]).reshape(-1)
    x0 = embedding_lookup(params["tables"], flat).reshape(b, m, cfg.embed_dim)
    x0 = constrain(x0, "dp", None, None)
    # linear term
    lin = jnp.take(params["linear"], flat).reshape(b, m).sum(-1)
    # CIN
    xk, cin_feats = x0, []
    for w in params["cin"]:
        z = jnp.einsum("bhd,bmd->bhmd", xk, x0)
        xk = jnp.einsum("bhmd,nhm->bnd", z, w.astype(z.dtype))
        cin_feats.append(xk.sum(-1))                 # (B, H_k)
    cin = layers.dense(params["cin_out"], jnp.concatenate(cin_feats, -1))[..., 0]
    # deep branch
    deep = layers.mlp_apply(params["mlp"], x0.reshape(b, -1),
                            act=jax.nn.relu)[..., 0]
    return lin + cin + deep


def xdeepfm_loss(params, batch, cfg):
    logit = xdeepfm_forward(params, batch["sparse"], cfg)
    loss = _bce(logit, batch["label"]).mean()
    return loss, {"loss": loss}


# ---------------------------------------------------------------------------
# BERT4Rec
# ---------------------------------------------------------------------------


def bert4rec_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4 + cfg.n_blocks)
    d = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        ks = jax.random.split(keys[i], 6)
        blocks.append({
            "ln1": layers.norm_init(d, kind="layer", dtype=dtype),
            "ln2": layers.norm_init(d, kind="layer", dtype=dtype),
            "wq": layers.dense_init(ks[0], d, d, bias=True, dtype=dtype),
            "wk": layers.dense_init(ks[1], d, d, bias=True, dtype=dtype),
            "wv": layers.dense_init(ks[2], d, d, bias=True, dtype=dtype),
            "wo": layers.dense_init(ks[3], d, d, bias=True, dtype=dtype),
            "w1": layers.dense_init(ks[4], d, cfg.d_ff, bias=True, dtype=dtype),
            "w2": layers.dense_init(ks[5], cfg.d_ff, d, bias=True, dtype=dtype),
        })
    return {
        # +2: [PAD]=0 row reserved, [MASK]=n_items+1; rows padded to 512×
        "item_embed": layers._normal(keys[-2], (pad_rows(cfg.n_items + 2), d),
                                     1.0 / math.sqrt(d), dtype),
        "pos_embed": layers._normal(keys[-1], (cfg.seq_len, d), 0.02, dtype),
        "blocks": blocks,
        "final_ln": layers.norm_init(d, kind="layer", dtype=dtype),
    }


def bert4rec_encode(params, seq, mask, cfg):
    """seq: (B, L) item ids; mask: (B, L) valid. → hidden (B, L, d)."""
    b, l = seq.shape
    h_heads = cfg.n_heads
    hd = cfg.embed_dim // h_heads
    x = embedding_lookup(params["item_embed"], seq) + params["pos_embed"][:l][None]
    x = constrain(x, "dp", None, None)
    for p in params["blocks"]:
        h = layers.apply_norm(p["ln1"], x)
        q = layers.dense(p["wq"], h).reshape(b, l, h_heads, hd)
        k = layers.dense(p["wk"], h).reshape(b, l, h_heads, hd)
        v = layers.dense(p["wv"], h).reshape(b, l, h_heads, hd)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(b, l, -1)
        x = x + layers.dense(p["wo"], o)
        h = layers.apply_norm(p["ln2"], x)
        x = x + layers.dense(p["w2"], jax.nn.gelu(layers.dense(p["w1"], h)))
    return layers.apply_norm(params["final_ln"], x)


def bert4rec_loss(params, batch, cfg):
    """Masked-item prediction: batch = {seq, mask, mlm_pos, mlm_tgt, mlm_mask}."""
    h = bert4rec_encode(params, batch["seq"], batch["mask"], cfg)
    pos = batch["mlm_pos"]                                  # (B, P)
    hm = jnp.take_along_axis(h, pos[..., None], axis=1)     # (B, P, d)
    logits = hm @ params["item_embed"].T.astype(h.dtype)    # (B, P, V+2)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["mlm_tgt"][..., None], -1)[..., 0]
    m = batch["mlm_mask"].astype(jnp.float32)
    loss = (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return loss, {"loss": loss}


def bert4rec_user_embedding(params, seq, mask, cfg):
    """Serving: embedding of the next-item slot = last valid position."""
    h = bert4rec_encode(params, seq, mask, cfg)
    last = jnp.maximum(mask.sum(-1) - 1, 0)                  # (B,)
    return jnp.take_along_axis(h, last[:, None, None], axis=1)[:, 0]


def bert4rec_score_all(params, seq, mask, cfg):
    u = bert4rec_user_embedding(params, seq, mask, cfg)      # (B, d)
    return u @ params["item_embed"].T.astype(u.dtype)        # (B, V+2)


# ---------------------------------------------------------------------------
# MIND (multi-interest capsules)
# ---------------------------------------------------------------------------


def mind_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "item_embed": layers._normal(keys[0], (pad_rows(cfg.n_items + 1), d),
                                     1.0 / math.sqrt(d), dtype),
        "bilinear": layers._normal(keys[1], (d, d), 1.0 / math.sqrt(d), dtype),
        "routing_init": layers._normal(keys[2], (cfg.n_interests, cfg.hist_len),
                                       1.0, dtype),
    }


def _squash(x, axis=-1):
    n2 = jnp.sum(jnp.square(x), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_interests(params, hist, hist_mask, cfg):
    """hist: (B, T) item ids → (B, K, d) interest capsules via dynamic routing."""
    e = embedding_lookup(params["item_embed"], hist)         # (B, T, d)
    e = constrain(e, "dp", None, None)
    eh = e @ params["bilinear"].astype(e.dtype)              # (B, T, d)
    b_logit = jnp.broadcast_to(params["routing_init"][None],
                               (hist.shape[0],) + params["routing_init"].shape)
    b_logit = b_logit.astype(jnp.float32)
    neg = jnp.float32(-1e30)
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(
            jnp.where(hist_mask[:, None, :], b_logit, neg), axis=-1)
        z = jnp.einsum("bkt,btd->bkd", w.astype(eh.dtype), eh)
        u = _squash(z)                                       # (B, K, d)
        b_logit = b_logit + jnp.einsum("bkd,btd->bkt", u, eh).astype(jnp.float32)
    return u


def mind_loss(params, batch, cfg):
    """Label-aware attention over interests + in-batch sampled softmax."""
    u = mind_interests(params, batch["hist"], batch["hist_mask"], cfg)
    tgt = embedding_lookup(params["item_embed"], batch["target"])  # (B, d)
    att = jax.nn.softmax(
        jnp.einsum("bkd,bd->bk", u, tgt).astype(jnp.float32) * 2.0, axis=-1)
    user = jnp.einsum("bk,bkd->bd", att.astype(u.dtype), u)  # (B, d)
    logits = (user @ tgt.T).astype(jnp.float32)              # (B, B) in-batch
    labels = jnp.arange(logits.shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.take_along_axis(logp, labels[:, None], -1).mean()
    return loss, {"loss": loss}


def mind_score_candidates(params, hist, hist_mask, cand_ids, cfg):
    """Retrieval: max-over-interest dot scores. cand_ids: (C,) → (B, C)."""
    u = mind_interests(params, hist, hist_mask, cfg)          # (B, K, d)
    ce = embedding_lookup(params["item_embed"], cand_ids)     # (C, d)
    ce = constrain(ce, "tp", None)
    s = jnp.einsum("bkd,cd->bkc", u, ce)
    return s.max(axis=1)
