"""Shared neural-net building blocks (functional, pytree params).

Everything is pure JAX: ``*_init(key, ...) -> params`` and stateless apply
functions. No framework dependency so the same code paths run under
``jax.eval_shape`` for the dry-run and eagerly for smoke tests.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initializers / dense
# ---------------------------------------------------------------------------


def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def dense_init(key, in_dim, out_dim, *, bias=False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": _normal(key, (in_dim, out_dim), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def mlp_init(key, dims, *, bias=True, dtype=jnp.float32):
    """Plain MLP: dims = (in, h1, ..., out)."""
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, dims[i], dims[i + 1], bias=bias, dtype=dtype)
            for i, k in enumerate(keys)]


def mlp_apply(params, x, *, act=jax.nn.relu, final_act=None):
    for i, p in enumerate(params):
        x = dense(p, x)
        if i < len(params) - 1:
            x = act(x)
        elif final_act is not None:
            x = final_act(x)
    return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(dim, *, kind="rms", dtype=jnp.float32):
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layer":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(p, x, *, eps=1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        # mean-square via a contraction: no (..., d) f32 square tensor is
        # materialized at any fusion boundary (§Perf gemma iteration 2)
        d = x32.shape[-1]
        ms = jnp.einsum("...d,...d->...", x32, x32)[..., None] / d
        y = x32 * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, *, theta=10_000.0):
    """Rotary embedding. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq          # (..., S, half)
    ang = ang[..., None, :]                                        # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# Attention — full-sequence (train / prefill), chunked online softmax.
# q: (B, S, H, D); k, v: (B, S, KV, D). H = KV * G (grouped-query).
# Pure-jnp oracle path; the Pallas flash kernel (kernels/flash_attention.py)
# implements the same contract for TPU.
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def _split_groups(q, n_kv):
    b, s, h, d = q.shape
    g = h // n_kv
    return q.reshape(b, s, n_kv, g, d)


def attention_full(q, k, v, *, causal=True, window=0, chunk=1024,
                   positions_q=None, positions_k=None):
    """Chunked online-softmax attention (memory O(S·chunk) not O(S²)).

    window > 0 limits attention to the last `window` positions (inclusive of
    self): pos_q - pos_k < window. Causal is required when window is set.
    """
    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    scale = 1.0 / math.sqrt(d)
    if positions_q is None:
        positions_q = jnp.arange(sq)
    if positions_k is None:
        positions_k = jnp.arange(sk)

    qg = _split_groups(q, n_kv).astype(jnp.float32) * scale  # (B,Sq,KV,G,D)
    chunk = min(chunk, sk)
    if sk % chunk:  # pad keys to a chunk multiple; padded slots masked out
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions_k = jnp.pad(positions_k, (0, pad),
                              constant_values=jnp.iinfo(jnp.int32).max)
        sk = sk + pad
    n_chunks = sk // chunk
    k_ch = k.reshape(b, n_chunks, chunk, n_kv, d)
    v_ch = v.reshape(b, n_chunks, chunk, n_kv, d)
    pk_ch = positions_k.reshape(n_chunks, chunk)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, pk = xs  # (B,chunk,KV,D), (B,chunk,KV,D), (chunk,)
        s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, kc.astype(jnp.float32))
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= positions_q[:, None] >= pk[None, :]
        if window:
            mask &= (positions_q[:, None] - pk[None, :]) < window
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqj,bjkd->bkgqd", p, vc.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, n_kv, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, n_kv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, n_kv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(k_ch, 1, 0), jnp.moveaxis(v_ch, 1, 0), pk_ch))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,KV,G,Sq,D)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, d)
    return out.astype(q.dtype)


def attention_local_banded(q, k, v, *, window, block=None):
    """Sliding-window attention via banded blocks: O(S·2W) compute/memory.

    Each q block of size W attends to [own block, previous block] with an
    exact band mask — equivalent to window-limited causal attention when
    block >= window.
    """
    b, s, h, d = q.shape
    _, _, n_kv, _ = k.shape
    g = h // n_kv
    block = block or window
    assert block >= window and s % block == 0
    nb = s // block
    scale = 1.0 / math.sqrt(d)

    qb = q.reshape(b, nb, block, n_kv, g, d).astype(jnp.float32) * scale
    kb = k.reshape(b, nb, block, n_kv, d)
    vb = v.reshape(b, nb, block, n_kv, d)
    # kv pair = (previous block, own block); previous of block 0 is zeros
    pad = jnp.zeros_like(kb[:, :1])
    k2 = jnp.concatenate([jnp.concatenate([pad, kb[:, :-1]], 1), kb], axis=2)
    v2 = jnp.concatenate([jnp.concatenate([pad, vb[:, :-1]], 1), vb], axis=2)
    s_ = jnp.einsum("bnqkgd,bnjkd->bnkgqj", qb, k2.astype(jnp.float32))
    # positions within the 2-block window
    pos_q = jnp.arange(block)[:, None] + block       # local index in [block,2b)
    pos_k = jnp.arange(2 * block)[None, :]
    mask = (pos_q >= pos_k) & (pos_q - pos_k < window)
    first = jnp.arange(nb) == 0                      # block 0 has no prev
    mask_first = mask & (pos_k >= block)
    full_mask = jnp.where(first[:, None, None], mask_first[None], mask[None])
    s_ = jnp.where(full_mask[None, :, None, None], s_, _NEG_INF)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bnkgqj,bnjkd->bnkgqd", p, v2.astype(jnp.float32))
    o = jnp.moveaxis(o, (1, 4), (1, 2)).reshape(b, s, h, d)
    return o.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window=0, ring=False):
    """Single-token decode against a (possibly ring-buffer) KV cache.

    q: (B, 1, H, D); caches: (B, T, KV, D); pos: (B,) current absolute
    position (the new token's position). ring=True means cache slot
    j holds absolute position p ≡ j (mod T) with p in (pos-T, pos].
    """
    b, _, h, d = q.shape
    _, t, n_kv, _ = k_cache.shape
    g = h // n_kv
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, n_kv, g, d).astype(jnp.float32) * scale
    s = jnp.einsum("bkgd,bjkd->bkgj", qg, k_cache.astype(jnp.float32))
    slot = jnp.arange(t)[None, :]                    # (1, T)
    p = pos[:, None]
    if ring:
        # absolute position held by slot j
        abs_pos = p - ((p - slot) % t)
        valid = abs_pos >= 0
        if window:
            valid &= (p - abs_pos) < window
    else:
        valid = slot <= p
        if window:
            valid &= (p - slot) < window
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgj,bjkd->bkgd", w, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def chunked_softmax_xent(x, unembed, targets, *, chunk=512, mask=None):
    """Cross-entropy over a large vocab, computed in sequence chunks so the
    (B, S, V) logits tensor is never materialized whole.

    x: (B, S, d) final hidden states; unembed: (d, V); targets: (B, S) int32.
    Returns mean loss over (masked) tokens.
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    n = s // chunk
    xc = x.reshape(b, n, chunk, d)
    tc = targets.reshape(b, n, chunk)
    mc = (mask.reshape(b, n, chunk) if mask is not None
          else jnp.ones((b, n, chunk), bool))

    def body(carry, xs):
        tot, cnt = carry
        xi, ti, mi = xs  # (B,chunk,d), (B,chunk), (B,chunk)
        logits = (xi @ unembed.astype(xi.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mi
        return (tot + nll.sum(), cnt + mi.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)),
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(tc, 1, 0),
         jnp.moveaxis(mc, 1, 0).astype(jnp.float32)))
    return tot / jnp.maximum(cnt, 1.0)
