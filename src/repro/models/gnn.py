"""GatedGCN [arXiv:2003.00982] with segment_sum message passing.

JAX has no CSR SpMM — message passing is built from ``jnp.take`` over an
edge index plus ``jax.ops.segment_sum`` (this IS part of the system, per the
assignment). Works in three regimes: full-batch node classification,
sampled-subgraph training (see data/graph_data.py for the neighbor sampler),
and batched small graphs with graph-level readout.

Graph dict contract (all arrays padded to static shapes):
  x          (N, d_in)   node features
  edge_src   (E,) int32  message source
  edge_dst   (E,) int32  message destination
  edge_attr  (E, d_e)    optional edge features (zeros if absent)
  node_mask  (N,)  bool  valid nodes
  edge_mask  (E,)  bool  valid edges
  graph_ids  (N,) int32  graph id per node (batched readout) [optional]
  labels     (N,) or (G,)  targets
  label_mask (N,) or (G,) which targets count (e.g. seed nodes)
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers


def gnn_init(key, cfg, d_in, n_classes, d_edge_in=0):
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_hidden
    keys = jax.random.split(key, cfg.n_layers + 4)
    blocks = []
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[i], 5)
        blocks.append({
            "A": layers.dense_init(ks[0], d, d, bias=True, dtype=dtype),
            "B": layers.dense_init(ks[1], d, d, bias=True, dtype=dtype),
            "C": layers.dense_init(ks[2], d, d, bias=True, dtype=dtype),
            "U": layers.dense_init(ks[3], d, d, bias=True, dtype=dtype),
            "V": layers.dense_init(ks[4], d, d, bias=True, dtype=dtype),
            "ln_h": layers.norm_init(d, kind="layer", dtype=dtype),
            "ln_e": layers.norm_init(d, kind="layer", dtype=dtype),
        })
    return {
        "node_in": layers.dense_init(keys[-4], d_in, d, bias=True, dtype=dtype),
        "edge_in": layers.dense_init(keys[-3], max(d_edge_in, 1), d, bias=True,
                                     dtype=dtype),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "readout": layers.dense_init(keys[-2], d, n_classes, bias=True,
                                     dtype=dtype),
    }


def gnn_forward(params, graph, cfg):
    """Returns logits: (N, n_classes) or (G, n_classes) for batched graphs."""
    n = graph["x"].shape[0]
    src, dst = graph["edge_src"], graph["edge_dst"]
    emask = graph["edge_mask"].astype(jnp.float32)[:, None]

    h = layers.dense(params["node_in"], graph["x"])
    h = constrain(h, "all", None)
    if "edge_attr" in graph and graph["edge_attr"] is not None:
        e = layers.dense(params["edge_in"], graph["edge_attr"])
    else:
        e = jnp.zeros((src.shape[0], cfg.d_hidden), h.dtype)
    e = constrain(e, "all", None)

    def body(carry, p):
        h, e = carry
        h_src = jnp.take(h, src, axis=0)          # (E, d) gather
        h_dst = jnp.take(h, dst, axis=0)
        # edge update: e' = e + ReLU(LN(A h_dst + B h_src + C e))
        e_new = layers.dense(p["A"], h_dst) + layers.dense(p["B"], h_src) \
            + layers.dense(p["C"], e)
        e_new = e + jax.nn.relu(layers.apply_norm(p["ln_e"], e_new))
        # gated aggregation
        eta = jax.nn.sigmoid(e_new) * emask
        msg = eta * layers.dense(p["V"], h_src)
        agg = jax.ops.segment_sum(msg, dst, num_segments=n)
        den = jax.ops.segment_sum(eta, dst, num_segments=n) + 1e-6
        upd = layers.dense(p["U"], h) + agg / den
        h_new = h + jax.nn.relu(layers.apply_norm(p["ln_h"], upd))
        if cfg.residual:
            pass  # residual already in the += forms above
        h_new = constrain(h_new, "all", None)
        e_new = constrain(e_new, "all", None)
        return (h_new, e_new), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, e), _ = jax.lax.scan(body, (h, e), params["blocks"])

    if "graph_ids" in graph and graph["graph_ids"] is not None:
        n_graphs = graph["n_graphs"]
        mask = graph["node_mask"].astype(h.dtype)[:, None]
        pooled = jax.ops.segment_sum(h * mask, graph["graph_ids"],
                                     num_segments=n_graphs)
        cnt = jax.ops.segment_sum(mask, graph["graph_ids"],
                                  num_segments=n_graphs)
        h = pooled / jnp.maximum(cnt, 1.0)
    return layers.dense(params["readout"], h)


def gnn_loss(params, graph, cfg):
    logits = gnn_forward(params, graph, cfg)
    labels = graph["labels"]
    lmask = graph["label_mask"].astype(jnp.float32)
    if logits.shape[-1] == 1:  # binary / regression head
        p = logits[..., 0]
        loss = jnp.square(p - labels.astype(jnp.float32))
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        loss = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = (loss * lmask).sum() / jnp.maximum(lmask.sum(), 1.0)
    acc = None
    if logits.shape[-1] > 1:
        acc = (((logits.argmax(-1) == labels) * lmask).sum()
               / jnp.maximum(lmask.sum(), 1.0))
    return loss, {"loss": loss, "acc": acc if acc is not None else loss}
