"""Vocab-tiled EmbeddingBag: multi-hot pooled lookup as MXU one-hot matmuls.

Regime note (DESIGN.md §4): this kernel targets *hash-bucketed / small-vocab*
tables (V up to a few 10k), where streaming the table through VMEM in
(block_v, d) tiles and accumulating ``onehot(idx ∈ tile) @ tile`` on the MXU
beats a host of scalar gathers — the standard TPU trick for pooled sparse
lookups without SparseCore. For the 40M-row DLRM tables the models use the
XLA-native gather (``jnp.take`` + ``segment_sum`` in models/recsys.py),
which GSPMD shards row-parallel; that path is the production default.

Inputs use the fixed multi-hot layout: idx (B, P) int32 per-bag pooled
indices, padded with -1 (weight 0).

Grid: (B/block_m, V/block_v), v innermost; the output block accumulates
partial pools across vocab tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, tab_ref, o_ref, *, block_v: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]                                  # (bm, P)
    v_start = j * block_v
    local = idx - v_start                               # position in tile
    in_tile = (local >= 0) & (local < block_v) & (idx >= 0)
    # multi-hot over the tile: (bm, block_v)
    iota = jax.lax.broadcasted_iota(jnp.int32, (block_v,), 0)
    onehot = (local[..., None] == iota[None, None, :]) & in_tile[..., None]
    counts = onehot.sum(axis=1).astype(jnp.float32)     # (bm, block_v)
    tab = tab_ref[...].astype(jnp.float32)              # (block_v, d)
    o_ref[...] += jax.lax.dot_general(
        counts, tab, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def embedding_bag(table, idx, *, block_m: int = 256, block_v: int = 512,
                  interpret: bool = True):
    """table: (V, d); idx: (B, P) int32, -1 padded → pooled sums (B, d)."""
    v, d = table.shape
    b, p = idx.shape
    block_m = min(block_m, b)
    block_v = min(block_v, v)
    pad_v = (-v) % block_v
    tab = jnp.pad(table, ((0, pad_v), (0, 0)))
    assert b % block_m == 0
    grid = (b // block_m, (v + pad_v) // block_v)
    return pl.pallas_call(
        functools.partial(_kernel, block_v=block_v),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, p), lambda i, j: (i, 0)),
            pl.BlockSpec((block_v, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(idx, tab)
