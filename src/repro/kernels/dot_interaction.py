"""DLRM pairwise-dot feature interaction: (B, F, d) → (B, F(F-1)/2).

The Pallas kernel computes the batched Gram matrix G = X Xᵀ — one MXU
batched matmul per batch tile, fp32 accumulation. The static upper-triangle
compaction (a compile-time-constant shuffle) happens OUTSIDE the kernel in
plain XLA: Pallas forbids captured constant index arrays, and a fixed
gather is XLA's bread and butter anyway — it fuses with the downstream
top-MLP concat. The kernel owns the FLOPs; XLA owns the layout shuffle.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                # (bm, F, d)
    g = jax.lax.dot_general(
        x, x, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)            # (bm, F, F)
    o_ref[...] = g.astype(o_ref.dtype)


def dot_interaction(feats, *, block_m: int = 128, interpret: bool = True):
    """feats: (B, F, d) → (B, F(F-1)/2) upper-triangle pairwise dots."""
    b, f, d = feats.shape
    block_m = min(block_m, b)
    assert b % block_m == 0
    gram = pl.pallas_call(
        _kernel,
        grid=(b // block_m,),
        in_specs=[pl.BlockSpec((block_m, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((block_m, f, f), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f, f), feats.dtype),
        interpret=interpret,
    )(feats)
    iu, ju = np.triu_indices(f, k=1)
    return gram[:, iu, ju]
