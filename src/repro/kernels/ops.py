"""Jit'd dispatch wrappers for the Pallas kernels.

``interpret=None`` auto-detects: compiled to Mosaic on a real TPU (or when
forced via env REPRO_PALLAS_COMPILE=1), Pallas interpreter everywhere else
(this CPU container) for correctness validation. The same rule backs
core/engine.default_interpret so every entry point agrees.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import (
    dot_interaction as _di,
    embedding_bag as _eb,
    flash_attention as _fa,
    fused_topk_score as _fts,
)


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE", "0") == "1":
        return False
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("k", "dist_max", "block_m",
                                             "block_n", "interpret"))
def fused_topk_score(q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids, w_hat,
                     *, k, dist_max, block_m=8, block_n=512, cand_scale=None,
                     interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _fts.fused_topk_score(
        q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids, w_hat, k=k,
        dist_max=dist_max, block_m=block_m, block_n=block_n,
        cand_scale=cand_scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "dist_max", "block_n",
                                             "interpret"))
def fused_topk_score_routed(q_emb, q_loc, w_st, top_c, buf_emb, buf_loc,
                            buf_ids, w_hat, *, k, dist_max, block_n=512,
                            buf_scale=None, interpret=None):
    """Gather-free query-phase kernel: scalar-prefetched cluster routing.
    ``buf_scale (c, cap)`` enables the dequant-in-kernel path for int8
    resident buffers (DESIGN.md §9)."""
    interpret = _interpret_default() if interpret is None else interpret
    return _fts.fused_topk_score_routed(
        q_emb, q_loc, w_st, top_c, buf_emb, buf_loc, buf_ids, w_hat, k=k,
        dist_max=dist_max, block_n=block_n, buf_scale=buf_scale,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "dist_max", "n_total",
                                             "block_n", "interpret"))
def fused_topk_score_cluster_major(q_emb_r, q_loc_r, w_st_r, u, roster,
                                   buf_emb, buf_loc, buf_ids, w_hat, *, k,
                                   dist_max, n_total, block_n=512,
                                   buf_scale=None, interpret=None):
    """Cluster-major query-phase kernel: stream each distinct routed
    cluster once per batch against its whole query roster (DESIGN.md
    §10). Inputs/outputs per the kernel docstring — fold the returned
    per-roster-slot partial top-k lists with
    ``engine.merge_cluster_major``."""
    interpret = _interpret_default() if interpret is None else interpret
    return _fts.fused_topk_score_cluster_major(
        q_emb_r, q_loc_r, w_st_r, u, roster, buf_emb, buf_loc, buf_ids,
        w_hat, k=k, dist_max=dist_max, n_total=n_total, block_n=block_n,
        buf_scale=buf_scale, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def dot_interaction(feats, *, block_m=128, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _di.dot_interaction(feats, block_m=block_m, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_v",
                                             "interpret"))
def embedding_bag(table, idx, *, block_m=256, block_v=512, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _eb.embedding_bag(table, idx, block_m=block_m, block_v=block_v,
                             interpret=interpret)
