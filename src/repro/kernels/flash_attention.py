"""Flash attention (block-wise online softmax) for train/prefill paths.

Contract matches ``layers.attention_full``: causal (+ optional sliding
window), GQA via a group-size fold in the index maps. fp32 accumulation,
inputs any float dtype.

Grid: (B·H, S_q/block_q, S_k/block_k), k innermost. Running (m, l, acc)
live in VMEM scratch; the output block is written on the last k step.
Fully-masked k blocks (causal/window) are skipped with ``pl.when`` — this
is what makes the sliding-window cells sub-quadratic on the dry-run HLO.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, causal: bool,
            window: int, seq_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_k

    # block-level mask culling: block is live iff some (q, k) pair in it
    # satisfies k <= q (causal) and q - k < window.
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window > 0:
        # earliest q in block vs latest k in block must be inside the window
        live = jnp.logical_and(
            live, q_start - (k_start + block_k - 1) < window)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale             # (bq, d)
        kk = k_ref[0].astype(jnp.float32)                    # (bk, d)
        s = jax.lax.dot_general(q, kk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos_q = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        pos_k = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos_k < seq_k
        if causal:
            mask &= pos_q >= pos_k
        if window > 0:
            mask &= (pos_q - pos_k) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, S, H, D); k, v: (B, S, KV, D). Returns (B, S, H, D).

    H = KV · G. Sequences are padded to block multiples internally.
    """
    from jax.experimental.pallas import tpu as pltpu

    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)

    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sqp, skp = sq + pad_q, sk + pad_k

    # fold (B, S, H, D) -> (B·H, S, D) so one grid axis covers batch×head
    qf = jnp.moveaxis(qp, 2, 1).reshape(b * h, sqp, d)
    kf = jnp.moveaxis(kp, 2, 1).reshape(b * n_kv, skp, d)
    vf = jnp.moveaxis(vp, 2, 1).reshape(b * n_kv, skp, d)

    grid = (b * h, sqp // block_q, skp // block_k)
    kern = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, seq_k=sk)

    def kv_map(hh, i, j):
        # head hh of q maps to kv head hh//g within its batch
        return ((hh // (h)) * n_kv + (hh % h) // g, j, 0)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, i, j: (hh, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hh, i, j: (hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(b, h, sqp, d)[:, :, :sq]
    return jnp.moveaxis(out, 1, 2)
