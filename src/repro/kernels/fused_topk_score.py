"""Fused spatio-textual score + running top-k — LIST's query-phase hot loop.

This is the op the paper's entire index exists to accelerate (Algorithm 1
line 17): for each routed query, score every object in its cluster buffer
with ST(q,o) = w_t·(q·o) + w_s·ŵ_s[⌊S_in·t⌋] and keep the top-k.

TPU-native design (DESIGN.md §3/§4): the candidate buffer streams through
VMEM in (block_n, d) tiles; each tile costs one (block_m × d × block_n)
MXU matmul for TRel plus a vectorized O(1) step-table lookup for SRel
(Eq. 5) — the spatial relevance never round-trips to HBM. A running top-k
lives in the revisited output block: per tile we concatenate (k + block_n)
candidates and re-top-k, so the merge cost is O(k+block_n · log) in VMEM.
The workload is memory-bound (corpus streaming); fusing score + spatial +
select into one pass is what reaches the HBM roofline.

Grid: (B/block_m, N/block_n), last dim innermost (sequential) so output
revisiting is legal on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, loc_ref, w_ref, wh_ref, ce_ref, cl_ref, ci_ref,
            os_ref, oi_ref, *, k: int, t: int, dist_max: float,
            block_n: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, NEG_INF)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    q = q_ref[...].astype(jnp.float32)            # (bm, d)
    ce = ce_ref[...].astype(jnp.float32)          # (bm, bn, d)
    trel = jax.lax.dot_general(
        q, ce, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)        # (bm, bn)

    # spatial: s_in = 1 - clip(dist/dist_max); srel = w_hat[floor(s_in*t)]
    dloc = loc_ref[...][:, None, :] - cl_ref[...]  # (bm, bn, 2)
    dist = jnp.sqrt(jnp.sum(dloc * dloc, axis=-1))
    s_in = 1.0 - jnp.clip(dist / dist_max, 0.0, 1.0)
    idx = jnp.clip((s_in * t).astype(jnp.int32), 0, t - 1)
    srel = jnp.take(wh_ref[...], idx)              # (bm, bn) O(1) lookup

    w = w_ref[...].astype(jnp.float32)             # (bm, 2)
    st = w[:, :1] * trel + w[:, 1:2] * srel
    ids = ci_ref[...]                              # (bm, bn) object ids
    st = jnp.where(ids >= 0, st, NEG_INF)          # mask buffer padding

    # local candidate positions within the full N axis
    local = j * block_n + jax.lax.broadcasted_iota(jnp.int32, st.shape, 1)

    # merge with the running top-k held in the revisited output block
    cat_s = jnp.concatenate([os_ref[...], st], axis=1)       # (bm, k+bn)
    cat_i = jnp.concatenate([oi_ref[...], local], axis=1)
    vals, pos = jax.lax.top_k(cat_s, k)
    os_ref[...] = vals
    oi_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)


def fused_topk_score(q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids,
                     w_hat, *, k: int, dist_max: float,
                     block_m: int = 8, block_n: int = 512,
                     interpret: bool = True):
    """Returns (scores (B, k) f32, local_idx (B, k) i32).

    q_emb (B, d); q_loc (B, 2); w_st (B, 2); cand_emb (B, N, d);
    cand_loc (B, N, 2); cand_ids (B, N) int32 (-1 pad); w_hat (t,) f32.
    """
    b, n, d = cand_emb.shape
    t = w_hat.shape[0]
    block_m = min(block_m, b)
    block_n = min(block_n, n)
    assert b % block_m == 0 and n % block_n == 0, (b, n, block_m, block_n)
    grid = (b // block_m, n // block_n)

    kern = functools.partial(_kernel, k=k, t=t, dist_max=float(dist_max),
                             block_n=block_n)
    out_shape = [
        jax.ShapeDtypeStruct((b, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k), jnp.int32),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),       # q_emb
            pl.BlockSpec((block_m, 2), lambda i, j: (i, 0)),       # q_loc
            pl.BlockSpec((block_m, 2), lambda i, j: (i, 0)),       # w_st
            pl.BlockSpec((t,), lambda i, j: (0,)),                 # w_hat
            pl.BlockSpec((block_m, block_n, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_m, block_n, 2), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),       # scores
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),       # idx
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(q_emb, q_loc, w_st, w_hat, cand_emb, cand_loc, cand_ids)
