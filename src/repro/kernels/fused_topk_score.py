"""Fused spatio-textual score + running top-k — LIST's query-phase hot loop.

This is the op the paper's entire index exists to accelerate (Algorithm 1
line 17): for each routed query, score every object in its cluster buffer
with ST(q,o) = w_t·(q·o) + w_s·ŵ_s[⌊S_in·t⌋] and keep the top-k.

TPU-native design (DESIGN.md §3/§4): the candidate buffer streams through
VMEM in (block_n, d) tiles; each tile costs one (block_m × d × block_n)
MXU matmul for TRel plus a vectorized O(1) step-table lookup for SRel
(Eq. 5) — the spatial relevance never round-trips to HBM. A running top-k
lives in the revisited output block: per tile we concatenate (k + block_n)
candidates and re-top-k, so the merge cost is O(k+block_n · log) in VMEM.
The workload is memory-bound (corpus streaming); fusing score + spatial +
select into one pass is what reaches the HBM roofline.

Grid: (B/block_m, N/block_n), last dim innermost (sequential) so output
revisiting is legal on TPU.

Three variants live here:

* :func:`fused_topk_score` — the original gather-path kernel. The caller
  materializes a ``(B, cr·cap, d)`` candidate copy (``buf[top_c]``) and the
  kernel streams that copy. Simple, but the gather itself is an HBM round
  trip the size of the scanned corpus slice.
* :func:`fused_topk_score_routed` — the gather-free kernel (DESIGN.md §4).
  The routed cluster ids are **scalar-prefetched**
  (``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index maps can
  block-index the resident ``(c, cap, d)`` buffers directly: grid step
  ``(b, r, j)`` DMAs tile ``j`` of cluster ``top_c[b, r]`` straight from the
  buffer — no candidate copy exists at any point, and the ``cr`` routed
  lists merge into one running top-k in VMEM instead of a second host-side
  top-k. Output ids are global object ids (taken from ``buf_ids`` in-kernel)
  so the caller needs no ``take_along_axis`` either.
* :func:`fused_topk_score_cluster_major` — the batched-IVF inversion of
  the routed kernel (DESIGN.md §10). The routed kernel is query-major:
  its ``(B, cr, cap/bn)`` grid re-streams a popular cluster's tiles once
  per routed query, so under skewed routing the dominant HBM stream is
  ``B·cr/U``× larger than the distinct-cluster working set ``U``. This
  kernel runs the batch plan of ``serving.cluster_major_plan`` instead:
  grid ``(u_max, cap/bn)`` scalar-prefetches the distinct routed
  clusters ``u`` and their query roster, DMAs each distinct cluster's
  tiles **once per batch**, and scores them against the cluster's whole
  roster in a single ``(Qcap, d) × (d, bn)`` MXU matmul. Per-roster-slot
  running top-k lives in the revisited ``(1, Qcap, k)`` output block;
  the caller folds the ``cr`` partial lists per query with
  ``engine.merge_cluster_major`` (a thin scatter + one top-k). With a
  quantized buffer the dequant also happens once per distinct cluster
  per batch, not once per route — the dedup and the precision cut
  compose multiplicatively.

Precision policy (DESIGN.md §9): the roofline is set by streaming the
candidate embeddings, so every kernel here grows a **dequant-in-kernel**
variant for quantized resident buffers. When a per-row scale array is passed
(``cand_scale`` / ``buf_scale``, int8 buffers), the compressed tile is
DMA'd to VMEM, upcast to f32 and multiplied by its scales *there*, and
then hits the same MXU matmul and running top-k — only compressed bytes
ever cross HBM (4× less traffic than f32 for int8). bf16 buffers need no
scale: the existing ``astype(f32)`` upcast handles them, halving traffic.
Locations, ids, and the padding mask always stay exact, so SRel and the
pad semantics are bit-identical across precision tiers. On a real TPU the
int8 min tile is (32, 128), so pick ``block_n`` a multiple of 32 and keep
``d`` a multiple of 128 for compiled int8 runs (interpret mode doesn't
care).

Filtered search (DESIGN.md §13): the routed and cluster-major kernels
grow an in-VMEM **predicate mask** variant for multi-tenant / attribute
filtering (core/filters.py). When a ``(c, cap, 3)`` int32 attribute
buffer and per-query compiled filter rows (``q_filt (B, 4)`` /
roster-gathered ``(u_max, Qcap, 4)``) are passed, each tile's attribute
strip is DMA'd beside the embeddings and the predicate is evaluated
right where the dequant happens: rows that fail score ``NEG_INF`` and
their ids null to ``-1`` — exactly the padding semantics — so filtered
candidates never round-trip to host and can never surface in a top-k.
The unfiltered call path is byte-identical to before (no attrs bytes
stream, same kernel body).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _largest_divisor_tile(size: int, requested: int) -> int:
    """The largest tile ≤ ``requested`` that divides ``size`` exactly."""
    tile = min(requested, size)
    if size % tile:
        tile = next(t for t in range(tile, 0, -1) if size % t == 0)
    return tile


def _predicate_tile(attrs, fvals):
    """In-VMEM filter predicate (the kernel twin of
    ``filters.predicate_mask``): ``attrs`` int32 ``(n, 3)`` candidate
    attribute rows [tenant, category bitmask, timestamp]; ``fvals`` int32
    ``(m, 4)`` compiled per-query filters [tenant, mask, t_min, t_max]
    with sentinel no-ops (tenant<0, mask==0, int32 extremes). Returns
    bool ``(m, n)`` — True = candidate passes that query's filter."""
    tenant = attrs[None, :, 0]                       # (1, n)
    cat = attrs[None, :, 1]
    ts = attrs[None, :, 2]
    f_tenant = fvals[:, 0:1]                         # (m, 1)
    f_mask = fvals[:, 1:2]
    t_lo = fvals[:, 2:3]
    t_hi = fvals[:, 3:4]
    ok_tenant = (f_tenant < 0) | (tenant == f_tenant)
    ok_cat = (f_mask == 0) | ((cat & f_mask) != 0)
    ok_time = (ts >= t_lo) & (ts <= t_hi)
    return ok_tenant & ok_cat & ok_time


def _gather_body(q_ref, loc_ref, w_ref, wh_ref, ce, cl_ref, ci_ref,
                 os_ref, oi_ref, *, k: int, t: int, dist_max: float,
                 block_n: int):
    """Score one (block_m, block_n) candidate tile (``ce`` already f32,
    dequantized by the caller) and fold it into the running top-k."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, NEG_INF)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    q = q_ref[...].astype(jnp.float32)            # (bm, d)
    trel = jax.lax.dot_general(
        q, ce, (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)        # (bm, bn)

    # spatial: s_in = 1 - clip(dist/dist_max); srel = w_hat[floor(s_in*t)]
    dloc = loc_ref[...][:, None, :] - cl_ref[...]  # (bm, bn, 2)
    dist = jnp.sqrt(jnp.sum(dloc * dloc, axis=-1))
    s_in = 1.0 - jnp.clip(dist / dist_max, 0.0, 1.0)
    idx = jnp.clip((s_in * t).astype(jnp.int32), 0, t - 1)
    srel = jnp.take(wh_ref[...], idx)              # (bm, bn) O(1) lookup

    w = w_ref[...].astype(jnp.float32)             # (bm, 2)
    st = w[:, :1] * trel + w[:, 1:2] * srel
    ids = ci_ref[...]                              # (bm, bn) object ids
    st = jnp.where(ids >= 0, st, NEG_INF)          # mask buffer padding

    # local candidate positions within the full N axis
    local = j * block_n + jax.lax.broadcasted_iota(jnp.int32, st.shape, 1)

    # merge with the running top-k held in the revisited output block
    cat_s = jnp.concatenate([os_ref[...], st], axis=1)       # (bm, k+bn)
    cat_i = jnp.concatenate([oi_ref[...], local], axis=1)
    vals, pos = jax.lax.top_k(cat_s, k)
    os_ref[...] = vals
    oi_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)


def _kernel(q_ref, loc_ref, w_ref, wh_ref, ce_ref, cl_ref, ci_ref,
            os_ref, oi_ref, **kw):
    # f32/bf16 tile: the astype is the whole upcast, no scales stream
    _gather_body(q_ref, loc_ref, w_ref, wh_ref,
                 ce_ref[...].astype(jnp.float32),
                 cl_ref, ci_ref, os_ref, oi_ref, **kw)


def _kernel_dequant(q_ref, loc_ref, w_ref, wh_ref, ce_ref, cs_ref, cl_ref,
                    ci_ref, os_ref, oi_ref, **kw):
    # int8 tile: upcast + per-row scale in VMEM, then the same MXU matmul
    ce = ce_ref[...].astype(jnp.float32) * cs_ref[...][..., None]
    _gather_body(q_ref, loc_ref, w_ref, wh_ref, ce,
                 cl_ref, ci_ref, os_ref, oi_ref, **kw)


def fused_topk_score(q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids,
                     w_hat, *, k: int, dist_max: float,
                     block_m: int = 8, block_n: int = 512,
                     cand_scale=None, interpret: bool = True):
    """Returns (scores (B, k) f32, local_idx (B, k) i32).

    q_emb (B, d); q_loc (B, 2); w_st (B, 2); cand_emb (B, N, d) in f32,
    bf16, or int8; cand_loc (B, N, 2); cand_ids (B, N) int32 (-1 pad);
    w_hat (t,) f32; cand_scale (B, N) f32 per-row dequant scales
    (required for int8 candidates, omitted otherwise — when given, the
    tile is dequantized in VMEM before scoring).
    """
    b, n, d = cand_emb.shape
    t = w_hat.shape[0]
    # both tile sizes clamp to the largest exact divisor — an odd batch
    # (b % block_m != 0) must never crash the serve path
    block_m = _largest_divisor_tile(b, block_m)
    block_n = _largest_divisor_tile(n, block_n)
    grid = (b // block_m, n // block_n)

    dequant = cand_scale is not None
    kern = functools.partial(_kernel_dequant if dequant else _kernel,
                             k=k, t=t, dist_max=float(dist_max),
                             block_n=block_n)
    emb_specs = [pl.BlockSpec((block_m, block_n, d), lambda i, j: (i, j, 0))]
    emb_args = [cand_emb]
    if dequant:
        emb_specs.append(pl.BlockSpec((block_m, block_n),
                                      lambda i, j: (i, j)))
        emb_args.append(cand_scale)
    out_shape = [
        jax.ShapeDtypeStruct((b, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k), jnp.int32),
    ]
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i, j: (i, 0)),       # q_emb
            pl.BlockSpec((block_m, 2), lambda i, j: (i, 0)),       # q_loc
            pl.BlockSpec((block_m, 2), lambda i, j: (i, 0)),       # w_st
            pl.BlockSpec((t,), lambda i, j: (0,)),                 # w_hat
            *emb_specs,                                # cand_emb [, scale]
            pl.BlockSpec((block_m, block_n, 2), lambda i, j: (i, j, 0)),
            pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),       # scores
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),       # idx
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(q_emb, q_loc, w_st, w_hat, *emb_args, cand_loc, cand_ids)


# ---------------------------------------------------------------------------
# Gather-free variant: scalar-prefetched routing into resident buffers
# ---------------------------------------------------------------------------


def _routed_body(q_ref, loc_ref, w_ref, wh_ref, ce, bl_ref, bi_ref,
                 os_ref, oi_ref, *, k: int, t: int, dist_max: float,
                 pred=None):
    """Score one routed (block_n, d) resident tile (``ce`` already f32,
    dequantized by the caller) against its query's running top-k.
    ``pred`` is the optional (1, block_n) filter mask evaluated by the
    filtered wrappers — failing rows take the padding semantics."""
    r = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when((r == 0) & (j == 0))
    def _init():
        os_ref[...] = jnp.full_like(os_ref, NEG_INF)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    q = q_ref[...].astype(jnp.float32)              # (1, d)
    trel = jax.lax.dot_general(
        q, ce, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (1, bn)

    dloc = loc_ref[...][:, None, :] - bl_ref[...]    # (1, bn, 2)
    dist = jnp.sqrt(jnp.sum(dloc * dloc, axis=-1))   # (1, bn)
    s_in = 1.0 - jnp.clip(dist / dist_max, 0.0, 1.0)
    idx = jnp.clip((s_in * t).astype(jnp.int32), 0, t - 1)
    srel = jnp.take(wh_ref[...], idx)                # (1, bn)

    w = w_ref[...].astype(jnp.float32)               # (1, 2)
    st = w[:, :1] * trel + w[:, 1:2] * srel
    ids = bi_ref[...]                                # (1, bn) object ids
    valid = ids >= 0                                 # mask buffer padding
    if pred is not None:
        valid = valid & pred                         # ...and filtered rows
        ids = jnp.where(valid, ids, -1)
    st = jnp.where(valid, st, NEG_INF)

    # merge with the running top-k held in the revisited output block;
    # carrying OBJECT ids (not positions) makes cr-merge order-free
    cat_s = jnp.concatenate([os_ref[...], st], axis=1)   # (1, k+bn)
    cat_i = jnp.concatenate([oi_ref[...], ids], axis=1)
    vals, pos = jax.lax.top_k(cat_s, k)
    os_ref[...] = vals
    oi_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)


def _routed_kernel(tc_ref, q_ref, loc_ref, w_ref, wh_ref,
                   be_ref, bl_ref, bi_ref, os_ref, oi_ref, **kw):
    _routed_body(q_ref, loc_ref, w_ref, wh_ref,
                 be_ref[...][0].astype(jnp.float32),
                 bl_ref, bi_ref, os_ref, oi_ref, **kw)


def _routed_kernel_dequant(tc_ref, q_ref, loc_ref, w_ref, wh_ref,
                           be_ref, bs_ref, bl_ref, bi_ref, os_ref, oi_ref,
                           **kw):
    # int8 resident tile → upcast + per-row scale in VMEM; only the
    # compressed bytes (plus a (block_n,) f32 scale strip) crossed HBM
    ce = be_ref[...][0].astype(jnp.float32) * bs_ref[...][0][:, None]
    _routed_body(q_ref, loc_ref, w_ref, wh_ref, ce,
                 bl_ref, bi_ref, os_ref, oi_ref, **kw)


def _routed_kernel_filtered(tc_ref, q_ref, loc_ref, w_ref, wh_ref,
                            be_ref, bl_ref, bi_ref, ba_ref, qf_ref,
                            os_ref, oi_ref, **kw):
    # predicate evaluated in VMEM right beside the upcast: the attribute
    # strip rode the same DMA wave as the tile it guards
    pred = _predicate_tile(ba_ref[...][0], qf_ref[...])
    _routed_body(q_ref, loc_ref, w_ref, wh_ref,
                 be_ref[...][0].astype(jnp.float32),
                 bl_ref, bi_ref, os_ref, oi_ref, pred=pred, **kw)


def _routed_kernel_dequant_filtered(tc_ref, q_ref, loc_ref, w_ref, wh_ref,
                                    be_ref, bs_ref, bl_ref, bi_ref, ba_ref,
                                    qf_ref, os_ref, oi_ref, **kw):
    pred = _predicate_tile(ba_ref[...][0], qf_ref[...])
    ce = be_ref[...][0].astype(jnp.float32) * bs_ref[...][0][:, None]
    _routed_body(q_ref, loc_ref, w_ref, wh_ref, ce,
                 bl_ref, bi_ref, os_ref, oi_ref, pred=pred, **kw)


def fused_topk_score_routed(q_emb, q_loc, w_st, top_c, buf_emb, buf_loc,
                            buf_ids, w_hat, *, k: int, dist_max: float,
                            block_n: int = 512, buf_scale=None,
                            buf_attrs=None, q_filt=None,
                            interpret: bool = True):
    """Gather-free fused score + top-k over routed cluster buffers.

    q_emb (B, d); q_loc (B, 2); w_st (B, 2); top_c (B, cr) int32 routed
    cluster ids (scalar-prefetched); buf_emb (c, cap, d) in f32, bf16,
    or int8; buf_loc (c, cap, 2); buf_ids (c, cap) int32 (-1 pad);
    w_hat (t,) f32; buf_scale (c, cap) f32 per-row dequant scales
    (required for int8 buffers, omitted otherwise — when given, each
    resident tile is dequantized in VMEM before scoring).

    Filtered search: pass BOTH ``buf_attrs (c, cap, 3)`` int32 object
    attributes and ``q_filt (B, 4)`` int32 compiled filter rows
    (core/filters.py) to mask failing candidates to the padding
    semantics (NEG_INF score, id -1) in VMEM. Omitting both streams zero
    extra bytes — the unfiltered plan is unchanged.

    Returns (scores (B, k) f32, ids (B, k) i32 **global object ids**,
    -1 where fewer than k valid candidates exist). The ``(B, cr·cap, d)``
    candidate copy of the gather path never materializes: grid step
    ``(b, r, j)`` streams tile ``j`` of resident cluster ``top_c[b, r]``
    and the cr routed lists fold into one running top-k in VMEM.
    """
    b, d = q_emb.shape
    c, cap, _ = buf_emb.shape
    cr = top_c.shape[1]
    t = w_hat.shape[0]
    # tile size must divide cap: take the largest divisor ≤ block_n (NOT
    # the gcd, which collapses to tiny tiles for e.g. cap=1000/block=512)
    requested = min(block_n, cap)
    block_n = _largest_divisor_tile(cap, requested)
    if block_n < max(1, requested // 4):
        import warnings
        warnings.warn(
            f"fused_topk_score_routed: capacity {cap} has no divisor near "
            f"the requested tile size ({requested}); tiles collapsed to "
            f"{block_n} — pathological grid. Prefer a capacity with a "
            f"large power-of-two factor (build_cluster_buffers rounds to "
            f"multiples of 128)", stacklevel=2)
    grid = (b, cr, cap // block_n)

    dequant = buf_scale is not None
    filtered = buf_attrs is not None
    if filtered != (q_filt is not None):
        raise ValueError("fused_topk_score_routed: pass buf_attrs and "
                         "q_filt together or not at all")
    emb_specs = [pl.BlockSpec((1, block_n, d),
                              lambda b_, r, j, tc: (tc[b_, r], j, 0))]
    emb_args = [buf_emb]
    if dequant:
        emb_specs.append(pl.BlockSpec((1, block_n),
                                      lambda b_, r, j, tc: (tc[b_, r], j)))
        emb_args.append(buf_scale)
    filt_specs, filt_args = [], []
    if filtered:
        filt_specs = [
            pl.BlockSpec((1, block_n, 3),
                         lambda b_, r, j, tc: (tc[b_, r], j, 0)),  # buf_attrs
            pl.BlockSpec((1, 4), lambda b_, r, j, tc: (b_, 0)),    # q_filt
        ]
        filt_args = [buf_attrs.astype(jnp.int32), q_filt.astype(jnp.int32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda b_, r, j, tc: (b_, 0)),     # q_emb
            pl.BlockSpec((1, 2), lambda b_, r, j, tc: (b_, 0)),     # q_loc
            pl.BlockSpec((1, 2), lambda b_, r, j, tc: (b_, 0)),     # w_st
            pl.BlockSpec((t,), lambda b_, r, j, tc: (0,)),          # w_hat
            *emb_specs,                                 # buf_emb [, scale]
            pl.BlockSpec((1, block_n, 2),
                         lambda b_, r, j, tc: (tc[b_, r], j, 0)),   # buf_loc
            pl.BlockSpec((1, block_n),
                         lambda b_, r, j, tc: (tc[b_, r], j)),      # buf_ids
            *filt_specs,                            # [buf_attrs, q_filt]
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda b_, r, j, tc: (b_, 0)),     # scores
            pl.BlockSpec((1, k), lambda b_, r, j, tc: (b_, 0)),     # ids
        ],
    )
    kerns = {(False, False): _routed_kernel,
             (True, False): _routed_kernel_dequant,
             (False, True): _routed_kernel_filtered,
             (True, True): _routed_kernel_dequant_filtered}
    kern = functools.partial(kerns[(dequant, filtered)],
                             k=k, t=t, dist_max=float(dist_max))
    out_shape = [
        jax.ShapeDtypeStruct((b, k), jnp.float32),
        jax.ShapeDtypeStruct((b, k), jnp.int32),
    ]
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(top_c.astype(jnp.int32), q_emb, q_loc, w_st, w_hat,
      *emb_args, buf_loc, buf_ids, *filt_args)


# ---------------------------------------------------------------------------
# Cluster-major variant: stream each distinct routed cluster once per batch
# ---------------------------------------------------------------------------


def _cluster_major_body(roster_ref, qe_ref, ql_ref, qw_ref, wh_ref, ce,
                        bl_ref, bi_ref, os_ref, oi_ref, *, k: int, t: int,
                        dist_max: float, n_total: int, pred=None):
    """Score one (block_n, d) resident tile (``ce`` already f32,
    dequantized by the caller) against the WHOLE query roster of the
    distinct cluster owning it, and fold into each roster slot's
    running top-k."""
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, NEG_INF)
        oi_ref[...] = jnp.full_like(oi_ref, -1)

    q = qe_ref[...][0].astype(jnp.float32)           # (Qcap, d)
    trel = jax.lax.dot_general(
        q, ce, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # (Qcap, bn) one matmul

    dloc = ql_ref[...][0][:, None, :] - bl_ref[...][0][None]  # (Qcap, bn, 2)
    dist = jnp.sqrt(jnp.sum(dloc * dloc, axis=-1))    # (Qcap, bn)
    s_in = 1.0 - jnp.clip(dist / dist_max, 0.0, 1.0)
    idx = jnp.clip((s_in * t).astype(jnp.int32), 0, t - 1)
    srel = jnp.take(wh_ref[...], idx)                 # (Qcap, bn)

    w = qw_ref[...][0].astype(jnp.float32)            # (Qcap, 2)
    st = w[:, :1] * trel + w[:, 1:2] * srel
    ids = bi_ref[...][0]                              # (bn,) object ids
    # mask buffer padding AND empty roster slots (roster pad = n_total):
    # a pad slot's partials stay (-1, NEG_INF) so the caller's merge can
    # scatter them anywhere harmlessly
    live = roster_ref[i, :] < n_total                 # (Qcap,)
    valid = live[:, None] & (ids[None, :] >= 0)       # (Qcap, bn)
    if pred is not None:
        valid = valid & pred                          # filtered rows too
    st = jnp.where(valid, st, NEG_INF)
    ids2 = jnp.where(valid, jnp.broadcast_to(ids[None, :], st.shape), -1)

    # per-roster-slot running top-k in the revisited output block;
    # carrying OBJECT ids keeps the final per-query merge order-free
    cat_s = jnp.concatenate([os_ref[...][0], st], axis=1)   # (Qcap, k+bn)
    cat_i = jnp.concatenate([oi_ref[...][0], ids2], axis=1)
    vals, pos = jax.lax.top_k(cat_s, k)
    os_ref[...] = vals[None]
    oi_ref[...] = jnp.take_along_axis(cat_i, pos, axis=1)[None]


def _cluster_major_kernel(u_ref, roster_ref, qe_ref, ql_ref, qw_ref, wh_ref,
                          be_ref, bl_ref, bi_ref, os_ref, oi_ref, **kw):
    _cluster_major_body(roster_ref, qe_ref, ql_ref, qw_ref, wh_ref,
                        be_ref[...][0].astype(jnp.float32),
                        bl_ref, bi_ref, os_ref, oi_ref, **kw)


def _cluster_major_kernel_dequant(u_ref, roster_ref, qe_ref, ql_ref, qw_ref,
                                  wh_ref, be_ref, bs_ref, bl_ref, bi_ref,
                                  os_ref, oi_ref, **kw):
    # int8 tile → upcast + per-row scale in VMEM ONCE per distinct
    # cluster per batch (the query-major kernel re-dequantizes per route)
    ce = be_ref[...][0].astype(jnp.float32) * bs_ref[...][0][:, None]
    _cluster_major_body(roster_ref, qe_ref, ql_ref, qw_ref, wh_ref, ce,
                        bl_ref, bi_ref, os_ref, oi_ref, **kw)


def _cluster_major_kernel_filtered(u_ref, roster_ref, qe_ref, ql_ref, qw_ref,
                                   wh_ref, be_ref, bl_ref, bi_ref, ba_ref,
                                   qf_ref, os_ref, oi_ref, **kw):
    # (Qcap, bn) predicate: the tile's attribute strip against the whole
    # roster's compiled filters — evaluated once per distinct cluster
    # per batch, right beside the (single) upcast
    pred = _predicate_tile(ba_ref[...][0], qf_ref[...][0])
    _cluster_major_body(roster_ref, qe_ref, ql_ref, qw_ref, wh_ref,
                        be_ref[...][0].astype(jnp.float32),
                        bl_ref, bi_ref, os_ref, oi_ref, pred=pred, **kw)


def _cluster_major_kernel_dequant_filtered(u_ref, roster_ref, qe_ref, ql_ref,
                                           qw_ref, wh_ref, be_ref, bs_ref,
                                           bl_ref, bi_ref, ba_ref, qf_ref,
                                           os_ref, oi_ref, **kw):
    pred = _predicate_tile(ba_ref[...][0], qf_ref[...][0])
    ce = be_ref[...][0].astype(jnp.float32) * bs_ref[...][0][:, None]
    _cluster_major_body(roster_ref, qe_ref, ql_ref, qw_ref, wh_ref, ce,
                        bl_ref, bi_ref, os_ref, oi_ref, pred=pred, **kw)


def fused_topk_score_cluster_major(q_emb_r, q_loc_r, w_st_r, u, roster,
                                   buf_emb, buf_loc, buf_ids, w_hat, *,
                                   k: int, dist_max: float, n_total: int,
                                   block_n: int = 512, buf_scale=None,
                                   buf_attrs=None, q_filt_r=None,
                                   interpret: bool = True):
    """Cluster-major fused score + top-k over the deduped batch plan.

    Inputs are the plan of ``serving.cluster_major_plan`` plus the
    roster-gathered query payloads: q_emb_r (u_max, Qcap, d) /
    q_loc_r (u_max, Qcap, 2) / w_st_r (u_max, Qcap, 2) the queries of
    each distinct cluster's roster; u (u_max,) int32 distinct routed
    cluster ids; roster (u_max, Qcap) int32 flattened (query, route)
    indices with ``n_total = B·cr`` marking empty slots (both ``u`` and
    ``roster`` are scalar-prefetched); buf_emb (c, cap, d) in f32, bf16,
    or int8; buf_loc (c, cap, 2); buf_ids (c, cap) int32 (-1 pad);
    w_hat (t,) f32; buf_scale (c, cap) f32 per-row dequant scales
    (required for int8 buffers, omitted otherwise).

    Filtered search: pass BOTH ``buf_attrs (c, cap, 3)`` int32 object
    attributes and ``q_filt_r (u_max, Qcap, 4)`` int32 roster-gathered
    compiled filter rows (blocked like the query payloads) to mask
    failing candidates to the padding semantics in VMEM.

    Returns partial per-roster-slot top-k lists
    (scores (u_max, Qcap, k) f32, ids (u_max, Qcap, k) i32 global object
    ids, (-1, NEG_INF) on empty roster slots and past-the-end). Fold
    them per query with ``engine.merge_cluster_major(roster)`` — the
    partial lists of a query's ``cr`` routes live at its roster slots.

    Grid ``(u_max, cap/block_n)``: step ``(i, j)`` DMAs tile ``j`` of
    distinct cluster ``u[i]`` — each distinct cluster's resident bytes
    cross HBM ONCE per batch instead of once per routed query, so the
    stream shrinks by the batch dedup factor ``B·cr/U`` (structurally
    bounded by ``B·cr / min(B·cr, c)``). The whole roster is scored
    against the tile in one ``(Qcap, d) × (d, block_n)`` MXU matmul; on
    a real TPU prefer ``Qcap`` a multiple of 8 (it is the matmul's
    sublane dim) — the default ``Qcap = B·cr`` of the engine's plans
    satisfies this for any batch that is itself a multiple of 8.
    """
    u_max, qcap, d = q_emb_r.shape
    c, cap, _ = buf_emb.shape
    t = w_hat.shape[0]
    requested = min(block_n, cap)
    block_n = _largest_divisor_tile(cap, requested)
    if block_n < max(1, requested // 4):
        import warnings
        warnings.warn(
            f"fused_topk_score_cluster_major: capacity {cap} has no "
            f"divisor near the requested tile size ({requested}); tiles "
            f"collapsed to {block_n} — pathological grid. Prefer a "
            f"capacity with a large power-of-two factor "
            f"(build_cluster_buffers rounds to multiples of 128)",
            stacklevel=2)
    grid = (u_max, cap // block_n)

    dequant = buf_scale is not None
    filtered = buf_attrs is not None
    if filtered != (q_filt_r is not None):
        raise ValueError("fused_topk_score_cluster_major: pass buf_attrs "
                         "and q_filt_r together or not at all")
    emb_specs = [pl.BlockSpec((1, block_n, d),
                              lambda i, j, u_, ro: (u_[i], j, 0))]
    emb_args = [buf_emb]
    if dequant:
        emb_specs.append(pl.BlockSpec((1, block_n),
                                      lambda i, j, u_, ro: (u_[i], j)))
        emb_args.append(buf_scale)
    filt_specs, filt_args = [], []
    if filtered:
        filt_specs = [
            pl.BlockSpec((1, block_n, 3),
                         lambda i, j, u_, ro: (u_[i], j, 0)),      # buf_attrs
            pl.BlockSpec((1, qcap, 4),
                         lambda i, j, u_, ro: (i, 0, 0)),          # q_filt_r
        ]
        filt_args = [buf_attrs.astype(jnp.int32),
                     q_filt_r.astype(jnp.int32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qcap, d), lambda i, j, u_, ro: (i, 0, 0)),
            pl.BlockSpec((1, qcap, 2), lambda i, j, u_, ro: (i, 0, 0)),
            pl.BlockSpec((1, qcap, 2), lambda i, j, u_, ro: (i, 0, 0)),
            pl.BlockSpec((t,), lambda i, j, u_, ro: (0,)),          # w_hat
            *emb_specs,                                 # buf_emb [, scale]
            pl.BlockSpec((1, block_n, 2),
                         lambda i, j, u_, ro: (u_[i], j, 0)),       # buf_loc
            pl.BlockSpec((1, block_n),
                         lambda i, j, u_, ro: (u_[i], j)),          # buf_ids
            *filt_specs,                          # [buf_attrs, q_filt_r]
        ],
        out_specs=[
            pl.BlockSpec((1, qcap, k), lambda i, j, u_, ro: (i, 0, 0)),
            pl.BlockSpec((1, qcap, k), lambda i, j, u_, ro: (i, 0, 0)),
        ],
    )
    kerns = {(False, False): _cluster_major_kernel,
             (True, False): _cluster_major_kernel_dequant,
             (False, True): _cluster_major_kernel_filtered,
             (True, True): _cluster_major_kernel_dequant_filtered}
    kern = functools.partial(kerns[(dequant, filtered)],
                             k=k, t=t, dist_max=float(dist_max),
                             n_total=int(n_total))
    out_shape = [
        jax.ShapeDtypeStruct((u_max, qcap, k), jnp.float32),
        jax.ShapeDtypeStruct((u_max, qcap, k), jnp.int32),
    ]
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(u.astype(jnp.int32), roster.astype(jnp.int32),
      q_emb_r, q_loc_r, w_st_r, w_hat, *emb_args, buf_loc, buf_ids,
      *filt_args)
