"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def fused_topk_score_ref(q_emb, q_loc, w_st, cand_emb, cand_loc, cand_ids,
                         w_hat, *, k: int, dist_max: float):
    """Reference for kernels/fused_topk_score (== core/relevance scoring)."""
    t = w_hat.shape[0]
    trel = jnp.einsum("bd,bnd->bn", q_emb.astype(jnp.float32),
                      cand_emb.astype(jnp.float32))
    d = jnp.linalg.norm(q_loc[:, None].astype(jnp.float32)
                        - cand_loc.astype(jnp.float32), axis=-1)
    s_in = 1.0 - jnp.clip(d / dist_max, 0.0, 1.0)
    idx = jnp.clip((s_in * t).astype(jnp.int32), 0, t - 1)
    srel = jnp.take(w_hat, idx)
    st = w_st[:, :1] * trel + w_st[:, 1:2] * srel
    st = jnp.where(cand_ids >= 0, st, -1e30)
    return jax.lax.top_k(st, k)


# NOTE: the routed (gather-free) kernel's dense oracle is
# core/engine.dense_routed_topk — ONE definition, built on the engine's
# score_candidates primitive, so the kernel tests and the engine parity
# tests certify the same contract.


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """Dense softmax attention with GQA, causal/window masks. fp32 math."""
    b, sq, h, d = q.shape
    _, sk, n_kv, _ = k.shape
    g = h // n_kv
    qg = q.reshape(b, sq, n_kv, g, d).astype(jnp.float32) / math.sqrt(d)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k.astype(jnp.float32))
    pos_q = jnp.arange(sq)[:, None]
    pos_k = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= pos_q >= pos_k
    if window > 0:
        mask &= (pos_q - pos_k) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(b, sq, h, d).astype(q.dtype)


def dot_interaction_ref(feats):
    b, f, d = feats.shape
    g = jnp.einsum("bfd,bgd->bfg", feats.astype(jnp.float32),
                   feats.astype(jnp.float32))
    iu, ju = jnp.triu_indices(f, k=1)
    return g[:, iu, ju].astype(feats.dtype)


def embedding_bag_ref(table, idx):
    """idx: (B, P) int32, -1 pad → (B, d) pooled sums."""
    safe = jnp.maximum(idx, 0)
    rows = jnp.take(table, safe, axis=0)                   # (B, P, d)
    rows = jnp.where((idx >= 0)[..., None], rows, 0.0)
    return rows.sum(axis=1).astype(jnp.float32)
