"""repro.api — the stable top-level surface of the LIST reproduction.

Everything a user of the system (driver, example, benchmark, notebook)
needs is four names; the artifact in the middle is the immutable,
versioned :class:`~repro.core.snapshot.IndexSnapshot` (DESIGN.md §8):

    from repro import api

    snap = api.build(cfg, corpus, rel_steps=300, idx_steps=600)   # train
    api.save(snap, "artifacts/index")          # durable, atomic commit
    snap = api.load("artifacts/index")         # any process, any host

    searcher = api.Searcher(snap)              # stateless query engine
    ids, scores = searcher.query(tokens, mask, loc, k=10)

    server = searcher.serve(ServerConfig(batch_size=64))   # long-lived
    ids, scores = await server.submit(tok_row, msk_row, loc_row)

The guarantee the whole stack rests on: ``save(dir)`` → ``load(dir)`` →
``Searcher.query`` is **bit-identical** to querying the in-memory
snapshot, on every backend (tests/test_snapshot.py), and a snapshot
published to a live server swaps atomically — zero torn or failed
requests (core/server.py).

Writes go through the server's LSM-style delta path (DESIGN.md §11):
``server.insert_objects`` / ``delete_objects`` are O(batch) — rows
append to the snapshot's delta segment, deletes tombstone, queries
merge both with the base, and background compaction folds the delta
into the cluster buffers past a threshold. A snapshot with pending
mutations round-trips through save/load like any other (schema v3).

``python -m repro.api`` runs the save→load→query round-trip self-test
on a small random index (``make snapshot-roundtrip``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as engine_lib
from repro.core import pipeline as pipeline_lib
from repro.core import server as server_lib
from repro.core import snapshot as snapshot_lib
from repro.core.snapshot import IndexSnapshot

# the operational exception surface: callers handle these without
# importing core.server / checkpoint.ckpt / distributed.resilience
# internals — Overloaded + DeadlineExceeded are the shedding responses
# (DESIGN.md §14), SnapshotCorrupt is recovery's checksum verdict, and
# ShardUnavailable is total shard loss on the mesh path (DESIGN.md §15;
# a SINGLE lost shard degrades coverage instead of raising)
from repro.checkpoint.ckpt import SnapshotCorrupt
from repro.core.server import DeadlineExceeded, Overloaded
from repro.distributed.resilience import ShardUnavailable

__all__ = ["build", "save", "load", "recover", "Searcher", "brute_force",
           "IndexSnapshot", "Overloaded", "DeadlineExceeded",
           "SnapshotCorrupt", "ShardUnavailable"]


# ---------------------------------------------------------------------------
# build / save / load
# ---------------------------------------------------------------------------


def build(cfg, corpus, *, rel_steps: int = 200, idx_steps: int = 400,
          batch: int = 64, rel_lr: float = 1.5e-3, idx_lr: float = 3e-3,
          capacity: Optional[int] = None, spill: int = 3,
          spatial_mode: str = "step", weight_mode: str = "mlp",
          precision: str = "f32", mesh=None, attrs=None, seed: int = 0,
          verbose: bool = False, log_every: Optional[int] = None,
          return_retriever: bool = False):
    """Train LIST end-to-end and return the built :class:`IndexSnapshot`.

    Runs the paper's three phases — relevance training (Eq. 8), index
    training (Eq. 13 pseudo-labels + Eq. 14 MCL), buffer packing — via
    :class:`~repro.core.pipeline.ListRetriever` and freezes the result.

    ``precision`` picks the resident buffers' storage tier
    (``"f32" | "bf16" | "int8"``, DESIGN.md §9): int8 cuts the query
    phase's dominant HBM stream ~4× via symmetric per-row scalar
    quantization, dequantized in-kernel; locations, ids, and the padding
    mask stay exact. An existing f32 snapshot can be requantized later
    with ``snap.with_precision("int8")`` without retraining.

    ``mesh`` (an int shard count or a ``jax.sharding.Mesh`` over the
    logical ``"cluster"`` axis) partitions the resident cluster buffers
    across devices along the cluster axis, router and relevance params
    replicated (DESIGN.md §12). Query results keep bit-identical top-k
    ids vs the single-device build at any shard count.

    ``attrs (n_objects, 3)`` attaches per-object filter attributes
    (tenant, category bitmask, timestamp — core/filters.py, DESIGN.md
    §13) so the built index serves filtered queries; None → all-zero.

    ``return_retriever=True`` additionally returns the retriever, for
    callers that need training-time state the artifact deliberately
    omits (training histories, object↦cluster assignments for cluster-
    quality metrics). The snapshot alone is sufficient to serve.
    """
    log = log_every if log_every is not None else max(rel_steps, 1)
    r = pipeline_lib.ListRetriever(cfg, corpus, spatial_mode=spatial_mode,
                                   weight_mode=weight_mode)
    r.train_relevance(steps=rel_steps, batch=batch, lr=rel_lr, seed=seed,
                      verbose=verbose, log_every=log)
    r.train_index(steps=idx_steps, batch=batch, lr=idx_lr, seed=seed,
                  verbose=verbose, log_every=log)
    r.build(capacity=capacity, spill=spill, precision=precision, attrs=attrs)
    snap = r.snapshot()
    if mesh is not None:
        snap = snap.with_mesh(mesh)
    return (snap, r) if return_retriever else snap


def save(snapshot: IndexSnapshot, directory: str, *, keep: int = 3) -> str:
    """Persist ``snapshot`` under ``directory`` (atomic commit; one ckpt
    step per snapshot version). Returns the committed path."""
    return snapshot.save(directory, keep=keep)


def load(directory: str, *, step: Optional[int] = None,
         mesh=None) -> IndexSnapshot:
    """Load the latest (or a specific ``step``/version) committed
    snapshot. Raises a clear error on schema-version mismatch.

    Arrays are persisted global (gathered on save), so a snapshot can be
    re-sharded elastically at load time: ``mesh`` (int shard count or a
    ``jax.sharding.Mesh``) re-partitions the cluster buffers for this
    process's device topology, independent of how the saving process was
    sharded (DESIGN.md §12)."""
    snap = IndexSnapshot.load(directory, step=step)
    if mesh is not None:
        snap = snap.with_mesh(mesh)
    return snap


def recover(snapshot_dir: str, wal_dir: Optional[str] = None, *,
            config: Optional["server_lib.ServerConfig"] = None,
            backend: str = "auto"):
    """Crash recovery in one call (DESIGN.md §14): rebuild a serving
    stack whose index is bit-identical to one that never crashed.

        server = api.recover("artifacts/index", "artifacts/wal")

    Walks ``snapshot_dir`` for the newest snapshot that actually
    restores (corrupted steps — truncated manifest, checksum-failed
    leaf — are skipped, not fatal), builds a :class:`Searcher` +
    streaming server over it, and replays the write-ahead log's intact
    records (torn tail dropped by checksum): every record whose version
    the loaded snapshot predates re-runs through the normal write path,
    so acknowledged inserts/deletes that only lived in the delta
    segment at crash time are restored, and compaction re-triggers
    deterministically.

    ``config`` must carry the same write-path knobs
    (``delta_threshold``, ``spill``) the crashed server ran with for
    bit-identical replay; its ``wal_dir`` defaults to ``wal_dir``.
    Returns the :class:`~repro.core.server.StreamingServer` (its
    ``stats.recovered_writes`` says how many records were applied;
    ``server.checkpoint(snapshot_dir)`` re-durabilizes and empties the
    log)."""
    import dataclasses as _dc

    snap = snapshot_lib.load_latest_good(snapshot_dir)
    cfg = config or server_lib.ServerConfig()
    if wal_dir is not None and cfg.wal_dir != wal_dir:
        cfg = _dc.replace(cfg, wal_dir=wal_dir)
    server = Searcher(snap, backend=backend).serve(cfg)
    server.replay_wal()
    return server


# ---------------------------------------------------------------------------
# Searcher
# ---------------------------------------------------------------------------


class Searcher:
    """A stateless query façade over one :class:`IndexSnapshot`.

    Thin sugar over :class:`~repro.core.engine.QueryEngine`: binds the
    snapshot once, answers batched queries, and spawns the streaming
    server for live traffic. Swapping to a successor snapshot
    (:meth:`publish`) is atomic and keeps every traced plan.
    """

    def __init__(self, snapshot: IndexSnapshot, *, backend: str = "auto",
                 interpret: Optional[bool] = None):
        self.engine = engine_lib.QueryEngine.from_snapshot(
            snapshot, backend=backend, interpret=interpret)

    @property
    def snapshot(self) -> IndexSnapshot:
        return self.engine.snapshot

    @property
    def last_coverage(self) -> float:
        """Coverage fraction (routed clusters scanned / routed) of the
        most recent :meth:`query` — 1.0 unless a mesh shard was DOWN
        and the answer merged the surviving partials (DESIGN.md §15)."""
        return self.engine.last_coverage

    def publish(self, snapshot: IndexSnapshot) -> IndexSnapshot:
        """Atomically swap the served snapshot (cfg-digest checked).
        Long-lived servers publish through StreamingServer.publish
        instead, which also drops their result caches."""
        self.engine.publish(snapshot)
        return snapshot

    def query(self, tokens, mask, loc, *, k: int = 10, cr: int = 1,
              batch: int = 256, backend: Optional[str] = None,
              filters=None):
        """Batched spatial-keyword query → (ids (n, k), scores (n, k)).

        tokens (n, L) int32 / mask (n, L) bool / loc (n, 2) float32 per
        the engine contract; ids are global object ids, -1 past-the-end.
        ``backend`` overrides the searcher's own for this call (any of
        ``engine.BACKENDS`` — ``"pallas-cm"``/``"dense-cm"`` force
        cluster-major batched execution, DESIGN.md §10; an auto searcher
        picks query- vs cluster-major per batch from the measured route
        dedup factor). ``filters`` — None, one
        :class:`~repro.core.filters.FilterSpec` for the whole call, or
        one per row — restricts results to objects passing the predicate
        (DESIGN.md §13).
        """
        return self.engine.query(tokens, mask, loc, k=k, cr=cr, batch=batch,
                                 backend=backend, filters=filters)

    def query_corpus(self, corpus, query_ids, *, k: int = 10, cr: int = 1,
                     batch: int = 256, backend: Optional[str] = None):
        """Convenience: answer a corpus's queries by id."""
        tokens, mask = corpus.query_tokens(query_ids)
        loc = corpus.q_loc[query_ids].astype(np.float32)
        return self.query(tokens, mask, loc, k=k, cr=cr, batch=batch,
                          backend=backend)

    def serve(self, config: Optional["server_lib.ServerConfig"] = None
              ) -> "server_lib.StreamingServer":
        """A streaming server (micro-batcher + caches, DESIGN.md §7)
        over this searcher's engine."""
        return server_lib.StreamingServer(self.engine, config)


# ---------------------------------------------------------------------------
# Offline oracle
# ---------------------------------------------------------------------------


def brute_force(snapshot: IndexSnapshot, corpus, query_ids, *, k: int = 20,
                batch: int = 256):
    """Exhaustive LIST-R scoring over the whole corpus — the recall
    oracle for a snapshot (re-embeds objects from the snapshot's own
    relevance params, so it describes exactly what the artifact would
    serve at cr = c)."""
    from repro.core import relevance

    cfg, meta = snapshot.cfg, snapshot.meta
    obj_emb = pipeline_lib.embed_objects(snapshot.rel_params, corpus, cfg,
                                         batch=batch)
    obj_loc = corpus.obj_loc.astype(np.float32)
    q_emb = pipeline_lib.embed_queries(snapshot.rel_params, corpus, cfg,
                                       query_ids, batch=batch)
    q_loc = corpus.q_loc[query_ids].astype(np.float32)

    @jax.jit
    def score_top(qe, ql):
        st = relevance.score_corpus(
            snapshot.rel_params, qe, ql, jnp.asarray(obj_emb),
            jnp.asarray(obj_loc), cfg, dist_max=meta.dist_max,
            spatial_mode=meta.spatial_mode, weight_mode=meta.weight_mode,
            train=False)
        sc, ids = jax.lax.top_k(st, k)
        return ids, sc

    return engine_lib.run_batched(score_top, [q_emb, q_loc], batch=batch)


# ---------------------------------------------------------------------------
# Round-trip self-test (make snapshot-roundtrip)
# ---------------------------------------------------------------------------


def _roundtrip_selftest(directory: Optional[str] = None) -> int:
    """build(random params) → save → load → query on every backend
    (dense | pallas | their cluster-major twins) AND every precision
    tier (f32 | bf16 | int8), asserting bit-identity per tier. Small and
    training-free: finishes in seconds, which is what a CI gate wants."""
    import dataclasses
    import os
    import tempfile

    from repro.configs import get_config
    from repro.core import index as index_lib
    from repro.core import relevance

    cfg = dataclasses.replace(
        get_config("list-dual-encoder"),
        n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab_size=512,
        max_len=8, spatial_t=50, n_clusters=4, index_mlp_hidden=(16,))
    rng = np.random.default_rng(0)
    rel = relevance.relevance_init(jax.random.PRNGKey(0), cfg)
    n, c = 64, cfg.n_clusters
    obj_emb = rng.normal(size=(n, cfg.d_model)).astype(np.float32)
    obj_loc = rng.uniform(size=(n, 2)).astype(np.float32)
    norm = index_lib.loc_normalizer(jnp.asarray(obj_loc))
    iparams = index_lib.index_init(jax.random.PRNGKey(1), cfg.d_model, c,
                                   hidden=(16,))
    feats = index_lib.build_features(jnp.asarray(obj_emb),
                                     jnp.asarray(obj_loc), norm)
    top = np.asarray(index_lib.assign_clusters(iparams, feats, top=2))
    from repro.core import filters as filters_lib
    attrs = filters_lib.make_attrs(np.arange(n) % 3,
                                   1 << (np.arange(n) % 4),
                                   np.arange(n))
    buf = index_lib.build_cluster_buffers(top, obj_emb, obj_loc,
                                          n_clusters=c, capacity=32,
                                          attrs=attrs)
    snap = IndexSnapshot.from_parts(cfg, rel, iparams, norm, buf,
                                    dist_max=1.4142)
    fspec = filters_lib.FilterSpec(tenant=1)

    tok = rng.integers(2, cfg.vocab_size, (12, cfg.max_len)).astype(np.int32)
    tok[:, 0] = 1
    msk = np.ones_like(tok, bool)
    loc = rng.uniform(size=(12, 2)).astype(np.float32)

    root = tempfile.mkdtemp() if directory is None else directory
    failures = 0
    for precision in index_lib.PRECISIONS:
        snap_p = snap.with_precision(precision)
        tmp = os.path.join(root, precision)
        path = save(snap_p, tmp)
        loaded = load(tmp)
        assert loaded.meta == snap_p.meta, (loaded.meta, snap_p.meta)
        assert loaded.cfg == snap_p.cfg
        for backend in ("dense", "pallas", "dense-cm", "pallas-cm"):
            a = Searcher(snap_p, backend=backend).query(tok, msk, loc, k=5,
                                                        cr=2, batch=4)
            b = Searcher(loaded, backend=backend).query(tok, msk, loc, k=5,
                                                        cr=2, batch=4)
            ok = (np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1]))
            print(f"snapshot-roundtrip [{backend:9s}|{precision:4s}] "
                  f"{'bit-identical' if ok else 'MISMATCH'}  ({path})")
            failures += 0 if ok else 1
            # filtered leg (schema v5, DESIGN.md §13): the attrs buffer
            # must survive the trip, and filtered results must stay
            # inside the tenant before and after it
            fa = Searcher(snap_p, backend=backend).query(
                tok, msk, loc, k=5, cr=2, batch=4, filters=fspec)
            fb = Searcher(loaded, backend=backend).query(
                tok, msk, loc, k=5, cr=2, batch=4, filters=fspec)
            live = fa[0][fa[0] >= 0]
            ok = (np.array_equal(fa[0], fb[0])
                  and np.array_equal(fa[1], fb[1])
                  and bool(np.all(attrs[live, 0] == 1)))
            print(f"snapshot-roundtrip [filt {backend:4s}|{precision:4s}] "
                  f"{'bit-identical' if ok else 'MISMATCH'}")
            failures += 0 if ok else 1
        # delta leg (schema v3): a snapshot with pending mutations must
        # round-trip and serve identically before and after the trip
        from repro.core import delta as delta_lib
        seg = delta_lib.DeltaSegment.empty(cfg.d_model, precision)
        seg = seg.insert(rng.normal(size=(3, cfg.d_model)).astype(np.float32),
                         rng.uniform(size=(3, 2)).astype(np.float32),
                         np.arange(9000, 9003))
        seg = seg.delete([0, 1])
        snap_d = snap_p.with_delta(seg)
        tmp_d = os.path.join(root, precision + "-delta")
        save(snap_d, tmp_d)
        loaded_d = load(tmp_d)
        assert loaded_d.meta == snap_d.meta, (loaded_d.meta, snap_d.meta)
        a = Searcher(snap_d, backend="dense").query(tok, msk, loc, k=5,
                                                    cr=2, batch=4)
        b = Searcher(loaded_d, backend="dense").query(tok, msk, loc, k=5,
                                                      cr=2, batch=4)
        ok = (np.array_equal(a[0], b[0]) and np.array_equal(a[1], b[1]))
        print(f"snapshot-roundtrip [delta    |{precision:4s}] "
              f"{'bit-identical' if ok else 'MISMATCH'}")
        failures += 0 if ok else 1
        # mesh leg (schema v4): a mesh-sharded snapshot must keep
        # bit-identical top-k ids vs the single-device engine, and its
        # save/load round trip (gather-on-save) must serve identically.
        # Adaptive: under plain CPU there is 1 device, under the mesh CI
        # job XLA_FLAGS forces 8 — shard as wide as the host allows.
        n_shards = min(2, jax.device_count())
        snap_m = snap_p.with_mesh(n_shards)
        a = Searcher(snap_p, backend="dense").query(tok, msk, loc, k=5,
                                                    cr=2, batch=4)
        b = Searcher(snap_m, backend="dense").query(tok, msk, loc, k=5,
                                                    cr=2, batch=4)
        tmp_m = os.path.join(root, precision + "-mesh")
        save(snap_m, tmp_m)
        c_ids, _ = Searcher(load(tmp_m, mesh=n_shards),
                            backend="dense").query(tok, msk, loc, k=5,
                                                   cr=2, batch=4)
        ok = (np.array_equal(a[0], b[0]) and np.array_equal(b[0], c_ids)
              and np.allclose(a[1], b[1], rtol=2e-5, atol=1e-6))
        print(f"snapshot-roundtrip [mesh S={n_shards} |{precision:4s}] "
              f"{'ids bit-identical' if ok else 'MISMATCH'}")
        failures += 0 if ok else 1
    return failures


if __name__ == "__main__":
    raise SystemExit(_roundtrip_selftest())
