"""Dry-run profiler: attribute HLO bytes/collectives to ops.

    PYTHONPATH=src python -m repro.analysis.hlo_top --arch kimi-k2-1t-a32b \
        --shape train_4k [--multi-pod] [--top 20]

Prints (a) every collective with wire bytes and metadata op_name, (b) the
top-N largest tensors written (fusion outputs), grouped by source op_name —
this is the "profile" the perf loop iterates against (no wall clock on CPU).
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import collections
import re

import jax

from repro.analysis.roofline import _INSTR_RE, _GROUPS_RE, _GROUPS_V2_RE, _shape_bytes
from repro.distributed import sharding as sh
from repro.launch import mesh as mesh_lib
from repro.launch import steps

_RESULT_RE = re.compile(r"^\s*(?:ROOT )?%?[\w.\-]+ = ((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+(\S+)\(")
_METADATA_RE = re.compile(r'op_name="([^"]+)"')


def analyze(arch, shape, multi_pod=False, top=20):
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    plan = steps.plan_cell(arch, shape, mesh)
    with mesh, sh.axis_rules(sh.rules_for_mesh(mesh)):
        jfn = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                      out_shardings=plan.out_shardings)
        compiled = jfn.lower(*plan.args).compile()
    text = compiled.as_text()

    coll = []
    writes = collections.Counter()
    for line in text.splitlines():
        m = _INSTR_RE.search(line)
        meta = _METADATA_RE.search(line)
        op_name = meta.group(1) if meta else "?"
        if m is not None:
            nbytes = _shape_bytes(m.group(1))
            g = _GROUPS_RE.search(line)
            n = (len(g.group(1).split(",")) if g else None)
            if n is None:
                g2 = _GROUPS_V2_RE.search(line)
                n = int(g2.group(2)) if g2 else 2
            coll.append((nbytes, m.group(2), n, op_name))
            continue
        r = _RESULT_RE.match(line)
        if r and r.group(2) in ("fusion", "custom-call", "dot", "convolution",
                                "scatter", "gather", "while", "copy",
                                "all-gather-done"):
            key = (r.group(2), _short(op_name))
            writes[key] += _shape_bytes(r.group(1))

    print(f"=== {arch} × {shape} [{'2x16x16' if multi_pod else '16x16'}] ===")
    cost = compiled.cost_analysis() or {}
    print(f"flops/chip={cost.get('flops', 0):.3e}  "
          f"bytes/chip={cost.get('bytes accessed', 0):.3e}")
    print(f"\n-- collectives ({len(coll)}) --")
    agg = collections.Counter()
    for nbytes, kind, n, op_name in coll:
        agg[(kind, _short(op_name), n)] += nbytes
    for (kind, op_name, n), nbytes in agg.most_common(top):
        print(f"  {nbytes/1e9:9.3f} GB  {kind:20s} n={n:<4d} {op_name}")
    print(f"\n-- top write targets --")
    for (kind, op_name), nbytes in writes.most_common(top):
        print(f"  {nbytes/1e9:9.3f} GB  {kind:12s} {op_name}")


def _short(op_name: str) -> str:
    # keep the tail of the jax op_name path, drop uniquifying digits
    tail = "/".join(op_name.split("/")[-3:])
    return re.sub(r"\d+", "", tail)[:80]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=20)
    a = ap.parse_args()
    analyze(a.arch, a.shape, multi_pod=a.multi_pod, top=a.top)
