"""Three-term roofline from the compiled dry-run artifact.

  compute    = HLO_FLOPs / peak_FLOPs          (per chip)
  memory     = HLO_bytes / HBM_bw              (per chip)
  collective = wire_bytes / link_bw            (per chip)

FLOPs / bytes come from ``compiled.cost_analysis()`` (post-SPMD, i.e.
per-device). Collective bytes are NOT in cost_analysis — we parse the
post-optimization HLO (``compiled.as_text()``, per-device shapes) and sum
the effective wire traffic of every collective with ring-algorithm factors:

  all-gather      out_bytes · (n-1)/n
  reduce-scatter  in_bytes  · (n-1)/n
  all-reduce      2 · bytes · (n-1)/n
  all-to-all      bytes · (n-1)/n
  collective-permute  bytes

``n`` is read from the op's replica_groups. Pod-axis (DCN) traffic is
reported separately when the group spans more devices than one pod's 256.
"""
from __future__ import annotations

import re
from typing import Dict, Optional

from repro.launch.mesh import (
    DCN_BW,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

# result shapes: "bf16[2,128]{1,0}" possibly inside a tuple "(bf16[..], ..)"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, *, pod_size: int = 256) -> Dict[str, float]:
    """Effective per-chip wire bytes by collective kind (+ ici/dcn split)."""
    out = {k: 0.0 for k in _COLLECTIVES}
    out["ici_bytes"] = 0.0
    out["dcn_bytes"] = 0.0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done" in line.split("=")[1][:40]:
            continue
        nbytes = _shape_bytes(shape_str)
        # group size n
        n = None
        g = _GROUPS_RE.search(line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip() != ""])
        else:
            g2 = _GROUPS_V2_RE.search(line)
            if g2:
                n = int(g2.group(2))
        n = n or 2
        frac = (n - 1) / n
        if kind == "all-gather":
            wire = nbytes * frac            # result bytes, ring
        elif kind == "reduce-scatter":
            wire = nbytes * n * frac        # result is 1/n of the input
        elif kind == "all-reduce":
            wire = 2 * nbytes * frac
        elif kind == "all-to-all":
            wire = nbytes * frac
        else:                               # collective-permute
            wire = nbytes
        out[kind] += wire
        if n > pod_size:
            out["dcn_bytes"] += wire
        else:
            out["ici_bytes"] += wire
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll: Dict[str, float]) -> Dict[str, float]:
    """All inputs are per-chip. Returns the three terms in seconds."""
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    ici_s = coll.get("ici_bytes", 0.0) / ICI_BW
    dcn_s = coll.get("dcn_bytes", 0.0) / DCN_BW
    collective_s = ici_s + dcn_s
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s, "ici_s": ici_s, "dcn_s": dcn_s}
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["bottleneck"] = dom
    step_s = max(compute_s, memory_s, collective_s)
    terms["step_time_lb_s"] = step_s
    terms["roofline_fraction"] = (compute_s / step_s) if step_s > 0 else 0.0
    return terms


def model_flops(cfg, *, tokens: Optional[int] = None, train: bool = True,
                extra: float = 0.0) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) for LM configs;
    `extra` lets callers add attention FLOPs etc. GLOBAL (all chips)."""
    if hasattr(cfg, "n_active_params"):
        n = cfg.n_active_params()
    elif hasattr(cfg, "n_params"):
        n = cfg.n_params()
    else:
        return 0.0
    mult = 6.0 if train else 2.0
    return mult * n * (tokens or 0) + extra
