"""First-principles HLO cost model with loop-trip-count accounting.

XLA's ``HloCostAnalysis`` (and hence ``compiled.cost_analysis()``) visits
every computation ONCE — a scan-over-layers model under-counts FLOPs/bytes/
collectives by the trip count (verified: stablelm train FLOPs low by ~24×,
its layer count). This module re-walks the optimized HLO text:

  1. split into computations; build the call graph (while bodies with
     ``known_trip_count``, fusion ``calls=``, conditional branches)
  2. propagate an execution multiplier from ENTRY
  3. per instruction: output bytes (writes), operand bytes (reads, resolved
     from the instruction's operand names / computation parameters), dot
     FLOPs (2 · out_elems · contracted_size from the dims spec), collective
     wire bytes with ring factors — each scaled by the multiplier.

Fusion-internal instructions are skipped for BYTES (a fusion reads its
operands and writes its result once — that is the fusion boundary XLA
materializes) but WALKED for FLOPs (dots inside fusions still execute).
"""
from __future__ import annotations

import collections
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 1,
    "pred": 1, "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems(shape_str: str) -> int:
    total = 0
    for _, dims in _shape_list(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


class Instr:
    __slots__ = ("name", "shape", "op", "rest", "line")

    def __init__(self, name, shape, op, rest, line):
        self.name, self.shape, self.op, self.rest, self.line = \
            name, shape, op, rest, line


def _parse(text: str):
    comps: Dict[str, List[Instr]] = {}
    params: Dict[str, Dict[str, str]] = {}
    cur = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and ("{" in line or line.rstrip().endswith("->")):
            cur = hdr.group(1)
            comps[cur] = []
            params[cur] = {}
            # parameter shapes from the signature
            sig = line[line.find("("):line.rfind("->")]
            for pm in re.finditer(r"%?([\w.\-]+):\s*((?:\([^)]*\))|\w+\[[\d,]*\](?:\{[^}]*\})?)", sig):
                params[cur][pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4), line))
    return comps, params


def _multipliers(comps) -> Dict[str, float]:
    """Propagate execution counts through while/fusion/conditional edges."""
    entry = None
    called = set()
    edges: Dict[str, List[Tuple[str, float]]] = collections.defaultdict(list)
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while":
                body = _BODY_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                trip = _TRIP_RE.search(ins.line)
                n = float(trip.group(1)) if trip else 1.0
                if body:
                    edges[cname].append((body.group(1), n))
                    called.add(body.group(1))
                if cond:
                    edges[cname].append((cond.group(1), n + 1))
                    called.add(cond.group(1))
            elif ins.op == "conditional":
                br = _BRANCHES_RE.search(ins.line)
                if br:
                    for b in _OPERAND_RE.findall(br.group(1)):
                        edges[cname].append((b, 1.0))
                        called.add(b)
            else:
                c = _CALLS_RE.search(ins.line)
                if c:
                    edges[cname].append((c.group(1), 1.0))
                    called.add(c.group(1))
                # reductions reference to_apply computations — negligible
    roots = [c for c in comps if c not in called]
    mult = {c: 0.0 for c in comps}
    # entry = the root with the most instructions (main)
    entry = max(roots, key=lambda c: len(comps[c])) if roots else None
    if entry is None:
        return {c: 1.0 for c in comps}
    stack = [(entry, 1.0)]
    while stack:
        c, m = stack.pop()
        mult[c] = mult.get(c, 0.0) + m
        for child, n in edges.get(c, ()):
            stack.append((child, m * n))
    return mult


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    ops = _OPERAND_RE.findall(ins.rest.split("),")[0] + ")")
    lhs = shapes.get(ops[0]) if ops else None
    out_e = _elems(ins.shape)
    cd = _DIMS_RE.search(ins.line)
    contracted = 1
    if lhs is not None and cd is not None:
        dims = _shape_list(lhs)
        if dims:
            _, ldims = dims[0]
            for d in (int(x) for x in cd.group(1).split(",") if x):
                if d < len(ldims):
                    contracted *= ldims[d]
    return 2.0 * out_e * contracted


_FUSION_KINDS = ("fusion",)


_METADATA_RE = re.compile(r'op_name="([^"]+)"')


def _short_op(line: str) -> str:
    m = _METADATA_RE.search(line)
    if not m:
        return "?"
    tail = "/".join(m.group(1).split("/")[-3:])
    return re.sub(r"\d+", "", tail)[:70]


def analyze(text: str, *, pod_size: int = 256,
            by_op: bool = False) -> Dict[str, float]:
    comps, params = _parse(text)
    mult = _multipliers(comps)

    flops = 0.0
    bytes_rw = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_by_op: Dict[str, float] = collections.Counter()
    bytes_by_op: Dict[str, float] = collections.Counter()
    ici = dcn = 0.0
    fusion_names = set()
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op in _FUSION_KINDS:
                c = _CALLS_RE.search(ins.line)
                if c:
                    fusion_names.add(c.group(1))

    for cname, instrs in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_names
        shapes = dict(params.get(cname, {}))
        for ins in instrs:
            shapes[ins.name] = ins.shape
        for ins in instrs:
            op = ins.op
            if op in ("dot", "dot-general", "convolution") or \
                    op.startswith("dot"):
                flops += m * _dot_flops(ins, shapes)
            if in_fusion:
                continue                      # bytes at fusion boundary only
            base = op.split("-start")[0]
            if base in _COLLECTIVES:
                nbytes = _shape_bytes(ins.shape)
                g = _GROUPS_RE.search(ins.line)
                n = len([x for x in g.group(1).split(",") if x.strip()]) \
                    if g else None
                if n is None:
                    g2 = _GROUPS_V2_RE.search(ins.line)
                    n = int(g2.group(2)) if g2 else 2
                frac = (n - 1) / max(n, 1)
                if base == "all-gather":
                    wire = nbytes * frac
                elif base == "reduce-scatter":
                    wire = nbytes * n * frac
                elif base == "all-reduce":
                    wire = 2 * nbytes * frac
                elif base == "all-to-all":
                    wire = nbytes * frac
                else:
                    wire = nbytes
                wire *= m
                coll[base] += wire
                if by_op:
                    coll_by_op[f"{base}|{_short_op(ins.line)}"] += wire
                if n > pod_size:
                    dcn += wire
                else:
                    ici += wire
                continue
            if op in ("parameter", "constant", "tuple", "get-tuple-element",
                      "bitcast", "while", "conditional", "after-all",
                      "partition-id", "replica-id"):
                continue
            # memory traffic: write output + read operands (fusion boundary)
            out_b = _shape_bytes(ins.shape)
            read_b = 0
            for opn in _OPERAND_RE.findall(ins.rest)[:8]:
                s = shapes.get(opn)
                if s:
                    read_b += _shape_bytes(s)
            bytes_rw += m * (out_b + read_b)
            if by_op:
                bytes_by_op[_short_op(ins.line)] += m * (out_b + read_b)

    coll["ici_bytes"] = ici
    coll["dcn_bytes"] = dcn
    coll["total"] = sum(coll[k] for k in _COLLECTIVES)
    out = {"flops": flops, "bytes": bytes_rw, "coll": coll}
    if by_op:
        out["coll_by_op"] = dict(coll_by_op)
        out["bytes_by_op"] = dict(bytes_by_op)
    return out
