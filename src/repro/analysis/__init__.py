from repro.analysis.roofline import (  # noqa: F401
    collective_bytes,
    model_flops,
    roofline_terms,
)
