"""dlrm-mlperf — MLPerf DLRM benchmark config (Criteo 1TB). [arXiv:1906.00091]."""
from repro.configs import base, register


def config():
    return base.DLRMConfig()


def shapes():
    return base.REC_SHAPES


register("dlrm-mlperf", config, shapes)
