"""moonshot-v1-16b-a3b (Moonlight) — MoE 64e top-6, GQA kv=16.

[hf:moonshotai/Moonlight-16B-A3B]. Per assignment table: 48L d=2048 16H kv=16
d_ff(expert)=1408 vocab=163840.
"""
from repro.configs import base, register


def config():
    return base.LMConfig(
        arch_id="moonshot-v1-16b-a3b",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1408,
        vocab_size=163_840,
        moe=base.MoESpec(n_experts=64, top_k=6, d_ff_expert=1408),
    )


def shapes():
    return base.lm_shapes("moonshot-v1-16b-a3b", full_attention_only=True)


register("moonshot-v1-16b-a3b", config, shapes)
