"""stablelm-1.6b — dense LM, MHA (kv=32). [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs import base, register


def config():
    return base.LMConfig(
        arch_id="stablelm-1.6b",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=5632,
        vocab_size=100_352,
        qkv_bias=True,
    )


def shapes():
    return base.lm_shapes("stablelm-1.6b", full_attention_only=True)


register("stablelm-1.6b", config, shapes)
