"""Architecture registry: ``get_config(arch_id)`` / ``get_shapes(arch_id)``.

Every assigned architecture (plus the paper's own dual encoder) registers an
exact full config and its shape cells here.
"""
from __future__ import annotations

from repro.configs import base
from repro.configs.base import reduced  # re-export

_REGISTRY = {}


def register(arch_id, cfg_fn, shapes_fn):
    _REGISTRY[arch_id] = (cfg_fn, shapes_fn)


def arch_ids():
    return sorted(_REGISTRY)


def get_config(arch_id: str):
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {arch_ids()}")
    return _REGISTRY[arch_id][0]()


def get_shapes(arch_id: str):
    return _REGISTRY[arch_id][1]()


def get_shape(arch_id: str, shape_name: str):
    for s in get_shapes(arch_id):
        if s.name == shape_name:
            return s
    raise KeyError(f"arch {arch_id} has no shape {shape_name!r}")


# --- import registrations (order: LM, gnn, recsys, paper) ---
from repro.configs import gemma3_27b          # noqa: F401,E402
from repro.configs import stablelm_1_6b       # noqa: F401,E402
from repro.configs import qwen2_7b            # noqa: F401,E402
from repro.configs import moonshot_16b_a3b    # noqa: F401,E402
from repro.configs import kimi_k2_1t_a32b     # noqa: F401,E402
from repro.configs import gatedgcn            # noqa: F401,E402
from repro.configs import mind                # noqa: F401,E402
from repro.configs import bert4rec            # noqa: F401,E402
from repro.configs import xdeepfm             # noqa: F401,E402
from repro.configs import dlrm_mlperf         # noqa: F401,E402
from repro.configs import list_dual_encoder   # noqa: F401,E402
