"""kimi-k2-1t-a32b — trillion-param MoE, 384e top-8, GQA kv=8.

[arXiv:2501.kimi2 per assignment table]. 61L d=7168 64H kv=8 d_ff(expert)=2048
vocab=163840. Uses Adafactor (factored 2nd moment) so optimizer state fits the
16 GB/chip HBM budget at 512 chips (see DESIGN.md §5).
"""
from repro.configs import base, register


def config():
    return base.LMConfig(
        arch_id="kimi-k2-1t-a32b",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=2048,
        vocab_size=163_840,
        moe=base.MoESpec(n_experts=384, top_k=8, d_ff_expert=2048),
        optimizer="adafactor",
        param_dtype="bfloat16",   # 1T params: bf16 master + Adafactor
    )


def shapes():
    return base.lm_shapes("kimi-k2-1t-a32b", full_attention_only=True)


register("kimi-k2-1t-a32b", config, shapes)
