"""mind — multi-interest capsule retrieval. [arXiv:1904.08030]."""
from repro.configs import base, register


def config():
    return base.MINDConfig()


def shapes():
    return base.REC_SHAPES


register("mind", config, shapes)
