"""gatedgcn — 16L d_hidden=70, gated edge aggregation. [arXiv:2003.00982]."""
from repro.configs import base, register


def config():
    return base.GNNConfig(arch_id="gatedgcn", n_layers=16, d_hidden=70,
                          aggregator="gated")


def shapes():
    return base.GNN_SHAPES


register("gatedgcn", config, shapes)
