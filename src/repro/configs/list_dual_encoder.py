"""list-dual-encoder — the paper's own relevance-model architecture.

BERT-base geometry (12L/768/12H) dual encoder + LIST hyperparameters
(Table 2 of the paper). Shapes mirror the paper's workloads: contrastive
training, corpus embedding (encode), query serving through the index, and
pseudo-label mining (brute-force scoring sweep).
"""
from repro.configs import base, register
from repro.configs.base import ShapeSpec


def config():
    return base.DualEncoderConfig()


def shapes():
    return (
        # Contrastive training step: (query, positive, b hard negatives).
        ShapeSpec("contrastive_train", "de_train",
                  dict(global_batch=4096, max_len=64, hard_negs=4)),
        # Offline corpus embedding at Geo-Glue scale (2.85M objects).
        ShapeSpec("encode_corpus", "de_encode",
                  dict(global_batch=16384, max_len=64)),
        # Query phase: route + fused score + top-k over cluster buffers.
        ShapeSpec("serve_queries", "list_serve",
                  dict(query_batch=4096, n_objects=2_849_754, n_clusters=300,
                       topk=20)),
        # Pseudo-label mining: distributed brute-force score + window select.
        ShapeSpec("mine_negatives", "list_mine",
                  dict(query_batch=1024, n_objects=2_849_754,
                       neg_start=180_000, neg_end=181_000)),
    )


register("list-dual-encoder", config, shapes)
