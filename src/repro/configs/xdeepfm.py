"""xdeepfm — CIN + deep MLP CTR model. [arXiv:1803.05170]."""
from repro.configs import base, register


def config():
    return base.XDeepFMConfig()


def shapes():
    return base.REC_SHAPES


register("xdeepfm", config, shapes)
